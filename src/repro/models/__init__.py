from . import dlrm, gnn, layers, transformer  # noqa: F401
