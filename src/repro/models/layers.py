"""Shared layers: norms, rotary embedding, init, sharding helpers."""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# ------------------------------------------------------------------ sharding
def shard(x, spec: Optional[P]):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def axis_size_divides(n: int, mesh, axis) -> bool:
    if mesh is None or axis is None:
        return True
    sz = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        sz *= mesh.shape[a]
    return n % sz == 0


# -------------------------------------------------------------------- norms
def rms_norm(x, w, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layer_norm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


# -------------------------------------------------------------------- rotary
def rope_freqs(d_head: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2) / d_head))


def apply_rope(x, positions, theta: float = 10000.0):
    """x [..., S, D]; positions [..., S] (absolute)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs       # [..., S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- init
def dense_init(key, shape: Sequence[int], in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape) * std).astype(dtype)


def mlp_params(key, sizes: Sequence[int], dtype=jnp.float32):
    ks = jax.random.split(key, len(sizes) - 1)
    return [
        dict(w=dense_init(ks[i], (sizes[i], sizes[i + 1]), dtype=dtype),
             b=jnp.zeros((sizes[i + 1],), dtype))
        for i in range(len(sizes) - 1)
    ]


def mlp_apply(params, x, act=jax.nn.relu, final_act=False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


def cross_entropy_loss(logits, labels, vocab_spec: Optional[P] = None):
    """Token-mean CE; logits may be sharded over vocab (model axis)."""
    logits = shard(logits.astype(jnp.float32), vocab_spec)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - ll).mean()
