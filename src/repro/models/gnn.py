"""GNN model zoo: PNA, EGNN, MeshGraphNet, SchNet.

All four are message-passing networks built on the same primitive the query
engine uses: gather-by-src → edge compute → segment-reduce-by-dst
(`jax.ops.segment_sum` / the `bucket_scatter` Pallas kernel).  JAX has no
sparse message-passing op — this scatter substrate IS part of the system.

Graphs are structure-of-arrays ``GraphBatch``; batched small graphs
(molecule shape) are flattened into one disjoint graph with a node→graph map.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .layers import layer_norm, mlp_apply, mlp_params


@dataclasses.dataclass
class GraphBatch:
    node_feat: jnp.ndarray          # [N, F]
    edge_src: jnp.ndarray           # [E]
    edge_dst: jnp.ndarray           # [E]
    coords: Optional[jnp.ndarray] = None     # [N, 3] (EGNN / SchNet / MGN)
    edge_feat: Optional[jnp.ndarray] = None  # [E, Fe]
    graph_of: Optional[jnp.ndarray] = None   # [N] graph id (batched-small)
    n_graphs: int = 1
    targets: Optional[jnp.ndarray] = None


def _agg(values, dst, n, op="sum"):
    if op == "sum":
        return jax.ops.segment_sum(values, dst, num_segments=n)
    if op == "mean":
        s = jax.ops.segment_sum(values, dst, num_segments=n)
        c = jax.ops.segment_sum(jnp.ones((values.shape[0], 1), values.dtype), dst,
                                num_segments=n)
        return s / jnp.maximum(c, 1.0)
    if op == "max":
        out = jax.ops.segment_max(values, dst, num_segments=n)
        return jnp.where(jnp.isfinite(out), out, 0.0)   # empty segments → 0
    if op == "min":
        out = jax.ops.segment_min(values, dst, num_segments=n)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(op)


# ====================================================================== PNA
@dataclasses.dataclass(frozen=True)
class PNACfg:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    aggregators: Sequence[str] = ("mean", "max", "min", "std")
    scalers: Sequence[str] = ("identity", "amplification", "attenuation")
    out_dim: int = 1


def pna_init(cfg: PNACfg, key, in_dim: int) -> Dict:
    ks = jax.random.split(key, cfg.n_layers + 2)
    n_in = len(cfg.aggregators) * len(cfg.scalers) * cfg.d_hidden + cfg.d_hidden
    return dict(
        encoder=mlp_params(ks[0], [in_dim, cfg.d_hidden]),
        layers=[
            dict(
                pre=mlp_params(ks[i + 1], [2 * cfg.d_hidden, cfg.d_hidden]),
                post=mlp_params(ks[i + 1], [n_in, cfg.d_hidden, cfg.d_hidden]),
            )
            for i in range(cfg.n_layers)
        ],
        decoder=mlp_params(ks[-1], [cfg.d_hidden, cfg.d_hidden, cfg.out_dim]),
    )


def pna_apply(cfg: PNACfg, params, g: GraphBatch) -> jnp.ndarray:
    n = g.node_feat.shape[0]
    h = mlp_apply(params["encoder"], g.node_feat, final_act=True)
    deg = jax.ops.segment_sum(jnp.ones_like(g.edge_dst, dtype=jnp.float32),
                              g.edge_dst, num_segments=n)
    log_deg = jnp.log1p(deg)[:, None]
    mean_log_deg = jnp.maximum(log_deg.mean(), 1e-6)
    for lp in params["layers"]:
        msg_in = jnp.concatenate([h[g.edge_src], h[g.edge_dst]], axis=-1)
        msg = mlp_apply(lp["pre"], msg_in, final_act=True)
        aggs = []
        mean = _agg(msg, g.edge_dst, n, "mean")
        for a in cfg.aggregators:
            if a == "std":
                sq = _agg(msg * msg, g.edge_dst, n, "mean")
                aggs.append(jnp.sqrt(jnp.maximum(sq - mean * mean, 1e-8)))
            elif a == "mean":
                aggs.append(mean)
            else:
                aggs.append(_agg(msg, g.edge_dst, n, a))
        scaled = []
        for s in cfg.scalers:
            for a in aggs:
                if s == "identity":
                    scaled.append(a)
                elif s == "amplification":
                    scaled.append(a * (log_deg / mean_log_deg))
                else:  # attenuation (degree-0 nodes get factor 1)
                    att = jnp.where(deg[:, None] > 0,
                                    mean_log_deg / jnp.maximum(log_deg, 1e-6), 1.0)
                    scaled.append(a * att)
        h = h + mlp_apply(lp["post"], jnp.concatenate(scaled + [h], axis=-1),
                          final_act=True)
    return mlp_apply(params["decoder"], h)


# ===================================================================== EGNN
@dataclasses.dataclass(frozen=True)
class EGNNCfg:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    out_dim: int = 1


def egnn_init(cfg: EGNNCfg, key, in_dim: int) -> Dict:
    ks = jax.random.split(key, cfg.n_layers * 3 + 2)
    d = cfg.d_hidden
    return dict(
        encoder=mlp_params(ks[0], [in_dim, d]),
        layers=[
            dict(
                phi_e=mlp_params(ks[3 * i + 1], [2 * d + 1, d, d]),
                phi_x=mlp_params(ks[3 * i + 2], [d, d, 1]),
                phi_h=mlp_params(ks[3 * i + 3], [2 * d, d, d]),
            )
            for i in range(cfg.n_layers)
        ],
        decoder=mlp_params(ks[-1], [d, d, cfg.out_dim]),
    )


def egnn_apply(cfg: EGNNCfg, params, g: GraphBatch):
    """E(n)-equivariant layers: scalar messages from invariant distances,
    coordinate updates along relative vectors."""
    n = g.node_feat.shape[0]
    h = mlp_apply(params["encoder"], g.node_feat, final_act=True)
    x = g.coords
    src, dst = g.edge_src, g.edge_dst
    for lp in params["layers"]:
        rel = x[src] - x[dst]
        d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
        m = mlp_apply(lp["phi_e"], jnp.concatenate([h[src], h[dst], d2], -1),
                      final_act=True)
        coef = jnp.tanh(mlp_apply(lp["phi_x"], m))          # bounded for stability
        dx = _agg(rel * coef, dst, n, "mean")
        x = x + dx
        magg = _agg(m, dst, n, "sum")
        h = h + mlp_apply(lp["phi_h"], jnp.concatenate([h, magg], -1), final_act=True)
    return mlp_apply(params["decoder"], h), x


# ============================================================ MeshGraphNet
@dataclasses.dataclass(frozen=True)
class MGNCfg:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    out_dim: int = 3


def _mgn_mlp(key, sizes):
    return mlp_params(key, sizes)


def mgn_init(cfg: MGNCfg, key, in_dim: int, edge_in: int = 4) -> Dict:
    d = cfg.d_hidden
    hidden = [d] * cfg.mlp_layers
    ks = jax.random.split(key, 2 * cfg.n_layers + 3)
    return dict(
        node_enc=_mgn_mlp(ks[0], [in_dim] + hidden),
        edge_enc=_mgn_mlp(ks[1], [edge_in] + hidden),
        layers=[
            dict(
                edge_mlp=_mgn_mlp(ks[2 + 2 * i], [3 * d] + hidden),
                node_mlp=_mgn_mlp(ks[3 + 2 * i], [2 * d] + hidden),
                ln_e=dict(w=jnp.ones(d), b=jnp.zeros(d)),
                ln_n=dict(w=jnp.ones(d), b=jnp.zeros(d)),
            )
            for i in range(cfg.n_layers)
        ],
        decoder=_mgn_mlp(ks[-1], hidden + [cfg.out_dim]),
    )


def mgn_apply(cfg: MGNCfg, params, g: GraphBatch):
    n = g.node_feat.shape[0]
    src, dst = g.edge_src, g.edge_dst
    h = mlp_apply(params["node_enc"], g.node_feat, final_act=True)
    if g.edge_feat is not None:
        e = mlp_apply(params["edge_enc"], g.edge_feat, final_act=True)
    else:
        rel = g.coords[src] - g.coords[dst]
        ef = jnp.concatenate([rel, jnp.linalg.norm(rel, axis=-1, keepdims=True)], -1)
        e = mlp_apply(params["edge_enc"], ef, final_act=True)
    for lp in params["layers"]:
        e_new = mlp_apply(lp["edge_mlp"], jnp.concatenate([e, h[src], h[dst]], -1),
                          final_act=True)
        e = e + layer_norm(e_new, lp["ln_e"]["w"], lp["ln_e"]["b"])
        agg = _agg(e, dst, n, "sum")
        h_new = mlp_apply(lp["node_mlp"], jnp.concatenate([h, agg], -1),
                          final_act=True)
        h = h + layer_norm(h_new, lp["ln_n"]["w"], lp["ln_n"]["b"])
    return mlp_apply(params["decoder"], h)


# ==================================================================== SchNet
@dataclasses.dataclass(frozen=True)
class SchNetCfg:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    out_dim: int = 1


def schnet_init(cfg: SchNetCfg, key, in_dim: int) -> Dict:
    d = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_interactions * 3 + 2)
    return dict(
        encoder=mlp_params(ks[0], [in_dim, d]),
        interactions=[
            dict(
                filter_net=mlp_params(ks[3 * i + 1], [cfg.n_rbf, d, d]),
                in_proj=mlp_params(ks[3 * i + 2], [d, d]),
                out_proj=mlp_params(ks[3 * i + 3], [d, d, d]),
            )
            for i in range(cfg.n_interactions)
        ],
        decoder=mlp_params(ks[-1], [d, d, cfg.out_dim]),
    )


def _rbf_expand(dist, n_rbf, cutoff):
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def _cosine_cutoff(dist, cutoff):
    c = 0.5 * (jnp.cos(jnp.pi * dist / cutoff) + 1.0)
    return jnp.where(dist < cutoff, c, 0.0)


def schnet_apply(cfg: SchNetCfg, params, g: GraphBatch):
    """Continuous-filter convolutions: W(r_ij) ⊙ h_j summed over neighbors."""
    n = g.node_feat.shape[0]
    src, dst = g.edge_src, g.edge_dst
    h = mlp_apply(params["encoder"], g.node_feat)
    dist = jnp.linalg.norm(g.coords[src] - g.coords[dst] + 1e-9, axis=-1)
    rbf = _rbf_expand(dist, cfg.n_rbf, cfg.cutoff)
    cut = _cosine_cutoff(dist, cfg.cutoff)[:, None]
    for lp in params["interactions"]:
        W = mlp_apply(lp["filter_net"], rbf, act=jax.nn.softplus, final_act=True) * cut
        hj = mlp_apply(lp["in_proj"], h)[src]
        msg = _agg(hj * W, dst, n, "sum")
        h = h + mlp_apply(lp["out_proj"], msg, act=jax.nn.softplus)
    out = mlp_apply(params["decoder"], h)
    if g.graph_of is not None:
        return jax.ops.segment_sum(out, g.graph_of, num_segments=g.n_graphs)
    return out


# ------------------------------------------------------------- loss wrappers
def gnn_loss(arch: str, cfg, params, g: GraphBatch) -> jnp.ndarray:
    if arch == "pna":
        pred = pna_apply(cfg, params, g)
    elif arch == "egnn":
        pred, _ = egnn_apply(cfg, params, g)
    elif arch == "meshgraphnet":
        pred = mgn_apply(cfg, params, g)
    elif arch == "schnet":
        pred = schnet_apply(cfg, params, g)
    else:
        raise ValueError(arch)
    tgt = g.targets
    if tgt is None or tgt.shape[0] != pred.shape[0]:
        tgt = jnp.zeros_like(pred)   # graph-level heads w/ node targets: MSE to 0
    elif tgt.shape != pred.shape:
        tgt = jnp.broadcast_to(tgt.reshape(tgt.shape[0], -1)[:, : pred.shape[-1]],
                               pred.shape)
    return jnp.mean((pred.astype(jnp.float32) - tgt.astype(jnp.float32)) ** 2)


INIT = {"pna": pna_init, "egnn": egnn_init, "meshgraphnet": mgn_init,
        "schnet": schnet_init}
