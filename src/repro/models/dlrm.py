"""DLRM (RM2 variant): huge sparse embedding tables → dot interaction → MLPs.

JAX has no native EmbeddingBag — implemented as gather + masked reduce
(`kernels/embedding_bag` provides the fused Pallas version; the XLA path is
the oracle).  Tables are row-sharded over the model axis at scale (the DLRM
analogue of the paper's type-based partitioning: the lookup hot path is a
distributed gather).  ``retrieval_score`` scores one query against N
candidates as a batched dot (no loop).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..kernels.embedding_bag import embedding_bag
from .layers import mlp_apply, mlp_params, shard


@dataclasses.dataclass(frozen=True)
class DLRMCfg:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    bot_mlp: Sequence[int] = (13, 512, 256, 64)
    top_mlp: Sequence[int] = (512, 512, 256, 1)
    vocab_sizes: Optional[Sequence[int]] = None   # default 1M rows each
    multi_hot: int = 1                            # lookups per field
    dtype: object = jnp.float32
    data_axes: Optional[tuple] = ("pod", "data")
    model_axis: Optional[str] = "model"
    ebag_impl: str = "xla"

    def vocabs(self) -> List[int]:
        if self.vocab_sizes is not None:
            return list(self.vocab_sizes)
        return [1_000_000] * self.n_sparse

    def interaction_dim(self) -> int:
        f = self.n_sparse + 1
        return self.embed_dim + f * (f - 1) // 2

    def param_count(self) -> int:
        n = sum(self.vocabs()) * self.embed_dim
        sizes = list(self.bot_mlp)
        for i in range(len(sizes) - 1):
            n += sizes[i] * sizes[i + 1] + sizes[i + 1]
        tops = [self.interaction_dim()] + list(self.top_mlp)[1:]
        for i in range(len(tops) - 1):
            n += tops[i] * tops[i + 1] + tops[i + 1]
        return n


def init_params(cfg: DLRMCfg, key) -> Dict:
    ks = jax.random.split(key, cfg.n_sparse + 2)
    tables = [
        (jax.random.normal(ks[i], (v, cfg.embed_dim)) * v ** -0.25).astype(cfg.dtype)
        for i, v in enumerate(cfg.vocabs())
    ]
    top_sizes = [cfg.interaction_dim()] + list(cfg.top_mlp)[1:]
    return dict(
        tables=tables,
        bot=mlp_params(ks[-2], list(cfg.bot_mlp)),
        top=mlp_params(ks[-1], top_sizes),
    )


def param_specs(cfg: DLRMCfg, mesh=None) -> Dict:
    tp = cfg.model_axis

    def tspec(v):
        if tp is None or (mesh is not None and v % mesh.shape[tp] != 0):
            return P(None, None)
        return P(tp, None)   # row-sharded tables

    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    specs = jax.tree_util.tree_map(lambda _: P(), shapes)
    specs["tables"] = [tspec(v) for v in cfg.vocabs()]
    return specs


def forward(cfg: DLRMCfg, params, dense, sparse_idx) -> jnp.ndarray:
    """dense [B, n_dense] float; sparse_idx [B, n_sparse, multi_hot] int32.

    Returns logits [B]."""
    B = dense.shape[0]
    dp = cfg.data_axes
    x = shard(dense.astype(cfg.dtype), P(dp, None) if dp else None)
    bot = mlp_apply(params["bot"], x, final_act=True)            # [B, d]
    embs = []
    for f in range(cfg.n_sparse):
        idx = sparse_idx[:, f, :]
        e = embedding_bag(params["tables"][f], idx, mode="sum", impl=cfg.ebag_impl)
        embs.append(e)
    feats = jnp.stack([bot] + embs, axis=1)                      # [B, F+1, d]
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)             # pairwise dots
    fdim = feats.shape[1]
    iu, ju = jnp.triu_indices(fdim, k=1)
    flat = inter[:, iu, ju]                                      # [B, F(F-1)/2]
    z = jnp.concatenate([bot, flat], axis=-1)
    out = mlp_apply(params["top"], z)
    return out[:, 0].astype(jnp.float32)


def loss_fn(cfg: DLRMCfg, params, batch) -> jnp.ndarray:
    logits = forward(cfg, params, batch["dense"], batch["sparse"])
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def serve_score(cfg: DLRMCfg, params, dense, sparse_idx) -> jnp.ndarray:
    return jax.nn.sigmoid(forward(cfg, params, dense, sparse_idx))


def retrieval_score(cfg: DLRMCfg, params, dense_q, sparse_q, cand_emb,
                    top_k: int = 100):
    """Score 1 query against n_candidates item embeddings (batched dot +
    top-k), the retrieval_cand shape."""
    q = forward_user_tower(cfg, params, dense_q, sparse_q)       # [1, d]
    scores = (cand_emb.astype(jnp.float32) @ q[0].astype(jnp.float32))  # [N]
    vals, idx = jax.lax.top_k(scores, top_k)
    return vals, idx


def forward_user_tower(cfg: DLRMCfg, params, dense, sparse_idx):
    bot = mlp_apply(params["bot"], dense.astype(cfg.dtype), final_act=True)
    embs = [
        embedding_bag(params["tables"][f], sparse_idx[:, f, :], mode="sum",
                      impl=cfg.ebag_impl)
        for f in range(cfg.n_sparse)
    ]
    return (bot + sum(embs)).astype(jnp.float32)
