"""Configurable LM transformer family (llama3 / minicpm / gemma3 / olmoe /
mixtral) — GQA + RoPE + SwiGLU, optional MoE, sliding-window & local:global
attention patterns, scan-over-layers with remat, MaxText-style sharding.

Design notes (dry-run relevant):
  * Layers are scanned (stacked [L, ...] params) so the HLO is O(1) in depth;
    remat policy saves only the layer-boundary carry, which is sharded
    (sequence-parallel) over the model axis so 126-layer × 4k-seq activations
    fit HBM (DESIGN.md §5).
  * Per-layer attention windows are a scanned int32[L] array (2^30 = full
    attention), so gemma3's 5:1 local:global pattern and mixtral's SWA share
    one uniform scanned layer.
  * Decode KV caches are sharded over the model axis on the kv-head dim when
    divisible, else on d_head (scores/outputs recombine with a small
    all-reduce) — this keeps 126×32k caches inside 16 GB/chip.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..kernels.flash_attention import flash_attention
from .layers import apply_rope, cross_entropy_loss, rms_norm, shard

FULL_WINDOW = 1 << 30


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class TransformerCfg:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 500000.0
    norm_eps: float = 1e-6
    moe: Optional[MoECfg] = None
    sliding_window: Optional[int] = None   # local window size
    global_every: int = 0                  # 0: uniform; k: every k-th layer full
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    scan_layers: bool = True
    remat: bool = True
    moe_group_map: str = "vmap"            # 'vmap' | 'scan' (sequential groups,
                                           # E·C·F temp divided by group count)
    gqa_native: bool = False               # grouped-einsum GQA (§Perf it. 2)
                                           # False = baseline repeat-KV path
    decode_kv_constraint: str = ""         # ''|'dh'|'head': pre-shard the new
                                           # KV token to the cache layout so
                                           # DUS never reshards the full cache
    remat_inner: bool = False              # checkpoint MoE groups & attn
                                           # chunks (bwd recompute, temp ↓)
    kv_cache_quant: bool = False           # int8 KV cache w/ per-token-head
                                           # scales (≈2× decode memory floor)
    attention_impl: str = "xla"            # 'xla' | 'pallas' | 'pallas_interpret'
    # sharding axis names (None disables constraints, e.g. smoke tests)
    data_axes: Optional[tuple] = ("pod", "data")
    model_axis: Optional[str] = "model"
    seq_shard_carry: bool = True           # sequence-parallel layer boundary

    @property
    def full_attention_only(self) -> bool:
        return self.sliding_window is None

    def layer_windows(self) -> np.ndarray:
        w = np.full(self.n_layers, FULL_WINDOW, np.int32)
        if self.sliding_window is not None:
            w[:] = self.sliding_window
            if self.global_every > 0:
                w[self.global_every - 1 :: self.global_every] = FULL_WINDOW
        return w

    def param_count(self) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        attn = D * self.n_heads * self.d_head * 2 + D * self.n_kv_heads * self.d_head * 2
        if self.moe:
            mlp = self.moe.n_experts * 3 * D * self.moe.d_ff + D * self.moe.n_experts
        else:
            mlp = 3 * D * F
        return V * D * (1 if self.tie_embeddings else 2) + L * (attn + mlp + 2 * D) + D

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        D, L = self.d_model, self.n_layers
        attn = D * self.n_heads * self.d_head * 2 + D * self.n_kv_heads * self.d_head * 2
        mlp = self.moe.top_k * 3 * D * self.moe.d_ff + D * self.moe.n_experts
        return self.vocab * D + L * (attn + mlp + 2 * D) + D


# ------------------------------------------------------------------- params
def init_params(cfg: TransformerCfg, key) -> Dict:
    D, L = cfg.d_model, cfg.n_layers
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 12)
    dt = cfg.dtype

    def ninit(k, shape, fan_in):
        return (jax.random.normal(k, shape) * fan_in ** -0.5).astype(dt)

    layers = dict(
        ln1=jnp.ones((L, D), dt),
        ln2=jnp.ones((L, D), dt),
        wq=ninit(ks[0], (L, D, Hq * Dh), D),
        wk=ninit(ks[1], (L, D, Hkv * Dh), D),
        wv=ninit(ks[2], (L, D, Hkv * Dh), D),
        wo=ninit(ks[3], (L, Hq * Dh, D), Hq * Dh),
    )
    if cfg.moe:
        E, Fe = cfg.moe.n_experts, cfg.moe.d_ff
        layers.update(
            router=ninit(ks[4], (L, D, E), D),
            wg=ninit(ks[5], (L, E, D, Fe), D),
            wu=ninit(ks[6], (L, E, D, Fe), D),
            wd=ninit(ks[7], (L, E, Fe, D), Fe),
        )
    else:
        F = cfg.d_ff
        layers.update(
            wg=ninit(ks[5], (L, D, F), D),
            wu=ninit(ks[6], (L, D, F), D),
            wd=ninit(ks[7], (L, F, D), F),
        )
    params = dict(
        embed=ninit(ks[8], (cfg.vocab, D), D),
        ln_f=jnp.ones((D,), dt),
        layers=layers,
    )
    if not cfg.tie_embeddings:
        params["head"] = ninit(ks[9], (D, cfg.vocab), D)
    return params


def param_specs(cfg: TransformerCfg, mesh=None) -> Dict:
    """PartitionSpecs mirroring init_params (FSDP over data × TP over model)."""
    dp, tp = cfg.data_axes, cfg.model_axis
    if dp is None or tp is None:
        none_tree = jax.tree_util.tree_map(lambda _: P(), init_shapes(cfg))
        return none_tree

    def div(n, axis):
        if mesh is None:
            return axis
        sz = np.prod([mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))])
        return axis if n % sz == 0 else None

    Hq, Hkv, Dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    layers = dict(
        ln1=P(None, None),
        ln2=P(None, None),
        wq=P(None, div(D, dp), div(Hq * Dh, tp)),
        wk=P(None, div(D, dp), div(Hkv * Dh, tp)),
        wv=P(None, div(D, dp), div(Hkv * Dh, tp)),
        wo=P(None, div(Hq * Dh, tp), div(D, dp)),
    )
    if cfg.moe:
        E, Fe = cfg.moe.n_experts, cfg.moe.d_ff
        e_ax = div(E, tp)
        if e_ax is not None:   # expert parallelism over the model axis
            layers.update(
                router=P(None, None, None),
                wg=P(None, e_ax, div(D, dp), None),
                wu=P(None, e_ax, div(D, dp), None),
                wd=P(None, e_ax, None, div(D, dp)),
            )
        else:                  # tensor-parallel inside each expert
            layers.update(
                router=P(None, None, None),
                wg=P(None, None, div(D, dp), div(Fe, tp)),
                wu=P(None, None, div(D, dp), div(Fe, tp)),
                wd=P(None, None, div(Fe, tp), div(D, dp)),
            )
    else:
        F = cfg.d_ff
        layers.update(
            wg=P(None, div(D, dp), div(F, tp)),
            wu=P(None, div(D, dp), div(F, tp)),
            wd=P(None, div(F, tp), div(D, dp)),
        )
    specs = dict(
        embed=P(div(cfg.vocab, tp), None),
        ln_f=P(None),
        layers=layers,
    )
    if not cfg.tie_embeddings:
        specs["head"] = P(None, div(cfg.vocab, tp))
    return specs


def init_shapes(cfg: TransformerCfg):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


# ------------------------------------------------------------------ compute
def _attention(cfg: TransformerCfg, lp, x, positions, window, cache=None,
               cache_len=None):
    """One attention sub-layer.  x [B, S, D]; window traced int32."""
    B, S, D = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    tp, dp = cfg.model_axis, cfg.data_axes
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = (h @ lp["wq"]).reshape(B, S, Hq, Dh).transpose(0, 2, 1, 3)
    k = (h @ lp["wk"]).reshape(B, S, Hkv, Dh).transpose(0, 2, 1, 3)
    v = (h @ lp["wv"]).reshape(B, S, Hkv, Dh).transpose(0, 2, 1, 3)
    q = shard(q, P(dp, tp, None, None) if tp else None)
    k = apply_rope(k, positions[:, None, :], cfg.rope_theta)
    q = apply_rope(q, positions[:, None, :], cfg.rope_theta)

    if cache is not None:
        if cfg.decode_kv_constraint == "dh" and tp:
            k = shard(k, P(dp, None, None, tp))
            v = shard(v, P(dp, None, None, tp))
        elif cfg.decode_kv_constraint == "head" and tp:
            k = shard(k, P(dp, tp, None, None))
            v = shard(v, P(dp, tp, None, None))
        pos = cache_len - 1                          # scalar position of token
        if cfg.kv_cache_quant:
            qk8, qv8, sk, sv = cache                 # int8 caches + scales
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            qk8 = jax.lax.dynamic_update_slice(qk8, kq, (0, 0, pos, 0))
            qv8 = jax.lax.dynamic_update_slice(qv8, vq, (0, 0, pos, 0))
            sk = jax.lax.dynamic_update_slice(sk, ks, (0, 0, pos))
            sv = jax.lax.dynamic_update_slice(sv, vs, (0, 0, pos))
            ck = qk8.astype(jnp.float32) * sk.astype(jnp.float32)[..., None]
            cv = qv8.astype(jnp.float32) * sv.astype(jnp.float32)[..., None]
            new_quant_cache = (qk8, qv8, sk, sv)
        else:
            ck, cv = cache                           # [B, Hkv, Smax, Dh]
            ck = jax.lax.dynamic_update_slice(ck, k, (0, 0, pos, 0))
            cv = jax.lax.dynamic_update_slice(cv, v, (0, 0, pos, 0))
            new_quant_cache = None
        Smax = ck.shape[2]
        group = Hq // Hkv
        if cfg.gqa_native:
            # GQA-native grouped einsum: never materialise the repeated
            # [B, Hq, Smax, Dh] cache (§Perf iteration 2 — the repeat costs
            # group× cache bytes of HBM temp AND forces an involuntary
            # reshard of the d_head-sharded cache).
            qg = q.reshape(B, Hkv, group, S, Dh)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                           ck.astype(jnp.float32)) * (Dh ** -0.5)
            kpos = jnp.arange(Smax)[None, None, None, None, :]
            valid = (kpos < cache_len) & (kpos > pos - window)
            s = jnp.where(valid, s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bkgqs,bksd->bkgqd", p, cv.astype(jnp.float32))
            o = o.reshape(B, Hq, S, Dh).astype(x.dtype)
        else:
            # baseline: repeat KV heads to Hq (straightforward port)
            kk = jnp.repeat(ck, group, axis=1)
            vv = jnp.repeat(cv, group, axis=1)
            s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                           kk.astype(jnp.float32)) * (Dh ** -0.5)
            kpos = jnp.arange(Smax)[None, None, None, :]
            valid = (kpos < cache_len) & (kpos > pos - window)
            s = jnp.where(valid, s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhqk,bhkd->bhqd", p,
                           vv.astype(jnp.float32)).astype(x.dtype)
        new_cache = new_quant_cache if cfg.kv_cache_quant else (ck, cv)
    else:
        # flash path when the window is static; otherwise (scanned layers pass
        # a traced per-layer window) a q-chunked masked attention that never
        # materialises [B, H, S, S] — transient is [B, H, chunk, S].
        if isinstance(window, (int, np.integer)):
            win = None if window >= FULL_WINDOW else int(window)
            o = flash_attention(q, k, v, causal=True, window=win,
                                impl=cfg.attention_impl)
        else:
            o = _chunked_attention(q, k, v, window, Dh, native=cfg.gqa_native,
                                   remat_chunks=cfg.remat_inner)
        o = o.astype(x.dtype)
        new_cache = None
    o = o.transpose(0, 2, 1, 3).reshape(B, S, Hq * Dh)
    out = o @ lp["wo"]
    return x + out, new_cache


def _chunked_attention(q, k, v, window, Dh, chunk: int = 512,
                       native: bool = False, remat_chunks: bool = False):
    """Causal + sliding-window GQA attention, chunked over query blocks so the
    score transient is [B, H, chunk, S].  ``window`` may be traced.
    native=True consumes KV with grouped einsums (no group× repeat in HBM)."""
    B, Hq, S, _ = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    if native:
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
    else:
        kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
        vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kpos = jnp.arange(S, dtype=jnp.int32)

    if native:
        qc = q.reshape(B, Hkv, group, n_chunks, chunk, q.shape[-1])
        qc = qc.transpose(3, 0, 1, 2, 4, 5)        # [n, B, Hkv, g, c, D]
    else:
        qc = q.reshape(B, Hq, n_chunks, chunk, q.shape[-1]).transpose(2, 0, 1, 3, 4)

    def one(args):
        i, qb = args
        qpos = i * chunk + jnp.arange(chunk, dtype=jnp.int32)
        mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] > qpos[:, None] - window)
        if native:
            s = jnp.einsum("bkgqd,bksd->bkgqs", qb.astype(jnp.float32), kf)
            s = jnp.where(mask[None, None, None], s * (Dh ** -0.5), -1e30)
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
        s = jnp.einsum("bhqd,bhkd->bhqk", qb.astype(jnp.float32), kf)
        s = jnp.where(mask[None, None], s * (Dh ** -0.5), -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vf)

    fn = jax.checkpoint(one) if remat_chunks else one
    out = jax.lax.map(fn, (jnp.arange(n_chunks), qc))
    if native:
        out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, n_chunks * chunk, -1)
    else:
        out = out.transpose(1, 2, 0, 3, 4).reshape(B, Hq, n_chunks * chunk, -1)
    return out[:, :, :S]


def _mlp(cfg: TransformerCfg, lp, x):
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe is None:
        g = jax.nn.silu(h @ lp["wg"]) * (h @ lp["wu"])
        g = shard(g, P(cfg.data_axes, None, cfg.model_axis) if cfg.model_axis else None)
        return x + g @ lp["wd"]
    # ---- MoE: sort-based dispatch (MegaBlocks/MaxText-style).  Tokens are
    # grouped per sequence (group = batch row) so the expert buffers and the
    # scatter stay local to the data shard; capacity C = cf·k·S/E per group.
    # No [T, E, C] one-hot tensors are ever materialised.
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    C = max(1, int(m.capacity_factor * K * S / E))

    def group_moe(hg):  # hg [S, D] — one group
        logits = hg @ lp["router"]
        gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        topw, topi = jax.lax.top_k(gates, K)                     # [S, K]
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
        flat_e = topi.reshape(-1)                                # [S*K]
        flat_t = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)
        flat_w = topw.reshape(-1)
        order = jnp.argsort(flat_e)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        # rank within expert = index − first index of that expert id
        first = jnp.searchsorted(se, se, side="left")
        epos = jnp.arange(S * K, dtype=jnp.int32) - first.astype(jnp.int32)
        keep = (epos < C).astype(jnp.float32)
        slot = jnp.clip(se * C + epos, 0, E * C - 1)
        buf = jnp.zeros((E * C, D), cfg.dtype)
        buf = buf.at[slot].add((hg[st].astype(jnp.float32) * keep[:, None]
                                ).astype(cfg.dtype))
        xe = buf.reshape(E, C, D)
        ge = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, lp["wg"]))
        ue = jnp.einsum("ecd,edf->ecf", xe, lp["wu"])
        oe = jnp.einsum("ecf,efd->ecd", ge * ue, lp["wd"]).reshape(E * C, D)
        contrib = oe[slot].astype(jnp.float32) * (sw * keep)[:, None]
        out = jnp.zeros((S, D), jnp.float32).at[st].add(contrib)
        return out.astype(x.dtype)

    fn = jax.checkpoint(group_moe) if cfg.remat_inner else group_moe
    if cfg.moe_group_map == "scan":
        out = jax.lax.map(fn, h)          # sequential: temp ÷ n_groups
    else:
        out = jax.vmap(fn)(h)
    return x + out


def _layer(cfg: TransformerCfg, lp, x, positions, window):
    x, _ = _attention(cfg, lp, x, positions, window)
    x = _mlp(cfg, lp, x)
    if cfg.seq_shard_carry and cfg.model_axis:
        x = shard(x, P(cfg.data_axes, cfg.model_axis, None))
    return x


def forward(cfg: TransformerCfg, params, tokens) -> jnp.ndarray:
    """tokens [B, S] → logits [B, S, V] (vocab possibly model-sharded)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = shard(x, P(cfg.data_axes, None, None) if cfg.model_axis else None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    windows = jnp.asarray(cfg.layer_windows())

    if cfg.scan_layers:
        def body(carry, xs):
            lp, w = xs
            fn = _layer
            if cfg.remat:
                fn = jax.checkpoint(
                    _layer, policy=jax.checkpoint_policies.nothing_saveable,
                    static_argnums=(0,),
                )
            return fn(cfg, lp, carry, positions, w), None

        x, _ = jax.lax.scan(body, x, (params["layers"], windows))
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x = _layer(cfg, lp, x, positions, int(cfg.layer_windows()[i]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))
    return shard(logits, P(cfg.data_axes, None, cfg.model_axis)
                 if cfg.model_axis else None)


def loss_fn(cfg: TransformerCfg, params, batch) -> jnp.ndarray:
    logits = forward(cfg, params, batch["tokens"])
    spec = P(cfg.data_axes, None, cfg.model_axis) if cfg.model_axis else None
    return cross_entropy_loss(logits, batch["labels"], vocab_spec=spec)


# -------------------------------------------------------------------- serve
def cache_specs(cfg: TransformerCfg, mesh=None):
    """Sharding for [L, B, Hkv, Smax, Dh] caches (see module docstring)."""
    tp, dp = cfg.model_axis, cfg.data_axes
    if tp is None:
        return P()
    tp_size = 1 if mesh is None else mesh.shape[tp]
    if cfg.n_kv_heads % max(tp_size, 1) == 0:
        return P(None, dp, tp, None, None)
    return P(None, dp, None, None, tp)   # shard d_head instead


def init_cache(cfg: TransformerCfg, batch: int, max_len: int):
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.d_head)
    if cfg.kv_cache_quant:
        sshape = shape[:-1]
        return (jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                jnp.zeros(sshape, jnp.bfloat16), jnp.zeros(sshape, jnp.bfloat16))
    return (jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype))


def _quantize_kv(x):
    """Per-(token, head) symmetric int8: x [B, Hkv, S, Dh]."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def decode_step(cfg: TransformerCfg, params, cache, tokens, cache_len):
    """One decode step.  tokens [B] int32; cache_len scalar (tokens so far,
    including this one).  Returns (logits [B, V], new_cache)."""
    B = tokens.shape[0]
    x = params["embed"][tokens][:, None, :].astype(cfg.dtype)
    positions = jnp.full((B, 1), cache_len - 1, jnp.int32)
    windows = jnp.asarray(cfg.layer_windows())

    def body(carry, xs):
        lp, w, layer_cache = xs
        y, new_kv = _attention(cfg, lp, carry, positions, w, cache=layer_cache,
                               cache_len=cache_len)
        y = _mlp(cfg, lp, y)
        return y, new_kv

    x, new_cache = jax.lax.scan(body, x, (params["layers"], windows, cache))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = (x[:, 0] @ head.astype(cfg.dtype)).astype(jnp.float32)
    return logits, new_cache


def prefill(cfg: TransformerCfg, params, tokens, max_len: int):
    """Prefill: run the full prompt, return (last logits, populated cache)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    windows = jnp.asarray(cfg.layer_windows())

    def body(carry, xs):
        lp, w = xs
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        k = (h @ lp["wk"]).reshape(B, S, Hkv, Dh).transpose(0, 2, 1, 3)
        v = (h @ lp["wv"]).reshape(B, S, Hkv, Dh).transpose(0, 2, 1, 3)
        k = apply_rope(k, positions[:, None, :], cfg.rope_theta)
        y = _layer(cfg, lp, carry, positions, w)
        pad = max_len - S
        kc = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return y, (kc, vc)

    x, cache = jax.lax.scan(body, x, (params["layers"], windows))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = (x[:, -1] @ head.astype(cfg.dtype)).astype(jnp.float32)
    return logits, cache
