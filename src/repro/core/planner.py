"""Cost-model query planner (Sec. 5 of the paper).

Two pieces:

1. **Cardinality recurrences** (Eq. 1–4): per superstep, estimate active and
   matched vertex/edge counts from the graph statistics (`stats.GraphStats`),
   with the paper's ⊗ aggregation of clause frequencies (Eq. 5–6: min for
   AND, max for OR, degree-weighted averages).

2. **Execution-time model**: the paper fits per-phase linear models
   (I, C, S, CC, IC) from micro-benchmarks.  Granite-JAX supersteps are dense
   tensor programs whose cost is driven by the *type-sliced* vertex/edge
   extents plus the estimated message volume (the distributed exchange term),
   so our linear model is

     T_i = θ0 + θ_v·|V_σi| + θ_e·|Ē_slice(σ_{i+1})| + θ_etr·[etr]·|Ē_slice|
           + θ_m·m̄_i

   fitted by least squares over micro-benchmarks (benchmarks/fit_cost_model),
   stored as JSON, reusable across graphs/queries on the same host — exactly
   the paper's methodology with phase extents adapted to the dense engine.

   **Distribution-aware extension**: when the planner is given a
   ``Partitioning`` (graphdata.partitioner), per-superstep compute extents
   are divided over the workers and a per-superstep PER-CHANNEL exchange
   term

     θ_net · m_state_i  +  θ_net_etr · m_etr_i

   is added, where the m's are the STRUCTURAL boundary volumes of that
   superstep on the executor's point-to-point exchange: ``m_state_i`` is the
   partitioner's halo ghost-entry count for plain hops (doubled when the
   MIN/MAX extremum channel rides the same lanes), ``m_etr_i`` the boundary
   rank-summary count for ETR hops (cut edges, whose producers' per-segment
   prefix tables live with the source-segment owner).  These are exactly the
   ragged lane volumes the executor moves (``superstep.p2p_exchange``) and
   the volumes the two θ_net coefficients are fitted against from measured
   partitioned supersteps (engine_partitioned.measure_supersteps, whose
   ``exchange_channels`` report the same three channels), keeping the model,
   the fit and the executor in one unit (paper Sec. 5's communication
   phase).  Every query class (plain counts, COUNT and MIN/MAX aggregates,
   ETR hops) is costed on the distributed path — plan selection has no
   dense-only fallback.

What matters (paper Sec. 5): not absolute accuracy but *discriminating good
plans from bad*.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import query as Q
from .stats import GraphStats, HEntry

DEFAULT_COEFFS = {
    # fallback, overwritten by benchmarks/fit_cost_model.py on the host
    "theta0": 0.2,        # ms per superstep (dispatch/barrier)
    "theta_v": 2.0e-5,    # ms per vertex in the typed slice
    "theta_e": 6.0e-5,    # ms per traversal edge in the hop slice
    "theta_etr": 8.0e-5,  # extra ms per edge on ETR hops (sort-prefix path)
    "theta_m": 2.0e-5,    # ms per estimated delivered message
    "theta_init": 2.0e-5, # ms per vertex evaluated at init
    "theta_net": 8.0e-5,  # ms per boundary vertex-state entry (plain/extremum
                          # channels of the point-to-point exchange)
    "theta_net_etr": 8.0e-5,  # ms per boundary ETR rank summary (cut edges)
    # per-impl hop-DELIVERY slope (ms per traversal edge in the hop slice):
    # the measured cost of the gather → mask → segment-reduce step under
    # each lowering (benchmarks/fit_cost_model fits both from hop-delivery
    # micro-benches).  The estimate applies the DELTA from the xla slope, so
    # impl='xla' plans cost exactly what the historical model says (theta_e
    # already folds the xla delivery in) and the impl sweep discriminates on
    # the fitted difference alone.  Defaults are 0 → tie → xla.
    "theta_scatter_xla": 0.0,
    "theta_scatter_pallas": 0.0,
}

#: the impl axis plan selection sweeps when asked to choose a lowering
HOP_IMPL_CHOICES = ("xla", "pallas")

#: canonical coefficient basis: a PlanEstimate's ``features`` vector is
#: indexed by this tuple, and ``t_ms == features @ coeff_vector(coeffs)``
#: EXACTLY (the scatter-delta trick is encoded as +e/w on the chosen impl's
#: column and -e/w on the xla column, so impl='xla' contributes zero).  This
#: is the contract the serving telemetry's online refit relies on: refitting
#: θ over recorded (features, measured) dispatch rows re-calibrates the very
#: predictions admission control makes.
COEFF_KEYS = ("theta0", "theta_init", "theta_v", "theta_e", "theta_etr",
              "theta_m", "theta_net", "theta_net_etr",
              "theta_scatter_xla", "theta_scatter_pallas")
_CK = {k: i for i, k in enumerate(COEFF_KEYS)}


def coeff_vector(coeffs: dict) -> np.ndarray:
    """The θ vector over the COEFF_KEYS basis (missing keys → defaults)."""
    return np.asarray([float(coeffs.get(k, DEFAULT_COEFFS.get(k, 0.0)))
                       for k in COEFF_KEYS])


_COEFF_PATH = os.path.join(os.path.dirname(__file__), "..", "configs", "cost_coeffs.json")


def load_coeffs(path: Optional[str] = None) -> dict:
    p = path or _COEFF_PATH
    if os.path.exists(p):
        with open(p) as f:
            return {**DEFAULT_COEFFS, **json.load(f)}
    return dict(DEFAULT_COEFFS)


def save_coeffs(coeffs: dict, path: Optional[str] = None) -> None:
    p = path or _COEFF_PATH
    os.makedirs(os.path.dirname(p), exist_ok=True)
    with open(p, "w") as f:
        json.dump(coeffs, f, indent=2)


# ---------------------------------------------------------------- estimates
@dataclasses.dataclass
class StepEstimate:
    a_v: float       # active vertices (Eq. 1)
    f_v: float       # histogram frequency for the vertex predicate
    m_v: float       # matched vertices (Eq. 2)
    a_e: float       # active edges (Eq. 3)
    f_e: float       # edge-predicate frequency
    m_e: float       # matched edges / messages (Eq. 4)
    t_ms: float      # estimated superstep time (per-worker makespan if W > 1)
    v_slice: float   # typed vertex extent processed
    e_slice: float   # typed traversal-edge extent processed
    etr: bool
    m_net: float = 0.0  # estimated cross-partition boundary messages
    #: feature row over the COEFF_KEYS basis (t_ms == features @ θ)
    features: Optional[np.ndarray] = None
    #: per-channel breakdown of m_net — (state, extremum, etr) structural
    #: boundary volumes of THIS hop (engine_partitioned.CHANNELS order; sums
    #: to m_net).  None on terminal (vertex-only) steps, so ``channels is
    #: not None`` identifies the hop steps a trace's superstep/exchange
    #: spans mirror.
    channels: Optional[Tuple[float, float, float]] = None


@dataclasses.dataclass
class PlanEstimate:
    split: int
    t_ms: float
    steps: List[StepEstimate]
    impl: str = "xla"   # hop-delivery lowering the estimate was costed at
    #: summed step features over COEFF_KEYS (t_ms == features @ coeff_vector);
    #: for estimate_batch, the batch-summed features
    features: Optional[np.ndarray] = None
    #: the full sweep choose()/choose_batch() ran to pick this plan: one
    #: dict(split, impl, t_ms, features) per candidate.  The flight
    #: recorder's plan span records these so obs/audit.plan_accuracy can
    #: re-cost the whole sweep under a trace-refit θ̂ offline (the paper's
    #: "% within X% of optimal plan" metric).  None when no sweep ran
    #: (direct estimate(), or use_planner=False).
    candidates: Optional[List[dict]] = None


def _clause_freq(stats: GraphStats, clauses: Sequence[Q.Clause], ent_type: int,
                 is_edge: bool) -> Tuple[float, float, float]:
    """⊗-aggregate clause frequencies (Eq. 5–6).  Returns (f, δin, δout)."""
    tot = stats.etype_count(ent_type) if is_edge else stats.type_count(ent_type)
    acc: Optional[HEntry] = None
    acc_conj_f = None
    for c in clauses:
        if c.kind == Q.K_PROP:
            h = stats.h_lookup(c.key, c.value, None, is_edge=is_edge)
            if c.cmp == Q.P_NEQ:
                h = HEntry(max(tot - h.f, 0.0), h.d_in, h.d_out)
        else:
            frac = stats.lifespan_frac(ent_type, tuple(c.interval), is_edge=is_edge)
            h = HEntry(frac * tot, 0.0, 0.0)
        if acc is None:
            acc = h
        else:
            if c.conj == Q.AND:
                f = min(acc.f, h.f)
            else:
                f = max(acc.f, h.f)
            wsum = max(acc.f + h.f, 1e-9)
            acc = HEntry(
                f,
                (acc.d_in * acc.f + h.d_in * h.f) / wsum,
                (acc.d_out * acc.f + h.d_out * h.f) / wsum,
            )
    if acc is None:
        return tot, 0.0, 0.0
    return acc.f, acc.d_in, acc.d_out


def estimate_segment(
    stats: GraphStats,
    v_preds: Sequence[Q.VertexPredicate],
    e_preds: Sequence[Q.EdgePredicate],
    coeffs: dict,
    trav_arrivals_by_type: np.ndarray,
    n_workers: int = 1,
    exchange_volume: float = 0.0,
    etr_exchange_volume: float = 0.0,
    extremum_channel: bool = False,
    impl: str = "xla",
) -> List[StepEstimate]:
    """Per-superstep estimates.  With ``n_workers > 1`` compute extents are
    divided over workers (balanced partitions) and each hop pays the θ_net
    exchange term: ``exchange_volume`` (halo ghost entries; doubled when the
    MIN/MAX ``extremum_channel`` rides along) on plain hops,
    ``etr_exchange_volume`` (boundary rank summaries — cut edges) on ETR
    hops.  ``impl`` selects the hop-delivery lowering being costed: each hop
    pays the fitted θ_scatter slope DELTA vs the xla baseline (zero for
    impl='xla', so the historical model is unchanged)."""
    steps: List[StepEstimate] = []
    prev_m_e = None
    w = max(1, int(n_workers))
    theta = coeff_vector(coeffs)
    for i, vp in enumerate(v_preds):
        V_sigma = stats.type_count(vp.vtype)
        if i == 0:
            a_v = V_sigma                                    # Eq. 1, init
        else:
            a_v = min(prev_m_e, V_sigma)                     # Eq. 1
        f_v, d_in, d_out = _clause_freq(stats, vp.clauses, vp.vtype, is_edge=False)
        if not vp.clauses:
            f_v = V_sigma
        m_v = a_v * (f_v / max(V_sigma, 1e-9))               # Eq. 2
        if i >= len(e_preds):
            steps.append(StepEstimate(a_v, f_v, m_v, 0, 0, 0, 0.0, V_sigma, 0.0,
                                      False, features=np.zeros(len(COEFF_KEYS))))
            break
        ep = e_preds[i]
        deg = stats.degree(vp.vtype, ep.etype, ep.direction)
        if deg == 0.0 and (d_in + d_out) > 0:
            deg = d_in + d_out                               # paper fallback δ
        a_e = m_v * max(deg, 0.0)                            # Eq. 3
        E_sigma = stats.etype_count(ep.etype)
        f_e, _, _ = _clause_freq(stats, ep.clauses, ep.etype, is_edge=True)
        if not ep.clauses:
            f_e = E_sigma
        sel_e = f_e / max(E_sigma, 1e-9)
        if ep.etr_op != -1:
            sel_e *= stats.etr_select.get(ep.etr_op, 0.5)    # beyond-paper term
        m_e = a_e * sel_e                                    # Eq. 4
        # ---- execution-time terms (dense type-sliced engine)
        nxt_type = v_preds[i + 1].vtype if i + 1 < len(v_preds) else -1
        e_slice = (
            float(trav_arrivals_by_type[nxt_type])
            if nxt_type >= 0
            else float(trav_arrivals_by_type.sum())
        )
        # structural boundary volume of this hop: what the executor's
        # point-to-point exchange actually moves (and what the per-channel
        # θ_net coefficients were fitted on) — ETR hops ship only the
        # boundary rank summaries of cut segments (see engine_partitioned)
        if w > 1:
            if ep.etr_op != -1:
                channels = (0.0, 0.0, float(etr_exchange_volume))
            else:
                channels = (float(exchange_volume),
                            float(exchange_volume) if extremum_channel
                            else 0.0, 0.0)
        else:
            channels = (0.0, 0.0, 0.0)
        m_net = sum(channels)
        # the superstep cost as a feature row over the COEFF_KEYS basis —
        # t is the dot product with θ, so the serving telemetry can refit θ
        # against measured dispatch times on exactly these columns
        feat = np.zeros(len(COEFF_KEYS))
        feat[_CK["theta0"]] = 1.0
        feat[_CK["theta_init" if i == 0 else "theta_v"]] = V_sigma / w
        feat[_CK["theta_e"]] = e_slice / w
        if ep.etr_op != -1:
            feat[_CK["theta_etr"]] = e_slice / w
            feat[_CK["theta_net_etr"]] = m_net
        else:
            # fused-hop saving applies to plain hops only: ETR hops
            # materialise per-edge counts by construction and only swap
            # the delivery step, which the fitted full-hop slope would
            # over-credit.  The delta-vs-xla encoding keeps impl='xla'
            # contributing exactly zero (historical model unchanged).
            base = ("pallas" if impl in ("pallas", "pallas_interpret")
                    else "xla")
            feat[_CK[f"theta_scatter_{base}"]] += e_slice / w
            feat[_CK["theta_scatter_xla"]] -= e_slice / w
            feat[_CK["theta_net"]] = m_net
        feat[_CK["theta_m"]] = max(m_e, 0.0) / w
        t = float(feat @ theta)
        steps.append(StepEstimate(a_v, f_v, m_v, a_e, f_e, m_e, t, V_sigma, e_slice,
                                  ep.etr_op != -1, m_net, features=feat,
                                  channels=channels))
        prev_m_e = max(m_e, 0.0)
    return steps


class Planner:
    def __init__(self, graph, stats: GraphStats, coeffs: Optional[dict] = None,
                 partitioning=None):
        """``partitioning``: an optional graphdata.partitioner.Partitioning
        (or PartitionArrays); when given, plan costs are per-worker makespans
        including the θ_net structural-exchange term from the partitioner's
        halo ghost counts."""
        self.g = graph
        self.stats = stats
        self.coeffs = coeffs or load_coeffs()
        self.n_workers = 1
        self.cut_frac = 0.0
        self.exchange_volume = 0.0
        self.etr_exchange_volume = 0.0
        if partitioning is not None:
            arrays = partitioning
            if not hasattr(arrays, "exchange_volume"):  # a Partitioning
                from ..graphdata.partitioner import build_partition_arrays
                arrays = build_partition_arrays(graph, partitioning)
            self.n_workers = int(arrays.n_workers)
            self.cut_frac = float(arrays.stats.get("edge_cut", 0.0))
            self.exchange_volume = float(arrays.exchange_volume())
            self.etr_exchange_volume = float(arrays.etr_exchange_volume())
        # traversal arrivals per vertex type (edge extent of a typed hop)
        deg = graph.in_degree.astype(np.int64) + graph.out_degree.astype(np.int64)
        self.trav_arrivals_by_type = np.zeros(graph.n_vertex_types, np.int64)
        np.add.at(self.trav_arrivals_by_type, graph.v_type, deg)
        # execution paths the fault layer has marked down (e.g. the
        # partitioned engine after a worker loss); the scheduler drives
        # these and consults engine_available before planning onto a path
        self.unavailable: set = set()

    # ------------------------------------------------- engine availability
    def mark_unavailable(self, engine: str) -> None:
        """Mark an execution path down (serving fault layer: a partitioned
        dispatch lost a worker; units re-plan dense until a probe clears)."""
        self.unavailable.add(engine)

    def mark_available(self, engine: str) -> None:
        self.unavailable.discard(engine)

    def engine_available(self, engine: str) -> bool:
        return engine not in self.unavailable

    def enumerate_plans(self, qry: Q.PathQuery) -> List[int]:
        if qry.agg_op != Q.AGG_NONE:
            return [0]
        return list(range(qry.n_vertices))

    def estimate(self, qry: Q.PathQuery, split: int,
                 impl: str = "xla") -> PlanEstimate:
        n = qry.n_vertices
        steps: List[StepEstimate] = []
        # MIN/MAX aggregates thread the extremum channel through the (right,
        # reversed) segment; its boundary state rides every plain exchange.
        extremum = qry.agg_op in (Q.AGG_MIN, Q.AGG_MAX)
        if split > 0:
            steps += estimate_segment(
                self.stats, qry.v_preds[: split + 1], qry.e_preds[:split],
                self.coeffs, self.trav_arrivals_by_type,
                n_workers=self.n_workers,
                exchange_volume=self.exchange_volume,
                etr_exchange_volume=self.etr_exchange_volume,
                impl=impl,
            )
        if (n - 1) - split > 0:
            rev = qry.reversed()
            m = (n - 1) - split
            steps += estimate_segment(
                self.stats, rev.v_preds[: m + 1], rev.e_preds[:m],
                self.coeffs, self.trav_arrivals_by_type,
                n_workers=self.n_workers,
                exchange_volume=self.exchange_volume,
                etr_exchange_volume=self.etr_exchange_volume,
                extremum_channel=extremum,
                impl=impl,
            )
        t = sum(s.t_ms for s in steps)
        feats = [s.features for s in steps if s.features is not None]
        features = (np.sum(feats, axis=0) if feats
                    else np.zeros(len(COEFF_KEYS)))
        return PlanEstimate(split, t, steps, impl, features)

    def choose(self, qry: Q.PathQuery,
               impls: Sequence[str] = ("xla",)) -> PlanEstimate:
        """Best (split, impl) over the plan space.  The default sweeps only
        the xla lowering (the historical behaviour); pass
        ``impls=HOP_IMPL_CHOICES`` to let the fitted per-impl θ_scatter term
        route hops onto the fused kernel where it wins — ties break toward
        the first entry (xla).  The swept candidates are recorded on the
        returned estimate (``candidates``) for the flight recorder."""
        best = None
        cands: List[dict] = []
        for split in self.enumerate_plans(qry):
            for impl in impls:
                est = self.estimate(qry, split, impl)
                cands.append(dict(split=split, impl=impl, t_ms=est.t_ms,
                                  features=est.features))
                if best is None or est.t_ms < best.t_ms:
                    best = est
        best.candidates = cands
        return best

    # ------------------------------------------------------- batched serving
    def estimate_batch(self, queries: Sequence[Q.PathQuery], split: int,
                       impl: str = "xla") -> PlanEstimate:
        """Cost a whole same-shape batch at one split point.

        Instances share the traced structure but not their parameter values,
        so predicate selectivities (clause-frequency lookups) differ per
        instance — the batch cost is the SUM of per-instance estimates, not
        the first instance's cost scaled.  The returned steps are the first
        instance's (for introspection); ``t_ms`` covers the batch.
        """
        assert queries, "empty batch"
        ests = [self.estimate(q, split, impl) for q in queries]
        return PlanEstimate(split, sum(e.t_ms for e in ests), ests[0].steps,
                            impl, np.sum([e.features for e in ests], axis=0))

    def choose_batch(self, queries: Sequence[Q.PathQuery],
                     impls: Sequence[str] = ("xla",)) -> PlanEstimate:
        """One (split, impl) for a same-shape batch, minimising whole-batch
        cost.

        This is the planner the batch scheduler uses: a vmapped group runs
        every instance at ONE split, so the right objective is the batch sum
        — picking the first instance's best split can lose when selectivities
        differ across instances (the old run_workload_batched bug).  The
        ``impls`` sweep mirrors ``choose()``: a group is dispatched on one
        hop-delivery lowering, so the impl is chosen batch-wide too."""
        assert queries, "empty batch"
        shape0 = queries[0].shape_key()
        for q in queries[1:]:
            if q.shape_key() != shape0:
                raise ValueError("batch planning needs same-shape queries")
        best = None
        cands: List[dict] = []
        for split in self.enumerate_plans(queries[0]):
            for impl in impls:
                est = self.estimate_batch(queries, split, impl)
                cands.append(dict(split=split, impl=impl, t_ms=est.t_ms,
                                  features=est.features))
                if best is None or est.t_ms < best.t_ms:
                    best = est
        best.candidates = cands
        return best


# -------------------------------------------------------------- fitting util
def fit_linear(features: np.ndarray, times_ms: np.ndarray) -> np.ndarray:
    """Least-squares fit; features [n, k] → coefficients [k]."""
    sol, *_ = np.linalg.lstsq(features, times_ms, rcond=None)
    return sol
