"""Granite-JAX core: the paper's primary contribution.

Temporal property graph model, temporal path query model (ETR + temporal
aggregation), the superstep execution engines, split-point query plans,
graph statistics and the distribution-aware cost-model planner.

Engine stack (three executors over one superstep core):

  superstep.py           hop primitives: predicate eval, edge masking, ETR
                         rank application, segment-sum delivery, state
                         algebra, interval/bucket joins
  engine.py              DENSE executor + the split/join plan skeleton all
                         executors share (``execute`` routes dense/sliced)
  engine_sliced.py       SLICED executor — typed-slice extents per hop
  engine_partitioned.py  PARTITIONED executor — per-worker shards from the
                         two-level partitioner, local segment-sum delivery,
                         boundary-halo exchange each superstep; vmap on one
                         device, shard_map over a device mesh on several

All three produce bit-identical results; the planner (planner.py) picks
split-point plans, adding a θ_net cross-partition exchange term when given a
partitioning.
"""
from . import intervals, query
from .engine import MODE_BUCKET, MODE_INTERVAL, MODE_STATIC, count_results, execute
from .graph import PropColumn, TemporalGraph
from .ref_engine import RefEngine

__all__ = [
    "intervals", "query", "TemporalGraph", "PropColumn",
    "execute", "count_results", "RefEngine",
    "MODE_STATIC", "MODE_BUCKET", "MODE_INTERVAL",
]
