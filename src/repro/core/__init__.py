"""Granite-JAX core: the paper's primary contribution.

Temporal property graph model, temporal path query model (ETR + temporal
aggregation), the distributed superstep execution engine, split-point query
plans, graph statistics and the cost-model planner.
"""
from . import intervals, query
from .engine import MODE_BUCKET, MODE_INTERVAL, MODE_STATIC, count_results, execute
from .graph import PropColumn, TemporalGraph
from .ref_engine import RefEngine

__all__ = [
    "intervals", "query", "TemporalGraph", "PropColumn",
    "execute", "count_results", "RefEngine",
    "MODE_STATIC", "MODE_BUCKET", "MODE_INTERVAL",
]
