"""Granite-JAX temporal path-query engine — dense executor + plan skeleton.

Execution model
---------------
The paper runs one BSP superstep per hop: vertex predicates in ``compute``,
edge predicates + ETR in ``scatter``, partial paths in messages.  Here a
superstep is a dense tensor program over the 2E traversal-edge arrays:

  vertex step : vectorised predicate eval over property columns  → match mask
  edge step   : gather source counts → edge predicate mask → per-edge counts
  delivery    : sorted segment-sum of per-edge counts by arrival vertex

Path multiplicity is carried as float32 *counts* (the tensor form of the
paper's result-tree message compression: per-hop DP state instead of per-path
messages).  Three temporal modes:

  MODE_STATIC    scalar counts               — static temporal graphs
  MODE_BUCKET    counts per time bucket      — dynamic graphs, per-bucket
                 (time-series) semantics; exact per bucket.  Used for the
                 temporal aggregation operator (EQ4-style answers).
  MODE_INTERVAL  counts per running-intersection interval (bucket-pair cells)
                 — dynamic graphs, exact *distinct temporal path* counts on
                 bucket-aligned data.

ETR (edge temporal relationship) hops use precomputed rank tables + segment
prefix sums (see graph.EtrTables): exact, O(E) per hop, no ragged state.

Three-layer architecture
------------------------
The hop primitives (predicate eval, edge masking, ETR rank application,
segment-sum delivery, state algebra, joins) live in ``superstep.py``; this
module adds the DENSE executor (``run_segment``) plus the split-point plan
skeleton (``execute_plan_traced``) that all executors share via the
``segment_runner`` hook:

  superstep core ──┬── engine.py              dense, whole-graph supersteps
                   ├── engine_sliced.py       type-slice extents per hop
                   └── engine_partitioned.py  per-worker shards + boundary
                                              exchange (distributed path)

``execute()`` routes between dense/sliced; ``engine_partitioned.execute()``
is the partition-sharded entry point with identical semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import intervals as iv
from . import query as Q
from . import superstep as SS
from .graph import TemporalGraph
from .superstep import MODE_BUCKET, MODE_INTERVAL, MODE_STATIC

# Back-compat aliases for primitives that moved to superstep.py, kept only
# for the external users that still reach them through this module
# (benchmarks/components.py).  New code should import from superstep.
_TRACE_BEDGES = SS.TRACE_BEDGES  # same list object — push/pop still scopes
_eval_predicate = SS.eval_predicate
_etr_weighted = SS.etr_weighted


# =========================================================================
# segment execution (dense)
# =========================================================================
@dataclasses.dataclass
class SegmentResult:
    arrivals_e: Optional[jnp.ndarray]  # per traversal-edge counts into final vertex
    arrivals_v: Optional[jnp.ndarray]  # segment-sum of the above ([V, *TS])
    stats: List[dict]                  # per-superstep instrumentation
    minmax_v: Optional[jnp.ndarray] = None  # min/max channel at final vertex


def _edge_predicate_weights(gdev, ep: Q.EdgePredicate, params, pbase, mode, bedges):
    """(weight f32[2E], bucket validity or interval validity) for a hop."""
    return SS.edge_predicate_weights(gdev, ep, params, pbase, mode, bedges)


def run_segment(
    gdev: dict,
    v_preds: Sequence[Q.VertexPredicate],
    e_preds: Sequence[Q.EdgePredicate],
    params,
    pbases_v: Sequence[int],
    pbases_e: Sequence[int],
    mode: int,
    n_buckets: int,
    backward: bool,
    with_minmax: bool = False,
    minmax_op: int = Q.AGG_MIN,
    minmax_col=None,
    impl: str = "xla",
    layout=None,
    delta=None,
) -> SegmentResult:
    """Run one path segment.  v_preds has one more entry than e_preds; the
    FINAL vertex predicate is NOT applied (it belongs to the join).

    ``delta`` (a ``graphdata.ingest.DeltaSpec.device()`` dict) adds the
    base+delta execution path: every plain hop also evaluates the edge
    predicate over the delta-edge slots and merges their (unsorted)
    delivery into the base arrivals — bit-identical to running on the
    merged epoch graph, with the base graph's compiled layout untouched.
    ETR hops read global rank tables and are delta-incompatible (callers
    gate on query shape; ``batch_executable_delta`` refuses them).

    ``impl``/``layout`` select the delivery lowering: with a
    ``kernels.hop_scatter.HopLayout`` over the graph's arrival-sorted
    traversal edges, every plain hop runs the FUSED gather → temporal mask →
    segment-reduce kernel (``superstep.fused_hop_deliver``; the extremum
    channel rides the same call) and ETR-hop deliveries run the blocked
    scatter kernel.  The per-edge count chain is still traced for the
    consumers that need per-edge state (ETR prefix sums, the ETR-at-join
    contraction) — when nothing reads it, jit DCE drops it, which is what
    makes the fused path materialisation-free end to end.

    Returns raw arrivals (per-edge and per-vertex) at the final vertex.
    """
    V = gdev["v_life"].shape[0]
    stats: List[dict] = []
    bedges = SS.current_bedges()
    fused = SS.use_pallas(impl) and layout is not None

    # ---- init superstep (first vertex predicate)
    vm, vv = SS.eval_predicate(
        gdev["vprops"], gdev["v_type"], gdev["v_life"], v_preds[0].vtype,
        v_preds[0].clauses, params, pbases_v[0], mode, bedges,
    )
    state_v = SS.init_state(vm, vv, mode, n_buckets)
    stats.append(dict(phase="init", matched=jnp.sum(vm)))

    mch_v = None
    if with_minmax:
        vals0, _ = minmax_col
        mch_v = SS.minmax_seed(state_v, vals0, minmax_op, mode)

    arrivals_e = None
    arrivals_v = None
    prev_raw_e = None
    for i, ep in enumerate(e_preds):
        wmask, evalidity = SS.edge_predicate_weights(
            gdev, ep, params, pbases_e[i], mode, bedges
        )
        if i > 0:
            # apply the intermediate vertex predicate (post-arrival)
            vm, vv = SS.eval_predicate(
                gdev["vprops"], gdev["v_type"], gdev["v_life"], v_preds[i].vtype,
                v_preds[i].clauses, params, pbases_v[i], mode, bedges,
            )
        if ep.etr_op != -1:
            if delta is not None:
                raise NotImplementedError(
                    "delta execution across ETR hops (global rank tables)")
            # ETR hop: prefix-sum over *raw* previous arrivals, then apply the
            # intermediate vertex predicate at the source gather.
            src_cnt = SS.etr_weighted(gdev, prev_raw_e, ep.etr_op, backward,
                                      use_arr=False)
            src_match = vm[gdev["t_src"]]
            if mode == MODE_STATIC:
                src_val = src_cnt * src_match.astype(jnp.float32)
            elif mode == MODE_BUCKET:
                src_val = src_cnt * (vm[:, None] & vv)[gdev["t_src"]].astype(jnp.float32)
            else:
                src_val = SS.apply_validity(src_cnt, vm[gdev["t_src"]],
                                            vv[gdev["t_src"]], mode)
        else:
            if i == 0:
                sv = state_v
            else:
                sv = SS.apply_validity(arrivals_v, vm, vv, mode)
            src_val = sv[gdev["t_src"]]
        cnt_e = SS.apply_edge(src_val, wmask, evalidity, mode)
        arrivals_e = cnt_e
        prev_raw_e = cnt_e
        if with_minmax and ep.etr_op != -1:
            raise NotImplementedError("min/max aggregation across ETR hops")
        d_add = d_mm = None
        if delta is not None:
            # delta-segment contribution, from the SAME pre-hop source state
            # and extremum channel the base delivery reads
            d_add, d_mm = SS.delta_hop_deliver(
                delta, ep, sv, params, pbases_e[i], mode, V,
                mch=(mch_v if with_minmax else None), minmax_op=minmax_op)
        if fused and ep.etr_op == -1:
            # fused kernel hop: arrivals (and the extremum channel) come from
            # ONE VMEM pass over the state table — cnt_e above stays traced
            # only for per-edge consumers (ETR, join) and is DCE'd otherwise
            arrivals_v, mch_new = SS.fused_hop_deliver(
                sv, gdev["t_src"], wmask, evalidity, mode, layout.tables,
                layout.block_v, V, impl=impl,
                mch=(mch_v if with_minmax else None), minmax_op=minmax_op)
            if with_minmax:
                mch_v = mch_new
        else:
            arrivals_v = SS.deliver(cnt_e, gdev["t_dst"], V, impl=impl,
                                    layout=layout)
            if with_minmax:
                m_e = SS.minmax_edge(mch_v[gdev["t_src"]], cnt_e, minmax_op,
                                     mode)
                mch_v = SS.deliver_extremum(m_e, gdev["t_dst"], V, minmax_op,
                                            impl=impl, layout=layout)
        if d_add is not None:
            arrivals_v = arrivals_v + d_add
            if with_minmax:
                comb = jnp.minimum if minmax_op == Q.AGG_MIN else jnp.maximum
                mch_v = comb(mch_v, d_mm)
        stat = dict(phase=f"hop{i}", matched_edges=jnp.sum(wmask))
        if not fused:
            # per-edge activity would force the materialisation the fused
            # path exists to avoid; report it on the XLA path only
            stat["active_edges"] = jnp.sum(
                (src_val if mode == MODE_STATIC else src_val.sum(
                    axis=tuple(range(1, src_val.ndim)))) > 0)
        stats.append(stat)

    return SegmentResult(arrivals_e, arrivals_v, stats, mch_v)


# =========================================================================
# plan execution (split-point plans, Sec. 4.3)
# =========================================================================
@dataclasses.dataclass
class ExecOutput:
    total: jnp.ndarray                 # scalar (static/interval) or [B] (bucket)
    per_vertex: Optional[jnp.ndarray]  # aggregation output ([V] / [V,B])
    minmax: Optional[jnp.ndarray]
    stats: List[dict]


def execute_plan_traced(
    gdev: dict,
    qry: Q.PathQuery,
    split: int,
    mode: int,
    n_buckets: int,
    params,
    bedges,
    segment_runner=None,
    impl: str = "xla",
    layout=None,
    delta=None,
):
    """Traceable plan execution.  All query structure is Python-static.

    ``segment_runner`` (defaults to the dense ``run_segment``) lets other
    executors reuse the split/join skeleton: it must return a SegmentResult
    whose arrivals live in GLOBAL vertex/traversal-edge space.
    ``impl``/``layout``/``delta`` only parameterise the DEFAULT dense
    runner — other executors thread their own delivery lowering through
    their runner.
    """
    with SS.bucket_scope(bedges):
        return _execute_plan_inner(gdev, qry, split, mode, n_buckets, params,
                                   segment_runner, impl=impl, layout=layout,
                                   delta=delta)


def _pbases(qry: Q.PathQuery):
    """Parameter-row offsets per predicate (matching query_params order)."""
    pv, pe = [], []
    off = 0
    for v in qry.v_preds:
        pv.append(off)
        off += len(v.clauses)
    for e in qry.e_preds:
        pe.append(off)
        off += len(e.clauses)
    return pv, pe


def _execute_plan_inner(gdev, qry, split, mode, n_buckets, params,
                        segment_runner=None, impl: str = "xla", layout=None,
                        delta=None):
    n = qry.n_vertices
    assert 0 <= split < n
    pv, pe = _pbases(qry)
    bedges = SS.current_bedges()
    runner = segment_runner
    if runner is None:
        def runner(*a, **kw):
            return run_segment(gdev, *a, impl=impl, layout=layout,
                               delta=delta, **kw)

    want_agg = qry.agg_op != Q.AGG_NONE
    want_minmax = qry.agg_op in (Q.AGG_MIN, Q.AGG_MAX)
    if want_agg:
        assert split == 0, "aggregate queries group by the first vertex → split=0"

    rev = qry.reversed()

    # ---- left segment: v0 .. v_split (forward)
    left = None
    if split > 0:
        left = runner(
            qry.v_preds[: split + 1], qry.e_preds[:split], params,
            pv[: split + 1], pe[:split], mode, n_buckets, backward=False,
        )

    # ---- right segment: v_{n-1} .. v_split (reversed)
    right = None
    n_right_hops = (n - 1) - split
    if n_right_hops > 0:
        # params rows were packed for the ORIGINAL query; map them:
        # rev.v_preds[i] == qry.v_preds[n-1-i]; rev.e_preds[j] == qry.e_preds[n-2-j]
        rpv_orig = [pv[n - 1 - i] for i in range(n)]
        rpe_orig = [pe[n - 2 - j] for j in range(n - 1)]
        right = runner(
            rev.v_preds[: n_right_hops + 1], rev.e_preds[:n_right_hops],
            params, rpv_orig[: n_right_hops + 1], rpe_orig[:n_right_hops],
            mode, n_buckets, backward=True,
            with_minmax=want_minmax,
            minmax_op=qry.agg_op,
            minmax_col=(gdev["vprops"].get(qry.agg_key) if want_minmax else None),
        )

    stats = (left.stats if left else []) + (right.stats if right else [])

    # ---- join at v_split
    vm, vv = SS.eval_predicate(
        gdev["vprops"], gdev["v_type"], gdev["v_life"], qry.v_preds[split].vtype,
        qry.v_preds[split].clauses, params, pv[split], mode, bedges,
    )
    etr_at_join = split > 0 and split < n - 1 and qry.e_preds[split].etr_op != -1

    def vertex_apply(av):
        return SS.apply_validity(av, vm, vv, mode)

    if n == 1:  # degenerate single-vertex query
        st = SS.init_state(vm, vv, mode, n_buckets)
        total = SS.state_total(st, mode)
        pv = mm = None
        if want_agg:
            pv = st if mode != MODE_INTERVAL else SS.cells_to_buckets(st)
        if want_minmax:
            vals0, _ = gdev["vprops"][qry.agg_key]
            mm = SS.minmax_seed(st, vals0, qry.agg_op, mode)
        return ExecOutput(total, pv, mm, stats)

    if not etr_at_join:
        if left is None:
            Rv = vertex_apply(right.arrivals_v)
            if want_agg:
                per_vertex = Rv if mode != MODE_INTERVAL else SS.cells_to_buckets(Rv)
                total = SS.state_total(Rv, mode)
                mm = None
                if want_minmax:
                    mm = jnp.where(SS.state_alive(Rv, mode), right.minmax_v,
                                   SS.minmax_neutral(qry.agg_op))
                return ExecOutput(total, per_vertex, mm, stats)
            total = SS.state_total(Rv, mode)
            return ExecOutput(total, None, None, stats)
        if right is None:
            Lv = vertex_apply(left.arrivals_v)
            return ExecOutput(SS.state_total(Lv, mode), None, None, stats)
        # both sides present, plain product join
        Lv = vertex_apply(left.arrivals_v)
        Rv = right.arrivals_v
        if mode == MODE_STATIC:
            total = jnp.sum(Lv * Rv)
        elif mode == MODE_BUCKET:
            total = jnp.sum(Lv * Rv, axis=0)
        else:
            total = jnp.sum(SS.join_interval_counts(Lv, Rv))
        return ExecOutput(total, None, None, stats)

    # ---- ETR-at-join: weight right final edges by left arrivals via ranks
    op = qry.e_preds[split].etr_op
    W = SS.etr_weighted(gdev, left.arrivals_e, op, backward=False, use_arr=True)
    # apply v_split predicate at the join vertex of each right edge
    if mode == MODE_STATIC:
        w_v = vm[gdev["t_dst"]].astype(jnp.float32)
        total = jnp.sum(W * right.arrivals_e * w_v)
    elif mode == MODE_BUCKET:
        mk = (vm[:, None] & vv).astype(jnp.float32)[gdev["t_dst"]]
        total = jnp.sum(W * right.arrivals_e * mk, axis=0)
    else:
        Wc = SS.apply_validity(W, vm[gdev["t_dst"]], vv[gdev["t_dst"]], mode)
        total = jnp.sum(SS.join_interval_counts_edges(Wc, right.arrivals_e))
    return ExecOutput(total, None, None, stats)


# =========================================================================
# public API with jit cache
# =========================================================================
_JIT_CACHE: Dict[tuple, callable] = {}

#: engine-level implementation axis — the kernels' shared idiom
#: ('xla' | 'pallas' | 'pallas_interpret'), validated by superstep.check_impl
from ..kernels.common import IMPLS as HOP_IMPLS  # noqa: E402


def hop_layout_for(graph: TemporalGraph, block_v: Optional[int] = None,
                   block_e_mult: int = 512):
    """The dense executor's static HopLayout (whole-graph arrival-sorted
    traversal edges → destination blocks), cached ON the graph object like
    its device-array cache so the layout's lifetime is tied to the graph.
    ``block_v=None`` auto-sizes (one block on the CPU interpreter; TPU
    deployments pass an explicit VMEM-shaped block)."""
    from ..kernels.hop_scatter import build_hop_layout

    cache = getattr(graph, "_hop_layout_cache", None)
    if cache is None:
        cache = {}
        graph._hop_layout_cache = cache
    key = ("dense", block_v, block_e_mult)
    lay = cache.get(key)
    if lay is None:
        seg = np.asarray(graph.traversal["t_dst"])
        lay = build_hop_layout(seg, graph.n_vertices, block_v=block_v,
                               block_e_mult=block_e_mult)
        cache[key] = lay
    return lay


def _prepare_gdev(graph: TemporalGraph) -> dict:
    g = dict(graph.device_arrays())
    # edge property columns gathered into traversal space (2E), lazily cached
    if "eprops_t" not in g:
        t_eid = np.asarray(graph.traversal["t_eid"])
        g["eprops_t"] = {
            k: (jnp.asarray(c.vals[t_eid]), jnp.asarray(c.life[t_eid]))
            for k, c in graph.eprops.items()
        }
        graph._device_cache = g
    return g


def execute(
    graph: TemporalGraph,
    qry: Q.PathQuery,
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    sliced: Optional[bool] = None,
    impl: str = "xla",
) -> ExecOutput:
    """Execute a path query with the given plan (split point).

    split=None defaults to left-to-right (split = n-1) for plain queries and
    right-to-left (split = 0) for aggregates.  ``sliced`` selects the
    type-sliced optimised path (engine_sliced.py); None = auto.  ``impl``
    selects the hop-delivery lowering (``HOP_IMPLS``): ``'pallas'`` runs the
    fused hop kernel over the graph's static block layout (interpreter mode
    auto-selected on CPU backends only).  For the partition-sharded
    distributed path use ``engine_partitioned.execute``.
    """
    if split is None:
        split = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
    gdev = _prepare_gdev(graph)
    bedges = jnp.asarray(
        iv.bucket_edges(graph.lifespan[0], graph.lifespan[1], n_buckets)
    )
    from . import engine_sliced as ES

    use_sliced = sliced
    if use_sliced is None:
        use_sliced = ES.sliceable(qry)
    if use_sliced and not ES.sliceable(qry):
        raise ValueError("query not sliceable (wildcard vertex type)")
    key = (id(graph), qry.shape_key(), split, mode, n_buckets,
           bool(use_sliced), SS.check_impl(impl))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        if use_sliced:
            sb = ES.SliceBounds.from_graph(graph)
            layouts = ES.slice_layouts_for(graph, qry, sb, impl)

            def traced(gd, params, be):
                out = ES.execute_plan_sliced(gd, qry, split, mode, n_buckets,
                                             params, be, sb, impl=impl,
                                             layouts=layouts)
                return out.total, out.per_vertex, out.minmax, []
        else:
            layout = hop_layout_for(graph) if SS.use_pallas(impl) else None

            def traced(gd, params, be):
                out = execute_plan_traced(gd, qry, split, mode, n_buckets,
                                          params, be, impl=impl,
                                          layout=layout)
                return (
                    out.total,
                    out.per_vertex,
                    out.minmax,
                    [{k: v for k, v in s.items() if not isinstance(v, str)}
                     for s in out.stats],
                )

        fn = jax.jit(traced)
        _JIT_CACHE[key] = fn
    params = jnp.asarray(Q.query_params(qry))
    total, per_vertex, minmax, stats_vals = fn(gdev, params, bedges)
    if use_sliced and per_vertex is not None:
        # sliced aggregates are on the first-vertex type slice; re-embed
        lo, hi = ES.SliceBounds.from_graph(graph).v[qry.v_preds[0].vtype]
        full_shape = (graph.n_vertices,) + tuple(np.asarray(per_vertex).shape[1:])
        pv = jnp.zeros(full_shape, per_vertex.dtype).at[lo:hi].set(per_vertex)
        per_vertex = pv
    return ExecOutput(total, per_vertex, minmax, stats_vals)


def count_results(graph, qry, **kw) -> float:
    out = execute(graph, qry, **kw)
    t = np.asarray(out.total)
    return float(t.sum()) if t.ndim else float(t)


def check_batch_shape(queries: Sequence[Q.PathQuery]) -> tuple:
    """Validate that a batch shares one template shape; returns the key."""
    assert queries, "empty batch"
    shape0 = queries[0].shape_key()
    for q in queries[1:]:
        if q.shape_key() != shape0:
            raise ValueError("batched queries must share a template shape")
    return shape0


def batch_executable(
    graph: TemporalGraph,
    qry: Q.PathQuery,
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    sliced: Optional[bool] = None,
    impl: str = "xla",
):
    """Compiled batched entry for one query shape (the serving runtime's
    executable unit).

    Returns ``run(params)`` where ``params`` is the stacked parameter tensor
    int32[B, n_clauses, 3] of same-shape instances; ``run`` yields an
    ``ExecOutput`` whose every field carries a leading query axis.  The jitted
    callable is cached per (graph, shape, plan) and retraces only on a new
    batch size B — callers that pad B to size buckets (serving/compile.py)
    re-trace a bounded number of times, then never again.
    """
    if split is None:
        split = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
    gdev = _prepare_gdev(graph)
    bedges = jnp.asarray(
        iv.bucket_edges(graph.lifespan[0], graph.lifespan[1], n_buckets)
    )
    from . import engine_sliced as ES

    use_sliced = ES.sliceable(qry) if sliced is None else sliced
    if use_sliced and not ES.sliceable(qry):
        raise ValueError("query not sliceable (wildcard vertex type)")
    key = ("batch", id(graph), qry.shape_key(), split, mode, n_buckets,
           bool(use_sliced), SS.check_impl(impl))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        if use_sliced:
            sb = ES.SliceBounds.from_graph(graph)
            layouts = ES.slice_layouts_for(graph, qry, sb, impl)

            def one(gd, params, be):
                out = ES.execute_plan_sliced(gd, qry, split, mode, n_buckets,
                                             params, be, sb, impl=impl,
                                             layouts=layouts)
                return out.total, out.per_vertex, out.minmax
        else:
            layout = hop_layout_for(graph) if SS.use_pallas(impl) else None

            def one(gd, params, be):
                out = execute_plan_traced(gd, qry, split, mode, n_buckets,
                                          params, be, impl=impl,
                                          layout=layout)
                return out.total, out.per_vertex, out.minmax

        fn = jax.jit(jax.vmap(one, in_axes=(None, 0, None)))
        _JIT_CACHE[key] = fn

    embed = None
    if use_sliced and qry.agg_op != Q.AGG_NONE:
        embed = ES.SliceBounds.from_graph(graph).v[qry.v_preds[0].vtype]
    V = graph.n_vertices

    def run(params) -> ExecOutput:
        total, per_vertex, minmax = fn(gdev, jnp.asarray(params), bedges)
        if embed is not None and per_vertex is not None:
            # sliced aggregates live on the first-vertex type slice; re-embed
            lo, hi = embed
            full = jnp.zeros((per_vertex.shape[0], V) + per_vertex.shape[2:],
                             per_vertex.dtype)
            per_vertex = full.at[:, lo:hi].set(per_vertex)
        return ExecOutput(total, per_vertex, minmax, [])

    return run


def batch_executable_delta(
    graph: TemporalGraph,
    qry: Q.PathQuery,
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    impl: str = "xla",
):
    """Base+delta twin of ``batch_executable`` for live-graph serving.

    ``graph`` is the COMPACTED BASE; the returned ``run(params, delta)``
    additionally takes a ``graphdata.ingest.DeltaSpec.device()`` dict and
    answers as if the delta edges were part of the graph — bit-identical to
    ``batch_executable`` on the merged epoch graph (tests/test_ingest.py).

    The jit cache key deliberately EXCLUDES the delta: one cached callable
    serves every epoch of a compaction window, retracing only when the
    delta outgrows its pow-2 padded capacity.  That is the executable-cache
    half of delta-aware invalidation — epochs that only append edges keep
    every compiled executable warm.

    ETR hops read whole-graph rank tables, so queries containing them are
    refused (the scheduler serves those from the merged epoch graph).
    """
    if any(e.etr_op != -1 for e in qry.e_preds):
        raise ValueError("ETR hops need global rank tables — not delta-"
                         "executable; serve from the merged epoch graph")
    if split is None:
        split = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
    gdev = _prepare_gdev(graph)
    bedges = jnp.asarray(
        iv.bucket_edges(graph.lifespan[0], graph.lifespan[1], n_buckets)
    )
    key = ("batch_delta", id(graph), qry.shape_key(), split, mode, n_buckets,
           SS.check_impl(impl))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        layout = hop_layout_for(graph) if SS.use_pallas(impl) else None

        def one(gd, params, be, delta):
            out = execute_plan_traced(gd, qry, split, mode, n_buckets,
                                      params, be, impl=impl, layout=layout,
                                      delta=delta)
            return out.total, out.per_vertex, out.minmax

        fn = jax.jit(jax.vmap(one, in_axes=(None, 0, None, None)))
        _JIT_CACHE[key] = fn

    def run(params, delta) -> ExecOutput:
        total, per_vertex, minmax = fn(gdev, jnp.asarray(params), bedges,
                                       delta)
        return ExecOutput(total, per_vertex, minmax, [])

    return run


def execute_batch_out(
    graph: TemporalGraph,
    queries: Sequence[Q.PathQuery],
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    sliced: Optional[bool] = None,
    impl: str = "xla",
) -> ExecOutput:
    """Batched execution of same-shape instances; full ExecOutput with a
    leading query axis on every field (aggregates included)."""
    check_batch_shape(queries)
    run = batch_executable(graph, queries[0], split, mode, n_buckets, sliced,
                           impl=impl)
    params = np.stack([Q.query_params(q) for q in queries])
    return run(params)


def execute_batch(
    graph: TemporalGraph,
    queries: Sequence[Q.PathQuery],
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    sliced: Optional[bool] = None,
    impl: str = "xla",
) -> np.ndarray:
    """Batched execution of query instances sharing one template shape.

    Queries must share ``shape_key()`` (same predicates/ops/hops — only the
    parameter values differ, e.g. the 100 LDBC instances of one template).
    The executable is vmapped over the packed parameter tensor, so a whole
    template batch costs one traversal sweep per hop — the serving-throughput
    mode of the engine (beyond-paper; see DESIGN.md §2 query-as-data).

    Returns totals [n_queries] (static/interval) or [n_queries, B] (bucket).
    For aggregates / per-vertex outputs use ``execute_batch_out``.
    """
    return np.asarray(
        execute_batch_out(graph, queries, split, mode, n_buckets, sliced,
                          impl=impl).total
    )
