"""Granite-JAX distributed temporal path-query engine (Sec. 4 of the paper).

Execution model
---------------
The paper runs one BSP superstep per hop: vertex predicates in ``compute``,
edge predicates + ETR in ``scatter``, partial paths in messages.  Here a
superstep is a dense tensor program over the 2E traversal-edge arrays:

  vertex step : vectorised predicate eval over property columns  → match mask
  edge step   : gather source counts → edge predicate mask → per-edge counts
  delivery    : sorted segment-sum of per-edge counts by arrival vertex

Path multiplicity is carried as float32 *counts* (the tensor form of the
paper's result-tree message compression: per-hop DP state instead of per-path
messages).  Three temporal modes:

  MODE_STATIC    scalar counts               — static temporal graphs
  MODE_BUCKET    counts per time bucket      — dynamic graphs, per-bucket
                 (time-series) semantics; exact per bucket.  Used for the
                 temporal aggregation operator (EQ4-style answers).
  MODE_INTERVAL  counts per running-intersection interval (bucket-pair cells)
                 — dynamic graphs, exact *distinct temporal path* counts on
                 bucket-aligned data.

ETR (edge temporal relationship) hops use precomputed rank tables + segment
prefix sums (see graph.EtrTables): exact, O(E) per hop, no ragged state.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import intervals as iv
from . import query as Q
from .graph import TemporalGraph

MODE_STATIC = 0
MODE_BUCKET = 1
MODE_INTERVAL = 2

_NEG = -(2 ** 30)

# ETR term kinds (rank-array rows in graph.EtrTables):
#   0: #(acc.start <  cur.start)     1: #(acc.start <= cur.start)
#   2: #(acc.start <  cur.end)       3: #(acc.end   <= cur.start)
# spec: (alpha, ((sign, term), ...)) st. result = alpha * n_acc + Σ sign * P[term]
_ETR_SPECS = {
    (iv.FULLY_BEFORE, False): (0.0, ((1.0, 3),)),
    (iv.STARTS_BEFORE, False): (0.0, ((1.0, 0),)),
    (iv.FULLY_AFTER, False): (1.0, ((-1.0, 2),)),
    (iv.STARTS_AFTER, False): (1.0, ((-1.0, 1),)),
    (iv.OVERLAPS, False): (0.0, ((1.0, 2), (-1.0, 3))),
    (iv.FULLY_BEFORE, True): (1.0, ((-1.0, 2),)),
    (iv.STARTS_BEFORE, True): (1.0, ((-1.0, 1),)),
    (iv.FULLY_AFTER, True): (0.0, ((1.0, 3),)),
    (iv.STARTS_AFTER, True): (0.0, ((1.0, 0),)),
    (iv.OVERLAPS, True): (0.0, ((1.0, 2), (-1.0, 3))),
}


# =========================================================================
# clause evaluation
# =========================================================================
def _empty_interval(n):
    return jnp.zeros((n, 2), jnp.int32)


def _eval_prop_clause(col, value, cmp: int, mode: int, bedges, ent_life):
    """Evaluate one property clause over an entity set.

    Returns (match bool[N], validity) where validity is a bucket mask [N,B]
    (MODE_BUCKET), an interval int32[N,2] (MODE_INTERVAL), or None.
    """
    vals, life = col  # [N,S], [N,S,2]
    slot_eq = vals == value
    has_any = jnp.any(vals >= 0, axis=1)
    if cmp == Q.P_NEQ:
        match = has_any & ~jnp.any(slot_eq, axis=1)
        if mode == MODE_BUCKET:
            return match, iv.interval_to_bucket_mask(ent_life, bedges)
        if mode == MODE_INTERVAL:
            return match, ent_life
        return match, None
    # EQ / CONTAINS: any slot equal
    match = jnp.any(slot_eq, axis=1)
    if mode == MODE_BUCKET:
        slot_masks = iv.interval_to_bucket_mask(life, bedges)  # [N,S,B]
        valid = jnp.any(slot_masks & slot_eq[..., None], axis=1)
        return match, valid
    if mode == MODE_INTERVAL:
        idx = jnp.argmax(slot_eq, axis=1)
        sel = jnp.take_along_axis(life, idx[:, None, None], axis=1)[:, 0]  # [N,2]
        valid = jnp.where(match[:, None], sel, 0)
        return match, valid
    return match, None


def _eval_time_clause(ent_life, cmp_id: int, interval, mode: int, bedges):
    const_iv = jnp.broadcast_to(jnp.asarray(interval, jnp.int32), ent_life.shape)
    match = iv.compare(cmp_id, ent_life, const_iv)
    if mode == MODE_BUCKET:
        return match, iv.interval_to_bucket_mask(ent_life, bedges)
    if mode == MODE_INTERVAL:
        return match, ent_life
    return match, None


def _fold_clauses(parts, mode):
    """AND/OR left-fold of (conj, match, validity) triples."""
    acc_m, acc_v = None, None
    for conj, m, v in parts:
        if acc_m is None:
            acc_m, acc_v = m, v
            continue
        if conj == Q.AND:
            acc_m = acc_m & m
            if mode == MODE_BUCKET:
                acc_v = acc_v & v
            elif mode == MODE_INTERVAL:
                acc_v = iv.intersect(acc_v, v)
        else:  # OR
            new_m = acc_m | m
            if mode == MODE_BUCKET:
                acc_v = (acc_v & acc_m[:, None]) | (v & m[:, None])
            elif mode == MODE_INTERVAL:
                # span approximation for OR in interval mode (documented)
                acc_v = jnp.where(
                    (acc_m & ~m)[:, None], acc_v,
                    jnp.where((m & ~acc_m)[:, None], v, iv.span(acc_v, v)),
                )
            acc_m = new_m
    return acc_m, acc_v


def _eval_predicate(
    props: Dict[int, tuple],
    ent_type,
    ent_life,
    req_type: int,
    clauses: Sequence[Q.Clause],
    params,
    pbase: int,
    mode: int,
    bedges,
):
    """Full predicate = type check ∧ folded clauses; returns (match, validity).

    ``params`` carries the data values: row i = (value, t_lo, t_hi) for the
    i-th clause of the whole query; ``pbase`` is this predicate's first row.
    """
    n = ent_life.shape[0]
    match = jnp.ones((n,), bool)
    if req_type >= 0:
        match = ent_type == req_type
    match = match & (ent_life[:, 0] < ent_life[:, 1])
    if mode == MODE_BUCKET:
        validity = iv.interval_to_bucket_mask(ent_life, bedges)
    elif mode == MODE_INTERVAL:
        validity = ent_life
    else:
        validity = None
    parts = []
    for i, c in enumerate(clauses):
        row = params[pbase + i]
        if c.kind == Q.K_PROP:
            col = props[c.key]
            m, v = _eval_prop_clause(col, row[0], c.cmp, mode, bedges, ent_life)
        else:
            m, v = _eval_time_clause(ent_life, c.cmp, row[1:3], mode, bedges)
        parts.append((c.conj, m, v))
    if parts:
        cm, cv = _fold_clauses(parts, mode)
        match = match & cm
        if mode == MODE_BUCKET:
            validity = validity & cv
        elif mode == MODE_INTERVAL:
            validity = iv.intersect(validity, cv)
    return match, validity


# =========================================================================
# mode-generic state ops
# =========================================================================
def _init_state(match, validity, mode: int, n_buckets: int):
    """Seed DP state from a vertex predicate result."""
    if mode == MODE_STATIC:
        return match.astype(jnp.float32)
    if mode == MODE_BUCKET:
        return (match[:, None] & validity).astype(jnp.float32)
    # INTERVAL: one-hot cell at (start_bucket, end_bucket); cells [B, B+1]
    B = n_buckets
    sb, eb = _interval_to_cells(validity, B)
    cell = (
        jax.nn.one_hot(sb, B, dtype=jnp.float32)[:, :, None]
        * jax.nn.one_hot(eb, B + 1, dtype=jnp.float32)[:, None, :]
    )
    return cell * match[:, None, None].astype(jnp.float32)


def _interval_to_cells(ivl, B):
    """Map int32[N,2] intervals to (start_bucket, end_bucket) cell ids."""
    # bedges are closed over by caller via _CELL_EDGES; passed through globals
    # of the trace — instead we normalise intervals to bucket ids here using
    # the bedges captured by _set_bucket_edges (thread-local per trace).
    bedges = _TRACE_BEDGES[-1]
    sb = jnp.clip(jnp.searchsorted(bedges, ivl[:, 0], side="right") - 1, 0, B - 1)
    eb = jnp.clip(jnp.searchsorted(bedges, ivl[:, 1], side="left"), 0, B)
    empty = ivl[:, 0] >= ivl[:, 1]
    eb = jnp.where(empty, sb, eb)  # empty → zero-width cell (filtered later)
    return sb, eb


_TRACE_BEDGES: List = []


def _apply_validity(state, match, validity, mode: int):
    """Multiply state by a predicate's (match, validity) at its entity."""
    if mode == MODE_STATIC:
        return state * match.astype(jnp.float32)
    if mode == MODE_BUCKET:
        return state * (match[:, None] & validity).astype(jnp.float32)
    # INTERVAL: clamp running-intersection cells by the validity interval
    B = state.shape[-2]
    sb, eb = _interval_to_cells(validity, B)
    out = _clamp_start(state, sb)
    out = _clamp_end(out, eb)
    out = out * match[..., None, None].astype(jnp.float32)
    return _mask_valid_cells(out)


def _clamp_start(state, ps):
    """cells[n, s, e] move to (max(s, ps[n]), e)."""
    B = state.shape[-2]
    cum = jnp.cumsum(state, axis=-2)
    keep = (jnp.arange(B)[None, :] > ps[:, None]).astype(state.dtype)
    cum_at = jnp.take_along_axis(cum, ps[:, None, None], axis=-2)[:, 0, :]
    onehot = jax.nn.one_hot(ps, B, dtype=state.dtype)
    return state * keep[:, :, None] + onehot[:, :, None] * cum_at[:, None, :]


def _clamp_end(state, pe):
    """cells[n, s, e] move to (s, min(e, pe[n]))."""
    Bp1 = state.shape[-1]
    rcum = jnp.cumsum(state[..., ::-1], axis=-1)[..., ::-1]
    keep = (jnp.arange(Bp1)[None, :] < pe[:, None]).astype(state.dtype)
    cum_at = jnp.take_along_axis(rcum, pe[:, None, None], axis=-1)[:, :, 0]
    onehot = jax.nn.one_hot(pe, Bp1, dtype=state.dtype)
    return state * keep[:, None, :] + onehot[:, None, :] * cum_at[:, :, None]


def _mask_valid_cells(state):
    B, Bp1 = state.shape[-2], state.shape[-1]
    s_ids = jnp.arange(B)[:, None]
    e_ids = jnp.arange(Bp1)[None, :]
    return state * (s_ids < e_ids).astype(state.dtype)


def _state_total(state, mode):
    if mode == MODE_STATIC:
        return jnp.sum(state)
    if mode == MODE_BUCKET:
        return jnp.sum(state, axis=0)  # per-bucket totals
    return jnp.sum(_mask_valid_cells(state))


# =========================================================================
# ETR prefix machinery
# =========================================================================
def _etr_weighted(gdev, cnt_e_prev, op: int, backward: bool, use_arr: bool):
    """Per current traversal edge: Σ over accumulated arrivals at its vertex
    of cnt × [ETR condition], via rank tables (exact)."""
    alpha, terms = _ETR_SPECS[(op, backward)]
    perm_s = gdev["etr_perm_start"]
    perm_e = gdev["etr_perm_end"]
    ranks = gdev["etr_arr_ranks"] if use_arr else gdev["etr_dep_ranks"]
    ptr = gdev["arr_ptr"]
    segv = gdev["t_dst"] if use_arr else gdev["t_src"]

    trailing = cnt_e_prev.shape[1:]
    zero = jnp.zeros((1,) + trailing, cnt_e_prev.dtype)

    S_s = jnp.concatenate([zero, jnp.cumsum(cnt_e_prev[perm_s], axis=0)], axis=0)
    need_end = any(t == 3 for _, t in terms)
    S_e = (
        jnp.concatenate([zero, jnp.cumsum(cnt_e_prev[perm_e], axis=0)], axis=0)
        if need_end
        else None
    )
    base_pos = ptr[segv]
    base_s = S_s[base_pos]
    out = 0.0
    if alpha:
        n_acc = S_s[ptr[segv + 1]] - base_s
        out = alpha * n_acc
    for sign, term in terms:
        S = S_e if term == 3 else S_s
        base = (S_e[base_pos] if term == 3 else base_s)
        val = S[base_pos + ranks[term]] - base
        out = out + sign * val
    return out


# =========================================================================
# segment execution
# =========================================================================
@dataclasses.dataclass
class SegmentResult:
    arrivals_e: Optional[jnp.ndarray]  # per traversal-edge counts into final vertex
    arrivals_v: Optional[jnp.ndarray]  # segment-sum of the above ([V, *TS])
    stats: List[dict]                  # per-superstep instrumentation
    minmax_v: Optional[jnp.ndarray] = None  # min/max channel at final vertex


def _edge_predicate_weights(gdev, ep: Q.EdgePredicate, params, pbase, mode, bedges):
    """(weight f32[2E], bucket validity or interval validity) for a hop."""
    t_life = gdev["t_life"]
    match, validity = _eval_predicate(
        gdev["eprops_t"], gdev["t_type"], t_life, ep.etype, ep.clauses,
        params, pbase, mode, bedges,
    )
    if ep.direction == Q.DIR_OUT:
        dmask = gdev["t_isfwd"] == 1
    elif ep.direction == Q.DIR_IN:
        dmask = gdev["t_isfwd"] == 0
    else:
        dmask = jnp.ones_like(gdev["t_isfwd"], bool)
    return (match & dmask), validity


def run_segment(
    gdev: dict,
    v_preds: Sequence[Q.VertexPredicate],
    e_preds: Sequence[Q.EdgePredicate],
    params,
    pbases_v: Sequence[int],
    pbases_e: Sequence[int],
    mode: int,
    n_buckets: int,
    backward: bool,
    with_minmax: bool = False,
    minmax_op: int = Q.AGG_MIN,
    minmax_col=None,
) -> SegmentResult:
    """Run one path segment.  v_preds has one more entry than e_preds; the
    FINAL vertex predicate is NOT applied (it belongs to the join).

    Returns raw arrivals (per-edge and per-vertex) at the final vertex.
    """
    V = gdev["v_life"].shape[0]
    stats: List[dict] = []
    bedges = _TRACE_BEDGES[-1] if _TRACE_BEDGES else None

    # ---- init superstep (first vertex predicate)
    vm, vv = _eval_predicate(
        gdev["vprops"], gdev["v_type"], gdev["v_life"], v_preds[0].vtype,
        v_preds[0].clauses, params, pbases_v[0], mode, bedges,
    )
    state_v = _init_state(vm, vv, mode, n_buckets)
    stats.append(dict(phase="init", matched=jnp.sum(vm)))

    mch_v = None
    if with_minmax:
        vals0, _ = minmax_col
        base = vals0[:, 0].astype(jnp.float32)  # first slot value
        bad = jnp.float32(np.inf if minmax_op == Q.AGG_MIN else -np.inf)
        mch_v = jnp.where((state_v if mode == MODE_STATIC else state_v.sum(
            axis=tuple(range(1, state_v.ndim)))) > 0, base, bad)

    arrivals_e = None
    arrivals_v = None
    prev_raw_e = None
    for i, ep in enumerate(e_preds):
        wmask, evalidity = _edge_predicate_weights(
            gdev, ep, params, pbases_e[i], mode, bedges
        )
        if i > 0:
            # apply the intermediate vertex predicate (post-arrival)
            vm, vv = _eval_predicate(
                gdev["vprops"], gdev["v_type"], gdev["v_life"], v_preds[i].vtype,
                v_preds[i].clauses, params, pbases_v[i], mode, bedges,
            )
        if ep.etr_op != -1:
            # ETR hop: prefix-sum over *raw* previous arrivals, then apply the
            # intermediate vertex predicate at the source gather.
            src_cnt = _etr_weighted(gdev, prev_raw_e, ep.etr_op, backward, use_arr=False)
            src_match = vm[gdev["t_src"]]
            if mode == MODE_STATIC:
                src_val = src_cnt * src_match.astype(jnp.float32)
            elif mode == MODE_BUCKET:
                src_val = src_cnt * (vm[:, None] & vv)[gdev["t_src"]].astype(jnp.float32)
            else:
                src_val = _apply_validity(src_cnt, vm[gdev["t_src"]],
                                          vv[gdev["t_src"]], mode)
        else:
            if i == 0:
                sv = state_v
            else:
                sv = _apply_validity(arrivals_v, vm, vv, mode)
            src_val = sv[gdev["t_src"]]
        # edge application
        if mode == MODE_STATIC:
            cnt_e = src_val * wmask.astype(jnp.float32)
        elif mode == MODE_BUCKET:
            cnt_e = src_val * (wmask[:, None] & evalidity).astype(jnp.float32)
        else:
            cnt_e = _apply_validity(src_val, wmask, evalidity, mode)
        arrivals_e = cnt_e
        arrivals_v = jax.ops.segment_sum(
            cnt_e, gdev["t_dst"], num_segments=V, indices_are_sorted=True
        )
        prev_raw_e = cnt_e
        if with_minmax:
            if ep.etr_op != -1:
                raise NotImplementedError("min/max aggregation across ETR hops")
            src_m = mch_v[gdev["t_src"]]
            alive = (cnt_e if mode == MODE_STATIC else cnt_e.sum(
                axis=tuple(range(1, cnt_e.ndim)))) > 0
            bad = jnp.float32(np.inf if minmax_op == Q.AGG_MIN else -np.inf)
            m_e = jnp.where(alive, src_m, bad)
            seg = (jax.ops.segment_min if minmax_op == Q.AGG_MIN else jax.ops.segment_max)
            mch_v = seg(m_e, gdev["t_dst"], num_segments=V, indices_are_sorted=True)
        stats.append(
            dict(
                phase=f"hop{i}",
                matched_edges=jnp.sum(wmask),
                active_edges=jnp.sum(
                    (src_val if mode == MODE_STATIC else src_val.sum(
                        axis=tuple(range(1, src_val.ndim)))) > 0),
            )
        )

    return SegmentResult(arrivals_e, arrivals_v, stats, mch_v)


# =========================================================================
# plan execution (split-point plans, Sec. 4.3)
# =========================================================================
@dataclasses.dataclass
class ExecOutput:
    total: jnp.ndarray                 # scalar (static/interval) or [B] (bucket)
    per_vertex: Optional[jnp.ndarray]  # aggregation output ([V] / [V,B])
    minmax: Optional[jnp.ndarray]
    stats: List[dict]


def _join_interval_counts(L, R):
    """Distinct-path count from left/right running-intersection cell states.

    D = Σ_v Σ_{cells} L·R·[intervals overlap]; computed via the complement
    (total − disjoint) with cumsum contractions — O(V·B²).
    L, R: [V, B, B+1].
    """
    totL = L.sum(axis=(1, 2))
    totR = R.sum(axis=(1, 2))
    Le = L.sum(axis=1)      # [V, B+1] marginal over start
    Ls = L.sum(axis=2)      # [V, B]   marginal over end
    Re = R.sum(axis=1)
    Rs = R.sum(axis=2)
    # pairs with L.end <= R.start  (cells: e1 <= s2)
    cumLe = jnp.cumsum(Le, axis=1)  # Σ_{e1 <= x}
    d1 = jnp.einsum("vb,vb->v", Rs, cumLe[:, : Rs.shape[1]])
    # pairs with R.end <= L.start
    cumRe = jnp.cumsum(Re, axis=1)
    d2 = jnp.einsum("vb,vb->v", Ls, cumRe[:, : Ls.shape[1]])
    return totL * totR - d1 - d2


def execute_plan_traced(
    gdev: dict,
    qry: Q.PathQuery,
    split: int,
    mode: int,
    n_buckets: int,
    params,
    bedges,
):
    """Traceable plan execution.  All query structure is Python-static."""
    _TRACE_BEDGES.append(bedges)
    try:
        return _execute_plan_inner(gdev, qry, split, mode, n_buckets, params)
    finally:
        _TRACE_BEDGES.pop()


def _pbases(qry: Q.PathQuery):
    """Parameter-row offsets per predicate (matching query_params order)."""
    pv, pe = [], []
    off = 0
    for v in qry.v_preds:
        pv.append(off)
        off += len(v.clauses)
    for e in qry.e_preds:
        pe.append(off)
        off += len(e.clauses)
    return pv, pe


def _execute_plan_inner(gdev, qry, split, mode, n_buckets, params):
    V = gdev["v_life"].shape[0]
    n = qry.n_vertices
    assert 0 <= split < n
    pv, pe = _pbases(qry)
    bedges = _TRACE_BEDGES[-1]

    want_agg = qry.agg_op != Q.AGG_NONE
    want_minmax = qry.agg_op in (Q.AGG_MIN, Q.AGG_MAX)
    if want_agg:
        assert split == 0, "aggregate queries group by the first vertex → split=0"

    rev = qry.reversed()

    # ---- left segment: v0 .. v_split (forward)
    left = None
    if split > 0:
        left = run_segment(
            gdev, qry.v_preds[: split + 1], qry.e_preds[:split], params,
            pv[: split + 1], pe[:split], mode, n_buckets, backward=False,
        )

    # ---- right segment: v_{n-1} .. v_split (reversed)
    right = None
    n_right_hops = (n - 1) - split
    if n_right_hops > 0:
        rpv, rpe = _pbases(rev)  # offsets are against rev's own clause order —
        # but params rows were packed for the ORIGINAL query; map them:
        # rev.v_preds[i] == qry.v_preds[n-1-i]; rev.e_preds[j] == qry.e_preds[n-2-j]
        rpv_orig = [pv[n - 1 - i] for i in range(n)]
        rpe_orig = [pe[n - 2 - j] for j in range(n - 1)]
        right = run_segment(
            gdev, rev.v_preds[: n_right_hops + 1], rev.e_preds[:n_right_hops],
            params, rpv_orig[: n_right_hops + 1], rpe_orig[:n_right_hops],
            mode, n_buckets, backward=True,
            with_minmax=want_minmax,
            minmax_op=qry.agg_op,
            minmax_col=(gdev["vprops"].get(qry.agg_key) if want_minmax else None),
        )

    stats = (left.stats if left else []) + (right.stats if right else [])

    # ---- join at v_split
    vm, vv = _eval_predicate(
        gdev["vprops"], gdev["v_type"], gdev["v_life"], qry.v_preds[split].vtype,
        qry.v_preds[split].clauses, params, pv[split], mode, bedges,
    )
    etr_at_join = split > 0 and split < n - 1 and qry.e_preds[split].etr_op != -1

    def vertex_apply(av):
        return _apply_validity(av, vm, vv, mode)

    if n == 1:  # degenerate single-vertex query
        st = _init_state(vm, vv, mode, n_buckets)
        total = _state_total(st, mode)
        return ExecOutput(total, st if want_agg else None, None, stats)

    if not etr_at_join:
        if left is None:
            Rv = vertex_apply(right.arrivals_v)
            if want_agg:
                per_vertex = Rv if mode != MODE_INTERVAL else _cells_to_buckets(Rv)
                total = _state_total(Rv, mode)
                mm = None
                if want_minmax:
                    alive = (Rv if mode == MODE_STATIC else Rv.sum(
                        axis=tuple(range(1, Rv.ndim)))) > 0
                    bad = jnp.float32(np.inf if qry.agg_op == Q.AGG_MIN else -np.inf)
                    mm = jnp.where(alive, right.minmax_v, bad)
                return ExecOutput(total, per_vertex, mm, stats)
            total = _state_total(Rv, mode)
            return ExecOutput(total, None, None, stats)
        if right is None:
            Lv = vertex_apply(left.arrivals_v)
            return ExecOutput(_state_total(Lv, mode), None, None, stats)
        # both sides present, plain product join
        Lv = vertex_apply(left.arrivals_v)
        Rv = right.arrivals_v
        if mode == MODE_STATIC:
            total = jnp.sum(Lv * Rv)
        elif mode == MODE_BUCKET:
            total = jnp.sum(Lv * Rv, axis=0)
        else:
            total = jnp.sum(_join_interval_counts(Lv, Rv))
        return ExecOutput(total, None, None, stats)

    # ---- ETR-at-join: weight right final edges by left arrivals via ranks
    op = qry.e_preds[split].etr_op
    W = _etr_weighted(gdev, left.arrivals_e, op, backward=False, use_arr=True)
    # apply v_split predicate at the join vertex of each right edge
    if mode == MODE_STATIC:
        w_v = vm[gdev["t_dst"]].astype(jnp.float32)
        total = jnp.sum(W * right.arrivals_e * w_v)
    elif mode == MODE_BUCKET:
        mk = (vm[:, None] & vv).astype(jnp.float32)[gdev["t_dst"]]
        total = jnp.sum(W * right.arrivals_e * mk, axis=0)
    else:
        Wc = _apply_validity(W, vm[gdev["t_dst"]], vv[gdev["t_dst"]], mode)
        total = jnp.sum(_join_interval_counts_edges(Wc, right.arrivals_e))
    return ExecOutput(total, None, None, stats)


def _cells_to_buckets(state):
    """[N,B,B+1] running-interval cells → [N,B] per-bucket time series."""
    B = state.shape[-2]
    out = []
    s_ids = jnp.arange(B)[:, None]
    e_ids = jnp.arange(B + 1)[None, :]
    for b in range(B):
        m = ((s_ids <= b) & (e_ids > b)).astype(state.dtype)
        out.append(jnp.sum(state * m, axis=(-2, -1)))
    return jnp.stack(out, axis=-1)


def _join_interval_counts_edges(L, R):
    """Distinct-count join at edge granularity (ETR-at-join, interval mode)."""
    totL = L.sum(axis=(1, 2))
    totR = R.sum(axis=(1, 2))
    Le = L.sum(axis=1)
    Ls = L.sum(axis=2)
    Re = R.sum(axis=1)
    Rs = R.sum(axis=2)
    cumLe = jnp.cumsum(Le, axis=1)
    d1 = jnp.einsum("eb,eb->e", Rs, cumLe[:, : Rs.shape[1]])
    cumRe = jnp.cumsum(Re, axis=1)
    d2 = jnp.einsum("eb,eb->e", Ls, cumRe[:, : Ls.shape[1]])
    return totL * totR - d1 - d2


# =========================================================================
# public API with jit cache
# =========================================================================
_JIT_CACHE: Dict[tuple, callable] = {}


def _prepare_gdev(graph: TemporalGraph) -> dict:
    g = dict(graph.device_arrays())
    # edge property columns gathered into traversal space (2E), lazily cached
    if "eprops_t" not in g:
        t_eid = np.asarray(graph.traversal["t_eid"])
        g["eprops_t"] = {
            k: (jnp.asarray(c.vals[t_eid]), jnp.asarray(c.life[t_eid]))
            for k, c in graph.eprops.items()
        }
        graph._device_cache = g
    return g


def execute(
    graph: TemporalGraph,
    qry: Q.PathQuery,
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    sliced: Optional[bool] = None,
) -> ExecOutput:
    """Execute a path query with the given plan (split point).

    split=None defaults to left-to-right (split = n-1) for plain queries and
    right-to-left (split = 0) for aggregates.  ``sliced`` selects the
    type-sliced optimised path (engine_sliced.py); None = auto.
    """
    if split is None:
        split = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
    gdev = _prepare_gdev(graph)
    bedges = jnp.asarray(
        iv.bucket_edges(graph.lifespan[0], graph.lifespan[1], n_buckets)
    )
    from . import engine_sliced as ES

    use_sliced = sliced
    if use_sliced is None:
        use_sliced = ES.sliceable(qry)
    if use_sliced and not ES.sliceable(qry):
        raise ValueError("query not sliceable (wildcard vertex type)")
    key = (id(graph), qry.shape_key(), split, mode, n_buckets, bool(use_sliced))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        if use_sliced:
            sb = ES.SliceBounds.from_graph(graph)

            def traced(gd, params, be):
                out = ES.execute_plan_sliced(gd, qry, split, mode, n_buckets,
                                             params, be, sb)
                return out.total, out.per_vertex, out.minmax, []
        else:
            def traced(gd, params, be):
                out = execute_plan_traced(gd, qry, split, mode, n_buckets,
                                          params, be)
                return (
                    out.total,
                    out.per_vertex,
                    out.minmax,
                    [{k: v for k, v in s.items() if not isinstance(v, str)}
                     for s in out.stats],
                )

        fn = jax.jit(traced)
        _JIT_CACHE[key] = fn
    params = jnp.asarray(Q.query_params(qry))
    total, per_vertex, minmax, stats_vals = fn(gdev, params, bedges)
    if use_sliced and per_vertex is not None:
        # sliced aggregates are on the first-vertex type slice; re-embed
        lo, hi = ES.SliceBounds.from_graph(graph).v[qry.v_preds[0].vtype]
        full_shape = (graph.n_vertices,) + tuple(np.asarray(per_vertex).shape[1:])
        pv = jnp.zeros(full_shape, per_vertex.dtype).at[lo:hi].set(per_vertex)
        per_vertex = pv
    return ExecOutput(total, per_vertex, minmax, stats_vals)


def count_results(graph, qry, **kw) -> float:
    out = execute(graph, qry, **kw)
    t = np.asarray(out.total)
    return float(t.sum()) if t.ndim else float(t)


def execute_batch(
    graph: TemporalGraph,
    queries: Sequence[Q.PathQuery],
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    sliced: Optional[bool] = None,
) -> np.ndarray:
    """Batched execution of query instances sharing one template shape.

    Queries must share ``shape_key()`` (same predicates/ops/hops — only the
    parameter values differ, e.g. the 100 LDBC instances of one template).
    The executable is vmapped over the packed parameter tensor, so a whole
    template batch costs one traversal sweep per hop — the serving-throughput
    mode of the engine (beyond-paper; see DESIGN.md §2 query-as-data).

    Returns totals [n_queries] (static/interval) or [n_queries, B] (bucket).
    """
    assert queries, "empty batch"
    shape0 = queries[0].shape_key()
    for q in queries[1:]:
        if q.shape_key() != shape0:
            raise ValueError("batched queries must share a template shape")
    qry = queries[0]
    if split is None:
        split = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
    gdev = _prepare_gdev(graph)
    bedges = jnp.asarray(
        iv.bucket_edges(graph.lifespan[0], graph.lifespan[1], n_buckets)
    )
    from . import engine_sliced as ES

    use_sliced = ES.sliceable(qry) if sliced is None else sliced
    key = ("batch", id(graph), shape0, split, mode, n_buckets, bool(use_sliced))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        if use_sliced:
            sb = ES.SliceBounds.from_graph(graph)

            def one(gd, params, be):
                return ES.execute_plan_sliced(
                    gd, qry, split, mode, n_buckets, params, be, sb).total
        else:
            def one(gd, params, be):
                return execute_plan_traced(
                    gd, qry, split, mode, n_buckets, params, be).total

        fn = jax.jit(jax.vmap(one, in_axes=(None, 0, None)))
        _JIT_CACHE[key] = fn
    params = jnp.stack([jnp.asarray(Q.query_params(q)) for q in queries])
    return np.asarray(fn(gdev, params, bedges))
