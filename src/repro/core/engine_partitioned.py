"""Partition-sharded superstep execution — the DISTRIBUTED executor.

This is the paper's actual execution model (Sec. 4): the graph is split by
the two-level partitioner (``graphdata.partitioner``), each worker owns the
traversal edges *arriving* at its vertices, a superstep is

  local compute   per worker: gather boundary state for its halo sources,
                  apply the edge predicate, and DELIVER locally via a
                  per-worker sorted segment-sum (no cross-worker writes);
  exchange        between supersteps: a point-to-point ragged all-to-all
                  (``superstep.p2p_exchange`` over the partitioner's lane
                  tables) delivers each worker exactly the ghost entries its
                  halo names — only boundary state moves, there is no global
                  [V]-sized buffer and no psum reduction per hop.

State lives OWNER-LOCAL throughout a segment: per-worker [W, Vmax, *TS]
vertex state and [W, Emax, *TS] edge counts.  Global views are materialised
once per segment (the plan skeleton joins in global space), not per hop.

Single-device simulation runs the worker axis with ``jax.vmap`` and the
exchange as an axis transpose; with more than one JAX device the WHOLE plan
runs under ``shard_map`` over a ``workers`` mesh axis (one dispatch per
query/batch) and the same exchange moves with one ``lax.all_to_all`` — both
paths are pure data movement over identical tables, hence bit-identical.

Three exchange channels ride the same mechanism:

  plain-hop state    each hop ships the ghost vertices' count state
                     (``PartitionArrays.exchange_volume()`` entries);
  extremum           MIN/MAX aggregates ship the per-vertex extremum channel
                     alongside (same lanes, ±inf fill — ownership is
                     exclusive, so the exchange is a copy, no pmin/pmax);
  ETR rank summaries ETR hops ship only the boundary rank summaries of cut
                     segments (``etr_exchange_volume()`` entries, O(cut
                     edges)): segment owners produce per-edge summaries from
                     SEGMENT-LOCAL prefix tables (``etr_local_summaries``)
                     and route them to the edges' owners.

Semantics: bit-identical to ``engine.execute`` for all three temporal modes
and the FULL query surface — plain counts, COUNT aggregates, MIN/MAX
aggregates and ETR hops.  Every per-edge/per-vertex value equals the dense
engine's because (a) all elementwise primitives come from ``superstep.py``
unchanged, and (b) each vertex's arrival edges live on ONE worker in
canonical order, so per-worker segment reductions reproduce the dense
delivery exactly.

Batched serving (``batch_executable``): the query-batch leading axis is
vmapped INSIDE the shard_map body, so one dispatch runs (batch × workers)
on the device mesh — the scheduler's unit of work on the distributed path.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import hop_scatter as HK
from . import intervals as iv
from . import query as Q
from . import superstep as SS
from .engine import (ExecOutput, SegmentResult, _pbases, _prepare_gdev,
                     execute_plan_traced)
from .graph import TemporalGraph
from .superstep import MODE_BUCKET, MODE_INTERVAL, MODE_STATIC

#: boundary-exchange channels, in reporting order (measure_supersteps,
#: weak_scaling, fit_cost_model all use these indices)
CHANNELS = ("state", "extremum", "etr")


# =========================================================================
# device tables
# =========================================================================
def _prepare_pdev(arrays) -> dict:
    """jnp views of the padded per-worker tables (PartitionArrays)."""
    return dict(
        own_ids=jnp.asarray(arrays.own_ids),
        edge_ids=jnp.asarray(arrays.edge_ids),
        dst_local=jnp.asarray(arrays.dst_local),
        halo_ids=jnp.asarray(arrays.halo_ids),
        src_halo=jnp.asarray(arrays.src_halo),
        halo_own_slot=jnp.asarray(arrays.halo_own_slot),
        xchg_send_slot=jnp.asarray(arrays.xchg_send_slot),
        xchg_recv_slot=jnp.asarray(arrays.xchg_recv_slot),
        etr_perm_local_s=jnp.asarray(arrays.etr_perm_local_s),
        etr_perm_local_e=jnp.asarray(arrays.etr_perm_local_e),
        etr_src_eids=jnp.asarray(arrays.etr_src_eids),
        etr_src_base=jnp.asarray(arrays.etr_src_base),
        etr_src_len=jnp.asarray(arrays.etr_src_len),
        etr_local_slot=jnp.asarray(arrays.etr_local_slot),
        etr_send_slot=jnp.asarray(arrays.etr_send_slot),
        etr_recv_slot=jnp.asarray(arrays.etr_recv_slot),
    )


def _zero_pad_rows(arr):
    """Append one all-zero entity row so pad sentinels gather zeros."""
    return jnp.concatenate(
        [arr, jnp.zeros((1,) + arr.shape[1:], arr.dtype)], axis=0
    )


def _shard_rows(global_arr, ids):
    """Gather global per-entity rows into padded per-worker layout [W, K, ...];
    pad ids point one past the end and read the synthetic zero row."""
    return _zero_pad_rows(global_arr)[ids]


def _halo_gather(sv_halo, src_halo):
    """Per-edge gather from each worker's halo slice.  A zero sentinel slot
    is appended per worker so ``src_halo`` pads (= Hmax) can never alias a
    real halo vertex, even when a worker's ghost set is empty."""
    sv_halo = jnp.concatenate(
        [sv_halo, jnp.zeros_like(sv_halo[:, :1])], axis=1)
    return jax.vmap(lambda h, s: h[s])(sv_halo, src_halo)


def _scatter_rows(rows_w, ids, n_global, fill=0.0):
    """Per-worker rows back to global [n_global, ...].  Each real entity
    appears in exactly one worker row; pads land on the dropped sentinel
    row.  ``fill`` sets the untouched-entry value (0 for count channels, the
    aggregation-neutral ±inf for extremum channels).  Used ONCE per segment
    to publish the final global views — never for the per-hop exchange."""
    flat_ids = ids.reshape(-1)
    flat = rows_w.reshape((-1,) + rows_w.shape[2:])
    out = jnp.full((n_global + 1,) + rows_w.shape[2:], fill, rows_w.dtype)
    return out.at[flat_ids].set(flat, unique_indices=False)[:n_global]


def _gather_vpred_w(vm, vv, own_ids):
    """Gather a global vertex predicate at owned vertices, flattened over
    [Wl·Vmax] (pad slots read the synthetic zero row → dead state)."""
    Wl, Vmax = own_ids.shape
    vm_w = _shard_rows(vm, own_ids).reshape(Wl * Vmax)
    vv_w = None
    if vv is not None:
        g = _shard_rows(vv, own_ids)
        vv_w = g.reshape((Wl * Vmax,) + g.shape[2:])
    return vm_w, vv_w


# =========================================================================
# the local hop (per worker): p2p exchange → halo gather → edge apply →
# local delivery
# =========================================================================
def _exchange_state(state_w, pdev, axis_name, fill=0.0):
    """The vertex-state boundary exchange: every worker receives its halo
    slice — self-owned entries by local copy, ghost entries point-to-point."""
    h_max = pdev["halo_ids"].shape[1]
    return SS.p2p_exchange(state_w, pdev["halo_own_slot"],
                           pdev["xchg_send_slot"], pdev["xchg_recv_slot"],
                           h_max, axis_name, fill=fill)


def _local_hop_p2p(state_w, wmask, evalid, pdev, mode: int, axis_name,
                   mch_w=None, minmax_op: int = Q.AGG_MIN, impl: str = "xla",
                   hop_block_v: int = 256):
    """One superstep on owner-local state.

    state_w [Wl, Vmax, *TS] is the owned-vertex state; ``wmask``/``evalid``
    are the (replicated) global edge-predicate results, gathered at owned
    edges.  When ``mch_w`` [Wl, Vmax] is given, the extremum channel is
    exchanged and delivered alongside on the same lanes.

    With ``impl='pallas'`` (per-worker layout tables ``hop_*`` in ``pdev``)
    each worker's local compute is the FUSED hop kernel mapped over the
    worker axis: gather from the exchanged halo slice → edge apply →
    blocked segment-reduce in VMEM, the extremum channel riding the same
    kernel call.  The per-edge count chain is still traced for the
    publishers that need it (segment-end arrivals_e, next-hop ETR prefix
    sums) and DCE'd when nothing does.

    Returns (cnt_w [Wl, Emax, *TS], arrivals_w [Wl, Vmax, *TS], mch or None).
    """
    edge_ids = pdev["edge_ids"]
    Wl, Emax = edge_ids.shape
    v_max = pdev["own_ids"].shape[1]
    halo = _exchange_state(state_w, pdev, axis_name)        # [Wl, Hmax, *TS]
    src_val = _halo_gather(halo, pdev["src_halo"])          # [Wl, Emax, *TS]
    # local edge predicate application (flatten workers: primitives are
    # elementwise over the leading entity axis)
    flat = lambda a: a.reshape((Wl * Emax,) + a.shape[2:])
    wmask_w = _shard_rows(wmask, edge_ids)
    ev_flat = None if evalid is None else flat(_shard_rows(evalid, edge_ids))
    cnt = SS.apply_edge(flat(src_val), flat(wmask_w), ev_flat, mode)
    cnt_w = cnt.reshape((Wl, Emax) + cnt.shape[1:])
    if SS.use_pallas(impl) and "hop_gather" in pdev:
        neutral = SS.minmax_neutral(minmax_op)
        nul = jnp.zeros((), jnp.float32)
        ev_arg = nul if evalid is None else _shard_rows(evalid, edge_ids)
        mh_arg = (nul if mch_w is None else
                  _exchange_state(mch_w, pdev, axis_name, fill=neutral))

        def one(h, s, wm, ev, lt, mh):
            return SS.fused_hop_deliver(
                h, s, wm, ev, mode, lt, hop_block_v, v_max + 1,
                impl=impl, mch=mh, minmax_op=minmax_op)

        arr, mch_out = jax.vmap(
            one, in_axes=(0, 0, 0, 0 if evalid is not None else None,
                          0, 0 if mch_w is not None else None),
        )(halo, pdev["src_halo"], wmask_w, ev_arg, HK.worker_tables(pdev),
          mh_arg)
        return cnt_w, arr[:, :v_max], (
            None if mch_out is None else mch_out[:, :v_max])
    # local delivery: per-worker sorted segment-sum (pad edges hit the trash
    # segment v_max, sliced off)
    arrivals_w = jax.vmap(
        lambda c, d: SS.deliver(c, d, v_max + 1)
    )(cnt_w, pdev["dst_local"])[:, :v_max]
    mch_out = None
    if mch_w is not None:
        neutral = SS.minmax_neutral(minmax_op)
        m_halo = _exchange_state(mch_w, pdev, axis_name, fill=neutral)
        m_src = _halo_gather(m_halo, pdev["src_halo"])
        m_e = SS.minmax_edge(flat(m_src), cnt, minmax_op, mode)
        mch_out = jax.vmap(
            lambda m, d: SS.deliver_extremum(m, d, v_max + 1, minmax_op)
        )(m_e.reshape((Wl, Emax)), pdev["dst_local"])[:, :v_max]
    return cnt_w, arrivals_w, mch_out


# =========================================================================
# ETR hop: per-worker rank-summary production + p2p summary exchange
# =========================================================================
def _ranks_for_produced(gdev, pdev):
    """Gather the global rank tables at each worker's produced edges:
    [W, 4, Smax]; pads read the appended zero row."""
    ranks_t = gdev["etr_dep_ranks"].T                       # [2E, 4]
    return jnp.swapaxes(_shard_rows(ranks_t, pdev["etr_src_eids"]), 1, 2)


def _worker_etr_summaries(cnt_w, perm_ls, perm_le, base, seg_len, ranks,
                          op: int, backward: bool):
    """Single-worker ETR producer: reorder owned prev-hop counts by the
    per-worker (dst, stat) permutations, take segment-local prefix sums, and
    emit the rank summaries for every edge whose source segment it owns."""
    cnt_pad = jnp.concatenate(
        [cnt_w, jnp.zeros((1,) + cnt_w.shape[1:], cnt_w.dtype)], axis=0)
    cps = cnt_pad[perm_ls]
    cpe = cnt_pad[perm_le] if SS.etr_needs_end(op, backward) else None
    return SS.etr_local_summaries(cps, cpe, base, seg_len, ranks, op, backward)


def _etr_produce_w(cnt_prev_w, gdev, pdev, op: int, backward: bool):
    """All workers' rank summaries from their owned prev-hop counts:
    [Wl, Smax, *TS]."""
    ranks_w = _ranks_for_produced(gdev, pdev)
    return jax.vmap(
        lambda c, pls, ple, b, sl, r: _worker_etr_summaries(
            c, pls, ple, b, sl, r, op, backward)
    )(cnt_prev_w, pdev["etr_perm_local_s"], pdev["etr_perm_local_e"],
      pdev["etr_src_base"], pdev["etr_src_len"], ranks_w)


def _exchange_etr(out_w, pdev, axis_name):
    """The ETR boundary exchange: producers route each summary to the edge's
    owner — self-consumed summaries by local copy, boundary summaries (cut
    segments) point-to-point.  Returns the per-owned-edge summary buffer
    [Wl, Emax, *TS]."""
    e_max = pdev["edge_ids"].shape[1]
    return SS.p2p_exchange(out_w, pdev["etr_local_slot"],
                           pdev["etr_send_slot"], pdev["etr_recv_slot"],
                           e_max, axis_name)


def _etr_apply_sources(summ_flat, vm, vv, tsrc_flat, mode: int):
    """Intermediate vertex predicate at the owned edges' source vertices
    (replicated elementwise compute, no exchange)."""
    if mode == MODE_STATIC:
        return summ_flat * vm[tsrc_flat].astype(jnp.float32)
    if mode == MODE_BUCKET:
        return summ_flat * (vm[:, None] & vv)[tsrc_flat].astype(jnp.float32)
    return SS.apply_validity(summ_flat, vm[tsrc_flat], vv[tsrc_flat], mode)


def _etr_hop_p2p(gdev, pdev, cnt_prev_w, vm, vv, wmask, evalid, op: int,
                 backward: bool, mode: int, axis_name, impl: str = "xla",
                 hop_block_v: int = 256):
    """One ETR superstep on owner-local state: produce → exchange →
    consumer edge apply + local delivery.  The per-edge counts exist here by
    construction (the rank summaries are per-edge), so the kernel path uses
    the delivery-only blocked scatter, not the fused hop."""
    edge_ids = pdev["edge_ids"]
    Wl, Emax = edge_ids.shape
    v_max = pdev["own_ids"].shape[1]
    out_w = _etr_produce_w(cnt_prev_w, gdev, pdev, op, backward)
    summ_w = _exchange_etr(out_w, pdev, axis_name)          # [Wl, Emax, *TS]
    flat = lambda a: a.reshape((Wl * Emax,) + a.shape[2:])
    tsrc_flat = _shard_rows(gdev["t_src"], edge_ids).reshape(-1)
    sv = _etr_apply_sources(flat(summ_w), vm, vv, tsrc_flat, mode)
    ev_flat = None if evalid is None else flat(_shard_rows(evalid, edge_ids))
    cnt = SS.apply_edge(sv, flat(_shard_rows(wmask, edge_ids)), ev_flat, mode)
    cnt_w = cnt.reshape((Wl, Emax) + cnt.shape[1:])
    if SS.use_pallas(impl) and "hop_gather" in pdev:
        arrivals_w = jax.vmap(
            lambda c, lt: HK.scatter_deliver(
                c, lt, v_max + 1, hop_block_v, impl=impl)
        )(cnt_w, HK.worker_tables(pdev))[:, :v_max]
    else:
        arrivals_w = jax.vmap(
            lambda c, d: SS.deliver(c, d, v_max + 1)
        )(cnt_w, pdev["dst_local"])[:, :v_max]
    return cnt_w, arrivals_w


# =========================================================================
# segment runner (plugs into engine.execute_plan_traced)
# =========================================================================
def run_segment_partitioned(
    gdev: dict,
    pdev: dict,
    axis_name: Optional[str],
    impl: str,
    hop_block_v: int,
    v_preds: Sequence[Q.VertexPredicate],
    e_preds: Sequence[Q.EdgePredicate],
    params,
    pbases_v: Sequence[int],
    pbases_e: Sequence[int],
    mode: int,
    n_buckets: int,
    backward: bool,
    with_minmax: bool = False,
    minmax_op: int = Q.AGG_MIN,
    minmax_col=None,
) -> SegmentResult:
    """Partitioned twin of engine.run_segment on owner-local state.

    ``axis_name`` names the shard_map mesh axis the worker dimension is
    sharded over (None = single-device vmap simulation).  Per-hop state
    never leaves the workers except through the point-to-point exchange;
    the GLOBAL views the shared plan/join skeleton needs are published once
    at segment end (the only psum on the distributed path)."""
    V = gdev["v_life"].shape[0]
    n2e = gdev["t_dst"].shape[0]
    stats: List[dict] = []
    bedges = SS.current_bedges()
    own_ids = pdev["own_ids"]
    Wl, Vmax = own_ids.shape

    vm, vv = SS.eval_predicate(
        gdev["vprops"], gdev["v_type"], gdev["v_life"], v_preds[0].vtype,
        v_preds[0].clauses, params, pbases_v[0], mode, bedges,
    )
    vm_w, vv_w = _gather_vpred_w(vm, vv, own_ids)
    state = SS.init_state(vm_w, vv_w, mode, n_buckets)
    state_w = state.reshape((Wl, Vmax) + state.shape[1:])
    stats.append(dict(phase="init", matched=jnp.sum(vm)))

    mch_w = None   # owner-local extremum channel [Wl, Vmax]
    if with_minmax:
        vals0, _ = minmax_col
        g = _shard_rows(vals0, own_ids)
        mch = SS.minmax_seed(state, g.reshape((Wl * Vmax,) + g.shape[2:]),
                             minmax_op, mode)
        mch_w = mch.reshape(Wl, Vmax)

    cnt_w = None       # owner-local per-edge counts of the last hop
    arrivals_w = None  # owner-local last delivery [Wl, Vmax, *TS]
    for i, ep in enumerate(e_preds):
        wmask, evalid = SS.edge_predicate_weights(
            gdev, ep, params, pbases_e[i], mode, bedges)
        if i > 0:
            vm, vv = SS.eval_predicate(
                gdev["vprops"], gdev["v_type"], gdev["v_life"],
                v_preds[i].vtype, v_preds[i].clauses, params, pbases_v[i],
                mode, bedges,
            )
        if ep.etr_op != -1:
            if with_minmax:
                raise NotImplementedError(
                    "min/max aggregation across ETR hops")
            cnt_w, arrivals_w = _etr_hop_p2p(
                gdev, pdev, cnt_w, vm, vv, wmask, evalid, ep.etr_op,
                backward, mode, axis_name, impl, hop_block_v)
        else:
            if i > 0:
                vm_w, vv_w = _gather_vpred_w(vm, vv, own_ids)
                av = arrivals_w.reshape((Wl * Vmax,) + arrivals_w.shape[2:])
                state = SS.apply_validity(av, vm_w, vv_w, mode)
                state_w = state.reshape((Wl, Vmax) + state.shape[1:])
            cnt_w, arrivals_w, mch_w = _local_hop_p2p(
                state_w, wmask, evalid, pdev, mode, axis_name,
                mch_w, minmax_op, impl, hop_block_v)
        stats.append(dict(phase=f"hop{i}", matched_edges=jnp.sum(wmask)))

    # publish the segment's GLOBAL views (the skeleton joins in global
    # space); under shard_map the partial scatters combine with one psum
    # (pmin/pmax for the extremum channel) — once per segment, not per hop.
    arrivals_e = _scatter_rows(cnt_w, pdev["edge_ids"], n2e)
    arrivals_v = _scatter_rows(arrivals_w, pdev["own_ids"], V)
    mch_g = None
    if mch_w is not None:
        mch_g = _scatter_rows(mch_w, pdev["own_ids"], V,
                              fill=SS.minmax_neutral(minmax_op))
    if axis_name is not None:
        arrivals_e = jax.lax.psum(arrivals_e, axis_name)
        arrivals_v = jax.lax.psum(arrivals_v, axis_name)
        if mch_g is not None:
            combine = (jax.lax.pmin if minmax_op == Q.AGG_MIN
                       else jax.lax.pmax)
            mch_g = combine(mch_g, axis_name)
    return SegmentResult(arrivals_e, arrivals_v, stats, mch_g)


# =========================================================================
# shard_map wrapper (whole-plan, one dispatch)
# =========================================================================
def _get_shard_map():
    try:  # moved out of experimental in newer jax
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    import inspect
    # the replication-check kwarg was renamed check_rep → check_vma; detect
    # from the signature, not from where the import succeeded
    rep_kw = ("check_vma" if "check_vma" in
              inspect.signature(shard_map).parameters else "check_rep")
    return shard_map, rep_kw


def _wrap_shard_map(body, n_devices: int):
    """shard_map a whole traced plan ``body(gdev, pdev, params, bedges)``:
    the per-worker tables are sharded over the ``workers`` mesh axis, the
    graph tables/params replicated, the outputs replicated (identical on
    every device after the segment-end psum)."""
    from jax.sharding import PartitionSpec as P

    from ..launch.mesh import make_worker_mesh

    shard_map, rep_kw = _get_shard_map()
    mesh = make_worker_mesh(n_devices)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), P("workers"), P(), P()),
        out_specs=P(),
        **{rep_kw: False},
    )


# =========================================================================
# public API
# =========================================================================
_JIT_CACHE: Dict[tuple, callable] = {}


def partition_for(graph: TemporalGraph, n_workers: int,
                  parts_per_type: Optional[int] = None):
    """(Partitioning, PartitionArrays, device tables) for a graph, cached ON
    the graph object (like its device-array cache) so the cache's lifetime —
    and the validity of the per-graph ownership tables — is tied to the
    graph itself."""
    from ..graphdata.partitioner import build_partition_arrays, partition_graph

    ppt = parts_per_type if parts_per_type is not None else max(4, n_workers // 2)
    cache = getattr(graph, "_partition_cache", None)
    if cache is None:
        cache = {}
        graph._partition_cache = cache
    key = (n_workers, ppt)
    hit = cache.get(key)
    if hit is None:
        # an ingestion epoch attaches a partition hint (graphdata/ingest.py)
        # that extends the BASE graph's cached partitioning over the delta
        # instead of re-running BFS growth; None → fresh partition
        part = None
        hint = getattr(graph, "_partition_hint", None)
        if hint is not None:
            part = hint(n_workers, ppt)
        if part is None:
            part = partition_graph(graph, n_workers=n_workers,
                                   parts_per_type=ppt)
        arrays = build_partition_arrays(graph, part)
        hit = (part, arrays, _prepare_pdev(arrays))
        cache[key] = hit
    return hit


def _with_hop_layouts(pdev: dict, arrays, impl: str):
    """Merge the per-worker hop-kernel layout tables into the device tables
    when the kernel path is selected.  The layout tensors have the worker
    axis leading, so they shard over the ``workers`` mesh axis exactly like
    the partitioner's other padded tables."""
    if not SS.use_pallas(SS.check_impl(impl)):
        return pdev, 0
    tables, block_v = arrays.worker_hop_layouts()
    return {**pdev, **tables}, block_v


def resolve_n_devices(requested: Optional[bool], n_workers: int) -> int:
    """How many devices to shard the worker axis over (1 = vmap simulation).
    ``requested`` is the user's ``use_shard_map`` tri-state: False forces the
    simulation, None/True shard when devices exist and divide the workers."""
    nd = jax.device_count()
    if requested is False or nd <= 1 or n_workers % nd != 0:
        return 1
    return nd


def _plan_fn(qry, split, mode, n_buckets, n_devices, batched: bool = False,
             impl: str = "xla", hop_block_v: int = 256):
    """Build the jitted (possibly shard_mapped) plan callable — the ONE
    construction both the sequential ``execute`` and the serving
    ``batch_executable`` entries share.  ``batched`` vmaps the params axis;
    on the sharded path that vmap sits INSIDE the shard_map body, so one
    dispatch runs (batch × workers) on the device mesh.  ``impl`` selects
    the per-worker delivery lowering (the fused hop kernel reads the
    ``hop_*`` layout tables riding in ``pd``)."""
    def plan(gd, pd, params, be, axis_name):
        runner = partial(run_segment_partitioned, gd, pd, axis_name, impl,
                         hop_block_v)
        out = execute_plan_traced(gd, qry, split, mode, n_buckets, params,
                                  be, segment_runner=runner)
        return out.total, out.per_vertex, out.minmax

    axis = None if n_devices <= 1 else "workers"
    body = lambda gd, pd, p, be: plan(gd, pd, p, be, axis)
    if batched:
        body = jax.vmap(body, in_axes=(None, None, 0, None))
    if n_devices <= 1:
        return jax.jit(body)
    return jax.jit(_wrap_shard_map(body, n_devices))


def execute(
    graph: TemporalGraph,
    qry: Q.PathQuery,
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    n_workers: int = 4,
    parts_per_type: Optional[int] = None,
    use_shard_map: Optional[bool] = None,
    impl: str = "xla",
) -> ExecOutput:
    """Partition-sharded execution; identical results to ``engine.execute``.

    ``n_workers`` selects the two-level partitioning (cached per graph).
    When >1 JAX devices exist and divide ``n_workers``, the whole plan runs
    under shard_map on a ``workers`` device mesh (point-to-point exchange
    between supersteps); otherwise the worker axis is vmapped on one device.
    ``impl='pallas'`` runs each worker's local hop through the fused kernel
    over its shard's block layout (``PartitionArrays.worker_hop_layouts``).
    """
    if split is None:
        split = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
    gdev = _prepare_gdev(graph)
    _, arrays, pdev = partition_for(graph, n_workers, parts_per_type)
    pdev, hop_block_v = _with_hop_layouts(pdev, arrays, impl)
    n_devices = resolve_n_devices(use_shard_map, n_workers)
    bedges = jnp.asarray(
        iv.bucket_edges(graph.lifespan[0], graph.lifespan[1], n_buckets)
    )
    key = (id(graph), qry.shape_key(), split, mode, n_buckets, n_workers,
           arrays.v_max, n_devices, SS.check_impl(impl))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = _plan_fn(qry, split, mode, n_buckets, n_devices, impl=impl,
                      hop_block_v=hop_block_v)
        _JIT_CACHE[key] = fn
    params = jnp.asarray(Q.query_params(qry))
    total, per_vertex, minmax = fn(gdev, pdev, params, bedges)
    return ExecOutput(total, per_vertex, minmax, [])


def count_results(graph, qry, **kw) -> float:
    out = execute(graph, qry, **kw)
    t = np.asarray(out.total)
    return float(t.sum()) if t.ndim else float(t)


def hop_exchange_channels(qry: Q.PathQuery, arrays) -> List[Dict[str, int]]:
    """Structural per-HOP boundary volume per channel on the p2p lanes —
    the CANONICAL statement of what each hop exchanges (the flight
    recorder's per-hop exchange spans report exactly these rows; the
    planner's ``estimate_segment`` channels/m_net terms apply the same rule
    per step).  Mirrors the plan skeleton: aggregates run the reversed
    segment, MIN/MAX ride the extremum channel on every plain hop, ETR hops
    ship only the boundary rank summaries."""
    minmax = qry.agg_op in (Q.AGG_MIN, Q.AGG_MAX)
    rows = []
    for ep in qry.e_preds:
        if ep.etr_op != -1:
            rows.append(dict(state=0, extremum=0,
                             etr=int(arrays.etr_exchange_volume())))
        else:
            v = int(arrays.exchange_volume())
            rows.append(dict(state=v, extremum=v if minmax else 0, etr=0))
    return rows


def query_exchange_volumes(qry: Q.PathQuery, arrays) -> Dict[str, int]:
    """Whole-query boundary volume per channel: the sum of
    ``hop_exchange_channels`` over the query's hops."""
    totals = dict(state=0, extremum=0, etr=0)
    for row in hop_exchange_channels(qry, arrays):
        for ch, v in row.items():
            totals[ch] += v
    return totals


def batch_executable(
    graph: TemporalGraph,
    qry: Q.PathQuery,
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    n_workers: int = 4,
    parts_per_type: Optional[int] = None,
    use_shard_map: Optional[bool] = None,
    impl: str = "xla",
):
    """Compiled batched entry on the DISTRIBUTED path: the whole superstep
    pipeline (p2p halo exchange → local delivery → segment-end publish) runs
    with a query-batch leading axis — one partitioned traversal sweep serves
    the entire same-shape batch.

    Returns ``run(params[B, n_clauses, 3]) -> ExecOutput`` with a leading
    query axis on every field.  With >1 devices dividing ``n_workers`` the
    batch axis is vmapped INSIDE the shard_map body, so ONE dispatch runs
    (batch × workers) on the device mesh; otherwise the worker axis runs in
    the (bit-identical) single-device vmap simulation.
    """
    if split is None:
        split = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
    gdev = _prepare_gdev(graph)
    _, arrays, pdev = partition_for(graph, n_workers, parts_per_type)
    pdev, hop_block_v = _with_hop_layouts(pdev, arrays, impl)
    n_devices = resolve_n_devices(use_shard_map, n_workers)
    bedges = jnp.asarray(
        iv.bucket_edges(graph.lifespan[0], graph.lifespan[1], n_buckets)
    )
    key = ("batch", id(graph), qry.shape_key(), split, mode, n_buckets,
           n_workers, arrays.v_max, n_devices, SS.check_impl(impl))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = _plan_fn(qry, split, mode, n_buckets, n_devices, batched=True,
                      impl=impl, hop_block_v=hop_block_v)
        _JIT_CACHE[key] = fn

    def run(params) -> ExecOutput:
        total, per_vertex, minmax = fn(gdev, pdev, jnp.asarray(params), bedges)
        return ExecOutput(total, per_vertex, minmax, [])

    return run


def execute_batch_out(
    graph: TemporalGraph,
    queries: Sequence[Q.PathQuery],
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    n_workers: int = 4,
    parts_per_type: Optional[int] = None,
    use_shard_map: Optional[bool] = None,
    impl: str = "xla",
) -> ExecOutput:
    """Batched partitioned execution of same-shape instances."""
    from .engine import check_batch_shape
    check_batch_shape(queries)
    run = batch_executable(graph, queries[0], split, mode, n_buckets,
                           n_workers, parts_per_type, use_shard_map,
                           impl=impl)
    params = np.stack([Q.query_params(q) for q in queries])
    return run(params)


# =========================================================================
# instrumented per-worker superstep timing (weak-scaling benchmark)
# =========================================================================
@dataclasses.dataclass
class SuperstepProfile:
    times_s: np.ndarray            # float64[n_hops, W] — measured local-hop time
    exchange_msgs: np.ndarray      # int64[n_hops] — boundary messages (all channels)
    exchange_channels: np.ndarray  # int64[n_hops, 3] — per CHANNELS breakdown
    total: float                   # query total (sanity cross-check)

    @property
    def makespan_s(self) -> np.ndarray:
        """Per-superstep makespan: the straggler worker's measured time."""
        return self.times_s.max(axis=1)

    @property
    def balance_eff(self) -> float:
        per_worker = self.times_s.sum(axis=0)
        return float(per_worker.mean() / max(per_worker.max(), 1e-12))

    def channel_totals(self) -> Dict[str, int]:
        """Whole-query boundary volume per exchange channel."""
        sums = self.exchange_channels.sum(axis=0)
        return {name: int(sums[i]) for i, name in enumerate(CHANNELS)}


_PROFILE_CACHE: Dict[tuple, dict] = {}


def _profile_fns(qry: Q.PathQuery, mode: int, n_buckets: int, v_max: int,
                 v_preds, e_preds, pv, pe, backward: bool,
                 with_minmax: bool, minmax_op: int, impl: str = "xla",
                 hop_block_v: int = 0) -> dict:
    """Jitted helpers for measure_supersteps, cached per (query shape, mode,
    buckets, padded worker extent, impl) so repeated profiling of one
    template (weak_scaling, fit_cost_model) re-traces nothing.  All graph
    data is passed as arguments; only static query structure is baked in."""
    # shape_key() covers agg_op/agg_key, i.e. the full profiled structure
    key = (qry.shape_key(), mode, n_buckets, v_max, impl)
    fns = _PROFILE_CACHE.get(key)
    if fns is not None:
        return fns
    fused = SS.use_pallas(impl)

    def vpred(i):
        def f(gd, prm, be):
            with SS.bucket_scope(be):
                vp = v_preds[i]
                return SS.eval_predicate(gd["vprops"], gd["v_type"],
                                         gd["v_life"], vp.vtype, vp.clauses,
                                         prm, pv[i], mode, be)
        return jax.jit(f)

    def hop_masks(i):
        def f(gd, prm, be):
            with SS.bucket_scope(be):
                return SS.edge_predicate_weights(gd, e_preds[i], prm,
                                                 pe[i], mode, be)
        return jax.jit(f)

    @jax.jit
    def init_fn(m, v, own, be):
        with SS.bucket_scope(be):
            Wl, Vmax = own.shape
            m_w, v_w = _gather_vpred_w(m, v if v.ndim else None, own)
            st = SS.init_state(m_w, v_w, mode, n_buckets)
            return st.reshape((Wl, Vmax) + st.shape[1:])

    @jax.jit
    def seed_mch(state_w, vals0, own):
        Wl, Vmax = own.shape
        g = _shard_rows(vals0, own)
        st = state_w.reshape((Wl * Vmax,) + state_w.shape[2:])
        mch = SS.minmax_seed(st, g.reshape((Wl * Vmax,) + g.shape[2:]),
                             minmax_op, mode)
        return mch.reshape(Wl, Vmax)

    @jax.jit
    def apply_vv_w(arr_w, m, v, own, be):
        with SS.bucket_scope(be):
            Wl, Vmax = own.shape
            m_w, v_w = _gather_vpred_w(m, v if v.ndim else None, own)
            st = SS.apply_validity(
                arr_w.reshape((Wl * Vmax,) + arr_w.shape[2:]), m_w, v_w, mode)
            return st.reshape((Wl, Vmax) + st.shape[1:])

    # the UNTIMED exchanges: point-to-point lane moves between supersteps
    @jax.jit
    def exchange_state_fn(state_w, pd):
        return _exchange_state(state_w, pd, None)

    @jax.jit
    def exchange_mch_fn(mch_w, pd):
        return _exchange_state(mch_w, pd, None,
                               fill=SS.minmax_neutral(minmax_op))

    # ONE compiled local-hop executable reused for every (hop, worker): each
    # worker's tables arrive with a leading axis of 1 so shapes agree.  The
    # halo buffer arrives pre-exchanged; the TIMED work is the local gather,
    # edge apply and delivery — the per-worker compute a real deployment's
    # straggler/makespan comes from.  On the kernel path that work is ONE
    # fused hop-kernel call; the per-edge counts are produced only by the
    # ``with_cnt`` variant, selected per hop by whether the NEXT hop's ETR
    # producer actually consumes them (so the timing never pays for a chain
    # the executor's jit would have DCE'd).
    def make_one_worker_hop(with_cnt: bool):
        @jax.jit
        def one_worker_hop(halo_1, wm, ev, eids, dloc, lt, shalo, mch_halo,
                           be):
            with SS.bucket_scope(be):
                e_max = eids.shape[1]
                flatten = lambda a: a.reshape((e_max,) + a.shape[2:])
                evf = None if not ev.ndim else flatten(_shard_rows(ev, eids))
                if fused:
                    mh = mch_halo[0] if mch_halo.ndim else None
                    ev_w = None if not ev.ndim else _shard_rows(ev, eids)[0]
                    arr, mch = SS.fused_hop_deliver(
                        halo_1[0], shalo[0], _shard_rows(wm, eids)[0], ev_w,
                        mode, {k: v[0] for k, v in lt.items()}, hop_block_v,
                        v_max + 1, impl=impl, mch=mh, minmax_op=minmax_op)
                    arr = arr[:v_max]
                    mch = (mch[:v_max][None] if mch is not None
                           else jnp.zeros((), jnp.float32))
                else:
                    src_val = _halo_gather(halo_1, shalo)
                    cnt = SS.apply_edge(flatten(src_val),
                                        flatten(_shard_rows(wm, eids)), evf,
                                        mode)
                    arr = SS.deliver(cnt, dloc[0], v_max + 1)[:v_max]
                    if mch_halo.ndim:
                        m_src = _halo_gather(mch_halo, shalo)
                        m_e = SS.minmax_edge(flatten(m_src), cnt, minmax_op,
                                             mode)
                        mch = SS.deliver_extremum(m_e, dloc[0], v_max + 1,
                                                  minmax_op)[:v_max][None]
                    else:
                        mch = jnp.zeros((), jnp.float32)
                if not with_cnt:
                    return jnp.zeros((), jnp.float32), arr[None], mch
                if fused:
                    src_val = _halo_gather(halo_1, shalo)
                    cnt = SS.apply_edge(flatten(src_val),
                                        flatten(_shard_rows(wm, eids)), evf,
                                        mode)
                return cnt[None], arr[None], mch
        return one_worker_hop

    # ETR producer body: segment-local prefix tables over the worker's owned
    # prev-hop counts → rank summaries for the edges whose source it owns.
    def etr_produce(i):
        op = e_preds[i].etr_op

        def f(cnt_1, pls, ple, base, slen, ranks, be):
            with SS.bucket_scope(be):
                return _worker_etr_summaries(cnt_1[0], pls[0], ple[0],
                                             base[0], slen[0], ranks[0], op,
                                             backward)[None]
        return jax.jit(f)

    @jax.jit
    def exchange_etr_fn(out_w, pd):
        return _exchange_etr(out_w, pd, None)

    # ETR consumer body: the received summaries are the exchanged state; the
    # local part is source-predicate apply + edge apply + delivery (counts
    # are per-edge by construction here, so the kernel path is the blocked
    # delivery-only scatter).
    @jax.jit
    def one_worker_etr(summ_1, m, v, tsrc, wm, ev, eids, dloc, lt, be):
        with SS.bucket_scope(be):
            e_max = eids.shape[1]
            flatten = lambda a: a.reshape((e_max,) + a.shape[2:])
            sv = _etr_apply_sources(flatten(summ_1), m,
                                    v if v.ndim else None,
                                    _shard_rows(tsrc, eids).reshape(-1), mode)
            evf = None if not ev.ndim else flatten(_shard_rows(ev, eids))
            cnt = SS.apply_edge(sv, flatten(_shard_rows(wm, eids)), evf, mode)
            if fused:
                arr = HK.scatter_deliver(cnt, {k: x[0] for k, x in
                                               lt.items()},
                                         v_max + 1, hop_block_v,
                                         impl=impl)[:v_max]
            else:
                arr = SS.deliver(cnt, dloc[0], v_max + 1)[:v_max]
            return cnt[None], arr[None]

    @jax.jit
    def total_fn(arr_w, own, m, v, be):
        with SS.bucket_scope(be):
            V = m.shape[0]
            av = _scatter_rows(arr_w, own, V)
            return SS.state_total(
                SS.apply_validity(av, m, v if v.ndim else None, mode), mode)

    fns = dict(
        vpred=[vpred(i) for i in range(len(v_preds))],
        hop_masks=[hop_masks(i) for i in range(len(e_preds))],
        etr_produce=[etr_produce(i) if ep.etr_op != -1 else None
                     for i, ep in enumerate(e_preds)],
        init_fn=init_fn,
        seed_mch=seed_mch,
        apply_vv_w=apply_vv_w,
        exchange_state_fn=exchange_state_fn,
        exchange_mch_fn=exchange_mch_fn,
        exchange_etr_fn=exchange_etr_fn,
        one_worker_hop=make_one_worker_hop(with_cnt=True),
        one_worker_hop_light=make_one_worker_hop(with_cnt=False),
        one_worker_etr=one_worker_etr,
        total_fn=total_fn,
    )
    _PROFILE_CACHE[key] = fns
    return fns


def measure_supersteps(
    graph: TemporalGraph,
    qry: Q.PathQuery,
    n_workers: int = 4,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    parts_per_type: Optional[int] = None,
    repeats: int = 2,
    impl: str = "xla",
    tracer=None,
) -> SuperstepProfile:
    """Measured (not modelled) per-worker superstep times.

    ``tracer`` (an ``obs.trace.Tracer``; None/NULL_TRACER = off) records the
    profile as a span tree — measure_supersteps → superstep (per hop, with
    the per-worker measured times) → exchange (per-channel boundary
    volumes) — the same schema the serving flight recorder emits, so
    trace_report renders profiler runs and served queries alike.

    ``impl`` selects the timed local-hop lowering (the xla-vs-pallas hop
    timings benchmarks/weak_scaling reports): ``'pallas'`` times the fused
    hop kernel per worker; the boundary-exchange volumes are impl-invariant.

    Plain-count queries profile the left-to-right plan (split = n−1); COUNT
    and MIN/MAX aggregates profile the reversed segment (split = 0, the plan
    aggregates run), with MIN/MAX threading the extremum channel through
    every hop — so all three boundary channels are measurable.  Each
    worker's local compute runs SEPARATELY through one compiled
    single-worker hop function and is timed with block_until_ready; the
    point-to-point exchange (state / extremum / ETR rank-summary lanes) runs
    between timings, untimed, and its per-channel ragged volume is reported
    in ``exchange_channels`` (halo ghosts for state and extremum, boundary
    rank summaries — cut edges — for ETR).
    """
    want_minmax = qry.agg_op in (Q.AGG_MIN, Q.AGG_MAX)
    if want_minmax and any(ep.etr_op != -1 for ep in qry.e_preds):
        # same rejection as every executor: a profile of an unrunnable plan
        # would silently poison the θ_net fit population
        raise NotImplementedError("min/max aggregation across ETR hops")
    backward = qry.agg_op != Q.AGG_NONE
    gdev = _prepare_gdev(graph)
    _, arrays, pdev = partition_for(graph, n_workers, parts_per_type)
    pdev, hop_block_v = _with_hop_layouts(pdev, arrays, impl)
    W = arrays.n_workers
    v_max = arrays.v_max
    bedges = jnp.asarray(
        iv.bucket_edges(graph.lifespan[0], graph.lifespan[1], n_buckets)
    )
    params = jnp.asarray(Q.query_params(qry))
    pv, pe = _pbases(qry)
    n = qry.n_vertices
    if backward:
        # the aggregate plan's (reversed) segment, params rows mapped back
        # to the original packing — same mapping as execute_plan_traced
        rev = qry.reversed()
        v_preds, e_preds = rev.v_preds, rev.e_preds
        pv = [pv[n - 1 - i] for i in range(n)]
        pe = [pe[n - 2 - j] for j in range(n - 1)]
    else:
        v_preds, e_preds = qry.v_preds, qry.e_preds
    n_hops = len(e_preds)

    fns = _profile_fns(qry, mode, n_buckets, v_max, v_preds, e_preds, pv, pe,
                       backward, want_minmax, qry.agg_op,
                       impl=SS.check_impl(impl), hop_block_v=hop_block_v)
    vpred, hop_masks = fns["vpred"], fns["hop_masks"]
    etr_produce = fns["etr_produce"]
    ranks_w = _ranks_for_produced(gdev, pdev)

    def _timed(fn, *args):
        best, out = np.inf, None
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best, out

    # ev/vv=None can't cross jit; encode "absent" as a 0-d placeholder.
    nul = jnp.zeros((), jnp.float32)
    if SS.use_pallas(impl):
        def hop_tabs(w):
            return HK.worker_tables(pdev, slice(w, w + 1))
    else:
        def hop_tabs(w):
            return {k: nul for k in HK.TABLE_KEYS}

    times = np.zeros((n_hops, W))
    channels = np.zeros((n_hops, len(CHANNELS)), np.int64)
    n_ghost = int(arrays.n_ghost.sum())
    n_etr_ghost = int(arrays.n_src_ghost.sum())

    vm, vv = vpred[0](gdev, params, bedges)
    vv_arg = nul if vv is None else vv
    state_w = fns["init_fn"](vm, vv_arg, pdev["own_ids"], bedges)
    mch_w = None
    if want_minmax:
        vals0, _ = gdev["vprops"][qry.agg_key]
        mch_w = fns["seed_mch"](state_w, vals0, pdev["own_ids"])
    cnt_w = None
    arrivals_w = None
    for i, ep in enumerate(e_preds):
        wmask, evalid = hop_masks[i](gdev, params, bedges)
        ev_arg = nul if evalid is None else evalid
        if i > 0:
            vm, vv = vpred[i](gdev, params, bedges)
            vv_arg = nul if vv is None else vv
        cnt_rows, arr_rows, mch_rows = [], [], []
        if ep.etr_op != -1:
            # producer half: each owner's summary production over its LOCAL
            # prefix tables is timed as part of that worker's superstep
            summ_rows = []
            for w in range(W):
                t_prod, ow = _timed(
                    etr_produce[i], cnt_w[w: w + 1],
                    pdev["etr_perm_local_s"][w: w + 1],
                    pdev["etr_perm_local_e"][w: w + 1],
                    pdev["etr_src_base"][w: w + 1],
                    pdev["etr_src_len"][w: w + 1],
                    ranks_w[w: w + 1], bedges)
                times[i, w] = t_prod
                summ_rows.append(ow)
            # rank-summary exchange (untimed): only boundary summaries —
            # producer ≠ consumer, O(cut edges) — are cross-partition traffic
            summ_w = fns["exchange_etr_fn"](
                jnp.concatenate(summ_rows, axis=0), pdev)
            channels[i, 2] = n_etr_ghost
            for w in range(W):
                t_best, (cw, aw) = _timed(
                    fns["one_worker_etr"], summ_w[w: w + 1], vm, vv_arg,
                    gdev["t_src"], wmask, ev_arg,
                    pdev["edge_ids"][w: w + 1], pdev["dst_local"][w: w + 1],
                    hop_tabs(w), bedges)
                times[i, w] += t_best
                cnt_rows.append(cw)
                arr_rows.append(aw)
        else:
            if i > 0:
                state_w = fns["apply_vv_w"](arrivals_w, vm, vv_arg,
                                            pdev["own_ids"], bedges)
            # state (+ extremum) exchange (untimed): ghost entries only
            halo_w = fns["exchange_state_fn"](state_w, pdev)
            channels[i, 0] = n_ghost
            mch_halo_w = nul
            if mch_w is not None:
                mch_halo_w = fns["exchange_mch_fn"](mch_w, pdev)
                channels[i, 1] = n_ghost
            # on the kernel path, produce the per-edge counts only when the
            # NEXT hop's ETR producer consumes them — matching the DCE the
            # executor's jit applies, so the timing stays faithful
            next_etr = i + 1 < n_hops and e_preds[i + 1].etr_op != -1
            hop_fn = (fns["one_worker_hop"]
                      if (not SS.use_pallas(impl) or next_etr)
                      else fns["one_worker_hop_light"])
            for w in range(W):
                mh = mch_halo_w if not mch_halo_w.ndim else \
                    mch_halo_w[w: w + 1]
                t_best, (cw, aw, mw) = _timed(
                    hop_fn, halo_w[w: w + 1], wmask, ev_arg,
                    pdev["edge_ids"][w: w + 1], pdev["dst_local"][w: w + 1],
                    hop_tabs(w), pdev["src_halo"][w: w + 1], mh, bedges)
                times[i, w] = t_best
                cnt_rows.append(cw)
                arr_rows.append(aw)
                mch_rows.append(mw)
            if mch_w is not None:
                mch_w = jnp.concatenate(mch_rows, axis=0)
        cnt_w = (jnp.concatenate(cnt_rows, axis=0)
                 if cnt_rows[0].ndim else None)
        arrivals_w = jnp.concatenate(arr_rows, axis=0)

    # final join: apply the segment-final vertex predicate, total (sanity)
    vmf, vvf = vpred[len(v_preds) - 1](gdev, params, bedges)
    total = np.asarray(fns["total_fn"](
        arrivals_w, pdev["own_ids"], vmf,
        nul if vvf is None else vvf, bedges))
    profile = SuperstepProfile(times, channels.sum(axis=1), channels,
                               float(total.sum()))
    if tracer is not None and getattr(tracer, "enabled", False):
        root = tracer.start("measure_supersteps", n_workers=W,
                            n_hops=n_hops, impl=impl, mode=mode,
                            backward=backward)
        for i in range(n_hops):
            ss = tracer.start(
                "superstep", parent=root, hop=i,
                measured_ms=float(times[i].max() * 1e3),
                per_worker_ms=[float(t * 1e3) for t in times[i]],
                etr=bool(e_preds[i].etr_op != -1))
            ex = tracer.start("exchange", parent=ss, hop=i,
                              state=int(channels[i, 0]),
                              extremum=int(channels[i, 1]),
                              etr=int(channels[i, 2]))
            tracer.end(ex)
            tracer.end(ss)
        tracer.end(root, total=profile.total,
                   balance_eff=profile.balance_eff)
    return profile
