"""Partition-sharded superstep execution — the DISTRIBUTED executor.

This is the paper's actual execution model (Sec. 4): the graph is split by
the two-level partitioner (``graphdata.partitioner``), each worker owns the
traversal edges *arriving* at its vertices, a superstep is

  local compute   per worker: gather boundary state for its halo sources,
                  apply the edge predicate, and DELIVER locally via a
                  per-worker sorted segment-sum (no cross-worker writes);
  exchange        between supersteps: workers publish the state of their
                  owned vertices; every worker receives the slice its halo
                  table names (ghost entries = cross-partition messages).

Single-device simulation runs the worker axis with ``jax.vmap``; with more
than one JAX device the same local-hop function runs under ``shard_map`` over
a ``workers`` mesh axis, with the exchange realised as an ``lax.psum`` of the
per-device partial scatters (a BSP all-to-all-ish broadcast — the multi-host
point-to-point exchange is a ROADMAP follow-on).

Semantics: bit-identical to ``engine.execute`` for all three temporal modes
and the FULL query surface — plain counts, COUNT aggregates, MIN/MAX
aggregates and ETR hops.  Every per-edge/per-vertex value equals the dense
engine's because (a) all elementwise primitives come from ``superstep.py``
unchanged, and (b) each vertex's arrival edges live on ONE worker in
canonical order, so per-worker segment reductions reproduce the dense
delivery exactly.

ETR hops need, per current edge, prefix sums over the arrival segment of its
*source* vertex — segments belong whole to the source vertex's owner, so
each owner computes the per-edge rank summaries from SEGMENT-LOCAL prefix
tables over its own prev-hop counts (``superstep.etr_local_summaries`` on
the partitioner's ``etr_*`` tables) and only the summaries whose consumer is
another worker cross partitions: O(cut edges) boundary traffic instead of
the full-frontier reassembly the first version shipped (the simulated
exchange is the same scatter-through-a-global-buffer used for halo state).

MIN/MAX aggregates ride an extremum channel alongside the count state: the
per-vertex channel is published with the boundary exchange each superstep,
workers gather the halo slice, form per-edge messages gated by live counts,
and deliver with a per-worker ``segment_min``/``segment_max``
(``superstep.deliver_extremum``); under shard_map the publish combines
partial scatters with ``lax.pmin``/``pmax`` instead of ``psum``.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import intervals as iv
from . import query as Q
from . import superstep as SS
from .engine import (ExecOutput, SegmentResult, _pbases, _prepare_gdev,
                     execute_plan_traced)
from .graph import TemporalGraph
from .superstep import MODE_BUCKET, MODE_INTERVAL, MODE_STATIC


# =========================================================================
# device tables
# =========================================================================
def _prepare_pdev(arrays) -> dict:
    """jnp views of the padded per-worker tables (PartitionArrays)."""
    return dict(
        own_ids=jnp.asarray(arrays.own_ids),
        edge_ids=jnp.asarray(arrays.edge_ids),
        dst_local=jnp.asarray(arrays.dst_local),
        halo_ids=jnp.asarray(arrays.halo_ids),
        src_halo=jnp.asarray(arrays.src_halo),
        etr_perm_local_s=jnp.asarray(arrays.etr_perm_local_s),
        etr_perm_local_e=jnp.asarray(arrays.etr_perm_local_e),
        etr_src_eids=jnp.asarray(arrays.etr_src_eids),
        etr_src_base=jnp.asarray(arrays.etr_src_base),
        etr_src_len=jnp.asarray(arrays.etr_src_len),
    )


def _zero_pad_rows(arr):
    """Append one all-zero entity row so pad sentinels gather zeros."""
    return jnp.concatenate(
        [arr, jnp.zeros((1,) + arr.shape[1:], arr.dtype)], axis=0
    )


def _shard_rows(global_arr, ids):
    """Gather global per-entity rows into padded per-worker layout [W, K, ...];
    pad ids point one past the end and read the synthetic zero row."""
    return _zero_pad_rows(global_arr)[ids]


def _halo_gather(sv_halo, src_halo):
    """Per-edge gather from each worker's halo slice.  A zero sentinel slot
    is appended per worker so ``src_halo`` pads (= Hmax) can never alias a
    real halo vertex, even when a worker's ghost set is empty."""
    sv_halo = jnp.concatenate(
        [sv_halo, jnp.zeros_like(sv_halo[:, :1])], axis=1)
    return jax.vmap(lambda h, s: h[s])(sv_halo, src_halo)


def _scatter_rows(rows_w, ids, n_global, fill=0.0):
    """Inverse of _shard_rows: per-worker rows back to global [n_global, ...].
    Each real entity appears in exactly one worker row; pads land on the
    dropped sentinel row.  ``fill`` sets the untouched-entry value (0 for
    count channels, the aggregation-neutral ±inf for extremum channels)."""
    flat_ids = ids.reshape(-1)
    flat = rows_w.reshape((-1,) + rows_w.shape[2:])
    out = jnp.full((n_global + 1,) + rows_w.shape[2:], fill, rows_w.dtype)
    return out.at[flat_ids].set(flat, unique_indices=False)[:n_global]


# =========================================================================
# the local hop (per worker): halo gather → edge apply → local delivery
# =========================================================================
def _local_hop(sv_global, wmask, evalid, own_ids, edge_ids, dst_local,
               halo_ids, src_halo, mode: int,
               mch_global=None, minmax_op: int = Q.AGG_MIN):
    """One worker-axis superstep of local compute.

    sv_global [V, *TS] is the post-exchange source state every worker reads
    its halo slice from; the remaining args carry a leading worker axis.
    When ``mch_global`` [V] is given, the extremum channel is exchanged and
    delivered alongside: same halo gather, per-edge messages gated by the
    live count, per-worker segment_min/segment_max delivery.
    Returns (cnt_w [W, Emax, *TS], arrivals_w [W, Vmax, *TS], mch_w or None).
    """
    W, Emax = edge_ids.shape
    v_max = own_ids.shape[1]
    # exchange receive: halo slice of the published state, then local gather
    sv_halo = _shard_rows(sv_global, halo_ids)              # [W, Hmax, *TS]
    src_val = _halo_gather(sv_halo, src_halo)               # [W, Emax, *TS]
    # local edge predicate application (flatten workers: primitives are
    # elementwise over the leading entity axis)
    wmask_w = _shard_rows(wmask, edge_ids)
    ts = src_val.shape[2:]
    flat = lambda a: a.reshape((W * Emax,) + a.shape[2:])
    ev_flat = None if evalid is None else flat(_shard_rows(evalid, edge_ids))
    cnt = SS.apply_edge(flat(src_val), flat(wmask_w), ev_flat, mode)
    cnt_w = cnt.reshape((W, Emax) + ts)
    # local delivery: per-worker sorted segment-sum (pad edges hit the trash
    # segment v_max, sliced off)
    arrivals_w = jax.vmap(
        lambda c, d: SS.deliver(c, d, v_max + 1)
    )(cnt_w, dst_local)[:, :v_max]
    mch_w = None
    if mch_global is not None:
        m_src = _halo_gather(_shard_rows(mch_global, halo_ids), src_halo)
        m_e = SS.minmax_edge(flat(m_src), cnt, minmax_op, mode)
        mch_w = jax.vmap(
            lambda m, d: SS.deliver_extremum(m, d, v_max + 1, minmax_op)
        )(m_e.reshape((W, Emax)), dst_local)[:, :v_max]
    return cnt_w, arrivals_w, mch_w


def _publish(cnt_w, arrivals_w, pdev, n2e, V, psum_axis=None,
             mch_w=None, minmax_op: int = Q.AGG_MIN):
    """Exchange send: scatter per-worker results to global views.  Under
    shard_map each device holds a partial scatter; psum (pmin/pmax for the
    extremum channel) completes it."""
    cnt_g = _scatter_rows(cnt_w, pdev["edge_ids"], n2e)
    arr_g = _scatter_rows(arrivals_w, pdev["own_ids"], V)
    mch_g = None
    if mch_w is not None:
        mch_g = _scatter_rows(mch_w, pdev["own_ids"], V,
                              fill=SS.minmax_neutral(minmax_op))
    if psum_axis is not None:
        cnt_g = jax.lax.psum(cnt_g, psum_axis)
        arr_g = jax.lax.psum(arr_g, psum_axis)
        if mch_g is not None:
            combine = (jax.lax.pmin if minmax_op == Q.AGG_MIN
                       else jax.lax.pmax)
            mch_g = combine(mch_g, psum_axis)
    return cnt_g, arr_g, mch_g


def _shard_map_call(n_devices: int, shard_fn, wargs, rargs):
    """Run ``shard_fn(*wargs, *rargs)`` under shard_map over a ``workers``
    mesh axis: worker-axis args sharded, the rest replicated."""
    from jax.sharding import Mesh, PartitionSpec as P
    try:  # moved out of experimental in newer jax
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    import inspect
    # the replication-check kwarg was renamed check_rep → check_vma; detect
    # from the signature, not from where the import succeeded
    rep_kw = ("check_vma" if "check_vma" in
              inspect.signature(shard_map).parameters else "check_rep")
    mesh = Mesh(np.asarray(jax.devices()[:n_devices]), ("workers",))
    wspec, rspec = P("workers"), P()
    out = shard_map(
        shard_fn, mesh=mesh,
        in_specs=tuple([wspec] * len(wargs) + [rspec] * len(rargs)),
        out_specs=rspec,
        **{rep_kw: False},
    )(*wargs, *rargs)
    return out


def _run_hop(gdev, pdev, sv_global, wmask, evalid, mode, n_devices: int,
             mch_global=None, minmax_op: int = Q.AGG_MIN):
    """Dispatch one hop's local compute over the worker axis: plain vmap on a
    single device, shard_map over a ``workers`` mesh axis otherwise."""
    V = gdev["v_life"].shape[0]
    n2e = gdev["t_dst"].shape[0]
    if n_devices <= 1:
        cnt_w, arrivals_w, mch_w = _local_hop(
            sv_global, wmask, evalid, pdev["own_ids"], pdev["edge_ids"],
            pdev["dst_local"], pdev["halo_ids"], pdev["src_halo"], mode,
            mch_global, minmax_op)
        return _publish(cnt_w, arrivals_w, pdev, n2e, V,
                        mch_w=mch_w, minmax_op=minmax_op)

    bedges = SS.current_bedges()
    with_mch = mch_global is not None

    def shard_fn(own_ids, edge_ids, dst_local, halo_ids, src_halo,
                 sv_g, wm, ev, mch_g, be):
        with SS.bucket_scope(be):
            cnt_w, arr_w, mch_w = _local_hop(
                sv_g, wm, ev, own_ids, edge_ids, dst_local, halo_ids,
                src_halo, mode, mch_g if with_mch else None, minmax_op)
            sub = dict(own_ids=own_ids, edge_ids=edge_ids)
            cnt_g, arr_g, mch_out = _publish(
                cnt_w, arr_w, sub, n2e, V, psum_axis="workers",
                mch_w=mch_w, minmax_op=minmax_op)
            if mch_out is None:
                mch_out = jnp.zeros((), jnp.float32)
            return cnt_g, arr_g, mch_out

    be = bedges if bedges is not None else jnp.zeros((1,), jnp.int32)
    cnt_g, arr_g, mch_out = _shard_map_call(
        n_devices, shard_fn,
        (pdev["own_ids"], pdev["edge_ids"], pdev["dst_local"],
         pdev["halo_ids"], pdev["src_halo"]),
        (sv_global, wmask,
         evalid if evalid is not None else jnp.zeros((n2e,), jnp.float32),
         mch_global if with_mch else jnp.zeros((), jnp.float32), be))
    return cnt_g, arr_g, (mch_out if with_mch else None)


# =========================================================================
# ETR hop: per-worker rank-summary production + exchange
# =========================================================================
def _ranks_for_produced(gdev, pdev):
    """Gather the global rank tables at each worker's produced edges:
    [W, 4, Smax]; pads read the appended zero row."""
    ranks_t = gdev["etr_dep_ranks"].T                       # [2E, 4]
    return jnp.swapaxes(_shard_rows(ranks_t, pdev["etr_src_eids"]), 1, 2)


def _worker_etr_summaries(cnt_w, perm_ls, perm_le, base, seg_len, ranks,
                          op: int, backward: bool):
    """Single-worker ETR producer: reorder owned prev-hop counts by the
    per-worker (dst, stat) permutations, take segment-local prefix sums, and
    emit the rank summaries for every edge whose source segment it owns."""
    cnt_pad = jnp.concatenate(
        [cnt_w, jnp.zeros((1,) + cnt_w.shape[1:], cnt_w.dtype)], axis=0)
    cps = cnt_pad[perm_ls]
    cpe = cnt_pad[perm_le] if SS.etr_needs_end(op, backward) else None
    return SS.etr_local_summaries(cps, cpe, base, seg_len, ranks, op, backward)


def _etr_summaries(gdev, pdev, arrivals_e, op: int, backward: bool,
                   n_devices: int):
    """The ETR boundary exchange: owners produce per-edge rank summaries from
    local prefix tables; the scatter to the global [2E, *TS] view simulates
    the sends.  Only summaries whose consumer is another worker are real
    cross-partition traffic (PartitionArrays.etr_exchange_volume)."""
    n2e = gdev["t_dst"].shape[0]
    ranks_w = _ranks_for_produced(gdev, pdev)
    if n_devices <= 1:
        cnt_w = _shard_rows(arrivals_e, pdev["edge_ids"])   # owner-local view
        out_w = jax.vmap(
            lambda c, pls, ple, b, sl, r: _worker_etr_summaries(
                c, pls, ple, b, sl, r, op, backward)
        )(cnt_w, pdev["etr_perm_local_s"], pdev["etr_perm_local_e"],
          pdev["etr_src_base"], pdev["etr_src_len"], ranks_w)
        return _scatter_rows(out_w, pdev["etr_src_eids"], n2e)

    def shard_fn(edge_ids, perm_ls, perm_le, base, seg_len, ranks, src_eids,
                 arr_e):
        cnt_w = _shard_rows(arr_e, edge_ids)
        out_w = jax.vmap(
            lambda c, pls, ple, b, sl, r: _worker_etr_summaries(
                c, pls, ple, b, sl, r, op, backward)
        )(cnt_w, perm_ls, perm_le, base, seg_len, ranks)
        summ = _scatter_rows(out_w, src_eids, n2e)
        return jax.lax.psum(summ, "workers")

    return _shard_map_call(
        n_devices, shard_fn,
        (pdev["edge_ids"], pdev["etr_perm_local_s"], pdev["etr_perm_local_e"],
         pdev["etr_src_base"], pdev["etr_src_len"], ranks_w,
         pdev["etr_src_eids"]),
        (arrivals_e,))


# =========================================================================
# segment runner (plugs into engine.execute_plan_traced)
# =========================================================================
def run_segment_partitioned(
    gdev: dict,
    pdev: dict,
    n_devices: int,
    v_preds: Sequence[Q.VertexPredicate],
    e_preds: Sequence[Q.EdgePredicate],
    params,
    pbases_v: Sequence[int],
    pbases_e: Sequence[int],
    mode: int,
    n_buckets: int,
    backward: bool,
    with_minmax: bool = False,
    minmax_op: int = Q.AGG_MIN,
    minmax_col=None,
) -> SegmentResult:
    """Partitioned twin of engine.run_segment; arrivals returned in GLOBAL
    space so the shared plan/join skeleton applies unchanged."""
    V = gdev["v_life"].shape[0]
    stats: List[dict] = []
    bedges = SS.current_bedges()

    vm, vv = SS.eval_predicate(
        gdev["vprops"], gdev["v_type"], gdev["v_life"], v_preds[0].vtype,
        v_preds[0].clauses, params, pbases_v[0], mode, bedges,
    )
    # init state lives sharded on its owners; the published global view is
    # what the first hop's halo gathers read.
    sv_global = SS.init_state(vm, vv, mode, n_buckets)
    stats.append(dict(phase="init", matched=jnp.sum(vm)))

    mch_global = None   # global [V] view of the extremum channel
    if with_minmax:
        vals0, _ = minmax_col
        mch_global = SS.minmax_seed(sv_global, vals0, minmax_op, mode)

    arrivals_e = None   # global [2E, *TS] view of the last hop's messages
    arrivals_v = None   # global [V, *TS] view of the last delivery
    for i, ep in enumerate(e_preds):
        wmask, evalid = SS.edge_predicate_weights(
            gdev, ep, params, pbases_e[i], mode, bedges)
        if i > 0:
            vm, vv = SS.eval_predicate(
                gdev["vprops"], gdev["v_type"], gdev["v_life"],
                v_preds[i].vtype, v_preds[i].clauses, params, pbases_v[i],
                mode, bedges,
            )
        if ep.etr_op != -1:
            if with_minmax:
                raise NotImplementedError(
                    "min/max aggregation across ETR hops")
            # ETR hop: segment owners produce rank summaries from LOCAL
            # prefix tables; only boundary summaries cross partitions.
            src_cnt = _etr_summaries(gdev, pdev, arrivals_e, ep.etr_op,
                                     backward, n_devices)
            # intermediate vertex predicate at the current edges' sources
            # (replicated elementwise compute, no exchange)
            if mode == MODE_STATIC:
                sv_edges = src_cnt * vm[gdev["t_src"]].astype(jnp.float32)
            elif mode == MODE_BUCKET:
                sv_edges = src_cnt * (vm[:, None] & vv)[gdev["t_src"]].astype(
                    jnp.float32)
            else:
                sv_edges = SS.apply_validity(src_cnt, vm[gdev["t_src"]],
                                             vv[gdev["t_src"]], mode)
            # consumer side: edge apply + delivery on the owned slice.
            ew = _shard_rows(sv_edges, pdev["edge_ids"])
            W, Emax = pdev["edge_ids"].shape
            v_max = pdev["own_ids"].shape[1]
            flat = lambda a: a.reshape((W * Emax,) + a.shape[2:])
            ev_flat = None if evalid is None else flat(
                _shard_rows(evalid, pdev["edge_ids"]))
            cnt = SS.apply_edge(flat(ew), flat(_shard_rows(wmask,
                                                           pdev["edge_ids"])),
                                ev_flat, mode)
            cnt_w = cnt.reshape((W, Emax) + cnt.shape[1:])
            arr_w = jax.vmap(lambda c, d: SS.deliver(c, d, v_max + 1))(
                cnt_w, pdev["dst_local"])[:, :v_max]
            arrivals_e, arrivals_v, _ = _publish(cnt_w, arr_w, pdev,
                                                 gdev["t_dst"].shape[0], V)
        else:
            if i > 0:
                sv_global = SS.apply_validity(arrivals_v, vm, vv, mode)
            arrivals_e, arrivals_v, mch_global = _run_hop(
                gdev, pdev, sv_global, wmask, evalid, mode, n_devices,
                mch_global, minmax_op)
        stats.append(dict(phase=f"hop{i}", matched_edges=jnp.sum(wmask)))

    return SegmentResult(arrivals_e, arrivals_v, stats, mch_global)


# =========================================================================
# public API
# =========================================================================
_JIT_CACHE: Dict[tuple, callable] = {}


def partition_for(graph: TemporalGraph, n_workers: int,
                  parts_per_type: Optional[int] = None):
    """(Partitioning, PartitionArrays, device tables) for a graph, cached ON
    the graph object (like its device-array cache) so the cache's lifetime —
    and the validity of the per-graph ownership tables — is tied to the
    graph itself."""
    from ..graphdata.partitioner import build_partition_arrays, partition_graph

    ppt = parts_per_type if parts_per_type is not None else max(4, n_workers // 2)
    cache = getattr(graph, "_partition_cache", None)
    if cache is None:
        cache = {}
        graph._partition_cache = cache
    key = (n_workers, ppt)
    hit = cache.get(key)
    if hit is None:
        part = partition_graph(graph, n_workers=n_workers, parts_per_type=ppt)
        arrays = build_partition_arrays(graph, part)
        hit = (part, arrays, _prepare_pdev(arrays))
        cache[key] = hit
    return hit


def _resolve_n_devices(requested: Optional[bool], n_workers: int) -> int:
    """How many devices to shard the worker axis over (1 = vmap simulation)."""
    nd = jax.device_count()
    if requested is False or nd <= 1 or n_workers % nd != 0:
        return 1
    return nd


def execute(
    graph: TemporalGraph,
    qry: Q.PathQuery,
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    n_workers: int = 4,
    parts_per_type: Optional[int] = None,
    use_shard_map: Optional[bool] = None,
) -> ExecOutput:
    """Partition-sharded execution; identical results to ``engine.execute``.

    ``n_workers`` selects the two-level partitioning (cached per graph).
    When >1 JAX devices exist and divide ``n_workers``, the worker axis runs
    under shard_map on a device mesh; otherwise it is vmapped on one device.
    """
    if split is None:
        split = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
    gdev = _prepare_gdev(graph)
    _, arrays, pdev = partition_for(graph, n_workers, parts_per_type)
    n_devices = _resolve_n_devices(use_shard_map, n_workers)
    bedges = jnp.asarray(
        iv.bucket_edges(graph.lifespan[0], graph.lifespan[1], n_buckets)
    )
    key = (id(graph), qry.shape_key(), split, mode, n_buckets, n_workers,
           arrays.v_max, n_devices)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        def traced(gd, pd, params, be):
            runner = partial(run_segment_partitioned, gd, pd, n_devices)
            out = execute_plan_traced(gd, qry, split, mode, n_buckets, params,
                                      be, segment_runner=runner)
            return out.total, out.per_vertex, out.minmax

        fn = jax.jit(traced)
        _JIT_CACHE[key] = fn
    params = jnp.asarray(Q.query_params(qry))
    total, per_vertex, minmax = fn(gdev, pdev, params, bedges)
    return ExecOutput(total, per_vertex, minmax, [])


def count_results(graph, qry, **kw) -> float:
    out = execute(graph, qry, **kw)
    t = np.asarray(out.total)
    return float(t.sum()) if t.ndim else float(t)


def batch_executable(
    graph: TemporalGraph,
    qry: Q.PathQuery,
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    n_workers: int = 4,
    parts_per_type: Optional[int] = None,
):
    """Compiled batched entry on the DISTRIBUTED path: the whole superstep
    pipeline (halo gather → local delivery → boundary exchange) runs with a
    query-batch leading axis, vmapped over the packed parameter tensor — one
    partitioned traversal sweep serves the entire same-shape batch.

    Returns ``run(params[B, n_clauses, 3]) -> ExecOutput`` with a leading
    query axis on every field.  The worker axis always runs in the vmap
    simulation here (a query-batch vmap around shard_map is not supported);
    sharded multi-device serving is a ROADMAP follow-on.
    """
    if split is None:
        split = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
    gdev = _prepare_gdev(graph)
    _, arrays, pdev = partition_for(graph, n_workers, parts_per_type)
    bedges = jnp.asarray(
        iv.bucket_edges(graph.lifespan[0], graph.lifespan[1], n_buckets)
    )
    key = ("batch", id(graph), qry.shape_key(), split, mode, n_buckets,
           n_workers, arrays.v_max)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        def one(gd, pd, params, be):
            runner = partial(run_segment_partitioned, gd, pd, 1)
            out = execute_plan_traced(gd, qry, split, mode, n_buckets, params,
                                      be, segment_runner=runner)
            return out.total, out.per_vertex, out.minmax

        fn = jax.jit(jax.vmap(one, in_axes=(None, None, 0, None)))
        _JIT_CACHE[key] = fn

    def run(params) -> ExecOutput:
        total, per_vertex, minmax = fn(gdev, pdev, jnp.asarray(params), bedges)
        return ExecOutput(total, per_vertex, minmax, [])

    return run


def execute_batch_out(
    graph: TemporalGraph,
    queries: Sequence[Q.PathQuery],
    split: Optional[int] = None,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    n_workers: int = 4,
    parts_per_type: Optional[int] = None,
) -> ExecOutput:
    """Batched partitioned execution of same-shape instances."""
    from .engine import check_batch_shape
    check_batch_shape(queries)
    run = batch_executable(graph, queries[0], split, mode, n_buckets,
                           n_workers, parts_per_type)
    params = np.stack([Q.query_params(q) for q in queries])
    return run(params)


# =========================================================================
# instrumented per-worker superstep timing (weak-scaling benchmark)
# =========================================================================
@dataclasses.dataclass
class SuperstepProfile:
    times_s: np.ndarray        # float64[n_hops, W] — measured local-hop time
    exchange_msgs: np.ndarray  # int64[n_hops] — boundary messages that hop
    total: float               # query total (sanity cross-check)

    @property
    def makespan_s(self) -> np.ndarray:
        """Per-superstep makespan: the straggler worker's measured time."""
        return self.times_s.max(axis=1)

    @property
    def balance_eff(self) -> float:
        per_worker = self.times_s.sum(axis=0)
        return float(per_worker.mean() / max(per_worker.max(), 1e-12))


_PROFILE_CACHE: Dict[tuple, dict] = {}


def _profile_fns(qry: Q.PathQuery, mode: int, n_buckets: int, v_max: int,
                 pv, pe) -> dict:
    """Jitted helpers for measure_supersteps, cached per (query shape, mode,
    buckets, padded worker extent) so repeated profiling of one template
    (weak_scaling, fit_cost_model) re-traces nothing.  All graph data is
    passed as arguments; only static query structure is baked in."""
    key = (qry.shape_key(), mode, n_buckets, v_max)
    fns = _PROFILE_CACHE.get(key)
    if fns is not None:
        return fns

    def vpred(i):
        def f(gd, prm, be):
            with SS.bucket_scope(be):
                vp = qry.v_preds[i]
                return SS.eval_predicate(gd["vprops"], gd["v_type"],
                                         gd["v_life"], vp.vtype, vp.clauses,
                                         prm, pv[i], mode, be)
        return jax.jit(f)

    def hop_masks(i):
        def f(gd, prm, be):
            with SS.bucket_scope(be):
                return SS.edge_predicate_weights(gd, qry.e_preds[i], prm,
                                                 pe[i], mode, be)
        return jax.jit(f)

    def etr_mask(i):
        def f(gd, summ, m, v, be):
            with SS.bucket_scope(be):
                if mode == MODE_STATIC:
                    return summ * m[gd["t_src"]].astype(jnp.float32)
                if mode == MODE_BUCKET:
                    return summ * (m[:, None] & v)[gd["t_src"]].astype(
                        jnp.float32)
                return SS.apply_validity(summ, m[gd["t_src"]], v[gd["t_src"]],
                                         mode)
        return jax.jit(f)

    @jax.jit
    def apply_vv(av, m, v, be):
        with SS.bucket_scope(be):
            return SS.apply_validity(av, m, v, mode)

    # ONE compiled local-hop executable reused for every (hop, worker): each
    # worker's tables arrive with a leading axis of 1 so shapes agree.
    @jax.jit
    def one_worker_hop(sv_g, wm, ev, own, eids, dloc, hids, shalo, be):
        with SS.bucket_scope(be):
            cnt_w, arr_w, _ = _local_hop(sv_g, wm, ev if ev.ndim else None,
                                         own, eids, dloc, hids, shalo, mode)
            return cnt_w, arr_w

    # ETR producer body: segment-local prefix tables over the worker's owned
    # prev-hop counts → rank summaries for the edges whose source it owns.
    def etr_produce(i):
        op = qry.e_preds[i].etr_op

        def f(arr_e, eids, pls, ple, base, slen, ranks, be, _backward=False):
            with SS.bucket_scope(be):
                cnt_w = _shard_rows(arr_e, eids)[0]
                return _worker_etr_summaries(cnt_w, pls[0], ple[0], base[0],
                                             slen[0], ranks[0], op,
                                             _backward)[None]
        return jax.jit(f)

    # ETR consumer body: the received summaries are the exchanged state; the
    # local part is edge apply + delivery.
    @jax.jit
    def one_worker_etr(sved, wm, ev, eids, dloc, be):
        with SS.bucket_scope(be):
            ew = _shard_rows(sved, eids)
            e_max = eids.shape[1]
            flatten = lambda a: a.reshape((e_max,) + a.shape[2:])
            evf = None if not ev.ndim else flatten(_shard_rows(ev, eids))
            cnt = SS.apply_edge(flatten(ew), flatten(_shard_rows(wm, eids)),
                                evf, mode)
            arr = SS.deliver(cnt, dloc[0], v_max + 1)[:v_max]
            return cnt[None], arr[None]

    @jax.jit
    def init_fn(m, v, be):
        with SS.bucket_scope(be):
            return SS.init_state(m, v, mode, n_buckets)

    @jax.jit
    def total_fn(av, m, v, be):
        with SS.bucket_scope(be):
            return SS.state_total(SS.apply_validity(av, m, v, mode), mode)

    fns = dict(
        vpred=[vpred(i) for i in range(qry.n_vertices)],
        hop_masks=[hop_masks(i) for i in range(len(qry.e_preds))],
        etr_mask=[etr_mask(i) if ep.etr_op != -1 else None
                  for i, ep in enumerate(qry.e_preds)],
        etr_produce=[etr_produce(i) if ep.etr_op != -1 else None
                     for i, ep in enumerate(qry.e_preds)],
        apply_vv=apply_vv,
        one_worker_hop=one_worker_hop,
        one_worker_etr=one_worker_etr,
        init_fn=init_fn,
        total_fn=total_fn,
    )
    _PROFILE_CACHE[key] = fns
    return fns


def measure_supersteps(
    graph: TemporalGraph,
    qry: Q.PathQuery,
    n_workers: int = 4,
    mode: int = MODE_STATIC,
    n_buckets: int = 16,
    parts_per_type: Optional[int] = None,
    repeats: int = 2,
) -> SuperstepProfile:
    """Measured (not modelled) per-worker superstep times.

    Runs the left-to-right plan (split = n−1) hop by hop, executing each
    worker's local compute SEPARATELY through one compiled single-worker hop
    function and timing it with block_until_ready — the per-(hop, worker)
    wall times a real deployment's straggler/makespan comes from.  ETR hops
    time both the producer (segment-local rank-summary prefix tables) and
    consumer (edge apply + delivery) halves per worker.  The exchange
    (scatter/halo republish) runs between timings, untimed; its volume is
    the halo ghost count on plain hops and the boundary rank-summary count
    (``PartitionArrays.etr_exchange_volume``) on ETR hops.
    """
    assert qry.agg_op == Q.AGG_NONE, "profile plain path counts"
    gdev = _prepare_gdev(graph)
    _, arrays, pdev = partition_for(graph, n_workers, parts_per_type)
    W = arrays.n_workers
    v_max = arrays.v_max
    bedges = jnp.asarray(
        iv.bucket_edges(graph.lifespan[0], graph.lifespan[1], n_buckets)
    )
    params = jnp.asarray(Q.query_params(qry))
    pv, pe = _pbases(qry)
    n_hops = len(qry.e_preds)
    V = graph.n_vertices
    n2e = 2 * graph.n_edges

    fns = _profile_fns(qry, mode, n_buckets, v_max, pv, pe)
    vpred, hop_masks = fns["vpred"], fns["hop_masks"]
    apply_vv, one_worker_hop = fns["apply_vv"], fns["one_worker_hop"]
    one_worker_etr, init_fn = fns["one_worker_etr"], fns["init_fn"]
    etr_mask, etr_produce = fns["etr_mask"], fns["etr_produce"]
    total_fn = fns["total_fn"]
    ranks_w = _ranks_for_produced(gdev, pdev)

    def _timed(fn, *args):
        best, out = np.inf, None
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best, out

    # ev=None can't cross jit; encode "no validity" as a 0-d placeholder.
    no_ev = jnp.zeros((), jnp.float32)

    times = np.zeros((n_hops, W))
    exchange = np.zeros(n_hops, np.int64)

    vm, vv = vpred[0](gdev, params, bedges)
    sv_global = init_fn(vm, vv, bedges)
    arrivals_e = None
    arrivals_v = None
    for i, ep in enumerate(qry.e_preds):
        wmask, evalid = hop_masks[i](gdev, params, bedges)
        ev_arg = no_ev if evalid is None else evalid
        if i > 0:
            vm, vv = vpred[i](gdev, params, bedges)
        cnt_rows, arr_rows = [], []
        if ep.etr_op != -1:
            # rank-prefix exchange: each owner's summary production over its
            # LOCAL prefix tables is timed as part of that worker's superstep;
            # only the boundary summaries (producer ≠ consumer) count as
            # cross-partition traffic — O(cut edges), not O(frontier).
            summ_rows = []
            for w in range(W):
                t_prod, ow = _timed(
                    etr_produce[i], arrivals_e,
                    pdev["edge_ids"][w: w + 1],
                    pdev["etr_perm_local_s"][w: w + 1],
                    pdev["etr_perm_local_e"][w: w + 1],
                    pdev["etr_src_base"][w: w + 1],
                    pdev["etr_src_len"][w: w + 1],
                    ranks_w[w: w + 1], bedges)
                times[i, w] = t_prod
                summ_rows.append(ow)
            summ = _scatter_rows(jnp.concatenate(summ_rows, axis=0),
                                 pdev["etr_src_eids"], n2e)
            sv_edges = etr_mask[i](gdev, summ, vm, vv, bedges)
            exchange[i] = int(arrays.n_src_ghost.sum())
            for w in range(W):
                t_best, (cw, aw) = _timed(
                    one_worker_etr, sv_edges, wmask, ev_arg,
                    pdev["edge_ids"][w: w + 1], pdev["dst_local"][w: w + 1],
                    bedges)
                times[i, w] += t_best
                cnt_rows.append(cw)
                arr_rows.append(aw)
        else:
            if i > 0:
                sv_global = apply_vv(arrivals_v, vm, vv, bedges)
            exchange[i] = int(arrays.n_ghost.sum())
            for w in range(W):
                t_best, (cw, aw) = _timed(
                    one_worker_hop, sv_global, wmask, ev_arg,
                    pdev["own_ids"][w: w + 1], pdev["edge_ids"][w: w + 1],
                    pdev["dst_local"][w: w + 1], pdev["halo_ids"][w: w + 1],
                    pdev["src_halo"][w: w + 1], bedges)
                times[i, w] = t_best
                cnt_rows.append(cw)
                arr_rows.append(aw)
        cnt_w = jnp.concatenate(cnt_rows, axis=0)
        arr_w = jnp.concatenate(arr_rows, axis=0)
        arrivals_e, arrivals_v, _ = _publish(cnt_w, arr_w, pdev, n2e, V)

    # final join: apply the last vertex predicate, total (sanity value)
    vmf, vvf = vpred[qry.n_vertices - 1](gdev, params, bedges)
    total = np.asarray(total_fn(arrivals_v, vmf, vvf, bedges))
    return SuperstepProfile(times, exchange, float(total.sum()))
