"""Shared superstep core — the hop primitives every executor builds on.

The engine stack is three executors over ONE superstep vocabulary:

  engine.py             dense executor     — whole-graph tensor supersteps
  engine_sliced.py      sliced executor    — type-slice extents per hop (§Perf)
  engine_partitioned.py partitioned executor — per-worker shards + boundary
                                              exchange each hop (distributed)

This module owns the primitives they share, so a hop means the same thing in
all three:

  predicate evaluation   eval_predicate()        — type ∧ folded clauses over
                                                   property columns, returning
                                                   (match, validity) per mode
  edge masking           direction_mask(),
                         edge_predicate_weights() — edge predicate ∧ direction
  state algebra          init_state(), apply_validity(), apply_edge(),
                         state_total(), state_alive(), cells_to_buckets()
  ETR rank application   etr_weighted()          — rank tables + segment prefix
                                                   sums (exact, O(E) per hop)
                         etr_local_summaries()   — the same contraction from
                                                   SEGMENT-LOCAL prefix tables
                                                   (the partitioned executor's
                                                   rank-summary exchange)
  boundary exchange      p2p_exchange()          — ragged all-to-all over the
                                                   worker axis: only ghost
                                                   entries move (the
                                                   partitioned executor's
                                                   exchange, all channels)
  delivery               deliver()               — sorted segment-sum of
                                                   per-edge counts by arrival
                         fused_hop_deliver()     — the fused kernel hop
                                                   (gather → temporal mask →
                                                   segment-reduce in VMEM via
                                                   kernels.hop_scatter; the
                                                   impl='pallas' hot path of
                                                   every plain hop)
  extremum channel       minmax_seed(), minmax_edge(), deliver_extremum()
                         — the MIN/MAX aggregate's per-hop DP channel
                           (segment_min/segment_max delivery; the partitioned
                           executor exchanges it alongside the count state)
  joins                  join_interval_counts(), join_interval_counts_edges()

Temporal modes (shared by all executors):

  MODE_STATIC    scalar counts per entity
  MODE_BUCKET    counts per time bucket          state [..., B]
  MODE_INTERVAL  counts per running-intersection interval cell
                 (start-bucket, end-bucket)      state [..., B, B+1]

State layout contract: every state/count tensor has the entity axis FIRST
(vertices, traversal edges, or padded per-worker slots) and the temporal-state
axes last.  All primitives here are elementwise over the entity axis except
``deliver``/``deliver_extremum`` (segment reductions) and the ETR prefix sums,
which is exactly what makes the partitioned executor possible: elementwise
steps shard trivially, the segment steps define the communication pattern
(and, because arrival segments never straddle workers, they all decompose
into per-worker segment ops + a boundary exchange).

Bucket edges are threaded through traces with the ``bucket_scope`` context
manager (a trace-scoped stack, not a function argument, so deeply nested
helpers stay signature-stable).
"""
from __future__ import annotations

import contextlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import intervals as iv
from . import query as Q
from ..kernels import hop_scatter as HK
from ..kernels.common import check_impl, resolve_interpret, use_pallas

MODE_STATIC = 0
MODE_BUCKET = 1
MODE_INTERVAL = 2

# ETR term kinds (rank-array rows in graph.EtrTables):
#   0: #(acc.start <  cur.start)     1: #(acc.start <= cur.start)
#   2: #(acc.start <  cur.end)       3: #(acc.end   <= cur.start)
# spec: (alpha, ((sign, term), ...)) st. result = alpha * n_acc + Σ sign * P[term]
ETR_SPECS = {
    (iv.FULLY_BEFORE, False): (0.0, ((1.0, 3),)),
    (iv.STARTS_BEFORE, False): (0.0, ((1.0, 0),)),
    (iv.FULLY_AFTER, False): (1.0, ((-1.0, 2),)),
    (iv.STARTS_AFTER, False): (1.0, ((-1.0, 1),)),
    (iv.OVERLAPS, False): (0.0, ((1.0, 2), (-1.0, 3))),
    (iv.FULLY_BEFORE, True): (1.0, ((-1.0, 2),)),
    (iv.STARTS_BEFORE, True): (1.0, ((-1.0, 1),)),
    (iv.FULLY_AFTER, True): (0.0, ((1.0, 3),)),
    (iv.STARTS_AFTER, True): (0.0, ((1.0, 0),)),
    (iv.OVERLAPS, True): (0.0, ((1.0, 2), (-1.0, 3))),
}

# Trace-scoped bucket-edge stack; executors push via bucket_scope().
TRACE_BEDGES: List = []


@contextlib.contextmanager
def bucket_scope(bedges):
    """Make ``bedges`` the current bucket edges for the enclosed trace."""
    TRACE_BEDGES.append(bedges)
    try:
        yield
    finally:
        TRACE_BEDGES.pop()


def current_bedges():
    return TRACE_BEDGES[-1] if TRACE_BEDGES else None


# =========================================================================
# clause evaluation
# =========================================================================
def _eval_prop_clause(col, value, cmp: int, mode: int, bedges, ent_life):
    """Evaluate one property clause over an entity set.

    Returns (match bool[N], validity) where validity is a bucket mask [N,B]
    (MODE_BUCKET), an interval int32[N,2] (MODE_INTERVAL), or None.
    """
    vals, life = col  # [N,S], [N,S,2]
    slot_eq = vals == value
    has_any = jnp.any(vals >= 0, axis=1)
    if cmp == Q.P_NEQ:
        match = has_any & ~jnp.any(slot_eq, axis=1)
        if mode == MODE_BUCKET:
            return match, iv.interval_to_bucket_mask(ent_life, bedges)
        if mode == MODE_INTERVAL:
            return match, ent_life
        return match, None
    # EQ / CONTAINS: any slot equal
    match = jnp.any(slot_eq, axis=1)
    if mode == MODE_BUCKET:
        slot_masks = iv.interval_to_bucket_mask(life, bedges)  # [N,S,B]
        valid = jnp.any(slot_masks & slot_eq[..., None], axis=1)
        return match, valid
    if mode == MODE_INTERVAL:
        idx = jnp.argmax(slot_eq, axis=1)
        sel = jnp.take_along_axis(life, idx[:, None, None], axis=1)[:, 0]  # [N,2]
        valid = jnp.where(match[:, None], sel, 0)
        return match, valid
    return match, None


def _eval_time_clause(ent_life, cmp_id: int, interval, mode: int, bedges):
    const_iv = jnp.broadcast_to(jnp.asarray(interval, jnp.int32), ent_life.shape)
    match = iv.compare(cmp_id, ent_life, const_iv)
    if mode == MODE_BUCKET:
        return match, iv.interval_to_bucket_mask(ent_life, bedges)
    if mode == MODE_INTERVAL:
        return match, ent_life
    return match, None


def _fold_clauses(parts, mode):
    """AND/OR left-fold of (conj, match, validity) triples."""
    acc_m, acc_v = None, None
    for conj, m, v in parts:
        if acc_m is None:
            acc_m, acc_v = m, v
            continue
        if conj == Q.AND:
            acc_m = acc_m & m
            if mode == MODE_BUCKET:
                acc_v = acc_v & v
            elif mode == MODE_INTERVAL:
                acc_v = iv.intersect(acc_v, v)
        else:  # OR
            new_m = acc_m | m
            if mode == MODE_BUCKET:
                acc_v = (acc_v & acc_m[:, None]) | (v & m[:, None])
            elif mode == MODE_INTERVAL:
                # span approximation for OR in interval mode (documented)
                acc_v = jnp.where(
                    (acc_m & ~m)[:, None], acc_v,
                    jnp.where((m & ~acc_m)[:, None], v, iv.span(acc_v, v)),
                )
            acc_m = new_m
    return acc_m, acc_v


def eval_predicate(
    props: Dict[int, tuple],
    ent_type,
    ent_life,
    req_type: int,
    clauses: Sequence[Q.Clause],
    params,
    pbase: int,
    mode: int,
    bedges,
):
    """Full predicate = type check ∧ folded clauses; returns (match, validity).

    ``params`` carries the data values: row i = (value, t_lo, t_hi) for the
    i-th clause of the whole query; ``pbase`` is this predicate's first row.
    """
    n = ent_life.shape[0]
    match = jnp.ones((n,), bool)
    if req_type >= 0:
        match = ent_type == req_type
    match = match & (ent_life[:, 0] < ent_life[:, 1])
    if mode == MODE_BUCKET:
        validity = iv.interval_to_bucket_mask(ent_life, bedges)
    elif mode == MODE_INTERVAL:
        validity = ent_life
    else:
        validity = None
    parts = []
    for i, c in enumerate(clauses):
        row = params[pbase + i]
        if c.kind == Q.K_PROP:
            col = props[c.key]
            m, v = _eval_prop_clause(col, row[0], c.cmp, mode, bedges, ent_life)
        else:
            m, v = _eval_time_clause(ent_life, c.cmp, row[1:3], mode, bedges)
        parts.append((c.conj, m, v))
    if parts:
        cm, cv = _fold_clauses(parts, mode)
        match = match & cm
        if mode == MODE_BUCKET:
            validity = validity & cv
        elif mode == MODE_INTERVAL:
            validity = iv.intersect(validity, cv)
    return match, validity


# =========================================================================
# edge masking
# =========================================================================
def direction_mask(t_isfwd, direction: int):
    """bool mask selecting traversal edges compatible with a hop direction."""
    if direction == Q.DIR_OUT:
        return t_isfwd == 1
    if direction == Q.DIR_IN:
        return t_isfwd == 0
    return jnp.ones_like(t_isfwd, bool)


def edge_predicate_weights(gdev, ep: Q.EdgePredicate, params, pbase, mode, bedges):
    """(weight mask bool[2E], bucket/interval validity) for one hop."""
    t_life = gdev["t_life"]
    match, validity = eval_predicate(
        gdev["eprops_t"], gdev["t_type"], t_life, ep.etype, ep.clauses,
        params, pbase, mode, bedges,
    )
    return (match & direction_mask(gdev["t_isfwd"], ep.direction)), validity


# =========================================================================
# mode-generic state ops
# =========================================================================
def init_state(match, validity, mode: int, n_buckets: int):
    """Seed DP state from a vertex predicate result."""
    if mode == MODE_STATIC:
        return match.astype(jnp.float32)
    if mode == MODE_BUCKET:
        return (match[:, None] & validity).astype(jnp.float32)
    # INTERVAL: one-hot cell at (start_bucket, end_bucket); cells [B, B+1]
    B = n_buckets
    sb, eb = _interval_to_cells(validity, B)
    cell = (
        jax.nn.one_hot(sb, B, dtype=jnp.float32)[:, :, None]
        * jax.nn.one_hot(eb, B + 1, dtype=jnp.float32)[:, None, :]
    )
    return cell * match[:, None, None].astype(jnp.float32)


def _interval_to_cells(ivl, B):
    """Map int32[N,2] intervals to (start_bucket, end_bucket) cell ids using
    the bucket edges of the enclosing bucket_scope()."""
    bedges = TRACE_BEDGES[-1]
    sb = jnp.clip(jnp.searchsorted(bedges, ivl[:, 0], side="right") - 1, 0, B - 1)
    eb = jnp.clip(jnp.searchsorted(bedges, ivl[:, 1], side="left"), 0, B)
    empty = ivl[:, 0] >= ivl[:, 1]
    eb = jnp.where(empty, sb, eb)  # empty → zero-width cell (filtered later)
    return sb, eb


def apply_validity(state, match, validity, mode: int):
    """Multiply state by a predicate's (match, validity) at its entity."""
    if mode == MODE_STATIC:
        return state * match.astype(jnp.float32)
    if mode == MODE_BUCKET:
        return state * (match[:, None] & validity).astype(jnp.float32)
    # INTERVAL: clamp running-intersection cells by the validity interval
    B = state.shape[-2]
    sb, eb = _interval_to_cells(validity, B)
    out = _clamp_start(state, sb)
    out = _clamp_end(out, eb)
    out = out * match[..., None, None].astype(jnp.float32)
    return _mask_valid_cells(out)


def apply_edge(src_val, wmask, evalidity, mode: int):
    """Apply a hop's edge weights to gathered source values (per-edge)."""
    if mode == MODE_STATIC:
        return src_val * wmask.astype(jnp.float32)
    if mode == MODE_BUCKET:
        return src_val * (wmask[:, None] & evalidity).astype(jnp.float32)
    return apply_validity(src_val, wmask, evalidity, mode)


def _clamp_start(state, ps):
    """cells[n, s, e] move to (max(s, ps[n]), e)."""
    B = state.shape[-2]
    cum = jnp.cumsum(state, axis=-2)
    keep = (jnp.arange(B)[None, :] > ps[:, None]).astype(state.dtype)
    cum_at = jnp.take_along_axis(cum, ps[:, None, None], axis=-2)[:, 0, :]
    onehot = jax.nn.one_hot(ps, B, dtype=state.dtype)
    return state * keep[:, :, None] + onehot[:, :, None] * cum_at[:, None, :]


def _clamp_end(state, pe):
    """cells[n, s, e] move to (s, min(e, pe[n]))."""
    Bp1 = state.shape[-1]
    rcum = jnp.cumsum(state[..., ::-1], axis=-1)[..., ::-1]
    keep = (jnp.arange(Bp1)[None, :] < pe[:, None]).astype(state.dtype)
    cum_at = jnp.take_along_axis(rcum, pe[:, None, None], axis=-1)[:, :, 0]
    onehot = jax.nn.one_hot(pe, Bp1, dtype=state.dtype)
    return state * keep[:, None, :] + onehot[:, None, :] * cum_at[:, :, None]


def _mask_valid_cells(state):
    B, Bp1 = state.shape[-2], state.shape[-1]
    s_ids = jnp.arange(B)[:, None]
    e_ids = jnp.arange(Bp1)[None, :]
    return state * (s_ids < e_ids).astype(state.dtype)


def state_total(state, mode):
    if mode == MODE_STATIC:
        return jnp.sum(state)
    if mode == MODE_BUCKET:
        return jnp.sum(state, axis=0)  # per-bucket totals
    return jnp.sum(_mask_valid_cells(state))


def state_alive(state, mode):
    """bool[N]: entities whose count state is non-zero anywhere (static
    scalar, any bucket, or any interval cell) — the liveness gate of the
    extremum channel."""
    if mode == MODE_STATIC:
        return state > 0
    return state.sum(axis=tuple(range(1, state.ndim))) > 0


def cells_to_buckets(state):
    """[N,B,B+1] running-interval cells → [N,B] per-bucket time series."""
    B = state.shape[-2]
    out = []
    s_ids = jnp.arange(B)[:, None]
    e_ids = jnp.arange(B + 1)[None, :]
    for b in range(B):
        m = ((s_ids <= b) & (e_ids > b)).astype(state.dtype)
        out.append(jnp.sum(state * m, axis=(-2, -1)))
    return jnp.stack(out, axis=-1)


# =========================================================================
# point-to-point boundary exchange (the distributed executor's collective)
# =========================================================================
def p2p_exchange(rows_w, local_src, send_slot, recv_slot, n_slots: int,
                 axis_name: Optional[str] = None, fill=0.0):
    """Ragged all-to-all over the worker axis — the boundary exchange.

    Every receive-buffer entry (a halo vertex's state, or an owned edge's
    ETR rank summary) lives with exactly ONE owner.  The partitioner's
    routing tables split them into a local copy (entries the receiver owns
    itself) and one ragged lane per worker pair carrying just the ghost
    entries — so only ghost entries move, with no global [V]/[2E] buffer and
    no psum reduction (ownership is exclusive: the exchange is a copy).

      rows_w     [Wl, K, *TS]   owner-local source rows (this device's
                                workers; Wl = W when simulated)
      local_src  int32[Wl, N]   own-row slot per self-owned receive entry,
                                pad = K (reads the ``fill`` row)
      send_slot  int32[Wl, W, C] own-row slot of the k-th row local worker i
                                sends to GLOBAL worker d, pad = K
      recv_slot  int32[Wl, W, C] receive-buffer position where the k-th row
                                from GLOBAL worker s lands, pad = N (a trash
                                slot, sliced off)
      n_slots    N              receive-buffer extent

    With ``axis_name`` unset the worker axis is fully local (the vmap
    simulation) and the all-to-all is an axis transpose; under shard_map the
    same payload moves with one ``lax.all_to_all`` over the mesh axis.  Both
    are pure data movement over identical tables, which is what makes the
    sharded path bit-identical to the simulation.  Lanes are padded to C
    (the max per-pair ghost count); the ragged content — Σ ghost entries —
    is the real traffic reported by ``PartitionArrays.exchange_volume()`` /
    ``etr_exchange_volume()``.
    """
    Wl, K = rows_w.shape[:2]
    W, C = send_slot.shape[1:3]
    ts = rows_w.shape[2:]
    pad = jnp.full((Wl, 1) + ts, fill, rows_w.dtype)
    rows_pad = jnp.concatenate([rows_w, pad], axis=1)
    take = jax.vmap(lambda r, s: r[s])
    local = take(rows_pad, local_src)                    # [Wl, N, *TS]
    payload = take(rows_pad, send_slot)                  # [Wl, W, C, *TS]
    if axis_name is None:
        received = jnp.swapaxes(payload, 0, 1)           # [W_dst, W_src, C]
    else:
        D = W // Wl
        q = payload.reshape((Wl, D, Wl, C) + ts)         # split dst by device
        q = jnp.moveaxis(q, 1, 0)                        # [D, Wl_src, Wl_dst, C]
        a = jax.lax.all_to_all(q, axis_name, 0, 0)       # [D_src, Wl_src, Wl_dst, C]
        received = jnp.moveaxis(a, 2, 0).reshape((Wl, W, C) + ts)

    def place(loc, rec, pos):
        buf = jnp.concatenate(
            [loc, jnp.full((1,) + ts, fill, rows_w.dtype)], axis=0)
        return buf.at[pos.reshape(-1)].set(rec.reshape((-1,) + ts))[:n_slots]

    return jax.vmap(place)(local, received, recv_slot)


# =========================================================================
# delivery
# =========================================================================
def deliver(cnt_e, seg_ids, num_segments: int, indices_are_sorted: bool = True,
            impl: str = "xla", layout=None):
    """Sorted segment-sum of per-edge counts by arrival vertex — the message
    delivery of one superstep.  Summation order is the canonical (arrival-
    sorted) edge order, which is what makes the partitioned executor's
    per-worker deliveries bit-identical to the dense one.

    ``impl`` selects the lowering: ``'xla'`` is the segment-sum scatter;
    ``'pallas'``/``'pallas_interpret'`` with a ``kernels.hop_scatter``
    ``HopLayout`` over the same (static, sorted) seg_ids runs the blocked
    scatter-as-matmul kernel instead — identical sums (bit-identical while
    counts are exact integers in float32, the engine's invariant)."""
    if not use_pallas(check_impl(impl)) or layout is None:
        return jax.ops.segment_sum(
            cnt_e, seg_ids, num_segments=num_segments,
            indices_are_sorted=indices_are_sorted,
        )
    return HK.scatter_deliver(cnt_e, layout.tables, num_segments,
                              layout.block_v, impl=impl)


def fused_hop_deliver(
    state,                       # [N, *TS] source-state table
    src_slot,                    # int32[E] — source row per edge; N = zero row
    wmask,                       # bool[E] edge-predicate ∧ direction match
    evalid,                      # temporal validity: None / bool[E, B] /
                                 # int32[E, 2] interval (per mode)
    mode: int,
    lt: Dict,                    # HopLayout.tables (or a worker-sliced row of
                                 #   stacked tables — a uniform array pytree,
                                 #   so executors can vmap it with in_axes=0)
    block_v: int,
    num_segments: int,
    impl: str = "pallas",
    mch=None,                    # optional extremum channel table [N]
    minmax_op: int = Q.AGG_MIN,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """One fused traversal hop: gather → temporal mask → segment-reduce.

    Pallas-only twin of the three-step XLA hop (``state[src]`` gather,
    ``apply_edge``, ``deliver``) that never materialises the per-edge
    ``[E, *TS]`` state: the ``kernels.hop_scatter`` kernel gathers, weights
    and prefix-reduces per destination block in VMEM.  When ``mch`` is
    given, the MIN/MAX extremum channel is gathered, liveness-gated by the
    in-VMEM contributions, and min/max-reduced alongside (the
    ``minmax_edge`` + ``deliver_extremum`` pair of the XLA path).

    ``evalid``/``mch`` may be 0-d placeholders for "absent" (the profiling
    and vmap call sites can't pass None through mapped axes).

    Returns (arrivals [num_segments, *TS], mch_out [num_segments] | None).
    """
    assert use_pallas(check_impl(impl)), "fused_hop_deliver is the kernel path"
    interpret = resolve_interpret(None, impl)
    if evalid is not None and getattr(evalid, "ndim", 1) == 0:
        evalid = None
    if mch is not None and getattr(mch, "ndim", 1) == 0:
        mch = None
    N = state.shape[0]
    ts = state.shape[1:]
    gather_idx, valid = lt["gather"], lt["valid"]
    n_blocks, block_e = lt["ldst"].shape
    src_sl = HK.slots(src_slot.astype(jnp.int32), gather_idx, valid,
                      N).reshape(n_blocks, block_e)
    mch_p = None
    neutral = 0.0
    op_is_min = minmax_op == Q.AGG_MIN
    if mch is not None:
        neutral = float(np.inf if op_is_min else -np.inf)
        mch_p = jnp.concatenate(
            [mch.astype(jnp.float32), jnp.full((1,), neutral, jnp.float32)]
        )[:, None]
    if mode == MODE_INTERVAL:
        B = state.shape[-2]
        state_p = jnp.concatenate(
            [state.reshape(N, B * (B + 1)),
             jnp.zeros((1, B * (B + 1)), state.dtype)], axis=0)
        w = HK.slots(wmask.astype(jnp.float32), gather_idx, valid,
                     0.0).reshape(n_blocks, block_e)
        sb, eb = _interval_to_cells(evalid, B)
        sb_sl = HK.slots(sb.astype(jnp.int32), gather_idx, valid,
                         0).reshape(n_blocks, block_e)
        eb_sl = HK.slots(eb.astype(jnp.int32), gather_idx, valid,
                         0).reshape(n_blocks, block_e)
        out, mch_out = HK.fused_hop_interval_pallas(
            state_p, src_sl, w, sb_sl, eb_sl, lt["sstart"], lt["send"],
            lt["ldst"], block_v, B, interpret=interpret, mch_p=mch_p,
            neutral=neutral, op_is_min=op_is_min)
        arrivals = out[:num_segments].reshape(num_segments, B, B + 1)
    else:
        C = 1 if mode == MODE_STATIC else state.shape[1]
        state_p = jnp.concatenate(
            [state.reshape(N, C), jnp.zeros((1, C), state.dtype)], axis=0)
        if mode == MODE_STATIC:
            wv = wmask.astype(jnp.float32)[:, None]
        else:
            wv = (wmask[:, None] & evalid).astype(jnp.float32)
        w_cols = HK.slots(wv, gather_idx, valid, 0.0).reshape(
            n_blocks, block_e, C)
        out, mch_out = HK.fused_hop_cols_pallas(
            state_p, src_sl, w_cols, lt["sstart"], lt["send"], lt["ldst"],
            block_v, interpret=interpret, mch_p=mch_p, neutral=neutral,
            op_is_min=op_is_min)
        arrivals = out[:num_segments].reshape((num_segments,) + ts)
    if mch_out is not None:
        mch_out = mch_out[:num_segments]
    return arrivals, mch_out


# =========================================================================
# extremum (MIN/MAX aggregate) channel
# =========================================================================
def minmax_neutral(op: int):
    """The aggregation-neutral element of the extremum channel."""
    return jnp.float32(np.inf if op == Q.AGG_MIN else -np.inf)


def minmax_seed(state, col_vals, op: int, mode: int):
    """Seed the per-entity extremum channel from the aggregate's property
    column: the first-slot value where the count state is alive, neutral
    elsewhere."""
    base = col_vals[:, 0].astype(jnp.float32)
    return jnp.where(state_alive(state, mode), base, minmax_neutral(op))


def minmax_edge(mch_src, cnt_e, op: int, mode: int):
    """Per-edge extremum message: the source channel where the edge carries
    any live count, neutral elsewhere (so dead/pad edges cannot win)."""
    return jnp.where(state_alive(cnt_e, mode), mch_src, minmax_neutral(op))


def deliver_extremum(m_e, seg_ids, num_segments: int, op: int,
                     indices_are_sorted: bool = True, impl: str = "xla",
                     layout=None):
    """Extremum twin of ``deliver``: sorted segment_min/segment_max of the
    per-edge channel by arrival vertex.  Min/max is order-independent, so
    per-worker deliveries over owned segments match the dense delivery
    exactly.  The ``impl`` axis mirrors ``deliver``'s: with a layout, the
    blocked masked-extremum kernel replaces the XLA segment reduce (same
    ±inf identity on empty segments)."""
    if not use_pallas(check_impl(impl)) or layout is None:
        seg = jax.ops.segment_min if op == Q.AGG_MIN else jax.ops.segment_max
        return seg(m_e, seg_ids, num_segments=num_segments,
                   indices_are_sorted=indices_are_sorted)
    # m_e is already liveness-gated by minmax_edge, so every slot is "alive"
    return HK.scatter_extremum(
        m_e, jnp.ones_like(m_e), layout.tables, num_segments, layout.block_v,
        neutral=float(minmax_neutral(op)), op_is_min=(op == Q.AGG_MIN),
        impl=impl)


# =========================================================================
# delta-segment delivery (base-CSR + delta execution, graphdata/ingest.py)
# =========================================================================
def delta_hop_deliver(delta, ep, sv, params, pbase, mode: int, V: int,
                      mch=None, minmax_op=Q.AGG_MIN):
    """One hop's arrival contribution from a padded delta-edge segment.

    ``delta`` is a ``DeltaSpec.device()`` dict shaped like a tiny unsorted
    gdev (t_src/t_dst/t_life/t_type/t_isfwd/eprops_t over 2·capacity slots
    plus a ``valid`` mask killing the padding).  The hop's edge predicate is
    evaluated over the delta slots exactly as over base traversal edges, the
    per-edge counts are delivered with an UNSORTED segment-sum (delta edges
    are in appended order, not arrival order), and the extremum channel
    rides along when ``mch`` is given.  Because counts are exact small
    integers in float32, base-sum + delta-sum equals the merged graph's
    single sorted sum bit-for-bit — the invariant that makes the base+delta
    executable interchangeable with a from-scratch epoch build.

    Returns (arrival counts [V, *TS], extremum [V] | None) to be combined
    into the base hop's delivery (add / min-max respectively).
    """
    bedges = current_bedges()
    wmask, evalid = edge_predicate_weights(delta, ep, params, pbase, mode,
                                           bedges)
    wmask = wmask & delta["valid"]
    cnt = apply_edge(sv[delta["t_src"]], wmask, evalid, mode)
    add = deliver(cnt, delta["t_dst"], V, indices_are_sorted=False)
    mm = None
    if mch is not None:
        m_e = minmax_edge(mch[delta["t_src"]], cnt, minmax_op, mode)
        mm = deliver_extremum(m_e, delta["t_dst"], V, minmax_op,
                              indices_are_sorted=False)
    return add, mm


# =========================================================================
# ETR prefix machinery
# =========================================================================
def etr_weighted(gdev, cnt_e_prev, op: int, backward: bool, use_arr: bool):
    """Per current traversal edge: Σ over accumulated arrivals at its vertex
    of cnt × [ETR condition], via rank tables (exact)."""
    alpha, terms = ETR_SPECS[(op, backward)]
    perm_s = gdev["etr_perm_start"]
    perm_e = gdev["etr_perm_end"]
    ranks = gdev["etr_arr_ranks"] if use_arr else gdev["etr_dep_ranks"]
    ptr = gdev["arr_ptr"]
    segv = gdev["t_dst"] if use_arr else gdev["t_src"]

    trailing = cnt_e_prev.shape[1:]
    zero = jnp.zeros((1,) + trailing, cnt_e_prev.dtype)

    S_s = jnp.concatenate([zero, jnp.cumsum(cnt_e_prev[perm_s], axis=0)], axis=0)
    need_end = etr_needs_end(op, backward)
    S_e = (
        jnp.concatenate([zero, jnp.cumsum(cnt_e_prev[perm_e], axis=0)], axis=0)
        if need_end
        else None
    )
    base_pos = ptr[segv]
    base_s = S_s[base_pos]
    out = 0.0
    if alpha:
        n_acc = S_s[ptr[segv + 1]] - base_s
        out = alpha * n_acc
    for sign, term in terms:
        S = S_e if term == 3 else S_s
        base = (S_e[base_pos] if term == 3 else base_s)
        val = S[base_pos + ranks[term]] - base
        out = out + sign * val
    return out


def etr_needs_end(op: int, backward: bool) -> bool:
    """Does this ETR spec read the (dst, life-end)-ordered prefix table?"""
    _, terms = ETR_SPECS[(op, backward)]
    return any(t == 3 for _, t in terms)


def etr_local_summaries(cnt_perm_s, cnt_perm_e, base, seg_len, ranks,
                        op: int, backward: bool):
    """Per-edge ETR rank summaries from SEGMENT-LOCAL prefix tables.

    The contraction of ``etr_weighted`` only ever takes prefix DIFFERENCES
    inside one arrival segment, so a worker owning whole segments can compute
    it from prefix sums over just its own prev-hop counts — this function is
    that local step, and its outputs are exactly the per-edge values the
    partitioned executor exchanges (boundary rank summaries) on ETR hops.

      cnt_perm_s  [K, *TS] — owned prev-hop counts in (dst, life-start) order
      cnt_perm_e  [K, *TS] — same in (dst, life-end) order; may be None when
                             ``not etr_needs_end(op, backward)``
      base        int32[S] — local prefix index of each produced edge's
                             source-segment base (0 ≤ base ≤ K)
      seg_len     int32[S] — that segment's length (base + seg_len ≤ K)
      ranks       int32[4, S] — the global rank tables gathered at the
                             produced edges (within-segment offsets)

    Returns [S, *TS] summaries; pad rows (base = len = ranks = 0) return 0.
    Matches ``etr_weighted`` exactly whenever the count sums are exactly
    representable (all engine counts are small integers in float32).
    """
    alpha, terms = ETR_SPECS[(op, backward)]
    trailing = cnt_perm_s.shape[1:]
    zero = jnp.zeros((1,) + trailing, cnt_perm_s.dtype)
    S_s = jnp.concatenate([zero, jnp.cumsum(cnt_perm_s, axis=0)], axis=0)
    S_e = (
        jnp.concatenate([zero, jnp.cumsum(cnt_perm_e, axis=0)], axis=0)
        if cnt_perm_e is not None
        else None
    )
    base_s = S_s[base]
    out = 0.0
    if alpha:
        out = alpha * (S_s[base + seg_len] - base_s)
    for sign, term in terms:
        S = S_e if term == 3 else S_s
        b0 = S_e[base] if term == 3 else base_s
        out = out + sign * (S[base + ranks[term]] - b0)
    return out


# =========================================================================
# joins
# =========================================================================
def join_interval_counts(L, R):
    """Distinct-path count from left/right running-intersection cell states.

    D = Σ_v Σ_{cells} L·R·[intervals overlap]; computed via the complement
    (total − disjoint) with cumsum contractions — O(V·B²).
    L, R: [V, B, B+1].
    """
    totL = L.sum(axis=(1, 2))
    totR = R.sum(axis=(1, 2))
    Le = L.sum(axis=1)      # [V, B+1] marginal over start
    Ls = L.sum(axis=2)      # [V, B]   marginal over end
    Re = R.sum(axis=1)
    Rs = R.sum(axis=2)
    # pairs with L.end <= R.start  (cells: e1 <= s2)
    cumLe = jnp.cumsum(Le, axis=1)  # Σ_{e1 <= x}
    d1 = jnp.einsum("vb,vb->v", Rs, cumLe[:, : Rs.shape[1]])
    # pairs with R.end <= L.start
    cumRe = jnp.cumsum(Re, axis=1)
    d2 = jnp.einsum("vb,vb->v", Ls, cumRe[:, : Ls.shape[1]])
    return totL * totR - d1 - d2


# identical contraction at traversal-edge granularity (ETR-at-join)
join_interval_counts_edges = join_interval_counts
