"""Temporal property graph container (host-side, numpy) + device views.

Layout decisions (see DESIGN.md Sec. 2/5):

* Structure-of-arrays, fully dictionary-encoded: vertex/edge types, property
  keys and property values are int ids.  The string dictionaries live in the
  loader (`repro.graphdata.loader`); the engine never sees a string.
* Vertices are **type-major**: the loader permutes vertex ids so each type is
  a contiguous id range (``type_ranges``).  This is the tensor analogue of the
  paper's type-based partitioning — a type predicate becomes a range check and
  an init superstep touches only that slice.
* Edges are materialised once as **traversal arrays** of size 2E: entry
  ``i < E`` is edge ``i`` traversed forward (src→dst), entry ``E + i`` is the
  same edge traversed backward.  Directed/undirected hops become weight masks
  over the same arrays, so ETR rank tables and segment offsets are built once.
* Traversal arrays are sorted by arrival vertex (``t_dst``); ``arr_ptr`` gives
  the CSR-style segment offsets.  Per-superstep message delivery is then a
  sorted segment-sum — the shape `bucket_scatter` Pallas kernel accelerates.
* **ETR rank tables**: for the edge-temporal-relationship operator we need,
  per candidate edge e', the weighted count of accumulated edges at a vertex
  whose lifespan stat (start/end) compares against a threshold taken from e'.
  Because the graph is static at query time, the *rank* of each threshold in
  the sorted per-vertex stat lists is precomputed; at query time an ETR hop is
  two cumsums + gathers (exact, O(E)).

Properties: per-key dense pivot ``vals int32[N, S]`` / ``life int32[N, S, 2]``
with ``S`` = max concurrent versions or multi-values; missing = -1 and empty
lifespan.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Dict, Optional, Tuple

import numpy as np

NO_VALUE = -1


@dataclasses.dataclass(frozen=True)
class PropColumn:
    """Dense pivot of one property key over vertices or edges."""

    vals: np.ndarray   # int32[N, S]
    life: np.ndarray   # int32[N, S, 2]

    @property
    def n_slots(self) -> int:
        return self.vals.shape[1]


@dataclasses.dataclass(frozen=True)
class EtrTables:
    """Precomputed rank tables for ETR prefix-sum evaluation.

    All arrays are over the 2E traversal-edge space in canonical
    (arrival-sorted) order.
    """

    perm_start: np.ndarray  # int32[2E] — traversal ids sorted by (t_dst, life_start)
    perm_end: np.ndarray    # int32[2E] — sorted by (t_dst, life_end)
    # rank arrays, one row per term kind (see engine.ETR_TERMS):
    #   0: #(acc.start <  cur.start)   over perm_start
    #   1: #(acc.start <= cur.start)   over perm_start
    #   2: #(acc.start <  cur.end)     over perm_start
    #   3: #(acc.end   <= cur.start)   over perm_end
    dep_ranks: np.ndarray   # int32[4, 2E] — thresholds from edges *departing* v (hop step)
    arr_ranks: np.ndarray   # int32[4, 2E] — thresholds from edges *arriving* at v (join)


class TemporalGraph:
    """Immutable temporal property graph (host container)."""

    def __init__(
        self,
        v_type: np.ndarray,
        v_life: np.ndarray,
        e_src: np.ndarray,
        e_dst: np.ndarray,
        e_type: np.ndarray,
        e_life: np.ndarray,
        vprops: Dict[int, PropColumn],
        eprops: Dict[int, PropColumn],
        n_vertex_types: int,
        n_edge_types: int,
        lifespan: Tuple[int, int],
        meta: Optional[dict] = None,
    ):
        self.v_type = np.asarray(v_type, np.int32)
        self.v_life = np.asarray(v_life, np.int32)
        self.e_src = np.asarray(e_src, np.int32)
        self.e_dst = np.asarray(e_dst, np.int32)
        self.e_type = np.asarray(e_type, np.int32)
        self.e_life = np.asarray(e_life, np.int32)
        self.vprops = vprops
        self.eprops = eprops
        self.n_vertex_types = int(n_vertex_types)
        self.n_edge_types = int(n_edge_types)
        self.lifespan = (int(lifespan[0]), int(lifespan[1]))
        self.meta = meta or {}
        self._validate()
        self._device_cache: Optional[dict] = None

    # ------------------------------------------------------------------ basic
    @property
    def n_vertices(self) -> int:
        return int(self.v_type.shape[0])

    @property
    def n_edges(self) -> int:
        return int(self.e_src.shape[0])

    def _validate(self) -> None:
        V, E = self.n_vertices, self.n_edges
        assert self.v_life.shape == (V, 2)
        assert self.e_dst.shape == (E,) and self.e_type.shape == (E,)
        assert self.e_life.shape == (E, 2)
        if E:
            assert self.e_src.min() >= 0 and self.e_src.max() < V
            assert self.e_dst.min() >= 0 and self.e_dst.max() < V
        # referential integrity: edge lifespan within both endpoint lifespans
        # (constraint from Sec. 3.2; generator guarantees it, we spot check).
        if E:
            k = min(E, 1024)
            idx = np.linspace(0, E - 1, k).astype(np.int64)
            s_ok = self.v_life[self.e_src[idx], 0] <= self.e_life[idx, 0]
            e_ok = self.v_life[self.e_src[idx], 1] >= self.e_life[idx, 1]
            if not (s_ok & e_ok).all():
                raise ValueError("edge lifespans violate referential integrity (src)")

    # ------------------------------------------------------- type structure
    @cached_property
    def type_ranges(self) -> np.ndarray:
        """int32[n_vertex_types, 2] — [start, end) vertex-id range per type.

        Requires type-major ordering (loader guarantees); falls back to
        full-range for any type that is not contiguous.
        """
        tr = np.zeros((self.n_vertex_types, 2), np.int32)
        sorted_ok = bool(np.all(np.diff(self.v_type) >= 0))
        for t in range(self.n_vertex_types):
            if sorted_ok:
                lo = int(np.searchsorted(self.v_type, t, side="left"))
                hi = int(np.searchsorted(self.v_type, t, side="right"))
            else:  # pragma: no cover — loaders always sort
                lo, hi = 0, self.n_vertices
            tr[t] = (lo, hi)
        return tr

    @cached_property
    def type_counts(self) -> np.ndarray:
        return np.bincount(self.v_type, minlength=self.n_vertex_types).astype(np.int64)

    @cached_property
    def edge_type_counts(self) -> np.ndarray:
        return np.bincount(self.e_type, minlength=self.n_edge_types).astype(np.int64)

    @cached_property
    def out_degree(self) -> np.ndarray:
        return np.bincount(self.e_src, minlength=self.n_vertices).astype(np.int32)

    @cached_property
    def in_degree(self) -> np.ndarray:
        return np.bincount(self.e_dst, minlength=self.n_vertices).astype(np.int32)

    # ------------------------------------------------------ traversal arrays
    @cached_property
    def traversal(self) -> dict:
        """2E traversal-edge arrays in canonical arrival-sorted order."""
        E = self.n_edges
        t_src = np.concatenate([self.e_src, self.e_dst])
        t_dst = np.concatenate([self.e_dst, self.e_src])
        t_life = np.concatenate([self.e_life, self.e_life], axis=0)
        t_type = np.concatenate([self.e_type, self.e_type])
        t_isfwd = np.concatenate(
            [np.ones(E, np.int32), np.zeros(E, np.int32)]
        )
        t_eid = np.concatenate([np.arange(E, dtype=np.int32)] * 2)
        order = np.lexsort((t_src, t_dst)).astype(np.int32)
        arr_ptr = np.zeros(self.n_vertices + 1, np.int64)
        np.cumsum(
            np.bincount(t_dst, minlength=self.n_vertices), out=arr_ptr[1:]
        )
        return dict(
            t_src=t_src[order].astype(np.int32),
            t_dst=t_dst[order].astype(np.int32),
            t_life=t_life[order].astype(np.int32),
            t_type=t_type[order].astype(np.int32),
            t_isfwd=t_isfwd[order].astype(np.int32),
            t_eid=t_eid[order].astype(np.int32),
            arr_ptr=arr_ptr.astype(np.int32),
        )

    @cached_property
    def etr_tables(self) -> EtrTables:
        tr = self.traversal
        n2e = tr["t_dst"].shape[0]
        t_dst = tr["t_dst"]
        t_src = tr["t_src"]
        starts = tr["t_life"][:, 0].astype(np.int64)
        ends = tr["t_life"][:, 1].astype(np.int64)
        ptr = tr["arr_ptr"].astype(np.int64)

        # Sort (within each arrival segment) by stat.  Canonical order is
        # already grouped by t_dst, so a stable lexsort on (t_dst, stat) works.
        perm_start = np.lexsort((starts, t_dst)).astype(np.int32)
        perm_end = np.lexsort((ends, t_dst)).astype(np.int32)
        sorted_starts = starts[perm_start]
        sorted_ends = ends[perm_end]

        def seg_searchsorted(sorted_vals, seg_of_query, thresh, side) -> np.ndarray:
            """rank of thresh within its vertex's segment of sorted_vals."""
            lo = ptr[seg_of_query]
            hi = ptr[seg_of_query + 1]
            out = np.zeros(thresh.shape[0], np.int32)
            # Vectorised trick: offset values per segment so a single global
            # searchsorted works.  Stats fit int32; segments indexed by vertex.
            # Simpler and still O(2E log E): loop-free via np.searchsorted on
            # concatenated arrays using np.searchsorted's sorter is not
            # segment-aware, so do it with a per-element binary search through
            # np.searchsorted on the global array bounded to segments:
            # implemented via the "offset encoding": val' = vertex * SPAN + val.
            span = int(max(sorted_vals.max(initial=0), thresh.max(initial=0)) + 2)
            seg_of_sorted = np.repeat(
                np.arange(len(ptr) - 1, dtype=np.int64), np.diff(ptr)
            )
            enc_sorted = seg_of_sorted * span + sorted_vals
            enc_q = seg_of_query.astype(np.int64) * span + thresh
            pos = np.searchsorted(enc_sorted, enc_q, side=side)
            out = (pos - lo).astype(np.int32)
            np.clip(out, 0, (hi - lo).astype(np.int64), out=out)
            return out

        def build_ranks(seg_of_query: np.ndarray) -> np.ndarray:
            q_start = starts
            q_end = ends
            r0 = seg_searchsorted(sorted_starts, seg_of_query, q_start, "left")
            r1 = seg_searchsorted(sorted_starts, seg_of_query, q_start, "right")
            r2 = seg_searchsorted(sorted_starts, seg_of_query, q_end, "left")
            r3 = seg_searchsorted(sorted_ends, seg_of_query, q_start, "right")
            return np.stack([r0, r1, r2, r3]).astype(np.int32)

        dep_ranks = build_ranks(t_src.astype(np.int64))
        arr_ranks = build_ranks(t_dst.astype(np.int64))
        assert dep_ranks.shape == (4, n2e)
        return EtrTables(perm_start, perm_end, dep_ranks, arr_ranks)

    # --------------------------------------------------------------- device
    def device_arrays(self, include_etr: bool = True) -> dict:
        """jnp views of everything the engine needs (cached)."""
        if self._device_cache is not None:
            return self._device_cache
        import jax.numpy as jnp

        tr = self.traversal
        g = dict(
            v_type=jnp.asarray(self.v_type),
            v_life=jnp.asarray(self.v_life),
            t_src=jnp.asarray(tr["t_src"]),
            t_dst=jnp.asarray(tr["t_dst"]),
            t_life=jnp.asarray(tr["t_life"]),
            t_type=jnp.asarray(tr["t_type"]),
            t_isfwd=jnp.asarray(tr["t_isfwd"]),
            arr_ptr=jnp.asarray(tr["arr_ptr"]),
            type_ranges=jnp.asarray(self.type_ranges),
        )
        if include_etr:
            et = self.etr_tables
            g.update(
                etr_perm_start=jnp.asarray(et.perm_start),
                etr_perm_end=jnp.asarray(et.perm_end),
                etr_dep_ranks=jnp.asarray(et.dep_ranks),
                etr_arr_ranks=jnp.asarray(et.arr_ranks),
            )
        g["vprops"] = {
            k: (jnp.asarray(c.vals), jnp.asarray(c.life)) for k, c in self.vprops.items()
        }
        g["eprops"] = {
            k: (jnp.asarray(c.vals), jnp.asarray(c.life)) for k, c in self.eprops.items()
        }
        self._device_cache = g
        return g

    # ------------------------------------------------------------- utilities
    def subgraph_stats(self) -> dict:
        return dict(
            n_vertices=self.n_vertices,
            n_edges=self.n_edges,
            n_vertex_types=self.n_vertex_types,
            n_edge_types=self.n_edge_types,
            lifespan=self.lifespan,
            n_vprop_keys=len(self.vprops),
            n_eprop_keys=len(self.eprops),
        )


def make_prop_column(
    n_entities: int,
    entity_ids: np.ndarray,
    values: np.ndarray,
    lifespans: np.ndarray,
) -> PropColumn:
    """Pivot a flat (entity, value, lifespan) table into a dense PropColumn."""
    entity_ids = np.asarray(entity_ids, np.int64)
    values = np.asarray(values, np.int32)
    lifespans = np.asarray(lifespans, np.int32).reshape(-1, 2)
    counts = np.bincount(entity_ids, minlength=n_entities)
    S = max(1, int(counts.max(initial=1)))
    vals = np.full((n_entities, S), NO_VALUE, np.int32)
    life = np.zeros((n_entities, S, 2), np.int32)
    order = np.argsort(entity_ids, kind="stable")
    slot = np.zeros(n_entities, np.int64)
    eo = entity_ids[order]
    # slot index within each entity via cumcount
    slot_of = np.arange(len(eo)) - np.concatenate(([0], np.cumsum(np.bincount(eo, minlength=n_entities))))[eo]
    vals[eo, slot_of] = values[order]
    life[eo, slot_of] = lifespans[order]
    del slot
    return PropColumn(vals=vals, life=life)
