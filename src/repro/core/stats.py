"""Temporal graph statistics for the cost model (Sec. 5.1 of the paper).

Per property key we maintain a 2-D histogram over (value × time-bucket) of
entity counts, coarsened into variance-bounded *tiles* (the paper uses the DP
hierarchical tiling of Muthukrishnan et al. [52]; we use the equivalent
top-down recursive split, which has the same invariant — per-tile frequency
variance ≤ threshold — at lower build cost), stored in an *interval tree*
keyed by tile time-range.  High-cardinality keys are frequency-clustered and
queries are rewritten to cluster ids (paper Sec. 5.1).

Beyond the paper (documented in DESIGN.md):
  * type-aware degree table ``D[vtype, etype, dir]`` — the paper keeps a
    single (δ_in, δ_out) per histogram entry; conditioning on the edge type
    sharpens the active-edge estimate for typed hops.
  * ETR selectivity: per edge-type-pair empirical probability that a random
    incident edge pair satisfies each ETR comparator (sampled at build time).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import intervals as iv
from . import query as Q
from .graph import TemporalGraph


# ---------------------------------------------------------------- tiles/tree
@dataclasses.dataclass
class Tile:
    v_lo: int
    v_hi: int
    t_lo: int
    t_hi: int
    freq: float          # average per-(value,bucket) frequency inside the tile
    d_in: float
    d_out: float


class IntervalTree:
    """Static augmented interval tree over tile time-ranges."""

    def __init__(self, tiles: List[Tile]):
        self.tiles = sorted(tiles, key=lambda t: (t.t_lo, t.t_hi))
        self.starts = np.asarray([t.t_lo for t in self.tiles], np.int64)
        self.maxend = np.zeros(len(self.tiles), np.int64)
        # balanced recursion replaced by a sorted array + running max-end —
        # lookup prunes with searchsorted (equivalent pruning power for the
        # partition-of-grid tiles we store).
        run = -(2 ** 62)
        for i, t in enumerate(self.tiles):
            run = max(run, t.t_hi)
            self.maxend[i] = run

    def query(self, t_lo: int, t_hi: int) -> List[Tile]:
        if not self.tiles:
            return []
        hi = int(np.searchsorted(self.starts, t_hi, side="left"))
        out = []
        for i in range(hi - 1, -1, -1):
            if self.maxend[i] <= t_lo:
                break
            t = self.tiles[i]
            if t.t_hi > t_lo:
                out.append(t)
        return out


def _tile_grid(grid: np.ndarray, din: np.ndarray, dout: np.ndarray,
               var_threshold: float) -> List[Tile]:
    """Top-down variance-bounded tiling of a (values × buckets) count grid."""
    tiles: List[Tile] = []

    def rec(v0, v1, t0, t1):
        sub = grid[v0:v1, t0:t1]
        if sub.size == 0:
            return
        if sub.size == 1 or float(sub.var()) <= var_threshold:
            cnt = float(sub.mean())
            w = sub.sum()
            if w > 0:
                di = float((din[v0:v1, t0:t1] * sub).sum() / w)
                do = float((dout[v0:v1, t0:t1] * sub).sum() / w)
            else:
                di = do = 0.0
            tiles.append(Tile(v0, v1, t0, t1, cnt, di, do))
            return
        if (v1 - v0) >= (t1 - t0) and (v1 - v0) > 1:
            mid = (v0 + v1) // 2
            rec(v0, mid, t0, t1)
            rec(mid, v1, t0, t1)
        else:
            mid = (t0 + t1) // 2
            rec(v0, v1, t0, mid)
            rec(v0, v1, mid, t1)

    rec(0, grid.shape[0], 0, grid.shape[1])
    return tiles


# ------------------------------------------------------------------ per key
@dataclasses.dataclass
class KeyStats:
    tree: IntervalTree
    cluster_of: Dict[int, int]       # value id → cluster row
    cluster_size: np.ndarray         # values per cluster row
    n_rows: int


@dataclasses.dataclass
class HEntry:
    f: float
    d_in: float
    d_out: float


class GraphStats:
    """All statistics the planner needs.  Built once per graph (host side)."""

    def __init__(
        self,
        graph: TemporalGraph,
        n_time_buckets: int = 16,
        max_value_clusters: int = 64,
        var_threshold: float = 4.0,
        etr_samples: int = 2048,
        seed: int = 0,
    ):
        self.g = graph
        self.B = n_time_buckets
        self.bedges = iv.bucket_edges(graph.lifespan[0], graph.lifespan[1], n_time_buckets)
        self.var_threshold = var_threshold
        self.max_clusters = max_value_clusters
        self.vkey_stats: Dict[int, KeyStats] = {}
        self.ekey_stats: Dict[int, KeyStats] = {}
        self.type_life_hist = np.zeros((graph.n_vertex_types, self.B))
        self.etype_life_hist = np.zeros((graph.n_edge_types, self.B))
        self.degree_table = np.zeros((graph.n_vertex_types, graph.n_edge_types, 2))
        self.etr_select: Dict[int, float] = {}
        self._build(etr_samples, seed)

    # ------------------------------------------------------------- builders
    def _bucket_overlap_counts(self, life: np.ndarray) -> np.ndarray:
        """bool[N, B]: does interval life[n] overlap bucket b."""
        lo = self.bedges[:-1][None, :]
        hi = self.bedges[1:][None, :]
        return (life[:, 0:1] < hi) & (lo < life[:, 1:2])

    def _build_key(self, col, degrees_in, degrees_out) -> KeyStats:
        vals = col.vals.reshape(-1)
        life = col.life.reshape(-1, 2)
        n_ent = col.vals.shape[0]
        ent = np.repeat(np.arange(n_ent), col.vals.shape[1])
        keep = vals >= 0
        vals, life, ent = vals[keep], life[keep], ent[keep]
        if vals.size == 0:
            return KeyStats(IntervalTree([]), {}, np.zeros(0), 0)
        uniq, inv, cnts = np.unique(vals, return_inverse=True, return_counts=True)
        # frequency clustering for high-cardinality keys
        if len(uniq) > self.max_clusters:
            order = np.argsort(-cnts, kind="stable")
            rows_of_sorted = (
                np.arange(len(uniq)) * self.max_clusters // len(uniq)
            )
            row_of_uniq = np.empty(len(uniq), np.int64)
            row_of_uniq[order] = rows_of_sorted
        else:
            row_of_uniq = np.arange(len(uniq))
        n_rows = int(row_of_uniq.max()) + 1
        cluster_of = {int(u): int(r) for u, r in zip(uniq, row_of_uniq)}
        cluster_size = np.bincount(row_of_uniq, minlength=n_rows).astype(np.float64)
        rows = row_of_uniq[inv]

        ovl = self._bucket_overlap_counts(life)  # [n, B]
        grid = np.zeros((n_rows, self.B))
        din = np.zeros((n_rows, self.B))
        dout = np.zeros((n_rows, self.B))
        for b in range(self.B):
            sel = ovl[:, b]
            np.add.at(grid, (rows[sel], b), 1.0)
            if degrees_in is not None:
                np.add.at(din, (rows[sel], b), degrees_in[ent[sel]])
                np.add.at(dout, (rows[sel], b), degrees_out[ent[sel]])
        with np.errstate(invalid="ignore", divide="ignore"):
            din = np.where(grid > 0, din / np.maximum(grid, 1), 0.0)
            dout = np.where(grid > 0, dout / np.maximum(grid, 1), 0.0)
        # per-row normalisation: grid holds counts per cluster row; divide by
        # cluster size to estimate per-VALUE frequency (paper's cluster map).
        grid = grid / np.maximum(cluster_size[:, None], 1.0)
        tiles = _tile_grid(grid, din, dout, self.var_threshold)
        return KeyStats(IntervalTree(tiles), cluster_of, cluster_size, n_rows)

    def _build(self, etr_samples: int, seed: int):
        g = self.g
        din = g.in_degree.astype(np.float64)
        dout = g.out_degree.astype(np.float64)
        for k, col in g.vprops.items():
            self.vkey_stats[k] = self._build_key(col, din, dout)
        for k, col in g.eprops.items():
            self.ekey_stats[k] = self._build_key(col, None, None)
        # lifespan histograms per type
        ovl_v = self._bucket_overlap_counts(g.v_life)
        for t in range(g.n_vertex_types):
            sel = g.v_type == t
            self.type_life_hist[t] = ovl_v[sel].sum(axis=0)
        ovl_e = self._bucket_overlap_counts(g.e_life)
        for t in range(g.n_edge_types):
            sel = g.e_type == t
            self.etype_life_hist[t] = ovl_e[sel].sum(axis=0)
        # type-aware degree table D[vt, et, dir]: avg #incident et-edges per
        # vt-vertex; dir 0 = outgoing, 1 = incoming.
        for et in range(g.n_edge_types):
            sel = g.e_type == et
            src_t = g.v_type[g.e_src[sel]]
            dst_t = g.v_type[g.e_dst[sel]]
            cnt_s = np.bincount(src_t, minlength=g.n_vertex_types)
            cnt_d = np.bincount(dst_t, minlength=g.n_vertex_types)
            denom = np.maximum(g.type_counts, 1)
            self.degree_table[:, et, 0] = cnt_s / denom
            self.degree_table[:, et, 1] = cnt_d / denom
        # ETR selectivity per comparator (sampled incident edge pairs)
        rng = np.random.default_rng(seed)
        if g.n_edges >= 2:
            e1 = rng.integers(0, g.n_edges, size=etr_samples)
            e2 = rng.integers(0, g.n_edges, size=etr_samples)
            a = g.e_life[e1].astype(np.int64)
            b = g.e_life[e2].astype(np.int64)
            sel = {
                iv.FULLY_BEFORE: np.mean(a[:, 1] <= b[:, 0]),
                iv.STARTS_BEFORE: np.mean(a[:, 0] < b[:, 0]),
                iv.FULLY_AFTER: np.mean(a[:, 0] >= b[:, 1]),
                iv.STARTS_AFTER: np.mean(a[:, 0] > b[:, 0]),
                iv.OVERLAPS: np.mean((a[:, 0] < b[:, 1]) & (b[:, 0] < a[:, 1])),
            }
            self.etr_select = {k: float(v) for k, v in sel.items()}

    # ------------------------------------------------------------- lookups
    def _bucket_range(self, interval: Optional[Tuple[int, int]]) -> Tuple[int, int]:
        if interval is None:
            return 0, self.B
        lo = int(np.searchsorted(self.bedges, interval[0], side="right")) - 1
        hi = int(np.searchsorted(self.bedges, interval[1], side="left"))
        return max(lo, 0), min(max(hi, lo + 1), self.B)

    def h_lookup(self, key: int, value: int, interval=None, is_edge=False) -> HEntry:
        """The paper's H_κ(val, τ) → (f, δ_in, δ_out)."""
        ks = (self.ekey_stats if is_edge else self.vkey_stats).get(key)
        if ks is None or ks.n_rows == 0:
            return HEntry(0.0, 0.0, 0.0)
        row = ks.cluster_of.get(int(value))
        if row is None:
            return HEntry(0.0, 0.0, 0.0)
        b_lo, b_hi = self._bucket_range(interval)
        tiles = ks.tree.query(b_lo, b_hi)
        f = di = do = w = 0.0
        for t in tiles:
            if t.v_lo <= row < t.v_hi:
                ow = min(t.t_hi, b_hi) - max(t.t_lo, b_lo)
                f += t.freq * ow
                di += t.d_in * ow
                do += t.d_out * ow
                w += ow
        if w == 0:
            return HEntry(0.0, 0.0, 0.0)
        return HEntry(f / w, di / w, do / w)   # time-weighted average

    def type_count(self, vtype: int) -> float:
        if vtype < 0:
            return float(self.g.n_vertices)
        return float(self.g.type_counts[vtype])

    def etype_count(self, etype: int) -> float:
        if etype < 0:
            return float(self.g.n_edges)
        return float(self.g.edge_type_counts[etype])

    def lifespan_frac(self, vtype: int, interval, is_edge=False) -> float:
        """Fraction of type-σ entities whose lifespan overlaps interval."""
        b_lo, b_hi = self._bucket_range(interval)
        hist = self.etype_life_hist if is_edge else self.type_life_hist
        if is_edge:
            tot = self.etype_count(vtype)
            row = hist[vtype] if vtype >= 0 else hist.sum(axis=0)
        else:
            tot = self.type_count(vtype)
            row = hist[vtype] if vtype >= 0 else hist.sum(axis=0)
        if tot == 0:
            return 0.0
        return float(row[b_lo:b_hi].max(initial=0.0)) / tot

    def degree(self, vtype: int, etype: int, direction: int) -> float:
        """avg # of traversable etype-edges per vtype-vertex for a hop dir."""
        if vtype < 0:
            d = self.degree_table.mean(axis=0)
        else:
            d = self.degree_table[vtype]
        if etype < 0:
            d = d.sum(axis=0)
        else:
            d = d[etype]
        if direction == Q.DIR_OUT:
            return float(d[0])
        if direction == Q.DIR_IN:
            return float(d[1])
        return float(d[0] + d[1])

    def size_report(self) -> dict:
        n_tiles = sum(len(s.tree.tiles) for s in self.vkey_stats.values())
        n_tiles += sum(len(s.tree.tiles) for s in self.ekey_stats.values())
        raw_cells = sum(s.n_rows * self.B for s in self.vkey_stats.values())
        raw_cells += sum(s.n_rows * self.B for s in self.ekey_stats.values())
        return dict(n_tiles=n_tiles, raw_cells=raw_cells,
                    bytes_tiled=n_tiles * 7 * 8, bytes_raw=raw_cells * 3 * 8)
