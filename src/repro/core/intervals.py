"""Interval algebra over half-open integer intervals ``[t_s, t_e)``.

Time is a linearly ordered discrete domain (int32 time-units).  An interval is
represented as the last axis of an array: ``iv[..., 0] = t_s``, ``iv[..., 1] = t_e``.
An interval is *empty* iff ``t_s >= t_e``.

The eight Allen-style comparators from the paper (Sec. 3.1):

====  ===========================  =========================================
id    paper symbol                 semantics for ``a CMP b``
====  ===========================  =========================================
0     ``<<`` (fully before)        ``a.e <= b.s``
1     ``<`` (starts before)        ``a.s < b.s``
2     ``>>`` (fully after)         ``a.s >= b.e``
3     ``>`` (starts after)         ``a.s > b.s``
4     ``during``                   ``a.s > b.s and a.e < b.e``
5     ``equals``                   ``a.s == b.s and a.e == b.e``
6     ``during_eq``                ``a.s >= b.s and a.e <= b.e``
7     ``overlaps``                 ``a.s < b.e and b.s < a.e``
====  ===========================  =========================================

All functions are pure jnp and broadcast; they are used both by the engine
(device) and, through numpy duck-typing, by host-side code.
"""
from __future__ import annotations

import jax.numpy as jnp

# Comparator ids (keep in sync with the table above and query.py).
FULLY_BEFORE = 0
STARTS_BEFORE = 1
FULLY_AFTER = 2
STARTS_AFTER = 3
DURING = 4
EQUALS = 5
DURING_EQ = 6
OVERLAPS = 7

TIME_CMP_NAMES = {
    "<<": FULLY_BEFORE,
    "<": STARTS_BEFORE,
    ">>": FULLY_AFTER,
    ">": STARTS_AFTER,
    "during": DURING,
    "==": EQUALS,
    "in": DURING_EQ,
    "overlaps": OVERLAPS,
}


def is_empty(iv):
    return iv[..., 0] >= iv[..., 1]


def intersect(a, b):
    """Elementwise interval intersection (may be empty)."""
    s = jnp.maximum(a[..., 0], b[..., 0])
    e = jnp.minimum(a[..., 1], b[..., 1])
    return jnp.stack([s, e], axis=-1)


def span(a, b):
    """Smallest interval covering both."""
    s = jnp.minimum(a[..., 0], b[..., 0])
    e = jnp.maximum(a[..., 1], b[..., 1])
    return jnp.stack([s, e], axis=-1)


def overlaps(a, b):
    nonempty = (a[..., 0] < a[..., 1]) & (b[..., 0] < b[..., 1])
    return (a[..., 0] < b[..., 1]) & (b[..., 0] < a[..., 1]) & nonempty


def compare(op, a, b):
    """Vectorised Allen comparison ``a op b``.

    ``op`` may be a traced int32 scalar (query-as-data) or a Python int.
    Computes all eight relations and selects — each relation is a couple of
    integer compares, so this is cheaper than control flow on TPU.
    """
    a_s, a_e = a[..., 0], a[..., 1]
    b_s, b_e = b[..., 0], b[..., 1]
    rels = jnp.stack(
        [
            a_e <= b_s,                      # fully before
            a_s < b_s,                       # starts before
            a_s >= b_e,                      # fully after
            a_s > b_s,                       # starts after
            (a_s > b_s) & (a_e < b_e),       # during
            (a_s == b_s) & (a_e == b_e),     # equals
            (a_s >= b_s) & (a_e <= b_e),     # during or equals
            (a_s < b_e) & (b_s < a_e),       # overlaps
        ],
        axis=0,
    )
    nonempty = (a_s < a_e) & (b_s < b_e)
    op = jnp.asarray(op, dtype=jnp.int32)
    return jnp.take(rels, op, axis=0) & nonempty


# ---------------------------------------------------------------------------
# Bucketised time axis (the TPU-dense stand-in for ICM's TimeWarp alignment).
# ---------------------------------------------------------------------------


def bucket_edges(t_min: int, t_max: int, n_buckets: int):
    """Host helper: integer bucket boundaries covering [t_min, t_max)."""
    import numpy as np

    width = max(1, -(-(t_max - t_min) // n_buckets))  # ceil div
    return np.asarray([t_min + i * width for i in range(n_buckets + 1)], dtype=np.int32)


def interval_to_bucket_mask(iv, edges):
    """``bool[..., B]`` mask of buckets the interval overlaps.

    ``edges`` is ``int32[B+1]`` of bucket boundaries.  Bucket b spans
    ``[edges[b], edges[b+1])``.
    """
    lo = edges[:-1]
    hi = edges[1:]
    s = iv[..., 0:1]
    e = iv[..., 1:2]
    return (s < hi) & (lo < e)


def bucket_id(t, edges):
    """Bucket index of time-point ``t`` (clamped)."""
    b = jnp.searchsorted(edges, t, side="right") - 1
    return jnp.clip(b, 0, edges.shape[0] - 2)
