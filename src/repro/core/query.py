"""Temporal path query model (Sec. 3.3 of the paper).

An n-hop linear chain query = n vertex predicates + (n-1) edge predicates.
Predicates are property clauses / time clauses combined with AND/OR, plus the
novel edge-temporal-relationship (ETR) clause and an optional temporal
aggregation operator.

The engine is jitted with the query *structure* static (clause kinds, keys,
comparators, hop count, directions, ETR ops — these define the traced
computation) and the query *parameters* as data (property values and interval
constants — so the 100 instances of an LDBC template share one executable).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from . import intervals as iv

# ----------------------------------------------------------------- constants
# clause kinds
K_PROP = 1
K_TIME = 2

# property comparators
P_EQ = 0
P_NEQ = 1
P_CONTAINS = 2  # '∋' membership over multi-valued keys

PROP_CMP_NAMES = {"==": P_EQ, "!=": P_NEQ, "in": P_CONTAINS}

# Boolean connectives
AND = 0
OR = 1

# edge directions
DIR_OUT = 0   # →
DIR_IN = 1    # ←
DIR_BOTH = 2  # ↔

# ETR ops (edge-lifespan vs edge-lifespan) — exact fast path subset
ETR_OPS = (
    iv.FULLY_BEFORE,
    iv.STARTS_BEFORE,
    iv.FULLY_AFTER,
    iv.STARTS_AFTER,
    iv.OVERLAPS,
)

# aggregation
AGG_NONE = -1
AGG_COUNT = 0
AGG_MIN = 1
AGG_MAX = 2
AGG_NAMES = {"count": AGG_COUNT, "min": AGG_MIN, "max": AGG_MAX}


# ----------------------------------------------------------------- AST types
@dataclasses.dataclass(frozen=True)
class Clause:
    kind: int                       # K_PROP | K_TIME
    conj: int = AND                 # connective to the running accumulator
    key: int = -1                   # property key id       (K_PROP)
    cmp: int = P_EQ                 # P_* or interval cmp id (K_TIME)
    value: int = -1                 # dict-encoded value     (K_PROP, data)
    interval: Tuple[int, int] = (0, 0)  # constant interval  (K_TIME, data)

    def shape_key(self):
        return (self.kind, self.conj, self.key, self.cmp)


def prop_clause(key: int, cmp: str, value: int, conj: int = AND) -> Clause:
    return Clause(kind=K_PROP, conj=conj, key=key, cmp=PROP_CMP_NAMES[cmp], value=value)


def time_clause(cmp: str, interval: Tuple[int, int], conj: int = AND) -> Clause:
    return Clause(
        kind=K_TIME, conj=conj, cmp=iv.TIME_CMP_NAMES[cmp], interval=tuple(interval)
    )


@dataclasses.dataclass(frozen=True)
class VertexPredicate:
    vtype: int = -1                       # -1 = wildcard
    clauses: Tuple[Clause, ...] = ()

    def shape_key(self):
        return (self.vtype, tuple(c.shape_key() for c in self.clauses))


@dataclasses.dataclass(frozen=True)
class EdgePredicate:
    etype: int = -1
    direction: int = DIR_OUT
    clauses: Tuple[Clause, ...] = ()
    etr_op: int = -1                      # -1 = no ETR clause on this hop

    def shape_key(self):
        return (
            self.etype,
            self.direction,
            tuple(c.shape_key() for c in self.clauses),
            self.etr_op,
        )


@dataclasses.dataclass(frozen=True)
class PathQuery:
    v_preds: Tuple[VertexPredicate, ...]
    e_preds: Tuple[EdgePredicate, ...]
    agg_op: int = AGG_NONE
    agg_key: int = -1                     # property at last vertex (min/max)

    def __post_init__(self):
        assert len(self.v_preds) == len(self.e_preds) + 1, "n vertex preds, n-1 edge preds"
        if self.e_preds and self.e_preds[0].etr_op != -1:
            raise ValueError("ETR needs a left edge; first hop cannot carry one")
        for e in self.e_preds:
            if e.etr_op != -1 and e.etr_op not in ETR_OPS:
                raise ValueError(f"unsupported ETR op {e.etr_op} (exact set: {ETR_OPS})")

    @property
    def n_hops(self) -> int:
        return len(self.e_preds)

    @property
    def n_vertices(self) -> int:
        return len(self.v_preds)

    def shape_key(self):
        """Hashable structure — the engine's jit/static key."""
        return (
            tuple(v.shape_key() for v in self.v_preds),
            tuple(e.shape_key() for e in self.e_preds),
            self.agg_op,
            self.agg_key,
        )

    # ------------------------------------------------------------- plan data
    def reversed(self) -> "PathQuery":
        """The same query traversed right-to-left (directions flipped).

        ETR note: an ETR clause on ``e_preds[i]`` constrains the *pair*
        ``(e_{i-1}, e_i)``.  Under reversal, the pair ``(e_k, e_{k+1})`` is
        checked while processing ``e_k`` (whose predecessor in execution
        order is ``e_{k+1}``), so ETR ops shift by one position.  The engine
        evaluates shifted ops with the *backward* comparator specs.
        """
        flip = {DIR_OUT: DIR_IN, DIR_IN: DIR_OUT, DIR_BOTH: DIR_BOTH}
        m = len(self.e_preds)
        v = tuple(reversed(self.v_preds))
        e = []
        for j, pred in enumerate(reversed(self.e_preds)):
            etr = -1 if j == 0 else self.e_preds[m - j].etr_op
            e.append(
                dataclasses.replace(pred, direction=flip[pred.direction], etr_op=etr)
            )
        return PathQuery(v, tuple(e), self.agg_op, self.agg_key)


# --------------------------------------------------------------- parameters
def query_params(q: PathQuery) -> np.ndarray:
    """Pack the data-dependent parameters into one int32[n_clauses, 3] array.

    Row layout: [value, t_lo, t_hi].  Order: vertex preds then edge preds,
    clauses in declaration order.  Matches `iter_clauses`.
    """
    rows = []
    for c in iter_clauses(q):
        rows.append((c.value, c.interval[0], c.interval[1]))
    if not rows:
        rows = [(0, 0, 0)]
    return np.asarray(rows, np.int32)


def iter_clauses(q: PathQuery):
    for v in q.v_preds:
        yield from v.clauses
    for e in q.e_preds:
        yield from e.clauses


# ------------------------------------------------------------- pretty print
_DIR_STR = {DIR_OUT: "→", DIR_IN: "←", DIR_BOTH: "↔"}


def format_query(q: PathQuery) -> str:
    parts = []
    for i, v in enumerate(q.v_preds):
        parts.append(f"V{i}(type={v.vtype},{len(v.clauses)}c)")
        if i < q.n_hops:
            e = q.e_preds[i]
            etr = f",ETR{e.etr_op}" if e.etr_op != -1 else ""
            parts.append(f"-E{i}(type={e.etype}{etr}){_DIR_STR[e.direction]}")
    if q.agg_op != AGG_NONE:
        parts.append(f" ⊕agg{q.agg_op}[{q.agg_key}]")
    return "".join(parts)
