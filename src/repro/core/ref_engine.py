"""Exact reference oracle for temporal path queries (pure Python/numpy).

Enumerates matching paths explicitly with true interval-list semantics.  Used
by the test-suite to validate the vectorised engine (engine.py) and by the
benchmarks as the "ground truth" result verifier.  Only suitable for small
graphs (explicit DFS).

Semantics mirrored (see engine.py docstring):
  * static mode   — boolean predicate matching, scalar path counts.
  * bucket mode   — per-bucket counts: a path counts at bucket b iff every
    entity on it is valid at b (validity = lifespan ∧ value-specific property
    validity for EQ/CONTAINS clauses).
  * interval mode — distinct temporal paths: one result per (path, maximal
    contiguous window of the running validity intersection).
ETR clauses compare adjacent edge lifespans; temporal aggregation groups by
the first vertex (and bucket, in temporal modes).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import intervals as iv
from . import query as Q
from .graph import TemporalGraph

Interval = Tuple[int, int]
IList = List[Interval]  # disjoint, sorted


# ------------------------------------------------------------ interval lists
def _norm(ivs: IList) -> IList:
    ivs = sorted((s, e) for s, e in ivs if s < e)
    out: IList = []
    for s, e in ivs:
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def _ilist_intersect(a: IList, b: IList) -> IList:
    out = []
    for s1, e1 in a:
        for s2, e2 in b:
            s, e = max(s1, s2), min(e1, e2)
            if s < e:
                out.append((s, e))
    return _norm(out)


def _ilist_union(a: IList, b: IList) -> IList:
    return _norm(list(a) + list(b))


def _cmp_interval(op: int, a: Interval, b: Interval) -> bool:
    if a[0] >= a[1] or b[0] >= b[1]:
        return False
    if op == iv.FULLY_BEFORE:
        return a[1] <= b[0]
    if op == iv.STARTS_BEFORE:
        return a[0] < b[0]
    if op == iv.FULLY_AFTER:
        return a[0] >= b[1]
    if op == iv.STARTS_AFTER:
        return a[0] > b[0]
    if op == iv.DURING:
        return a[0] > b[0] and a[1] < b[1]
    if op == iv.EQUALS:
        return a == b
    if op == iv.DURING_EQ:
        return a[0] >= b[0] and a[1] <= b[1]
    if op == iv.OVERLAPS:
        return a[0] < b[1] and b[0] < a[1]
    raise ValueError(op)


# ------------------------------------------------------------- clause eval
class _Entity:
    """A vertex or edge with its properties, for oracle-side predicate eval."""

    __slots__ = ("etype", "life", "props")

    def __init__(self, etype: int, life: Interval, props: Dict[int, List[Tuple[int, Interval]]]):
        self.etype = etype
        self.life = life
        self.props = props  # key -> [(value, validity)]


def _eval_clause(ent: _Entity, c: Q.Clause) -> Tuple[bool, IList]:
    base = [ent.life]
    if c.kind == Q.K_TIME:
        return _cmp_interval(c.cmp, ent.life, tuple(c.interval)), base
    vals = ent.props.get(c.key, [])
    if c.cmp == Q.P_NEQ:
        has = len(vals) > 0
        m = has and all(v != c.value for v, _ in vals)
        return m, base
    matched = [(v, ivl) for v, ivl in vals if v == c.value]
    valid = _norm([ivl for _, ivl in matched])
    return len(matched) > 0, (valid if valid else [])


def _eval_predicate(ent: _Entity, req_type: int, clauses: Sequence[Q.Clause]):
    """Returns (match, validity ilist)."""
    if ent.life[0] >= ent.life[1]:
        return False, []
    if req_type >= 0 and ent.etype != req_type:
        return False, []
    validity: IList = [ent.life]
    if not clauses:
        return True, validity
    acc_m: Optional[bool] = None
    acc_v: IList = []
    for c in clauses:
        m, v = _eval_clause(ent, c)
        if acc_m is None:
            acc_m, acc_v = m, v
        elif c.conj == Q.AND:
            acc_m = acc_m and m
            acc_v = _ilist_intersect(acc_v, v)
        else:
            if acc_m and not m:
                pass
            elif m and not acc_m:
                acc_v = v
            else:
                acc_v = _ilist_union(acc_v, v)
            acc_m = acc_m or m
    return bool(acc_m), _ilist_intersect(validity, acc_v)


# --------------------------------------------------------------- the oracle
class RefEngine:
    def __init__(self, graph: TemporalGraph, max_expansions: int = 5_000_000):
        self.g = graph
        self.max_expansions = max_expansions
        self._adj_out: Dict[int, List[int]] = defaultdict(list)
        self._adj_in: Dict[int, List[int]] = defaultdict(list)
        for e in range(graph.n_edges):
            self._adj_out[int(graph.e_src[e])].append(e)
            self._adj_in[int(graph.e_dst[e])].append(e)
        self._vcache: Dict[int, _Entity] = {}
        self._ecache: Dict[int, _Entity] = {}

    # ---- entity views
    def vertex(self, vid: int) -> _Entity:
        ent = self._vcache.get(vid)
        if ent is None:
            props = {}
            for k, col in self.g.vprops.items():
                lst = []
                for s in range(col.n_slots):
                    v = int(col.vals[vid, s])
                    if v >= 0:
                        lst.append((v, (int(col.life[vid, s, 0]), int(col.life[vid, s, 1]))))
                if lst:
                    props[k] = lst
            ent = _Entity(int(self.g.v_type[vid]),
                          (int(self.g.v_life[vid, 0]), int(self.g.v_life[vid, 1])), props)
            self._vcache[vid] = ent
        return ent

    def edge(self, eid: int) -> _Entity:
        ent = self._ecache.get(eid)
        if ent is None:
            props = {}
            for k, col in self.g.eprops.items():
                lst = []
                for s in range(col.n_slots):
                    v = int(col.vals[eid, s])
                    if v >= 0:
                        lst.append((v, (int(col.life[eid, s, 0]), int(col.life[eid, s, 1]))))
                if lst:
                    props[k] = lst
            ent = _Entity(int(self.g.e_type[eid]),
                          (int(self.g.e_life[eid, 0]), int(self.g.e_life[eid, 1])), props)
            self._ecache[eid] = ent
        return ent

    def _neighbors(self, vid: int, direction: int):
        """Yield (edge_id, neighbor_vid) honoring hop direction."""
        if direction in (Q.DIR_OUT, Q.DIR_BOTH):
            for e in self._adj_out[vid]:
                yield e, int(self.g.e_dst[e])
        if direction in (Q.DIR_IN, Q.DIR_BOTH):
            for e in self._adj_in[vid]:
                yield e, int(self.g.e_src[e])

    # ---- enumeration
    def enumerate_paths(self, qry: Q.PathQuery):
        """Yield (path_vertices, path_edges, validity_ilist) for every match.

        validity is the running intersection of entity validities (interval
        mode semantics); static-mode callers ignore it.
        """
        n = qry.n_vertices
        expansions = 0
        for v0 in range(self.g.n_vertices):
            m, val = _eval_predicate(self.vertex(v0), qry.v_preds[0].vtype, qry.v_preds[0].clauses)
            if not m:
                continue
            stack = [([v0], [], val)]
            while stack:
                vs, es, run_val = stack.pop()
                hop = len(es)
                if hop == n - 1:
                    yield vs, es, run_val
                    continue
                ep = qry.e_preds[hop]
                vp_next = qry.v_preds[hop + 1]
                for eid, nxt in self._neighbors(vs[-1], ep.direction):
                    expansions += 1
                    if expansions > self.max_expansions:
                        raise RuntimeError("oracle expansion budget exceeded")
                    em, ev = _eval_predicate(self.edge(eid), ep.etype, ep.clauses)
                    if not em:
                        continue
                    if ep.etr_op != -1:
                        left = self.edge(es[-1]).life
                        right = self.edge(eid).life
                        if not _cmp_interval(ep.etr_op, left, right):
                            continue
                    vm, vv = _eval_predicate(self.vertex(nxt), vp_next.vtype, vp_next.clauses)
                    if not vm:
                        continue
                    nv = _ilist_intersect(_ilist_intersect(run_val, ev), vv)
                    stack.append((vs + [nxt], es + [eid], nv))

    # ---- counting, per mode
    def count(self, qry: Q.PathQuery, mode: int = 0, n_buckets: int = 16):
        from .engine import MODE_BUCKET, MODE_INTERVAL, MODE_STATIC

        if mode == MODE_STATIC:
            return float(sum(1 for _ in self.enumerate_paths(qry)))
        edges = iv.bucket_edges(self.g.lifespan[0], self.g.lifespan[1], n_buckets)
        if mode == MODE_BUCKET:
            out = np.zeros(n_buckets)
            for _, _, val in self.enumerate_paths(qry):
                for b in range(n_buckets):
                    blo, bhi = int(edges[b]), int(edges[b + 1])
                    if any(s < bhi and blo < e for s, e in val):
                        out[b] += 1
            return out
        if mode == MODE_INTERVAL:
            total = 0
            for _, _, val in self.enumerate_paths(qry):
                total += len(val)  # one result per maximal window
            return float(total)
        raise ValueError(mode)

    def aggregate(self, qry: Q.PathQuery, mode: int = 0, n_buckets: int = 16):
        """Temporal aggregation: group by first vertex (× bucket in temporal
        modes); returns dict v0 -> value (static) or array [V, B] (bucket)."""
        from .engine import MODE_BUCKET, MODE_STATIC

        assert qry.agg_op != Q.AGG_NONE
        if mode == MODE_STATIC:
            groups: Dict[int, List[float]] = defaultdict(list)
            for vs, _, _ in self.enumerate_paths(qry):
                last = vs[-1]
                if qry.agg_op == Q.AGG_COUNT:
                    groups[vs[0]].append(1.0)
                else:
                    col = self.g.vprops[qry.agg_key]
                    groups[vs[0]].append(float(col.vals[last, 0]))
            out = {}
            for v0, lst in groups.items():
                if qry.agg_op == Q.AGG_COUNT:
                    out[v0] = float(len(lst))
                elif qry.agg_op == Q.AGG_MIN:
                    out[v0] = min(lst)
                else:
                    out[v0] = max(lst)
            return out
        assert mode == MODE_BUCKET and qry.agg_op == Q.AGG_COUNT
        edges = iv.bucket_edges(self.g.lifespan[0], self.g.lifespan[1], n_buckets)
        out = np.zeros((self.g.n_vertices, n_buckets))
        for vs, _, val in self.enumerate_paths(qry):
            for b in range(n_buckets):
                blo, bhi = int(edges[b]), int(edges[b + 1])
                if any(s < bhi and blo < e for s, e in val):
                    out[vs[0], b] += 1
        return out
