"""Type-sliced plan execution — the §Perf-optimised engine path.

The paper's type-based partitioning (Sec. 4.4.1) lets a superstep skip every
partition whose vertex type cannot match.  In tensor form: vertices are
type-major and traversal edges are arrival-sorted, so *the traversal edges
arriving at one vertex type are one contiguous slice* and a typed hop only
has to touch that slice.  Slice bounds are host-known per graph, hence
compile-time constants; everything else (predicate eval, delivery, ETR rank
prefix sums) operates on the slices unchanged.

Work per hop drops from O(2E) to O(arrivals(σ_{i+1})) and the init from O(V)
to O(|V_σ0|) — this is what makes split-point plans differ in cost and what
the cost model's extent terms (planner.py) measure.

Requires: every vertex predicate carries a type (the LDBC workload does).
Falls back to the dense engine otherwise (engine.execute handles routing).

Layering: this is the SLICED executor of the three-layer stack (superstep
core → dense / sliced / partitioned executors); all hop primitives come from
``superstep.py`` — only the slice bookkeeping lives here.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import query as Q
from . import superstep as SS
from .engine import ExecOutput, _pbases
from .graph import TemporalGraph
from .superstep import MODE_BUCKET, MODE_INTERVAL, MODE_STATIC


@dataclasses.dataclass(frozen=True)
class SliceBounds:
    """Host-side static slice bounds for one graph."""
    v: Tuple[Tuple[int, int], ...]   # per type: [vlo, vhi)
    e: Tuple[Tuple[int, int], ...]   # per type: arrival-edge slice [elo, ehi)

    @staticmethod
    def from_graph(g: TemporalGraph) -> "SliceBounds":
        tr = g.type_ranges
        ptr = g.traversal["arr_ptr"]
        v = tuple((int(a), int(b)) for a, b in tr)
        e = tuple((int(ptr[a]), int(ptr[b])) for a, b in tr)
        return SliceBounds(v, e)


def _vslice(arr, lo, hi):
    return arr[lo:hi]


def slice_layouts_for(graph: TemporalGraph, qry: Q.PathQuery,
                      sb: SliceBounds, impl: str = "xla",
                      block_v: Optional[int] = None,
                      block_e_mult: int = 512) -> dict:
    """Per-arrival-type HopLayouts for a query's hops (the sliced twin of
    ``engine.hop_layout_for``): the traversal edges arriving at one vertex
    type are one contiguous slice, so each type gets its own block layout
    over slice-local destinations.  Cached on the graph; empty slices are
    skipped (the sliced planner early-outs before delivering into them)."""
    if not SS.use_pallas(impl):
        return {}
    from ..kernels.hop_scatter import build_hop_layout

    cache = getattr(graph, "_hop_layout_cache", None)
    if cache is None:
        cache = {}
        graph._hop_layout_cache = cache
    t_dst = None
    layouts = {}
    for vp in qry.v_preds:
        vt = vp.vtype
        vlo, vhi = sb.v[vt]
        if vt in layouts or vhi <= vlo:
            continue
        key = ("slice", vt, block_v, block_e_mult)
        lay = cache.get(key)
        if lay is None:
            if t_dst is None:
                t_dst = np.asarray(graph.traversal["t_dst"])
            elo, ehi = sb.e[vt]
            lay = build_hop_layout(t_dst[elo:ehi] - vlo, vhi - vlo,
                                   block_v=block_v,
                                   block_e_mult=block_e_mult)
            cache[key] = lay
        layouts[vt] = lay
    return layouts


def _vertex_eval_sliced(gdev, vp, params, pbase, mode, bedges, vb):
    lo, hi = vb
    props = {k: (v[0][lo:hi], v[1][lo:hi]) for k, v in gdev["vprops"].items()}
    return SS.eval_predicate(
        props, gdev["v_type"][lo:hi], gdev["v_life"][lo:hi], vp.vtype,
        vp.clauses, params, pbase, mode, bedges,
    )


def _edge_eval_sliced(gdev, ep, params, pbase, mode, bedges, eb):
    lo, hi = eb
    eprops = {k: (v[0][lo:hi], v[1][lo:hi]) for k, v in gdev["eprops_t"].items()}
    t_life = gdev["t_life"][lo:hi]
    match, validity = SS.eval_predicate(
        eprops, gdev["t_type"][lo:hi], t_life, ep.etype, ep.clauses,
        params, pbase, mode, bedges,
    )
    isfwd = gdev["t_isfwd"][lo:hi]
    if ep.direction == Q.DIR_OUT:
        dmask = isfwd == 1
    elif ep.direction == Q.DIR_IN:
        dmask = isfwd == 0
    else:
        dmask = jnp.ones_like(isfwd, bool)
    return (match & dmask), validity


def _etr_weighted_sliced(gdev, cnt_prev, op, backward, use_arr,
                         prev_eb, cur_eb, prev_vb):
    """ETR prefix over the previous arrival slice, gathered for the current
    slice's edges.  cnt_prev lives on [prev_eb), ranks are slice-invariant."""
    alpha, terms = SS.ETR_SPECS[(op, backward)]
    plo, phi = prev_eb
    clo, chi = cur_eb
    vlo, _ = prev_vb
    perm_s = gdev["etr_perm_start"][plo:phi] - plo
    perm_e = gdev["etr_perm_end"][plo:phi] - plo
    ranks = (gdev["etr_arr_ranks"] if use_arr else gdev["etr_dep_ranks"])[:, clo:chi]
    ptr = gdev["arr_ptr"]
    segv = (gdev["t_dst"] if use_arr else gdev["t_src"])[clo:chi]

    trailing = cnt_prev.shape[1:]
    zero = jnp.zeros((1,) + trailing, cnt_prev.dtype)
    S_s = jnp.concatenate([zero, jnp.cumsum(cnt_prev[perm_s], axis=0)], axis=0)
    need_end = any(t == 3 for _, t in terms)
    S_e = (jnp.concatenate([zero, jnp.cumsum(cnt_prev[perm_e], axis=0)], axis=0)
           if need_end else None)
    nmax = phi - plo
    base_pos = jnp.clip(ptr[segv] - plo, 0, nmax)
    end_pos = jnp.clip(ptr[segv + 1] - plo, 0, nmax)
    # edges whose source is outside the previous type slice contribute 0
    in_range = (ptr[segv] >= plo) & (ptr[segv + 1] <= phi)
    out = 0.0
    base_s = S_s[base_pos]
    if alpha:
        out = alpha * (S_s[end_pos] - base_s)
    for sign, term in terms:
        S = S_e if term == 3 else S_s
        base = S_e[base_pos] if term == 3 else base_s
        pos = jnp.clip(base_pos + ranks[term], 0, nmax)
        out = out + sign * (S[pos] - base)
    shape_mask = in_range
    for _ in trailing:
        shape_mask = shape_mask[..., None]
    return out * shape_mask.astype(cnt_prev.dtype)


@dataclasses.dataclass
class _SegResult:
    arrivals_e: Optional[jnp.ndarray]   # on the final arrival slice
    arrivals_v: Optional[jnp.ndarray]   # [vhi-vlo, *TS] of final vertex type
    final_eb: Tuple[int, int]
    final_vb: Tuple[int, int]


def _run_segment_sliced(gdev, v_preds, e_preds, params, pv, pe, mode,
                        n_buckets, backward, sb: SliceBounds,
                        impl: str = "xla", layouts=None):
    bedges = SS.current_bedges()
    fused = SS.use_pallas(impl) and layouts
    vb0 = sb.v[v_preds[0].vtype]
    vm, vv = _vertex_eval_sliced(gdev, v_preds[0], params, pv[0], mode, bedges, vb0)
    state_v = SS.init_state(vm, vv, mode, n_buckets)   # on slice of type σ0

    arrivals_e = None
    arrivals_v = None
    prev_raw = None
    prev_eb = None
    cur_vb = vb0
    for i, ep in enumerate(e_preds):
        nxt_vb = sb.v[v_preds[i + 1].vtype]
        cur_eb = sb.e[v_preds[i + 1].vtype]     # edges arriving at next type
        wmask, evalid = _edge_eval_sliced(gdev, ep, params, pe[i], mode,
                                          bedges, cur_eb)
        if i > 0:
            vm, vv = _vertex_eval_sliced(gdev, v_preds[i], params, pv[i], mode,
                                         bedges, cur_vb)
        lo, hi = cur_eb
        vlo, vhi = cur_vb
        src = gdev["t_src"][lo:hi]
        src_local = jnp.clip(src - vlo, 0, vhi - vlo - 1)
        src_in = (src >= vlo) & (src < vhi)
        if ep.etr_op != -1:
            src_cnt = _etr_weighted_sliced(gdev, prev_raw, ep.etr_op, backward,
                                           False, prev_eb, cur_eb, cur_vb)
            if mode == MODE_STATIC:
                src_val = src_cnt * (vm[src_local] & src_in).astype(jnp.float32)
            elif mode == MODE_BUCKET:
                mk = (vm[:, None] & vv)
                src_val = src_cnt * (mk[src_local] & src_in[:, None]).astype(jnp.float32)
            else:
                src_val = SS.apply_validity(src_cnt, vm[src_local] & src_in,
                                          vv[src_local], mode)
        else:
            if i == 0:
                sv = state_v
            else:
                sv = SS.apply_validity(arrivals_v, vm, vv, mode)
            gathered = sv[src_local]
            m = src_in
            for _ in sv.shape[1:]:
                m = m[..., None]
            src_val = gathered * m.astype(sv.dtype)
        if mode == MODE_STATIC:
            cnt_e = src_val * wmask.astype(jnp.float32)
        elif mode == MODE_BUCKET:
            cnt_e = src_val * (wmask[:, None] & evalid).astype(jnp.float32)
        else:
            cnt_e = SS.apply_validity(src_val, wmask, evalid, mode)
        nvlo, nvhi = nxt_vb
        lay = layouts.get(v_preds[i + 1].vtype) if layouts else None
        if fused and ep.etr_op == -1 and lay is not None:
            # fused kernel hop on the arrival-type slice: the out-of-slice
            # sources point at the layout's zero row instead of clip+mask
            src_slot = jnp.where(src_in, src - vlo, vhi - vlo)
            arrivals_v, _ = SS.fused_hop_deliver(
                sv, src_slot, wmask, evalid, mode, lay.tables, lay.block_v,
                nvhi - nvlo, impl=impl)
        else:
            seg = gdev["t_dst"][lo:hi] - nvlo
            arrivals_v = SS.deliver(cnt_e, seg, nvhi - nvlo, impl=impl,
                                    layout=lay)
        arrivals_e = cnt_e
        prev_raw = cnt_e
        prev_eb = cur_eb
        cur_vb = nxt_vb
    return _SegResult(arrivals_e, arrivals_v, prev_eb or sb.e[v_preds[0].vtype],
                      cur_vb)


def execute_plan_sliced(gdev, qry: Q.PathQuery, split: int, mode: int,
                        n_buckets: int, params, bedges, sb: SliceBounds,
                        impl: str = "xla", layouts=None):
    """Sliced twin of engine._execute_plan_inner (counts + count-aggregates).

    ``impl``/``layouts`` (per-arrival-type HopLayouts from
    ``slice_layouts_for``) select the fused hop-kernel delivery."""
    with SS.bucket_scope(bedges):
        return _inner(gdev, qry, split, mode, n_buckets, params, sb,
                      impl=impl, layouts=layouts)


def _zero_output(qry, mode, n_buckets, sb, want_agg):
    """Static early-out when any hop's type slice is empty (no such
    vertices exist → zero matches, trivially)."""
    if mode == MODE_BUCKET:
        total = jnp.zeros((n_buckets,), jnp.float32)
    else:
        total = jnp.zeros((), jnp.float32)
    pv = None
    if want_agg:
        lo, hi = sb.v[qry.v_preds[0].vtype]
        shape = (hi - lo,) if mode == MODE_STATIC else (hi - lo, n_buckets)
        pv = jnp.zeros(shape, jnp.float32)
    return ExecOutput(total, pv, None, [])


def _inner(gdev, qry, split, mode, n_buckets, params, sb, impl: str = "xla",
           layouts=None):
    n = qry.n_vertices
    pv, pe = _pbases(qry)
    bedges = SS.current_bedges()
    want_agg = qry.agg_op != Q.AGG_NONE
    if any(sb.v[v.vtype][1] <= sb.v[v.vtype][0] for v in qry.v_preds):
        return _zero_output(qry, mode, n_buckets, sb, want_agg)
    # arrival types of this plan: forward segment arrives at v_1..v_split,
    # reversed segment arrives at v_{n-2}..v_split
    arrival_preds = list(qry.v_preds[1: split + 1]) + list(qry.v_preds[split: n - 1])
    if any(sb.e[v.vtype][1] <= sb.e[v.vtype][0] for v in arrival_preds):
        return _zero_output(qry, mode, n_buckets, sb, want_agg)
    if want_agg:
        assert qry.agg_op == Q.AGG_COUNT, "sliced path: count aggregates"
        assert split == 0
    rev = qry.reversed()

    left = None
    if split > 0:
        left = _run_segment_sliced(gdev, qry.v_preds[: split + 1],
                                   qry.e_preds[:split], params,
                                   pv[: split + 1], pe[:split], mode,
                                   n_buckets, False, sb, impl, layouts)
    right = None
    m_hops = (n - 1) - split
    if m_hops > 0:
        rpv = [pv[n - 1 - i] for i in range(n)]
        rpe = [pe[n - 2 - j] for j in range(n - 1)]
        right = _run_segment_sliced(gdev, rev.v_preds[: m_hops + 1],
                                    rev.e_preds[:m_hops], params,
                                    rpv[: m_hops + 1], rpe[:m_hops], mode,
                                    n_buckets, True, sb, impl, layouts)

    vb = sb.v[qry.v_preds[split].vtype]
    vm, vv = _vertex_eval_sliced(gdev, qry.v_preds[split], params, pv[split],
                                 mode, bedges, vb)
    etr_at_join = 0 < split < n - 1 and qry.e_preds[split].etr_op != -1

    def vapply(av):
        return SS.apply_validity(av, vm, vv, mode)

    if n == 1:
        st = SS.init_state(vm, vv, mode, n_buckets)
        pv = None
        if want_agg:
            pv = st if mode != MODE_INTERVAL else SS.cells_to_buckets(st)
        return ExecOutput(SS.state_total(st, mode), pv, None, [])

    if not etr_at_join:
        if left is None:
            Rv = vapply(right.arrivals_v)
            if want_agg:
                total = SS.state_total(Rv, mode)
                # interval cells flatten to per-bucket series, as dense does
                pv = Rv if mode != MODE_INTERVAL else SS.cells_to_buckets(Rv)
                return ExecOutput(total, pv, None, [])
            return ExecOutput(SS.state_total(Rv, mode), None, None, [])
        if right is None:
            Lv = vapply(left.arrivals_v)
            return ExecOutput(SS.state_total(Lv, mode), None, None, [])
        Lv = vapply(left.arrivals_v)
        Rv = right.arrivals_v
        if mode == MODE_STATIC:
            total = jnp.sum(Lv * Rv)
        elif mode == MODE_BUCKET:
            total = jnp.sum(Lv * Rv, axis=0)
        else:
            total = jnp.sum(SS.join_interval_counts(Lv, Rv))
        return ExecOutput(total, None, None, [])

    # ETR at join: left/right final arrivals share the split-type edge slice
    op = qry.e_preds[split].etr_op
    eb = sb.e[qry.v_preds[split].vtype]
    W = _etr_weighted_sliced(gdev, left.arrivals_e, op, False, True,
                             eb, eb, vb)
    lo, hi = eb
    vlo, _ = vb
    dst_local = gdev["t_dst"][lo:hi] - vlo
    if mode == MODE_STATIC:
        w_v = vm[dst_local].astype(jnp.float32)
        total = jnp.sum(W * right.arrivals_e * w_v)
    elif mode == MODE_BUCKET:
        mk = (vm[:, None] & vv).astype(jnp.float32)[dst_local]
        total = jnp.sum(W * right.arrivals_e * mk, axis=0)
    else:
        Wc = SS.apply_validity(W, vm[dst_local], vv[dst_local], mode)
        total = jnp.sum(SS.join_interval_counts_edges(Wc, right.arrivals_e))
    return ExecOutput(total, None, None, [])


def sliceable(qry: Q.PathQuery) -> bool:
    return all(v.vtype >= 0 for v in qry.v_preds) and (
        qry.agg_op in (Q.AGG_NONE, Q.AGG_COUNT))
