"""Observability: the query flight recorder, metrics registry, and
cost-model audit pipeline.

  trace.py    hierarchical spans (query → admit → plan → compile → dispatch
              → superstep → exchange) with explicit parent handles and an
              injected clock; in-memory ring + optional JSONL sink; the
              NULL_TRACER default keeps the disabled path a no-op
  metrics.py  counter/gauge/histogram registry with fixed log-spaced latency
              buckets, Prometheus text exposition and JSON snapshot
  audit.py    predicted-vs-measured joins recomputed from trace data alone:
              telemetry replay, θ refit drift, and the paper's "% of queries
              within X% of the optimal plan" metric

The serving runtime (serving/scheduler.py, serving/replay.py) and the
instrumented profiler (core/engine_partitioned.measure_supersteps) emit
into these; ``launch/query.py --trace-out/--metrics-out`` and
``scripts/trace_report.py`` are the operator surface.
"""
from .metrics import (DEFAULT_LATENCY_BUCKETS_MS, Counter, Gauge, Histogram,
                      MetricsRegistry)
from .trace import (NULL_TRACER, NullTracer, Span, StepClock, Tracer,
                    load_jsonl, span_trees)

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Span", "StepClock",
    "load_jsonl", "span_trees",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "DEFAULT_LATENCY_BUCKETS_MS",
]
