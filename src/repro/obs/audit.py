"""Cost-model audit: predicted-vs-measured joins computed from trace data
alone.

The serving telemetry (serving/telemetry.py) already records one
(features, predicted_ms, measured_ms) row per timed group dispatch and
refits θ online.  This module recomputes the SAME quantities offline from a
flight-recorder trace — a JSONL file, a live ``Tracer``'s ring, or a plain
record list — with no access to the scheduler that produced it:

  replay_telemetry   rebuild the telemetry buffer from the trace's dispatch
                     spans (deduped by dispatch ``seq`` — member spans of
                     one group share the group row); its ``error_stats``
                     reproduce the live buffer's EXACTLY, float for float
                     (the tracer serialises via repr round-trip);
  refit_from_trace   run the production ``TelemetryBuffer.refit`` over the
                     replayed rows — the drift signal: what θ the online
                     machinery would converge to given this trace;
  coefficient_drift  per-coefficient incumbent-vs-trace-refit delta;
  plan_accuracy      the paper's §VI metric — "% of queries whose chosen
                     plan is within X% of the optimal plan" — scored by
                     re-costing every candidate the planner swept (recorded
                     on the plan span) under the trace-refit θ̂;
  audit_report       all of the above in one dict (scripts/trace_report.py
                     --audit renders it).

Import discipline: ``TelemetryBuffer`` (and with it the planner stack) is
imported inside functions, so ``repro.obs`` stays importable without the
serving layer and the serving layer can import ``repro.obs.trace`` freely.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from .trace import Tracer, load_jsonl, span_trees

TraceLike = Union[str, Tracer, Sequence[dict]]


def load_trace(source: TraceLike) -> List[dict]:
    """Normalise a trace source to a span-record list: a JSONL path, a live
    Tracer (its ring), or an already-loaded record sequence."""
    if isinstance(source, str):
        return load_jsonl(source)
    if isinstance(source, Tracer):
        return source.records()
    return list(source)


def spans_named(trace: TraceLike, name: str) -> List[dict]:
    return [r for r in load_trace(trace) if r["name"] == name]


def dispatch_records(trace: TraceLike) -> List[dict]:
    """One record per GROUP dispatch, in dispatch order.

    Every member query of a group carries its own dispatch span with the
    shared group attrs (seq, group_features, group_predicted_ms,
    group_measured_ms); the group row appears once here, keyed by ``seq`` —
    exactly the row the live TelemetryBuffer recorded.
    """
    by_seq: Dict[int, dict] = {}
    for rec in spans_named(trace, "dispatch"):
        a = rec["attrs"]
        if "seq" in a and a["seq"] not in by_seq:
            by_seq[a["seq"]] = a
    return [by_seq[s] for s in sorted(by_seq)]


def replay_telemetry(trace: TraceLike, **buffer_kw):
    """Rebuild a TelemetryBuffer from the trace's group dispatch rows.

    Defaults to a pure recorder (``refit=False``): replaying must not
    re-refit, because the recorded predictions already embed whatever θ was
    live when each dispatch ran.  The returned buffer's ``error_stats``
    match the live scheduler's float for float.
    """
    from ..serving.telemetry import TelemetryBuffer
    buffer_kw.setdefault("refit", False)
    tb = TelemetryBuffer(**buffer_kw)
    for a in dispatch_records(trace):
        tb.record(np.asarray(a["group_features"], float),
                  float(a["group_predicted_ms"]),
                  float(a["group_measured_ms"]))
    return tb


def error_report(trace: TraceLike, tail: Optional[int] = None) -> dict:
    """The live telemetry's prediction-error stats, recomputed from trace."""
    return replay_telemetry(trace).error_stats(tail=tail)


def refit_from_trace(trace: TraceLike, coeffs: Optional[dict] = None,
                     blend: float = 1.0) -> dict:
    """θ̂ the production refit converges to on this trace (the drift signal).

    ``coeffs`` is the incumbent θ to blend against (package defaults when
    omitted); ``blend=1.0`` jumps straight to the trace's least-squares
    solution — the audit wants the trace's own verdict, not a smoothed one.
    """
    from ..core.planner import load_coeffs
    rows = dispatch_records(trace)
    incumbent = dict(coeffs) if coeffs is not None else load_coeffs()
    if len(rows) < 2:
        return incumbent
    tb = replay_telemetry(trace, capacity=max(len(rows), 2),
                          min_samples=2, blend=blend)
    return tb.refit(incumbent)


def coefficient_drift(trace: TraceLike,
                      coeffs: Optional[dict] = None) -> dict:
    """Per-coefficient drift: incumbent θ vs the trace-refit θ̂.

    ``rel`` is |θ̂-θ|/max(|θ|, ε) — large values on a column say the live
    model's slope for that term no longer matches measured dispatch times
    (the signal that should trigger — or explain — an online refit).
    """
    from ..core.planner import COEFF_KEYS, load_coeffs
    incumbent = dict(coeffs) if coeffs is not None else load_coeffs()
    fitted = refit_from_trace(trace, incumbent)
    out = {}
    for k in COEFF_KEYS:
        old = float(incumbent.get(k, 0.0))
        new = float(fitted.get(k, old))
        out[k] = dict(incumbent=old, refit=new, abs_delta=abs(new - old),
                      rel=abs(new - old) / max(abs(old), 1e-12))
    return out


def plan_accuracy(trace: TraceLike, within: float = 0.10,
                  coeffs: Optional[dict] = None) -> dict:
    """The paper's plan-quality metric from trace data alone.

    The candidate sweep the batch planner ran (split × impl, with each
    candidate's feature row) is one decision per dispatched GROUP — the
    scheduler records it once, on the first member's plan span, and every
    member's plan span carries the group ``seq``.  Re-costing those
    candidates under the trace-refit θ̂ — the best post-hoc estimate of true
    cost — scores the planner the way the paper's §VI does: the fraction of
    planning decisions whose chosen plan costs at most (1+within)× the
    optimal candidate, weighted per QUERY (each decision counts once per
    group member), matching "% of queries".
    """
    from ..core.planner import coeff_vector
    theta = coeff_vector(refit_from_trace(trace, coeffs))
    # re-join the group decision to its members by seq
    groups: dict = {}
    for rec in spans_named(trace, "plan"):
        a = rec["attrs"]
        if a.get("seq") is None:
            continue
        grp = groups.setdefault(
            a["seq"], dict(cands=None, chosen=(a["split"], a["impl"]), n=0))
        grp["n"] += 1
        if a.get("candidates"):
            grp["cands"] = a["candidates"]
    n_q = n_within = n_decisions = 0
    ratios = []
    for grp in groups.values():
        cands = grp["cands"]
        if not cands:
            continue
        n_decisions += 1
        costs = {(c["split"], c["impl"]):
                 float(np.asarray(c["features"], float) @ theta)
                 for c in cands}
        best = min(costs.values())
        chosen = costs.get(grp["chosen"])
        if chosen is None or best <= 0:
            continue
        ratio = chosen / best
        ratios.extend([ratio] * grp["n"])  # weight accuracy per member query
        n_q += grp["n"]
        if ratio <= 1.0 + within:
            n_within += grp["n"]
    return dict(
        n_queries=n_q,
        n_decisions=n_decisions,
        within=within,
        frac_within=(n_within / n_q) if n_q else 1.0,
        mean_ratio=float(np.mean(ratios)) if ratios else 1.0,
        worst_ratio=float(np.max(ratios)) if ratios else 1.0,
    )


def audit_report(trace: TraceLike, within: float = 0.10,
                 tail: Optional[int] = None,
                 coeffs: Optional[dict] = None) -> dict:
    """The full cost-model audit: error stats, refit drift, plan accuracy."""
    trace = load_trace(trace)
    return dict(
        n_spans=len(trace),
        n_dispatches=len(dispatch_records(trace)),
        error=error_report(trace, tail=tail),
        drift=coefficient_drift(trace, coeffs),
        plan=plan_accuracy(trace, within=within, coeffs=coeffs),
    )


def query_summaries(trace: TraceLike) -> List[dict]:
    """Per-query rollup rows (scripts/trace_report.py's table): one dict per
    root 'query' span with its admit verdict and dispatch timings joined."""
    roots = span_trees(load_trace(trace))
    out = []
    for tid in sorted(roots):
        root = roots[tid]
        row = dict(trace_id=tid,
                   template=root["attrs"].get("template", "?"),
                   status=root["attrs"].get("status", "?"),
                   t_start=root["t_start"], t_end=root["t_end"],
                   verdict=None, rungs=None, predicted_ms=None,
                   measured_ms=None, seq=None)
        stack = list(root["children"])
        while stack:
            rec = stack.pop()
            stack.extend(rec["children"])
            a = rec["attrs"]
            if rec["name"] == "admit":
                row["verdict"] = a.get("verdict")
                row["rungs"] = a.get("rungs")
            elif rec["name"] == "dispatch":
                row["predicted_ms"] = a.get("predicted_ms")
                row["measured_ms"] = a.get("measured_ms")
                row["seq"] = a.get("seq")
        out.append(row)
    return out
