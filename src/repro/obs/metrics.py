"""Serving metrics registry: counters, gauges, log-bucketed histograms.

What the serving stack exposes (names are stable API — the README's span/
metric schema table documents them):

    granite_admission_total{verdict,rung}   admission outcomes by ladder rung
    granite_rejected_total / granite_degraded_total
    granite_queue_depth                     queued entries after each submit
    granite_dispatch_ms                     per-group measured dispatch time
    granite_dispatched_total                real queries dispatched
    granite_cache_total{cache,event}        plan/executable hit/miss/invalidation
    granite_refit_total                     online θ refits applied
    granite_deadline_slack_ms               per-completed-query slack vs its
                                            own deadline (replay harness)
    granite_replay_total{status}            done/failed/rejected per replay
    granite_goodput_qps                     deadline hits per second (gauge)

Exposition is dependency-free in two formats: ``to_prometheus()`` renders
the text format a Prometheus scrape expects (histograms as cumulative
``_bucket{le=...}`` + ``_sum``/``_count``), ``snapshot()`` a plain JSON
dict (what ``launch/query.py --metrics-out`` writes).  Histogram buckets
are FIXED log-spaced latency edges (2^-4 … 2^16 ms) so two runs — or a run
and its committed baseline — are always bucket-comparable.

Everything is deterministic given the observation stream: no timestamps,
no background threads, plain dict state.
"""
from __future__ import annotations

import bisect
import json
from typing import Dict, List, Optional, Sequence, Tuple

#: fixed log-spaced latency bucket upper edges (ms): 62.5 µs … ~65.5 s
DEFAULT_LATENCY_BUCKETS_MS: Tuple[float, ...] = tuple(
    2.0 ** k for k in range(-4, 17))


def _label_key(labelnames: Sequence[str], labels: Dict[str, str]) -> tuple:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"labels {sorted(labels)} != declared {sorted(labelnames)}")
    return tuple(str(labels[n]) for n in labelnames)


def _fmt_labels(labelnames: Sequence[str], key: tuple,
                extra: str = "") -> str:
    parts = [f'{n}="{v}"' for n, v in zip(labelnames, key)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class Counter:
    """Monotonic counter, optionally labelled."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._vals: Dict[tuple, float] = {}

    def inc(self, n: float = 1.0, **labels) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        key = _label_key(self.labelnames, labels)
        self._vals[key] = self._vals.get(key, 0.0) + n

    def value(self, **labels) -> float:
        return self._vals.get(_label_key(self.labelnames, labels), 0.0)

    def collect(self) -> List[Tuple[tuple, float]]:
        return sorted(self._vals.items())


class Gauge:
    """Set-to-current-value metric, optionally labelled."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._vals: Dict[tuple, float] = {}

    def set(self, v: float, **labels) -> None:
        self._vals[_label_key(self.labelnames, labels)] = float(v)

    def inc(self, n: float = 1.0, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        self._vals[key] = self._vals.get(key, 0.0) + n

    def value(self, **labels) -> float:
        return self._vals.get(_label_key(self.labelnames, labels), 0.0)

    def collect(self) -> List[Tuple[tuple, float]]:
        return sorted(self._vals.items())


class Histogram:
    """Fixed-bucket histogram (cumulative exposition, Prometheus-style)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_MS,
                 labelnames: Sequence[str] = ()):
        if list(buckets) != sorted(buckets):
            raise ValueError("bucket edges must be sorted")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(float(b) for b in buckets)
        # per label-key: (per-bucket counts incl. +Inf overflow, sum, count)
        self._series: Dict[tuple, list] = {}

    def observe(self, v: float, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        s = self._series.get(key)
        if s is None:
            s = [[0] * (len(self.buckets) + 1), 0.0, 0]
            self._series[key] = s
        s[0][bisect.bisect_left(self.buckets, float(v))] += 1
        s[1] += float(v)
        s[2] += 1

    def count(self, **labels) -> int:
        s = self._series.get(_label_key(self.labelnames, labels))
        return 0 if s is None else s[2]

    def sum(self, **labels) -> float:
        s = self._series.get(_label_key(self.labelnames, labels))
        return 0.0 if s is None else s[1]

    def collect(self) -> List[Tuple[tuple, list]]:
        return sorted(self._series.items())


class MetricsRegistry:
    """Name → metric, memoised: asking twice returns the SAME object, so
    scattered instrumentation sites share series without plumbing."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, cls, name: str, kwargs: dict):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, **kwargs)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(m).__name__}")
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get(Counter, name,
                         dict(help=help, labelnames=labelnames))

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get(Gauge, name, dict(help=help, labelnames=labelnames))

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_MS,
                  labelnames: Sequence[str] = ()) -> Histogram:
        return self._get(Histogram, name,
                         dict(help=help, buckets=buckets,
                              labelnames=labelnames))

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str):
        return self._metrics[name]

    # ------------------------------------------------------------ exposition
    def to_prometheus(self) -> str:
        """Prometheus text exposition format (histograms cumulative)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for key, (counts, total, n) in m.collect():
                    cum = 0
                    for edge, c in zip(m.buckets, counts):
                        cum += c
                        lab = _fmt_labels(m.labelnames, key,
                                          extra=f'le="{edge:g}"')
                        lines.append(f"{name}_bucket{lab} {cum}")
                    cum += counts[-1]
                    lab = _fmt_labels(m.labelnames, key, extra='le="+Inf"')
                    lines.append(f"{name}_bucket{lab} {cum}")
                    lab = _fmt_labels(m.labelnames, key)
                    lines.append(f"{name}_sum{lab} {total:g}")
                    lines.append(f"{name}_count{lab} {n}")
            else:
                for key, v in m.collect():
                    lab = _fmt_labels(m.labelnames, key)
                    lines.append(f"{name}{lab} {v:g}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-native dump: metric name → {kind, series} (label tuples
        joined with ',' as keys; '' for the unlabelled series)."""
        out: dict = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                series = {
                    ",".join(k): dict(buckets=list(counts), sum=total,
                                      count=n)
                    for k, (counts, total, n) in m.collect()}
                out[name] = dict(kind=m.kind, labelnames=list(m.labelnames),
                                 bucket_edges_ms=list(m.buckets),
                                 series=series)
            else:
                series = {",".join(k): v for k, v in m.collect()}
                out[name] = dict(kind=m.kind, labelnames=list(m.labelnames),
                                 series=series)
        return out

    def write(self, path: str) -> None:
        """Write the registry to ``path``: JSON when it ends in .json,
        Prometheus text format otherwise."""
        if path.endswith(".json"):
            with open(path, "w") as f:
                json.dump(self.snapshot(), f, indent=2)
        else:
            with open(path, "w") as f:
                f.write(self.to_prometheus())
