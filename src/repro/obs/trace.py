"""Query flight recorder: hierarchical trace spans with explicit parents.

Every query the serving runtime touches leaves one span TREE:

    query                       root: template, shape, deadline, final status
    ├─ admit                    verdict (admit/degrade/reject), ladder rungs
    └─ dispatch-side children, one set per member of the dispatched group:
       ├─ plan                  split, impl, plan-cache hit, predicted
       │                        features·θ (the cost model's commitment)
       ├─ compile               executable-cache hit/miss + dispatch key
       └─ dispatch              group seq, batch size, EDF position,
          │                     predicted vs measured ms (query and group)
          └─ superstep (×hop)   per-hop predicted/measured share
             └─ exchange        per-channel structural boundary volumes
                                (state / extremum / etr — the same rule as
                                engine_partitioned.query_exchange_volumes)

Design constraints, in order:

  determinism   the clock is INJECTED (``Tracer(clock=...)``) and span ids
                are a plain counter, so under the FakeDispatcher virtual
                clock (serving/testing.py) plus a ``StepClock`` the exact
                span tree — ids, parents, timestamps, attrs — is a pinnable
                test vector, not a flaky wall-clock artifact;
  zero-cost off the default is the module-level ``NULL_TRACER`` whose every
                operation is a constant no-op attribute lookup (the bench
                gate in scripts/check_bench.py holds the disabled path to
                ≤1% dispatch overhead);
  append-only   completed spans go to a bounded in-memory ring (newest kept)
                and, when a ``sink`` path is given, one JSON line each —
                floats serialise via repr round-trip, so an offline audit
                (obs/audit.py) recomputes EXACTLY what the live telemetry
                saw.
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import numpy as np


def _json_default(o):
    """Numpy-to-JSON bridge: scalars to Python numbers, arrays to lists."""
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, (np.bool_,)):
        return bool(o)
    raise TypeError(f"not JSON serialisable: {type(o).__name__}")


def _clean(attrs: Dict[str, Any]) -> Dict[str, Any]:
    """Normalise attr values to JSON-native types at record time, so the
    ring and the JSONL sink hold the SAME values (ndarray → list, numpy
    scalar → Python scalar) and audit-from-ring == audit-from-file."""
    out = {}
    for k, v in attrs.items():
        if isinstance(v, np.ndarray):
            out[k] = v.tolist()
        elif isinstance(v, np.integer):
            out[k] = int(v)
        elif isinstance(v, np.floating):
            out[k] = float(v)
        elif isinstance(v, np.bool_):
            out[k] = bool(v)
        else:
            out[k] = v
    return out


@dataclasses.dataclass
class Span:
    """One node of a trace tree.  Mutable until ``Tracer.end`` seals it."""
    name: str
    span_id: int
    parent_id: Optional[int]
    trace_id: int
    t_start: float
    t_end: Optional[float] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def as_record(self) -> dict:
        return dict(name=self.name, span_id=self.span_id,
                    parent_id=self.parent_id, trace_id=self.trace_id,
                    t_start=self.t_start, t_end=self.t_end, attrs=self.attrs)


class _NullSpan:
    """The no-op span handed out by NullTracer: accepts everything."""
    __slots__ = ()
    name = ""
    span_id = -1
    parent_id = None
    trace_id = -1
    attrs: Dict[str, Any] = {}


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled-path tracer: every call is a constant-time no-op.

    ``enabled`` is False so instrumentation sites can skip building attr
    payloads entirely (``if tracer.enabled: ...``) — the overhead the bench
    gate pins is the residual start/end call cost when a site does not
    guard."""
    enabled = False

    def start(self, name: str, parent=None, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def end(self, span, **attrs) -> None:
        return None

    def annotate(self, span, **attrs) -> None:
        return None

    def records(self) -> List[dict]:
        return []

    def export_jsonl(self, path: str) -> int:
        return 0

    def close(self) -> None:
        return None


#: the module-level default: share one instance so the disabled check is an
#: attribute lookup on a singleton, never an allocation
NULL_TRACER = NullTracer()


class Tracer:
    """Recording tracer: explicit-parent spans → ring buffer (+ JSONL sink).

    ``clock`` is any zero-arg callable returning seconds; tests inject a
    ``StepClock`` so t_start/t_end are exact.  ``sink`` (a path) appends one
    JSON line per COMPLETED span, in completion order — a crashed run keeps
    every span that finished.
    """
    enabled = True

    def __init__(self, clock=time.perf_counter, capacity: int = 65536,
                 sink: Optional[str] = None):
        self._clock = clock
        self._ring: Deque[dict] = deque(maxlen=capacity)
        self._next_id = 0
        self._sink_path = sink
        self._sink = open(sink, "w") if sink else None
        self.n_started = 0
        self.n_completed = 0

    # ---------------------------------------------------------------- spans
    def start(self, name: str, parent=None, **attrs) -> Span:
        sid = self._next_id
        self._next_id += 1
        if parent is None or parent is _NULL_SPAN:
            parent_id, trace_id = None, sid
        else:
            parent_id, trace_id = parent.span_id, parent.trace_id
        self.n_started += 1
        return Span(name, sid, parent_id, trace_id, self._clock(),
                    attrs=_clean(attrs))

    def annotate(self, span, **attrs) -> None:
        if span is _NULL_SPAN:
            return
        span.attrs.update(_clean(attrs))

    def end(self, span, **attrs) -> None:
        if span is _NULL_SPAN or not isinstance(span, Span):
            return
        if attrs:
            span.attrs.update(_clean(attrs))
        span.t_end = self._clock()
        rec = span.as_record()
        self._ring.append(rec)
        self.n_completed += 1
        if self._sink is not None:
            self._sink.write(json.dumps(rec, default=_json_default) + "\n")

    # ------------------------------------------------------------- querying
    def records(self) -> List[dict]:
        """Completed spans (completion order), newest ``capacity`` kept."""
        return list(self._ring)

    def export_jsonl(self, path: str) -> int:
        """Write the ring to ``path`` (one span per line); returns count."""
        recs = self.records()
        with open(path, "w") as f:
            for rec in recs:
                f.write(json.dumps(rec, default=_json_default) + "\n")
        return len(recs)

    def close(self) -> None:
        if self._sink is not None:
            self._sink.flush()
            self._sink.close()
            self._sink = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class StepClock:
    """Deterministic clock for span tests: each call returns start, then
    advances by ``step`` — two consecutive reads differ by exactly one step,
    so measured-duration assertions are equalities, not tolerances."""

    def __init__(self, start: float = 0.0, step: float = 1.0):
        self.t = float(start)
        self.step = float(step)

    def __call__(self) -> float:
        t, self.t = self.t, self.t + self.step
        return t


# ---------------------------------------------------------------- tree utils
def load_jsonl(path: str) -> List[dict]:
    """Read a trace JSONL sink back into span records."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def span_trees(records: List[dict]) -> Dict[int, dict]:
    """Group span records into trees: trace_id → root record, with a
    ``children`` list (start order) attached to every record."""
    by_id: Dict[int, dict] = {}
    for rec in records:
        rec = dict(rec)
        rec["children"] = []
        by_id[rec["span_id"]] = rec
    roots: Dict[int, dict] = {}
    for rec in by_id.values():
        pid = rec["parent_id"]
        if pid is not None and pid in by_id:
            by_id[pid]["children"].append(rec)
        else:
            roots[rec["trace_id"]] = rec
    for rec in by_id.values():
        rec["children"].sort(key=lambda r: r["span_id"])
    return roots
