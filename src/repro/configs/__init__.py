"""Architecture configs (assigned pool + the paper's own engine).

Each module exposes ``get_arch() -> common.ArchSpec`` with the exact
published configuration, per-shape ``input_specs`` (ShapeDtypeStructs — no
allocation), sharding rules, and a reduced smoke config.
"""
ARCH_IDS = (
    "llama3-405b", "minicpm-2b", "gemma3-4b", "olmoe-1b-7b", "mixtral-8x22b",
    "pna", "egnn", "meshgraphnet", "schnet",
    "dlrm-rm2",
    "granite-ldbc",
)


def load_arch(arch_id: str):
    import importlib

    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_')}"
    )
    return mod.get_arch()
