"""Llama-3 405B — dense GQA transformer [arXiv:2407.21783].

126L, d_model 16384, 128 heads (GQA kv=8), d_ff 53248, vocab 128256.
Pure full attention → long_500k skipped (DESIGN.md §4).
"""
import dataclasses
from functools import partial

import jax.numpy as jnp

from ..models import transformer as tr
from ..training.optimizer import OptCfg
from . import common

CONFIG = tr.TransformerCfg(
    name="llama3-405b",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_head=128,
    d_ff=53248, vocab=128256, rope_theta=500000.0, dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=160, vocab=512, dtype=jnp.float32, data_axes=None, model_axis=None,
)


def get_arch() -> common.ArchSpec:
    shapes = {
        name: partial(common.lm_cell, CONFIG, name)
        for name in ("train_4k", "prefill_32k", "decode_32k")
    }
    return common.ArchSpec(
        arch_id="llama3-405b", family="lm-dense", shapes=shapes,
        skip={"long_500k": "pure full attention (assignment rule)"},
        smoke=lambda: common.lm_smoke(SMOKE),
        meta=dict(params=CONFIG.param_count(), opt=OptCfg(schedule="cosine")),
    )
