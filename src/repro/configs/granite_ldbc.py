"""Granite query engine as a first-class dry-run architecture.

Cells lower the *paper's own technique* — distributed temporal path query
supersteps — at the paper's largest-graph scale (100k:F ≈ 52M vertices,
218M edges, Table 4) on the production mesh.  Traversal/ETR arrays are
edge-sharded over every mesh axis; the per-superstep frontier exchange and
the ETR prefix scans become the collectives the roofline reads.
"""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core import engine as E
from ..core import intervals as iv
from ..core import query as Q
from . import common

import os


def _pad512(n: int) -> int:
    """Dry-run arrays are padded to a 512-device multiple (padding vertices/
    edges carry empty lifespans and never match — exact semantics)."""
    return -(-n // 512) * 512


# 100k:F-S scale (paper Table 4); GRANITE_DRYRUN_SCALE=small for CI traces
if os.environ.get("GRANITE_DRYRUN_SCALE") == "small":
    V_FULL, E_FULL = _pad512(100_000), _pad512(400_000)
else:
    V_FULL = _pad512(52_000_000)
    E_FULL = _pad512(218_000_000)
N_VTYPES = 4
T_LIFE = 1096

_K_TAG, _K_COUNTRY = 0, 1


def _gdev_sds(V: int, E2: int, n_buckets: int):
    s = common.sds
    return dict(
        v_type=s((V,), jnp.int32),
        v_life=s((V, 2), jnp.int32),
        t_src=s((E2,), jnp.int32),
        t_dst=s((E2,), jnp.int32),
        t_life=s((E2, 2), jnp.int32),
        t_type=s((E2,), jnp.int32),
        t_isfwd=s((E2,), jnp.int32),
        arr_ptr=s((V + 1,), jnp.int32),
        type_ranges=s((N_VTYPES, 2), jnp.int32),
        etr_perm_start=s((E2,), jnp.int32),
        etr_perm_end=s((E2,), jnp.int32),
        etr_dep_ranks=s((4, E2), jnp.int32),
        etr_arr_ranks=s((4, E2), jnp.int32),
        vprops={
            _K_TAG: (s((V, 1), jnp.int32), s((V, 1, 2), jnp.int32)),
            _K_COUNTRY: (s((V, 1), jnp.int32), s((V, 1, 2), jnp.int32)),
        },
        eprops_t={},
    )


def _gdev_shardings(mesh, V: int, E2: int):
    a = tuple(mesh.axis_names)
    n = common.named
    return dict(
        v_type=n(mesh, P(a)),
        v_life=n(mesh, P(a, None)),
        t_src=n(mesh, P(a)),
        t_dst=n(mesh, P(a)),
        t_life=n(mesh, P(a, None)),
        t_type=n(mesh, P(a)),
        t_isfwd=n(mesh, P(a)),
        arr_ptr=n(mesh, P(None)),          # offsets replicated (see DESIGN §5)
        type_ranges=n(mesh, P(None, None)),
        etr_perm_start=n(mesh, P(a)),
        etr_perm_end=n(mesh, P(a)),
        etr_dep_ranks=n(mesh, P(None, a)),
        etr_arr_ranks=n(mesh, P(None, a)),
        vprops={
            _K_TAG: (n(mesh, P(a, None)), n(mesh, P(a, None, None))),
            _K_COUNTRY: (n(mesh, P(a, None)), n(mesh, P(a, None, None))),
        },
        eprops_t={},
    )


def _query_3hop_etr() -> Q.PathQuery:
    """Q1-shaped: Post(tag) ← Forum → Post(tag, ETR ≺) ← Person(country)."""
    return Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(1, (Q.prop_clause(_K_TAG, "in", 7),)),
            Q.VertexPredicate(3, (Q.time_clause("overlaps", (100, T_LIFE)),)),
            Q.VertexPredicate(1, (Q.prop_clause(_K_TAG, "in", 9),)),
            Q.VertexPredicate(0, (Q.prop_clause(_K_COUNTRY, "==", 2),)),
        ),
        e_preds=(
            Q.EdgePredicate(4, Q.DIR_IN),
            Q.EdgePredicate(4, Q.DIR_OUT, etr_op=iv.STARTS_BEFORE),
            Q.EdgePredicate(3, Q.DIR_IN),
        ),
    )


def _query_2hop_agg() -> Q.PathQuery:
    return Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(0, (Q.prop_clause(_K_COUNTRY, "==", 2),)),
            Q.VertexPredicate(0),
            Q.VertexPredicate(0, (Q.prop_clause(_K_TAG, "in", 5),)),
        ),
        e_preds=(
            Q.EdgePredicate(0, Q.DIR_OUT),
            Q.EdgePredicate(0, Q.DIR_OUT, etr_op=iv.FULLY_BEFORE),
        ),
        agg_op=Q.AGG_COUNT,
    )


SHAPES = dict(
    q3hop_etr=dict(split=1, mode=E.MODE_STATIC, qf=_query_3hop_etr, agg=False),
    q3hop_rtl=dict(split=0, mode=E.MODE_STATIC, qf=_query_3hop_etr, agg=False),
    agg_2hop=dict(split=0, mode=E.MODE_STATIC, qf=_query_2hop_agg, agg=True),
    warp_2hop=dict(split=0, mode=E.MODE_BUCKET, qf=_query_2hop_agg, agg=True),
)


def analytic_flops(shape_name: str, n_vertices=None, n_edges=None,
                   n_buckets: int = 16) -> float:
    """Analytic global FLOP count for a query execution.

    Per hop: predicate eval + weight mask ≈ 60 flops/traversal-edge, delivery
    segment-sum ≈ 1, ETR hops add 2 log-depth prefix scans (~2·log2(2E)) and
    4 gathers; bucket mode multiplies edge work by B.  XLA's CPU cost model
    cannot be used for these cells (cumsum → reduce-window counted
    quadratically), see EXPERIMENTS.md §Roofline.
    """
    V = n_vertices or V_FULL
    e2 = 2.0 * (n_edges or E_FULL)
    info = SHAPES[shape_name]
    n_hops = len(info["qf"]().e_preds)
    has_etr = any(p.etr_op != -1 for p in info["qf"]().e_preds)
    per_edge = 60.0
    if has_etr:
        per_edge += 2 * np.log2(e2) + 8
    bucket_mult = n_buckets if info["mode"] == E.MODE_BUCKET else 1
    return n_hops * e2 * per_edge * bucket_mult + 4.0 * V * n_hops


def _cell(shape_name: str, mesh) -> common.ShapeCell:
    info = SHAPES[shape_name]
    qry = info["qf"]()
    split, mode = info["split"], info["mode"]
    n_buckets = 16
    V, E2 = V_FULL, 2 * E_FULL
    gdev_sds = _gdev_sds(V, E2, n_buckets)
    gdev_sh = _gdev_shardings(mesh, V, E2)
    params_sds = common.sds(Q.query_params(qry).shape, jnp.int32)
    bedges_sds = common.sds((n_buckets + 1,), jnp.int32)
    a = tuple(mesh.axis_names)

    def run(gdev, params, bedges):
        out = E.execute_plan_traced(gdev, qry, split, mode, n_buckets, params,
                                    bedges)
        if info["agg"]:
            return out.total, out.per_vertex
        return out.total

    if info["agg"]:
        pv_spec = P(a) if mode == E.MODE_STATIC else P(a, None)
        out_sh = (common.named(mesh, P()), common.named(mesh, pv_spec))
    else:
        out_sh = common.named(mesh, P() if mode == E.MODE_STATIC else P(None))
    return common.ShapeCell(
        run, (gdev_sds, params_sds, bedges_sds),
        (gdev_sh, common.named(mesh, P(None, None)), common.named(mesh, P(None))),
        out_sh, "query", note=f"split={split} mode={mode}",
        analytic_flops=analytic_flops(shape_name),
    )


def _smoke() -> dict:
    from ..core.ref_engine import RefEngine
    from ..graphdata.ldbc import LdbcParams, generate_ldbc
    from ..graphdata.queries import make_workload

    g = generate_ldbc(LdbcParams(n_persons=50, seed=11))
    wl = make_workload(g, templates=("Q2", "Q4"), n_per_template=1, seed=3)
    ref = RefEngine(g)
    ok = True
    for inst in wl:
        want = ref.count(inst.qry, mode=E.MODE_STATIC)
        got = E.count_results(g, inst.qry, mode=E.MODE_STATIC)
        ok &= got == want
    return dict(ok=bool(ok))


def get_arch() -> common.ArchSpec:
    shapes = {name: partial(_cell, name) for name in SHAPES}
    return common.ArchSpec(
        arch_id="granite-ldbc", family="graph-query", shapes=shapes, skip={},
        smoke=_smoke,
        meta=dict(V=V_FULL, E=E_FULL, note="paper 100k:F scale"),
    )
