"""PNA — Principal Neighbourhood Aggregation [arXiv:2004.05718].

4 layers, d_hidden 75, aggregators mean/max/min/std, scalers id/amp/atten.
"""
from functools import partial

from ..models.gnn import PNACfg
from . import common

CONFIG = PNACfg()


def get_arch() -> common.ArchSpec:
    shapes = {
        name: partial(common.gnn_cell, "pna", CONFIG, name)
        for name in common.GNN_SHAPES
    }
    return common.ArchSpec(
        arch_id="pna", family="gnn-spmm", shapes=shapes, skip={},
        smoke=lambda: common.gnn_smoke("pna", CONFIG), meta={},
    )
