"""DLRM RM2 [arXiv:1906.00091] — 13 dense + 26 sparse features, embed 64,
bot MLP 13-512-256-64, top MLP 512-512-256-1, dot interaction.

Shapes: train 65536 / serve_p99 512 / serve_bulk 262144 / retrieval 1×1M.
"""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models import dlrm as dm
from ..training.optimizer import OptCfg, init_state
from . import common

CONFIG = dm.DLRMCfg()
SMOKE = dataclasses.replace(CONFIG, vocab_sizes=[512] * 26,
                            data_axes=None, model_axis=None)

SHAPES = dict(
    train_batch=dict(batch=65536, kind="train"),
    serve_p99=dict(batch=512, kind="serve"),
    serve_bulk=dict(batch=262144, kind="serve"),
    retrieval_cand=dict(batch=1, n_candidates=1_000_000, kind="retrieval"),
)


def _cell(shape_name: str, mesh) -> common.ShapeCell:
    info = SHAPES[shape_name]
    cfg = dataclasses.replace(CONFIG, data_axes=common.data_axes_of(mesh),
                              model_axis="model")
    dp = cfg.data_axes
    pspecs = dm.param_specs(cfg, mesh)
    params_sds = jax.eval_shape(lambda k: dm.init_params(cfg, k),
                                jax.random.PRNGKey(0))
    params_sh = common.tree_named(mesh, pspecs)
    B = info["batch"]
    dense_sds = common.sds((B, cfg.n_dense), jnp.float32)
    sparse_sds = common.sds((B, cfg.n_sparse, cfg.multi_hot), jnp.int32)
    bspec = P(dp) if B > 1 else P()

    if info["kind"] == "train":
        opt_cfg = OptCfg(lr=1e-3, weight_decay=0.0)
        opt_sds = jax.eval_shape(init_state, params_sds)
        opt_specs = dict(mu=pspecs, nu=pspecs, step=P())
        opt_sh = common.tree_named(mesh, opt_specs)
        label_sds = common.sds((B,), jnp.float32)

        def step(params, opt_state, batch):
            from ..training.optimizer import apply_updates
            loss, grads = jax.value_and_grad(
                lambda p: dm.loss_fn(cfg, p, batch))(params)
            new_p, new_s, m = apply_updates(opt_cfg, params, grads, opt_state)
            return new_p, new_s, dict(loss=loss, **m)

        batch_sds = dict(dense=dense_sds, sparse=sparse_sds, label=label_sds)
        batch_sh = common.tree_named(
            mesh, dict(dense=P(dp, None), sparse=P(dp, None, None), label=P(dp)))
        out_sh = (params_sh, opt_sh,
                  dict(loss=common.named(mesh, P()), lr=common.named(mesh, P()),
                       grad_norm=common.named(mesh, P())))
        return common.ShapeCell(step, (params_sds, opt_sds, batch_sds),
                                (params_sh, opt_sh, batch_sh), out_sh, "train")

    if info["kind"] == "serve":
        def serve(params, dense, sparse):
            return dm.serve_score(cfg, params, dense, sparse)

        in_sh = (params_sh, common.named(mesh, P(dp, None)),
                 common.named(mesh, P(dp, None, None)))
        return common.ShapeCell(serve, (params_sds, dense_sds, sparse_sds),
                                in_sh, common.named(mesh, bspec), "serve")

    # retrieval: 1 query vs 1M candidate embeddings (padded to 512 multiple)
    N = -(-info["n_candidates"] // 512) * 512
    cand_sds = common.sds((N, cfg.embed_dim), jnp.float32)
    all_ax = tuple(mesh.axis_names)

    def retrieve(params, dense, sparse, cand):
        return dm.retrieval_score(cfg, params, dense, sparse, cand, top_k=128)

    in_sh = (params_sh, common.named(mesh, P(None, None)),
             common.named(mesh, P(None, None, None)),
             common.named(mesh, P(all_ax, None)))
    out_sh = (common.named(mesh, P()), common.named(mesh, P()))
    return common.ShapeCell(retrieve,
                            (params_sds, common.sds((1, cfg.n_dense), jnp.float32),
                             common.sds((1, cfg.n_sparse, cfg.multi_hot), jnp.int32),
                             cand_sds),
                            in_sh, out_sh, "serve", note="retrieval top-k")


def _smoke() -> dict:
    rng = np.random.default_rng(0)
    p = dm.init_params(SMOKE, jax.random.PRNGKey(0))
    B = 16
    batch = dict(
        dense=jnp.asarray(rng.normal(size=(B, 13)), jnp.float32),
        sparse=jnp.asarray(rng.integers(0, 512, (B, 26, 1)), jnp.int32),
        label=jnp.asarray(rng.integers(0, 2, B), jnp.float32),
    )
    loss = dm.loss_fn(SMOKE, p, batch)
    return dict(ok=bool(jnp.isfinite(loss)), loss=float(loss))


def get_arch() -> common.ArchSpec:
    shapes = {name: partial(_cell, name) for name in SHAPES}
    return common.ArchSpec(
        arch_id="dlrm-rm2", family="recsys", shapes=shapes, skip={},
        smoke=_smoke, meta=dict(params=CONFIG.param_count()),
    )
