"""MeshGraphNet [arXiv:2010.03409]. 15 layers, d_hidden 128, sum agg, 2-layer MLPs."""
from functools import partial

from ..models.gnn import MGNCfg
from . import common

CONFIG = MGNCfg()


def get_arch() -> common.ArchSpec:
    shapes = {
        name: partial(common.gnn_cell, "meshgraphnet", CONFIG, name)
        for name in common.GNN_SHAPES
    }
    return common.ArchSpec(
        arch_id="meshgraphnet", family="gnn-mpnn", shapes=shapes, skip={},
        smoke=lambda: common.gnn_smoke("meshgraphnet", CONFIG), meta={},
    )
