"""Shared dry-run/step machinery for all architectures.

An ArchSpec describes, per input shape:
  * the step function to lower (train_step for training shapes, decode/
    prefill/serve for inference shapes),
  * ShapeDtypeStruct argument trees (never allocated),
  * in/out shardings on the production mesh,
plus a smoke() callable that runs a reduced config end-to-end on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models import dlrm as dlrm_mod
from ..models import gnn as gnn_mod
from ..models import transformer as tr
from ..training.optimizer import OptCfg, init_state


@dataclasses.dataclass
class ShapeCell:
    """One (arch × shape) dry-run cell."""
    fn: Callable                      # traced step function
    args: Tuple                       # ShapeDtypeStruct pytrees
    in_shardings: Tuple
    out_shardings: Any
    kind: str                         # 'train' | 'prefill' | 'decode' | 'serve'
    note: str = ""
    analytic_flops: Optional[float] = None   # global, for HLO-cost-model fixes


@dataclasses.dataclass
class ArchSpec:
    arch_id: str
    family: str
    shapes: Dict[str, Callable]       # shape name → (mesh) → ShapeCell
    skip: Dict[str, str]              # shape name → reason
    smoke: Callable[[], dict]         # reduced-config CPU check
    meta: Dict[str, Any]


def data_axes_of(mesh) -> tuple:
    return tuple(n for n in mesh.axis_names if n != "model")


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def named(mesh, spec):
    return NamedSharding(mesh, spec)


def tree_named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: named(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ===================================================================== LM
LM_SHAPES = dict(
    train_4k=dict(seq=4096, batch=256, kind="train"),
    prefill_32k=dict(seq=32768, batch=32, kind="prefill"),
    decode_32k=dict(seq=32768, batch=128, kind="decode"),
    long_500k=dict(seq=524288, batch=1, kind="decode"),
)


def lm_cfg_for_mesh(cfg: tr.TransformerCfg, mesh) -> tr.TransformerCfg:
    return dataclasses.replace(cfg, data_axes=data_axes_of(mesh), model_axis="model")


def lm_cell(cfg0: tr.TransformerCfg, shape_name: str, mesh,
            opt_cfg: Optional[OptCfg] = None) -> ShapeCell:
    info = LM_SHAPES[shape_name]
    cfg = lm_cfg_for_mesh(cfg0, mesh)
    dp = cfg.data_axes
    B, S = info["batch"], info["seq"]
    pspecs = tr.param_specs(cfg, mesh)
    params_sds = tr.init_shapes(cfg)
    params_sh = tree_named(mesh, pspecs)

    if info["kind"] == "train":
        opt_cfg = opt_cfg or OptCfg()
        opt_sds = jax.eval_shape(init_state, params_sds)
        opt_specs = dict(
            mu=pspecs, nu=pspecs, step=P()
        )
        opt_sh = tree_named(mesh, opt_specs)
        batch_sds = dict(tokens=sds((B, S), jnp.int32), labels=sds((B, S), jnp.int32))
        bspec = dict(tokens=P(dp, None), labels=P(dp, None))
        batch_sh = tree_named(mesh, bspec)

        def train_step(params, opt_state, batch):
            from ..training.optimizer import apply_updates
            loss, grads = jax.value_and_grad(
                lambda p: tr.loss_fn(cfg, p, batch))(params)
            new_p, new_s, metrics = apply_updates(opt_cfg, params, grads, opt_state)
            return new_p, new_s, dict(loss=loss, **metrics)

        out_sh = (params_sh, opt_sh,
                  dict(loss=named(mesh, P()), lr=named(mesh, P()),
                       grad_norm=named(mesh, P())))
        return ShapeCell(train_step, (params_sds, opt_sds, batch_sds),
                         (params_sh, opt_sh, batch_sh), out_sh, "train")

    if info["kind"] == "prefill":
        tokens_sds = sds((B, S), jnp.int32)
        tok_sh = named(mesh, P(dp, None))
        cspec = tr.cache_specs(cfg, mesh)
        cache_sh = (named(mesh, cspec), named(mesh, cspec))

        def prefill_step(params, tokens):
            return tr.prefill(cfg, params, tokens, max_len=S)

        vocab_ax = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
        out_sh = (named(mesh, P(dp, vocab_ax)), cache_sh)
        return ShapeCell(prefill_step, (params_sds, tokens_sds),
                         (params_sh, tok_sh), out_sh, "prefill")

    # decode: one new token against a seq-length cache
    cshape = (cfg.n_layers, B, cfg.n_kv_heads, S, cfg.d_head)
    cspec = tr.cache_specs(cfg, mesh) if B > 1 else _cache_spec_b1(cfg, mesh)
    if cfg.kv_cache_quant:
        sspec = P(*cspec[:-1])                      # scales drop the Dh dim
        cache_sds = (sds(cshape, jnp.int8), sds(cshape, jnp.int8),
                     sds(cshape[:-1], jnp.bfloat16),
                     sds(cshape[:-1], jnp.bfloat16))
        cache_sh = (named(mesh, cspec), named(mesh, cspec),
                    named(mesh, sspec), named(mesh, sspec))
    else:
        cache_sds = (sds(cshape, cfg.dtype), sds(cshape, cfg.dtype))
        cache_sh = (named(mesh, cspec), named(mesh, cspec))
    tok_sds = sds((B,), jnp.int32)
    tok_sh = named(mesh, P(dp) if B > 1 else P())
    len_sds = sds((), jnp.int32)

    def decode(params, cache, tokens, cache_len):
        return tr.decode_step(cfg, params, cache, tokens, cache_len)

    out_sh = (named(mesh, P(dp, None) if B > 1 else P(None, None)), cache_sh)
    return ShapeCell(decode, (params_sds, cache_sds, tok_sds, len_sds),
                     (params_sh, cache_sh, tok_sh, named(mesh, P())),
                     out_sh, "decode")


def _cache_spec_b1(cfg, mesh) -> P:
    # batch-1 long-context decode: shard the sequence axis of the cache over
    # the data axes (flash-decode style length parallelism is realised by
    # XLA's sharded softmax-sum reductions), heads/d_head over model.
    tp = "model"
    if cfg.n_kv_heads % mesh.shape[tp] == 0:
        return P(None, None, tp, data_axes_of(mesh), None)
    return P(None, None, None, data_axes_of(mesh), tp)


# ===================================================================== GNN
def pad512(n: int) -> int:
    """Sharded dry-run dims are padded to the 512-device multiple; padding
    nodes/edges are masked (degree 0 / self-loop) so semantics are exact."""
    return -(-n // 512) * 512


GNN_SHAPES = dict(
    full_graph_sm=dict(n_nodes=2708, n_edges=10556, d_feat=1433, kind="train"),
    minibatch_lg=dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                      fanout=(15, 10), d_feat=602, kind="train_sampled"),
    ogb_products=dict(n_nodes=2449029, n_edges=61859140, d_feat=100, kind="train"),
    molecule=dict(n_nodes=30, n_edges=64, batch=128, d_feat=16, kind="train"),
)


def gnn_cell(arch: str, cfg, shape_name: str, mesh) -> ShapeCell:
    info = GNN_SHAPES[shape_name]
    dp = data_axes_of(mesh)
    all_ax = tuple(mesh.axis_names)
    opt_cfg = OptCfg(lr=1e-3)

    if info["kind"] == "train_sampled":
        N, E = pad512(info["n_nodes"]), pad512(info["n_edges"])
        seeds = info["batch_nodes"]
        f1, f2 = info["fanout"]
        n1, n2 = seeds * f1, seeds * f1 * f2
        n_sub = seeds + n1 + n2
        e_sub = n1 + n2
        feat_sds = sds((N, info["d_feat"]), jnp.float32)
        csr_sds = dict(indptr=sds((N + 1,), jnp.int32), indices=sds((E,), jnp.int32))
        seeds_sds = sds((seeds,), jnp.int32)
        key_sds = sds((2,), jnp.uint32)

        params = gnn_mod.INIT[arch](cfg, jax.random.PRNGKey(0), info["d_feat"])
        params_sds = jax.tree_util.tree_map(
            lambda x: sds(x.shape, x.dtype), params)
        opt_sds = jax.eval_shape(init_state, params_sds)
        params_sh = jax.tree_util.tree_map(lambda _: named(mesh, P()), params_sds)
        opt_sh = jax.tree_util.tree_map(lambda _: named(mesh, P()), opt_sds)

        def step(params, opt_state, feats, csr, seed_ids, key):
            from ..graphdata.sampler import CSR, sample_union_graph
            from ..training.optimizer import apply_updates
            gids, src_l, dst_l = sample_union_graph(
                CSR(csr["indptr"], csr["indices"]), seed_ids, (f1, f2),
                jax.random.wrap_key_data(key, impl="threefry2x32"),
            )
            gathered = feats[gids]
            g = gnn_mod.GraphBatch(
                node_feat=gathered,
                edge_src=src_l,
                edge_dst=dst_l,
                coords=gathered[:, :3],
                targets=None,
            )
            loss, grads = jax.value_and_grad(
                lambda p: gnn_mod.gnn_loss(arch, cfg, p, g))(params)
            new_p, new_s, m = apply_updates(opt_cfg, params, grads, opt_state)
            return new_p, new_s, loss

        in_sh = (params_sh, opt_sh,
                 named(mesh, P(all_ax, None)),
                 dict(indptr=named(mesh, P(None)),
                      indices=named(mesh, P(all_ax))),
                 named(mesh, P()), named(mesh, P()))
        out_sh = (params_sh, opt_sh, named(mesh, P()))
        return ShapeCell(step, (params_sds, opt_sds, feat_sds, csr_sds,
                                seeds_sds, key_sds),
                         in_sh, out_sh, "train", note="sampler+train fused")

    # full-batch (or flattened molecule batch)
    if shape_name == "molecule":
        N = pad512(info["n_nodes"] * info["batch"])
        E = pad512(info["n_edges"] * info["batch"])
        n_graphs = info["batch"]
    else:
        N, E = pad512(info["n_nodes"]), pad512(info["n_edges"])
        n_graphs = 1
    F = info["d_feat"]
    params = gnn_mod.INIT[arch](cfg, jax.random.PRNGKey(0), F)
    params_sds = jax.tree_util.tree_map(lambda x: sds(x.shape, x.dtype), params)
    opt_sds = jax.eval_shape(init_state, params_sds)
    params_sh = jax.tree_util.tree_map(lambda _: named(mesh, P()), params_sds)
    opt_sh = jax.tree_util.tree_map(lambda _: named(mesh, P()), opt_sds)
    g_sds = dict(
        node_feat=sds((N, F), jnp.float32),
        edge_src=sds((E,), jnp.int32),
        edge_dst=sds((E,), jnp.int32),
        coords=sds((N, 3), jnp.float32),
        graph_of=sds((N,), jnp.int32),
        targets=sds((N, 1), jnp.float32),
    )
    g_sh = dict(
        node_feat=named(mesh, P(all_ax, None)),
        edge_src=named(mesh, P(all_ax)),
        edge_dst=named(mesh, P(all_ax)),
        coords=named(mesh, P(all_ax, None)),
        graph_of=named(mesh, P(all_ax)),
        targets=named(mesh, P(all_ax, None)),
    )

    def step(params, opt_state, gb):
        from ..training.optimizer import apply_updates
        g = gnn_mod.GraphBatch(
            node_feat=gb["node_feat"], edge_src=gb["edge_src"],
            edge_dst=gb["edge_dst"], coords=gb["coords"],
            graph_of=gb["graph_of"], n_graphs=n_graphs, targets=gb["targets"],
        )
        loss, grads = jax.value_and_grad(
            lambda p: gnn_mod.gnn_loss(arch, cfg, p, g))(params)
        new_p, new_s, m = apply_updates(opt_cfg, params, grads, opt_state)
        return new_p, new_s, loss

    out_sh = (params_sh, opt_sh, named(mesh, P()))
    return ShapeCell(step, (params_sds, opt_sds, g_sds),
                     (params_sh, opt_sh, g_sh), out_sh, "train")


# ---------------------------------------------------------------- smoke kits
def lm_smoke(cfg_small: tr.TransformerCfg, moe: bool = False) -> dict:
    p = tr.init_params(cfg_small, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg_small.vocab)
    logits = tr.forward(cfg_small, p, toks)
    loss = tr.loss_fn(cfg_small, p, {"tokens": toks, "labels": toks})
    cache = tr.init_cache(cfg_small, 2, 32)
    lg, cache = tr.decode_step(cfg_small, p, cache, toks[:, 0], 1)
    ok = bool(jnp.isfinite(logits).all() and jnp.isfinite(loss) and
              jnp.isfinite(lg).all())
    return dict(ok=ok, loss=float(loss), logits_shape=tuple(logits.shape))


def gnn_smoke(arch: str, cfg) -> dict:
    rng = np.random.default_rng(0)
    N, E, F = 40, 120, 8
    g = gnn_mod.GraphBatch(
        node_feat=jnp.asarray(rng.normal(size=(N, F)), jnp.float32),
        edge_src=jnp.asarray(rng.integers(0, N, E), jnp.int32),
        edge_dst=jnp.asarray(rng.integers(0, N, E), jnp.int32),
        coords=jnp.asarray(rng.normal(size=(N, 3)), jnp.float32),
        graph_of=jnp.asarray(rng.integers(0, 4, N), jnp.int32), n_graphs=4,
        targets=jnp.asarray(rng.normal(size=(N, 1)), jnp.float32),
    )
    params = gnn_mod.INIT[arch](cfg, jax.random.PRNGKey(0), F)
    loss = gnn_mod.gnn_loss(arch, cfg, params, g)
    return dict(ok=bool(jnp.isfinite(loss)), loss=float(loss))
