"""MiniCPM-2B — llama-like dense with the WSD schedule [arXiv:2404.06395; hf].

40L, d_model 2304, 36 heads (kv=36, i.e. MHA), d_ff 5760, vocab 122753.
"""
import dataclasses
from functools import partial

import jax.numpy as jnp

from ..models import transformer as tr
from ..training.optimizer import OptCfg
from . import common

CONFIG = tr.TransformerCfg(
    name="minicpm-2b",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_head=64,
    d_ff=5760, vocab=122753, rope_theta=10000.0, dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=72, n_heads=6, n_kv_heads=6, d_head=12,
    d_ff=180, vocab=512, dtype=jnp.float32, data_axes=None, model_axis=None,
)


def get_arch() -> common.ArchSpec:
    shapes = {
        name: partial(common.lm_cell, CONFIG, name,
                      opt_cfg=OptCfg(schedule="wsd", total_steps=10_000))
        for name in ("train_4k",)
    }
    shapes.update({
        name: partial(common.lm_cell, CONFIG, name)
        for name in ("prefill_32k", "decode_32k")
    })
    return common.ArchSpec(
        arch_id="minicpm-2b", family="lm-dense", shapes=shapes,
        skip={"long_500k": "pure full attention (assignment rule)"},
        smoke=lambda: common.lm_smoke(SMOKE),
        meta=dict(params=CONFIG.param_count(),
                  opt=OptCfg(schedule="wsd", total_steps=10_000)),
    )
