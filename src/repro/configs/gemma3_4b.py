"""Gemma-3 4B — 5:1 local:global attention, 262k vocab [hf:google/gemma-3].

34L, d_model 2560, 8 heads (kv=4), d_head 256, d_ff 10240.  Sliding window
1024 on local layers; every 6th layer is global.  long_500k RUNS (local
layers are sub-quadratic; decode against the long cache).
"""
import dataclasses
from functools import partial

import jax.numpy as jnp

from ..models import transformer as tr
from . import common

CONFIG = tr.TransformerCfg(
    name="gemma3-4b",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=10240, vocab=262144, rope_theta=1_000_000.0, dtype=jnp.bfloat16,
    sliding_window=1024, global_every=6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=512, dtype=jnp.float32, data_axes=None, model_axis=None,
    sliding_window=8, global_every=3,
)


def get_arch() -> common.ArchSpec:
    shapes = {
        name: partial(common.lm_cell, CONFIG, name)
        for name in ("train_4k", "prefill_32k", "decode_32k", "long_500k")
    }
    return common.ArchSpec(
        arch_id="gemma3-4b", family="lm-dense-swa", shapes=shapes, skip={},
        smoke=lambda: common.lm_smoke(SMOKE),
        meta=dict(params=CONFIG.param_count()),
    )
