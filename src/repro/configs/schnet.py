"""SchNet [arXiv:1706.08566]. 3 interactions, d_hidden 64, 300 RBF, cutoff 10."""
from functools import partial

from ..models.gnn import SchNetCfg
from . import common

CONFIG = SchNetCfg()


def get_arch() -> common.ArchSpec:
    shapes = {
        name: partial(common.gnn_cell, "schnet", CONFIG, name)
        for name in common.GNN_SHAPES
    }
    return common.ArchSpec(
        arch_id="schnet", family="gnn-molecular", shapes=shapes, skip={},
        smoke=lambda: common.gnn_smoke("schnet", CONFIG), meta={},
    )
