"""Mixtral-8x22B — 8-expert top-2 MoE with sliding-window attn [arXiv:2401.04088].

56L, d_model 6144, 48 heads (GQA kv=8), per-expert d_ff 16384, vocab 32768.
SWA → long_500k RUNS (rolling-window attention is sub-quadratic).
"""
import dataclasses
from functools import partial

import jax.numpy as jnp

from ..models import transformer as tr
from . import common

CONFIG = tr.TransformerCfg(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab=32768, rope_theta=1_000_000.0, dtype=jnp.bfloat16,
    moe=tr.MoECfg(n_experts=8, top_k=2, d_ff=16384),
    sliding_window=4096,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=96, vocab=512, dtype=jnp.float32, data_axes=None, model_axis=None,
    moe=tr.MoECfg(n_experts=4, top_k=2, d_ff=96), sliding_window=8,
)


def get_arch() -> common.ArchSpec:
    shapes = {
        name: partial(common.lm_cell, CONFIG, name)
        for name in ("train_4k", "prefill_32k", "decode_32k", "long_500k")
    }
    return common.ArchSpec(
        arch_id="mixtral-8x22b", family="lm-moe-swa", shapes=shapes, skip={},
        smoke=lambda: common.lm_smoke(SMOKE),
        meta=dict(params=CONFIG.param_count(),
                  active_params=CONFIG.active_param_count()),
    )
