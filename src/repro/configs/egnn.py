"""EGNN — E(n)-equivariant GNN [arXiv:2102.09844]. 4 layers, d_hidden 64."""
from functools import partial

from ..models.gnn import EGNNCfg
from . import common

CONFIG = EGNNCfg()


def get_arch() -> common.ArchSpec:
    shapes = {
        name: partial(common.gnn_cell, "egnn", CONFIG, name)
        for name in common.GNN_SHAPES
    }
    return common.ArchSpec(
        arch_id="egnn", family="gnn-equivariant", shapes=shapes, skip={},
        smoke=lambda: common.gnn_smoke("egnn", CONFIG), meta={},
    )
