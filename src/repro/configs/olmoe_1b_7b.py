"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060; hf].

16L, d_model 2048, 16 heads (kv=16), per-expert d_ff 1024, vocab 50304.
"""
import dataclasses
from functools import partial

import jax.numpy as jnp

from ..models import transformer as tr
from . import common

CONFIG = tr.TransformerCfg(
    name="olmoe-1b-7b",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1024, vocab=50304, rope_theta=10000.0, dtype=jnp.bfloat16,
    moe=tr.MoECfg(n_experts=64, top_k=8, d_ff=1024),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=64, vocab=512, dtype=jnp.float32, data_axes=None, model_axis=None,
    moe=tr.MoECfg(n_experts=8, top_k=2, d_ff=64),
)


def get_arch() -> common.ArchSpec:
    shapes = {
        name: partial(common.lm_cell, CONFIG, name)
        for name in ("train_4k", "prefill_32k", "decode_32k")
    }
    return common.ArchSpec(
        arch_id="olmoe-1b-7b", family="lm-moe", shapes=shapes,
        skip={"long_500k": "pure full attention (assignment rule)"},
        smoke=lambda: common.lm_smoke(SMOKE),
        meta=dict(params=CONFIG.param_count(),
                  active_params=CONFIG.active_param_count()),
    )
