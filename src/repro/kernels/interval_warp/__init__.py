from .ops import interval_warp  # noqa: F401
from .ref import interval_warp_ref  # noqa: F401
