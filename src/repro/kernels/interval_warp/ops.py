"""Wrapper: padding + implementation selection."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..common import resolve_interpret, use_pallas
from .interval_warp import interval_warp_pallas
from .ref import interval_warp_ref


def interval_warp(counts, ivl, bedges, impl: str = "xla",
                  block_n: int = 1024, interpret: Optional[bool] = None):
    if not use_pallas(impl):
        return interval_warp_ref(counts, ivl, bedges)
    N = counts.shape[0]
    pad = (-N) % block_n
    if pad:
        counts = jnp.pad(counts, ((0, pad), (0, 0)))
        ivl = jnp.pad(ivl, ((0, pad), (0, 0)))
    out = interval_warp_pallas(counts, ivl, bedges, block_n=block_n,
                               interpret=resolve_interpret(interpret, impl))
    return out[:N]
