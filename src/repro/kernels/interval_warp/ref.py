"""Oracle: TimeWarp bucket alignment = counts × interval-overlap mask."""
from __future__ import annotations

import jax.numpy as jnp


def interval_warp_ref(counts: jnp.ndarray, ivl: jnp.ndarray, bedges: jnp.ndarray):
    """counts [N, B] float, ivl [N, 2] int32, bedges [B+1] int32 → [N, B].

    Zeroes the count of every bucket the entity's validity interval does not
    overlap — the dense form of ICM's TimeWarp alignment.
    """
    lo = bedges[:-1][None, :]
    hi = bedges[1:][None, :]
    mask = (ivl[:, 0:1] < hi) & (lo < ivl[:, 1:2])
    return counts * mask.astype(counts.dtype)
