"""Fused TimeWarp alignment kernel.

Elementwise-heavy [N, B] op on the engine's hot path (dynamic modes run it
per hop per entity).  Fusing the mask computation with the multiply keeps the
bucket-state tile resident in VMEM and avoids materialising the bool mask in
HBM.  Tiled over N with B (≤ 32 buckets) kept whole in the lane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _warp_kernel(counts_ref, ivl_ref, bedges_ref, o_ref):
    counts = counts_ref[...]           # [bn, B]
    ivl = ivl_ref[...]                 # [bn, 2]
    bedges = bedges_ref[...]           # [1, B+1]
    lo = bedges[0, :-1][None, :]
    hi = bedges[0, 1:][None, :]
    mask = (ivl[:, 0:1] < hi) & (lo < ivl[:, 1:2])
    o_ref[...] = counts * mask.astype(counts.dtype)


def interval_warp_pallas(
    counts: jnp.ndarray,    # [N, B]
    ivl: jnp.ndarray,       # [N, 2]
    bedges: jnp.ndarray,    # [B+1]
    block_n: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    N, B = counts.shape
    assert N % block_n == 0
    return pl.pallas_call(
        _warp_kernel,
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, B), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 2), lambda i: (i, 0)),
            pl.BlockSpec((1, B + 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, B), counts.dtype),
        interpret=interpret,
    )(counts, ivl, bedges.reshape(1, -1))
