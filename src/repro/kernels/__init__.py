"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package provides:
  <name>.py — the pl.pallas_call with explicit BlockSpec VMEM tiling
  ops.py    — the jit'd public wrapper (padding, GQA mapping, impl selection)
  ref.py    — the pure-jnp oracle used by the test sweeps

Implementation selection is uniform across packages (``common.py``):
``impl='xla' | 'pallas' | 'pallas_interpret'`` plus an ``interpret`` flag
that defaults to AUTO — interpreter mode only when the backend is CPU, so a
GPU/TPU run can never silently execute a kernel in interpreter mode.

Hot-spots covered:
  hop_scatter     — FUSED traversal-hop delivery: gather source state →
                    temporal mask (static/bucket/interval cells) →
                    segment-reduce (sum or min/max) per destination block,
                    with no materialised per-edge state (the engine's query
                    hot path; see core/superstep.fused_hop_deliver)
  bucket_scatter  — scatter-as-matmul segment reduction (the delivery-only
                    building block hop_scatter extends; GNN aggregation)
  interval_warp   — fused TimeWarp bucket alignment (engine temporal modes)
  flash_attention — blocked online-softmax GQA attention w/ causal + sliding
                    window (LM train/prefill)
  embedding_bag   — fused gather + segment-reduce over huge tables (DLRM)
"""
from . import (bucket_scatter, embedding_bag, flash_attention,  # noqa: F401
               hop_scatter, interval_warp)
from .common import IMPLS, resolve_interpret, use_pallas  # noqa: F401
