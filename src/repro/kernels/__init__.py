"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package provides:
  <name>.py — the pl.pallas_call with explicit BlockSpec VMEM tiling
  ops.py    — the jit'd public wrapper (padding, GQA mapping, interpret flag)
  ref.py    — the pure-jnp oracle used by the test sweeps

Kernels are TPU-targeted and validated with ``interpret=True`` on CPU (this
container has no TPU).  Models select kernels via ``impl='pallas'|'xla'``;
the dry-run compiles the XLA path (Pallas does not lower on the CPU backend).

Hot-spots covered:
  bucket_scatter  — scatter-as-matmul segment reduction (engine superstep
                    message delivery; GNN aggregation)
  interval_warp   — fused TimeWarp bucket alignment (engine temporal modes)
  flash_attention — blocked online-softmax GQA attention w/ causal + sliding
                    window (LM train/prefill)
  embedding_bag   — fused gather + segment-reduce over huge tables (DLRM)
"""
from . import bucket_scatter, embedding_bag, flash_attention, interval_warp  # noqa: F401
