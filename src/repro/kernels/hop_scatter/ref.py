"""Oracle: the fused hop as plain jnp ops over the same block-slot inputs.

Semantically the hop is  segment_sum(state[src] * weights, dst)  — the
engine's XLA path (superstep.apply_edge + superstep.deliver) is the
ground truth the kernel tests compare against.  This module provides the
intermediate oracle at BLOCK granularity (same operands as the pallas
wrappers), so a layout bug and a kernel bug show up as different failures.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .hop_scatter import _interval_apply


def _block_segment_sum(contrib, local_dst, block_v: int):
    """[n_blocks, block_e, C] contributions → [n_blocks·block_v, C]."""
    n_blocks, block_e, C = contrib.shape
    base = jnp.arange(n_blocks, dtype=jnp.int32)[:, None] * block_v
    trash = n_blocks * block_v
    seg = jnp.where(local_dst >= 0, local_dst + base, trash).reshape(-1)
    return jax.ops.segment_sum(contrib.reshape(-1, C), seg,
                               num_segments=trash + 1)[:trash]


def _block_segment_extremum(m_e, alive, local_dst, block_v: int,
                            neutral: float, op_is_min: bool):
    n_blocks, block_e = m_e.shape
    base = jnp.arange(n_blocks, dtype=jnp.int32)[:, None] * block_v
    trash = n_blocks * block_v
    seg = jnp.where(local_dst >= 0, local_dst + base, trash).reshape(-1)
    vals = jnp.where(alive > 0, m_e, neutral).reshape(-1)
    red = jax.ops.segment_min if op_is_min else jax.ops.segment_max
    return red(vals, seg, num_segments=trash + 1)[:trash]


def fused_hop_cols_ref(state_p, src_slot, w_cols, seg_start, seg_end,
                       local_dst, block_v: int, mch_p=None,
                       neutral: float = 0.0,
                       op_is_min: bool = True) -> Tuple[jnp.ndarray,
                                                        Optional[jnp.ndarray]]:
    del seg_start, seg_end  # the oracle reduces by membership, not prefixes
    contrib = state_p[src_slot] * w_cols
    out = _block_segment_sum(contrib, local_dst, block_v)
    if mch_p is None:
        return out, None
    alive = (contrib.sum(axis=-1) > 0).astype(jnp.float32)
    mch = _block_segment_extremum(mch_p[src_slot][..., 0], alive, local_dst,
                                  block_v, neutral, op_is_min)
    return out, mch


def fused_hop_interval_ref(state_p, src_slot, w, sb, eb, seg_start, seg_end,
                           local_dst, block_v: int, n_buckets: int,
                           mch_p=None, neutral: float = 0.0,
                           op_is_min: bool = True):
    del seg_start, seg_end
    n_blocks, block_e = w.shape
    flat = lambda a: a.reshape((n_blocks * block_e,) + a.shape[2:])
    contrib = _interval_apply(state_p[flat(src_slot)], flat(w), flat(sb),
                              flat(eb), n_buckets, n_buckets + 1)
    contrib = contrib.reshape(n_blocks, block_e, -1)
    out = _block_segment_sum(contrib, local_dst, block_v)
    if mch_p is None:
        return out, None
    alive = (contrib.sum(axis=-1) > 0).astype(jnp.float32)
    mch = _block_segment_extremum(mch_p[src_slot][..., 0], alive, local_dst,
                                  block_v, neutral, op_is_min)
    return out, mch
