from .hop_scatter import (fused_hop_cols_pallas, fused_hop_interval_pallas,
                          scatter_cols_pallas, scatter_extremum_pallas)
from .ops import (TABLE_KEYS, HopLayout, build_hop_layout,
                  build_worker_layouts, scatter_deliver, scatter_extremum,
                  slots, stack_layout_tables, worker_tables)
from .ref import fused_hop_cols_ref, fused_hop_interval_ref

__all__ = [
    "TABLE_KEYS", "HopLayout", "build_hop_layout", "build_worker_layouts",
    "stack_layout_tables", "worker_tables", "slots", "scatter_deliver",
    "scatter_extremum", "fused_hop_cols_pallas", "fused_hop_interval_pallas",
    "scatter_cols_pallas", "scatter_extremum_pallas", "fused_hop_cols_ref",
    "fused_hop_interval_ref",
]
