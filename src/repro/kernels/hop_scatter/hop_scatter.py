"""Fused hop-delivery kernels: gather → temporal mask → segment-reduce.

One traversal hop of the engine is

    src_val = state[t_src]                  # gather   [E, *TS]
    cnt_e   = src_val * edge_weights        # mask     [E, *TS]
    arrivals = segment_sum(cnt_e, t_dst)    # deliver  [V, *TS]

The XLA path materialises both [E, *TS] intermediates in HBM and lowers the
delivery to a scatter-add.  These kernels fuse the three steps over the
sorted-CSR block layout of ``bucket_scatter.build_layout``: per
destination-vertex block, the block's (padded) edge slots gather their source
rows straight from the state table, apply the per-edge weights — including
the interval-mode cell clamps — and segment-reduce in VMEM, so no per-edge
state tensor ever round-trips through HBM.

Delivery is a PREFIX-DIFFERENCE reduction, not a scatter: edges are sorted
by arrival, so a destination's contributions are one contiguous slot run and

    out[v] = S[seg_end[v]] - S[seg_start[v]],   S = exclusive prefix sums

with the boundary positions static per layout.  This keeps the reduce at
O(E·C) work (a chunked cumsum + two static gathers — the same prefix
machinery the engine's ETR rank contraction runs per hop), where the
scatter-as-matmul form of ``bucket_scatter`` pays O(E·block_v·C) MXU work.
Bit-equality with segment_sum holds whenever counts are exact integers in
float32 — the engine's invariant (and the ETR machinery's existing
correctness argument).

The extremum variant reduces a per-edge min/max channel alongside, gated by
the per-edge count liveness computed from the contributions already in VMEM:
a masked min/max over block membership when the block is small (the
TPU-shaped layouts), an in-kernel segment reduce for the big single-block
layouts the CPU interpreter prefers.

Temporal state rides with trailing axes flattened to C columns: C = 1
(static), B (bucket), B·(B+1) (interval cells).  The interval kernel also
applies the running-intersection clamp — cells (s, e) move to
(max(s, sb), min(e, eb)) — via masked row/column sums, the matmul-free form
of superstep's ``_clamp_start``/``_clamp_end`` cumsum contractions.

Grid: (n_blocks,).  The state table rides along whole (the reused operand;
its index map pins block 0), per-block operands are sliced by the grid.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: above this [block_e × block_v] footprint the extremum reduction switches
#: from the masked broadcast (TPU-friendly) to an in-kernel segment reduce
_MASKED_EXTREMUM_CELLS = 1 << 22


def _chunk_len(be: int) -> int:
    """Cumsum chunk length: cache-resident chunks make the prefix sums two
    streaming passes instead of log(be) full-array passes."""
    for k in (512, 256, 128):
        if be % k == 0:
            return k
    return be


def _prefix_segment_sum(contrib, sstart, send):
    """[be, C] contributions → [bv, C] segment sums via boundary differences.

    ``sstart``/``send`` are each destination's first/one-past-last slot in
    the block (static layout tables; empty segments have sstart == send)."""
    be, C = contrib.shape
    K = _chunk_len(be)
    ch = contrib.reshape(be // K, K, C)
    local = jnp.cumsum(ch, axis=1)
    tot = local[:, -1, :]
    carry = jnp.cumsum(tot, axis=0) - tot          # exclusive chunk prefix
    S = (local + carry[:, None, :]).reshape(be, C)
    S = jnp.concatenate([jnp.zeros((1, C), S.dtype), S], axis=0)
    return S[send] - S[sstart]


def _segment_extremum(m_e, alive, ldst, block_v: int, neutral: float,
                      op_is_min: bool):
    """[be] channel → [bv] segment min/max; dead edges are neutral."""
    m_e = jnp.where(alive, m_e, neutral)
    be = m_e.shape[0]
    if be * block_v <= _MASKED_EXTREMUM_CELLS:
        cols = jax.lax.broadcasted_iota(jnp.int32, (be, block_v), 1)
        masked = jnp.where(ldst[:, None] == cols, m_e[:, None], neutral)
        return (jnp.min(masked, axis=0) if op_is_min
                else jnp.max(masked, axis=0))
    # big single-block layouts: segment reduce (pad slots → trash row)
    seg = jnp.where(ldst >= 0, ldst, block_v)
    red = jax.ops.segment_min if op_is_min else jax.ops.segment_max
    return red(m_e, seg, num_segments=block_v + 1,
               indices_are_sorted=True)[:block_v]


def _fused_cols_kernel(state_ref, src_ref, w_ref, ss_ref, se_ref, o_ref):
    """static/bucket fused hop: per-column weights, prefix delivery."""
    sv = jnp.take(state_ref[...], src_ref[0], axis=0)     # [be, C]
    contrib = sv.astype(jnp.float32) * w_ref[0]
    o_ref[0] = _prefix_segment_sum(contrib, ss_ref[0],
                                   se_ref[0]).astype(o_ref.dtype)


def _fused_cols_extremum_kernel(state_ref, mch_ref, src_ref, w_ref, ss_ref,
                                se_ref, ldst_ref, o_ref, m_ref, *,
                                block_v: int, neutral: float,
                                op_is_min: bool):
    sv = jnp.take(state_ref[...], src_ref[0], axis=0)
    contrib = sv.astype(jnp.float32) * w_ref[0]
    o_ref[0] = _prefix_segment_sum(contrib, ss_ref[0],
                                   se_ref[0]).astype(o_ref.dtype)
    alive = jnp.sum(contrib, axis=1) > 0                  # count liveness
    mch_e = jnp.take(mch_ref[...], src_ref[0], axis=0)[:, 0]
    m_ref[0] = _segment_extremum(mch_e, alive, ldst_ref[0], block_v,
                                 neutral, op_is_min)


def _interval_apply(sv, w, sb, eb, B: int, Bp1: int):
    """The interval-cell edge algebra on a block of gathered state.

    Matches superstep.apply_validity(MODE_INTERVAL): clamp cell starts up to
    sb, clamp cell ends down to eb, zero degenerate cells, scale by the edge
    weight.  The clamp moves the below-threshold mass onto the threshold
    row/column — here as a masked sum instead of a cumsum lookup.
    """
    f32 = jnp.float32
    cells = sv.reshape(sv.shape[0], B, Bp1).astype(f32)
    s_ids = jax.lax.broadcasted_iota(jnp.int32, (1, B, 1), 1)
    sbx = sb[:, None, None]
    acc_s = jnp.sum(cells * (s_ids <= sbx).astype(f32), axis=1, keepdims=True)
    cells = (cells * (s_ids > sbx).astype(f32)
             + (s_ids == sbx).astype(f32) * acc_s)
    e_ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, Bp1), 2)
    ebx = eb[:, None, None]
    acc_e = jnp.sum(cells * (e_ids >= ebx).astype(f32), axis=2, keepdims=True)
    cells = (cells * (e_ids < ebx).astype(f32)
             + (e_ids == ebx).astype(f32) * acc_e)
    cells = cells * (s_ids < e_ids).astype(f32)           # valid cells only
    cells = cells * w[:, None, None]
    return cells.reshape(sv.shape[0], B * Bp1)


def _fused_interval_kernel(state_ref, src_ref, w_ref, sb_ref, eb_ref,
                           ss_ref, se_ref, o_ref, *, n_buckets: int):
    sv = jnp.take(state_ref[...], src_ref[0], axis=0)     # [be, B*(B+1)]
    contrib = _interval_apply(sv, w_ref[0], sb_ref[0], eb_ref[0],
                              n_buckets, n_buckets + 1)
    o_ref[0] = _prefix_segment_sum(contrib, ss_ref[0],
                                   se_ref[0]).astype(o_ref.dtype)


def _fused_interval_extremum_kernel(state_ref, mch_ref, src_ref, w_ref,
                                    sb_ref, eb_ref, ss_ref, se_ref, ldst_ref,
                                    o_ref, m_ref, *, block_v: int,
                                    n_buckets: int, neutral: float,
                                    op_is_min: bool):
    sv = jnp.take(state_ref[...], src_ref[0], axis=0)
    contrib = _interval_apply(sv, w_ref[0], sb_ref[0], eb_ref[0],
                              n_buckets, n_buckets + 1)
    o_ref[0] = _prefix_segment_sum(contrib, ss_ref[0],
                                   se_ref[0]).astype(o_ref.dtype)
    alive = jnp.sum(contrib, axis=1) > 0
    mch_e = jnp.take(mch_ref[...], src_ref[0], axis=0)[:, 0]
    m_ref[0] = _segment_extremum(mch_e, alive, ldst_ref[0], block_v,
                                 neutral, op_is_min)


def _scatter_cols_kernel(c_ref, ss_ref, se_ref, o_ref):
    """Delivery-only prefix reduce of pre-materialised contributions."""
    o_ref[0] = _prefix_segment_sum(c_ref[0].astype(jnp.float32), ss_ref[0],
                                   se_ref[0]).astype(o_ref.dtype)


def _scatter_extremum_kernel(m_ref_in, alive_ref, ldst_ref, m_ref, *,
                             block_v: int, neutral: float, op_is_min: bool):
    """Extremum twin for pre-materialised channels."""
    m_ref[0] = _segment_extremum(m_ref_in[0], alive_ref[0] > 0, ldst_ref[0],
                                 block_v, neutral, op_is_min)


# =========================================================================
# pallas_call wrappers (operands already in block-slot layout)
# =========================================================================
def _table_spec(n_rows: int, n_cols: int):
    # the whole state table is one reused block: every grid step maps to it
    return pl.BlockSpec((n_rows, n_cols), lambda b: (0, 0))


def _slot_spec(width: int):
    return pl.BlockSpec((1, width), lambda b: (b, 0))


def fused_hop_cols_pallas(
    state_p: jnp.ndarray,         # [N+1, C] — zero pad row at N
    src_slot: jnp.ndarray,        # int32[n_blocks, block_e] — pad = N
    w_cols: jnp.ndarray,          # f32[n_blocks, block_e, C] — pad = 0
    seg_start: jnp.ndarray,       # int32[n_blocks, block_v]
    seg_end: jnp.ndarray,         # int32[n_blocks, block_v]
    local_dst: jnp.ndarray,       # int32[n_blocks, block_e] — pad = -1
    block_v: int,
    interpret: bool = False,
    mch_p: Optional[jnp.ndarray] = None,   # [N+1, 1] — neutral pad row
    neutral: float = 0.0,
    op_is_min: bool = True,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """static/bucket fused hop; returns ([n_blocks·block_v, C], mch|None)."""
    n_blocks, block_e, C = w_cols.shape
    n_rows = state_p.shape[0]
    w_spec = pl.BlockSpec((1, block_e, C), lambda b: (b, 0, 0))
    out_spec = pl.BlockSpec((1, block_v, C), lambda b: (b, 0, 0))
    out_shape = jax.ShapeDtypeStruct((n_blocks, block_v, C), state_p.dtype)
    if mch_p is None:
        out = pl.pallas_call(
            _fused_cols_kernel,
            grid=(n_blocks,),
            in_specs=[_table_spec(n_rows, C), _slot_spec(block_e), w_spec,
                      _slot_spec(block_v), _slot_spec(block_v)],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(state_p, src_slot, w_cols, seg_start, seg_end)
        return out.reshape(n_blocks * block_v, C), None
    out, mch = pl.pallas_call(
        functools.partial(_fused_cols_extremum_kernel, block_v=block_v,
                          neutral=neutral, op_is_min=op_is_min),
        grid=(n_blocks,),
        in_specs=[_table_spec(n_rows, C), _table_spec(n_rows, 1),
                  _slot_spec(block_e), w_spec, _slot_spec(block_v),
                  _slot_spec(block_v), _slot_spec(block_e)],
        out_specs=(out_spec, _slot_spec(block_v)),
        out_shape=(out_shape,
                   jax.ShapeDtypeStruct((n_blocks, block_v), jnp.float32)),
        interpret=interpret,
    )(state_p, mch_p, src_slot, w_cols, seg_start, seg_end, local_dst)
    return out.reshape(n_blocks * block_v, C), mch.reshape(n_blocks * block_v)


def fused_hop_interval_pallas(
    state_p: jnp.ndarray,         # [N+1, B·(B+1)] flattened cells, zero pad row
    src_slot: jnp.ndarray,        # int32[n_blocks, block_e]
    w: jnp.ndarray,               # f32[n_blocks, block_e] — edge match, pad 0
    sb: jnp.ndarray,              # int32[n_blocks, block_e] — start clamp
    eb: jnp.ndarray,              # int32[n_blocks, block_e] — end clamp
    seg_start: jnp.ndarray,
    seg_end: jnp.ndarray,
    local_dst: jnp.ndarray,
    block_v: int,
    n_buckets: int,
    interpret: bool = False,
    mch_p: Optional[jnp.ndarray] = None,
    neutral: float = 0.0,
    op_is_min: bool = True,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """interval fused hop; returns ([n_blocks·block_v, B·(B+1)], mch|None)."""
    n_blocks, block_e = w.shape
    C = n_buckets * (n_buckets + 1)
    n_rows = state_p.shape[0]
    out_spec = pl.BlockSpec((1, block_v, C), lambda b: (b, 0, 0))
    out_shape = jax.ShapeDtypeStruct((n_blocks, block_v, C), state_p.dtype)
    slot_e = _slot_spec(block_e)
    slot_v = _slot_spec(block_v)
    if mch_p is None:
        out = pl.pallas_call(
            functools.partial(_fused_interval_kernel, n_buckets=n_buckets),
            grid=(n_blocks,),
            in_specs=[_table_spec(n_rows, C), slot_e, slot_e, slot_e, slot_e,
                      slot_v, slot_v],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(state_p, src_slot, w, sb, eb, seg_start, seg_end)
        return out.reshape(n_blocks * block_v, C), None
    out, mch = pl.pallas_call(
        functools.partial(_fused_interval_extremum_kernel, block_v=block_v,
                          n_buckets=n_buckets, neutral=neutral,
                          op_is_min=op_is_min),
        grid=(n_blocks,),
        in_specs=[_table_spec(n_rows, C), _table_spec(n_rows, 1),
                  slot_e, slot_e, slot_e, slot_e, slot_v, slot_v, slot_e],
        out_specs=(out_spec, slot_v),
        out_shape=(out_shape,
                   jax.ShapeDtypeStruct((n_blocks, block_v), jnp.float32)),
        interpret=interpret,
    )(state_p, mch_p, src_slot, w, sb, eb, seg_start, seg_end, local_dst)
    return out.reshape(n_blocks * block_v, C), mch.reshape(n_blocks * block_v)


def scatter_cols_pallas(
    contrib: jnp.ndarray,         # [n_blocks, block_e, C] — per-slot values
    seg_start: jnp.ndarray,
    seg_end: jnp.ndarray,
    block_v: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Delivery-only blocked prefix reduce; returns [n_blocks·block_v, C]."""
    n_blocks, block_e, C = contrib.shape
    out = pl.pallas_call(
        _scatter_cols_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, block_e, C), lambda b: (b, 0, 0)),
                  _slot_spec(block_v), _slot_spec(block_v)],
        out_specs=pl.BlockSpec((1, block_v, C), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block_v, C), contrib.dtype),
        interpret=interpret,
    )(contrib, seg_start, seg_end)
    return out.reshape(n_blocks * block_v, C)


def scatter_extremum_pallas(
    m_e: jnp.ndarray,             # f32[n_blocks, block_e] — per-slot channel
    alive: jnp.ndarray,           # f32[n_blocks, block_e] — liveness gate
    local_dst: jnp.ndarray,
    block_v: int,
    neutral: float,
    op_is_min: bool,
    interpret: bool = False,
) -> jnp.ndarray:
    """Blocked segment-min/max of a pre-materialised per-edge channel."""
    n_blocks, block_e = m_e.shape
    out = pl.pallas_call(
        functools.partial(_scatter_extremum_kernel, block_v=block_v,
                          neutral=neutral, op_is_min=op_is_min),
        grid=(n_blocks,),
        in_specs=[_slot_spec(block_e)] * 3,
        out_specs=_slot_spec(block_v),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block_v), jnp.float32),
        interpret=interpret,
    )(m_e, alive, local_dst)
    return out.reshape(n_blocks * block_v)
