"""Wrapper: hop layouts (static, per graph/shard) + edge-level entries.

``HopLayout`` extends ``bucket_scatter.build_layout``'s sorted-CSR block
layout with the per-block segment-boundary tables the prefix-difference
delivery reads (``seg_start``/``seg_end``: each destination's first /
one-past-last slot in its block) and device-resident mirrors, so the slot
permutation — the O(E) gathers that move per-edge operands into padded block
slots — stays inside the traced program while the layout itself is
host-static (part of the executable key, never retraced).

Block sizing: ``block_v=None`` (the default) auto-sizes ONE block covering
all destinations — the right shape for the CPU interpreter, whose per-block
operand slicing dominates multi-block grids.  TPU deployments pass an
explicit MXU/VMEM-shaped ``block_v`` (e.g. 256) and get the grid the module
docstring describes.

Three consumers:

  * the fused hop kernels (``hop_scatter.fused_hop_*``) take slot-layout
    operands prepared with ``slots()`` — the mode-specific weight prep lives
    with the state algebra in ``core/superstep.py``;
  * ``scatter_deliver`` / ``scatter_extremum`` are the delivery-only entries
    for per-edge values that must exist anyway (ETR hop outputs);
  * ``build_worker_layouts`` stacks one layout per partition shard with a
    common slot shape, so the partitioned executor can vmap (or shard_map)
    the kernel over its worker axis.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..bucket_scatter.ops import ScatterLayout, build_layout
from ..common import resolve_interpret
from .hop_scatter import scatter_cols_pallas, scatter_extremum_pallas

#: keys of the device-table dict the kernels consume (``HopLayout.tables``)
TABLE_KEYS = ("gather", "valid", "ldst", "sstart", "send")


@dataclasses.dataclass(frozen=True)
class HopLayout:
    """A ScatterLayout plus boundary tables and device mirrors."""
    host: ScatterLayout
    gather_idx: jnp.ndarray   # int32[n_blocks * block_e]
    valid: jnp.ndarray        # bool [n_blocks * block_e]
    local_dst: jnp.ndarray    # int32[n_blocks, block_e]
    seg_start: jnp.ndarray    # int32[n_blocks, block_v]
    seg_end: jnp.ndarray      # int32[n_blocks, block_v]

    @property
    def n_blocks(self) -> int:
        return self.host.n_blocks

    @property
    def block_e(self) -> int:
        return self.host.block_e

    @property
    def block_v(self) -> int:
        return self.host.block_v

    @property
    def num_segments(self) -> int:
        return self.host.num_segments

    @property
    def tables(self) -> dict:
        """The kernels' device operands as one dict (a uniform pytree, so
        executors can vmap worker-stacked tables with ``in_axes=0``)."""
        return dict(gather=self.gather_idx, valid=self.valid,
                    ldst=self.local_dst, sstart=self.seg_start,
                    send=self.seg_end)

    def signature(self) -> tuple:
        """Hashable shape identity — the executable-cache key component."""
        return ("hop_layout", self.n_blocks, self.block_e, self.block_v,
                self.num_segments)


def _auto_block_v(num_segments: int) -> int:
    """One block over all destinations, padded to the lane width."""
    return max(128, -(-num_segments // 128) * 128)


def _boundary_tables(seg_ids: np.ndarray, host: ScatterLayout) -> tuple:
    """Per-block (seg_start, seg_end) slot positions for every destination.

    Destinations are blocked by ``v // block_v`` and edges are arrival-
    sorted, so each destination's contributions are one contiguous run of
    its block's REAL slots; empty destinations get a zero-width run."""
    nb, bv = host.n_blocks, host.block_v
    counts = np.bincount(np.asarray(seg_ids), minlength=host.num_segments)
    gend = np.cumsum(counts)
    gstart = gend - counts
    block_base = np.zeros(nb, np.int64)
    blk_counts = np.bincount(np.asarray(seg_ids) // bv, minlength=nb)
    np.cumsum(blk_counts[:-1], out=block_base[1:])
    sstart = np.zeros((nb, bv), np.int32)
    send = np.zeros((nb, bv), np.int32)
    for b in range(nb):
        vlo = b * bv
        vhi = min(vlo + bv, host.num_segments)
        sstart[b, : vhi - vlo] = gstart[vlo:vhi] - block_base[b]
        send[b, : vhi - vlo] = gend[vlo:vhi] - block_base[b]
    return sstart, send


def build_hop_layout(seg_ids: np.ndarray, num_segments: int,
                     block_v: Optional[int] = None, block_e_mult: int = 512,
                     block_e: Optional[int] = None) -> HopLayout:
    if block_v is None:
        block_v = _auto_block_v(num_segments)
    host = build_layout(seg_ids, num_segments, block_v=block_v,
                        block_e_mult=block_e_mult, block_e=block_e)
    sstart, send = _boundary_tables(seg_ids, host)
    return HopLayout(
        host,
        jnp.asarray(host.gather_idx, jnp.int32),
        jnp.asarray(host.valid),
        jnp.asarray(host.local_dst),
        jnp.asarray(sstart),
        jnp.asarray(send),
    )


def build_worker_layouts(seg_rows: np.ndarray, num_segments: int,
                         block_v: Optional[int] = None,
                         block_e_mult: int = 512) -> List[HopLayout]:
    """One layout per partition shard over a COMMON slot shape.

    ``seg_rows`` [W, Emax] are the per-worker (sorted) local destination
    arrays — pad entries carry the trash segment id (num_segments - 1), so
    they land in real slots and deliver their (zero) contributions to the
    sliced-off trash row.  Forcing one ``block_e`` across workers lets the
    executor stack the layouts and map the kernel over the worker axis.
    """
    seg_rows = np.asarray(seg_rows)
    if block_v is None:
        block_v = _auto_block_v(num_segments)
    n_blocks = -(-num_segments // block_v)
    fullest = max(
        (int(np.bincount(row // block_v, minlength=n_blocks).max(initial=1))
         for row in seg_rows), default=1)
    block_e = max(block_e_mult,
                  int(-(-fullest // block_e_mult) * block_e_mult))
    return [
        build_hop_layout(row, num_segments, block_v=block_v,
                         block_e_mult=block_e_mult, block_e=block_e)
        for row in seg_rows
    ]


def stack_layout_tables(layouts: Sequence[HopLayout]) -> dict:
    """Stack per-worker HopLayout tables into [W, ...] device tensors (the
    ``hop_``-prefixed entries of the partitioned executor's pdev dict; same
    role as the partitioner's padded per-worker tensors)."""
    assert len({(l.n_blocks, l.block_e, l.block_v) for l in layouts}) == 1
    stacked = {k: jnp.stack([l.tables[k] for l in layouts])
               for k in TABLE_KEYS}
    return {f"hop_{k}": v for k, v in stacked.items()}


def worker_tables(pdev: dict, w: Optional[slice] = None) -> dict:
    """The generic-keyed table dict back out of a pdev-style dict; ``w``
    optionally slices one worker's rows (profiling call sites)."""
    out = {k: pdev[f"hop_{k}"] for k in TABLE_KEYS}
    if w is not None:
        out = {k: v[w] for k, v in out.items()}
    return out


def slots(x: jnp.ndarray, gather_idx: jnp.ndarray, valid: jnp.ndarray, fill):
    """Permute per-edge values into (flat) block slots; pad slots → fill."""
    g = x[gather_idx]
    mask = valid
    for _ in x.shape[1:]:
        mask = mask[..., None]
    return jnp.where(mask, g, jnp.asarray(fill, x.dtype))


def scatter_deliver(
    cnt_e: jnp.ndarray,           # [E, *TS] per-edge contributions
    lt: dict,                     # HopLayout.tables (possibly worker-sliced)
    num_segments: int,
    block_v: int,
    interpret: Optional[bool] = None,
    impl: str = "pallas",
) -> jnp.ndarray:
    """Delivery-only fused reduce of already-materialised per-edge state."""
    ts = cnt_e.shape[1:]
    C = int(np.prod(ts)) if ts else 1
    cp = slots(cnt_e.reshape(cnt_e.shape[0], C), lt["gather"], lt["valid"],
               0.0)
    n_blocks, block_e = lt["ldst"].shape
    out = scatter_cols_pallas(
        cp.reshape(n_blocks, block_e, C), lt["sstart"], lt["send"], block_v,
        interpret=resolve_interpret(interpret, impl))
    return out[:num_segments].reshape((num_segments,) + ts)


def scatter_extremum(
    m_e: jnp.ndarray,             # f32[E] per-edge extremum channel
    alive_e: jnp.ndarray,         # f32/bool[E] per-edge count liveness
    lt: dict,                     # HopLayout.tables
    num_segments: int,
    block_v: int,
    neutral: float,
    op_is_min: bool,
    interpret: Optional[bool] = None,
    impl: str = "pallas",
) -> jnp.ndarray:
    """Delivery-only fused min/max of a per-edge channel (empty segments
    land on the aggregation-neutral element, like segment_min/segment_max)."""
    n_blocks, block_e = lt["ldst"].shape
    mp = slots(m_e, lt["gather"], lt["valid"], neutral).reshape(n_blocks,
                                                                block_e)
    ap = slots(alive_e.astype(jnp.float32), lt["gather"], lt["valid"], 0.0)
    out = scatter_extremum_pallas(
        mp, ap.reshape(n_blocks, block_e), lt["ldst"], block_v, neutral,
        op_is_min, interpret=resolve_interpret(interpret, impl))
    return out[:num_segments]
