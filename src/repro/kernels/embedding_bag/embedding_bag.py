"""Fused EmbeddingBag kernel for huge tables (DLRM hot path).

TPU-native design: the table stays in HBM (``memory_space=ANY``); per grid
step we process a block of bags, issuing explicit row DMAs
(``pltpu.make_async_copy``) from the table into a VMEM scratch row and
accumulating in a VMEM accumulator.  This is the TPU analogue of FBGEMM's
table-batched-embedding: the random-access gather never round-trips through
XLA gather (which would materialise [B, L, D] in HBM).

The indices block is VMEM-resident; -1 marks padding.  ``mode='sum'|'mean'``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ebag_kernel(idx_ref, table_ref, o_ref, scratch, sem, *, mode: str):
    bb, L = idx_ref.shape
    D = o_ref.shape[1]

    def bag(i, _):
        def item(j, acc_cnt):
            acc, cnt = acc_cnt
            ix = idx_ref[i, j]

            @pl.when(ix >= 0)
            def _():
                cp = pltpu.make_async_copy(
                    table_ref.at[pl.dslice(ix, 1), :], scratch, sem
                )
                cp.start()
                cp.wait()

            take = (ix >= 0).astype(jnp.float32)
            # where (not multiply): the scratch row is uninitialised when the
            # DMA was skipped, and 0 × garbage/NaN would poison the sum.
            row = jnp.where(ix >= 0, scratch[0, :].astype(jnp.float32), 0.0)
            acc = acc + row
            return acc, cnt + take

        acc, cnt = jax.lax.fori_loop(
            0, L, item, (jnp.zeros((D,), jnp.float32), jnp.float32(0.0))
        )
        if mode == "mean":
            acc = acc / jnp.maximum(cnt, 1.0)
        o_ref[i, :] = acc.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bb, bag, 0)


def embedding_bag_pallas(
    table: jnp.ndarray,      # [V, D]
    indices: jnp.ndarray,    # [B, L] int32, -1 pad
    mode: str = "sum",
    block_b: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, L = indices.shape
    V, D = table.shape
    assert B % block_b == 0
    kernel = functools.partial(_ebag_kernel, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, L), lambda b: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # table stays in HBM
        ],
        out_specs=pl.BlockSpec((block_b, D), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, D), table.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(indices, table)
