"""Wrapper: padding + implementation selection."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..common import resolve_interpret, use_pallas
from .embedding_bag import embedding_bag_pallas
from .ref import embedding_bag_ref


def embedding_bag(table, indices, mode: str = "sum", impl: str = "xla",
                  block_b: int = 128, interpret: Optional[bool] = None):
    """EmbeddingBag over a [V, D] table with [B, L] (-1 padded) indices."""
    if not use_pallas(impl):
        return embedding_bag_ref(table, indices, mode)
    B = indices.shape[0]
    pad = (-B) % block_b
    if pad:
        indices = jnp.pad(indices, ((0, pad), (0, 0)), constant_values=-1)
    out = embedding_bag_pallas(table, indices, mode=mode, block_b=block_b,
                               interpret=resolve_interpret(interpret, impl))
    return out[:B]
