"""Oracle: EmbeddingBag = gather + masked reduce (JAX has no native one)."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table: jnp.ndarray, indices: jnp.ndarray, mode: str = "sum"):
    """table [V, D]; indices [B, L] int32 with -1 padding → [B, D]."""
    safe = jnp.maximum(indices, 0)
    rows = table[safe]                                   # [B, L, D]
    mask = (indices >= 0).astype(table.dtype)[..., None]
    out = (rows * mask).sum(axis=1)
    if mode == "mean":
        out = out / jnp.maximum(mask.sum(axis=1), 1.0)
    return out
