from .ops import embedding_bag  # noqa: F401
from .ref import embedding_bag_ref  # noqa: F401
