"""Wrapper: static per-graph block layout + the pallas call."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..common import resolve_interpret, use_pallas
from .bucket_scatter import bucket_scatter_pallas
from .ref import bucket_scatter_ref


@dataclasses.dataclass(frozen=True)
class ScatterLayout:
    """Static block layout for a fixed (sorted) seg_ids array."""
    gather_idx: np.ndarray   # int64[n_blocks * block_e] — edge id per padded slot
    valid: np.ndarray        # bool same shape
    local_dst: np.ndarray    # int32[n_blocks, block_e]
    n_blocks: int
    block_e: int
    block_v: int
    num_segments: int


def build_layout(seg_ids: np.ndarray, num_segments: int,
                 block_v: int = 256, block_e_mult: int = 256,
                 block_e: Optional[int] = None) -> ScatterLayout:
    """Sorted-CSR block layout: destinations tile into blocks of ``block_v``,
    each block's edge range pads to ``block_e`` slots (derived from the
    fullest block unless forced — forcing lets callers share one slot shape
    across several layouts, e.g. the per-worker shards of a partition)."""
    seg_ids = np.asarray(seg_ids)
    assert (np.diff(seg_ids) >= 0).all(), "seg_ids must be sorted"
    n_blocks = -(-num_segments // block_v)
    counts = np.bincount(seg_ids // block_v, minlength=n_blocks)
    need = int(-(-counts.max(initial=1) // block_e_mult) * block_e_mult)
    if block_e is None:
        block_e = max(block_e_mult, need)
    else:
        assert block_e >= counts.max(initial=0), "forced block_e too small"
    starts = np.zeros(n_blocks + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    gather = np.zeros((n_blocks, block_e), np.int64)
    valid = np.zeros((n_blocks, block_e), bool)
    ldst = np.full((n_blocks, block_e), -1, np.int32)
    for b in range(n_blocks):
        n = counts[b]
        gather[b, :n] = np.arange(starts[b], starts[b] + n)
        valid[b, :n] = True
        ldst[b, :n] = seg_ids[starts[b]:starts[b] + n] - b * block_v
    return ScatterLayout(gather.reshape(-1), valid.reshape(-1), ldst,
                         n_blocks, block_e, block_v, num_segments)


def bucket_scatter(
    contrib: jnp.ndarray,            # [E, C]
    seg_ids: jnp.ndarray,            # [E] sorted
    num_segments: int,
    layout: Optional[ScatterLayout] = None,
    impl: str = "xla",
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Segment-sum of contributions; layout enables the pallas path."""
    if not use_pallas(impl) or layout is None:
        return bucket_scatter_ref(contrib, seg_ids, num_segments)
    cp = contrib[jnp.asarray(layout.gather_idx)]
    cp = cp * jnp.asarray(layout.valid, contrib.dtype)[:, None]
    cp = cp.reshape(layout.n_blocks, layout.block_e, contrib.shape[1])
    out = bucket_scatter_pallas(cp, jnp.asarray(layout.local_dst),
                                layout.block_v,
                                interpret=resolve_interpret(interpret, impl))
    return out[: num_segments]
