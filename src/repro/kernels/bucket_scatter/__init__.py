from .ops import bucket_scatter  # noqa: F401
from .ref import bucket_scatter_ref  # noqa: F401
