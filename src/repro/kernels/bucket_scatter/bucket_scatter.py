"""Scatter-as-matmul segment reduction for TPU.

The engine's superstep delivery (and GNN aggregation) is a segment-sum of
per-edge contributions into destination vertices.  Scatter is hostile to the
TPU vector unit, but because traversal edges are pre-sorted by destination we
can tile destinations into blocks of ``block_v`` rows, pad each block's edge
range to ``block_e``, and compute

    out[block] = onehot(local_dst).T @ contrib[block]      # [bv, be]·[be, C]

— turning the scatter into an MXU matmul.  The host-side prep (ops.py)
computes the per-block edge ranges once per graph (they are static).

Grid: (n_blocks,).  VMEM per step: be·C + be·bv + bv·C floats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scatter_kernel(contrib_ref, ldst_ref, o_ref, *, block_v: int):
    contrib = contrib_ref[0].astype(jnp.float32)          # [be, C]
    ldst = ldst_ref[0]                                    # [be] int32, -1 = pad
    onehot = (
        ldst[:, None] == jax.lax.iota(jnp.int32, block_v)[None, :]
    ).astype(jnp.float32)                                 # [be, bv]
    o_ref[0] = jax.lax.dot_general(
        onehot, contrib, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)                                 # [bv, C]


def bucket_scatter_pallas(
    contrib_padded: jnp.ndarray,   # [n_blocks, block_e, C]
    local_dst: jnp.ndarray,        # [n_blocks, block_e] int32 (-1 pad)
    block_v: int,
    interpret: bool = False,
) -> jnp.ndarray:
    n_blocks, block_e, C = contrib_padded.shape
    kernel = functools.partial(_scatter_kernel, block_v=block_v)
    out = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, block_e, C), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, block_e), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_v, C), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block_v, C), contrib_padded.dtype),
        interpret=interpret,
    )(contrib_padded, local_dst)
    return out.reshape(n_blocks * block_v, C)
