"""Oracle: plain sorted segment-sum (message delivery / GNN aggregation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bucket_scatter_ref(contrib: jnp.ndarray, seg_ids: jnp.ndarray, num_segments: int):
    """contrib [E, C] float, seg_ids [E] int32 (sorted), → [num_segments, C]."""
    return jax.ops.segment_sum(
        contrib, seg_ids, num_segments=num_segments, indices_are_sorted=True
    )
