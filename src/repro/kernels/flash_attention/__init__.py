from .ops import flash_attention  # noqa: F401
from .ref import attention_ref  # noqa: F401
