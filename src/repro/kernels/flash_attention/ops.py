"""Public wrapper: padding, implementation selection, decode convenience."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import resolve_interpret
from .flash_attention import flash_attention_pallas
from .ref import attention_ref


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "q_offset", "impl",
                     "block_q", "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    q_offset: int = 0,
    impl: str = "xla",            # 'xla' (ref) | 'pallas' | 'pallas_interpret'
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,   # None → auto: interpret on CPU only
):
    """GQA attention. q [B,Hq,Sq,D]; k/v [B,Hkv,Sk,D] (Sk >= Sq for decode)."""
    if impl == "xla":
        return attention_ref(q, k, v, causal=causal, window=window,
                             sm_scale=sm_scale, q_offset=q_offset)
    interp = resolve_interpret(interpret, impl)
    Sq0 = q.shape[2]
    bq = min(block_q, max(8, Sq0))
    q_p, _ = _pad_to(q, 2, bq)
    k_p, Sk0 = _pad_to(k, 2, block_k)
    v_p, _ = _pad_to(v, 2, block_k)
    # mask padded kv with empty lifetimes by pushing them outside the causal
    # horizon: padded kpos > any qpos iff causal; for non-causal we pad scores
    # via an explicit validity window = causal OR window trick; simplest exact
    # approach: run and rely on causal mask; for non-causal inputs pad k with
    # -inf-producing sentinel by zeroing v and huge-negative k·q is not exact,
    # so require non-causal calls to be pre-padded.
    if not causal and k_p.shape[2] != Sk0:
        raise ValueError("non-causal pallas path requires Sk divisible by block_k")
    out = flash_attention_pallas(
        q_p, k_p, v_p, causal=causal, window=window, sm_scale=sm_scale,
        q_offset=q_offset, block_q=bq, block_k=block_k, interpret=interp,
    )
    return out[:, :, : q.shape[2], :]


def decode_attention(q1, k_cache, v_cache, cache_len: int, **kw):
    """Single-token decode: q1 [B,Hq,1,D] against a cache prefix."""
    return flash_attention(q1, k_cache, v_cache, causal=True,
                           q_offset=cache_len - 1, **kw)
