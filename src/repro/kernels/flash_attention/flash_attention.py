"""Blocked online-softmax attention kernel (FlashAttention-style) for TPU.

Design (TPU-native, not a CUDA port):
  * grid = (batch, q_heads, Sq/block_q); one MXU-aligned q tile per step.
  * K/V for the (GQA-mapped) kv head are staged as whole-sequence VMEM blocks
    — at d_head 128 and block_k 512 the working set is a few MB, well inside
    the ~16 MB v5e VMEM budget; the inner fori_loop walks K/V in block_k
    slices with the classic (m, l, acc) online-softmax carry.
  * causal and sliding-window masks are computed from absolute positions, so
    the same kernel serves training, chunked prefill and decode (q_offset).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, *,
    sm_scale: float,
    block_k: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    seq_k: int,
):
    block_q, d = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale          # [bq, d]
    qi = pl.program_id(2)
    qpos = q_offset + qi * block_q + jax.lax.iota(jnp.int32, block_q)

    nk = seq_k // block_k

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                    # [bq, bk]
        kpos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,   # [B, Hq, Sq, D]
    k: jnp.ndarray,   # [B, Hkv, Sk, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0 and Sq % block_q == 0 and Sk % block_k == 0
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else D ** -0.5

    kernel = functools.partial(
        _attn_kernel, sm_scale=scale, block_k=block_k, causal=causal,
        window=window, q_offset=q_offset, seq_k=Sk,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, Sq // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
