"""Pure-jnp oracle for GQA flash attention (causal / sliding-window)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,       # [B, Hq, Sq, D]
    k: jnp.ndarray,       # [B, Hkv, Sk, D]
    v: jnp.ndarray,       # [B, Hkv, Sk, D]
    causal: bool = True,
    window: Optional[int] = None,   # sliding window size (None = full)
    sm_scale: Optional[float] = None,
    q_offset: int = 0,    # absolute position of q[0] (decode: cache length)
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else D ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s * scale
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    denom = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", p / denom, vv.astype(jnp.float32))
    return out.astype(q.dtype)
