"""Shared implementation-selection helpers for the kernel wrappers.

Every kernel package exposes the same idiom (set by flash_attention):

  impl       'xla' (reference path) | 'pallas' | 'pallas_interpret'
  interpret  None  → auto: interpreter mode ONLY when the backend is CPU
                     (Pallas has no compiled CPU path), so a GPU/TPU run can
                     never silently execute a kernel in interpreter mode;
             bool  → explicit override (tests pin True for determinism).

``impl='pallas_interpret'`` always forces the interpreter regardless of the
``interpret`` argument — it exists so a caller can demand the portable path
explicitly (debugging, differential tests on accelerators).
"""
from __future__ import annotations

from typing import Optional

import jax

IMPLS = ("xla", "pallas", "pallas_interpret")


def check_impl(impl: str) -> str:
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    return impl


def use_pallas(impl: str) -> bool:
    return check_impl(impl) != "xla"


def resolve_interpret(interpret: Optional[bool], impl: str = "pallas") -> bool:
    """Resolve the effective interpreter flag for a pallas call."""
    if impl == "pallas_interpret":
        return True
    if interpret is None:
        return jax.default_backend() == "cpu"
    return bool(interpret)
