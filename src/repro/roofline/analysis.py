"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs / (chips × 197e12)          [bf16 MXU peak]
  memory     = HLO_bytes / (chips × 819e9)           [HBM bandwidth]
  collective = collective_bytes / (chips × 50e9)     [per-link ICI]

FLOPs/bytes come from ``compiled.cost_analysis()`` (whole-program totals —
on the SPMD-partitioned module they are per-device values for most ops, but
XLA reports the *global* program; we therefore divide by chip count, which
matches the per-chip roofline definition in the assignment).

collective_bytes is NOT in cost_analysis: we parse the post-SPMD optimized
HLO (``compiled.as_text()``) and sum modeled per-chip byte volumes per
collective op:
  all-reduce: 2×size (ring, send+recv per chip) · all-gather: output size
  reduce-scatter: input≈output×n ≈ modeled as output size × (n-1)/n ≈ size
  all-to-all / collective-permute: size.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e)
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*((?:f|bf|s|u|pred|c)[0-9a-z]*)\[([0-9,]*)\][^=]*?"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?(?!-done)\b"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$",
                      re.M)
_WHILE_BODY_RE = re.compile(r"(?:body|condition)=%?([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo_text: str):
    """Yield (computation_name, text) blocks from post-optimization HLO."""
    marks = [(m.start(), m.group(1)) for m in _COMP_RE.finditer(hlo_text)]
    if not marks:
        yield ("__all__", hlo_text)
        return
    for i, (pos, name) in enumerate(marks):
        end = marks[i + 1][0] if i + 1 < len(marks) else len(hlo_text)
        yield (name, hlo_text[pos:end])


def collective_bytes_from_hlo(hlo_text: str, loop_scale: float = 1.0
                              ) -> Dict[str, float]:
    """Sum modeled per-chip collective bytes by op kind.

    Collectives inside while-loop body/condition computations execute once
    per trip — they are multiplied by ``loop_scale``; everything else counts
    once.  ``-done`` halves of async pairs are excluded.
    """
    loop_comps = set(_WHILE_BODY_RE.findall(hlo_text))
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    for name, block in _split_computations(hlo_text):
        mult = loop_scale if name in loop_comps else 1.0
        for m in _COLL_RE.finditer(block):
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            size = _shape_bytes(dtype, dims)
            if kind == "all-reduce":
                out[kind] += mult * 2.0 * size
            else:
                out[kind] += mult * float(size)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    coll_by_kind: Dict[str, float]
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: Optional[float] = None
    useful_flops_frac: Optional[float] = None
    memory_per_device: Optional[dict] = None
    scan_scale: float = 1.0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze_compiled(compiled, chips: int, arch: str, shape: str, mesh_name: str,
                     model_flops: Optional[float] = None,
                     hlo_text: Optional[str] = None,
                     scan_trips: Optional[int] = None,
                     analytic_flops: Optional[float] = None) -> RooflineReport:
    """``scan_trips``: XLA's cost_analysis counts a while-loop body ONCE.
    Scanned-layer LMs are body-dominated, so when the program contains a
    while loop we scale all three terms by
    ``scan_scale = clip(model_flops/chips / hlo_flops, 1, scan_trips)`` —
    anchored on the analytic 6·N·D FLOPs (see EXPERIMENTS.md §Roofline note).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, list):          # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))          # per-device SPMD program
    byts = float(ca.get("bytes accessed", 0.0))  # per-device
    text = hlo_text if hlo_text is not None else compiled.as_text()
    scan_scale = 1.0
    has_while = (" while(" in text) or ("while (" in text)
    if scan_trips and scan_trips > 1 and has_while and model_flops and flops > 0:
        per_chip_model = model_flops / chips
        scan_scale = min(max(per_chip_model / flops, 1.0), float(scan_trips))
    if analytic_flops is not None and flops > 0:
        # XLA's CPU cost model counts reduce-window-lowered prefix sums
        # quadratically; when an analytic per-chip FLOP count is provided and
        # the HLO number is wildly above it, trust the analytic one.
        per_chip = analytic_flops / chips
        if flops > 50.0 * per_chip:
            flops = per_chip
    # collectives: loop-body ops scale by trip count, prologue/epilogue once
    coll = collective_bytes_from_hlo(text, loop_scale=scan_scale)
    coll_total = sum(coll.values())
    flops *= scan_scale
    byts *= scan_scale
    # cost_analysis/HLO are per-device: divide by per-chip peaks only.
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll_total / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bott = max(terms, key=terms.get)
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = dict(
            argument_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
            output_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
            temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
            code_bytes=int(getattr(ma, "generated_code_size_in_bytes", 0)),
        )
    except Exception:
        pass
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, collective_bytes=coll_total,
        coll_by_kind=coll, t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bott, model_flops=model_flops,
        useful_flops_frac=(model_flops / (flops * chips)
                           if model_flops and flops else None),
        memory_per_device=mem, scan_scale=scan_scale,
    )


def roofline_terms(report: RooflineReport) -> dict:
    return dict(compute=report.t_compute, memory=report.t_memory,
                collective=report.t_collective, bottleneck=report.bottleneck)


def save_report(report: RooflineReport, path: str):
    import os

    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(report.to_json(), f, indent=2)
