from .analysis import analyze_compiled, roofline_terms  # noqa: F401
