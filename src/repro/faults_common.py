"""Shared fault-handling primitives: backoff math, liveness, stragglers.

One implementation serves both fault-tolerant layers — the training-side
checkpoint-restart driver (``repro.training.fault``) and the serving-side
chaos/retry machinery (``repro.serving.faults``) — so backoff curves and
straggler policy are defined exactly once:

* ``backoff_delay`` — exponential backoff with a cap and optional seeded
  jitter.  Delays are *accounted*, never slept: both consumers run on
  virtual clocks, so a backoff is a number added to a deadline/latency
  budget, which keeps every retry schedule deterministic and testable.
* ``HeartbeatMonitor`` — workers report liveness; the monitor declares
  failure after ``timeout_s`` silence.
* ``StragglerPolicy`` / ``mitigate_stragglers`` — speculative re-execution:
  partitions slower than ``k × median`` are duplicated on the least-loaded
  other worker and the first result wins (the paper's Q3/Q4 weak-scaling
  stragglers motivate this).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np


def backoff_delay(attempt: int, base_s: float = 0.01,
                  multiplier: float = 2.0, max_s: float = 1.0,
                  jitter_frac: float = 0.0,
                  rng: Optional[np.random.Generator] = None) -> float:
    """Exponential backoff for retry ``attempt`` (0-based): ``base ·
    multiplier^attempt`` capped at ``max_s``, with ±``jitter_frac``
    multiplicative jitter drawn from ``rng`` (deterministic when the caller
    seeds it — the serving retry tests pin exact schedules)."""
    d = min(float(base_s) * float(multiplier) ** int(attempt), float(max_s))
    if jitter_frac and rng is not None:
        d *= 1.0 + float(jitter_frac) * (2.0 * float(rng.random()) - 1.0)
    return d


class HeartbeatMonitor:
    def __init__(self, n_workers: int, timeout_s: float = 5.0):
        self.timeout = timeout_s
        self.last_beat: Dict[int, float] = {w: time.time()
                                            for w in range(n_workers)}
        self.dead: set = set()

    def beat(self, worker: int, t: Optional[float] = None):
        if worker not in self.dead:
            self.last_beat[worker] = time.time() if t is None else t

    def kill(self, worker: int):
        self.dead.add(worker)

    def check(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        failed = [
            w for w, t in self.last_beat.items()
            if w not in self.dead and now - t > self.timeout
        ]
        failed += [w for w in self.dead if now is not None]
        return sorted(set(failed))

    def alive(self) -> List[int]:
        now = time.time()
        return [w for w in self.last_beat
                if w not in self.dead
                and now - self.last_beat[w] <= self.timeout]


@dataclasses.dataclass
class StragglerPolicy:
    slowdown_factor: float = 3.0
    max_duplicates: int = 2


def mitigate_stragglers(
    part_times_ms: np.ndarray,
    part_worker: np.ndarray,
    policy: StragglerPolicy = StragglerPolicy(),
) -> Dict[int, int]:
    """Given per-partition times and placements, pick partitions to duplicate.

    Returns {partition_id: backup_worker}.  First-result-wins semantics are
    applied by the caller (the superstep barrier takes min(primary, backup)).
    """
    med = float(np.median(part_times_ms))
    worker_load = {}
    for p, w in enumerate(part_worker):
        worker_load[int(w)] = (worker_load.get(int(w), 0.0)
                               + float(part_times_ms[p]))
    slow = np.argsort(-part_times_ms)
    out: Dict[int, int] = {}
    for p in slow[: policy.max_duplicates]:
        if part_times_ms[p] > policy.slowdown_factor * max(med, 1e-9):
            # least-loaded worker that doesn't already own p
            cands = sorted(worker_load, key=worker_load.get)
            for w in cands:
                if w != int(part_worker[p]):
                    out[int(p)] = w
                    worker_load[w] += float(part_times_ms[p])
                    break
    return out
