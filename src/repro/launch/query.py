"""Granite query-serving driver: the paper's Master/Worker flow.

Master receives path queries, rewrites values to dictionary ids, asks the
cost-model planner for the split point, executes on the in-memory graph, and
returns counts/aggregates — with per-query latency accounting and an
execution budget (the paper's 600 s budget, scaled).  Throughput serving
goes through the batch-scheduler runtime (``run_workload_scheduled`` /
``repro.serving``); the legacy ``run_workload_batched`` per-server batching
mode is gone — the scheduler supersedes it with zero per-query fallbacks.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import numpy as np

from ..core import engine as E
from ..core.planner import Planner
from ..core.ref_engine import RefEngine
from ..core.stats import GraphStats
from ..graphdata.ldbc import LdbcParams, generate_ldbc, graph_name
from ..graphdata.queries import QueryInstance, make_workload


@dataclasses.dataclass
class QueryResultRecord:
    template: str
    split: int
    planned: bool
    count: float
    latency_ms: float
    ok: bool = True
    error: str = ""


class GraniteServer:
    def __init__(self, graph, use_planner: bool = True, mode: Optional[int] = None,
                 budget_s: float = 600.0, n_buckets: int = 16):
        self.graph = graph
        self.stats = GraphStats(graph, n_time_buckets=n_buckets)
        self.planner = Planner(graph, self.stats)
        self.use_planner = use_planner
        self.budget_s = budget_s
        self.n_buckets = n_buckets
        dynamic = bool(graph.meta.get("params", {}).get("dynamic", False))
        self.mode = mode if mode is not None else (
            E.MODE_BUCKET if dynamic else E.MODE_STATIC)

    def plan(self, inst: QueryInstance) -> int:
        if not self.use_planner:
            return 0 if inst.qry.agg_op != -1 else inst.qry.n_vertices - 1
        return self.planner.choose(inst.qry).split

    def warmup(self, inst: QueryInstance, split: Optional[int] = None):
        """Compile (excluded from latency, as the paper excludes load time)."""
        s = self.plan(inst) if split is None else split
        E.execute(self.graph, inst.qry, split=s, mode=self._mode_for(inst),
                  n_buckets=self.n_buckets)

    def _mode_for(self, inst: QueryInstance) -> int:
        if inst.qry.agg_op != -1 and self.mode == E.MODE_INTERVAL:
            return E.MODE_BUCKET
        return self.mode

    def execute(self, inst: QueryInstance, split: Optional[int] = None
                ) -> QueryResultRecord:
        s = self.plan(inst) if split is None else split
        t0 = time.perf_counter()
        try:
            out = E.execute(self.graph, inst.qry, split=s,
                            mode=self._mode_for(inst), n_buckets=self.n_buckets)
            total = np.asarray(out.total)
            count = float(total.sum()) if total.ndim else float(total)
            dt = (time.perf_counter() - t0) * 1e3
            ok = dt <= self.budget_s * 1e3
            return QueryResultRecord(inst.template, s, split is None, count, dt, ok)
        except Exception as e:  # pragma: no cover
            dt = (time.perf_counter() - t0) * 1e3
            return QueryResultRecord(inst.template, s, split is None, -1.0, dt,
                                     False, str(e))

    def run_workload(self, workload: List[QueryInstance], verbose=False
                     ) -> List[QueryResultRecord]:
        for inst in workload:
            self.warmup(inst)
        out = []
        for inst in workload:
            rec = self.execute(inst)
            out.append(rec)
            if verbose:
                print(f"{rec.template} split={rec.split} count={rec.count:.0f} "
                      f"{rec.latency_ms:.1f}ms")
        return out

    def run_workload_scheduled(self, workload: List[QueryInstance],
                               engine: str = "auto", warm: bool = True,
                               tracer=None, metrics=None):
        """Serve the workload through the batch-scheduler runtime (one
        vmapped call per shape group, no fallbacks).  Returns
        ``serving.ServedResult`` records in submission order.  ``tracer``/
        ``metrics`` (repro.obs) attach the flight recorder."""
        from ..serving import BatchScheduler
        sched = BatchScheduler(self.graph, engine=engine, mode=self.mode,
                               n_buckets=self.n_buckets,
                               use_planner=self.use_planner,
                               budget_s=self.budget_s,
                               tracer=tracer, metrics=metrics)
        return sched.run(workload, warm=warm)


def _serve_live(args, graph, workload, tracer, metrics):
    """``--live``: epoch-pinned serving over an ingesting event log.

    A fresh start decomposes the built graph into epoch 0 minus
    ``--holdout`` edges, attaches the WAL (when ``--wal`` is given), then
    ingests the held-out edges back across ``--epochs`` sealed epochs,
    draining the workload against each pinned snapshot.  If the WAL path
    already exists the server RECOVERS instead: the torn tail is truncated,
    sealed epochs replay, and serving resumes from the exact pre-crash
    pinned fingerprint (crash-recoverable ingestion — ROADMAP item 1e).
    """
    import os
    from ..graphdata.ingest import log_from_graph
    from ..serving import BatchScheduler, EpochManager

    held: list = []
    if args.wal and os.path.exists(args.wal):
        mgr = EpochManager.recover(args.wal, metrics=metrics, tracer=tracer)
        print(f"recovered {mgr.log.n_epochs} sealed epoch(s) from "
              f"{args.wal}: pinned fp {mgr.current.fingerprint}, "
              f"{mgr.log.n_open} open event(s) pending")
    else:
        log, held = log_from_graph(graph, holdout_edges=args.holdout,
                                   seed=args.seed)
        if args.wal:
            log.attach_wal(args.wal)
            print(f"WAL -> {args.wal}")
        mgr = EpochManager(log, metrics=metrics, tracer=tracer)

    sched = BatchScheduler(graph, engine=args.engine,
                           use_planner=not args.no_planner,
                           tracer=tracer, metrics=metrics)
    mgr.attach(sched)

    def drain(tag: str):
        recs = sched.run(workload, warm=True)
        done = sum(1 for r in recs if r.ok)
        lat = np.mean([r.latency_ms for r in recs if r.ok]) if done else 0.0
        print(f"  {tag}: fp={sched.pinned_epoch.fingerprint} "
              f"done={done}/{len(recs)} avg={lat:.2f}ms")

    drain(f"epoch {mgr.current.id}")
    if mgr.log.n_open:              # open suffix survived the crash: seal it
        ep = mgr.advance(sched)
        drain(f"epoch {ep.id} (recovered open suffix)")
    if held:
        chunks = np.array_split(np.arange(len(held)), max(args.epochs, 1))
        for ids in chunks:
            if not len(ids):
                continue
            mgr.ingest(held[int(ids[0]): int(ids[-1]) + 1])
            ep = mgr.advance(sched)
            drain(f"epoch {ep.id} (+{len(ids)} edges)")
    mgr.log.close_wal()


def main():
    """Thin CLI over the serving runtime: sequential loop (default), batched
    scheduler drain (--serve), open-loop Poisson replay (--replay), or
    live epoch-pinned serving with a crash-recoverable WAL (--live)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--persons", type=int, default=1000)
    ap.add_argument("--dist", default="facebook",
                    choices=["altmann", "weibull", "facebook", "zipf"])
    ap.add_argument("--dynamic", action="store_true")
    ap.add_argument("--queries", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0,
                    help="workload + arrival-process seed (reproducible runs)")
    ap.add_argument("--no-planner", action="store_true")
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--serve", action="store_true",
                    help="drain the workload through the batch scheduler")
    ap.add_argument("--replay", action="store_true",
                    help="open-loop Poisson replay through the scheduler")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="--replay arrival rate (queries/s)")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "dense", "sliced", "partitioned"])
    ap.add_argument("--live", action="store_true",
                    help="live-graph serving: ingest epochs from an event "
                         "log and serve each pinned snapshot")
    ap.add_argument("--wal", default=None, metavar="PATH",
                    help="--live write-ahead log; if PATH exists the server "
                         "recovers from it instead of rebuilding")
    ap.add_argument("--holdout", type=int, default=64,
                    help="--live edges held out of epoch 0 and ingested "
                         "back across --epochs live epochs")
    ap.add_argument("--epochs", type=int, default=3,
                    help="--live ingestion epochs after epoch 0")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record the query flight recorder to a trace JSONL "
                         "(render with scripts/trace_report.py)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics registry at exit (.json = JSON "
                         "snapshot, anything else = Prometheus text format)")
    args = ap.parse_args()

    params = LdbcParams(n_persons=args.persons, degree_dist=args.dist,
                        dynamic=args.dynamic)
    g = generate_ldbc(params)
    print(f"graph {graph_name(params)}: {g.subgraph_stats()}")
    server = GraniteServer(g, use_planner=not args.no_planner)
    wl = make_workload(g, n_per_template=args.queries, seed=args.seed)

    tracer = metrics = None
    if args.trace_out:
        from ..obs import Tracer
        tracer = Tracer(sink=args.trace_out)
    if args.metrics_out:
        from ..obs import MetricsRegistry
        metrics = MetricsRegistry()

    def _finish_obs():
        if tracer is not None:
            tracer.close()
            print(f"trace: {tracer.n_completed} spans -> {args.trace_out}")
        if metrics is not None:
            metrics.write(args.metrics_out)
            print(f"metrics -> {args.metrics_out}")

    if args.live:
        _serve_live(args, g, wl, tracer, metrics)
        _finish_obs()
        return

    if args.replay:
        from ..serving import BatchScheduler, replay_workload
        sched = BatchScheduler(g, engine=args.engine,
                               use_planner=not args.no_planner,
                               tracer=tracer, metrics=metrics)
        rep = replay_workload(sched, wl, rate_qps=args.rate, seed=args.seed,
                              warm=True)
        for k, v in rep.as_dict().items():
            print(f"  {k}: {v}")
        _finish_obs()
        return

    if args.serve:
        recs = server.run_workload_scheduled(wl, engine=args.engine,
                                             tracer=tracer, metrics=metrics)
        _finish_obs()
    else:
        recs = server.run_workload(wl, verbose=True)
    by_t = {}
    for r in recs:
        by_t.setdefault(r.template, []).append(r.latency_ms)
    print("\navg latency per template:")
    for t, ls in sorted(by_t.items()):
        print(f"  {t}: {np.mean(ls):8.2f} ms over {len(ls)} queries")
    if args.verify:
        ref = RefEngine(g)
        for inst, rec in zip(wl[: 8], recs[: 8]):
            want = ref.count(inst.qry, mode=server._mode_for(inst))
            want = float(np.sum(want))
            assert abs(want - rec.count) < 1e-6, (inst.template, want, rec.count)
        print("verification vs oracle: OK")


if __name__ == "__main__":
    main()
