"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  Single pod = (16, 16) data×model = 256
chips; multi-pod adds a leading pod axis = (2, 16, 16) = 512 chips.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over actually-present devices (tests / CPU benches)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
