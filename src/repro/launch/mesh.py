"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  Single pod = (16, 16) data×model = 256
chips; multi-pod adds a leading pod axis = (2, 16, 16) = 512 chips.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over actually-present devices (tests / CPU benches)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_worker_mesh(n_devices: int):
    """1-D ``workers`` mesh for the partitioned query engine: the partition
    worker axis is sharded over the first ``n_devices`` devices (forced-host
    CPU devices in tests/CI via --xla_force_host_platform_device_count, real
    chips in deployment).  The device order fixes the worker→device map, so
    the partitioner's point-to-point lane tables stay valid per process."""
    import numpy as np

    return jax.sharding.Mesh(np.asarray(jax.devices()[:n_devices]),
                             ("workers",))
