"""End-to-end training driver (examples/train_lm.py wraps this).

Runs a real (reduced-scale on CPU; production-scale on TPU) training job:
data pipeline → jitted train step (loss+grad+AdamW) → periodic async
checkpointing → fault-tolerant resume.  ``--arch`` selects any LM config;
``--smoke`` uses its reduced config.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_lm_batches(vocab: int, batch: int, seq: int, n: int, seed=0):
    """Deterministic synthetic LM data stream (zipf-ish token dist)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        toks = (rng.zipf(1.3, size=(batch, seq + 1)) % vocab).astype(np.int32)
        out.append(dict(tokens=jnp.asarray(toks[:, :-1]),
                        labels=jnp.asarray(toks[:, 1:])))
    return out


def train(arch_id: str = "minicpm-2b", steps: int = 50, smoke: bool = True,
          ckpt_dir: str = "/tmp/repro_ckpt", batch: int = 4, seq: int = 64,
          microbatches: int = 1, resume: bool = True):
    import importlib

    from ..models import transformer as tr
    from ..training import checkpoint as ckpt
    from ..training.optimizer import OptCfg, init_state
    from ..training.train_loop import make_train_step

    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    cfg = mod.SMOKE if smoke else mod.CONFIG
    opt_cfg = mod.get_arch().meta.get("opt", OptCfg())
    opt_cfg = dataclasses.replace(opt_cfg, total_steps=steps, warmup_steps=max(1, steps // 10))

    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_state(params)
    step_fn = make_train_step(lambda p, b: tr.loss_fn(cfg, p, b), opt_cfg,
                              microbatches=microbatches, donate=False)
    start = 0
    if resume and ckpt.latest_step(ckpt_dir) is not None:
        (params, opt_state), start = ckpt.restore((params, opt_state), ckpt_dir)
        print(f"resumed from step {start}")
    batches = synthetic_lm_batches(cfg.vocab, batch, seq, steps)
    losses = []
    t0 = time.time()
    for i in range(start, steps):
        params, opt_state, m = step_fn(params, opt_state, batches[i])
        losses.append(float(m["loss"]))
        if (i + 1) % 10 == 0:
            ckpt.save_async((params, opt_state), i + 1, ckpt_dir)
            print(f"step {i+1}: loss={losses[-1]:.4f} lr={float(m['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1-start)*1e3:.0f} ms/step)")
    ckpt.wait_pending()
    ckpt.save((params, opt_state), steps, ckpt_dir)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    train(args.arch, steps=args.steps, smoke=not args.full,
          ckpt_dir=args.ckpt_dir, batch=args.batch, seq=args.seq,
          microbatches=args.microbatches)


if __name__ == "__main__":
    main()
