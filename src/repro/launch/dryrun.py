import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices build the production meshes; every cell's step function is
jit-lowered with its in/out shardings, compiled, and its memory/cost/
collective analyses are written to ``experiments/dryrun/*.json`` for the
roofline report (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import ARCH_IDS, load_arch
from ..roofline.analysis import analyze_compiled, save_report
from .mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def model_flops_for(arch_id: str, shape: str, spec) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) for training; 2·N·D inference."""
    meta = spec.meta
    n = meta.get("active_params") or meta.get("params")
    if n is None:
        return None
    from ..configs import common
    if shape in common.LM_SHAPES:
        info = common.LM_SHAPES[shape]
        tokens = info["batch"] * (info["seq"] if info["kind"] != "decode" else 1)
        mult = 6.0 if info["kind"] == "train" else 2.0
        return mult * float(n) * tokens
    return None


def run_cell(arch_id: str, shape: str, mesh_kind: str, skip_existing=False) -> dict:
    tag = f"{arch_id}__{shape}__{mesh_kind}"
    out_path = os.path.join(OUT_DIR, tag + ".json")
    if skip_existing and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)
    spec = load_arch(arch_id)
    if shape in spec.skip:
        rec = dict(arch=arch_id, shape=shape, mesh=mesh_kind,
                   status="skipped", reason=spec.skip[shape])
        os.makedirs(OUT_DIR, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    t0 = time.time()
    try:
        with mesh:
            cell = spec.shapes[shape](mesh)
            fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings)
            lowered = fn.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            print(f"[{tag}] memory_analysis: {mem}")
            ca = compiled.cost_analysis()
            print(f"[{tag}] cost_analysis flops={ca.get('flops', 0):.3e} "
                  f"bytes={ca.get('bytes accessed', 0):.3e}")
            trips = None
            if hasattr(spec, "meta"):
                trips = spec.meta.get("n_layers")
            if trips is None and arch_id.startswith(
                    ("llama", "minicpm", "gemma", "olmoe", "mixtral")):
                import importlib
                mod = importlib.import_module(
                    f"repro.configs.{arch_id.replace('-', '_')}")
                trips = mod.CONFIG.n_layers
            rep = analyze_compiled(
                compiled, chips, arch_id, shape, mesh_kind,
                model_flops=model_flops_for(arch_id, shape, spec),
                scan_trips=trips,
                analytic_flops=getattr(cell, "analytic_flops", None))
        rec = rep.to_json()
        rec.update(status="ok", t_lower_s=t_lower, t_compile_s=t_compile,
                   kind=cell.kind, note=cell.note)
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec = dict(arch=arch_id, shape=shape, mesh=mesh_kind, status="error",
                   error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        print(f"[{tag}] FAILED: {rec['error']}")
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def all_cells():
    for arch_id in ARCH_IDS:
        spec = load_arch(arch_id)
        names = list(spec.shapes.keys()) + list(spec.skip.keys())
        for shape in names:
            yield arch_id, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    results = []
    if args.all:
        for arch_id, shape in all_cells():
            for mk in meshes:
                rec = run_cell(arch_id, shape, mk, args.skip_existing)
                results.append(rec)
                s = rec.get("status")
                extra = (f"bottleneck={rec.get('bottleneck')}" if s == "ok"
                         else rec.get("reason", rec.get("error", "")))
                print(f"== {arch_id:16s} {shape:14s} {mk:6s} {s:8s} {extra}")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mk in meshes:
            results.append(run_cell(args.arch, args.shape, mk))
    n_ok = sum(r.get("status") == "ok" for r in results)
    n_skip = sum(r.get("status") == "skipped" for r in results)
    n_err = sum(r.get("status") == "error" for r in results)
    print(f"DRYRUN SUMMARY: ok={n_ok} skipped={n_skip} errors={n_err}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
