"""LDBC workload replay through the batch scheduler — open- and closed-loop.

The paper's serving experiment (Table 5) drives 1600 LDBC queries and reports
latency and completion within a budget.  This harness reproduces that shape
in two load-generation modes:

  open    arrivals follow a Poisson process whose rate does NOT react to
          service times (the load generator never waits on the server), so
          queueing delay is part of measured latency — the honest way to
          report a serving system, and the mode where an overloaded queue
          grows without bound;
  closed  at most ``max_outstanding`` requests are in flight: a new query is
          issued only when a slot frees (completion, failure, or admission
          reject).  Backlog is bounded by construction — the control
          experiment against open-loop divergence.

Mechanics: a virtual clock advances over measured (or injected — see
serving/testing.py) batch service times.  At each dispatch point every
arrived query is submitted — through the admission controller when the
scheduler carries one, so rejects happen at the arrival instant — and the
scheduler drains its queue earliest-deadline-first; each query's latency is
its dispatch-chunk completion time minus its own arrival time.

Per-query deadlines: ``deadline_s`` may be a scalar (every query) or a
``(lo, hi)`` tuple (sampled uniformly per query from the replay seed —
reproducible).  The report separates COMPLETION (finished within budget)
from DEADLINE HIT (finished within its own deadline), and scores goodput as
deadline-hits per second — the SLO quantity admission control optimises.

A group that fails to dispatch (e.g. a non-sliceable query forced onto the
sliced engine) marks its queries FAILED: they are excluded from latency
percentiles and counted against completion — a failed query is not a
completed query.  An empty workload returns a well-formed all-zero report.

Structured failures: every non-done query carries its scheduler status
(FAILED / QUARANTINED / TIMEOUT — serving/faults.py) or admission verdict
(REJECTED, with the controller's reason), and the report's ``failures``
list gives (index, template, status, error) per query — never only
aggregate counts, so a silent failure cannot hide inside a rate.  Retry
backoff accounted by the fault layer rides inside ``GroupDispatch.
service_s``, so the virtual clock (and with it latency percentiles and
goodput) includes the waiting a retried query actually experienced.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from ..graphdata.queries import QueryInstance
from .scheduler import BatchScheduler

#: per-query terminal states in ``ReplayReport.statuses`` (the last two
#: come from the scheduler's fault layer — serving/faults.py)
DONE, FAILED, REJECTED = "done", "failed", "rejected"
QUARANTINED, TIMEOUT = "quarantined", "timeout"


def poisson_arrivals(n: int, rate_qps: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Cumulative arrival times (seconds) of an open-loop Poisson process."""
    assert rate_qps > 0
    return np.cumsum(rng.exponential(1.0 / rate_qps, size=n))


def _draw_deadlines(n: int, deadline_s, rng: np.random.Generator
                    ) -> np.ndarray:
    """Per-query relative deadlines: scalar, (lo, hi) uniform, or +inf."""
    if deadline_s is None:
        return np.full(n, math.inf)
    if isinstance(deadline_s, (tuple, list)):
        lo, hi = deadline_s
        return rng.uniform(float(lo), float(hi), size=n)
    return np.full(n, float(deadline_s))


@dataclasses.dataclass
class ReplayReport:
    n_queries: int
    rate_qps: float               # 0 in closed-loop mode (no external rate)
    seed: int
    wall_s: float                 # virtual makespan (arrival of first → last done)
    throughput_qps: float         # completed queries per second
    latency_ms_p50: float         # percentiles over COMPLETED queries
    latency_ms_p95: float
    latency_ms_p99: float
    latency_ms_mean: float
    completion_rate: float        # fraction of ALL queries done within budget
    budget_s: float
    mean_batch: float
    max_batch: int
    n_dispatches: int
    caches: dict
    # ---- SLO accounting (defaults describe a plain open-loop run)
    mode: str = "open"
    max_outstanding: int = 0      # closed-loop slot count (0 = open loop)
    n_completed: int = 0
    n_failed: int = 0             # dispatch raised: NOT completed
    n_rejected: int = 0           # admission refused at arrival
    n_quarantined: int = 0        # poison queries isolated by bisection
    n_timeout: int = 0            # retry budget exhausted vs EDF deadline
    n_degraded: int = 0
    reject_rate: float = 0.0
    deadline_hit_rate: float = 1.0  # fraction of ALL queries inside their own
                                    # deadline (rejects/failures are misses)
    goodput_qps: float = 0.0        # deadline hits per second
    slo: Optional[dict] = None      # scheduler.slo_report() (admission +
                                    # telemetry counters)
    latencies_ms: Optional[np.ndarray] = None   # per query, arrival order
                                                # (NaN = not completed)
    statuses: Optional[List[str]] = None        # DONE/FAILED/REJECTED/
                                                # QUARANTINED/TIMEOUT
    #: one structured record per NON-done query: {index, template, status,
    #: error} — the per-query story behind the aggregate counts
    failures: Optional[List[dict]] = None

    def as_dict(self, with_latencies: bool = False) -> dict:
        d = {k: v for k, v in dataclasses.asdict(self).items()
             if k not in ("latencies_ms", "statuses")}
        if with_latencies and self.latencies_ms is not None:
            d["latencies_ms"] = [round(float(x), 3) for x in self.latencies_ms]
        return d


def _percentile(x: np.ndarray, q: float) -> float:
    return float(np.percentile(x, q)) if x.size else 0.0


def _finish_report(
    *, n: int, mode: str, rate_qps: float, seed: int, budget: float,
    sched: BatchScheduler, t: float, arrivals: np.ndarray,
    rel_deadline: np.ndarray, latencies: np.ndarray, statuses: List[str],
    batch_sizes: List[int], n_dispatches: int, max_outstanding: int,
    errors: Optional[List[str]] = None,
    templates: Optional[List[str]] = None,
) -> ReplayReport:
    done = np.asarray([s == DONE for s in statuses], bool)
    lat_done = latencies[done]
    lat = np.where(done, latencies, np.inf)   # NaN-free for the comparisons
    completed = done & (lat <= budget * 1e3)
    hit = done & (lat <= rel_deadline * 1e3)
    wall = float(t)
    n_rejected = sum(s == REJECTED for s in statuses)
    failures = [
        dict(index=i, status=statuses[i],
             template=(templates[i] if templates is not None else ""),
             error=(errors[i] if errors is not None else ""))
        for i in range(n) if statuses[i] != DONE
    ]
    if getattr(sched, "metrics", None) is not None:
        mx = sched.metrics
        slack = mx.histogram("granite_deadline_slack_ms",
                             "per-completed-query slack vs its own deadline "
                             "(ms; finite deadlines only)")
        for i in range(n):
            if done[i] and math.isfinite(rel_deadline[i]):
                slack.observe(rel_deadline[i] * 1e3 - latencies[i])
        status_ctr = mx.counter("granite_replay_total",
                                "replayed queries by terminal status",
                                labelnames=("status",))
        for s in statuses:
            status_ctr.inc(status=s)
        mx.gauge("granite_goodput_qps",
                 "deadline hits per second, last replay").set(
            float(hit.sum()) / max(wall, 1e-12))
    return ReplayReport(
        n_queries=n,
        rate_qps=rate_qps,
        seed=seed,
        wall_s=wall,
        throughput_qps=int(done.sum()) / max(wall, 1e-12),
        latency_ms_p50=_percentile(lat_done, 50),
        latency_ms_p95=_percentile(lat_done, 95),
        latency_ms_p99=_percentile(lat_done, 99),
        latency_ms_mean=float(lat_done.mean()) if lat_done.size else 0.0,
        completion_rate=float(completed.sum()) / n if n else 0.0,
        budget_s=budget,
        mean_batch=float(np.mean(batch_sizes)) if batch_sizes else 0.0,
        max_batch=int(np.max(batch_sizes)) if batch_sizes else 0,
        n_dispatches=n_dispatches,
        caches=sched.cache_report(),
        mode=mode,
        max_outstanding=max_outstanding,
        n_completed=int(done.sum()),
        n_failed=sum(s == FAILED for s in statuses),
        n_rejected=n_rejected,
        n_quarantined=sum(s == QUARANTINED for s in statuses),
        n_timeout=sum(s == TIMEOUT for s in statuses),
        n_degraded=sched.n_degraded,
        reject_rate=n_rejected / n if n else 0.0,
        deadline_hit_rate=float(hit.sum()) / n if n else 1.0,
        goodput_qps=float(hit.sum()) / max(wall, 1e-12),
        slo=sched.slo_report(),
        latencies_ms=latencies,
        statuses=statuses,
        failures=failures,
    )


def _drain(sched: BatchScheduler, t: float, admitted: List[int],
           latencies: np.ndarray, statuses: List[str],
           arrivals: np.ndarray, batch_sizes: List[int], warm: bool,
           errors: Optional[List[str]] = None) -> Tuple[float, int]:
    """One flush: advance the virtual clock over each dispatch's service
    time (EDF order — service_s includes any accounted retry backoff),
    record completions; every non-done query takes its scheduler status
    (FAILED / QUARANTINED / TIMEOUT) and structured error — such units
    consumed no measured service and must not count as completed."""
    results = sched.flush(warm=warm)
    assert len(results) == len(admitted)
    n_disp = 0
    for disp in sched.last_dispatches:
        t += disp.service_s
        batch_sizes.append(disp.n_real)
        n_disp += 1
        for pos in disp.indices:
            qi = admitted[pos]
            latencies[qi] = (t - arrivals[qi]) * 1e3
            statuses[qi] = DONE
    for pos, r in enumerate(results):
        if r is not None and r.status != DONE:
            qi = admitted[pos]
            statuses[qi] = r.status
            if errors is not None:
                errors[qi] = r.error
    return t, n_disp


def replay_workload(
    sched: BatchScheduler,
    workload: Sequence[QueryInstance],
    rate_qps: float = 0.0,
    seed: int = 0,
    budget_s: Optional[float] = None,
    warm: bool = False,
    mode: str = "open",
    max_outstanding: int = 0,
    deadline_s: Union[None, float, Tuple[float, float]] = None,
) -> ReplayReport:
    """Drive ``workload`` through ``sched`` on a virtual clock.

    ``mode='open'`` (default) draws Poisson arrivals at ``rate_qps``;
    ``mode='closed'`` keeps at most ``max_outstanding`` requests in flight
    and ignores ``rate_qps``.  ``deadline_s`` assigns per-query deadlines
    (scalar or uniform ``(lo, hi)``), threaded through ``sched.submit`` so
    an attached admission controller sees them.  ``warm=True`` makes every
    dispatch pre-run its executable untimed (use for the measured pass after
    a cold pass has populated the caches — or directly, to exclude compile
    time the way the paper excludes load time).
    """
    if mode not in ("open", "closed"):
        raise ValueError(f"mode must be 'open' or 'closed', got {mode!r}")
    n = len(workload)
    budget = budget_s if budget_s is not None else sched.budget_s
    rng = np.random.default_rng(seed)
    if mode == "open":
        assert rate_qps > 0, "open-loop replay needs an arrival rate"
        arrivals = poisson_arrivals(n, rate_qps, rng)
    else:
        assert max_outstanding >= 1, "closed-loop replay needs slots"
        arrivals = np.zeros(n)          # filled at issue time
        rate_qps = 0.0
    rel_deadline = _draw_deadlines(n, deadline_s, rng)

    latencies = np.full(n, np.nan)
    statuses: List[Optional[str]] = [None] * n
    errors: List[str] = [""] * n
    batch_sizes: List[int] = []
    n_dispatches = 0
    t = 0.0

    def _submit(j: int, now: float) -> bool:
        """Submit query j at virtual time ``now``; False = rejected.

        The deadline clock starts at ARRIVAL (that is what the report's hit
        accounting measures), so the relative deadline handed to admission
        is what REMAINS at the submission instant — a query that already
        queued past its deadline rejects outright."""
        if math.isinf(rel_deadline[j]):
            dl = None
        else:
            dl = float(rel_deadline[j] - (now - arrivals[j]))
        dec = sched.submit(workload[j], deadline_s=dl, now=now)
        if dec is not None and not dec.admitted:
            statuses[j] = REJECTED
            errors[j] = dec.reason
            return False
        return True

    if mode == "open":
        i = 0                   # next not-yet-admitted arrival
        while i < n:
            if t < arrivals[i]:
                t = float(arrivals[i])
            # admit everything that has arrived by the dispatch point
            admitted: List[int] = []
            j = i
            while j < n and arrivals[j] <= t:
                if _submit(j, t):
                    admitted.append(j)
                j += 1
            i = j
            t, nd = _drain(sched, t, admitted, latencies, statuses,
                           arrivals, batch_sizes, warm, errors)
            n_dispatches += nd
    else:
        # batch-synchronous closed loop: issue up to ``max_outstanding``,
        # wait for the whole wave (flush resolves every admitted entry —
        # completion, failure, or reject frees the slot), issue the next.
        issued = 0
        while issued < n:
            admitted = []
            while issued < n and len(admitted) < max_outstanding:
                arrivals[issued] = t
                if _submit(issued, t):
                    admitted.append(issued)
                issued += 1
            if not admitted:
                continue        # a wave of rejects; keep issuing
            t, nd = _drain(sched, t, admitted, latencies, statuses,
                           arrivals, batch_sizes, warm, errors)
            n_dispatches += nd

    return _finish_report(
        n=n, mode=mode, rate_qps=rate_qps, seed=seed, budget=budget,
        sched=sched, t=t, arrivals=arrivals, rel_deadline=rel_deadline,
        latencies=latencies, statuses=statuses, batch_sizes=batch_sizes,
        n_dispatches=n_dispatches, max_outstanding=max_outstanding,
        errors=errors,
        templates=[getattr(w, "template", "adhoc") for w in workload])
