"""Open-loop LDBC workload replay through the batch scheduler.

The paper's serving experiment (Table 5) drives 1600 LDBC queries and reports
latency and completion within a budget.  This harness reproduces that shape
as an *open-loop* experiment: arrivals follow a Poisson process whose rate
does NOT react to service times (the load generator never waits on the
server), so queueing delay is part of measured latency — the honest way to
report a serving system.

Mechanics: arrival times are pre-drawn (reproducible via the workload seed);
a virtual clock advances over measured batch service times.  At each
dispatch point every query that has arrived joins the admission queue; the
scheduler drains it group by group (one vmapped engine call each), and each
query's latency is its group's completion time minus its own arrival time.
If the queue is empty the clock jumps to the next arrival.  Backlog grows →
batches grow → per-query cost shrinks: the amortisation the shape-bucketed
scheduler exists to exploit.

Report: p50/p95/p99 latency, throughput, completion-rate-within-budget, mean
batch size, and the cache counters proving steady state re-plans and
re-traces nothing.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from ..graphdata.queries import QueryInstance
from .scheduler import BatchScheduler


def poisson_arrivals(n: int, rate_qps: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Cumulative arrival times (seconds) of an open-loop Poisson process."""
    assert rate_qps > 0
    return np.cumsum(rng.exponential(1.0 / rate_qps, size=n))


@dataclasses.dataclass
class ReplayReport:
    n_queries: int
    rate_qps: float
    seed: int
    wall_s: float                 # virtual makespan (arrival of first → last done)
    throughput_qps: float
    latency_ms_p50: float
    latency_ms_p95: float
    latency_ms_p99: float
    latency_ms_mean: float
    completion_rate: float        # fraction done within budget_s
    budget_s: float
    mean_batch: float
    max_batch: int
    n_dispatches: int
    caches: dict
    latencies_ms: Optional[np.ndarray] = None   # per query, arrival order

    def as_dict(self, with_latencies: bool = False) -> dict:
        d = {k: v for k, v in dataclasses.asdict(self).items()
             if k != "latencies_ms"}
        if with_latencies and self.latencies_ms is not None:
            d["latencies_ms"] = [round(float(x), 3) for x in self.latencies_ms]
        return d


def replay_workload(
    sched: BatchScheduler,
    workload: Sequence[QueryInstance],
    rate_qps: float,
    seed: int = 0,
    budget_s: Optional[float] = None,
    warm: bool = False,
) -> ReplayReport:
    """Drive ``workload`` through ``sched`` at ``rate_qps`` open-loop.

    ``warm=True`` makes every dispatch pre-run its executable untimed (use
    for the measured pass after a cold pass has populated the caches — or
    directly, to exclude compile time the way the paper excludes load time).
    """
    n = len(workload)
    budget = budget_s if budget_s is not None else sched.budget_s
    rng = np.random.default_rng(seed)
    arrivals = poisson_arrivals(n, rate_qps, rng)

    latencies = np.zeros(n)
    t = 0.0
    i = 0                       # next not-yet-admitted arrival
    batch_sizes: List[int] = []
    n_dispatches = 0
    while i < n:
        if t < arrivals[i]:
            t = float(arrivals[i])
        # admit everything that has arrived by the dispatch point
        j = i
        while j < n and arrivals[j] <= t:
            sched.submit(workload[j])
            j += 1
        admitted = list(range(i, j))
        i = j
        results = sched.flush(warm=warm)
        assert len(results) == len(admitted)
        # groups complete in dispatch order; members of a group share its
        # completion time
        for disp in sched.last_dispatches:
            t += disp.service_s
            batch_sizes.append(disp.n_real)
            n_dispatches += 1
            for pos in disp.indices:
                qi = admitted[pos]
                latencies[qi] = (t - arrivals[qi]) * 1e3

    wall = float(t - 0.0)
    lat = latencies
    return ReplayReport(
        n_queries=n,
        rate_qps=rate_qps,
        seed=seed,
        wall_s=wall,
        throughput_qps=n / max(wall, 1e-12),
        latency_ms_p50=float(np.percentile(lat, 50)),
        latency_ms_p95=float(np.percentile(lat, 95)),
        latency_ms_p99=float(np.percentile(lat, 99)),
        latency_ms_mean=float(lat.mean()),
        completion_rate=float(np.mean(lat <= budget * 1e3)),
        budget_s=budget,
        mean_batch=float(np.mean(batch_sizes)) if batch_sizes else 0.0,
        max_batch=int(np.max(batch_sizes)) if batch_sizes else 0,
        n_dispatches=n_dispatches,
        caches=sched.cache_report(),
        latencies_ms=lat,
    )
