"""Deadline admission control: predict at the door, reject or degrade —
never queue unboundedly.

The paper's serving claim is *completion*: every admitted query finishes
within budget.  Under sustained overload an open-loop queue cannot deliver
that — latency grows without bound and the deadline is missed by everything.
This module closes the loop at admission time: each arriving query carries a
deadline, the fitted cost model (``Planner.estimate`` — the same θ the
serving telemetry refits online) predicts its service cost and the predicted
backlog already admitted ahead of it, and the controller decides:

  admit     predicted completion (wait + service) fits inside the deadline
            with ``headroom`` to spare;
  degrade   it does not fit as-is, but a rung of the degradation ladder
            makes it fit: a cheaper hop-delivery impl (the fitted per-impl
            θ_scatter slopes say which), a dense→sliced engine downgrade
            (smaller typed extents — same bit-identical answer), and a
            bounded dispatch quantum (``degrade_max_batch`` caps the group
            chunk the query rides in, so EDF can interleave urgent work
            instead of waiting out one huge vmapped call);
  reject    no rung fits — refuse NOW, at predicted cost zero, rather than
            burn service time on a query that will miss its deadline anyway
            (goodput over throughput).

Backlog accounting is intentionally simple and conservative: the sum of
predicted costs of everything admitted since the last flush (the scheduler
resets it via ``on_flush`` when the queue drains).  Predictions come from
the live planner coefficients, so an online θ refit (serving/telemetry.py)
tightens admission decisions as serving proceeds.

Every decision is deterministic given (queue state, θ) — the FakeDispatcher
test harness (serving/testing.py) pins exact admit/degrade/reject sequences
on a virtual clock.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from ..core import engine_sliced as ES
from ..core.planner import HOP_IMPL_CHOICES

ADMIT, DEGRADE, REJECT = "admit", "degrade", "reject"


@dataclasses.dataclass
class AdmissionPolicy:
    """Knobs of the admission controller (README: "degradation ladder")."""
    #: deadline assigned when submit() gives none (seconds, relative)
    default_deadline_s: float = 600.0
    #: admit when wait + service <= headroom · deadline — < 1.0 keeps slack
    #: for prediction error (the telemetry report says how much is needed)
    headroom: float = 0.8
    #: hard bound on predicted queued work (seconds); None = deadline-driven
    max_backlog_s: Optional[float] = None
    #: ladder rung 1 — sweep these impls for a cheaper lowering (fitted
    #: per-impl θ_scatter slopes); () disables the rung
    degrade_impls: Tuple[str, ...] = HOP_IMPL_CHOICES
    #: ladder rung 2 — dense→sliced downgrade when the query is sliceable
    allow_engine_downgrade: bool = True
    #: predicted-cost multiplier of the sliced downgrade (typed extents are
    #: strictly smaller than whole-graph extents; refit-calibrated hosts can
    #: tighten this)
    sliced_discount: float = 0.7
    #: ladder rung 3 — cap the dispatch quantum of degraded queries so EDF
    #: interleaves at finer grain; None disables the rung
    degrade_max_batch: Optional[int] = 8


@dataclasses.dataclass
class AdmissionDecision:
    """What the controller decided for one query, and why."""
    action: str                   # ADMIT | DEGRADE | REJECT
    reason: str
    deadline: float               # absolute deadline assigned (inf = none)
    predicted_s: float            # predicted service cost of this query
    predicted_wait_s: float       # predicted backlog ahead of it
    impl: Optional[str] = None    # degradation overrides (None = scheduler
    engine: Optional[str] = None  # defaults)
    max_batch: Optional[int] = None
    #: degradation-ladder rungs taken, in order (e.g. "impl=pallas",
    #: "engine=sliced") — the flight recorder's admit-span and
    #: admission-metric labels; empty for plain admits and rejects
    rungs: Tuple[str, ...] = ()

    @property
    def admitted(self) -> bool:
        return self.action != REJECT


class AdmissionController:
    """Stateful deadline admission for one BatchScheduler.

    Life of a decision (``decide``, called from ``BatchScheduler.submit``
    before anything is enqueued): resolve the query's engine/mode and the
    plan it *would* run at → predict its service cost with the scheduler's
    live (possibly refit) cost model → compare ``predicted wait + service``
    against ``headroom · deadline``.  If it fits, ADMIT; otherwise walk the
    degradation ladder (cheaper hop impl → dense→sliced downgrade → bounded
    dispatch quantum) and admit DEGRADEd at the first fitting rung; if no
    rung fits, REJECT at submit time — zero service cost spent, goodput
    over throughput.

    State is one number: ``backlog_ms``, the summed predicted cost admitted
    since the last flush (``on_flush`` zeroes it).  That makes decisions
    deterministic given the submission sequence — the property the
    virtual-clock SLO tests pin exact admit/degrade/reject traces on.

    The scheduler owns the planner and the plan cache; the controller only
    reads them (``peek`` — admission must not poison the batch-aware plan
    cache with single-query plans).  It holds no graph state at all, so
    epoch pinning (``BatchScheduler.pin_epoch``) never invalidates it: cost
    predictions track the scheduler's planner, which rebases only at
    compaction.
    """

    def __init__(self, policy: Optional[AdmissionPolicy] = None):
        self.policy = policy or AdmissionPolicy()
        self.backlog_ms = 0.0     # predicted cost queued since last flush
        self.n_admitted = 0
        self.n_degraded = 0
        self.n_rejected = 0

    # ------------------------------------------------------------- lifecycle
    def on_flush(self) -> None:
        """The scheduler drained its queue: predicted backlog is gone."""
        self.backlog_ms = 0.0

    # -------------------------------------------------------------- decision
    def _planned(self, sched, qry, engine: str, mode: int):
        """(split, impl) the group would run at — the cached batch-aware plan
        when one exists, the scheduler's defaults otherwise (admission never
        writes the plan cache)."""
        from .compile import bucket_key
        fixed = None if sched.impl == "auto" else sched.impl
        plan = sched.plan_cache.peek(
            sched._plan_key(bucket_key(qry), mode, engine, sched.impl))
        if plan is not None:
            return plan[0], plan[1]
        import repro.core.query as Q
        split = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
        return split, fixed or "xla"

    def _cost_ms(self, sched, qry, engine: str, split: int, impl: str) -> float:
        return float(sched._planner_for(engine).estimate(qry, split, impl).t_ms)

    def decide(self, sched, inst, now: float,
               deadline_s: Optional[float]) -> AdmissionDecision:
        pol = self.policy
        rel = pol.default_deadline_s if deadline_s is None else float(deadline_s)
        deadline = math.inf if math.isinf(rel) else now + rel
        qry = inst.qry
        engine = sched._engine_for(qry)
        mode = sched._mode_for(qry)
        split, impl = self._planned(sched, qry, engine, mode)
        cost_ms = self._cost_ms(sched, qry, engine, split, impl)
        wait_s = self.backlog_ms / 1e3

        def fits(c_ms: float) -> bool:
            if (pol.max_backlog_s is not None
                    and wait_s + c_ms / 1e3 > pol.max_backlog_s):
                return False
            return wait_s + c_ms / 1e3 <= pol.headroom * rel

        if fits(cost_ms):
            self.n_admitted += 1
            self.backlog_ms += cost_ms
            return AdmissionDecision(ADMIT, "fits", deadline, cost_ms / 1e3,
                                     wait_s)

        # ---- degradation ladder: cheaper impl → sliced engine → bounded
        # dispatch quantum; taken cumulatively, first fitting rung wins
        deg_impl: Optional[str] = None
        deg_engine: Optional[str] = None
        best_ms = cost_ms
        rungs = []
        if pol.degrade_impls:
            for cand in pol.degrade_impls:
                if cand == impl:
                    continue
                c = self._cost_ms(sched, qry, engine, split, cand)
                if c < best_ms:
                    best_ms, deg_impl = c, cand
            if deg_impl is not None:
                rungs.append(f"impl={deg_impl}")
        if (pol.allow_engine_downgrade and engine == "dense"
                and ES.sliceable(qry)):
            best_ms *= pol.sliced_discount
            deg_engine = "sliced"
            rungs.append("engine=sliced")
        if fits(best_ms):
            if pol.degrade_max_batch is not None:
                rungs.append(f"quantum={pol.degrade_max_batch}")
            self.n_degraded += 1
            self.backlog_ms += best_ms
            return AdmissionDecision(
                DEGRADE, "degraded: " + ",".join(rungs), deadline,
                best_ms / 1e3, wait_s, impl=deg_impl, engine=deg_engine,
                max_batch=pol.degrade_max_batch, rungs=tuple(rungs))

        self.n_rejected += 1
        return AdmissionDecision(
            REJECT,
            f"predicted wait {wait_s:.3f}s + service {best_ms / 1e3:.3f}s "
            f"exceeds {pol.headroom:g}·deadline {rel:.3f}s",
            deadline, best_ms / 1e3, wait_s)

    # ------------------------------------------------------------- reporting
    def report(self) -> dict:
        return dict(n_admitted=self.n_admitted, n_degraded=self.n_degraded,
                    n_rejected=self.n_rejected,
                    backlog_ms=round(self.backlog_ms, 6))
