"""Serving caches: plans and compiled executables.

Steady-state serving must not re-plan or re-trace.  Two caches make that an
invariant the scheduler can assert on, with hit/miss counters the replay
harness reports:

  PlanCache        (shape bucket, graph fingerprint, mode, engine[, workers])
                   → chosen (split, hop impl).  The first batch of a bucket
                   pays one batch-aware planner pass; every later batch
                   reuses it.
  ExecutableCache  full dispatch key (plan key + hop-layout signature +
                   padded batch size) → the bound batched executable from
                   the engines.  Together with pow-2 size buckets
                   (compile.py) this caps compilations per shape bucket at
                   log2(max batch size).

The graph fingerprint keys cache entries to graph *content* rather than
object identity, so a regenerated-but-identical graph still hits while a
different graph cannot alias (the engines' own jit caches key on ``id()``,
which is only safe within one graph object's lifetime).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, Optional

from ..core import engine as _E
from ..core import engine_partitioned as _EP
from ..core import engine_sliced as _ES


def graph_fingerprint(graph) -> str:
    """Content fingerprint of a graph, cached on the graph object.

    Covers everything query results depend on: topology, types, vertex/edge
    lifespans, and the property columns (K_PROP clauses and MIN/MAX
    aggregation read them) — two graphs may only share a fingerprint if every
    engine answer over them is identical."""
    fp = getattr(graph, "_serving_fingerprint", None)
    if fp is None:
        h = hashlib.sha1()
        h.update(repr((graph.n_vertices, graph.n_edges, graph.lifespan,
                       graph.n_vertex_types, graph.n_edge_types)).encode())
        for arr in (graph.v_type, graph.v_life, graph.e_src, graph.e_dst,
                    graph.e_type, graph.e_life):
            h.update(arr.tobytes())
        for name, props in (("v", graph.vprops), ("e", graph.eprops)):
            for key in sorted(props):
                col = props[key]
                h.update(f"{name}{key}".encode())
                h.update(col.vals.tobytes())
                h.update(col.life.tobytes())
        fp = h.hexdigest()[:16]
        graph._serving_fingerprint = fp
    return fp


def layout_signature(graph, engine: str, qry, n_workers: int,
                     impl: str) -> tuple:
    """The static hop-kernel layout identity a compiled executable binds.

    On the kernel path (``impl != 'xla'``) an executable closes over a
    ``kernels.hop_scatter`` block layout — dense whole-graph, per-arrival-
    type slices, or stacked per-worker shards — so the layout's shape is
    part of the dispatch key: two graphs may share a content fingerprint yet
    be served by different block shapes only if the key says so.  Building
    the signature warms the same per-graph layout caches the engine
    executable will read (layouts are host-static: cached alongside the
    plan, never retraced)."""
    if impl == "xla":
        return ()
    if engine == "partitioned":
        _, arrays, _ = _EP.partition_for(graph, n_workers)
        tables, block_v = arrays.worker_hop_layouts()
        return ("worker_hop_layout", tuple(tables["hop_ldst"].shape), block_v)
    if engine == "sliced":
        sb = _ES.SliceBounds.from_graph(graph)
        layouts = _ES.slice_layouts_for(graph, qry, sb, impl)
        return tuple(sorted(
            (vt,) + lay.signature() for vt, lay in layouts.items()))
    return _E.hop_layout_for(graph).signature()


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    invalidations: int = 0   # whole-cache clears (online θ refits) count 1;
                             # targeted evictions (epoch compaction) count
                             # one per dropped entry

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    def as_dict(self) -> dict:
        return dict(hits=self.hits, misses=self.misses,
                    invalidations=self.invalidations)


class PlanCache:
    """(shape bucket, graph fingerprint, ...) → (split point, hop impl)."""

    def __init__(self):
        self._plans: Dict[tuple, tuple] = {}
        self.stats = CacheStats()

    def get(self, key: tuple) -> Optional[tuple]:
        plan = self._plans.get(key)
        if plan is None:
            self.stats.misses += 1
        else:
            self.stats.hits += 1
        return plan

    def put(self, key: tuple, plan: tuple) -> None:
        self._plans[key] = plan

    def peek(self, key: tuple) -> Optional[tuple]:
        """Lookup WITHOUT touching the hit/miss counters — for admission
        control, which consults the cache but must not skew the steady-state
        no-replan invariant the counters assert."""
        return self._plans.get(key)

    def clear(self) -> None:
        """Drop every cached plan (an online θ refit invalidates them: the
        best split may have moved).  Counters are kept — clears are part of
        the serving history, not a reset of it (``invalidations`` counts
        them)."""
        self._plans.clear()
        self.stats.invalidations += 1

    def evict(self, pred: Callable[[tuple], bool]) -> int:
        """Targeted invalidation: drop entries whose KEY matches ``pred``;
        returns the count.  Unlike ``clear`` (one whole-cache event), every
        evicted entry counts as one invalidation — the delta-aware path
        (serving/epochs.py) evicts only keys mentioning retired fingerprints
        at compaction, and the counters are how tests assert that nothing
        else was touched."""
        dead = [k for k in self._plans if pred(k)]
        for k in dead:
            del self._plans[k]
        self.stats.invalidations += len(dead)
        return len(dead)

    def __len__(self) -> int:
        return len(self._plans)


class ExecutableCache:
    """Dispatch key → bound batched executable (``fn(params) -> ExecOutput``).

    ``get_or_build`` runs ``builder`` exactly once per key; the builder
    returns the engine's batched callable already bound to graph/plan/mode.
    """

    def __init__(self):
        self._fns: Dict[tuple, Callable] = {}
        self.stats = CacheStats()

    def get_or_build(self, key: tuple, builder: Callable[[], Callable]):
        fn = self._fns.get(key)
        if fn is None:
            self.stats.misses += 1
            fn = builder()
            self._fns[key] = fn
        else:
            self.stats.hits += 1
        return fn

    def __contains__(self, key: tuple) -> bool:
        return key in self._fns

    def evict(self, pred: Callable[[tuple], bool]) -> int:
        """Targeted invalidation mirroring ``PlanCache.evict`` (one
        invalidation per dropped executable)."""
        dead = [k for k in self._fns if pred(k)]
        for k in dead:
            del self._fns[k]
        self.stats.invalidations += len(dead)
        return len(dead)

    def __len__(self) -> int:
        return len(self._fns)
