"""Plan-tensor compiler: query batches → padded parameter tensors.

The engine jits with the query *structure* static and the *parameters* as
data (core/query.py), so every group of instances sharing ``shape_key()``
can run as one stacked tensor batch.  This module is the lowering step the
scheduler feeds the engines:

  * ``bucket_key(qry)`` — the shape bucket an instance lands in (the jit /
    executable-cache key component);
  * ``compile_plan_tensor(queries)`` — stack the per-instance parameter rows
    into one int32[B_pad, n_clauses, 3] tensor, padding the batch axis up to
    the next power of two.

Why pad: a vmapped executable is specialised on B, so free-running batch
sizes would retrace per distinct group size.  Rounding B up to pow-2 size
buckets bounds the executables per shape bucket at log2(max batch) — after a
short warm phase the compiled-executable cache (cache.py) absorbs every
dispatch.  Pad slots repeat the first instance's parameters (any valid row
works: batch elements are independent under vmap) and are sliced off the
outputs by the scheduler.

The same tensor feeds the shard_map-native partitioned path unchanged: the
batch axis is vmapped INSIDE the shard_map body (params replicated across
the worker mesh), so padding needs no device-count awareness — only the
executable-cache key does (scheduler.py adds the resolved device count).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from ..core import query as Q


def bucket_key(qry: Q.PathQuery) -> tuple:
    """The shape bucket of an instance: its hashable structural key."""
    return qry.shape_key()


def pad_batch_size(n: int) -> int:
    """Next power-of-two size bucket (1 → 1, 3 → 4, 5 → 8, ...)."""
    assert n >= 1
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class PlanTensor:
    """One shape bucket's batch, lowered to the stacked parameter tensor."""
    key: tuple                 # shape bucket (queries[0].shape_key())
    queries: List[Q.PathQuery]
    params: np.ndarray         # int32[B_pad, n_clauses, 3]
    n_real: int                # live instances; rows [n_real:] are padding

    @property
    def n_pad(self) -> int:
        return self.params.shape[0] - self.n_real


def compile_plan_tensor(queries: Sequence[Q.PathQuery],
                        pad: bool = True) -> PlanTensor:
    """Lower a same-shape batch into one padded parameter tensor."""
    from ..core.engine import check_batch_shape
    key = check_batch_shape(queries)
    rows = np.stack([Q.query_params(q) for q in queries])
    n_real = rows.shape[0]
    if pad:
        b_pad = pad_batch_size(n_real)
        if b_pad > n_real:
            fill = np.broadcast_to(rows[:1], (b_pad - n_real,) + rows.shape[1:])
            rows = np.concatenate([rows, fill], axis=0)
    return PlanTensor(key, list(queries), np.ascontiguousarray(rows), n_real)
