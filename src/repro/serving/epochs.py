"""Epoch-pinned snapshot serving over a streaming event log.

``EpochManager`` is the control loop between a ``graphdata.ingest.EventLog``
and a ``BatchScheduler``: ingest events → seal an epoch → materialize it
incrementally → decide compaction → retire stale cache entries → pin the
scheduler.  Queries keep serving during ingestion because pinning is the
ONLY point where the serving graph changes, and every pinned epoch is an
immutable snapshot (bit-identical to a from-scratch build of its graph —
the conformance harness's ingestion leg).

Fingerprint model (the delta-aware cache invalidation of ROADMAP item 1):

  epoch fingerprint   chained ``events_fingerprint``: hash(prev fp + the
                      epoch's events in canonical order).  O(delta) per
                      epoch; identifies graph *content* because replay is
                      deterministic.  Keys merged-graph executables.
  base fingerprint    ``graph_fingerprint`` of the last compacted graph.
                      Keys plans and base+delta executables — both survive
                      every pure edge-append epoch unchanged, which is why
                      steady-state ingestion costs zero recompilation.
  part fingerprints   one per vertex type, evolved only when an epoch
                      touches that type (vertex events → the vertex's type,
                      edge events → both endpoint types).  The per-
                      partition half of "invalidate only what changed":
                      consumers holding per-type artifacts compare these
                      instead of the whole-graph fingerprint.

Compaction policy: epoch 0 always compacts (it IS the base); afterwards a
window closes when it stops being delta-pure, when ``compact_every`` epochs
have accumulated, or when the delta outgrows ``max_delta_frac`` of the base
edge count — whichever comes first (or on an explicit ``compact=True``).
Compaction re-bases the materializer, recomputes the base fingerprint, and
evicts exactly the cache entries whose keys mention a retired fingerprint
(counted per entry in ``granite_cache_total{event="invalidation"}``).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterable, List, Optional

from ..graphdata.ingest import (EV_ADD_EDGE, EV_ADD_VERTEX, EV_CLOSE_EDGE,
                                EV_CLOSE_VERTEX, EV_SET_EPROP, EV_SET_VPROP,
                                DeltaSpec, Event, EventLog, Materializer,
                                events_fingerprint)
from ..obs.trace import NULL_TRACER
from .cache import graph_fingerprint


@dataclasses.dataclass
class Epoch:
    """One sealed, immutable snapshot of the evolving graph."""
    id: int
    n_events: int                     # events sealed into this epoch
    fingerprint: str                  # chained event fingerprint (content id)
    base_fingerprint: str             # fingerprint of the compaction base
    part_fingerprints: Dict[int, str]  # vertex type → per-partition fp
    graph: object                     # the epoch's merged TemporalGraph
    base_graph: object                # compaction base (== graph right after
                                      # a compaction)
    delta: Optional[DeltaSpec]        # pure edge-append window, else None
    compacted: bool                   # this seal closed a compaction window
    n_delta_edges: int                # edges appended since the base


def _mentions(key, fps: frozenset) -> bool:
    """Does a (nested-tuple) cache key mention any retired fingerprint?"""
    if isinstance(key, tuple):
        return any(_mentions(k, fps) for k in key)
    return isinstance(key, str) and key in fps


class EpochManager:
    """Streams events into an ``EventLog`` and serves sealed epochs.

    Typical loop (see docs/ingestion.md and the serving bench's ingest leg)::

        log, _ = ingest.log_from_graph(seed_graph)    # or a fresh EventLog
        mgr = EpochManager(log, metrics=registry)
        e0 = mgr.seal()                               # epoch 0 == the base
        sched = BatchScheduler(e0.graph, metrics=registry)
        mgr.attach(sched)                             # pins e0
        while serving:
            mgr.ingest(new_events)
            mgr.advance(sched)     # seal → materialize → evict → pin
            sched.run(batch)       # answers AS OF the pinned epoch

    ``seal``/``advance`` are the only methods that change what queries see;
    between them ``ingest`` can run freely (unsealed events are invisible
    to every pinned scheduler — snapshot isolation is structural, not
    locked: each epoch is a fresh immutable graph object).
    """

    def __init__(self, log: EventLog, compact_every: int = 8,
                 max_delta_frac: float = 0.5, metrics=None, tracer=None):
        self.log = log
        self.mat = Materializer(log)
        self.compact_every = int(compact_every)
        self.max_delta_frac = float(max_delta_frac)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        self.current: Optional[Epoch] = None
        self.n_compactions = 0
        self._since_compact = 0
        self._window_fps: List[str] = []   # fingerprints live in this window
        self._part_fps: Dict[int, str] = {}
        if metrics is not None:
            self._mx_events = metrics.counter(
                "granite_ingest_events_total", "events ingested into the log")
            self._mx_epochs = metrics.counter(
                "granite_epochs_total", "epochs sealed")
            self._mx_compactions = metrics.counter(
                "granite_compactions_total", "compaction windows closed")
            self._mx_delta_edges = metrics.gauge(
                "granite_delta_edges", "edges appended since the base")
            self._mx_cache = metrics.counter(
                "granite_cache_total", "serving cache events",
                labelnames=("cache", "event"))
            self._mx_recovery = metrics.counter(
                "granite_recovery_epochs",
                "sealed epochs replayed from a WAL at crash recovery")

    # ------------------------------------------------------------- ingest
    def ingest(self, events: Iterable[Event]) -> int:
        """Append events to the open (unsealed) suffix of the log.  Pinned
        epochs cannot observe them until the next ``seal``/``advance``."""
        sp = self.tracer.start("ingest")
        n = self.log.extend(events)
        self.tracer.end(sp, n_events=n)
        if self.metrics is not None:
            self._mx_events.inc(n)
        return n

    # ------------------------------------------------------------- sealing
    def _touched_types(self, events) -> set:
        """Vertex types an epoch's events touch (computed AFTER the epoch is
        applied, so every key resolves)."""
        out = set()
        for ev in events:
            if ev.kind == EV_ADD_VERTEX:
                out.add(ev.data[0])
            elif ev.kind in (EV_SET_VPROP, EV_CLOSE_VERTEX):
                out.add(self.mat.vertex_type_of_key(ev.key))
            elif ev.kind in (EV_ADD_EDGE, EV_SET_EPROP, EV_CLOSE_EDGE):
                out.update(self.mat.edge_endpoint_types(ev.key))
        return out

    def _should_compact(self) -> bool:
        if not self.mat.delta_pure:
            return True
        if self._since_compact + 1 >= self.compact_every:
            return True
        base_e = max(1, self.mat.base_n_edges)
        delta_e = self.mat.graph.n_edges - self.mat.base_n_edges
        return delta_e > self.max_delta_frac * base_e

    def seal(self, compact: Optional[bool] = None) -> Epoch:
        """Seal the open suffix as the next epoch and materialize it.

        ``compact`` forces (True) or suppresses (False) compaction; None
        applies the policy.  Epoch 0 always compacts — it is the base."""
        # The log may already hold sealed-but-unapplied epochs (e.g. epoch 0
        # from ``log_from_graph``); only seal the open suffix when there is
        # nothing pending, and always read the events of the epoch actually
        # being applied — sealing unconditionally would drift seal() one
        # epoch ahead of apply_next().
        fresh = self.mat.applied >= self.log.n_epochs
        if fresh:
            self.log.seal()
        sp = self.tracer.start("epoch", id=self.mat.applied)
        events = self.log.epoch_events(self.mat.applied)
        ms = self.tracer.start("materialize", parent=sp)
        g = self.mat.apply_next()
        self.tracer.end(ms, n_vertices=g.n_vertices, n_edges=g.n_edges)
        eid = self.mat.applied - 1
        first = self.current is None
        if first:
            fp = graph_fingerprint(g)
        else:
            fp = events_fingerprint(self.current.fingerprint, events)
        do_compact = first or (self._should_compact() if compact is None
                               else bool(compact))
        touched = self._touched_types(events)
        if do_compact:
            cs = self.tracer.start("compact", parent=sp,
                                   n_delta_edges=(g.n_edges
                                                  - self.mat.base_n_edges))
            self.mat.compact()
            base_fp = graph_fingerprint(g)
            # per-partition fingerprints restart from the new base content
            self._part_fps = {
                t: hashlib.sha1(f"{base_fp}/{t}".encode()).hexdigest()[:16]
                for t in range(g.n_vertex_types)}
            self._since_compact = 0
            self.n_compactions += 1
            if not first:
                self.tracer.end(cs)
            else:
                self.tracer.end(cs, bootstrap=True)
            if self.metrics is not None:
                self._mx_compactions.inc()
        else:
            base_fp = self.current.base_fingerprint
            self._since_compact += 1
            # evolve exactly the touched partitions' fingerprints
            self._part_fps = dict(self._part_fps)
            for t in touched:
                prev = self._part_fps.get(t, "")
                self._part_fps[t] = hashlib.sha1(
                    f"{prev}+{fp}".encode()).hexdigest()[:16]
        if fresh and getattr(self.log, "_wal", None) is not None:
            # journal the decision (policy or forced) so ``recover`` replays
            # it exactly — the recovered base fingerprint must match even
            # when a caller forced compaction off-policy
            self.log.wal_note(eid, compacted=bool(do_compact))
        delta = None if do_compact else self.mat.delta_spec()
        n_delta = g.n_edges - self.mat.base_n_edges
        hint = self.mat.partition_hint()
        if hint is not None:
            g._partition_hint = hint
        ep = Epoch(eid, len(events), fp, base_fp, dict(self._part_fps), g,
                   self.mat.base_graph, delta, do_compact, n_delta)
        self._window_fps.append(fp)
        self.current = ep
        self.tracer.end(sp, fingerprint=fp, compacted=do_compact,
                        n_delta_edges=n_delta)
        if self.metrics is not None:
            self._mx_epochs.inc()
            self._mx_delta_edges.set(n_delta)
        return ep

    # ------------------------------------------------------------ recovery
    @classmethod
    def recover(cls, path, compact_every: int = 8,
                max_delta_frac: float = 0.5, metrics=None, tracer=None,
                fault_plan=None) -> "EpochManager":
        """Rebuild a manager from a WAL after a crash.

        ``EventLog.from_wal`` truncates the torn tail and restores sealed
        epochs + the open suffix; the manager then replays every sealed
        epoch through ``seal`` — compaction decisions come from the
        journaled ``wal_note`` records (policy decisions replay identically
        anyway given the same ``compact_every``/``max_delta_frac``).
        Replay is deterministic, so the recovered pinned epoch's
        fingerprint is bit-identical to the pre-crash one (pinned by
        tests/test_serving_faults.py and the chaos bench leg).  The WAL is
        re-attached in append mode: ingestion continues where it left off.
        """
        from ..graphdata.ingest import EventLog
        log, notes = EventLog.from_wal(path, fault_plan=fault_plan)
        mgr = cls(log, compact_every=compact_every,
                  max_delta_frac=max_delta_frac, metrics=metrics,
                  tracer=tracer)
        decisions = {int(n["epoch"]): bool(n["compacted"])
                     for n in notes if "compacted" in n}
        n_sealed = log.n_epochs
        sp = mgr.tracer.start("recover", path=str(path), n_epochs=n_sealed,
                              n_open=log.n_open)
        for i in range(n_sealed):
            mgr.seal(compact=decisions.get(i))
        mgr.tracer.end(sp, fingerprint=(mgr.current.fingerprint
                                        if mgr.current else None))
        if metrics is not None and n_sealed:
            mgr._mx_recovery.inc(n_sealed)
        return mgr

    # ------------------------------------------------------------- serving
    def attach(self, scheduler) -> None:
        """Pin ``scheduler`` to the current epoch (seals epoch 0 first if
        the log has open events and nothing was ever sealed)."""
        if self.current is None:
            self.seal()
        scheduler.pin_epoch(self.current)

    def advance(self, scheduler, compact: Optional[bool] = None) -> Epoch:
        """Seal the next epoch, retire stale cache entries, pin the
        scheduler.  The serving-loop step: everything submitted after this
        call answers AS OF the new epoch.

        Cache handling is delta-aware: a non-compacted epoch evicts NOTHING
        (plans and delta executables keep their base-fingerprint keys;
        merged-graph executables of earlier epochs age out at the next
        compaction).  A compacting epoch evicts exactly the entries whose
        keys mention a retired fingerprint — the old base or a superseded
        epoch — and counts each one in
        ``granite_cache_total{cache=...,event="invalidation"}``."""
        prev = self.current
        ep = self.seal(compact=compact)
        if ep.compacted and prev is not None:
            # retire the closed window: the old base fp (plans + delta
            # executables) and superseded epoch fps (merged executables).
            # The new epoch's own fp stays valid — it names the new base.
            retired = (frozenset([prev.base_fingerprint] + self._window_fps)
                       - frozenset([ep.fingerprint, ep.base_fingerprint]))
            n_plans = scheduler.plan_cache.evict(
                lambda k: _mentions(k, retired))
            n_execs = scheduler.exec_cache.evict(
                lambda k: _mentions(k, retired))
            self._window_fps = [ep.fingerprint]
            if self.metrics is not None:
                if n_plans:
                    self._mx_cache.inc(n_plans, cache="plan",
                                       event="invalidation")
                if n_execs:
                    self._mx_cache.inc(n_execs, cache="executable",
                                       event="invalidation")
        scheduler.pin_epoch(ep)
        return ep
