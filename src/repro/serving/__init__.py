"""Workload-serving runtime — the subsystem that turns the engine stack into
a query *service* (ROADMAP north-star: heavy traffic, amortised traversals).

Layout:

  compile.py    plan-tensor compiler: same-shape instances → one padded
                parameter tensor per shape bucket (pow-2 size buckets bound
                retracing)
  cache.py      plan cache (shape bucket × graph fingerprint → split) and
                compiled-executable cache — steady-state serving neither
                re-plans nor re-traces
  scheduler.py  admission queue + batch scheduler: groups by (shape bucket,
                mode, engine), plans each group with the batch-aware cost
                model, dispatches ONE vmapped engine call per group
                (aggregates and the partitioned engine included — no
                per-query fallback)
  replay.py     open-loop Poisson + closed-loop bounded-outstanding replay
                of the LDBC workload through the scheduler; p50/p95/p99
                latency, throughput, completion-rate, deadline-hit rate,
                goodput (the paper's Table 5 serving metrics, plus SLO
                accounting)
  admission.py  deadline admission control: cost-model-predicted wait +
                service vs deadline → admit / degrade (cheaper impl,
                dense→sliced, bounded dispatch quantum) / reject
  telemetry.py  (predicted, measured) dispatch-cost ring buffer + periodic
                online θ refit — prediction error shrinks during serving
  faults.py     deterministic chaos injection (FaultPlan: seeded rates /
                explicit schedules at named points in the dispatch path and
                the WAL) + RetryPolicy (backoff retries accounted on the
                virtual clock, deadline-aware budgets, bisection quarantine,
                worker-loss degradation) — the completion story
  epochs.py     live-graph serving: EpochManager seals event-log epochs,
                materializes them incrementally, decides compaction, evicts
                exactly the cache entries whose fingerprints retired, and
                pins the scheduler to immutable snapshots (queries keep
                serving during ingestion — see docs/ingestion.md)
  testing.py    FakeDispatcher: synthetic service times on a virtual clock,
                zero JAX — the deterministic harness the SLO layer is
                tested on
"""
from .admission import (AdmissionController, AdmissionDecision,
                        AdmissionPolicy)
from .cache import (ExecutableCache, PlanCache, graph_fingerprint,
                    layout_signature)
from .compile import PlanTensor, bucket_key, compile_plan_tensor
from .epochs import Epoch, EpochManager
from .faults import (CompileError, FaultError, FaultPlan, PoisonQueryError,
                     RetryPolicy, TornWriteError, TransientDispatchError,
                     WorkerLostError)
from .replay import ReplayReport, replay_workload
from .scheduler import BatchScheduler, GroupDispatch, ServedResult
from .telemetry import TelemetryBuffer
from .testing import FakeDispatcher

__all__ = [
    "BatchScheduler", "ServedResult", "GroupDispatch", "PlanCache",
    "ExecutableCache", "graph_fingerprint", "layout_signature", "PlanTensor",
    "bucket_key", "compile_plan_tensor", "ReplayReport", "replay_workload",
    "AdmissionController", "AdmissionDecision", "AdmissionPolicy",
    "TelemetryBuffer", "FakeDispatcher", "Epoch", "EpochManager",
    "FaultPlan", "RetryPolicy", "FaultError", "TransientDispatchError",
    "CompileError", "WorkerLostError", "TornWriteError", "PoisonQueryError",
]
