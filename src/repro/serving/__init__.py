"""Workload-serving runtime — the subsystem that turns the engine stack into
a query *service* (ROADMAP north-star: heavy traffic, amortised traversals).

Layout:

  compile.py    plan-tensor compiler: same-shape instances → one padded
                parameter tensor per shape bucket (pow-2 size buckets bound
                retracing)
  cache.py      plan cache (shape bucket × graph fingerprint → split) and
                compiled-executable cache — steady-state serving neither
                re-plans nor re-traces
  scheduler.py  admission queue + batch scheduler: groups by (shape bucket,
                mode, engine), plans each group with the batch-aware cost
                model, dispatches ONE vmapped engine call per group
                (aggregates and the partitioned engine included — no
                per-query fallback)
  replay.py     open-loop Poisson replay of the LDBC workload through the
                scheduler; p50/p95/p99 latency, throughput, completion-rate
                (the paper's Table 5 serving metrics)
"""
from .cache import (ExecutableCache, PlanCache, graph_fingerprint,
                    layout_signature)
from .compile import PlanTensor, bucket_key, compile_plan_tensor
from .replay import ReplayReport, replay_workload
from .scheduler import BatchScheduler, ServedResult

__all__ = [
    "BatchScheduler", "ServedResult", "PlanCache", "ExecutableCache",
    "graph_fingerprint", "layout_signature", "PlanTensor", "bucket_key",
    "compile_plan_tensor", "ReplayReport", "replay_workload",
]
