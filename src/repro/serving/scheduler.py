"""Shape-bucketed batch scheduler — the serving runtime's dispatch core.

Queries enter an admission queue (``submit``); ``flush`` drains it in three
moves:

  group     queued instances are bucketed by (shape bucket, temporal mode,
            engine) — everything in a group shares one traced structure;
  plan      each group's split point comes from the batch-aware cost model
            (``Planner.choose_batch``: whole-batch cost, not the first
            instance's — per-instance selectivities differ), memoised in the
            PlanCache keyed by (bucket, graph fingerprint);
  dispatch  ONE vmapped engine call per group through the compiled-executable
            cache.  Aggregates (COUNT/MIN/MAX) and the partitioned engine
            batch exactly like plain counts — there is no per-query fallback
            path in this runtime, which is the point (the legacy — since
            removed — ``GraniteServer.run_workload_batched`` fell back for
            both).

Engines: ``dense`` / ``sliced`` (engine.batch_executable), ``partitioned``
(engine_partitioned.batch_executable), or ``auto`` (sliced when the query
qualifies, dense otherwise — resolved at admission so the group key is
concrete).

Hop-delivery lowering: the ``impl`` knob (``HOP_IMPLS``) pins every group on
one lowering (``'xla'`` or the fused ``'pallas'`` hop kernel), or
``'auto'`` lets the batch-aware planner sweep (split × impl) with the
fitted per-impl θ_scatter slopes and dispatch each group on the winner.
The chosen impl and its static hop-layout signature are part of the
compiled-executable key (sharing a graph fingerprint is not enough — a
kernel executable binds its block layout).

The partitioned engine's dispatch is shard_map-native: when >1 JAX devices
exist and divide ``n_workers`` (CI forces this with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``), the group's
query-batch axis is vmapped INSIDE the shard_map body, so ONE dispatch runs
(batch × workers) on the device mesh with the point-to-point boundary
exchange between supersteps; with one device the worker axis runs in the
bit-identical vmap simulation.  ``use_shard_map=False`` forces the
simulation; the resolved device count is part of the executable-cache key.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from ..core import engine as E
from ..core import engine_partitioned as EP
from ..core import engine_sliced as ES
from ..core import query as Q
from ..core.planner import HOP_IMPL_CHOICES, Planner
from ..core.stats import GraphStats
from ..graphdata.queries import QueryInstance
from .cache import (ExecutableCache, PlanCache, graph_fingerprint,
                    layout_signature)
from .compile import bucket_key, compile_plan_tensor

ENGINES = ("auto", "dense", "sliced", "partitioned")
#: hop-delivery lowering knob: fixed, or "auto" = the batch-aware planner
#: picks per group from the fitted per-impl θ_scatter slopes
HOP_IMPLS = ("auto", "xla", "pallas", "pallas_interpret")


@dataclasses.dataclass
class ServedResult:
    """Per-query serving outcome (one row of the paper's Table 5 bookkeeping)."""
    template: str
    engine: str
    split: int
    count: float
    latency_ms: float            # amortised share of the group service time
    ok: bool
    batch_size: int              # real instances in the dispatched group
    total: Optional[np.ndarray] = None       # kept when keep_outputs=True
    per_vertex: Optional[np.ndarray] = None
    minmax: Optional[np.ndarray] = None
    error: str = ""              # non-empty when the group dispatch failed


@dataclasses.dataclass
class GroupDispatch:
    """One vmapped engine call: the scheduler's unit of work."""
    key: tuple                   # (bucket, mode, engine)
    engine: str
    split: int
    n_real: int
    n_pad: int
    service_s: float             # measured wall time of the batched call
    indices: List[int]           # queue positions served by this dispatch
    plan_cached: bool
    exec_cached: bool
    impl: str = "xla"            # hop-delivery lowering the group ran on


class BatchScheduler:
    def __init__(
        self,
        graph,
        engine: str = "auto",
        mode: Optional[int] = None,
        n_buckets: int = 16,
        n_workers: int = 4,
        use_planner: bool = True,
        budget_s: float = 600.0,
        keep_outputs: bool = False,
        plan_cache: Optional[PlanCache] = None,
        exec_cache: Optional[ExecutableCache] = None,
        pad_batches: bool = True,
        use_shard_map: Optional[bool] = None,
        impl: str = "xla",
    ):
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}")
        if impl not in HOP_IMPLS:
            raise ValueError(f"impl must be one of {HOP_IMPLS}")
        self.graph = graph
        self.engine = engine
        self.impl = impl
        self.n_buckets = n_buckets
        self.n_workers = n_workers
        self.use_shard_map = use_shard_map
        # resolved once: device count is fixed per process, and the resolved
        # value keys the executable cache (sharded ≠ simulated executables)
        self.n_devices = EP.resolve_n_devices(use_shard_map, n_workers)
        self.use_planner = use_planner
        self.budget_s = budget_s
        self.keep_outputs = keep_outputs
        self.pad_batches = pad_batches
        dynamic = bool(graph.meta.get("params", {}).get("dynamic", False))
        self.mode = mode if mode is not None else (
            E.MODE_BUCKET if dynamic else E.MODE_STATIC)
        self.fingerprint = graph_fingerprint(graph)
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self.exec_cache = exec_cache if exec_cache is not None else ExecutableCache()
        self._stats = GraphStats(graph, n_time_buckets=n_buckets)
        self._planner = Planner(graph, self._stats)
        self._planner_part: Optional[Planner] = None   # built on first use
        self._queue: List[QueryInstance] = []
        self.last_dispatches: List[GroupDispatch] = []
        self.n_dispatched = 0

    # ------------------------------------------------------------ admission
    def submit(self, inst: Union[QueryInstance, Q.PathQuery]) -> None:
        if isinstance(inst, Q.PathQuery):
            inst = QueryInstance("adhoc", inst, {})
        self._queue.append(inst)

    @property
    def queued(self) -> int:
        return len(self._queue)

    def _mode_for(self, qry: Q.PathQuery) -> int:
        # aggregates in interval mode answer as bucket series (same policy as
        # the sequential server): the temporal aggregation operator is
        # defined per bucket.
        if qry.agg_op != Q.AGG_NONE and self.mode == E.MODE_INTERVAL:
            return E.MODE_BUCKET
        return self.mode

    def _engine_for(self, qry: Q.PathQuery) -> str:
        if self.engine != "auto":
            return self.engine
        return "sliced" if ES.sliceable(qry) else "dense"

    # ------------------------------------------------------------- planning
    def _planner_for(self, engine: str) -> Planner:
        if engine != "partitioned":
            return self._planner
        if self._planner_part is None:
            # distribution-aware costs: θ_net exchange terms from the same
            # partitioning the executor will run on
            _, arrays, _ = EP.partition_for(self.graph, self.n_workers)
            self._planner_part = Planner(self.graph, self._stats,
                                         partitioning=arrays)
        return self._planner_part

    def _plan_group(self, queries: List[Q.PathQuery], bucket: tuple,
                    mode: int, engine: str):
        """(split, hop impl, plan_cached) for one group.  A fixed ``impl``
        pins the lowering and the planner only picks the split; ``'auto'``
        sweeps (split × impl) with the fitted per-impl θ_scatter slopes."""
        qry = queries[0]
        default = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
        fixed_impl = None if self.impl == "auto" else self.impl
        if not self.use_planner:
            return default, fixed_impl or "xla", True
        key = (bucket, self.fingerprint, mode, engine, self.n_buckets,
               self.n_workers if engine == "partitioned" else 0, self.impl)
        plan = self.plan_cache.get(key)
        if plan is not None:
            return plan[0], plan[1], True
        impls = HOP_IMPL_CHOICES if fixed_impl is None else (fixed_impl,)
        est = self._planner_for(engine).choose_batch(queries, impls=impls)
        split, impl = est.split, fixed_impl or est.impl
        self.plan_cache.put(key, (split, impl))
        return split, impl, False

    # ------------------------------------------------------------- dispatch
    def _build_executable(self, qry: Q.PathQuery, split: int, mode: int,
                          engine: str, impl: str):
        if engine == "partitioned":
            return EP.batch_executable(self.graph, qry, split, mode,
                                       self.n_buckets, self.n_workers,
                                       use_shard_map=self.use_shard_map,
                                       impl=impl)
        return E.batch_executable(self.graph, qry, split, mode,
                                  self.n_buckets,
                                  sliced=(engine == "sliced"), impl=impl)

    def flush(self, warm: bool = False) -> List[ServedResult]:
        """Drain the queue: one vmapped engine call per (bucket, mode,
        engine) group; results return in submission order.  ``warm=True``
        runs each executable once untimed first (compile excluded from
        latency, as the paper excludes load time)."""
        queue, self._queue = self._queue, []
        if not queue:
            self.last_dispatches = []
            return []
        groups: Dict[tuple, List[int]] = {}
        for i, inst in enumerate(queue):
            key = (bucket_key(inst.qry), self._mode_for(inst.qry),
                   self._engine_for(inst.qry))
            groups.setdefault(key, []).append(i)

        out: List[Optional[ServedResult]] = [None] * len(queue)
        dispatches: List[GroupDispatch] = []
        for key, idxs in groups.items():
            bucket, mode, engine = key
            insts = [queue[i] for i in idxs]
            queries = [x.qry for x in insts]
            try:
                split, impl, plan_cached = self._plan_group(queries, bucket,
                                                            mode, engine)
                pt = compile_plan_tensor(queries, pad=self.pad_batches)
                ekey = (engine, self.fingerprint, bucket, split, mode,
                        self.n_buckets,
                        self.n_workers if engine == "partitioned" else 0,
                        self.n_devices if engine == "partitioned" else 0,
                        impl,
                        layout_signature(self.graph, engine, queries[0],
                                         self.n_workers, impl),
                        pt.params.shape[0])
                exec_cached = ekey in self.exec_cache
                run = self.exec_cache.get_or_build(
                    ekey, lambda: self._build_executable(queries[0], split,
                                                         mode, engine, impl))
                if warm and not exec_cached:
                    # first dispatch at this key: run once untimed so compile
                    # stays out of latency (a cache-hit executable has already
                    # been traced and run at this key)
                    jax.block_until_ready(run(pt.params).total)
                t0 = time.perf_counter()
                res = run(pt.params)
                jax.block_until_ready(res.total)
                dt = time.perf_counter() - t0
            except Exception as e:
                # a failing group (e.g. a non-sliceable query forced onto the
                # sliced engine, or an unsupported op surfacing at trace time)
                # must not take the rest of the flush with it
                for i in idxs:
                    out[i] = ServedResult(
                        template=queue[i].template, engine=engine, split=-1,
                        count=-1.0, latency_ms=0.0, ok=False,
                        batch_size=len(idxs), error=str(e))
                continue
            per_query_ms = dt * 1e3 / pt.n_real
            ok = per_query_ms <= self.budget_s * 1e3

            total = np.asarray(res.total)
            pv = None if res.per_vertex is None else np.asarray(res.per_vertex)
            mm = None if res.minmax is None else np.asarray(res.minmax)
            for j, i in enumerate(idxs):
                t_j = total[j]
                out[i] = ServedResult(
                    template=insts[j].template, engine=engine, split=split,
                    count=float(t_j.sum()) if t_j.ndim else float(t_j),
                    latency_ms=per_query_ms, ok=ok, batch_size=pt.n_real,
                    total=t_j if self.keep_outputs else None,
                    per_vertex=(pv[j] if self.keep_outputs and pv is not None
                                else None),
                    minmax=(mm[j] if self.keep_outputs and mm is not None
                            else None),
                )
            dispatches.append(GroupDispatch(
                key, engine, split, pt.n_real, pt.n_pad, dt, list(idxs),
                plan_cached, exec_cached, impl))
        self.last_dispatches = dispatches
        self.n_dispatched += len(queue)
        return out  # type: ignore[return-value]

    def run(self, workload: Sequence[Union[QueryInstance, Q.PathQuery]],
            warm: bool = False) -> List[ServedResult]:
        """Submit a whole workload and drain it in one flush."""
        for inst in workload:
            self.submit(inst)
        return self.flush(warm=warm)

    # ------------------------------------------------------------- reporting
    def cache_report(self) -> dict:
        return dict(
            plan=self.plan_cache.stats.as_dict(),
            executable=self.exec_cache.stats.as_dict(),
            n_plans=len(self.plan_cache),
            n_executables=len(self.exec_cache),
        )
