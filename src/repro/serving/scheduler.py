"""Shape-bucketed batch scheduler — the serving runtime's dispatch core.

Queries enter an admission queue (``submit``); ``flush`` drains it in three
moves:

  group     queued instances are bucketed by (shape bucket, temporal mode,
            engine) — everything in a group shares one traced structure;
  plan      each group's split point comes from the batch-aware cost model
            (``Planner.choose_batch``: whole-batch cost, not the first
            instance's — per-instance selectivities differ), memoised in the
            PlanCache keyed by (bucket, graph fingerprint);
  dispatch  ONE vmapped engine call per group through the compiled-executable
            cache.  Aggregates (COUNT/MIN/MAX) and the partitioned engine
            batch exactly like plain counts — there is no per-query fallback
            path in this runtime, which is the point (the legacy — since
            removed — ``GraniteServer.run_workload_batched`` fell back for
            both).

Engines: ``dense`` / ``sliced`` (engine.batch_executable), ``partitioned``
(engine_partitioned.batch_executable), or ``auto`` (sliced when the query
qualifies, dense otherwise — resolved at admission so the group key is
concrete).

Hop-delivery lowering: the ``impl`` knob (``HOP_IMPLS``) pins every group on
one lowering (``'xla'`` or the fused ``'pallas'`` hop kernel), or
``'auto'`` lets the batch-aware planner sweep (split × impl) with the
fitted per-impl θ_scatter slopes and dispatch each group on the winner.
The chosen impl and its static hop-layout signature are part of the
compiled-executable key (sharing a graph fingerprint is not enough — a
kernel executable binds its block layout).

The partitioned engine's dispatch is shard_map-native: when >1 JAX devices
exist and divide ``n_workers`` (CI forces this with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``), the group's
query-batch axis is vmapped INSIDE the shard_map body, so ONE dispatch runs
(batch × workers) on the device mesh with the point-to-point boundary
exchange between supersteps; with one device the worker axis runs in the
bit-identical vmap simulation.  ``use_shard_map=False`` forces the
simulation; the resolved device count is part of the executable-cache key.

SLO layer (serving/admission.py, serving/telemetry.py):

  deadlines  every queue entry carries an absolute deadline (``submit``'s
             ``deadline_s`` is relative to ``now``); ``flush`` dispatches
             groups EARLIEST-DEADLINE-FIRST (group deadline = its most
             urgent member; ties keep arrival order, so the historical
             no-deadline behaviour is unchanged);
  admission  with an ``admission`` controller attached, ``submit`` predicts
             wait + service from the live cost model and returns an
             AdmissionDecision — rejected queries never enter the queue,
             degraded ones carry per-entry impl/engine/batch-cap overrides
             that join the group key (degraded groups dispatch separately,
             in bounded chunks the EDF order can interleave);
  telemetry  every timed dispatch records (features, predicted, measured)
             into the TelemetryBuffer; periodic online θ refit updates the
             planners' coefficients in place (and clears the plan cache so
             stale split choices are re-planned once).

The ``dispatcher`` hook swaps the JAX build-and-run step for an injected one
(serving/testing.FakeDispatcher): all SLO control logic — grouping, EDF,
chunking, admission, telemetry — is testable on a virtual clock with zero
compilation.

Fault layer (serving/faults.py): a ``fault_plan`` injects deterministic
failures at the named points inside ``_dispatch`` (compile / dispatch /
worker / straggler), and a ``retry`` policy turns failures into completion
instead of errors — exponential-backoff retries whose delays are ACCOUNTED
into the virtual clock (never slept), a deadline-aware budget (a retry that
would land past the group's EDF deadline re-enters admission or times out
with a structured error), bisection quarantine (a unit that keeps failing
splits in half until the single poison query is isolated and rejected while
the rest answer), and worker-loss degradation (a partitioned unit that
loses a worker re-plans onto the dense executor — bit-identical answers —
and the planner marks the partitioned path unavailable until a probe
succeeds).  Without a ``retry`` policy the historical behaviour is
unchanged: one exception marks the whole unit failed.

Observability (repro.obs): with a ``tracer`` attached every submitted query
leaves one span tree — query → admit → plan → compile → dispatch →
superstep (per hop) → exchange (per channel) — carrying the admission
verdict/rungs, the plan's candidate sweep, cache hits, EDF position, and
predicted-vs-measured ms at query, group, and hop granularity; a
``metrics`` registry mirrors the counters (admission verdicts, cache
events, refits, dispatch latency histogram, queue depth).  The default
``NULL_TRACER`` makes the disabled path a no-op attribute lookup (overhead
gated by benchmarks/serving.py + scripts/check_bench.py), and all timing
flows through the injected ``clock``, so under the FakeDispatcher virtual
clock the exact span tree is deterministic.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from ..core import engine as E
from ..core import engine_partitioned as EP
from ..core import engine_sliced as ES
from ..core import query as Q
from ..core.planner import HOP_IMPL_CHOICES, Planner, coeff_vector
from ..core.stats import GraphStats
from ..faults_common import backoff_delay
from ..graphdata.queries import QueryInstance
from ..obs.trace import NULL_TRACER
from .admission import AdmissionController, AdmissionDecision, AdmissionPolicy
from .cache import (ExecutableCache, PlanCache, graph_fingerprint,
                    layout_signature)
from .compile import bucket_key, compile_plan_tensor
from .faults import (CompileError, FaultError, FaultPlan, PoisonQueryError,
                     RetryPolicy, TransientDispatchError, WorkerLostError)
from .telemetry import TelemetryBuffer

ENGINES = ("auto", "dense", "sliced", "partitioned")
#: hop-delivery lowering knob: fixed, or "auto" = the batch-aware planner
#: picks per group from the fitted per-impl θ_scatter slopes
HOP_IMPLS = ("auto", "xla", "pallas", "pallas_interpret")


@dataclasses.dataclass
class ServedResult:
    """Per-query serving outcome (one row of the paper's Table 5 bookkeeping)."""
    template: str
    engine: str
    split: int
    count: float
    latency_ms: float            # amortised share of the group service time
    ok: bool
    batch_size: int              # real instances in the dispatched group
    total: Optional[np.ndarray] = None       # kept when keep_outputs=True
    per_vertex: Optional[np.ndarray] = None
    minmax: Optional[np.ndarray] = None
    error: str = ""              # non-empty when the group dispatch failed
    deadline: float = math.inf   # absolute deadline the entry carried
    #: terminal disposition: "done" | "failed" | "quarantined" | "timeout"
    status: str = "done"


@dataclasses.dataclass
class QueueEntry:
    """One admitted query waiting in the scheduler's queue."""
    inst: QueryInstance
    deadline: float = math.inf   # absolute
    arrival: float = 0.0
    impl: Optional[str] = None   # admission-degradation overrides (None =
    engine: Optional[str] = None  # scheduler defaults)
    max_batch: Optional[int] = None
    span: object = None          # root "query" span (flight recorder)


@dataclasses.dataclass
class GroupDispatch:
    """One vmapped engine call: the scheduler's unit of work."""
    key: tuple                   # (bucket, mode, engine, impl override)
    engine: str
    split: int
    n_real: int
    n_pad: int
    service_s: float             # measured wall time of the batched call
    indices: List[int]           # queue positions served by this dispatch
    plan_cached: bool
    exec_cached: bool
    impl: str = "xla"            # hop-delivery lowering the group ran on
    deadline: float = math.inf   # most urgent member's deadline (EDF key)
    predicted_ms: float = 0.0    # cost-model prediction (telemetry rows)
    delta: bool = False          # served on the base+delta executable path
    n_retries: int = 0           # backoff retries the unit burned
    fallback_from: str = ""      # engine the unit was re-planned away from
    penalty_s: float = 0.0       # accounted retry backoff inside service_s


class BatchScheduler:
    """The serving runtime's dispatch core (see the module docstring for the
    full control flow).

    Life of a query: ``submit`` admits it (optionally through the SLO
    admission controller) into the queue; ``flush`` groups the queue by
    (shape bucket, temporal mode, engine, impl override), plans each group
    once through the batch-aware cost model (memoised in ``plan_cache``),
    and dispatches ONE vmapped engine call per group through ``exec_cache``
    — earliest-deadline-first, results in submission order.

    Live graphs: ``pin_epoch(epoch)`` (driven by ``serving.epochs.
    EpochManager.advance``) switches the scheduler to a sealed-epoch
    snapshot without dropping warm state.  Between two compactions the
    *base* graph (``self.graph``) — planner stats, partitionings, compiled
    executables — is immutable; an epoch whose delta window is pure edge
    appends serves eligible groups on the base+delta executable
    (``engine.batch_executable_delta``), so cache keys carrying the base
    fingerprint keep hitting across epochs.  Ineligible groups (ETR hops,
    impure windows, non-dense engines) serve from the epoch's merged graph
    under the epoch fingerprint.  Either way results are bit-identical to a
    from-scratch build of the pinned epoch's graph, and queries never see
    events sealed after their batch's pin.

    Key invariants:
      * plan keys carry the BASE fingerprint (splits are planned against
        base statistics; any split yields identical results);
      * executable keys carry the serving fingerprint — the base
        fingerprint for delta dispatches, the epoch fingerprint otherwise;
      * cache eviction at compaction is targeted (``evict`` of retired
        fingerprints), counted per entry in the metrics registry as
        ``granite_cache_total{event="invalidation"}``.
    """

    def __init__(
        self,
        graph,
        engine: str = "auto",
        mode: Optional[int] = None,
        n_buckets: int = 16,
        n_workers: int = 4,
        use_planner: bool = True,
        budget_s: float = 600.0,
        keep_outputs: bool = False,
        plan_cache: Optional[PlanCache] = None,
        exec_cache: Optional[ExecutableCache] = None,
        pad_batches: bool = True,
        use_shard_map: Optional[bool] = None,
        impl: str = "xla",
        admission=None,
        telemetry: Optional[TelemetryBuffer] = None,
        dispatcher=None,
        clock=time.perf_counter,
        tracer=None,
        metrics=None,
        fault_plan: Optional[FaultPlan] = None,
        retry: Optional[RetryPolicy] = None,
    ):
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}")
        if impl not in HOP_IMPLS:
            raise ValueError(f"impl must be one of {HOP_IMPLS}")
        self.graph = graph
        self.engine = engine
        self.impl = impl
        self.n_buckets = n_buckets
        self.n_workers = n_workers
        self.use_shard_map = use_shard_map
        # resolved once: device count is fixed per process, and the resolved
        # value keys the executable cache (sharded ≠ simulated executables)
        self.n_devices = EP.resolve_n_devices(use_shard_map, n_workers)
        self.use_planner = use_planner
        self.budget_s = budget_s
        self.keep_outputs = keep_outputs
        self.pad_batches = pad_batches
        dynamic = bool(graph.meta.get("params", {}).get("dynamic", False))
        self.mode = mode if mode is not None else (
            E.MODE_BUCKET if dynamic else E.MODE_STATIC)
        self.fingerprint = graph_fingerprint(graph)
        # ---- epoch pinning (pin_epoch): base vs serving graph split.
        # self.graph stays the compaction BASE (planner stats, partition
        # tables, delta executables bind to it); _serve_graph is the pinned
        # epoch's merged graph (== graph until an epoch is pinned).
        self._serve_graph = graph
        self._base_fp = self.fingerprint    # compaction-base fingerprint
        self._plan_fp = self.fingerprint    # fingerprint slot of plan keys
        self._epoch = None
        self._delta = None                  # DeltaSpec.device() dict | None
        self._delta_capacity = 0
        self._warmed_delta = set()          # (ekey, capacity) pairs warmed
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self.exec_cache = exec_cache if exec_cache is not None else ExecutableCache()
        self._stats = GraphStats(graph, n_time_buckets=n_buckets)
        self._planner = Planner(graph, self._stats)
        self._planner_part: Optional[Planner] = None   # built on first use
        self._queue: List[QueueEntry] = []
        self.last_dispatches: List[GroupDispatch] = []
        self.n_dispatched = 0
        # ---- SLO layer (all optional; None keeps the historical behaviour)
        if isinstance(admission, AdmissionPolicy):
            admission = AdmissionController(admission)
        self.admission: Optional[AdmissionController] = admission
        self.telemetry = telemetry
        self.dispatcher = dispatcher
        self._clock = clock
        self.n_rejected = 0
        self.n_degraded = 0
        # ---- fault layer (serving/faults.py; None keeps the historical
        # one-exception-fails-the-unit behaviour)
        self.fault_plan: Optional[FaultPlan] = fault_plan
        self.retry: Optional[RetryPolicy] = retry
        self.n_retries = 0
        self.n_quarantined = 0
        self.n_timeout = 0
        self.n_fallbacks = 0
        self._flush_count = 0
        self._part_down_until = -1   # flush count the partitioned probe waits for
        # ---- observability (tracer defaults to the no-op singleton)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        self._dispatch_seq = 0
        # per-query PlanEstimate memo: features are θ-INDEPENDENT structural
        # sums (GraphStats), so entries survive online refits — predictions
        # are recomputed as features @ live θ at use time
        self._est_memo: Dict[tuple, object] = {}
        if metrics is not None:
            self._mx_admission = metrics.counter(
                "granite_admission_total", "admission outcomes",
                labelnames=("verdict", "rung"))
            self._mx_queue = metrics.gauge(
                "granite_queue_depth", "entries queued for the next flush")
            self._mx_dispatch_ms = metrics.histogram(
                "granite_dispatch_ms",
                "measured wall time per group dispatch (ms)")
            self._mx_dispatched = metrics.counter(
                "granite_dispatched_total", "real queries dispatched")
            self._mx_cache = metrics.counter(
                "granite_cache_total", "serving cache events",
                labelnames=("cache", "event"))
            self._mx_refit = metrics.counter(
                "granite_refit_total", "online θ refits applied")
            self._mx_retries = metrics.counter(
                "granite_retries_total", "dispatch retries by fault kind",
                labelnames=("kind",))
            self._mx_quarantined = metrics.counter(
                "granite_quarantined_total",
                "queries rejected as poison after bisection")
            self._mx_degraded_disp = metrics.counter(
                "granite_degraded_dispatches_total",
                "units re-planned off the partitioned path",
                labelnames=("reason",))

    # ------------------------------------------------------------ admission
    def submit(self, inst: Union[QueryInstance, Q.PathQuery],
               deadline_s: Optional[float] = None,
               now: Optional[float] = None) -> Optional[AdmissionDecision]:
        """Enqueue a query.  ``deadline_s`` is relative to ``now`` (default:
        the scheduler's clock — replay harnesses pass their virtual time).
        With an admission controller attached, returns its decision — a
        rejected query never enters the queue; without one, every submit
        admits (deadlines still order the flush)."""
        if isinstance(inst, Q.PathQuery):
            inst = QueryInstance("adhoc", inst, {})
        if now is None:
            now = self._clock() if (deadline_s is not None
                                    or self.admission is not None) else 0.0
        tr = self.tracer
        root = tr.start("query", template=inst.template,
                        n_vertices=inst.qry.n_vertices,
                        deadline_s=deadline_s)
        if self.admission is not None:
            adm = tr.start("admit", parent=root)
            dec = self.admission.decide(self, inst, now, deadline_s)
            tr.end(adm, verdict=dec.action, rungs=list(dec.rungs),
                   reason=dec.reason, predicted_s=dec.predicted_s,
                   predicted_wait_s=dec.predicted_wait_s)
            if self.metrics is not None:
                self._mx_admission.inc(verdict=dec.action,
                                       rung=",".join(dec.rungs))
            if not dec.admitted:
                self.n_rejected += 1
                tr.end(root, status="rejected")
                return dec
            if dec.action == "degrade":
                self.n_degraded += 1
            self._queue.append(QueueEntry(inst, dec.deadline, now, dec.impl,
                                          dec.engine, dec.max_batch,
                                          span=root))
            if self.metrics is not None:
                self._mx_queue.set(len(self._queue))
            return dec
        if tr.enabled:
            adm = tr.start("admit", parent=root)
            tr.end(adm, verdict="admit", rungs=[],
                   reason="no admission controller")
        if self.metrics is not None:
            self._mx_admission.inc(verdict="admit", rung="")
        deadline = math.inf if deadline_s is None else now + float(deadline_s)
        self._queue.append(QueueEntry(inst, deadline, now, span=root))
        if self.metrics is not None:
            self._mx_queue.set(len(self._queue))
        return None

    @property
    def queued(self) -> int:
        return len(self._queue)

    def _mode_for(self, qry: Q.PathQuery) -> int:
        # aggregates in interval mode answer as bucket series (same policy as
        # the sequential server): the temporal aggregation operator is
        # defined per bucket.
        if qry.agg_op != Q.AGG_NONE and self.mode == E.MODE_INTERVAL:
            return E.MODE_BUCKET
        return self.mode

    def _engine_for(self, qry: Q.PathQuery) -> str:
        if self.engine != "auto":
            return self.engine
        # with a pinned pure-append delta window, ETR-free queries steer to
        # the dense engine: base+delta execution is dense-only (the sliced
        # engine binds type extents to one concrete graph) and reusing the
        # base executable beats re-tracing sliced on every epoch
        if self._delta is not None and all(
                ep.etr_op == -1 for ep in qry.e_preds):
            return "dense"
        return "sliced" if ES.sliceable(qry) else "dense"

    # ------------------------------------------------------------- planning
    def _planner_for(self, engine: str) -> Planner:
        if engine != "partitioned":
            return self._planner
        if self._planner_part is None:
            # distribution-aware costs: θ_net exchange terms from the same
            # partitioning the executor will run on
            _, arrays, _ = EP.partition_for(self.graph, self.n_workers)
            self._planner_part = Planner(self.graph, self._stats,
                                         partitioning=arrays)
        return self._planner_part

    def _plan_key(self, bucket: tuple, mode: int, engine: str,
                  impl_choice: str) -> tuple:
        # plans are keyed by the BASE fingerprint: split choice comes from
        # base statistics and stays optimal-enough across edge-append epochs
        # (any split is result-identical); compaction retires the key
        return (bucket, self._plan_fp, mode, engine, self.n_buckets,
                self.n_workers if engine == "partitioned" else 0, impl_choice)

    def _plan_group(self, queries: List[Q.PathQuery], bucket: tuple,
                    mode: int, engine: str,
                    impl_override: Optional[str] = None):
        """(split, hop impl, plan_cached, candidates) for one group.  A
        fixed ``impl`` (the scheduler's, or a per-group admission-
        degradation override) pins the lowering and the planner only picks
        the split; ``'auto'`` sweeps (split × impl) with the fitted per-impl
        θ_scatter slopes.  ``candidates`` is the fresh sweep's candidate
        list (None on a cache hit or without the planner) — the plan span's
        audit payload."""
        qry = queries[0]
        default = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
        impl_choice = impl_override or self.impl
        fixed_impl = None if impl_choice == "auto" else impl_choice
        if not self.use_planner:
            return default, fixed_impl or "xla", True, None
        key = self._plan_key(bucket, mode, engine, impl_choice)
        plan = self.plan_cache.get(key)
        if plan is not None:
            return plan[0], plan[1], True, None
        impls = HOP_IMPL_CHOICES if fixed_impl is None else (fixed_impl,)
        est = self._planner_for(engine).choose_batch(queries, impls=impls)
        split, impl = est.split, fixed_impl or est.impl
        self.plan_cache.put(key, (split, impl))
        return split, impl, False, est.candidates

    # ------------------------------------------------------------- dispatch
    def _build_executable(self, qry: Q.PathQuery, split: int, mode: int,
                          engine: str, impl: str):
        if engine == "partitioned":
            return EP.batch_executable(self._serve_graph, qry, split, mode,
                                       self.n_buckets, self.n_workers,
                                       use_shard_map=self.use_shard_map,
                                       impl=impl)
        return E.batch_executable(self._serve_graph, qry, split, mode,
                                  self.n_buckets,
                                  sliced=(engine == "sliced"), impl=impl)

    def _delta_eligible(self, qry: Q.PathQuery, engine: str) -> bool:
        """Can this group run on the base+delta executable?  Needs a pinned
        pure-append delta window, the dense engine (sliced/partitioned bind
        type extents / partition tables to a concrete graph), and no ETR
        hops (global rank tables)."""
        return (self._delta is not None and engine == "dense"
                and all(ep.etr_op == -1 for ep in qry.e_preds))

    def _dispatch_jax(self, queries: List[Q.PathQuery], split: int, mode: int,
                      engine: str, impl: str, bucket: tuple, pt, warm: bool):
        """The real build-and-run step: executable cache → one vmapped call,
        timed.  Swapped out wholesale by an injected ``dispatcher``.

        Delta-eligible groups run ``engine.batch_executable_delta`` against
        the compaction BASE: their cache key carries the base fingerprint
        (not the epoch's) and no capacity, so one cached executable serves
        every epoch of the window — the scheduler only re-warms when the
        padded delta capacity grows (a jit retrace inside the same entry).
        """
        use_delta = self._delta_eligible(queries[0], engine)
        self._last_used_delta = use_delta
        fp = ("delta", self._base_fp) if use_delta else self.fingerprint
        lay_graph = self.graph if use_delta else self._serve_graph
        ekey = (engine, fp, bucket, split, mode,
                self.n_buckets,
                self.n_workers if engine == "partitioned" else 0,
                self.n_devices if engine == "partitioned" else 0,
                impl,
                layout_signature(lay_graph, engine, queries[0],
                                 self.n_workers, impl),
                pt.params.shape[0])
        exec_cached = ekey in self.exec_cache
        if use_delta:
            run0 = self.exec_cache.get_or_build(
                ekey, lambda: E.batch_executable_delta(
                    self.graph, queries[0], split, mode, self.n_buckets,
                    impl=impl))
            delta = self._delta
            run = lambda params: run0(params, delta)  # noqa: E731
            # a cached delta executable still retraces when the padded
            # capacity grows — warm per (key, capacity), not per key
            warm_needed = (ekey, self._delta_capacity) not in self._warmed_delta
            if warm and warm_needed:
                self._warmed_delta.add((ekey, self._delta_capacity))
        else:
            run = self.exec_cache.get_or_build(
                ekey, lambda: self._build_executable(queries[0], split,
                                                     mode, engine, impl))
            warm_needed = not exec_cached
        if warm and warm_needed:
            # first dispatch at this key: run once untimed so compile
            # stays out of latency (a cache-hit executable has already
            # been traced and run at this key)
            jax.block_until_ready(run(pt.params).total)
        # timing goes through the INJECTED clock (default time.perf_counter)
        # so dispatch durations — and with them telemetry rows and trace
        # spans — are deterministic under a test-injected step clock
        t0 = self._clock()
        res = run(pt.params)
        jax.block_until_ready(res.total)
        return res, self._clock() - t0, exec_cached

    def _dispatch(self, queries: List[Q.PathQuery], split: int, mode: int,
                  engine: str, impl: str, bucket: tuple, pt, warm: bool):
        """One dispatch attempt with the named fault-injection points.

        This is the single funnel both the real JAX path and an injected
        ``dispatcher`` (FakeDispatcher) run through, so a ``FaultPlan``
        exercises identical failure surfaces with zero compilation.
        Consultation order: poison (deterministic per-query) → "compile" →
        "worker" (partitioned only) → "dispatch" → real call → "straggler"
        (service-time inflation, accounted not slept)."""
        plan = self.fault_plan
        if plan is not None:
            if plan.poison is not None and any(plan.is_poison(q)
                                               for q in queries):
                raise PoisonQueryError(
                    f"poison query in unit of {len(queries)}")
            if plan.should_fail("compile"):
                raise CompileError(
                    f"injected compile failure (engine={engine}, "
                    f"impl={impl}, split={split})")
            if engine == "partitioned" and plan.should_fail("worker"):
                raise WorkerLostError(
                    f"injected partition-worker loss "
                    f"(n_workers={self.n_workers})")
            if plan.should_fail("dispatch"):
                raise TransientDispatchError(
                    "injected transient dispatch error")
        if self.dispatcher is not None:
            res, dt = self.dispatcher.dispatch(
                self, queries, split, mode, engine, impl, pt, warm)
            exec_cached = True
        else:
            res, dt, exec_cached = self._dispatch_jax(
                queries, split, mode, engine, impl, bucket, pt, warm)
        if plan is not None:
            dt *= plan.straggle()
        return res, dt, exec_cached

    # ------------------------------------------------------------ epochs
    def pin_epoch(self, epoch) -> None:
        """Pin serving to a sealed epoch (``serving.epochs.Epoch``).

        Until the next pin, every dispatch answers from this epoch's graph
        — queries never observe later (or unsealed) events, and results are
        bit-identical to a from-scratch build of the epoch's graph.  On a
        compacted epoch the scheduler REBASEs: planner statistics, the
        partitioned planner, and the estimate memo are rebuilt against the
        new base (cache eviction of retired fingerprints is the
        EpochManager's job, so its metrics can count what was dropped).
        Non-compacted epochs keep all warm state; delta-pure ones also
        attach the delta block for the base+delta dispatch path."""
        if epoch.base_fingerprint != self._base_fp:
            base = epoch.base_graph if epoch.base_graph is not None else epoch.graph
            self.graph = base
            self._stats = GraphStats(base, n_time_buckets=self.n_buckets)
            self._planner = Planner(base, self._stats)
            self._planner_part = None
            self._est_memo.clear()
            self._warmed_delta.clear()
        self._epoch = epoch
        self._base_fp = epoch.base_fingerprint
        self._plan_fp = epoch.base_fingerprint
        self.fingerprint = epoch.fingerprint
        self._serve_graph = epoch.graph
        if epoch.delta is not None:
            self._delta = epoch.delta.device()
            self._delta_capacity = epoch.delta.capacity
        else:
            self._delta = None
            self._delta_capacity = 0

    @property
    def pinned_epoch(self):
        """The currently pinned ``Epoch`` (None before any ``pin_epoch``)."""
        return self._epoch

    def _estimate_query(self, qry: Q.PathQuery, split: int, engine: str,
                        impl: str):
        """Memoised per-query PlanEstimate at a concrete (split, impl).

        Safe across refits: the estimate's FEATURES are θ-independent
        structural sums, and every prediction derived from a memo hit is
        recomputed as ``features @ live θ`` — only the stale ``t_ms`` on
        the cached object must not be read directly."""
        key = (Q.query_params(qry).tobytes(), qry.shape_key(), split,
               engine, impl)
        est = self._est_memo.get(key)
        if est is None:
            est = self._planner_for(engine).estimate(qry, split, impl)
            self._est_memo[key] = est
        return est

    def _group_features(self, queries: List[Q.PathQuery], split: int,
                        engine: str, impl: str, pt):
        """(batch-summed feature row, per-query estimates) for one dispatch
        — the same sums ``Planner.estimate_batch`` produces (identical
        np.sum reduction, so telemetry rows are bit-identical to the
        un-memoised path)."""
        ests = [self._estimate_query(q, split, engine, impl)
                for q in queries]
        feats = np.sum([e.features for e in ests], axis=0)
        if pt.n_pad:
            # padded rows run too: they repeat instance 0's parameters
            feats = feats + pt.n_pad * ests[0].features
        return feats, ests

    def _record_telemetry(self, feats: np.ndarray, engine: str,
                          dt: float) -> float:
        """One (features, predicted, measured) telemetry row per timed
        dispatch; periodic online θ refit updates the live planners (and
        clears the plan cache once, so stale split choices re-plan against
        the new coefficients)."""
        planner = self._planner_for(engine)
        predicted_ms = float(feats @ coeff_vector(planner.coeffs))
        self.telemetry.record(feats, predicted_ms, dt * 1e3)
        if self.telemetry.should_refit():
            new = self.telemetry.refit(planner.coeffs)
            self._planner.coeffs.update(new)
            if self._planner_part is not None:
                self._planner_part.coeffs.update(new)
            self.plan_cache.clear()
            if self.metrics is not None:
                self._mx_refit.inc()
                self._mx_cache.inc(cache="plan", event="invalidation")
        return predicted_ms

    def _trace_group(self, queue, idxs, ests, feats, split, engine, impl,
                     pt, dt, plan_cached, exec_cached, candidates, seq,
                     edf_pos, group_deadline, predicted_ms, out):
        """Emit one dispatched group's span set: for EVERY member query a
        plan → compile → dispatch → superstep (per hop) → exchange chain
        under its root, so each query's tree is complete on its own.
        Group-shared quantities (the telemetry row: batch-summed features,
        group predicted/measured ms) repeat on each member's dispatch span
        keyed by ``seq`` — obs/audit dedupes them back to one row per
        dispatch.  Measured group time is apportioned to members (and to
        hops within a member) by predicted fractions."""
        tr = self.tracer
        theta = coeff_vector(self._planner_for(engine).coeffs)
        group_pred = (predicted_ms if self.telemetry is not None
                      else float(feats @ theta))
        cand_attrs = None
        if candidates is not None:
            cand_attrs = [dict(split=c["split"], impl=c["impl"],
                               t_ms=float(c["t_ms"]),
                               features=np.asarray(c["features"]).tolist())
                          for c in candidates]
        q_preds = [float(e.features @ theta) for e in ests]
        pred_sum = sum(q_preds)
        group_ms = dt * 1e3
        key_repr = repr((engine, impl, split, pt.params.shape[0]))
        for j, i in enumerate(idxs):
            root = queue[i].span
            est = ests[j]
            plan_span = tr.start("plan", parent=root, seq=seq, split=split,
                                 impl=impl, engine=engine,
                                 plan_cached=plan_cached,
                                 predicted_ms=q_preds[j],
                                 features=est.features)
            if cand_attrs is not None and j == 0:
                # the candidate sweep is one decision per GROUP — record it
                # once, on the first member's plan span (audit re-joins it
                # to the other members by seq); repeating the full sweep on
                # all members multiplies record volume ~batch-fold
                tr.annotate(plan_span, candidates=cand_attrs)
            tr.end(plan_span)
            comp = tr.start("compile", parent=root, seq=seq,
                            cache="hit" if exec_cached else "miss",
                            key=key_repr)
            tr.end(comp)
            share = (q_preds[j] / pred_sum if pred_sum > 0
                     else 1.0 / len(idxs))
            q_meas = group_ms * share
            disp = tr.start(
                "dispatch", parent=root, seq=seq, batch=pt.n_real,
                n_pad=pt.n_pad, edf_pos=edf_pos, engine=engine, impl=impl,
                split=split,
                deadline=(None if math.isinf(group_deadline)
                          else group_deadline),
                predicted_ms=q_preds[j], measured_ms=q_meas,
                features=est.features, group_features=feats,
                group_predicted_ms=group_pred, group_measured_ms=group_ms)
            hop_steps = [s for s in est.steps if s.channels is not None]
            hop_preds = [float(s.features @ theta) for s in hop_steps]
            hp_sum = sum(hop_preds)
            for h, s in enumerate(hop_steps):
                hshare = (hop_preds[h] / hp_sum if hp_sum > 0
                          else 1.0 / len(hop_steps))
                ss = tr.start("superstep", parent=disp, hop=h, etr=s.etr,
                              predicted_ms=hop_preds[h],
                              measured_ms=q_meas * hshare)
                ex = tr.start("exchange", parent=ss, hop=h,
                              state=s.channels[0],
                              extremum=s.channels[1], etr=s.channels[2])
                tr.end(ex)
                tr.end(ss)
            tr.end(disp)
            r = out[i]
            tr.end(root, status="done", ok=r.ok, count=r.count,
                   latency_ms=r.latency_ms)

    def flush(self, warm: bool = False) -> List[ServedResult]:
        """Drain the queue: one vmapped engine call per (bucket, mode,
        engine, impl-override) group chunk, dispatched EARLIEST-DEADLINE-
        FIRST (no-deadline entries all tie at +inf, so the historical
        arrival order is preserved); results return in submission order.
        ``warm=True`` runs each executable once untimed first (compile
        excluded from latency, as the paper excludes load time)."""
        queue, self._queue = self._queue, []
        if self.admission is not None:
            self.admission.on_flush()
        if self.metrics is not None:
            self._mx_queue.set(0)
        if not queue:
            self.last_dispatches = []
            return []
        groups: Dict[tuple, List[int]] = {}
        for i, entry in enumerate(queue):
            qry = entry.inst.qry
            key = (bucket_key(qry), self._mode_for(qry),
                   entry.engine or self._engine_for(qry), entry.impl)
            groups.setdefault(key, []).append(i)

        # EDF at dispatch-chunk granularity: each group's members sort by
        # deadline, split into bounded chunks when any member carries an
        # admission batch cap, and every chunk competes in one global
        # earliest-deadline order (seq breaks ties by arrival).
        units: List[tuple] = []
        seq = 0
        for key, idxs in groups.items():
            idxs = sorted(idxs, key=lambda i: (queue[i].deadline, i))
            caps = [queue[i].max_batch for i in idxs
                    if queue[i].max_batch is not None]
            cap = min(caps) if caps else len(idxs)
            for k in range(0, len(idxs), cap):
                chunk = idxs[k:k + cap]
                units.append((min(queue[i].deadline for i in chunk), seq,
                              key, chunk))
                seq += 1
        units.sort(key=lambda u: (u[0], u[1]))

        out: List[Optional[ServedResult]] = [None] * len(queue)
        dispatches: List[GroupDispatch] = []
        traced_groups: List[tuple] = []
        self._flush_count += 1
        # the retry state machine runs on the flush's VIRTUAL now: arrival
        # frame (what submit's ``now`` used) + accounted service so far —
        # deadline-aware retry budgets compare in the deadline's own frame
        flush_now = max((e.arrival for e in queue), default=0.0)
        retry_rng = self.retry.rng() if self.retry is not None else None
        for edf_pos, (group_deadline, _, key, idxs) in enumerate(units):
            self._serve_unit(queue, out, key, list(idxs), warm, edf_pos,
                             group_deadline, dispatches, traced_groups,
                             flush_now, retry_rng)
        for grp in traced_groups:
            self._trace_group(queue, *grp, out)
        self.last_dispatches = dispatches
        self.n_dispatched += len(queue)
        return out  # type: ignore[return-value]

    # ------------------------------------------------------- fault handling
    def _mark_unit(self, queue, out, idxs, engine: str, err,
                   status: str) -> None:
        """Terminal non-answer for every member of a unit: a structured
        per-query error (never an unhandled exception — the completion
        contract is answer-or-structured-reject)."""
        msg = str(err)
        for i in idxs:
            out[i] = ServedResult(
                template=queue[i].inst.template, engine=engine,
                split=-1, count=-1.0, latency_ms=0.0, ok=False,
                batch_size=len(idxs), error=msg,
                deadline=queue[i].deadline, status=status)
            self.tracer.end(queue[i].span, status=status, error=msg)

    def _trace_fault(self, e, action: str, attempt: int, idxs) -> None:
        """One flight-recorder span per fault-handling decision."""
        tr = self.tracer
        if not tr.enabled:
            return
        sp = tr.start("fault", point=getattr(type(e), "point", "fault"),
                      action=action, attempt=attempt, unit_size=len(idxs),
                      error=str(e))
        tr.end(sp)

    def _count_fallback(self, reason: str) -> None:
        self.n_fallbacks += 1
        if self.metrics is not None:
            self._mx_degraded_disp.inc(reason=reason)

    def _bisect(self, queue, out, key, idxs, warm, edf_pos, dispatches,
                traced_groups, flush_now, retry_rng, depth) -> None:
        """Split a repeatedly-failing unit in half and serve each half
        independently — recursion isolates a deterministic poison query
        down to a singleton, which quarantine then rejects while every
        other member still answers."""
        mid = len(idxs) // 2
        for half in (idxs[:mid], idxs[mid:]):
            gd = min(queue[i].deadline for i in half)
            self._serve_unit(queue, out, key, half, warm, edf_pos, gd,
                             dispatches, traced_groups, flush_now,
                             retry_rng, depth + 1)

    def _serve_unit(self, queue, out, key, idxs, warm, edf_pos,
                    group_deadline, dispatches, traced_groups, flush_now,
                    retry_rng, depth: int = 0) -> None:
        """Serve one EDF dispatch unit through the retry/quarantine state
        machine (the historical one-attempt behaviour when no ``retry``
        policy is attached)."""
        bucket, mode, engine, impl_over = key
        fallback_from = ""
        # partitioned-path availability: while the planner holds the path
        # down, units re-plan onto the dense executor (bit-identical
        # answers); once the probe window elapses the next unit probes the
        # partitioned path for real
        if (engine == "partitioned" and self.retry is not None
                and not self._planner.engine_available("partitioned")
                and self._flush_count < self._part_down_until):
            fallback_from, engine = engine, "dense"
            self._count_fallback("path-down")
        insts = [queue[i].inst for i in idxs]
        queries = [x.qry for x in insts]
        self._last_used_delta = False
        penalty_s = 0.0
        n_retries = 0
        attempt = 0
        failures = 0
        readmitted = False
        while True:
            try:
                split, impl, plan_cached, candidates = self._plan_group(
                    queries, bucket, mode, engine, impl_override=impl_over)
                pt = compile_plan_tensor(queries, pad=self.pad_batches)
                res, dt_raw, exec_cached = self._dispatch(
                    queries, split, mode, engine, impl, bucket, pt, warm)
                break
            except FaultError as e:
                if self.retry is None:
                    self._mark_unit(queue, out, idxs, engine, e, "failed")
                    return
                if isinstance(e, WorkerLostError) and engine == "partitioned":
                    # worker-loss degradation: mark the path down, re-plan
                    # this unit dense (conformance-pinned bit-identical)
                    self._planner.mark_unavailable("partitioned")
                    self._part_down_until = (self._flush_count
                                             + self.retry.probe_after)
                    fallback_from, engine = engine, "dense"
                    self._count_fallback("worker-loss")
                    self._trace_fault(e, "fallback", attempt, idxs)
                    continue
                failures += 1
                if (failures >= self.retry.max_group_failures
                        and len(idxs) > 1):
                    self._trace_fault(e, "bisect", attempt, idxs)
                    self._bisect(queue, out, key, idxs, warm, edf_pos,
                                 dispatches, traced_groups, flush_now,
                                 retry_rng, depth)
                    return
                if attempt + 1 >= self.retry.max_attempts:
                    if len(idxs) > 1:
                        self._trace_fault(e, "bisect", attempt, idxs)
                        self._bisect(queue, out, key, idxs, warm, edf_pos,
                                     dispatches, traced_groups, flush_now,
                                     retry_rng, depth)
                        return
                    self.n_quarantined += 1
                    if self.metrics is not None:
                        self._mx_quarantined.inc()
                    self._trace_fault(e, "quarantine", attempt, idxs)
                    self._mark_unit(
                        queue, out, idxs, engine,
                        f"quarantined after {attempt + 1} attempts: {e}",
                        "quarantined")
                    return
                delay = backoff_delay(
                    attempt, self.retry.base_delay_s, self.retry.multiplier,
                    self.retry.max_delay_s, self.retry.jitter_frac,
                    retry_rng)
                t_now = (flush_now + sum(d.service_s for d in dispatches)
                         + penalty_s)
                if t_now + delay > group_deadline:
                    # retry budget exhausted: a retry never fires past the
                    # EDF deadline — re-enter admission once with the
                    # remaining budget (an admit earns one immediate,
                    # possibly impl-degraded, attempt), else time out
                    if not readmitted and self.admission is not None:
                        i0 = min(idxs, key=lambda i: queue[i].deadline)
                        dec = self.admission.decide(
                            self, queue[i0].inst, t_now,
                            max(group_deadline - t_now, 0.0))
                        if dec.admitted:
                            readmitted = True
                            if dec.impl is not None:
                                impl_over = dec.impl
                            attempt += 1
                            self._trace_fault(e, "readmit", attempt, idxs)
                            continue
                    self.n_timeout += len(idxs)
                    self._trace_fault(e, "timeout", attempt, idxs)
                    self._mark_unit(
                        queue, out, idxs, engine,
                        f"timed out: retry at +{delay:.3f}s would pass the "
                        f"deadline: {e}", "timeout")
                    return
                penalty_s += delay
                n_retries += 1
                self.n_retries += 1
                if self.metrics is not None:
                    self._mx_retries.inc(kind=getattr(type(e), "point",
                                                      "fault"))
                self._trace_fault(e, "retry", attempt, idxs)
                attempt += 1
            except Exception as e:
                # a failing group (e.g. a non-sliceable query forced onto the
                # sliced engine, or an unsupported op surfacing at trace time)
                # must not take the rest of the flush with it
                self._mark_unit(queue, out, idxs, engine, e, "failed")
                return
        if (engine == "partitioned"
                and not self._planner.engine_available("partitioned")):
            self._planner.mark_available("partitioned")  # probe succeeded
        seq = self._dispatch_seq
        self._dispatch_seq += 1
        feats = ests = None
        if self.telemetry is not None or self.tracer.enabled:
            feats, ests = self._group_features(queries, split, engine,
                                               impl, pt)
        predicted_ms = 0.0
        if self.telemetry is not None:
            # θ refit sees the RAW dispatch time: retry backoff is queueing
            # penalty, not service cost, and must not skew the cost model
            predicted_ms = self._record_telemetry(feats, engine, dt_raw)
        if self.metrics is not None:
            self._mx_dispatch_ms.observe(dt_raw * 1e3)
            self._mx_dispatched.inc(pt.n_real)
            self._mx_cache.inc(cache="plan",
                               event="hit" if plan_cached else "miss")
            self._mx_cache.inc(cache="executable",
                               event="hit" if exec_cached else "miss")
        # latency the CLIENT sees includes accounted retry backoff
        dt_total = dt_raw + penalty_s
        per_query_ms = dt_total * 1e3 / pt.n_real
        ok = per_query_ms <= self.budget_s * 1e3

        total = np.asarray(res.total)
        pv = None if res.per_vertex is None else np.asarray(res.per_vertex)
        mm = None if res.minmax is None else np.asarray(res.minmax)
        for j, i in enumerate(idxs):
            t_j = total[j]
            out[i] = ServedResult(
                template=insts[j].template, engine=engine, split=split,
                count=float(t_j.sum()) if t_j.ndim else float(t_j),
                latency_ms=per_query_ms, ok=ok, batch_size=pt.n_real,
                total=t_j if self.keep_outputs else None,
                per_vertex=(pv[j] if self.keep_outputs and pv is not None
                            else None),
                minmax=(mm[j] if self.keep_outputs and mm is not None
                        else None),
                deadline=queue[i].deadline,
            )
        if self.tracer.enabled:
            # span construction is DEFERRED to after the dispatch loop:
            # building hundreds of record dicts between two ~ms timed
            # JAX calls measurably pollutes the CPU caches the next
            # dispatch runs on (the bench obs leg gates this at ≤5%)
            traced_groups.append(
                (idxs, ests, feats, split, engine, impl, pt, dt_raw,
                 plan_cached, exec_cached, candidates, seq, edf_pos,
                 group_deadline, predicted_ms))
        dispatches.append(GroupDispatch(
            key, engine, split, pt.n_real, pt.n_pad, dt_total, list(idxs),
            plan_cached, exec_cached, impl, group_deadline, predicted_ms,
            delta=self._last_used_delta, n_retries=n_retries,
            fallback_from=fallback_from, penalty_s=penalty_s))

    def run(self, workload: Sequence[Union[QueryInstance, Q.PathQuery]],
            warm: bool = False) -> List[ServedResult]:
        """Submit a whole workload and drain it in one flush."""
        for inst in workload:
            self.submit(inst)
        return self.flush(warm=warm)

    # ------------------------------------------------------------- reporting
    def cache_report(self) -> dict:
        return dict(
            plan=self.plan_cache.stats.as_dict(),
            executable=self.exec_cache.stats.as_dict(),
            n_plans=len(self.plan_cache),
            n_executables=len(self.exec_cache),
        )

    def slo_report(self) -> dict:
        """Admission + telemetry counters (all zero without an SLO layer)."""
        d = dict(n_rejected=self.n_rejected, n_degraded=self.n_degraded)
        if self.admission is not None:
            d["admission"] = self.admission.report()
        if self.telemetry is not None:
            d["telemetry"] = self.telemetry.error_stats()
        return d

    def fault_report(self) -> dict:
        """Retry/quarantine/degradation counters (all zero without a fault
        layer) plus the fault plan's consultation ledger."""
        d = dict(n_retries=self.n_retries, n_quarantined=self.n_quarantined,
                 n_timeout=self.n_timeout, n_fallbacks=self.n_fallbacks,
                 partitioned_available=self._planner.engine_available(
                     "partitioned"))
        if self.fault_plan is not None:
            d["fault_plan"] = self.fault_plan.report()
        return d
