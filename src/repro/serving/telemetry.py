"""Serving telemetry: a dispatch-cost ring buffer with periodic online θ
refit.

Every dispatch the scheduler times is recorded as a row

    (features, predicted_ms, measured_ms)

where ``features`` is the group's batch-summed feature vector over the
planner's ``COEFF_KEYS`` basis (core/planner.py) — the same columns
``benchmarks/fit_cost_model.py`` fits offline, derived from the very
estimate the scheduler predicted the dispatch with, so

    predicted_ms == features @ coeff_vector(θ)

holds by construction at record time.  Periodically (every ``refit_every``
records, once ``min_samples`` rows exist) the buffer re-solves the same
least-squares regression the offline fit runs — restricted to the columns
the serving trace actually exercises, clamped non-negative, and blended with
the incumbent θ for stability — and hands the scheduler an updated
coefficient dict.  Prediction error therefore SHRINKS during serving instead
of requiring an offline ``fit_cost_model`` run: an unfitted host starts on
the package defaults and calibrates itself from its own dispatch stream
(the paper's "within 10% of optimal 90% of the time" accuracy claim, made a
live property instead of an offline one).

The ring buffer is bounded (``capacity``) so a long-running server tracks
the RECENT cost regime — after a workload shift the stale rows age out and
the refit follows.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from ..core.planner import COEFF_KEYS, coeff_vector, fit_linear


@dataclasses.dataclass
class DispatchSample:
    """One timed dispatch: the prediction made and the truth measured."""
    features: np.ndarray        # [len(COEFF_KEYS)] batch-summed feature row
    predicted_ms: float
    measured_ms: float


def _abs_rel_err(pred: np.ndarray, meas: np.ndarray) -> np.ndarray:
    return np.abs(pred - meas) / np.maximum(np.abs(meas), 1e-9)


class TelemetryBuffer:
    """Bounded (predicted, measured) dispatch log + online θ refit.

    ``refit=False`` turns the buffer into a pure error recorder (the
    static-θ baseline the benches compare the online fit against).
    ``blend`` is the fraction of the fresh least-squares solution mixed into
    the incumbent θ per refit (1.0 = jump straight to the new fit).
    """

    def __init__(self, capacity: int = 512, refit_every: int = 32,
                 min_samples: int = 8, blend: float = 0.5,
                 refit: bool = True):
        assert capacity >= min_samples >= 2
        self.capacity = capacity
        self.refit_every = refit_every
        self.min_samples = min_samples
        self.blend = blend
        self.refit_enabled = refit
        self._rows: Deque[DispatchSample] = deque(maxlen=capacity)
        #: full-trace error log (never truncated — the report's raw series)
        self.errors: List[float] = []
        self.n_recorded = 0
        self.n_refits = 0

    # -------------------------------------------------------------- recording
    def record(self, features: np.ndarray, predicted_ms: float,
               measured_ms: float) -> None:
        self._rows.append(DispatchSample(np.asarray(features, float),
                                         float(predicted_ms),
                                         float(measured_ms)))
        self.errors.append(float(_abs_rel_err(
            np.asarray(predicted_ms), np.asarray(measured_ms))))
        self.n_recorded += 1

    def __len__(self) -> int:
        return len(self._rows)

    def should_refit(self) -> bool:
        return (self.refit_enabled
                and len(self._rows) >= self.min_samples
                and self.n_recorded % self.refit_every == 0)

    # ---------------------------------------------------------------- refit
    def refit(self, coeffs: dict) -> dict:
        """One online refit pass: least squares over the buffered rows on the
        columns this trace exercises, non-negative, blended into ``coeffs``.

        Returns the updated coefficient dict (also suitable for
        ``planner.coeffs.update``).  Columns the trace never exercised keep
        their incumbent values — a dense-only serving trace cannot perturb
        the partitioned exchange terms, and vice versa.
        """
        X = np.stack([s.features for s in self._rows])
        y = np.asarray([s.measured_ms for s in self._rows])
        theta = coeff_vector(coeffs)
        # only columns with signal in THIS trace participate in the solve;
        # the incumbent values of the rest are moved to the left-hand side so
        # the active columns fit the residual (the offline fit's two-stage
        # residual regression, generalised to whatever columns are live)
        active = np.any(X != 0.0, axis=0)
        if not np.any(active):
            return dict(coeffs)
        resid = y - X[:, ~active] @ theta[~active]
        sol = fit_linear(X[:, active], resid)
        new = theta.copy()
        new[active] = np.maximum(
            (1.0 - self.blend) * theta[active] + self.blend * sol, 0.0)
        self.n_refits += 1
        out = dict(coeffs)
        out.update({k: float(new[i]) for i, k in enumerate(COEFF_KEYS)
                    if active[i]})
        return out

    # -------------------------------------------------------------- reporting
    def error_stats(self, tail: Optional[int] = None) -> dict:
        """Mean/p90 absolute relative prediction error — over the whole
        recorded trace and (``tail_*``) its final stretch, where the online
        refit has had samples to learn from.

        Always returns a well-defined NaN-free dict: an empty buffer is all
        zeros, ``tail`` is clamped to the recorded length (``tail=0`` means
        an empty tail → 0.0, not whole-trace stats via ``e[-0:]``)."""
        if not self.errors:
            return dict(n=0, mean_abs_rel_err=0.0, p90_abs_rel_err=0.0,
                        tail_mean_abs_rel_err=0.0, n_refits=self.n_refits)
        e = np.asarray(self.errors)
        if tail is None:
            k = max(1, len(e) // 2)
        else:
            k = max(0, min(int(tail), len(e)))
        return dict(
            n=len(e),
            mean_abs_rel_err=float(e.mean()),
            p90_abs_rel_err=float(np.percentile(e, 90)),
            tail_mean_abs_rel_err=float(e[-k:].mean()) if k else 0.0,
            n_refits=self.n_refits,
        )
