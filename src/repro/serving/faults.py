"""Deterministic chaos injection + retry policy for the serving stack.

The paper's headline robustness claim is *completion*: Granite answers 100%
of the 1600-query workload where the baselines finish 32–92% (§VI).  This
module supplies the two halves that make that claim testable here:

**FaultPlan** — a deterministic chaos-injection harness.  Production code
consults the plan at *named injection points* ("compile", "dispatch",
"worker", "straggler", "wal"); the plan decides — from a seeded RNG rate
and/or an explicit per-point schedule — whether that consultation fails,
and the caller raises the matching ``FaultError`` subclass.  Decisions are
keyed by ``(seed, point, k)`` where ``k`` is the per-point consultation
counter, so a plan replays identically regardless of how calls from
different points interleave — every failure mode is reproducible with zero
real compilation (the FakeDispatcher virtual clock consults the same
points as the real JAX dispatch path).

Injection points (who consults, what failing means):

====================  ====================================================
``compile``           ``BatchScheduler._dispatch`` before lowering — the
                      group's executable build failed (``CompileError``).
``dispatch``          ``BatchScheduler._dispatch`` around the engine call —
                      a transient execution error (``TransientDispatchError``),
                      retryable with backoff.
``worker``            partitioned dispatches only — a designated partition
                      worker was lost (``WorkerLostError``); the scheduler
                      re-plans the group onto the dense executor and marks
                      the partitioned path unavailable until a probe
                      succeeds.
``straggler``         never raises — returns a multiplicative service-time
                      inflation (``straggler_factor``) accounted into the
                      virtual clock.
``wal``               ``EventLog`` WAL appends — the write is torn mid-line
                      (a prefix hits the disk, then ``TornWriteError``),
                      simulating a crash; recovery must truncate the tail.
====================  ====================================================

**RetryPolicy** — how the scheduler responds: exponential backoff with
seeded jitter (``repro.faults_common.backoff_delay``; delays are accounted
into the virtual clock, never slept), a deadline-aware retry budget (a
retry that would land past the group's EDF deadline re-enters admission
instead of firing), and poison-query quarantine (a group that keeps
failing is bisected until the single poison query is isolated and rejected
with a structured error while the rest of the batch still answers).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Set

import numpy as np

#: injection points a FaultPlan understands
FAULT_POINTS = ("compile", "dispatch", "worker", "straggler", "wal")


# --------------------------------------------------------------------- errors
class FaultError(RuntimeError):
    """Base of every injected (or injected-equivalent real) serving fault."""
    point = "fault"


class TransientDispatchError(FaultError):
    """A dispatch failed in a way a retry can fix."""
    point = "dispatch"


class CompileError(FaultError):
    """The group's executable failed to build."""
    point = "compile"


class WorkerLostError(FaultError):
    """A partition worker died mid-dispatch (partitioned engine only)."""
    point = "worker"

    def __init__(self, msg: str = "partition worker lost", worker: int = 0):
        super().__init__(msg)
        self.worker = int(worker)


class TornWriteError(FaultError):
    """A WAL append was cut mid-line — the simulated process crash."""
    point = "wal"


class PoisonQueryError(FaultError):
    """A query that fails deterministically no matter how it is dispatched."""
    point = "poison"


# ----------------------------------------------------------------- fault plan
@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault schedule consulted at named injection points.

    ``rates[point]`` gives an independent per-consultation fault probability
    drawn from ``SeedSequence([seed, hash(point), k])`` — reproducible and
    interleaving-independent.  ``schedule[point]`` names exact consultation
    indices (0-based ``k``) that must fail, for surgical tests ("the second
    dispatch dies").  Both may be active; either firing injects.

    ``poison`` marks queries as deterministically bad: the scheduler raises
    ``PoisonQueryError`` whenever a dispatch group contains one, which is
    what drives the bisection/quarantine machinery.

    A plan never *raises* by itself — ``should_fail`` returns a bool and the
    consulting site raises the taxonomy error — so the same plan object can
    drive the FakeDispatcher harness, the real JAX path, and the WAL.
    """
    seed: int = 0
    #: per-point independent fault probability in [0, 1)
    rates: Mapping[str, float] = dataclasses.field(default_factory=dict)
    #: per-point explicit consultation indices that must fail
    schedule: Mapping[str, Set[int]] = dataclasses.field(default_factory=dict)
    #: queries for which every dispatch fails (drives quarantine bisection)
    poison: Optional[Callable] = None
    #: service-time inflation applied when the "straggler" point fires
    straggler_factor: float = 3.0

    def __post_init__(self):
        for pt in list(self.rates) + list(self.schedule):
            if pt not in FAULT_POINTS:
                raise ValueError(f"unknown fault point {pt!r}; "
                                 f"expected one of {FAULT_POINTS}")
        self.consulted: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}

    # ------------------------------------------------------------ consultation
    def _draw(self, point: str, k: int) -> float:
        # hash via a stable per-point integer (index in FAULT_POINTS) so the
        # stream is identical across processes (PYTHONHASHSEED-independent)
        pid = FAULT_POINTS.index(point)
        ss = np.random.SeedSequence([int(self.seed), pid, int(k)])
        return float(np.random.Generator(np.random.PCG64(ss)).random())

    def should_fail(self, point: str) -> bool:
        """Consult the plan at ``point``; advances that point's counter."""
        k = self.consulted.get(point, 0)
        self.consulted[point] = k + 1
        fail = k in self.schedule.get(point, ())
        rate = float(self.rates.get(point, 0.0))
        if not fail and rate > 0.0:
            fail = self._draw(point, k) < rate
        if fail:
            self.fired[point] = self.fired.get(point, 0) + 1
        return fail

    def straggle(self) -> float:
        """Service-time multiplier for this consultation (1.0 = no fault)."""
        return self.straggler_factor if self.should_fail("straggler") else 1.0

    def is_poison(self, qry) -> bool:
        return bool(self.poison is not None and self.poison(qry))

    # --------------------------------------------------------------- reporting
    def report(self) -> dict:
        return dict(seed=self.seed,
                    consulted=dict(self.consulted),
                    fired=dict(self.fired))


# --------------------------------------------------------------- retry policy
@dataclasses.dataclass
class RetryPolicy:
    """How ``BatchScheduler`` responds to a failed dispatch unit.

    Attempts are bounded by ``max_attempts``; between attempts the scheduler
    *accounts* (never sleeps) ``backoff_delay(attempt, ...)`` of virtual
    time.  A retry whose backoff would land past the group's EDF deadline
    does not fire — the group re-enters admission with its remaining budget
    and either gets one immediate (possibly degraded) retry or times out
    with a structured error.  A unit that accumulates ``max_group_failures``
    failures and still holds >1 query is bisected; a single query that
    exhausts its attempts is quarantined.  After a worker-loss fallback the
    partitioned path stays marked unavailable for ``probe_after`` flushes
    before a probe dispatch is attempted.
    """
    max_attempts: int = 3
    base_delay_s: float = 0.01
    multiplier: float = 2.0
    max_delay_s: float = 1.0
    jitter_frac: float = 0.1
    #: unit failures before bisection kicks in (the "fails twice" rule)
    max_group_failures: int = 2
    #: flushes the partitioned path stays down before probing it again
    probe_after: int = 2
    seed: int = 0

    def rng(self) -> np.random.Generator:
        """Fresh seeded jitter stream (one per flush keeps runs replayable)."""
        return np.random.Generator(np.random.PCG64(
            np.random.SeedSequence([int(self.seed), 0xB0FF])))
