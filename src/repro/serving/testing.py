"""Deterministic serving test harness: synthetic dispatch on a virtual clock.

The SLO layer (admission control, EDF ordering, online θ refit, closed-loop
replay) is control logic — none of it needs a compiled engine to be tested,
and compiling one per test case would bury the logic under JAX tracing time
and host-timing noise.  ``FakeDispatcher`` plugs into
``BatchScheduler(dispatcher=...)`` and replaces the build-and-run step with:

  * a SYNTHETIC service time from an injected model (e.g. the planner's own
    feature rows dotted with a hidden "true" θ* — so refit convergence is a
    provable property, not a flaky timing assertion), and
  * deterministic fake outputs derived from each query's parameter row (so
    submission-order and permutation-invariance properties can check that
    every query got ITS OWN answer back through the grouping machinery).

Everything downstream — EDF ordering, chunking, telemetry recording,
admission backlog, replay accounting — runs EXACTLY the production code
path; only the JAX call is swapped out.  Zero compilation, virtual time.

Fault injection rides the same funnel: the scheduler consults its
``FaultPlan`` in ``BatchScheduler._dispatch`` BEFORE delegating here, so
chaos tests (``tests/test_serving_faults.py``) exercise retry, quarantine,
and worker-loss fallback against the virtual clock — backoff penalties are
accounted into service time, never slept.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from ..core import query as Q
from ..core.planner import coeff_vector
from .compile import PlanTensor


@dataclasses.dataclass
class FakeOutput:
    """Mimics the engines' batched output surface (total/per_vertex/minmax)."""
    total: np.ndarray
    per_vertex: Optional[np.ndarray] = None
    minmax: Optional[np.ndarray] = None


def fake_count(qry: Q.PathQuery) -> float:
    """Deterministic per-query 'result': a pure function of the parameter
    row, so tests can assert each query's answer survived grouping, EDF
    reordering, chunking, and permutation."""
    return float(int(np.abs(Q.query_params(qry)).sum()) % 9973)


def planner_service_model(true_coeffs: dict, scale: float = 1.0
                          ) -> Callable:
    """Service model: the group's batch-summed planner features dotted with
    a FROZEN 'true' θ* (ms → s).  Because the scheduler predicts with its
    LIVE θ, setting θ* ≠ θ creates a known prediction error that the online
    refit must provably shrink — the telemetry test's ground truth."""
    theta_star = None

    def model(sched, queries, split, mode, engine, impl,
              pt: PlanTensor) -> float:
        nonlocal theta_star
        if theta_star is None:
            theta_star = coeff_vector(true_coeffs)
        planner = sched._planner_for(engine)
        feats = planner.estimate_batch(queries, split, impl=impl).features
        if pt.n_pad:
            feats = feats + pt.n_pad * planner.estimate(
                queries[0], split, impl).features
        return float(feats @ theta_star) * scale / 1e3

    return model


def constant_service_model(per_query_s: float, overhead_s: float = 0.0
                           ) -> Callable:
    """Service = overhead + per_query · B_pad: the simplest closed-form for
    exact latency arithmetic in deadline/backlog tests."""
    def model(sched, queries, split, mode, engine, impl,
              pt: PlanTensor) -> float:
        return overhead_s + per_query_s * pt.params.shape[0]
    return model


@dataclasses.dataclass
class FakeCall:
    """One recorded dispatch (the harness's observability channel)."""
    queries: List[Q.PathQuery]
    split: int
    mode: int
    engine: str
    impl: str
    n_real: int
    n_pad: int
    service_s: float


class FakeDispatcher:
    """Drop-in for the scheduler's JAX dispatch: synthetic service times,
    deterministic outputs, optional injected failures.

    ``fail``: predicate ``(queries, engine, impl) -> bool`` — a True return
    raises inside dispatch, exercising the scheduler's failing-group
    isolation and the replay harness's failed-group accounting without
    needing a real trace-time error.
    """

    def __init__(self, service_model: Optional[Callable] = None,
                 fail: Optional[Callable] = None,
                 per_vertex: bool = False):
        self.service_model = service_model or constant_service_model(1e-3)
        self.fail = fail
        self.per_vertex = per_vertex
        self.calls: List[FakeCall] = []

    def dispatch(self, sched, queries, split, mode, engine, impl,
                 pt: PlanTensor, warm: bool):
        if self.fail is not None and self.fail(queries, engine, impl):
            raise RuntimeError(
                f"injected dispatch failure (engine={engine}, impl={impl})")
        service_s = float(self.service_model(
            sched, queries, split, mode, engine, impl, pt))
        b_pad = pt.params.shape[0]
        total = np.zeros(b_pad, np.float64)
        for j, q in enumerate(queries):
            total[j] = fake_count(q)
        total[len(queries):] = total[0] if queries else 0.0  # pad rows
        pv = (np.zeros((b_pad, 1), np.float64) if self.per_vertex else None)
        self.calls.append(FakeCall(list(queries), split, mode, engine, impl,
                                   pt.n_real, pt.n_pad, service_s))
        return FakeOutput(total, pv), service_s
