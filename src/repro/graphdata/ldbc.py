"""LDBC-SNB-style temporal property graph generator (S3G2-flavoured).

Generates downscaled versions of the paper's evaluation graphs (Table 4):
Person / Post / Comment / Forum vertices; follows / likes / created /
hasMember / containerOf / replyOf edges; correlated properties with lifespans
over a 3-year horizon.  Supports the paper's four person-follows-person
degree distributions (Altmann A, Discrete-Weibull DW, Facebook F, Zipf Z) and
both static (S) and dynamic (D) property variants.

Time model: day-granular int32 time-units over ``[0, T)`` with ``T = 1096``
(3 years).  With ``align=n``, every timestamp is snapped to a multiple of
``T/n`` so the bucketised temporal modes are exact (see DESIGN.md §2); the
benchmark workloads use ``align=16``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .loader import GraphBuilder

T_HORIZON = 1096

VTYPES = ("person", "post", "comment", "forum")
ETYPES = ("follows", "likes", "created", "hasMember", "containerOf", "replyOf")

COUNTRIES = [
    "india", "uk", "us", "china", "germany", "france", "brazil", "japan",
    "kenya", "spain", "mexico", "canada", "italy", "australia", "nigeria",
]
COMPANIES = [f"company{i}" for i in range(40)]
N_TAGS = 64
GENDERS = ("m", "f")
LANGS = ("en", "hi", "zh", "es", "de")


@dataclasses.dataclass
class LdbcParams:
    n_persons: int = 1000
    degree_dist: str = "zipf"          # 'altmann' | 'weibull' | 'facebook' | 'zipf'
    dynamic: bool = False              # static (S) vs dynamic (D) properties
    posts_per_person: float = 4.0
    comments_per_person: float = 8.0
    forums_per_person: float = 0.8
    avg_follows: float = 10.2          # paper: 10.2 friends on average
    interests_per_person: float = 4.0  # paper: 23 (downscaled)
    tags_per_message: float = 1.22     # paper: 1.22 tags per comment
    align: int = 16                    # snap times to T/align grid (0 = off)
    seed: int = 0


def _snap(rng_times: np.ndarray, align: int) -> np.ndarray:
    """Snap to the same ceil-width grid that intervals.bucket_edges uses, so
    bucketised temporal modes are exact on generated data."""
    if not align:
        return rng_times.astype(np.int64)
    step = -(-T_HORIZON // align)  # ceil
    return (rng_times // step) * step


def _degree_samples(rng, dist: str, n: int, avg: float) -> np.ndarray:
    """Out-degree samples for person-follows-person under the four dists."""
    if dist == "zipf":
        d = rng.zipf(2.0, size=n)
    elif dist == "facebook":                      # heavy-ish lognormal
        d = np.exp(rng.normal(np.log(avg) - 0.5, 1.0, size=n))
    elif dist == "weibull":                       # discrete Weibull
        d = rng.weibull(0.8, size=n) * avg
    elif dist == "altmann":                       # power law w/ exp cutoff
        d = rng.zipf(1.9, size=n) * np.exp(-rng.exponential(0.2, size=n))
    else:
        raise ValueError(dist)
    d = np.clip(np.round(d * (avg / max(d.mean(), 1e-9))), 0, 20 * avg)
    return d.astype(np.int64)


def generate_ldbc(params: LdbcParams) -> "TemporalGraph":
    rng = np.random.default_rng(params.seed)
    b = GraphBuilder()
    b.lifespan = (0, T_HORIZON)
    tp = {n: b.vertex_type(n) for n in VTYPES}
    te = {n: b.edge_type(n) for n in ETYPES}
    k_name = b.key("name")
    k_country = b.key("country")
    k_gender = b.key("gender")
    k_interest = b.key("hasInterest")
    k_works = b.key("worksAt")
    k_tag = b.key("tag")
    k_lang = b.key("language")
    k_len = b.key("length", ordered=True)

    N = params.n_persons
    align = params.align

    def birth(n, late=0.9):
        return _snap(rng.integers(0, int(T_HORIZON * late), size=n), align)

    # ---------------------------------------------------------------- persons
    p_start = birth(N)
    person_ids = [b.add_vertex(tp["person"], (int(s), T_HORIZON)) for s in p_start]
    tag_pop = rng.zipf(1.6, size=4 * N) % N_TAGS  # zipf-popular tag pool
    for i, vid in enumerate(person_ids):
        b.set_vprop(vid, k_name, f"p{i}")
        b.set_vprop(vid, k_gender, GENDERS[int(rng.integers(2))])
        s = int(p_start[i])
        if params.dynamic:
            # country + worksAt change over time (the paper's dynamic props)
            n_seg = int(rng.integers(1, 4))
            cuts = np.sort(_snap(rng.integers(s, T_HORIZON, size=n_seg - 1), align)) \
                if n_seg > 1 else np.asarray([], np.int64)
            bounds = [s, *[int(c) for c in cuts], T_HORIZON]
            bounds = sorted(set(bounds))
            n_seg_eff = len(bounds) - 1
            # sample without replacement so each (key, value) pair is valid
            # for a single contiguous window — the engine's interval-mode
            # envelope (DESIGN.md §2) and the natural "moved country" shape.
            cs = rng.choice(len(COUNTRIES), size=n_seg_eff, replace=False)
            ws = rng.choice(len(COMPANIES), size=n_seg_eff, replace=False)
            for j in range(n_seg_eff):
                if bounds[j] < bounds[j + 1]:
                    b.set_vprop(vid, k_country, COUNTRIES[int(cs[j])],
                                (bounds[j], bounds[j + 1]))
                    b.set_vprop(vid, k_works, COMPANIES[int(ws[j])],
                                (bounds[j], bounds[j + 1]))
        else:
            b.set_vprop(vid, k_country, COUNTRIES[int(rng.integers(len(COUNTRIES)))])
            b.set_vprop(vid, k_works, COMPANIES[int(rng.integers(len(COMPANIES)))])
        n_int = max(1, int(rng.poisson(params.interests_per_person)))
        ints = np.unique(rng.choice(tag_pop, size=n_int))
        for t in ints:
            if params.dynamic:
                ts = int(_snap(rng.integers(s, T_HORIZON), align))
                b.set_vprop(vid, k_interest, f"tag{t}", (min(ts, T_HORIZON - 1), T_HORIZON))
            else:
                b.set_vprop(vid, k_interest, f"tag{t}")

    # ---------------------------------------------------------------- follows
    deg = _degree_samples(rng, params.degree_dist, N, params.avg_follows)
    src = np.repeat(np.arange(N), deg)
    dst = rng.integers(0, N, size=src.shape[0])
    keep = src != dst
    src, dst = src[keep], dst[keep]
    for s_, d_ in zip(src, dst):
        lo = max(int(p_start[s_]), int(p_start[d_]))
        st = int(_snap(rng.integers(lo, T_HORIZON), align))
        st = min(st, T_HORIZON - 1)
        # some follows end (unfollow) — makes ETR queries non-trivial
        if rng.random() < 0.35:
            step = -(-T_HORIZON // align) if align else 1
            en = int(_snap(rng.integers(st + 1, T_HORIZON + 1), align))
            if en <= st:  # keep grid-aligned when pushing past start
                en = st + step
            en = min(en, T_HORIZON)
        else:
            en = T_HORIZON
        b.add_edge(int(person_ids[s_]), int(person_ids[d_]), te["follows"], (st, en))

    # ------------------------------------------------------------------ forums
    n_forums = int(params.forums_per_person * N)
    f_start = birth(n_forums)
    forum_ids = [b.add_vertex(tp["forum"], (int(s), T_HORIZON)) for s in f_start]
    forum_tags = rng.choice(tag_pop, size=n_forums)
    for i, vid in enumerate(forum_ids):
        b.set_vprop(vid, k_tag, f"tag{forum_tags[i]}")
    # membership: each person joins ~3 forums
    for i, pid in enumerate(person_ids):
        for f in rng.integers(0, max(n_forums, 1), size=int(rng.poisson(3.0))):
            lo = max(int(p_start[i]), int(f_start[f]))
            st = min(int(_snap(rng.integers(lo, T_HORIZON), align)), T_HORIZON - 1)
            b.add_edge(int(forum_ids[f]), int(pid), te["hasMember"], (st, T_HORIZON))

    # ------------------------------------------------------------------- posts
    n_posts = int(params.posts_per_person * N)
    creators = rng.integers(0, N, size=n_posts)
    post_forum = rng.integers(0, max(n_forums, 1), size=n_posts)
    post_ids = []
    for i in range(n_posts):
        lo = max(int(p_start[creators[i]]), int(f_start[post_forum[i]]) if n_forums else 0)
        st = min(int(_snap(rng.integers(lo, T_HORIZON), align)), T_HORIZON - 1)
        vid = b.add_vertex(tp["post"], (st, T_HORIZON))
        post_ids.append(vid)
        for t in np.unique(rng.choice(tag_pop, size=max(1, int(rng.poisson(params.tags_per_message))))):
            b.set_vprop(vid, k_tag, f"tag{t}")
        b.set_vprop(vid, k_lang, LANGS[int(rng.integers(len(LANGS)))])
        b.set_vprop(vid, k_len, int(rng.integers(1, 500)))
        b.add_edge(int(person_ids[creators[i]]), vid, te["created"], (st, T_HORIZON))
        if n_forums:
            b.add_edge(int(forum_ids[post_forum[i]]), vid, te["containerOf"], (st, T_HORIZON))

    # ----------------------------------------------------------------- comments
    n_comments = int(params.comments_per_person * N)
    c_creators = rng.integers(0, N, size=n_comments)
    c_parents = rng.integers(0, max(n_posts, 1), size=n_comments)
    for i in range(n_comments):
        parent = post_ids[c_parents[i]] if n_posts else person_ids[0]
        parent_start = int(b._v_lives[parent][0])
        lo = max(int(p_start[c_creators[i]]), parent_start)
        st = min(int(_snap(rng.integers(lo, T_HORIZON), align)), T_HORIZON - 1)
        vid = b.add_vertex(tp["comment"], (st, T_HORIZON))
        for t in np.unique(rng.choice(tag_pop, size=max(1, int(rng.poisson(params.tags_per_message))))):
            b.set_vprop(vid, k_tag, f"tag{t}")
        b.set_vprop(vid, k_len, int(rng.integers(1, 200)))
        b.add_edge(int(person_ids[c_creators[i]]), vid, te["created"], (st, T_HORIZON))
        if n_posts:
            b.add_edge(vid, parent, te["replyOf"], (st, T_HORIZON))

    # ------------------------------------------------------------------- likes
    n_likes = int(2.0 * N)
    l_p = rng.integers(0, N, size=n_likes)
    l_m = rng.integers(0, max(n_posts, 1), size=n_likes)
    for i in range(n_likes):
        if not n_posts:
            break
        post = post_ids[l_m[i]]
        lo = max(int(p_start[l_p[i]]), int(b._v_lives[post][0]))
        st = min(int(_snap(rng.integers(lo, T_HORIZON), align)), T_HORIZON - 1)
        b.add_edge(int(person_ids[l_p[i]]), post, te["likes"], (st, T_HORIZON))

    g = b.build()
    g.meta["params"] = dataclasses.asdict(params)
    g.meta["builder"] = b  # keep dictionaries for query rewriting
    return g


def graph_name(params: LdbcParams) -> str:
    tag = {"altmann": "A", "weibull": "DW", "facebook": "F", "zipf": "Z"}[params.degree_dist]
    sd = "D" if params.dynamic else "S"
    return f"{params.n_persons}:{tag}-{sd}"
