"""Graph construction, dictionary encoding and (de)serialisation.

The paper's memory optimisations (Sec. 4.4.3) — property-key bytes and string
interning — become *dictionary encoding* here: every key and every string
value is assigned an integer id at load time, and queries are rewritten
against the dictionaries (`GraphBuilder.encode_*`).  Vertices are permuted
into type-major order at build time (the tensor analogue of type-based
partitioning, Sec. 4.4.1).

Keys may be declared ``ordered=True``: their values must be non-negative ints
and are used as ids directly, preserving order so that min/max temporal
aggregation and range comparisons are meaningful.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.graph import NO_VALUE, PropColumn, TemporalGraph, make_prop_column


class GraphBuilder:
    def __init__(self):
        self.v_type_ids: Dict[str, int] = {}
        self.e_type_ids: Dict[str, int] = {}
        self.key_ids: Dict[str, int] = {}
        self.key_ordered: Dict[int, bool] = {}
        self.value_dicts: Dict[int, Dict[str, int]] = {}
        self._v_types: List[int] = []
        self._v_lives: List[Tuple[int, int]] = []
        self._edges: List[Tuple[int, int, int, int, int]] = []
        self._vprop_rows: Dict[int, List[Tuple[int, int, int, int]]] = {}
        self._eprop_rows: Dict[int, List[Tuple[int, int, int, int]]] = {}
        self.lifespan = (0, 1)

    # ----------------------------------------------------------- dictionaries
    def vertex_type(self, name: str) -> int:
        return self.v_type_ids.setdefault(name, len(self.v_type_ids))

    def edge_type(self, name: str) -> int:
        return self.e_type_ids.setdefault(name, len(self.e_type_ids))

    def key(self, name: str, ordered: bool = False) -> int:
        k = self.key_ids.setdefault(name, len(self.key_ids))
        self.key_ordered.setdefault(k, ordered)
        if not ordered:
            self.value_dicts.setdefault(k, {})
        return k

    def encode_value(self, key: int, value) -> int:
        if self.key_ordered[key]:
            v = int(value)
            assert v >= 0, "ordered keys need non-negative int values"
            return v
        d = self.value_dicts[key]
        s = str(value)
        return d.setdefault(s, len(d))

    def lookup_value(self, key: int, value) -> int:
        """Encode without inserting (query rewrite); -2 if unseen (matches nothing)."""
        if self.key_ordered[key]:
            return int(value)
        return self.value_dicts[key].get(str(value), -2)

    # ------------------------------------------------------------- structure
    def add_vertex(self, vtype: int, life: Tuple[int, int]) -> int:
        self._v_types.append(vtype)
        self._v_lives.append((int(life[0]), int(life[1])))
        return len(self._v_types) - 1

    def add_edge(self, src: int, dst: int, etype: int, life: Tuple[int, int]) -> int:
        self._edges.append((src, dst, etype, int(life[0]), int(life[1])))
        return len(self._edges) - 1

    def set_vprop(self, vid: int, key: int, value, life: Optional[Tuple[int, int]] = None):
        if life is None:
            life = self._v_lives[vid]
        self._vprop_rows.setdefault(key, []).append(
            (vid, self.encode_value(key, value), int(life[0]), int(life[1]))
        )

    def set_eprop(self, eid: int, key: int, value, life: Optional[Tuple[int, int]] = None):
        if life is None:
            life = self._edges[eid][3:5]
        self._eprop_rows.setdefault(key, []).append(
            (eid, self.encode_value(key, value), int(life[0]), int(life[1]))
        )

    # ----------------------------------------------------------------- build
    def build(self) -> TemporalGraph:
        V = len(self._v_types)
        v_type = np.asarray(self._v_types, np.int32)
        v_life = np.asarray(self._v_lives, np.int32).reshape(V, 2)
        # type-major permutation (stable keeps generator locality within type)
        perm = np.argsort(v_type, kind="stable").astype(np.int64)
        inv = np.empty_like(perm)
        inv[perm] = np.arange(V)
        v_type = v_type[perm]
        v_life = v_life[perm]

        if self._edges:
            earr = np.asarray(self._edges, np.int64)
            e_src = inv[earr[:, 0]].astype(np.int32)
            e_dst = inv[earr[:, 1]].astype(np.int32)
            e_type = earr[:, 2].astype(np.int32)
            e_life = earr[:, 3:5].astype(np.int32)
        else:
            e_src = e_dst = e_type = np.zeros(0, np.int32)
            e_life = np.zeros((0, 2), np.int32)

        vprops = {}
        for k, rows in self._vprop_rows.items():
            r = np.asarray(rows, np.int64)
            vprops[k] = make_prop_column(V, inv[r[:, 0]], r[:, 1], r[:, 2:4])
        eprops = {}
        for k, rows in self._eprop_rows.items():
            r = np.asarray(rows, np.int64)
            eprops[k] = make_prop_column(len(self._edges), r[:, 0], r[:, 1], r[:, 2:4])

        meta = dict(
            v_type_ids=dict(self.v_type_ids),
            e_type_ids=dict(self.e_type_ids),
            key_ids=dict(self.key_ids),
            key_ordered={str(k): v for k, v in self.key_ordered.items()},
            value_dicts={str(k): d for k, d in self.value_dicts.items()},
        )
        return TemporalGraph(
            v_type, v_life, e_src, e_dst, e_type, e_life, vprops, eprops,
            n_vertex_types=len(self.v_type_ids),
            n_edge_types=max(1, len(self.e_type_ids)),
            lifespan=self.lifespan,
            meta=meta,
        )


# ------------------------------------------------------------- serialisation
def save_graph(graph: TemporalGraph, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrs = dict(
        v_type=graph.v_type, v_life=graph.v_life,
        e_src=graph.e_src, e_dst=graph.e_dst, e_type=graph.e_type,
        e_life=graph.e_life,
    )
    for k, c in graph.vprops.items():
        arrs[f"vp{k}_vals"] = c.vals
        arrs[f"vp{k}_life"] = c.life
    for k, c in graph.eprops.items():
        arrs[f"ep{k}_vals"] = c.vals
        arrs[f"ep{k}_life"] = c.life
    np.savez_compressed(path, **arrs)
    meta = {k: v for k, v in graph.meta.items()
            if isinstance(v, (dict, list, str, int, float, bool, type(None)))}
    hdr = dict(
        n_vertex_types=graph.n_vertex_types,
        n_edge_types=graph.n_edge_types,
        lifespan=list(graph.lifespan),
        vprop_keys=sorted(graph.vprops),
        eprop_keys=sorted(graph.eprops),
        meta=meta,
    )
    with open(path + ".json", "w") as f:
        json.dump(hdr, f)


def load_graph(path: str) -> TemporalGraph:
    with open(path + ".json") as f:
        hdr = json.load(f)
    z = np.load(path if path.endswith(".npz") else path + ".npz")
    vprops = {
        k: PropColumn(z[f"vp{k}_vals"], z[f"vp{k}_life"]) for k in hdr["vprop_keys"]
    }
    eprops = {
        k: PropColumn(z[f"ep{k}_vals"], z[f"ep{k}_life"]) for k in hdr["eprop_keys"]
    }
    return TemporalGraph(
        z["v_type"], z["v_life"], z["e_src"], z["e_dst"], z["e_type"], z["e_life"],
        vprops, eprops, hdr["n_vertex_types"], hdr["n_edge_types"],
        tuple(hdr["lifespan"]), meta=hdr.get("meta"),
    )
