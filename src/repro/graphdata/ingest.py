"""Streaming ingestion: append-only temporal event log + canonical replay.

Every structure downstream of this module — sorted-CSR traversal layouts,
partition tables, serving-cache fingerprints — assumes a frozen graph.  This
module is the boundary that makes "frozen" a *per-epoch* notion instead of a
forever one (ROADMAP item 1; the snapshot/delta storage split of "Storing and
Querying Evolving Graphs in NoSQL Storage Models"):

  EventLog      append-only log of temporal events (vertex/edge add, property
                set, interval close) with external integer keys.  ``seal()``
                freezes the current suffix as one **epoch**; sealed prefixes
                are immutable forever.
  materialize   from-scratch canonical replay of the first k epochs into a
                TemporalGraph — the reference semantics.  The canonical
                orders are chosen so that (a) replay is insensitive to event
                order within an epoch and (b) every epoch's arrays are an
                *extension* of the previous epoch's (append-friendly).
  Materializer  the incremental path: applies one sealed epoch to the
                previous epoch's graph with a monotone gid remap, a
                searchsorted merge of new traversal entries into the
                arrival-sorted order (no O(E log E) re-lexsort), and
                copy-on-write property columns — **bit-identical** to
                ``materialize`` (pinned by tests/test_ingest.py).
  DeltaSpec     padded device arrays for the base-CSR + delta-segment
                execution path (``engine.batch_executable_delta``): when the
                window since the last compaction is pure edge-appends, the
                serving scheduler keeps dispatching the *base* graph's
                compiled executables and adds an unsorted delta-segment
                delivery per hop — cross-epoch executable-cache hits.

Canonical orders (the whole module hangs on these three):

  vertices   (vtype, epoch introduced, external key)  — type-major is
             preserved (``type_ranges`` stays a range check) and new
             vertices of a type append at the end of its block, so the gid
             remap between epochs is monotone;
  edges      (epoch introduced, src key, dst key, etype, external key) —
             edge ids are append-only across epochs, so eprop rows and
             traversal ``t_eid`` entries never move;
  prop rows  per entity (epoch, life start, life end, value) — a set, not a
             sequence: any within-epoch event permutation pivots to the same
             PropColumn.

Within one epoch the materializer groups events by kind before applying
them, so replay is order-insensitive *by construction*; the only
order-sensitive part is the log's optional incremental referential-integrity
validation (``validate=False`` to ingest unordered streams).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.graph import (NO_VALUE, PropColumn, TemporalGraph,
                          make_prop_column)

# ---------------------------------------------------------------- events
EV_ADD_VERTEX = 0    # key=vertex key,  data=(vtype, life0, life1)
EV_ADD_EDGE = 1      # key=edge key,    data=(src key, dst key, etype, l0, l1)
EV_SET_VPROP = 2     # key=vertex key,  data=(prop key, value, l0, l1)
EV_SET_EPROP = 3     # key=edge key,    data=(prop key, value, l0, l1)
EV_CLOSE_VERTEX = 4  # key=vertex key,  data=(t,)   → life1 = min(life1, t)
EV_CLOSE_EDGE = 5    # key=edge key,    data=(t,)

EVENT_KINDS = (EV_ADD_VERTEX, EV_ADD_EDGE, EV_SET_VPROP, EV_SET_EPROP,
               EV_CLOSE_VERTEX, EV_CLOSE_EDGE)


@dataclasses.dataclass(frozen=True, order=True)
class Event:
    """One temporal event.  ``order=True`` gives the canonical within-epoch
    sort (kind, key, data) used by the permutation-invariant fingerprints."""
    kind: int
    key: int
    data: Tuple[int, ...]


def add_vertex(key: int, vtype: int, life: Tuple[int, int]) -> Event:
    return Event(EV_ADD_VERTEX, int(key), (int(vtype), int(life[0]), int(life[1])))


def add_edge(key: int, src_key: int, dst_key: int, etype: int,
             life: Tuple[int, int]) -> Event:
    return Event(EV_ADD_EDGE, int(key),
                 (int(src_key), int(dst_key), int(etype),
                  int(life[0]), int(life[1])))


def set_vprop(key: int, pkey: int, value: int, life: Tuple[int, int]) -> Event:
    return Event(EV_SET_VPROP, int(key),
                 (int(pkey), int(value), int(life[0]), int(life[1])))


def set_eprop(key: int, pkey: int, value: int, life: Tuple[int, int]) -> Event:
    return Event(EV_SET_EPROP, int(key),
                 (int(pkey), int(value), int(life[0]), int(life[1])))


def close_vertex(key: int, t: int) -> Event:
    return Event(EV_CLOSE_VERTEX, int(key), (int(t),))


def close_edge(key: int, t: int) -> Event:
    return Event(EV_CLOSE_EDGE, int(key), (int(t),))


# ------------------------------------------------------------------- WAL
#: genesis value of the WAL's chained record fingerprint
WAL_GENESIS = "wal:genesis"


def _wal_payload(obj: dict) -> str:
    """Canonical serialization a record's chain fingerprint is computed
    over (sorted keys, no whitespace — byte-stable across processes)."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def _json_default(o):
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.bool_):
        return bool(o)
    raise TypeError(f"not JSON-serializable: {type(o).__name__}")


def _json_safe_meta(meta: dict) -> dict:
    """The journalable subset of a log's meta: entries that survive a JSON
    round trip (numpy scalars normalised).  Non-serializable attachments
    (e.g. the LDBC generator's ``builder`` object, kept for query
    rewriting) are dropped — nothing fingerprint- or execution-relevant
    lives there, and a recovered deployment regenerates them from
    ``meta["params"]``."""
    out = {}
    for k, v in meta.items():
        try:
            out[k] = json.loads(json.dumps(v, default=_json_default))
        except TypeError:
            continue
    return out


def _wal_chain(prev_fp: str, payload: str) -> str:
    return hashlib.sha1((prev_fp + payload).encode()).hexdigest()[:16]


def events_fingerprint(prev_fp: str, events: Sequence[Event]) -> str:
    """Chained, permutation-invariant fingerprint: hash of the previous
    fingerprint plus the epoch's events in canonical sorted order.  Two logs
    share an epoch fingerprint iff they share base content and (as sets) the
    same event history — O(delta) per epoch, never O(graph)."""
    h = hashlib.sha1(prev_fp.encode())
    for ev in sorted(events):
        h.update(repr((ev.kind, ev.key, ev.data)).encode())
    return h.hexdigest()[:16]


class EventLog:
    """Append-only temporal event log with sealed-epoch boundaries.

    The log carries the fixed schema every materialization shares (type
    counts, the global ``lifespan`` that bucket edges derive from, ``meta``
    passed through to graphs).  ``append``/``extend`` add events to the
    *open* suffix; ``seal()`` freezes that suffix as the next epoch.  Sealed
    events are immutable — epoch-pinned queries (serving/epochs.py) rely on
    that for snapshot isolation.

    With ``validate=True`` (default) appends check referential integrity
    incrementally: known endpoint keys, no duplicate adds, edge lifespans
    within both endpoints' current lifespans, and vertex closes never
    truncating below a live incident edge (the engine's graph-level
    invariant).  Validation is the only order-sensitive part of ingestion;
    disable it to ingest streams whose within-epoch order is arbitrary.

    Durability (``attach_wal`` / ``from_wal``): an append-only JSONL
    write-ahead log mirrors every event, seal, and manager note.  Records
    carry a chained fingerprint (``sha1(prev_fp + canonical payload)``),
    seal records are flushed + fsync'd (the atomic commit point — an epoch
    either has its seal on disk or it does not), and recovery truncates the
    first torn or chain-breaking record and everything after it.  Events
    after the last intact seal are replayed as the open suffix, exactly the
    pre-crash unsealed state.
    """

    def __init__(self, n_vertex_types: int, n_edge_types: int,
                 lifespan: Tuple[int, int], meta: Optional[dict] = None,
                 validate: bool = True):
        self.n_vertex_types = int(n_vertex_types)
        self.n_edge_types = int(n_edge_types)
        self.lifespan = (int(lifespan[0]), int(lifespan[1]))
        self.meta = dict(meta or {})
        self.validate = validate
        self._events: List[Event] = []
        self._seals: List[int] = []          # event-count boundary per epoch
        # validation state (only maintained when validate=True)
        self._v: Dict[int, list] = {}   # key -> [vtype, l0, l1, max_inc_end]
        self._e: Dict[int, list] = {}   # key -> [skey, dkey, l0, l1]
        # write-ahead log (attach_wal / from_wal); clones never share it
        self._wal = None
        self._wal_fp = WAL_GENESIS
        self._wal_path: Optional[str] = None
        self._wal_plan = None           # FaultPlan consulted at "wal" point

    # ------------------------------------------------------------- append
    def _check(self, ev: Event) -> None:
        k = ev.kind
        if k == EV_ADD_VERTEX:
            if ev.key in self._v:
                raise ValueError(f"duplicate vertex key {ev.key}")
            vt, l0, l1 = ev.data
            if not (0 <= vt < self.n_vertex_types):
                raise ValueError(f"vertex type {vt} out of range")
            if l0 >= l1:
                raise ValueError(f"empty vertex lifespan ({l0}, {l1})")
            self._v[ev.key] = [vt, l0, l1, l0]
        elif k == EV_ADD_EDGE:
            if ev.key in self._e:
                raise ValueError(f"duplicate edge key {ev.key}")
            sk, dk, et, l0, l1 = ev.data
            if not (0 <= et < self.n_edge_types):
                raise ValueError(f"edge type {et} out of range")
            if l0 >= l1:
                raise ValueError(f"empty edge lifespan ({l0}, {l1})")
            for ep in (sk, dk):
                v = self._v.get(ep)
                if v is None:
                    raise ValueError(f"edge {ev.key} references unknown vertex {ep}")
                if l0 < v[1] or l1 > v[2]:
                    raise ValueError(
                        f"edge {ev.key} lifespan ({l0}, {l1}) outside vertex "
                        f"{ep} lifespan ({v[1]}, {v[2]})")
                v[3] = max(v[3], l1)
            self._e[ev.key] = [sk, dk, l0, l1]
        elif k in (EV_SET_VPROP, EV_SET_EPROP):
            tab = self._v if k == EV_SET_VPROP else self._e
            if ev.key not in tab:
                raise ValueError(f"property on unknown entity key {ev.key}")
            if ev.data[2] >= ev.data[3]:
                raise ValueError(f"empty property lifespan {ev.data[2:]}")
        elif k == EV_CLOSE_VERTEX:
            v = self._v.get(ev.key)
            if v is None:
                raise ValueError(f"close of unknown vertex {ev.key}")
            (t,) = ev.data
            if t <= v[1]:
                raise ValueError(f"vertex close at {t} not after start {v[1]}")
            if t < v[3]:
                raise ValueError(
                    f"vertex close at {t} truncates a live incident edge "
                    f"(ends {v[3]})")
            v[2] = min(v[2], t)
        elif k == EV_CLOSE_EDGE:
            e = self._e.get(ev.key)
            if e is None:
                raise ValueError(f"close of unknown edge {ev.key}")
            (t,) = ev.data
            if t <= e[2]:
                raise ValueError(f"edge close at {t} not after start {e[2]}")
            e[3] = min(e[3], t)
        else:
            raise ValueError(f"unknown event kind {k}")

    def append(self, ev: Event) -> None:
        if self.validate:
            self._check(ev)
        self._events.append(ev)
        if self._wal is not None:
            self._wal_write(dict(k="ev", kind=int(ev.kind), key=int(ev.key),
                                 data=[int(x) for x in ev.data]))

    def extend(self, events: Iterable[Event]) -> int:
        n = 0
        for ev in events:
            self.append(ev)
            n += 1
        return n

    # -------------------------------------------------------------- epochs
    def seal(self) -> List[Event]:
        """Freeze the open suffix as the next epoch; returns its events
        (possibly empty — an empty epoch is a valid no-op snapshot)."""
        self._seals.append(len(self._events))
        i = len(self._seals) - 1
        events = self.epoch_events(i)
        if self._wal is not None:
            # the atomic commit point: flushed + fsync'd, so a crash either
            # leaves the epoch sealed on disk or recovery reopens its events
            self._wal_write(dict(k="seal", epoch=i, n=len(events)),
                            sync=True)
        return events

    @property
    def n_epochs(self) -> int:
        return len(self._seals)

    @property
    def n_open(self) -> int:
        """Events appended but not yet sealed into an epoch."""
        start = self._seals[-1] if self._seals else 0
        return len(self._events) - start

    def epoch_events(self, i: int) -> List[Event]:
        lo = self._seals[i - 1] if i > 0 else 0
        return self._events[lo:self._seals[i]]

    def __len__(self) -> int:
        return len(self._events)

    def clone(self) -> "EventLog":
        """Independent copy (events, seals, validation state) — replay the
        same stream through several managers without sharing seal state."""
        out = EventLog(self.n_vertex_types, self.n_edge_types, self.lifespan,
                       meta=self.meta, validate=self.validate)
        out._events = list(self._events)
        out._seals = list(self._seals)
        out._v = {k: list(v) for k, v in self._v.items()}
        out._e = {k: list(v) for k, v in self._e.items()}
        return out

    # ---------------------------------------------------------------- WAL
    def _wal_write(self, obj: dict, consult: bool = True,
                   sync: bool = False) -> None:
        """Append one chained record; the "wal" fault point tears the write
        (a prefix reaches disk, then the simulated crash) when it fires."""
        if self._wal is None:
            return
        payload = _wal_payload(obj)
        fp = _wal_chain(self._wal_fp, payload)
        line = _wal_payload({**obj, "fp": fp}) + "\n"
        if (consult and self._wal_plan is not None
                and self._wal_plan.should_fail("wal")):
            self._wal.write(line[: max(1, len(line) // 2)])
            self._wal.flush()
            os.fsync(self._wal.fileno())
            self._wal.close()
            self._wal = None
            from ..serving.faults import TornWriteError
            raise TornWriteError(f"torn WAL write at {self._wal_path}")
        self._wal.write(line)
        self._wal_fp = fp
        if sync:
            self._wal.flush()
            os.fsync(self._wal.fileno())

    def attach_wal(self, path, fault_plan=None) -> None:
        """Start journaling to ``path`` (truncates any existing file): one
        header record, the log's existing history (events interleaved with
        their seal records), then every future ``append``/``seal``/
        ``wal_note`` live.  ``fault_plan``'s "wal" point is consulted for
        live writes only — never while dumping history."""
        self._wal_path = str(path)
        self._wal = open(path, "w", encoding="utf-8")
        self._wal_fp = WAL_GENESIS
        self._wal_plan = None
        self._wal_write(dict(k="hdr", nvt=self.n_vertex_types,
                             net=self.n_edge_types,
                             life=[int(x) for x in self.lifespan],
                             meta=_json_safe_meta(self.meta),
                             validate=bool(self.validate)))
        lo = 0
        for s, hi in enumerate(self._seals):
            for ev in self._events[lo:hi]:
                self._wal_write(dict(k="ev", kind=int(ev.kind),
                                     key=int(ev.key),
                                     data=[int(x) for x in ev.data]))
            self._wal_write(dict(k="seal", epoch=s, n=hi - lo))
            lo = hi
        for ev in self._events[lo:]:
            self._wal_write(dict(k="ev", kind=int(ev.kind), key=int(ev.key),
                                 data=[int(x) for x in ev.data]))
        self._wal.flush()
        os.fsync(self._wal.fileno())
        self._wal_plan = fault_plan

    def wal_note(self, epoch: int, **fields) -> None:
        """Durable side-channel record (fsync'd) — the EpochManager journals
        its per-seal compaction decision here so recovery replays even
        forced decisions exactly."""
        self._wal_write(dict(k="note", epoch=int(epoch), **fields),
                        sync=True)

    def close_wal(self) -> None:
        if self._wal is not None:
            self._wal.flush()
            os.fsync(self._wal.fileno())
            self._wal.close()
            self._wal = None

    @classmethod
    def from_wal(cls, path, fault_plan=None) -> Tuple["EventLog", List[dict]]:
        """Rebuild a log from its WAL — the crash-recovery path.

        Scans records validating the chained fingerprint; the first torn
        line (no newline / invalid JSON) or chain break marks the torn
        tail, which is truncated from the file.  Sealed epochs are restored
        as sealed; intact events after the last seal become the open
        suffix, exactly the pre-crash unsealed state.  Returns
        ``(log, notes)`` with the WAL re-attached in append mode (the
        surviving chain continues), ``notes`` the intact ``wal_note``
        records in order."""
        with open(path, "rb") as f:
            data = f.read()
        fp = WAL_GENESIS
        records: List[dict] = []
        pos = good = 0
        while pos < len(data):
            nl = data.find(b"\n", pos)
            if nl < 0:
                break                      # torn: line never finished
            try:
                obj = json.loads(data[pos:nl].decode("utf-8"))
                rec_fp = obj.pop("fp")
            except Exception:
                break                      # torn: unparseable record
            if rec_fp != _wal_chain(fp, _wal_payload(obj)):
                break                      # chain break: corrupt tail
            records.append(obj)
            fp = rec_fp
            pos = good = nl + 1
        if good < len(data):
            with open(path, "r+b") as f:
                f.truncate(good)
        if not records or records[0].get("k") != "hdr":
            raise ValueError(f"WAL {path} has no intact header record")
        hdr = records[0]
        log = cls(hdr["nvt"], hdr["net"],
                  (int(hdr["life"][0]), int(hdr["life"][1])),
                  meta=hdr["meta"], validate=bool(hdr["validate"]))
        notes: List[dict] = []
        for obj in records[1:]:
            kind = obj["k"]
            if kind == "ev":
                log.append(Event(int(obj["kind"]), int(obj["key"]),
                                 tuple(int(x) for x in obj["data"])))
            elif kind == "seal":
                log._seals.append(len(log._events))
            elif kind == "note":
                notes.append(obj)
        log._wal_path = str(path)
        log._wal = open(path, "a", encoding="utf-8")
        log._wal_fp = fp
        log._wal_plan = fault_plan
        return log, notes


# ------------------------------------------------------- canonical tables
def _canonical_tables(log: EventLog, upto: int) -> dict:
    """Entity/prop tables for the first ``upto`` epochs in canonical order.

    Closes are applied after all adds (min over close times), so the result
    depends only on the *set* of events per epoch, never their order."""
    verts: Dict[int, list] = {}   # key -> [vtype, l0, l1, epoch]
    edges: Dict[int, list] = {}   # key -> [skey, dkey, etype, l0, l1, epoch]
    vrows: Dict[int, list] = {}   # pkey -> [(epoch, key, l0, l1, val)]
    erows: Dict[int, list] = {}
    closes_v: List[Tuple[int, int]] = []
    closes_e: List[Tuple[int, int]] = []
    for ep in range(upto):
        for ev in log.epoch_events(ep):
            k = ev.kind
            if k == EV_ADD_VERTEX:
                if ev.key in verts:
                    raise ValueError(f"duplicate vertex key {ev.key}")
                vt, l0, l1 = ev.data
                verts[ev.key] = [vt, l0, l1, ep]
            elif k == EV_ADD_EDGE:
                if ev.key in edges:
                    raise ValueError(f"duplicate edge key {ev.key}")
                sk, dk, et, l0, l1 = ev.data
                edges[ev.key] = [sk, dk, et, l0, l1, ep]
            elif k == EV_SET_VPROP:
                pk, val, l0, l1 = ev.data
                vrows.setdefault(pk, []).append((ep, ev.key, l0, l1, val))
            elif k == EV_SET_EPROP:
                pk, val, l0, l1 = ev.data
                erows.setdefault(pk, []).append((ep, ev.key, l0, l1, val))
            elif k == EV_CLOSE_VERTEX:
                closes_v.append((ev.key, ev.data[0]))
            elif k == EV_CLOSE_EDGE:
                closes_e.append((ev.key, ev.data[0]))
    for key, t in closes_v:
        verts[key][2] = min(verts[key][2], t)
    for key, t in closes_e:
        edges[key][4] = min(edges[key][4], t)

    v_key = np.array(list(verts.keys()), np.int64).reshape(-1)
    v_cols = np.array([verts[k] for k in v_key], np.int64).reshape(-1, 4)
    vo = np.lexsort((v_key, v_cols[:, 3], v_cols[:, 0])) if len(v_key) else \
        np.zeros(0, np.int64)
    e_key = np.array(list(edges.keys()), np.int64).reshape(-1)
    e_cols = np.array([edges[k] for k in e_key], np.int64).reshape(-1, 6)
    eo = np.lexsort((e_key, e_cols[:, 2], e_cols[:, 1], e_cols[:, 0],
                     e_cols[:, 5])) if len(e_key) else np.zeros(0, np.int64)
    return dict(
        v_key=v_key[vo], v_type=v_cols[vo, 0].astype(np.int32),
        v_life=v_cols[vo, 1:3].astype(np.int32),
        v_epoch=v_cols[vo, 3].astype(np.int32),
        e_key=e_key[eo], e_srck=e_cols[eo, 0], e_dstk=e_cols[eo, 1],
        e_type=e_cols[eo, 2].astype(np.int32),
        e_life=e_cols[eo, 3:5].astype(np.int32),
        e_epoch=e_cols[eo, 5].astype(np.int32),
        vrows=vrows, erows=erows,
    )


def _pivot_rows(rows: List[tuple], key_to_id: Dict[int, int],
                n_entities: int) -> PropColumn:
    """Canonical PropColumn pivot: rows globally sorted by (epoch, l0, l1,
    value) so each entity's slot order is canonical (``make_prop_column``
    preserves the given within-entity row order)."""
    a = np.array(rows, np.int64).reshape(-1, 5)
    order = np.lexsort((a[:, 4], a[:, 3], a[:, 2], a[:, 0]))
    a = a[order]
    ids = np.array([key_to_id[int(k)] for k in a[:, 1]], np.int64)
    return make_prop_column(n_entities, ids, a[:, 4].astype(np.int32),
                            a[:, 2:4].astype(np.int32))


def materialize(log: EventLog, upto: Optional[int] = None) -> TemporalGraph:
    """From-scratch canonical replay of the first ``upto`` sealed epochs.

    This is the *reference* build: plain canonical sorts, traversal arrays
    via the graph's own lexsort.  ``Materializer`` must produce bit-identical
    arrays for every epoch (test-pinned) — a pinned epoch served from the
    incremental path answers exactly like this rebuild."""
    upto = log.n_epochs if upto is None else int(upto)
    t = _canonical_tables(log, upto)
    gid = {int(k): i for i, k in enumerate(t["v_key"])}
    eid = {int(k): i for i, k in enumerate(t["e_key"])}
    e_src = np.array([gid[int(k)] for k in t["e_srck"]], np.int32)
    e_dst = np.array([gid[int(k)] for k in t["e_dstk"]], np.int32)
    vprops = {pk: _pivot_rows(rows, gid, len(t["v_key"]))
              for pk, rows in sorted(t["vrows"].items())}
    eprops = {pk: _pivot_rows(rows, eid, len(t["e_key"]))
              for pk, rows in sorted(t["erows"].items())}
    return TemporalGraph(
        t["v_type"], t["v_life"], e_src, e_dst, t["e_type"], t["e_life"],
        vprops, eprops, log.n_vertex_types, log.n_edge_types, log.lifespan,
        meta=dict(log.meta))


# ---------------------------------------------------- incremental replay
@dataclasses.dataclass
class DeltaSpec:
    """Padded device block for the base-CSR + delta-segment execution path.

    Holds the traversal entries (both directions) of every edge appended
    since the last compaction, padded to a pow-2 ``capacity`` so the jitted
    delta executable retraces at most log2 times as the delta grows.  Padded
    slots carry an empty lifespan and ``valid=False`` — doubly masked out of
    every predicate.  ``eprop_slots`` mirrors the base graph's edge-property
    schema with all-missing columns (delta-pure edges carry no properties by
    construction), so property clauses evaluate identically to the merged
    graph."""
    n_edges: int
    capacity: int
    arrays: Dict[str, np.ndarray]
    eprop_slots: Dict[int, int]

    def device(self) -> dict:
        """jnp views shaped like an engine ``gdev`` (cached)."""
        dev = getattr(self, "_device", None)
        if dev is None:
            import jax.numpy as jnp
            n = 2 * self.capacity
            dev = {k: jnp.asarray(v) for k, v in self.arrays.items()}
            dev["eprops_t"] = {
                k: (jnp.full((n, s), NO_VALUE, jnp.int32),
                    jnp.zeros((n, s, 2), jnp.int32))
                for k, s in self.eprop_slots.items()
            }
            self._device = dev
        return dev


def _pow2(n: int, floor: int = 256) -> int:
    c = floor
    while c < n:
        c *= 2
    return c


class Materializer:
    """Incremental epoch-by-epoch materialization of an EventLog.

    ``apply_next()`` folds the next sealed epoch into the previous epoch's
    graph without re-sorting the world:

      * new vertices insert at the end of their type block — the gid remap
        is monotone, so every sorted structure stays sorted under it;
      * new edges append (edge ids never move) and their 2·d traversal
        entries merge into the arrival-sorted order with two searchsorted
        calls (O(E + d log d), vs the O(E log E) from-scratch lexsort);
      * untouched property columns are reused (or row-extended) by
        reference; touched keys re-pivot from the accumulated canonical
        rows.

    Each epoch yields a NEW immutable TemporalGraph (previous epochs' arrays
    are never mutated — snapshot isolation is structural).  ``compact()``
    re-bases the delta window: the current graph becomes the base every
    later ``DeltaSpec`` is measured against.
    """

    def __init__(self, log: EventLog):
        self.log = log
        self.applied = 0
        self.graph: Optional[TemporalGraph] = None
        self._v_key = np.zeros(0, np.int64)
        self._v_epoch = np.zeros(0, np.int32)
        self._e_key = np.zeros(0, np.int64)
        self._key2gid: Dict[int, int] = {}
        self._key2eid: Dict[int, int] = {}
        self._vrows: Dict[int, list] = {}
        self._erows: Dict[int, list] = {}
        # delta window since the last compaction
        self.base_graph: Optional[TemporalGraph] = None
        self.base_n_edges = 0
        self._delta_pure = True
        self._remap_from_base = np.zeros(0, np.int64)

    # ------------------------------------------------------------ helpers
    def _bootstrap(self) -> TemporalGraph:
        g = materialize(self.log, 1)
        t = _canonical_tables(self.log, 1)
        self._v_key, self._v_epoch = t["v_key"], t["v_epoch"]
        self._e_key = t["e_key"]
        self._key2gid = {int(k): i for i, k in enumerate(self._v_key)}
        self._key2eid = {int(k): i for i, k in enumerate(self._e_key)}
        self._vrows = {pk: list(rows) for pk, rows in t["vrows"].items()}
        self._erows = {pk: list(rows) for pk, rows in t["erows"].items()}
        self.graph = g
        self.applied = 1
        self.compact()
        return g

    def vertex_type_of_key(self, key: int) -> int:
        return int(self.graph.v_type[self._key2gid[key]])

    def edge_endpoint_types(self, key: int) -> Tuple[int, int]:
        e = self._key2eid[key]
        return (int(self.graph.v_type[self.graph.e_src[e]]),
                int(self.graph.v_type[self.graph.e_dst[e]]))

    # ----------------------------------------------------------- epochs
    def apply_next(self) -> TemporalGraph:
        """Apply the next sealed epoch; returns that epoch's graph."""
        if self.applied >= self.log.n_epochs:
            raise ValueError("no sealed epoch to apply — call log.seal()")
        if self.graph is None:
            return self._bootstrap()
        p = self.applied
        evs = self.log.epoch_events(p)
        g = self.graph
        adds_v = [e for e in evs if e.kind == EV_ADD_VERTEX]
        adds_e = [e for e in evs if e.kind == EV_ADD_EDGE]
        sets_v = [e for e in evs if e.kind == EV_SET_VPROP]
        sets_e = [e for e in evs if e.kind == EV_SET_EPROP]
        cls_v = [e for e in evs if e.kind == EV_CLOSE_VERTEX]
        cls_e = [e for e in evs if e.kind == EV_CLOSE_EDGE]
        V0, E0 = g.n_vertices, g.n_edges

        # ---- vertices: monotone insert at type-block ends
        remap = None
        v_type, v_life = g.v_type, g.v_life
        v_key, v_epoch = self._v_key, self._v_epoch
        if adds_v:
            nk = np.array([e.key for e in adds_v], np.int64)
            nt = np.array([e.data[0] for e in adds_v], np.int32)
            nl = np.array([e.data[1:3] for e in adds_v], np.int32)
            o = np.lexsort((nk, nt))
            nk, nt, nl = nk[o], nt[o], nl[o]
            for k in nk:
                if int(k) in self._key2gid:
                    raise ValueError(f"duplicate vertex key {int(k)}")
            per_type = np.bincount(nt, minlength=g.n_vertex_types)
            before = np.concatenate(([0], np.cumsum(per_type)))
            remap = np.arange(V0, dtype=np.int64) + before[g.v_type]
            rank = np.arange(len(nt)) - before[nt]
            new_gids = (g.type_ranges[nt, 1].astype(np.int64)
                        + before[nt] + rank)
            V = V0 + len(nk)
            v_type = np.empty(V, np.int32)
            v_type[remap], v_type[new_gids] = g.v_type, nt
            v_life = np.empty((V, 2), np.int32)
            v_life[remap], v_life[new_gids] = g.v_life, nl
            v_key = np.empty(V, np.int64)
            v_key[remap], v_key[new_gids] = self._v_key, nk
            v_epoch = np.empty(V, np.int32)
            v_epoch[remap], v_epoch[new_gids] = self._v_epoch, p
            self._key2gid = {int(k): i for i, k in enumerate(v_key)}
        V = v_type.shape[0]
        if cls_v:
            v_life = v_life.copy() if v_life is g.v_life else v_life
            for e in cls_v:
                gi = self._key2gid[e.key]
                v_life[gi, 1] = min(int(v_life[gi, 1]), e.data[0])

        # ---- edges: append in canonical order, remap endpoints
        if remap is not None:
            e_src = remap[g.e_src].astype(np.int32)
            e_dst = remap[g.e_dst].astype(np.int32)
        else:
            e_src, e_dst = g.e_src, g.e_dst
        e_type, e_life, e_key = g.e_type, g.e_life, self._e_key
        d_src = d_dst = None
        if adds_e:
            ek = np.array([e.key for e in adds_e], np.int64)
            cols = np.array([e.data for e in adds_e], np.int64)
            o = np.lexsort((ek, cols[:, 2], cols[:, 1], cols[:, 0]))
            ek, cols = ek[o], cols[o]
            for k in ek:
                if int(k) in self._key2eid:
                    raise ValueError(f"duplicate edge key {int(k)}")
            d_src = np.array([self._key2gid[int(k)] for k in cols[:, 0]],
                             np.int32)
            d_dst = np.array([self._key2gid[int(k)] for k in cols[:, 1]],
                             np.int32)
            e_src = np.concatenate([e_src, d_src])
            e_dst = np.concatenate([e_dst, d_dst])
            e_type = np.concatenate([e_type, cols[:, 2].astype(np.int32)])
            e_life = np.concatenate([e_life, cols[:, 3:5].astype(np.int32)])
            e_key = np.concatenate([e_key, ek])
            for i, k in enumerate(ek):
                self._key2eid[int(k)] = E0 + i
        E = e_src.shape[0]
        if cls_e:
            e_life = e_life.copy() if e_life is g.e_life else e_life
            for e in cls_e:
                ei = self._key2eid[e.key]
                e_life[ei, 1] = min(int(e_life[ei, 1]), e.data[0])

        # ---- properties: copy-on-write columns
        touched_v = {e.data[0] for e in sets_v}
        touched_e = {e.data[0] for e in sets_e}
        for e in sets_v:
            pk, val, l0, l1 = e.data
            self._vrows.setdefault(pk, []).append((p, e.key, l0, l1, val))
        for e in sets_e:
            pk, val, l0, l1 = e.data
            self._erows.setdefault(pk, []).append((p, e.key, l0, l1, val))
        vprops: Dict[int, PropColumn] = {}
        for pk in sorted(set(g.vprops) | touched_v):
            if pk in touched_v:
                vprops[pk] = _pivot_rows(self._vrows[pk], self._key2gid, V)
            elif remap is not None:
                col = g.vprops[pk]
                vals = np.full((V, col.n_slots), NO_VALUE, np.int32)
                life = np.zeros((V, col.n_slots, 2), np.int32)
                vals[remap], life[remap] = col.vals, col.life
                vprops[pk] = PropColumn(vals, life)
            else:
                vprops[pk] = g.vprops[pk]
        eprops: Dict[int, PropColumn] = {}
        for pk in sorted(set(g.eprops) | touched_e):
            if pk in touched_e:
                eprops[pk] = _pivot_rows(self._erows[pk], self._key2eid, E)
            elif adds_e:
                col = g.eprops[pk]
                d = E - E0
                vals = np.concatenate(
                    [col.vals, np.full((d, col.n_slots), NO_VALUE, np.int32)])
                life = np.concatenate(
                    [col.life, np.zeros((d, col.n_slots, 2), np.int32)])
                eprops[pk] = PropColumn(vals, life)
            else:
                eprops[pk] = g.eprops[pk]

        # ---- traversal: monotone remap + searchsorted merge of new entries
        tr = g.traversal
        tb_eid = tr["t_eid"].astype(np.int64)
        tb_fwd = tr["t_isfwd"].astype(np.int64)
        if remap is not None:
            tb_src = remap[tr["t_src"]]
            tb_dst = remap[tr["t_dst"]]
        else:
            tb_src = tr["t_src"].astype(np.int64)
            tb_dst = tr["t_dst"].astype(np.int64)
        if adds_e:
            d = E - E0
            dd_eid = np.concatenate([np.arange(E0, E), np.arange(E0, E)])
            dd_fwd = np.concatenate([np.ones(d, np.int64),
                                     np.zeros(d, np.int64)])
            dd_src = np.concatenate([d_src, d_dst]).astype(np.int64)
            dd_dst = np.concatenate([d_dst, d_src]).astype(np.int64)
            od = np.lexsort((dd_eid, 1 - dd_fwd, dd_src, dd_dst))
            dd_eid, dd_fwd = dd_eid[od], dd_fwd[od]
            dd_src, dd_dst = dd_src[od], dd_dst[od]

            def enc(dst, src, fwd):
                return (dst * (V + 1) + src) * 2 + (1 - fwd)

            eb, ed = enc(tb_dst, tb_src, tb_fwd), enc(dd_dst, dd_src, dd_fwd)
            # merged positions: equal keys put base entries first (base edge
            # ids < appended ids, matching the from-scratch stable lexsort)
            pos_b = np.arange(len(eb)) + np.searchsorted(ed, eb, side="left")
            pos_d = np.arange(len(ed)) + np.searchsorted(eb, ed, side="right")
            m_eid = np.empty(len(eb) + len(ed), np.int64)
            m_fwd = np.empty_like(m_eid)
            m_eid[pos_b], m_eid[pos_d] = tb_eid, dd_eid
            m_fwd[pos_b], m_fwd[pos_d] = tb_fwd, dd_fwd
        else:
            m_eid, m_fwd = tb_eid, tb_fwd
        t_src = np.where(m_fwd == 1, e_src[m_eid], e_dst[m_eid])
        t_dst = np.where(m_fwd == 1, e_dst[m_eid], e_src[m_eid])
        arr_ptr = np.zeros(V + 1, np.int64)
        np.cumsum(np.bincount(t_dst, minlength=V), out=arr_ptr[1:])
        trav = dict(
            t_src=t_src.astype(np.int32), t_dst=t_dst.astype(np.int32),
            t_life=e_life[m_eid], t_type=e_type[m_eid],
            t_isfwd=m_fwd.astype(np.int32), t_eid=m_eid.astype(np.int32),
            arr_ptr=arr_ptr.astype(np.int32),
        )

        ng = TemporalGraph(v_type, v_life, e_src, e_dst, e_type, e_life,
                           vprops, eprops, g.n_vertex_types, g.n_edge_types,
                           self.log.lifespan, meta=dict(g.meta))
        ng.__dict__["traversal"] = trav   # bypass the cached_property lexsort

        # ---- delta-window bookkeeping
        if remap is not None:
            self._remap_from_base = remap[self._remap_from_base]
        if adds_v or sets_v or sets_e or cls_v:
            self._delta_pure = False
        for e in cls_e:
            if self._key2eid[e.key] < self.base_n_edges:
                self._delta_pure = False
        self._v_key, self._v_epoch, self._e_key = v_key, v_epoch, e_key
        self.graph = ng
        self.applied += 1
        return ng

    # ------------------------------------------------------------- delta
    def compact(self) -> None:
        """Re-base the delta window: the current graph becomes the base the
        next DeltaSpec (and the serving caches' base fingerprint) refer to."""
        self.base_graph = self.graph
        self.base_n_edges = self.graph.n_edges
        self._delta_pure = True
        self._remap_from_base = np.arange(self.graph.n_vertices,
                                          dtype=np.int64)

    @property
    def delta_pure(self) -> bool:
        """True while every event since the last compaction is an edge
        append or a close on an appended edge — the delta-executable
        eligibility condition."""
        return self._delta_pure

    def delta_spec(self) -> Optional[DeltaSpec]:
        """Padded delta-segment block since the base, or None when the
        window is impure (or empty): impure windows fall back to the merged
        epoch graph."""
        g, b0 = self.graph, self.base_n_edges
        if not self._delta_pure or g is None:
            return None
        nd = g.n_edges - b0
        if nd == 0:
            return None
        cap = _pow2(nd)
        n = 2 * cap

        def pad(a, fill=0):
            out = np.full((n,) + a.shape[1:], fill, a.dtype)
            out[:2 * nd] = np.concatenate([a, a]) if a.ndim > 0 else a
            return out

        src, dst = g.e_src[b0:], g.e_dst[b0:]
        arrays = dict(
            t_src=np.full(n, 0, np.int32), t_dst=np.full(n, 0, np.int32),
            t_life=np.zeros((n, 2), np.int32),
            t_type=pad(g.e_type[b0:]),
            t_isfwd=np.zeros(n, np.int32),
            valid=np.zeros(n, bool),
        )
        arrays["t_src"][:2 * nd] = np.concatenate([src, dst])
        arrays["t_dst"][:2 * nd] = np.concatenate([dst, src])
        arrays["t_life"][:2 * nd] = np.concatenate([g.e_life[b0:]] * 2)
        arrays["t_isfwd"][:nd] = 1
        arrays["valid"][:2 * nd] = True
        return DeltaSpec(nd, cap, arrays,
                         {k: c.n_slots for k, c in g.eprops.items()})

    def partition_hint(self) -> Optional[Callable]:
        """Partition carry-over for the current epoch graph: a callable
        ``(n_workers, parts_per_type) -> Partitioning | None`` extending the
        base graph's cached partitioning over the delta (partitioner
        ``extend_partitioning``) instead of re-running BFS growth.  Any
        assignment is bit-identical on the partitioned executor; the hint
        only saves repartitioning time."""
        base, g = self.base_graph, self.graph
        if base is None or g is None or g is base:
            return None
        remap = self._remap_from_base.copy()

        def hint(n_workers: int, parts_per_type: int):
            from .partitioner import extend_partitioning
            cache = getattr(base, "_partition_cache", None) or {}
            hit = cache.get((n_workers, parts_per_type))
            if hit is None:
                return None
            return extend_partitioning(hit[0], g, remap)

        return hint


# --------------------------------------------------------- stream helpers
def log_from_graph(graph: TemporalGraph, holdout_edges: int = 0,
                   seed: int = 0) -> Tuple[EventLog, List[Event]]:
    """Decompose a built TemporalGraph into an EventLog whose epoch 0
    rebuilds it minus ``holdout_edges`` random edges; the held-out edges are
    returned as pure ADD_EDGE events (properties dropped, which keeps later
    epochs delta-executable) for the caller to ingest in later epochs.

    External keys are the source graph's vertex/edge ids, so epoch-0
    materialization reproduces the vertex order exactly (edges re-sort into
    canonical key order; engine results are unaffected by edge order)."""
    rng = np.random.default_rng(seed)
    E = graph.n_edges
    held = np.zeros(E, bool)
    if holdout_edges:
        held[rng.choice(E, size=min(holdout_edges, E), replace=False)] = True
    log = EventLog(graph.n_vertex_types, graph.n_edge_types, graph.lifespan,
                   meta=dict(graph.meta))
    for v in range(graph.n_vertices):
        log.append(add_vertex(v, int(graph.v_type[v]),
                              tuple(graph.v_life[v])))
    for pk, col in sorted(graph.vprops.items()):
        ent, slot = np.nonzero(col.vals != NO_VALUE)
        for v, s in zip(ent, slot):
            log.append(set_vprop(int(v), pk, int(col.vals[v, s]),
                                 tuple(col.life[v, s])))
    for e in range(E):
        if held[e]:
            continue
        log.append(add_edge(e, int(graph.e_src[e]), int(graph.e_dst[e]),
                            int(graph.e_type[e]), tuple(graph.e_life[e])))
    for pk, col in sorted(graph.eprops.items()):
        ent, slot = np.nonzero(col.vals != NO_VALUE)
        for e, s in zip(ent, slot):
            if not held[e]:
                log.append(set_eprop(int(e), pk, int(col.vals[e, s]),
                                     tuple(col.life[e, s])))
    log.seal()
    held_events = [add_edge(e, int(graph.e_src[e]), int(graph.e_dst[e]),
                            int(graph.e_type[e]), tuple(graph.e_life[e]))
                   for e in np.nonzero(held)[0]]
    return log, held_events
