"""Two-level graph partitioning (paper Sec. 4.4.1).

Level 1: group vertices by *type* (the loader already makes ids type-major).
Level 2: split each typed group into ``p`` topological sub-partitions.  The
paper uses METIS on the same-type subgraph with edge-lifespan weights; METIS
is unavailable offline, so we use a greedy BFS block-growing partitioner with
the same objective (balanced sizes, low weighted edge-cut) and report the cut
quality so the approximation is measurable.

Placement: sub-partitions are assigned round-robin over workers, so each
worker holds ~t·p/w sub-partitions with ~p/w per type — the paper's load
balancing argument for typed supersteps.

Execution arrays: ``build_partition_arrays`` lowers a ``Partitioning`` into
the padded per-worker tensors the partitioned executor
(``core.engine_partitioned``) runs on — each worker owns the traversal edges
*arriving* at its vertices (so delivery is a purely local segment-sum) plus a
halo table of the source vertices it must receive boundary state for each
superstep (the exchange).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List

import numpy as np

from ..core.graph import TemporalGraph


@dataclasses.dataclass
class Partitioning:
    part_of: np.ndarray        # int32[V] — global sub-partition id
    worker_of_part: np.ndarray # int32[n_parts]
    n_parts: int
    n_workers: int
    stats: Dict

    def worker_of(self, vid: int) -> int:
        return int(self.worker_of_part[self.part_of[vid]])


def _greedy_bfs_blocks(n: int, adj_ptr, adj_idx, weights, p: int) -> np.ndarray:
    """Split [0, n) into p balanced blocks by BFS growth; returns block ids."""
    target = max(1, -(-n // p))
    block = np.full(n, -1, np.int32)
    order = np.argsort(-np.diff(adj_ptr))  # seed from high degree
    cur = 0
    filled = 0
    q: deque = deque()
    for seed in order:
        if block[seed] != -1:
            continue
        q.append(seed)
        while q:
            v = q.popleft()
            if block[v] != -1:
                continue
            block[v] = cur
            filled += 1
            if filled >= target:
                cur = min(cur + 1, p - 1)
                filled = 0
                q.clear()
                break
            for e in range(adj_ptr[v], adj_ptr[v + 1]):
                u = adj_idx[e]
                if block[u] == -1:
                    q.append(u)
    block[block == -1] = cur
    return block


def partition_graph(
    graph: TemporalGraph,
    n_workers: int = 8,
    parts_per_type: int = 4,
    hash_baseline: bool = False,
) -> Partitioning:
    V = graph.n_vertices
    part_of = np.zeros(V, np.int32)
    if hash_baseline:
        # Giraph's default: hash partitioning by vertex id.
        n_parts = n_workers * parts_per_type
        part_of = (np.arange(V, dtype=np.int64) * 2654435761 % n_parts).astype(np.int32)
        worker = (np.arange(n_parts) % n_workers).astype(np.int32)
        cut = _edge_cut(graph, part_of)
        return Partitioning(part_of, worker, n_parts, n_workers,
                            dict(kind="hash", edge_cut=cut))

    # same-type subgraph adjacency with lifespan-length edge weights
    next_part = 0
    for t in range(graph.n_vertex_types):
        lo, hi = graph.type_ranges[t]
        n = hi - lo
        if n == 0:
            continue
        sel = (
            (graph.e_src >= lo) & (graph.e_src < hi)
            & (graph.e_dst >= lo) & (graph.e_dst < hi)
        )
        src = graph.e_src[sel] - lo
        dst = graph.e_dst[sel] - lo
        w = (graph.e_life[sel, 1] - graph.e_life[sel, 0]).astype(np.float64)
        # symmetric CSR
        s2 = np.concatenate([src, dst])
        d2 = np.concatenate([dst, src])
        order = np.argsort(s2, kind="stable")
        adj_idx = d2[order].astype(np.int64)
        adj_ptr = np.zeros(n + 1, np.int64)
        np.cumsum(np.bincount(s2, minlength=n), out=adj_ptr[1:])
        blocks = _greedy_bfs_blocks(n, adj_ptr, adj_idx,
                                    np.concatenate([w, w])[order], parts_per_type)
        part_of[lo:hi] = blocks + next_part
        next_part += parts_per_type

    n_parts = next_part if next_part else 1
    worker = (np.arange(n_parts) % n_workers).astype(np.int32)
    cut = _edge_cut(graph, part_of)
    sizes = np.bincount(part_of, minlength=n_parts)
    return Partitioning(
        part_of, worker, n_parts, n_workers,
        dict(kind="type+topo", edge_cut=cut,
             size_imbalance=float(sizes.max() / max(sizes.mean(), 1)),
             parts_per_type=parts_per_type),
    )


def _edge_cut(graph: TemporalGraph, part_of: np.ndarray) -> float:
    if graph.n_edges == 0:
        return 0.0
    crossing = part_of[graph.e_src] != part_of[graph.e_dst]
    w = (graph.e_life[:, 1] - graph.e_life[:, 0]).astype(np.float64)
    return float((w * crossing).sum() / max(w.sum(), 1e-9))


@dataclasses.dataclass
class PartitionArrays:
    """Padded per-worker execution tables for the partitioned executor.

    Shapes: W = n_workers, Vmax/Emax/Hmax = padded per-worker extents.
    Padding sentinels: vertex ids pad with V, traversal-edge ids with 2E —
    both index a synthetic zero row on device — and ``dst_local`` pads with
    Vmax (a trash delivery segment that is sliced off).

    Ownership invariants (asserted by ``build_partition_arrays``):
      * every vertex appears in exactly one worker's ``own_ids`` row;
      * every traversal edge appears in exactly one worker's ``edge_ids`` row
        (the worker owning its arrival vertex), preserving canonical
        arrival-sorted order so per-worker segment-sum delivery reproduces
        the dense engine's summation order bit-for-bit.
    """

    n_workers: int
    own_ids: np.ndarray    # int32[W, Vmax] — owned global vertex ids, pad = V
    edge_ids: np.ndarray   # int32[W, Emax] — owned traversal-edge ids, pad = 2E
    dst_local: np.ndarray  # int32[W, Emax] — arrival slot in own_ids, pad = Vmax
    halo_ids: np.ndarray   # int32[W, Hmax] — source vertices needed, pad = V
    src_halo: np.ndarray   # int32[W, Emax] — per-edge slot into halo_ids, pad = 0
    owner_of_vertex: np.ndarray  # int32[V]
    n_own: np.ndarray      # int64[W] — real owned-vertex count
    n_edges: np.ndarray    # int64[W] — real owned-edge count
    n_halo: np.ndarray     # int64[W] — halo table size
    n_ghost: np.ndarray    # int64[W] — halo entries owned by ANOTHER worker
    stats: Dict

    @property
    def v_max(self) -> int:
        return int(self.own_ids.shape[1])

    @property
    def e_max(self) -> int:
        return int(self.edge_ids.shape[1])

    @property
    def h_max(self) -> int:
        return int(self.halo_ids.shape[1])

    def exchange_volume(self) -> int:
        """Boundary messages per superstep: ghost-state entries received."""
        return int(self.n_ghost.sum())


def build_partition_arrays(
    graph: TemporalGraph, part: Partitioning
) -> PartitionArrays:
    """Lower a vertex partitioning into padded per-worker superstep tables."""
    V = graph.n_vertices
    W = part.n_workers
    tr = graph.traversal
    t_src = tr["t_src"].astype(np.int64)
    t_dst = tr["t_dst"].astype(np.int64)
    n2e = t_src.shape[0]

    owner = part.worker_of_part[part.part_of].astype(np.int32)  # int32[V]
    local_of = np.zeros(V, np.int64)

    owned: List[np.ndarray] = []
    edges: List[np.ndarray] = []
    halos: List[np.ndarray] = []
    src_halos: List[np.ndarray] = []
    dst_locals: List[np.ndarray] = []
    n_ghost = np.zeros(W, np.int64)
    edge_owner = owner[t_dst]
    for w in range(W):
        own = np.where(owner == w)[0].astype(np.int64)  # ascending
        local_of[own] = np.arange(own.shape[0])
        eidx = np.where(edge_owner == w)[0].astype(np.int64)  # canonical order
        halo = np.unique(t_src[eidx])
        owned.append(own)
        edges.append(eidx)
        halos.append(halo)
        src_halos.append(np.searchsorted(halo, t_src[eidx]))
        dst_locals.append(local_of[t_dst[eidx]])
        n_ghost[w] = int((owner[halo] != w).sum())

    n_own = np.asarray([o.shape[0] for o in owned], np.int64)
    n_edges = np.asarray([e.shape[0] for e in edges], np.int64)
    n_halo = np.asarray([h.shape[0] for h in halos], np.int64)
    assert int(n_own.sum()) == V, "every vertex must be owned exactly once"
    assert int(n_edges.sum()) == n2e, "every traversal edge owned exactly once"

    v_max = max(1, int(n_own.max()))
    e_max = max(1, int(n_edges.max()))
    h_max = max(1, int(n_halo.max()))

    def _pad(rows, width, fill):
        out = np.full((W, width), fill, np.int32)
        for w, r in enumerate(rows):
            out[w, : r.shape[0]] = r
        return out

    arrays = PartitionArrays(
        n_workers=W,
        own_ids=_pad(owned, v_max, V),
        edge_ids=_pad(edges, e_max, n2e),
        dst_local=_pad(dst_locals, e_max, v_max),
        halo_ids=_pad(halos, h_max, V),
        src_halo=_pad(src_halos, e_max, 0),
        owner_of_vertex=owner,
        n_own=n_own,
        n_edges=n_edges,
        n_halo=n_halo,
        n_ghost=n_ghost,
        stats=dict(
            **part.stats,
            n_workers=W,
            edge_imbalance=float(n_edges.max() / max(n_edges.mean(), 1e-9)),
            ghost_frac=float(n_ghost.sum() / max(n_halo.sum(), 1)),
            exchange_volume=int(n_ghost.sum()),
        ),
    )
    return arrays


def reassign_on_failure(p: Partitioning, failed_worker: int) -> Partitioning:
    """Rebalance a failed worker's sub-partitions over survivors (fault path)."""
    survivors = [w for w in range(p.n_workers) if w != failed_worker]
    new_worker = p.worker_of_part.copy()
    j = 0
    for i in range(p.n_parts):
        if new_worker[i] == failed_worker:
            new_worker[i] = survivors[j % len(survivors)]
            j += 1
    return Partitioning(p.part_of, new_worker, p.n_parts, p.n_workers,
                        {**p.stats, "reassigned_from": failed_worker})
