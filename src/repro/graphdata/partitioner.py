"""Two-level graph partitioning (paper Sec. 4.4.1).

Level 1: group vertices by *type* (the loader already makes ids type-major).
Level 2: split each typed group into ``p`` topological sub-partitions.  The
paper uses METIS on the same-type subgraph with edge-lifespan weights; METIS
is unavailable offline, so we use a greedy BFS block-growing partitioner with
the same objective (balanced sizes, low weighted edge-cut) and report the cut
quality so the approximation is measurable.

Placement: sub-partitions are assigned round-robin over workers, so each
worker holds ~t·p/w sub-partitions with ~p/w per type — the paper's load
balancing argument for typed supersteps.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List

import numpy as np

from ..core.graph import TemporalGraph


@dataclasses.dataclass
class Partitioning:
    part_of: np.ndarray        # int32[V] — global sub-partition id
    worker_of_part: np.ndarray # int32[n_parts]
    n_parts: int
    n_workers: int
    stats: Dict

    def worker_of(self, vid: int) -> int:
        return int(self.worker_of_part[self.part_of[vid]])


def _greedy_bfs_blocks(n: int, adj_ptr, adj_idx, weights, p: int) -> np.ndarray:
    """Split [0, n) into p balanced blocks by BFS growth; returns block ids."""
    target = max(1, -(-n // p))
    block = np.full(n, -1, np.int32)
    order = np.argsort(-np.diff(adj_ptr))  # seed from high degree
    cur = 0
    filled = 0
    q: deque = deque()
    for seed in order:
        if block[seed] != -1:
            continue
        q.append(seed)
        while q:
            v = q.popleft()
            if block[v] != -1:
                continue
            block[v] = cur
            filled += 1
            if filled >= target:
                cur = min(cur + 1, p - 1)
                filled = 0
                q.clear()
                break
            for e in range(adj_ptr[v], adj_ptr[v + 1]):
                u = adj_idx[e]
                if block[u] == -1:
                    q.append(u)
    block[block == -1] = cur
    return block


def partition_graph(
    graph: TemporalGraph,
    n_workers: int = 8,
    parts_per_type: int = 4,
    hash_baseline: bool = False,
) -> Partitioning:
    V = graph.n_vertices
    part_of = np.zeros(V, np.int32)
    if hash_baseline:
        # Giraph's default: hash partitioning by vertex id.
        n_parts = n_workers * parts_per_type
        part_of = (np.arange(V, dtype=np.int64) * 2654435761 % n_parts).astype(np.int32)
        worker = (np.arange(n_parts) % n_workers).astype(np.int32)
        cut = _edge_cut(graph, part_of)
        return Partitioning(part_of, worker, n_parts, n_workers,
                            dict(kind="hash", edge_cut=cut))

    # same-type subgraph adjacency with lifespan-length edge weights
    next_part = 0
    for t in range(graph.n_vertex_types):
        lo, hi = graph.type_ranges[t]
        n = hi - lo
        if n == 0:
            continue
        sel = (
            (graph.e_src >= lo) & (graph.e_src < hi)
            & (graph.e_dst >= lo) & (graph.e_dst < hi)
        )
        src = graph.e_src[sel] - lo
        dst = graph.e_dst[sel] - lo
        w = (graph.e_life[sel, 1] - graph.e_life[sel, 0]).astype(np.float64)
        # symmetric CSR
        s2 = np.concatenate([src, dst])
        d2 = np.concatenate([dst, src])
        order = np.argsort(s2, kind="stable")
        adj_idx = d2[order].astype(np.int64)
        adj_ptr = np.zeros(n + 1, np.int64)
        np.cumsum(np.bincount(s2, minlength=n), out=adj_ptr[1:])
        blocks = _greedy_bfs_blocks(n, adj_ptr, adj_idx,
                                    np.concatenate([w, w])[order], parts_per_type)
        part_of[lo:hi] = blocks + next_part
        next_part += parts_per_type

    n_parts = next_part if next_part else 1
    worker = (np.arange(n_parts) % n_workers).astype(np.int32)
    cut = _edge_cut(graph, part_of)
    sizes = np.bincount(part_of, minlength=n_parts)
    return Partitioning(
        part_of, worker, n_parts, n_workers,
        dict(kind="type+topo", edge_cut=cut,
             size_imbalance=float(sizes.max() / max(sizes.mean(), 1)),
             parts_per_type=parts_per_type),
    )


def _edge_cut(graph: TemporalGraph, part_of: np.ndarray) -> float:
    if graph.n_edges == 0:
        return 0.0
    crossing = part_of[graph.e_src] != part_of[graph.e_dst]
    w = (graph.e_life[:, 1] - graph.e_life[:, 0]).astype(np.float64)
    return float((w * crossing).sum() / max(w.sum(), 1e-9))


def reassign_on_failure(p: Partitioning, failed_worker: int) -> Partitioning:
    """Rebalance a failed worker's sub-partitions over survivors (fault path)."""
    survivors = [w for w in range(p.n_workers) if w != failed_worker]
    new_worker = p.worker_of_part.copy()
    j = 0
    for i in range(p.n_parts):
        if new_worker[i] == failed_worker:
            new_worker[i] = survivors[j % len(survivors)]
            j += 1
    return Partitioning(p.part_of, new_worker, p.n_parts, p.n_workers,
                        {**p.stats, "reassigned_from": failed_worker})
