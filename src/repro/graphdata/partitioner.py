"""Two-level graph partitioning (paper Sec. 4.4.1).

Level 1: group vertices by *type* (the loader already makes ids type-major).
Level 2: split each typed group into ``p`` topological sub-partitions.  The
paper uses METIS on the same-type subgraph with edge-lifespan weights; METIS
is unavailable offline, so we use a greedy BFS block-growing partitioner with
the same objective (balanced sizes, low weighted edge-cut) and report the cut
quality so the approximation is measurable.

Placement: sub-partitions are assigned round-robin over workers, so each
worker holds ~t·p/w sub-partitions with ~p/w per type — the paper's load
balancing argument for typed supersteps.

Execution arrays: ``build_partition_arrays`` lowers a ``Partitioning`` into
the padded per-worker tensors the partitioned executor
(``core.engine_partitioned``) runs on — each worker owns the traversal edges
*arriving* at its vertices (so delivery is a purely local segment-sum) plus a
halo table of the source vertices it must receive boundary state for each
superstep (the exchange).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List

import numpy as np

from ..core.graph import TemporalGraph


@dataclasses.dataclass
class Partitioning:
    part_of: np.ndarray        # int32[V] — global sub-partition id
    worker_of_part: np.ndarray # int32[n_parts]
    n_parts: int
    n_workers: int
    stats: Dict

    def worker_of(self, vid: int) -> int:
        return int(self.worker_of_part[self.part_of[vid]])


def _greedy_bfs_blocks(n: int, adj_ptr, adj_idx, weights, p: int) -> np.ndarray:
    """Split [0, n) into p balanced blocks by BFS growth; returns block ids."""
    target = max(1, -(-n // p))
    block = np.full(n, -1, np.int32)
    order = np.argsort(-np.diff(adj_ptr))  # seed from high degree
    cur = 0
    filled = 0
    q: deque = deque()
    for seed in order:
        if block[seed] != -1:
            continue
        q.append(seed)
        while q:
            v = q.popleft()
            if block[v] != -1:
                continue
            block[v] = cur
            filled += 1
            if filled >= target:
                cur = min(cur + 1, p - 1)
                filled = 0
                q.clear()
                break
            for e in range(adj_ptr[v], adj_ptr[v + 1]):
                u = adj_idx[e]
                if block[u] == -1:
                    q.append(u)
    block[block == -1] = cur
    return block


def partition_graph(
    graph: TemporalGraph,
    n_workers: int = 8,
    parts_per_type: int = 4,
    hash_baseline: bool = False,
) -> Partitioning:
    V = graph.n_vertices
    part_of = np.zeros(V, np.int32)
    if hash_baseline:
        # Giraph's default: hash partitioning by vertex id.
        n_parts = n_workers * parts_per_type
        part_of = (np.arange(V, dtype=np.int64) * 2654435761 % n_parts).astype(np.int32)
        worker = (np.arange(n_parts) % n_workers).astype(np.int32)
        cut = _edge_cut(graph, part_of)
        return Partitioning(part_of, worker, n_parts, n_workers,
                            dict(kind="hash", edge_cut=cut))

    # same-type subgraph adjacency with lifespan-length edge weights
    next_part = 0
    for t in range(graph.n_vertex_types):
        lo, hi = graph.type_ranges[t]
        n = hi - lo
        if n == 0:
            continue
        sel = (
            (graph.e_src >= lo) & (graph.e_src < hi)
            & (graph.e_dst >= lo) & (graph.e_dst < hi)
        )
        src = graph.e_src[sel] - lo
        dst = graph.e_dst[sel] - lo
        w = (graph.e_life[sel, 1] - graph.e_life[sel, 0]).astype(np.float64)
        # symmetric CSR
        s2 = np.concatenate([src, dst])
        d2 = np.concatenate([dst, src])
        order = np.argsort(s2, kind="stable")
        adj_idx = d2[order].astype(np.int64)
        adj_ptr = np.zeros(n + 1, np.int64)
        np.cumsum(np.bincount(s2, minlength=n), out=adj_ptr[1:])
        blocks = _greedy_bfs_blocks(n, adj_ptr, adj_idx,
                                    np.concatenate([w, w])[order], parts_per_type)
        part_of[lo:hi] = blocks + next_part
        next_part += parts_per_type

    n_parts = next_part if next_part else 1
    worker = (np.arange(n_parts) % n_workers).astype(np.int32)
    cut = _edge_cut(graph, part_of)
    sizes = np.bincount(part_of, minlength=n_parts)
    return Partitioning(
        part_of, worker, n_parts, n_workers,
        dict(kind="type+topo", edge_cut=cut,
             size_imbalance=float(sizes.max() / max(sizes.mean(), 1)),
             parts_per_type=parts_per_type),
    )


def extend_partitioning(base: Partitioning, graph: TemporalGraph,
                        remap: np.ndarray):
    """Carry a partitioning forward over an ingestion epoch (the partitioner
    delta table of graphdata/ingest.py).

    ``remap[i]`` is base vertex i's gid in ``graph``; carried vertices keep
    their sub-partition, and each NEW vertex joins a same-type part by
    majority vote over its already-assigned neighbours (ties → lowest part
    id; isolated vertices → the least-loaded part of the type).  Worker
    placement is untouched, so the epoch's partition tables stay aligned
    with the base's and only the delta is re-placed — O(new + incident
    edges) instead of the full BFS growth.  Any assignment yields
    bit-identical results on the partitioned executor (ownership only
    routes delivery); the vote just keeps the edge cut from degrading.

    Returns None when a new vertex's type has no existing part (a type
    introduced mid-stream) — the caller falls back to a fresh
    ``partition_graph``."""
    V = graph.n_vertices
    part_of = np.full(V, -1, np.int32)
    part_of[remap] = base.part_of
    n_parts = base.n_parts
    assigned = part_of >= 0
    part_type = np.full(n_parts, -1, np.int32)
    part_type[part_of[assigned]] = graph.v_type[assigned]
    sizes = np.bincount(part_of[assigned], minlength=n_parts).astype(np.int64)
    new = np.nonzero(~assigned)[0]
    cands = {t: np.nonzero(part_type == t)[0]
             for t in range(graph.n_vertex_types)}
    # adjacency restricted to edges touching an unassigned vertex
    nbrs: Dict[int, list] = {}
    touch = ~assigned[graph.e_src] | ~assigned[graph.e_dst]
    for s, d in zip(graph.e_src[touch], graph.e_dst[touch]):
        nbrs.setdefault(int(s), []).append(int(d))
        nbrs.setdefault(int(d), []).append(int(s))
    for v in new:
        c = cands[int(graph.v_type[v])]
        if len(c) == 0:
            return None
        cset = set(int(x) for x in c)
        votes: Dict[int, int] = {}
        for u in nbrs.get(int(v), ()):
            pu = int(part_of[u])
            if pu >= 0 and pu in cset:
                votes[pu] = votes.get(pu, 0) + 1
        if votes:
            best = min(votes, key=lambda pk: (-votes[pk], pk))
        else:
            best = int(c[np.argmin(sizes[c])])
        part_of[v] = best
        sizes[best] += 1
    stats = dict(base.stats)
    stats.update(kind=str(stats.get("kind", "?")) + "+extend",
                 edge_cut=_edge_cut(graph, part_of),
                 extended=int(len(new)))
    return Partitioning(part_of, base.worker_of_part, n_parts,
                        base.n_workers, stats)


def _edge_cut(graph: TemporalGraph, part_of: np.ndarray) -> float:
    if graph.n_edges == 0:
        return 0.0
    crossing = part_of[graph.e_src] != part_of[graph.e_dst]
    w = (graph.e_life[:, 1] - graph.e_life[:, 0]).astype(np.float64)
    return float((w * crossing).sum() / max(w.sum(), 1e-9))


@dataclasses.dataclass
class PartitionArrays:
    """Padded per-worker execution tables for the partitioned executor.

    Shapes: W = n_workers, Vmax/Emax/Hmax/Smax = padded per-worker extents.
    Padding sentinels: vertex ids pad with V, traversal-edge ids with 2E —
    both index a synthetic zero row on device — ``dst_local`` pads with Vmax
    (a trash delivery segment that is sliced off) and ``src_halo`` pads with
    Hmax (a synthetic zero slot appended to each worker's halo slice, so pad
    edges can never alias a real halo vertex).

    Ownership invariants (asserted by ``build_partition_arrays``):
      * every vertex appears in exactly one worker's ``own_ids`` row;
      * every traversal edge appears in exactly one worker's ``edge_ids`` row
        (the worker owning its arrival vertex), preserving canonical
        arrival-sorted order so per-worker segment-sum delivery reproduces
        the dense engine's summation order bit-for-bit.

    ETR exchange tables: an ETR hop needs, per current edge e, prefix sums
    over the arrival segment of its *source* vertex.  Those segment edges are
    owned by worker(t_src[e]) — the tables below let that owner compute the
    per-edge rank summary from purely local prefix tables (its owned prev-hop
    counts reordered by the global (dst, lifespan-stat) permutations restrict
    to per-worker permutations because every arrival segment lives whole on
    one worker).  Only summaries for edges consumed by ANOTHER worker
    (``n_src_ghost``) cross partitions — O(cut edges), not O(frontier).

    Point-to-point routing tables: the executor's exchange is a ragged
    all-to-all (``superstep.p2p_exchange``) — each worker pair (s, d) has a
    lane carrying exactly the entries d needs that s owns, so only ghost
    entries move (no global [V]/[2E] scatter+psum buffer).  Two channels
    share one table layout:

      vertex-state channel (plain-hop state; the MIN/MAX extremum channel
      rides the same tables with a ±inf fill):
        halo_own_slot[d, h]     local own-slot of halo entry h when d owns it
                                itself (local copy, no traffic), pad = Vmax
        xchg_send_slot[s, d, k] own-slot of the k-th state row s sends to d,
                                pad = Vmax; diagonal lanes are empty
        xchg_recv_slot[d, s, k] halo slot where that row lands at d, pad = Hmax

      ETR rank-summary channel:
        etr_local_slot[d, j]    producer-row slot of owned edge j's summary
                                when d produced it itself, pad = Smax
        etr_send_slot[s, d, k]  producer-row slot of the k-th summary s sends
                                to d, pad = Smax
        etr_recv_slot[d, s, k]  owned-edge slot where it lands at d, pad = Emax

    Lanes are padded to the max per-pair ghost count (``c_max`` /
    ``etr_c_max``); the REAL traffic — what ``exchange_volume()`` /
    ``etr_exchange_volume()`` report and θ_net is fitted on — is the ragged
    content: Σ n_ghost and Σ n_src_ghost entries per superstep.
    """

    n_workers: int
    own_ids: np.ndarray    # int32[W, Vmax] — owned global vertex ids, pad = V
    edge_ids: np.ndarray   # int32[W, Emax] — owned traversal-edge ids, pad = 2E
    dst_local: np.ndarray  # int32[W, Emax] — arrival slot in own_ids, pad = Vmax
    halo_ids: np.ndarray   # int32[W, Hmax] — source vertices needed, pad = V
    src_halo: np.ndarray   # int32[W, Emax] — per-edge slot into halo_ids, pad = Hmax
    owner_of_vertex: np.ndarray  # int32[V]
    n_own: np.ndarray      # int64[W] — real owned-vertex count
    n_edges: np.ndarray    # int64[W] — real owned-edge count
    n_halo: np.ndarray     # int64[W] — halo table size
    n_ghost: np.ndarray    # int64[W] — halo entries owned by ANOTHER worker
    # ---- ETR rank-summary exchange tables
    etr_perm_local_s: np.ndarray  # int32[W, Emax] — local slot of the j-th owned
    #                               edge in global (dst, life-start) order, pad = Emax
    etr_perm_local_e: np.ndarray  # int32[W, Emax] — same for (dst, life-end) order
    etr_src_eids: np.ndarray      # int32[W, Smax] — edges whose SOURCE vertex this
    #                               worker owns (it produces their summaries), pad = 2E
    etr_src_base: np.ndarray      # int32[W, Smax] — local prefix index of the source
    #                               segment's base in this worker's perm order, pad = 0
    etr_src_len: np.ndarray       # int32[W, Smax] — source arrival-segment length, pad = 0
    n_src: np.ndarray             # int64[W] — summaries produced per worker
    n_src_ghost: np.ndarray       # int64[W] — summaries consumed by ANOTHER worker
    # ---- point-to-point routing tables (see class docstring)
    halo_own_slot: np.ndarray     # int32[W, Hmax] — pad = Vmax
    xchg_send_slot: np.ndarray    # int32[W, W, Cmax] — pad = Vmax
    xchg_recv_slot: np.ndarray    # int32[W, W, Cmax] — pad = Hmax
    etr_local_slot: np.ndarray    # int32[W, Emax] — pad = Smax
    etr_send_slot: np.ndarray     # int32[W, W, Cetr] — pad = Smax
    etr_recv_slot: np.ndarray     # int32[W, W, Cetr] — pad = Emax
    stats: Dict

    @property
    def v_max(self) -> int:
        return int(self.own_ids.shape[1])

    @property
    def e_max(self) -> int:
        return int(self.edge_ids.shape[1])

    @property
    def h_max(self) -> int:
        return int(self.halo_ids.shape[1])

    @property
    def s_max(self) -> int:
        return int(self.etr_src_eids.shape[1])

    def exchange_volume(self) -> int:
        """Boundary messages per plain superstep: ghost-state entries received."""
        return int(self.n_ghost.sum())

    def worker_hop_layouts(self, block_v=None,
                           block_e_mult: int = 512) -> tuple:
        """Stacked per-worker hop-kernel layouts over ``dst_local``.

        Each worker's owned edges are already sorted by local arrival slot
        (canonical order restricted to the shard) with pads on the trash
        segment ``v_max`` — exactly a sorted seg_ids array per worker — so
        each shard gets its own ``kernels.hop_scatter`` block layout over
        ``v_max + 1`` local destinations, built with a COMMON slot shape so
        the executor can vmap/shard_map the fused kernel over the worker
        axis.  Returns ({hop_gather, hop_valid, hop_ldst} [W, ...] tables,
        block_v); cached on the arrays object.
        """
        from ..kernels.hop_scatter import (build_worker_layouts,
                                           stack_layout_tables)

        cache = getattr(self, "_hop_layout_cache", None)
        if cache is None:
            cache = {}
            self._hop_layout_cache = cache
        key = (block_v, block_e_mult)
        hit = cache.get(key)
        if hit is None:
            layouts = build_worker_layouts(self.dst_local, self.v_max + 1,
                                           block_v=block_v,
                                           block_e_mult=block_e_mult)
            hit = (stack_layout_tables(layouts), layouts[0].block_v)
            cache[key] = hit
        return hit

    def etr_exchange_volume(self) -> int:
        """Boundary messages per ETR superstep: rank summaries whose producer
        (source-segment owner) differs from their consumer (edge owner)."""
        return int(self.n_src_ghost.sum())


def build_partition_arrays(
    graph: TemporalGraph, part: Partitioning
) -> PartitionArrays:
    """Lower a vertex partitioning into padded per-worker superstep tables."""
    V = graph.n_vertices
    W = part.n_workers
    tr = graph.traversal
    t_src = tr["t_src"].astype(np.int64)
    t_dst = tr["t_dst"].astype(np.int64)
    n2e = t_src.shape[0]

    owner = part.worker_of_part[part.part_of].astype(np.int32)  # int32[V]
    local_of = np.zeros(V, np.int64)

    owned: List[np.ndarray] = []
    edges: List[np.ndarray] = []
    halos: List[np.ndarray] = []
    src_halos: List[np.ndarray] = []
    dst_locals: List[np.ndarray] = []
    n_ghost = np.zeros(W, np.int64)
    edge_owner = owner[t_dst]
    for w in range(W):
        own = np.where(owner == w)[0].astype(np.int64)  # ascending
        local_of[own] = np.arange(own.shape[0])
        eidx = np.where(edge_owner == w)[0].astype(np.int64)  # canonical order
        halo = np.unique(t_src[eidx])
        owned.append(own)
        edges.append(eidx)
        halos.append(halo)
        src_halos.append(np.searchsorted(halo, t_src[eidx]))
        dst_locals.append(local_of[t_dst[eidx]])
        n_ghost[w] = int((owner[halo] != w).sum())

    n_own = np.asarray([o.shape[0] for o in owned], np.int64)
    n_edges = np.asarray([e.shape[0] for e in edges], np.int64)
    n_halo = np.asarray([h.shape[0] for h in halos], np.int64)
    assert int(n_own.sum()) == V, "every vertex must be owned exactly once"
    assert int(n_edges.sum()) == n2e, "every traversal edge owned exactly once"

    v_max = max(1, int(n_own.max()))
    e_max = max(1, int(n_edges.max()))
    h_max = max(1, int(n_halo.max()))

    def _pad(rows, width, fill):
        out = np.full((W, width), fill, np.int32)
        for w, r in enumerate(rows):
            out[w, : r.shape[0]] = r
        return out

    # ---- ETR rank-summary exchange tables.
    # Arrival segments are whole per worker (edge ownership is by t_dst), so
    # the global (dst, stat) permutations split into per-worker permutations
    # over each worker's owned edges; within-segment order — and hence every
    # within-segment prefix difference the rank machinery takes — is
    # preserved exactly.  ``base_local[v]`` counts this worker's perm entries
    # before v's segment (identical for the start- and end-stat orders, which
    # only differ *inside* segments).
    etr = graph.etr_tables
    perm_s = etr.perm_start.astype(np.int64)
    perm_e = etr.perm_end.astype(np.int64)
    ptr = graph.traversal["arr_ptr"].astype(np.int64)
    seg_len_v = np.diff(ptr)
    src_owner = owner[t_src]
    base_local = np.zeros(V, np.int64)
    perm_locals_s: List[np.ndarray] = []
    perm_locals_e: List[np.ndarray] = []
    src_eids: List[np.ndarray] = []
    src_bases: List[np.ndarray] = []
    src_lens: List[np.ndarray] = []
    n_src = np.zeros(W, np.int64)
    n_src_ghost = np.zeros(W, np.int64)
    eo_perm_s = edge_owner[perm_s]
    eo_perm_e = edge_owner[perm_e]
    for w in range(W):
        own = owned[w]
        lens = seg_len_v[own]
        base_local[own] = np.concatenate(([0], np.cumsum(lens)[:-1]))
        eidx = edges[w]
        perm_locals_s.append(np.searchsorted(eidx, perm_s[eo_perm_s == w]))
        perm_locals_e.append(np.searchsorted(eidx, perm_e[eo_perm_e == w]))
        produced = np.where(src_owner == w)[0].astype(np.int64)  # ascending
        src_eids.append(produced)
        src_bases.append(base_local[t_src[produced]])
        src_lens.append(seg_len_v[t_src[produced]])
        n_src[w] = produced.shape[0]
        n_src_ghost[w] = int((edge_owner[produced] != w).sum())
    assert int(n_src.sum()) == n2e, "every edge's summary produced exactly once"
    s_max = max(1, int(n_src.max()))

    # ---- point-to-point routing tables: one ragged lane per worker pair.
    # Vertex-state channel: d's halo entries owned by s travel on lane (s, d)
    # in d's halo order; entries d owns itself are a local copy
    # (halo_own_slot).  Every halo entry is either local or on exactly one
    # lane, so a padded all-to-all over the lanes moves only ghost entries.
    halo_own_slot = np.full((W, h_max), v_max, np.int32)
    send_lists: Dict[tuple, tuple] = {}
    for d in range(W):
        halo = halos[d]
        hpos = np.arange(halo.shape[0], dtype=np.int64)
        halo_owner = owner[halo]
        self_sel = halo_owner == d
        halo_own_slot[d, hpos[self_sel]] = local_of[halo[self_sel]]
        for s in np.unique(halo_owner[~self_sel]):
            sel = halo_owner == s
            send_lists[(int(s), d)] = (local_of[halo[sel]], hpos[sel])
    c_max = max(1, max((v[0].shape[0] for v in send_lists.values()), default=0))
    xchg_send_slot = np.full((W, W, c_max), v_max, np.int32)
    xchg_recv_slot = np.full((W, W, c_max), h_max, np.int32)
    for (s, d), (slots, hpos) in send_lists.items():
        xchg_send_slot[s, d, : slots.shape[0]] = slots
        xchg_recv_slot[d, s, : hpos.shape[0]] = hpos
    lane_ghost = np.asarray(
        [sum(v[0].shape[0] for (s, d), v in send_lists.items() if d == w)
         for w in range(W)], np.int64)
    assert np.array_equal(lane_ghost, n_ghost), "p2p lanes must cover ghosts"

    # ETR rank-summary channel: producer s's k-th produced summary goes to
    # the owner of its edge; self-consumed summaries are a local copy.
    etr_local_slot = np.full((W, e_max), s_max, np.int32)
    etr_lists: Dict[tuple, tuple] = {}
    for s in range(W):
        produced = src_eids[s]
        consumer = edge_owner[produced]
        self_sel = consumer == s
        # local copy: position of the self-consumed summaries in s's own
        # edge row (edges are ascending, produced eids too → searchsorted)
        etr_local_slot[s, np.searchsorted(edges[s], produced[self_sel])] = \
            np.nonzero(self_sel)[0]
        for d in np.unique(consumer[~self_sel]):
            sel = consumer == d
            etr_lists[(s, int(d))] = (
                np.nonzero(sel)[0],
                np.searchsorted(edges[int(d)], produced[sel]),
            )
    etr_c_max = max(1, max((v[0].shape[0] for v in etr_lists.values()),
                           default=0))
    etr_send_slot = np.full((W, W, etr_c_max), s_max, np.int32)
    etr_recv_slot = np.full((W, W, etr_c_max), e_max, np.int32)
    for (s, d), (slots, epos) in etr_lists.items():
        etr_send_slot[s, d, : slots.shape[0]] = slots
        etr_recv_slot[d, s, : epos.shape[0]] = epos
    lane_etr = np.asarray(
        [sum(v[0].shape[0] for (s, d), v in etr_lists.items() if s == w)
         for w in range(W)], np.int64)
    assert np.array_equal(lane_etr, n_src_ghost), "ETR lanes must cover ghosts"

    arrays = PartitionArrays(
        n_workers=W,
        own_ids=_pad(owned, v_max, V),
        edge_ids=_pad(edges, e_max, n2e),
        dst_local=_pad(dst_locals, e_max, v_max),
        halo_ids=_pad(halos, h_max, V),
        src_halo=_pad(src_halos, e_max, h_max),
        owner_of_vertex=owner,
        n_own=n_own,
        n_edges=n_edges,
        n_halo=n_halo,
        n_ghost=n_ghost,
        etr_perm_local_s=_pad(perm_locals_s, e_max, e_max),
        etr_perm_local_e=_pad(perm_locals_e, e_max, e_max),
        etr_src_eids=_pad(src_eids, s_max, n2e),
        etr_src_base=_pad(src_bases, s_max, 0),
        etr_src_len=_pad(src_lens, s_max, 0),
        n_src=n_src,
        n_src_ghost=n_src_ghost,
        halo_own_slot=halo_own_slot,
        xchg_send_slot=xchg_send_slot,
        xchg_recv_slot=xchg_recv_slot,
        etr_local_slot=etr_local_slot,
        etr_send_slot=etr_send_slot,
        etr_recv_slot=etr_recv_slot,
        stats=dict(
            **part.stats,
            n_workers=W,
            edge_imbalance=float(n_edges.max() / max(n_edges.mean(), 1e-9)),
            ghost_frac=float(n_ghost.sum() / max(n_halo.sum(), 1)),
            exchange_volume=int(n_ghost.sum()),
            etr_exchange_volume=int(n_src_ghost.sum()),
            p2p_lane_width=int(c_max),
            p2p_etr_lane_width=int(etr_c_max),
        ),
    )
    return arrays


def reassign_on_failure(p: Partitioning, failed_worker: int) -> Partitioning:
    """Rebalance a failed worker's sub-partitions over survivors (fault path)."""
    survivors = [w for w in range(p.n_workers) if w != failed_worker]
    new_worker = p.worker_of_part.copy()
    j = 0
    for i in range(p.n_parts):
        if new_worker[i] == failed_worker:
            new_worker[i] = survivors[j % len(survivors)]
            j += 1
    return Partitioning(p.part_of, new_worker, p.n_parts, p.n_workers,
                        {**p.stats, "reassigned_from": failed_worker})
