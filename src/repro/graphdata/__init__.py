from .loader import GraphBuilder, load_graph, save_graph  # noqa: F401
from .ingest import (DeltaSpec, Event, EventLog, Materializer,  # noqa: F401
                     events_fingerprint, log_from_graph, materialize)
