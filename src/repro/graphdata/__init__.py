from .loader import GraphBuilder, load_graph, save_graph  # noqa: F401
