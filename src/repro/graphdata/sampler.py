"""Neighbor sampler for sampled GNN training (minibatch_lg shape).

GraphSAGE-style fixed-fanout uniform neighbor sampling over a CSR adjacency,
implemented in pure JAX (jit-able, fixed shapes): layer l expands the current
frontier by ``fanout[l]`` sampled neighbors (with replacement; zero-degree
nodes self-loop).  Returns padded block tensors consumable by the GNN models:
for each layer, (src_local, dst_local) edge lists indexing into the node set.

This IS part of the system (assignment: "minibatch_lg needs a real neighbor
sampler").
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CSR:
    indptr: jnp.ndarray   # int32[N+1]
    indices: jnp.ndarray  # int32[E]

    @staticmethod
    def from_edge_index(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> "CSR":
        order = np.argsort(src, kind="stable")
        indices = np.asarray(dst)[order].astype(np.int32)
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(np.bincount(src, minlength=n_nodes), out=indptr[1:])
        return CSR(jnp.asarray(indptr.astype(np.int32)), jnp.asarray(indices))


@dataclasses.dataclass
class SampledBlock:
    """One message-passing layer block: edges point sampled-neighbor → target."""
    src: jnp.ndarray      # int32[n_edges] — global node ids (sampled neighbors)
    dst: jnp.ndarray      # int32[n_edges] — global node ids (targets)


@dataclasses.dataclass
class SampledSubgraph:
    layers: List[SampledBlock]       # outermost layer first
    nodes: jnp.ndarray               # all node ids touched (frontier order, padded)
    seeds: jnp.ndarray


def sample_neighbors(csr: CSR, frontier: jnp.ndarray, fanout: int, key) -> jnp.ndarray:
    """Uniform with-replacement sampling: returns int32[len(frontier), fanout]."""
    deg = csr.indptr[frontier + 1] - csr.indptr[frontier]
    r = jax.random.randint(key, (frontier.shape[0], fanout), 0, jnp.maximum(deg, 1)[:, None])
    pos = csr.indptr[frontier][:, None] + jnp.minimum(r, jnp.maximum(deg - 1, 0)[:, None])
    nbr = csr.indices[pos]
    # zero-degree → self loop
    return jnp.where((deg > 0)[:, None], nbr, frontier[:, None])


def sample_subgraph(
    csr: CSR, seeds: jnp.ndarray, fanouts: Sequence[int], key
) -> SampledSubgraph:
    """k-hop fanout sampling; frontier grows seeds → seeds·f1 → seeds·f1·f2."""
    layers: List[SampledBlock] = []
    frontier = seeds
    all_nodes = [seeds]
    for l, f in enumerate(fanouts):
        key, sub = jax.random.split(key)
        nbr = sample_neighbors(csr, frontier, f, sub)           # [n, f]
        src = nbr.reshape(-1)
        dst = jnp.repeat(frontier, f)
        layers.append(SampledBlock(src.astype(jnp.int32), dst.astype(jnp.int32)))
        frontier = src
        all_nodes.append(src)
    # innermost (largest) layer first is how models consume them: reverse so
    # layer[0] aggregates the outermost sampled neighbors.
    layers = layers[::-1]
    return SampledSubgraph(layers, jnp.concatenate(all_nodes), seeds)


def sample_union_graph(csr: CSR, seeds: jnp.ndarray, fanouts: Sequence[int], key):
    """Fanout sampling returning a *local* union graph for subgraph training.

    Sampled slots get positional local ids (no dedup — fixed-fanout standard):
      seeds → [0, S); layer-l samples appended contiguously.  Local edges are
    therefore computable with pure arange arithmetic (static shapes), and the
    returned global ids gather node features.

    Returns (global_ids [n_total], src_local [E_sub], dst_local [E_sub]).
    """
    frontier = seeds
    globals_, srcs, dsts = [seeds], [], []
    offset_prev = 0           # local offset of the current frontier
    offset_next = seeds.shape[0]
    for f in fanouts:
        key, sub = jax.random.split(key)
        nbr = sample_neighbors(csr, frontier, f, sub)            # [n, f]
        n = frontier.shape[0]
        src_local = offset_next + jnp.arange(n * f, dtype=jnp.int32)
        dst_local = offset_prev + jnp.repeat(jnp.arange(n, dtype=jnp.int32), f)
        globals_.append(nbr.reshape(-1))
        srcs.append(src_local)
        dsts.append(dst_local)
        frontier = nbr.reshape(-1)
        offset_prev = offset_next
        offset_next = offset_next + n * f
    return (jnp.concatenate(globals_), jnp.concatenate(srcs),
            jnp.concatenate(dsts))


def block_shapes(n_seeds: int, fanouts: Sequence[int]) -> List[Tuple[int, int]]:
    """Static (n_edges, n_targets) per layer, outermost-first (for dry-run
    ShapeDtypeStructs)."""
    sizes = [n_seeds]
    for f in fanouts:
        sizes.append(sizes[-1] * f)
    shapes = []
    for l, f in enumerate(fanouts):
        shapes.append((sizes[l] * f, sizes[l]))
    return shapes[::-1]
