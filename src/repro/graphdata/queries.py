"""LDBC-derived temporal path query workload (paper Table 5, Q1–Q8).

Each template mirrors the corresponding paper query's shape: hop count,
number of property/time predicates, ETR presence, and (for Q8) dependence on
a dynamic property.  Parameters (underlined values in the paper) are sampled
per instance from the graph's value dictionaries, frequency-weighted so most
instances have non-empty result sets (the paper's workload generator does the
same).  The aggregate workload wraps templates with the count operator.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core import intervals as iv
from ..core import query as Q
from .ldbc import T_HORIZON

TEMPLATES = ("Q1", "Q2", "Q3", "Q4", "Q5", "Q6", "Q7", "Q8")
DYNAMIC_ONLY = ("Q8",)


@dataclasses.dataclass
class QueryInstance:
    template: str
    qry: Q.PathQuery
    params: dict


class _Schema:
    """Resolved ids for the generated LDBC schema."""

    def __init__(self, graph):
        b = graph.meta["builder"]
        self.b = b
        self.vt = b.v_type_ids
        self.et = b.e_type_ids
        self.k = b.key_ids

    def val(self, key_name: str, value) -> int:
        return self.b.lookup_value(self.k[key_name], value)


def _freq_values(graph, key_name: str, top_frac: float = 0.6) -> List[int]:
    """Value ids for a key, restricted to the most frequent ones."""
    b = graph.meta["builder"]
    k = b.key_ids[key_name]
    col = graph.vprops.get(k)
    if col is None:
        return []
    vals = col.vals.reshape(-1)
    vals = vals[vals >= 0]
    uniq, cnts = np.unique(vals, return_counts=True)
    order = np.argsort(-cnts)
    keep = max(1, int(len(uniq) * top_frac))
    return [int(v) for v in uniq[order[:keep]]]


def _interval(rng, align=16):
    step = -(-T_HORIZON // align)
    lo = int(rng.integers(0, T_HORIZON // 2) // step * step)
    return (lo, T_HORIZON)


# ------------------------------------------------------------ the templates
def _q1(s: _Schema, rng, pools) -> QueryInstance:
    tagx = int(rng.choice(pools["tag"]))
    tagy = int(rng.choice(pools["tag"]))
    cty = int(rng.choice(pools["country"]))
    ivl = _interval(rng)
    qry = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(s.vt["post"], (Q.prop_clause(s.k["tag"], "in", tagx),)),
            Q.VertexPredicate(s.vt["forum"], (Q.time_clause("overlaps", ivl),)),
            Q.VertexPredicate(s.vt["post"], (Q.prop_clause(s.k["tag"], "in", tagy),)),
            Q.VertexPredicate(s.vt["person"], (Q.prop_clause(s.k["country"], "==", cty),)),
        ),
        e_preds=(
            Q.EdgePredicate(s.et["containerOf"], Q.DIR_IN),
            Q.EdgePredicate(s.et["containerOf"], Q.DIR_OUT, etr_op=iv.STARTS_BEFORE),
            Q.EdgePredicate(s.et["hasMember"], Q.DIR_IN),
        ),
    )
    return QueryInstance("Q1", qry, dict(tagx=tagx, tagy=tagy, country=cty, ivl=ivl))


def _q2(s: _Schema, rng, pools) -> QueryInstance:
    tag = int(rng.choice(pools["tag"]))
    cty = int(rng.choice(pools["country"]))
    g = s.val("gender", "f")
    ivl = _interval(rng)
    qry = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(
                s.vt["person"],
                (Q.prop_clause(s.k["country"], "==", cty),
                 Q.prop_clause(s.k["gender"], "==", g, conj=Q.OR)),
            ),
            Q.VertexPredicate(
                s.vt["post"],
                (Q.prop_clause(s.k["tag"], "in", tag),
                 Q.time_clause(">", ivl, conj=Q.AND)),
            ),
            Q.VertexPredicate(
                s.vt["person"], (Q.prop_clause(s.k["hasInterest"], "in", tag),)
            ),
        ),
        e_preds=(
            Q.EdgePredicate(s.et["created"], Q.DIR_OUT),
            Q.EdgePredicate(s.et["likes"], Q.DIR_IN),
        ),
    )
    return QueryInstance("Q2", qry, dict(tag=tag, country=cty, ivl=ivl))


def _q3(s: _Schema, rng, pools) -> QueryInstance:
    c1 = int(rng.choice(pools["country"]))
    c2 = int(rng.choice(pools["country"]))
    ivl = _interval(rng)
    qry = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(s.vt["person"], (Q.prop_clause(s.k["country"], "==", c1),)),
            Q.VertexPredicate(s.vt["post"], (Q.time_clause("overlaps", ivl),)),
            Q.VertexPredicate(s.vt["person"], (Q.prop_clause(s.k["country"], "==", c2),)),
            Q.VertexPredicate(s.vt["person"]),
        ),
        e_preds=(
            Q.EdgePredicate(s.et["likes"], Q.DIR_OUT),
            Q.EdgePredicate(s.et["likes"], Q.DIR_IN, etr_op=iv.FULLY_BEFORE),
            Q.EdgePredicate(s.et["follows"], Q.DIR_OUT),
        ),
    )
    return QueryInstance("Q3", qry, dict(c1=c1, c2=c2, ivl=ivl))


def _q4(s: _Schema, rng, pools) -> QueryInstance:
    c1 = int(rng.choice(pools["country"]))
    ivl1 = _interval(rng)
    ivl2 = _interval(rng)
    person = s.vt["person"]
    fo = s.et["follows"]
    qry = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(person, (Q.prop_clause(s.k["country"], "==", c1),)),
            Q.VertexPredicate(person, (Q.time_clause("overlaps", ivl1),)),
            Q.VertexPredicate(person),
            Q.VertexPredicate(person, (Q.time_clause("overlaps", ivl2),)),
            Q.VertexPredicate(person),
        ),
        e_preds=(
            Q.EdgePredicate(fo, Q.DIR_OUT),
            Q.EdgePredicate(fo, Q.DIR_OUT, etr_op=iv.STARTS_BEFORE),
            Q.EdgePredicate(fo, Q.DIR_OUT, etr_op=iv.STARTS_BEFORE),
            Q.EdgePredicate(fo, Q.DIR_OUT),
        ),
    )
    return QueryInstance("Q4", qry, dict(c1=c1, ivl1=ivl1, ivl2=ivl2))


def _q5(s: _Schema, rng, pools) -> QueryInstance:
    tagx = int(rng.choice(pools["tag"]))
    tagy = int(rng.choice(pools["tag"]))
    cty = int(rng.choice(pools["country"]))
    g = s.val("gender", "m")
    ivl = _interval(rng)
    ivl2 = _interval(rng)
    ivl3 = _interval(rng)
    qry = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(s.vt["person"], (Q.prop_clause(s.k["country"], "==", cty),)),
            Q.VertexPredicate(s.vt["post"],
                              (Q.prop_clause(s.k["tag"], "in", tagx),
                               Q.time_clause("overlaps", ivl, conj=Q.AND))),
            Q.VertexPredicate(s.vt["forum"], (Q.time_clause("overlaps", ivl2),)),
            Q.VertexPredicate(s.vt["post"],
                              (Q.prop_clause(s.k["tag"], "in", tagy),
                               Q.time_clause(">", ivl3, conj=Q.AND))),
            Q.VertexPredicate(s.vt["person"], (Q.prop_clause(s.k["gender"], "==", g),)),
        ),
        e_preds=(
            Q.EdgePredicate(s.et["created"], Q.DIR_OUT),
            Q.EdgePredicate(s.et["containerOf"], Q.DIR_IN),
            Q.EdgePredicate(s.et["containerOf"], Q.DIR_OUT, etr_op=iv.FULLY_AFTER),
            Q.EdgePredicate(s.et["created"], Q.DIR_IN),
        ),
    )
    return QueryInstance("Q5", qry, dict(tagx=tagx, tagy=tagy, country=cty))


def _q6(s: _Schema, rng, pools) -> QueryInstance:
    g = s.val("gender", "f")
    tag = int(rng.choice(pools["tag"]))
    ivl = _interval(rng)
    qry = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(s.vt["person"], (Q.prop_clause(s.k["gender"], "==", g),)),
            Q.VertexPredicate(s.vt["comment"]),
            Q.VertexPredicate(s.vt["post"],
                              (Q.prop_clause(s.k["tag"], "in", tag),
                               Q.time_clause("overlaps", ivl, conj=Q.AND))),
            Q.VertexPredicate(s.vt["comment"]),
            Q.VertexPredicate(s.vt["person"]),
        ),
        e_preds=(
            Q.EdgePredicate(s.et["created"], Q.DIR_OUT),
            Q.EdgePredicate(s.et["replyOf"], Q.DIR_OUT),
            Q.EdgePredicate(s.et["replyOf"], Q.DIR_IN, etr_op=iv.FULLY_AFTER),
            Q.EdgePredicate(s.et["created"], Q.DIR_IN),
        ),
    )
    return QueryInstance("Q6", qry, dict(gender=g, tag=tag))


def _q7(s: _Schema, rng, pools) -> QueryInstance:
    c1 = int(rng.choice(pools["country"]))
    c2 = int(rng.choice(pools["country"]))
    lang = s.val("language", "en")
    ivl = _interval(rng)
    ivl2 = _interval(rng)
    qry = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(s.vt["post"],
                              (Q.prop_clause(s.k["language"], "==", lang),
                               Q.time_clause("overlaps", ivl, conj=Q.AND))),
            Q.VertexPredicate(s.vt["person"], (Q.prop_clause(s.k["country"], "==", c1),)),
            Q.VertexPredicate(s.vt["person"],
                              (Q.prop_clause(s.k["country"], "==", c2),
                               Q.time_clause("overlaps", ivl2, conj=Q.AND))),
            Q.VertexPredicate(s.vt["post"]),
        ),
        e_preds=(
            Q.EdgePredicate(s.et["created"], Q.DIR_IN),
            Q.EdgePredicate(s.et["follows"], Q.DIR_OUT, etr_op=iv.STARTS_AFTER),
            Q.EdgePredicate(s.et["created"], Q.DIR_OUT, etr_op=iv.STARTS_BEFORE),
        ),
    )
    return QueryInstance("Q7", qry, dict(c1=c1, c2=c2))


def _q8(s: _Schema, rng, pools) -> QueryInstance:
    w1 = int(rng.choice(pools["worksAt"]))
    w2 = int(rng.choice(pools["worksAt"]))
    ivl = _interval(rng)
    qry = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(s.vt["person"], (Q.prop_clause(s.k["worksAt"], "==", w1),)),
            Q.VertexPredicate(s.vt["person"], (Q.time_clause("overlaps", ivl),)),
            Q.VertexPredicate(s.vt["person"], (Q.prop_clause(s.k["worksAt"], "==", w2),)),
        ),
        e_preds=(
            Q.EdgePredicate(s.et["follows"], Q.DIR_OUT),
            Q.EdgePredicate(s.et["follows"], Q.DIR_IN, etr_op=iv.OVERLAPS),
        ),
    )
    return QueryInstance("Q8", qry, dict(w1=w1, w2=w2))


_BUILDERS: Dict[str, Callable] = {
    "Q1": _q1, "Q2": _q2, "Q3": _q3, "Q4": _q4,
    "Q5": _q5, "Q6": _q6, "Q7": _q7, "Q8": _q8,
}


def to_minmax(inst: QueryInstance, graph, op: int = Q.AGG_MIN) -> QueryInstance:
    """MIN/MAX variant of a plain instance, aggregating the post ``length``
    property — the ONE construction the fit population
    (benchmarks/fit_cost_model), the serving bench's extremum leg
    (benchmarks/serving) and the multidevice conformance tests share, so the
    query whose extremum-channel traffic is fitted is the same one that is
    benchmarked and gated."""
    b = graph.meta["builder"]
    tag = "min" if op == Q.AGG_MIN else "max"
    return dataclasses.replace(
        inst, template=f"{inst.template}-{tag}",
        qry=dataclasses.replace(inst.qry, agg_op=op,
                                agg_key=b.key_ids["length"]))


def make_workload(
    graph,
    templates: Sequence[str] = TEMPLATES,
    n_per_template: int = 100,
    seed: int = 0,
    aggregate: bool = False,
    rng: Optional[np.random.Generator] = None,
) -> List[QueryInstance]:
    """Generate the benchmark workload for a graph.

    Instance parameters are drawn from ``rng`` when given, else from a fresh
    ``default_rng(seed)`` — the same (graph, templates, n_per_template, seed)
    always yields the identical workload, which is what makes serving replay
    runs (benchmarks/serving.py → BENCH_serving.json) reproducible."""
    s = _Schema(graph)
    if rng is None:
        rng = np.random.default_rng(seed)
    dynamic = bool(graph.meta.get("params", {}).get("dynamic", False))
    pools = {
        "tag": _freq_values(graph, "tag") or [0],
        "country": _freq_values(graph, "country") or [0],
        "worksAt": _freq_values(graph, "worksAt") or [0],
    }
    out: List[QueryInstance] = []
    for name in templates:
        if name in DYNAMIC_ONLY and not dynamic:
            continue
        fn = _BUILDERS[name]
        for _ in range(n_per_template):
            inst = fn(s, rng, pools)
            if aggregate:
                inst = QueryInstance(
                    inst.template,
                    dataclasses.replace(inst.qry, agg_op=Q.AGG_COUNT),
                    inst.params,
                )
            out.append(inst)
    return out
