"""AdamW with warmup-stable-decay (WSD, MiniCPM) and cosine schedules.

Built from scratch (no optax offline).  Optimizer state mirrors the parameter
pytree, so pjit shards it identically to the parameters (ZeRO-style sharded
optimizer states for free under FSDP param sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptCfg:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    schedule: str = "cosine"       # 'const' | 'cosine' | 'wsd'
    warmup_steps: int = 100
    total_steps: int = 10_000
    wsd_decay_frac: float = 0.1    # MiniCPM: final 10% exponential-ish decay
    min_lr_frac: float = 0.1


def schedule_lr(cfg: OptCfg, step) -> jnp.ndarray:
    s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        post = 1.0
    elif cfg.schedule == "cosine":
        t = jnp.clip((s - cfg.warmup_steps) /
                     jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        post = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        decay_start = cfg.total_steps * (1.0 - cfg.wsd_decay_frac)
        t = jnp.clip((s - decay_start) /
                     jnp.maximum(cfg.total_steps - decay_start, 1), 0.0, 1.0)
        # stable plateau, then fast decay to min_lr (MiniCPM Sec. 4)
        post = jnp.where(s < decay_start, 1.0,
                         cfg.min_lr_frac ** t)
    else:
        raise ValueError(cfg.schedule)
    return cfg.lr * warm * post


def init_state(params) -> Dict:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return dict(mu=zeros,
                nu=jax.tree_util.tree_map(jnp.zeros_like, zeros),
                step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(cfg: OptCfg, params, grads, state) -> Tuple[Any, Dict, Dict]:
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    b1, b2 = cfg.beta1, cfg.beta2
    mu = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads)
    nu = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state["nu"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, dict(mu=mu, nu=nu, step=step), dict(lr=lr, grad_norm=gnorm)
