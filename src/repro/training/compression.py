"""Gradient compression for the data-parallel all-reduce.

Two schemes, both with error feedback (residual carried to the next step so
the compression is unbiased over time):

* int8 quantisation — per-tensor scale, 4× volume reduction on f32 grads.
* top-k sparsification — keep the k largest-|g| entries per tensor.

These apply on the explicit shard_map DP path (`train_loop.dp_train_step`)
where the gradient exchange is a real ``lax.psum`` — compress before, decode
after.  (Under plain pjit the all-reduce is implicit in XLA and cannot be
intercepted; see DESIGN.md §7.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionCfg:
    kind: str = "int8"       # 'none' | 'int8' | 'topk'
    topk_frac: float = 0.01


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def _quant_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress(cfg: CompressionCfg, grads, err):
    """Returns (payload pytree to all-reduce, new residual)."""
    if cfg.kind == "none":
        return grads, err

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if cfg.kind == "int8":
            q, s = _quant_int8(gf)
            approx = _dequant_int8(q, s)
            return (q, s), gf - approx
        if cfg.kind == "topk":
            flat = gf.reshape(-1)
            k = max(1, int(flat.shape[0] * cfg.topk_frac))
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            vals = flat[idx]
            approx = jnp.zeros_like(flat).at[idx].set(vals).reshape(gf.shape)
            return (vals, idx, jnp.asarray(gf.shape[0] if gf.ndim else 1)), gf - approx
        raise ValueError(cfg.kind)

    flat, tdef = jax.tree_util.tree_flatten(grads)
    eflat = jax.tree_util.tree_leaves(err)
    outs = [one(g, e) for g, e in zip(flat, eflat)]
    payload = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return payload, new_err


def decompress(cfg: CompressionCfg, payload, like):
    if cfg.kind == "none":
        return payload

    def one(p, ref):
        if cfg.kind == "int8":
            q, s = p
            return _dequant_int8(q, s)
        vals, idx, _ = p
        flat = jnp.zeros((ref.size,), jnp.float32).at[idx].set(vals)
        return flat.reshape(ref.shape)

    flat_p = jax.tree_util.tree_leaves(payload, is_leaf=lambda x: isinstance(x, tuple))
    flat_r, tdef = jax.tree_util.tree_flatten(like)
    return jax.tree_util.tree_unflatten(tdef, [one(p, r) for p, r in zip(flat_p, flat_r)])


def compressed_psum(cfg: CompressionCfg, grads, err, axis_name: str):
    """compress → psum the compact payload → decompress (+ mean over axis)."""
    n = jax.lax.psum(1, axis_name)
    if cfg.kind == "none":
        return jax.tree_util.tree_map(lambda g: jax.lax.psum(g, axis_name) / n,
                                      grads), err
    payload, new_err = compress(cfg, grads, err)

    if cfg.kind == "int8":
        def red(p):
            q, s = p
            # sum of dequantised shards ≡ psum of (q·s); send int8 + scales
            return jax.lax.psum(_dequant_int8(q, s), axis_name) / n
        flat, tdef = jax.tree_util.tree_flatten(
            payload, is_leaf=lambda x: isinstance(x, tuple))
        summed = [red(p) for p in flat]
        return jax.tree_util.tree_unflatten(tdef, summed), new_err

    # topk: psum of scattered dense (indices differ per shard)
    def red_topk(p, ref):
        vals, idx, _ = p
        dense = jnp.zeros((ref.size,), jnp.float32).at[idx].set(vals)
        return jax.lax.psum(dense, axis_name).reshape(ref.shape) / n

    flat_p = jax.tree_util.tree_leaves(payload, is_leaf=lambda x: isinstance(x, tuple))
    flat_r, tdef = jax.tree_util.tree_flatten(grads)
    return (jax.tree_util.tree_unflatten(
        tdef, [red_topk(p, r) for p, r in zip(flat_p, flat_r)]), new_err)
