"""Sharded, atomic, elastic checkpointing.

Layout:
  <dir>/step_<n>.tmp/…  →  atomic rename →  <dir>/step_<n>/
     manifest.json   — leaf paths, shapes, dtypes, fnv1a content hashes, step
     arr_<i>.npy     — one file per pytree leaf (host numpy)

Properties needed at 1000-node scale, realised here at process scale:
  * atomicity — readers only ever see fully-renamed directories;
  * integrity — per-leaf content hash verified on restore;
  * elasticity — arrays are stored unsharded (host canonical); restore
    device_puts them under *any* new sharding/mesh shape, so a job restarted
    on a different topology resumes cleanly;
  * async — `save_async` runs serialisation off the training thread;
  * retention — keep_last garbage collection.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _fnv1a(data: bytes) -> str:
    h = 0xCBF29CE484222325
    for b in data[:: max(1, len(data) // 65536)]:  # sampled hash for speed
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return f"{h:016x}"


def _leaf_paths(tree) -> List[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def save(state: Any, step: int, ckpt_dir: str, keep_last: int = 3) -> str:
    leaves, treedef = jax.tree_util.tree_flatten(state)
    paths = _leaf_paths(state)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = dict(step=step, leaves=[])
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        fn = f"arr_{i}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            dict(path=p, file=fn, shape=list(arr.shape), dtype=str(arr.dtype),
                 hash=_fnv1a(arr.tobytes())))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


_ASYNC_THREADS: List[threading.Thread] = []


def save_async(state: Any, step: int, ckpt_dir: str, keep_last: int = 3):
    host_state = jax.tree_util.tree_map(np.asarray, state)  # snapshot now
    t = threading.Thread(target=save, args=(host_state, step, ckpt_dir, keep_last),
                         daemon=True)
    t.start()
    _ASYNC_THREADS.append(t)
    return t


def wait_pending():
    for t in _ASYNC_THREADS:
        t.join()
    _ASYNC_THREADS.clear()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append(int(d.split("_")[1]))
            except ValueError:
                pass
    return max(steps) if steps else None


def restore(like: Any, ckpt_dir: str, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True) -> Tuple[Any, int]:
    """Restore into the structure of ``like``; optionally device_put with new
    shardings (elastic restore onto a different mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    assert len(leaves) == len(manifest["leaves"]), "pytree structure mismatch"
    out = []
    for meta in manifest["leaves"]:
        arr = np.load(os.path.join(d, meta["file"]))
        if verify and _fnv1a(arr.tobytes()) != meta["hash"]:
            raise IOError(f"checkpoint corruption in {meta['path']}")
        out.append(arr)
    state = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        state = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return state, manifest["step"]


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
