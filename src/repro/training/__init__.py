from . import checkpoint, compression, fault, optimizer, train_loop  # noqa: F401
