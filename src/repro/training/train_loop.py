"""Train-step factories: pjit path (GSPMD) and explicit shard_map DP path.

* ``make_train_step`` — the production path: loss+grad+AdamW in one jitted
  function; sharding comes from in_shardings/out_shardings at the call site
  (launch/dryrun.py, launch/train.py).  Supports microbatch gradient
  accumulation (sequential lax.scan over microbatches).
* ``make_dp_train_step`` — explicit data-parallel shard_map variant with a
  real ``lax.psum`` gradient exchange, where gradient *compression* (int8 /
  top-k with error feedback) is applied.  Used by the compression tests and
  the weak-scaling bench.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .compression import CompressionCfg, compressed_psum, init_error_state
from .optimizer import OptCfg, apply_updates, init_state


def make_train_step(
    loss_fn: Callable[[Any, Dict], jnp.ndarray],
    opt_cfg: OptCfg,
    microbatches: int = 1,
    donate: bool = True,
):
    """loss_fn(params, batch) → scalar.  Returns jitted step fn."""

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def mb(carry, mbatch):
                acc_loss, acc_grads = carry
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                acc_grads = jax.tree_util.tree_map(jnp.add, acc_grads, g)
                return (acc_loss + l, acc_grads), None

            split = jax.tree_util.tree_map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]),
                batch,
            )
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(mb, (jnp.float32(0.0), zeros), split)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        new_params, new_state, metrics = apply_updates(opt_cfg, params, grads, opt_state)
        return new_params, new_state, dict(loss=loss, **metrics)

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(step, donate_argnums=donate_argnums)


def make_dp_train_step(
    loss_fn: Callable[[Any, Dict], jnp.ndarray],
    opt_cfg: OptCfg,
    mesh,
    compression: Optional[CompressionCfg] = None,
    axis: str = "data",
):
    """Explicit shard_map DP step with (optionally compressed) psum."""
    from jax.experimental.shard_map import shard_map

    comp = compression or CompressionCfg(kind="none")

    try:
        from jax import shard_map as _sm  # jax >= 0.8
        shard_map = _sm
    except ImportError:
        pass

    def step(params, opt_state, err, batch):
        def shard_fn(params, opt_state, err, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads, new_err = compressed_psum(comp, grads, err, axis)
            loss = jax.lax.pmean(loss, axis)
            new_params, new_state, metrics = apply_updates(
                opt_cfg, params, grads, opt_state)
            return new_params, new_state, new_err, dict(loss=loss, **metrics)

        pspec_rep = jax.tree_util.tree_map(lambda _: P(), params)
        ospec_rep = jax.tree_util.tree_map(lambda _: P(), opt_state)
        espec_rep = jax.tree_util.tree_map(lambda _: P(), err)
        bspec = jax.tree_util.tree_map(lambda _: P(axis), batch)
        kw = {}
        import inspect
        sig = inspect.signature(shard_map)
        if "check_vma" in sig.parameters:
            kw["check_vma"] = False
        else:  # pragma: no cover — older jax
            kw["check_rep"] = False
        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(pspec_rep, ospec_rep, espec_rep, bspec),
            out_specs=(pspec_rep, ospec_rep, espec_rep,
                       dict(loss=P(), lr=P(), grad_norm=P())),
            **kw,
        )(params, opt_state, err, batch)

    return jax.jit(step)


def train_state_init(params, opt_cfg: OptCfg, with_err: bool = False):
    st = init_state(params)
    if with_err:
        return st, init_error_state(params)
    return st
