"""GPipe-style pipeline parallelism over a mesh axis.

The §Perf analysis (EXPERIMENTS.md, llama3-405b train) shows the FSDP
all-gather of 810 GB of weights dominating the collective term.  Pipelining
layers over an axis keeps each stage's weights resident (no per-layer
all-gather); only microbatch activations cross stage boundaries via
``collective-permute`` — O(n_micro · B_mb·S·D) ICI bytes instead of
O(params).

Design (shard_map, TPU-native):
  * the layer stack [L, ...] is reshaped to [n_stages, L/n_stages, ...] and
    sharded over the pipeline axis — each device along that axis holds its
    stage's layers only;
  * the classic GPipe schedule runs n_micro + n_stages − 1 ticks; at each
    tick every stage processes the microbatch it holds and the carry ring is
    rotated with ``jax.lax.ppermute`` (bubble fraction =
    (n_stages−1)/(n_micro+n_stages−1));
  * losses are computed on the last stage and psum'd.

This module implements the generic schedule plus a transformer binding
(`pipeline_forward`).  Correctness is validated against the non-pipelined
forward in tests/test_pipeline.py; the dry-run perf cell lowers it at 405B
scale (scripts/perf_iterations.py llama3_pp).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PipelineCfg:
    axis: str = "data"          # mesh axis carrying the stages
    n_microbatches: int = 8


def _stage_index(axis):
    return jax.lax.axis_index(axis)


def pipeline_apply(
    layer_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,           # this stage's stacked layers [L/S, ...]
    x_micro: jnp.ndarray,        # this stage's share of microbatches
                                 # [n_micro/S_in? no: full [n_micro, B_mb, ...]]
    cfg: PipelineCfg,
    n_stages: int,
):
    """Inside-shard_map GPipe schedule.

    Every stage holds the full microbatch queue in HBM (simple variant);
    stage s processes microbatch m at tick t = m + s.  The carry ring
    rotates stage outputs to the next stage each tick.
    """
    axis = cfg.axis
    n_micro = cfg.n_microbatches
    sidx = _stage_index(axis)
    n_ticks = n_micro + n_stages - 1

    def run_stage(x):
        def body(carry, lp):
            return layer_fn(lp, carry), None

        out, _ = jax.lax.scan(body, x, stage_params)
        return out

    state = jnp.zeros_like(x_micro[0])           # current in-flight activation
    outputs = jnp.zeros_like(x_micro)            # completed microbatches

    def tick(t, carry):
        state, outputs = carry
        m_in = t - sidx                          # microbatch this stage sees
        # stage 0 ingests fresh microbatches; others use the rotated carry
        fresh = x_micro[jnp.clip(m_in, 0, n_micro - 1)]
        x_in = jnp.where(sidx == 0, fresh, state)
        active = (m_in >= 0) & (m_in < n_micro)
        y = run_stage(x_in)
        y = jnp.where(active, y, state)
        # last stage emits its finished microbatch
        outputs = jax.lax.cond(
            active & (sidx == n_stages - 1),
            lambda o: o.at[jnp.clip(m_in, 0, n_micro - 1)].set(y),
            lambda o: o,
            outputs,
        )
        # rotate the ring: stage s → stage s+1
        state_next = jax.lax.ppermute(
            y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
        )
        return state_next, outputs

    state, outputs = jax.lax.fori_loop(0, n_ticks, tick, (state, outputs))
    # every shard returns the last stage's outputs (broadcast for the caller)
    outputs = jax.lax.ppermute(
        outputs, axis,
        [(n_stages - 1, i) for i in range(n_stages)],
    ) if False else outputs  # callers read from the last stage's shard
    return outputs


def make_pipelined_forward(layer_fn, n_stages: int, cfg: PipelineCfg, mesh):
    """Returns f(stacked_params [L,...], x [n_micro, B_mb, ...]) → outputs.

    ``stacked_params`` are sharded over the pipeline axis on dim 0 (stages);
    x is replicated along the pipeline axis (each stage sees the queue).
    """
    try:  # moved out of experimental in newer jax
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    import inspect
    # replication-check kwarg renamed check_rep → check_vma across jax
    # versions; detect from the signature, not the import location
    _rep_kw = ("check_vma" if "check_vma" in
               inspect.signature(shard_map).parameters else "check_rep")

    axis = cfg.axis

    def inner(stage_params, x_micro):
        # each shard holds exactly its stage: strip the sharded stage dim
        stage_params = jax.tree_util.tree_map(lambda a: a[0], stage_params)
        return pipeline_apply(layer_fn, stage_params, x_micro, cfg, n_stages)

    def wrapped(params_stacked, x):
        # reshape [L, ...] → [S, L/S, ...] then shard dim 0
        def to_stages(a):
            L = a.shape[0]
            assert L % n_stages == 0, "layers must divide stages"
            return a.reshape(n_stages, L // n_stages, *a.shape[1:])

        staged = jax.tree_util.tree_map(to_stages, params_stacked)
        pspec_tree = jax.tree_util.tree_map(
            lambda a: P(axis, *([None] * (a.ndim - 1))), staged)
        xspec = P(*([None] * x.ndim))
        return shard_map(
            inner, mesh=mesh,
            in_specs=(jax.tree_util.tree_map(
                lambda a: P(axis, *([None] * (a.ndim - 1))), staged), xspec),
            out_specs=xspec,
            **{_rep_kw: False},
        )(staged, x)

    return wrapped
