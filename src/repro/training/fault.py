"""Fault tolerance & elasticity: heartbeats, failure recovery, stragglers.

At datacenter scale these mechanisms live in the job launcher; here they are
implemented as a process-local control plane with the same state machine, so
the recovery logic (the part that is actually subtle) is tested for real:

* ``HeartbeatMonitor`` — workers report liveness; the monitor declares
  failure after ``timeout_s`` silence.
* ``FaultTolerantRunner`` — drives a step function; on (injected or detected)
  worker failure it (a) reassigns the failed worker's graph partitions
  (query engine path, `partitioner.reassign_on_failure`) or (b) restores the
  latest checkpoint and replays (training path).  Restore may land on a
  different worker count — elastic restart.
* ``mitigate_stragglers`` — speculative re-execution: per-partition times are
  monitored; partitions slower than ``k × median`` are duplicated on the
  fastest idle worker and the first result wins (the paper's Q3/Q4 weak-
  scaling stragglers motivate this).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import checkpoint as ckpt


class HeartbeatMonitor:
    def __init__(self, n_workers: int, timeout_s: float = 5.0):
        self.timeout = timeout_s
        self.last_beat: Dict[int, float] = {w: time.time() for w in range(n_workers)}
        self.dead: set = set()

    def beat(self, worker: int, t: Optional[float] = None):
        if worker not in self.dead:
            self.last_beat[worker] = time.time() if t is None else t

    def kill(self, worker: int):
        self.dead.add(worker)

    def check(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        failed = [
            w for w, t in self.last_beat.items()
            if w not in self.dead and now - t > self.timeout
        ]
        failed += [w for w in self.dead if now is not None]
        return sorted(set(failed))

    def alive(self) -> List[int]:
        now = time.time()
        return [w for w in self.last_beat
                if w not in self.dead and now - self.last_beat[w] <= self.timeout]


@dataclasses.dataclass
class StragglerPolicy:
    slowdown_factor: float = 3.0
    max_duplicates: int = 2


def mitigate_stragglers(
    part_times_ms: np.ndarray,
    part_worker: np.ndarray,
    policy: StragglerPolicy = StragglerPolicy(),
) -> Dict[int, int]:
    """Given per-partition times and placements, pick partitions to duplicate.

    Returns {partition_id: backup_worker}.  First-result-wins semantics are
    applied by the caller (the superstep barrier takes min(primary, backup)).
    """
    med = float(np.median(part_times_ms))
    worker_load = {}
    for p, w in enumerate(part_worker):
        worker_load[int(w)] = worker_load.get(int(w), 0.0) + float(part_times_ms[p])
    slow = np.argsort(-part_times_ms)
    out: Dict[int, int] = {}
    for p in slow[: policy.max_duplicates]:
        if part_times_ms[p] > policy.slowdown_factor * max(med, 1e-9):
            # least-loaded worker that doesn't already own p
            cands = sorted(worker_load, key=worker_load.get)
            for w in cands:
                if w != int(part_worker[p]):
                    out[int(p)] = w
                    worker_load[w] += float(part_times_ms[p])
                    break
    return out


class FaultTolerantRunner:
    """Checkpoint-restart training driver with failure injection hooks."""

    def __init__(self, step_fn: Callable, state, ckpt_dir: str,
                 ckpt_every: int = 10, keep_last: int = 3):
        self.step_fn = step_fn
        self.state = state
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep_last = keep_last
        self.step = 0
        self.recoveries = 0

    def run(self, batches: Sequence, fail_at: Optional[Dict[int, Exception]] = None,
            shardings=None) -> List[dict]:
        """Run over batches; ``fail_at[step]`` raises at that step (injected
        failure) and the runner restores + replays."""
        fail_at = fail_at or {}
        metrics: List[dict] = []
        i = 0
        injected = set()
        while i < len(batches):
            try:
                if self.step in fail_at and self.step not in injected:
                    injected.add(self.step)
                    raise fail_at[self.step]
                out = self.step_fn(self.state, batches[i])
                self.state, m = out
                self.step += 1
                i += 1
                metrics.append(dict(step=self.step, **m))
                if self.step % self.ckpt_every == 0:
                    ckpt.save(self.state, self.step, self.ckpt_dir, self.keep_last)
            except Exception:
                last = ckpt.latest_step(self.ckpt_dir)
                if last is None:
                    # no checkpoint yet: restart from scratch
                    self.step = 0
                    i = 0
                    self.recoveries += 1
                    continue
                self.state, self.step = ckpt.restore(
                    self.state, self.ckpt_dir, shardings=shardings)
                i = self.step  # deterministic data order: replay from ckpt step
                self.recoveries += 1
        ckpt.save(self.state, self.step, self.ckpt_dir, self.keep_last)
        return metrics


def elastic_remesh(n_alive: int, want_shape: Tuple[int, ...]) -> Tuple[int, ...]:
    """Largest mesh shape (same rank) fitting the surviving workers —
    elastic scale-down policy: shrink the data axis first."""
    shape = list(want_shape)
    total = int(np.prod(shape))
    while total > n_alive and shape[0] > 1:
        shape[0] //= 2
        total = int(np.prod(shape))
    if total > n_alive:
        shape = [1] * (len(shape) - 1) + [n_alive]
    return tuple(shape)
