"""Fault tolerance & elasticity: heartbeats, failure recovery, stragglers.

At datacenter scale these mechanisms live in the job launcher; here they are
implemented as a process-local control plane with the same state machine, so
the recovery logic (the part that is actually subtle) is tested for real:

* ``HeartbeatMonitor`` / ``StragglerPolicy`` / ``mitigate_stragglers`` —
  shared with the serving fault layer; the single implementation lives in
  ``repro.faults_common`` and is re-exported here for compatibility.
* ``FaultTolerantRunner`` — drives a step function; on (injected or detected)
  worker failure it (a) reassigns the failed worker's graph partitions
  (query engine path, `partitioner.reassign_on_failure`) or (b) restores the
  latest checkpoint and replays (training path).  Restore may land on a
  different worker count — elastic restart.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import checkpoint as ckpt
from ..faults_common import (  # noqa: F401  (re-exported compatibility names)
    HeartbeatMonitor,
    StragglerPolicy,
    backoff_delay,
    mitigate_stragglers,
)

__all__ = [
    "HeartbeatMonitor",
    "StragglerPolicy",
    "backoff_delay",
    "mitigate_stragglers",
    "FaultTolerantRunner",
    "elastic_remesh",
]


class FaultTolerantRunner:
    """Checkpoint-restart training driver with failure injection hooks."""

    def __init__(self, step_fn: Callable, state, ckpt_dir: str,
                 ckpt_every: int = 10, keep_last: int = 3):
        self.step_fn = step_fn
        self.state = state
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep_last = keep_last
        self.step = 0
        self.recoveries = 0

    def run(self, batches: Sequence, fail_at: Optional[Dict[int, Exception]] = None,
            shardings=None) -> List[dict]:
        """Run over batches; ``fail_at[step]`` raises at that step (injected
        failure) and the runner restores + replays."""
        fail_at = fail_at or {}
        metrics: List[dict] = []
        i = 0
        injected = set()
        while i < len(batches):
            try:
                if self.step in fail_at and self.step not in injected:
                    injected.add(self.step)
                    raise fail_at[self.step]
                out = self.step_fn(self.state, batches[i])
                self.state, m = out
                self.step += 1
                i += 1
                metrics.append(dict(step=self.step, **m))
                if self.step % self.ckpt_every == 0:
                    ckpt.save(self.state, self.step, self.ckpt_dir, self.keep_last)
            except Exception:
                last = ckpt.latest_step(self.ckpt_dir)
                if last is None:
                    # no checkpoint yet: restart from scratch
                    self.step = 0
                    i = 0
                    self.recoveries += 1
                    continue
                self.state, self.step = ckpt.restore(
                    self.state, self.ckpt_dir, shardings=shardings)
                i = self.step  # deterministic data order: replay from ckpt step
                self.recoveries += 1
        ckpt.save(self.state, self.step, self.ckpt_dir, self.keep_last)
        return metrics


def elastic_remesh(n_alive: int, want_shape: Tuple[int, ...]) -> Tuple[int, ...]:
    """Largest mesh shape (same rank) fitting the surviving workers —
    elastic scale-down policy: shrink the data axis first."""
    shape = list(want_shape)
    total = int(np.prod(shape))
    while total > n_alive and shape[0] > 1:
        shape[0] //= 2
        total = int(np.prod(shape))
    if total > n_alive:
        shape = [1] * (len(shape) - 1) + [n_alive]
    return tuple(shape)
