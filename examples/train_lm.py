"""Train a reduced MiniCPM (WSD schedule) with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
Interrupt and re-run to see fault-tolerant resume from the last checkpoint.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

if __name__ == "__main__":
    main()
