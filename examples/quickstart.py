"""Quickstart: build a temporal property graph, run temporal path queries.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import engine as E
from repro.core import intervals as iv
from repro.core import query as Q
from repro.graphdata.loader import GraphBuilder


def main():
    # ---- build the paper's Figure-1-style community graph
    b = GraphBuilder()
    b.lifespan = (0, 100)
    person = b.vertex_type("person")
    post = b.vertex_type("post")
    follows = b.edge_type("follows")
    likes = b.edge_type("likes")
    k_name = b.key("name")
    k_country = b.key("country")
    k_tag = b.key("tag")

    cleo = b.add_vertex(person, (0, 100))
    alice = b.add_vertex(person, (0, 100))
    bob = b.add_vertex(person, (5, 100))
    don = b.add_vertex(person, (0, 100))
    pic = b.add_vertex(post, (20, 100))

    for vid, name in [(cleo, "Cleo"), (alice, "Alice"), (bob, "Bob"), (don, "Don")]:
        b.set_vprop(vid, k_name, name)
    # Cleo's country CHANGES over time → dynamic temporal property
    b.set_vprop(cleo, k_country, "uk", (0, 40))
    b.set_vprop(cleo, k_country, "us", (40, 100))
    b.set_vprop(alice, k_country, "india")
    b.set_vprop(bob, k_country, "uk")
    b.set_vprop(pic, k_tag, "vacation")

    b.add_edge(cleo, alice, follows, (50, 100))   # after Cleo left the UK!
    b.add_edge(alice, bob, follows, (10, 100))
    b.add_edge(bob, don, follows, (10, 30))
    b.add_edge(alice, don, follows, (45, 100))    # starts AFTER bob→don ends
    b.add_edge(bob, pic, likes, (25, 40))
    b.add_edge(don, pic, likes, (60, 100))        # Don likes it AFTER Bob

    g = b.build()
    print("graph:", g.subgraph_stats())

    uk = b.lookup_value(k_country, "uk")
    vac = b.lookup_value(k_tag, "vacation")

    # EQ1: person in 'UK' → follows → person → follows → person
    eq1 = Q.PathQuery(
        v_preds=(Q.VertexPredicate(person, (Q.prop_clause(k_country, "==", uk),)),
                 Q.VertexPredicate(person), Q.VertexPredicate(person)),
        e_preds=(Q.EdgePredicate(follows, Q.DIR_OUT),
                 Q.EdgePredicate(follows, Q.DIR_OUT)),
    )
    static = E.count_results(g, eq1, mode=E.MODE_STATIC)
    temporal = E.count_results(g, eq1, mode=E.MODE_INTERVAL, n_buckets=20)
    print(f"EQ1 matches: {static:.0f} structurally, {temporal:.0f} with "
          f"time-aligned semantics (Cleo path drops out)")

    # EQ2 (ETR): person liked post BEFORE another person liked it
    eq2 = Q.PathQuery(
        v_preds=(Q.VertexPredicate(person),
                 Q.VertexPredicate(post, (Q.prop_clause(k_tag, "in", vac),)),
                 Q.VertexPredicate(person)),
        e_preds=(Q.EdgePredicate(likes, Q.DIR_OUT),
                 Q.EdgePredicate(likes, Q.DIR_IN, etr_op=iv.FULLY_BEFORE)),
    )
    print(f"EQ2 (liked before): {E.count_results(g, eq2):.0f} path(s)  "
          f"(Bob→PicPost←Don)")

    # EQ4-style temporal aggregate: who follows how many people, when?
    eq4 = Q.PathQuery(
        v_preds=(Q.VertexPredicate(person), Q.VertexPredicate(person)),
        e_preds=(Q.EdgePredicate(follows, Q.DIR_OUT),),
        agg_op=Q.AGG_COUNT,
    )
    out = E.execute(g, eq4, mode=E.MODE_BUCKET, n_buckets=20)
    counts = np.asarray(out.per_vertex)
    for vid in np.nonzero(counts.sum(1))[0]:
        name_col = g.vprops[k_name]
        print(f"  vertex {vid}: follow-count per time bucket "
              f"{counts[vid].astype(int)}")


if __name__ == "__main__":
    main()
