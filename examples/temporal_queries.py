"""End-to-end driver: generate an LDBC temporal graph, build statistics,
plan with the cost model, serve the 8-template workload, verify vs oracle.

    PYTHONPATH=src python examples/temporal_queries.py [--persons 1000]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.query import main

if __name__ == "__main__":
    if "--verify" not in sys.argv:
        sys.argv.append("--verify")
    main()
