"""Serve batched decode requests from a (reduced) gemma3-style model:
prefill the prompt batch, then stream tokens with the KV cache.

    PYTHONPATH=src python examples/serve_decode.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.gemma3_4b import SMOKE as CFG
from repro.models import transformer as tr


def main(batch=8, prompt_len=16, gen_len=32):
    params = tr.init_params(CFG, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                 0, CFG.vocab)
    max_len = prompt_len + gen_len

    # prefill
    t0 = time.perf_counter()
    logits, cache = tr.prefill(CFG, params, prompts, max_len=max_len)
    jax.block_until_ready(logits)
    print(f"prefill[{batch}x{prompt_len}]: {(time.perf_counter()-t0)*1e3:.1f} ms")

    decode = jax.jit(lambda p, c, t, n: tr.decode_step(CFG, p, c, t, n))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks = [tok]
    t0 = time.perf_counter()
    for i in range(gen_len - 1):
        logits, cache = decode(params, cache, tok, prompt_len + i + 1)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decode: {gen_len-1} steps × {batch} seqs in {dt*1e3:.1f} ms "
          f"({dt/(gen_len-1)*1e3:.2f} ms/token, greedy)")
    out = jnp.stack(toks, 1)
    print("sampled token ids (first seq):", out[0][:16].tolist())


if __name__ == "__main__":
    main()
