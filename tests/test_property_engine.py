"""Hypothesis property tests on system invariants."""
import dataclasses

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import engine as E
from repro.core import intervals as iv
from repro.core import query as Q
from repro.core.graph import TemporalGraph, make_prop_column
from repro.core.ref_engine import RefEngine

BUCKET_STEP = 10  # all generated times on a 10-unit grid, T = 160, B = 16


@st.composite
def tiny_graphs(draw):
    """Random bucket-aligned temporal graphs with 2 vertex types, 1 prop."""
    n_v = draw(st.integers(4, 14))
    T = 160
    v_type = np.asarray(draw(st.lists(st.integers(0, 1), min_size=n_v,
                                      max_size=n_v)), np.int32)
    v_type = np.sort(v_type)
    starts = np.asarray(
        draw(st.lists(st.integers(0, 8), min_size=n_v, max_size=n_v))
    ) * BUCKET_STEP
    v_life = np.stack([starts, np.full(n_v, T)], 1).astype(np.int32)
    n_e = draw(st.integers(0, 25))
    edges = []
    for _ in range(n_e):
        s = draw(st.integers(0, n_v - 1))
        d = draw(st.integers(0, n_v - 1))
        lo = max(v_life[s, 0], v_life[d, 0])
        es = draw(st.integers(lo // BUCKET_STEP, 15)) * BUCKET_STEP
        ee = draw(st.integers(es // BUCKET_STEP + 1, 16)) * BUCKET_STEP
        edges.append((s, d, 0, es, ee))
    if edges:
        earr = np.asarray(edges, np.int64)
        e_src, e_dst = earr[:, 0].astype(np.int32), earr[:, 1].astype(np.int32)
        e_type = earr[:, 2].astype(np.int32)
        e_life = earr[:, 3:5].astype(np.int32)
    else:
        e_src = e_dst = e_type = np.zeros(0, np.int32)
        e_life = np.zeros((0, 2), np.int32)
    pvals = np.asarray(draw(st.lists(st.integers(0, 2), min_size=n_v,
                                     max_size=n_v)), np.int32)
    col = make_prop_column(n_v, np.arange(n_v), pvals,
                           np.stack([v_life[:, 0], v_life[:, 1]], 1))
    return TemporalGraph(v_type, v_life, e_src, e_dst, e_type, e_life,
                         {0: col}, {}, 2, 1, (0, T))


@settings(max_examples=25, deadline=None)
@given(g=tiny_graphs(), vt=st.integers(-1, 1), val=st.integers(0, 2),
       etr=st.sampled_from([-1, iv.FULLY_BEFORE, iv.OVERLAPS]))
def test_engine_matches_oracle_random_graphs(g, vt, val, etr):
    qry = Q.PathQuery(
        v_preds=(Q.VertexPredicate(vt, (Q.prop_clause(0, "==", val),)),
                 Q.VertexPredicate(-1),
                 Q.VertexPredicate(-1)),
        e_preds=(Q.EdgePredicate(-1, Q.DIR_OUT),
                 Q.EdgePredicate(-1, Q.DIR_OUT, etr_op=etr)),
    )
    want = RefEngine(g).count(qry)
    for split in range(3):
        got = E.count_results(g, qry, split=split)
        assert got == want, (split, got, want)


@settings(max_examples=15, deadline=None)
@given(g=tiny_graphs(), val=st.integers(0, 2))
def test_adding_clause_never_increases_count(g, val):
    base = Q.PathQuery(
        v_preds=(Q.VertexPredicate(0), Q.VertexPredicate(-1)),
        e_preds=(Q.EdgePredicate(-1, Q.DIR_OUT),),
    )
    narrowed = Q.PathQuery(
        v_preds=(Q.VertexPredicate(0, (Q.prop_clause(0, "==", val),)),
                 Q.VertexPredicate(-1)),
        e_preds=(Q.EdgePredicate(-1, Q.DIR_OUT),),
    )
    c_base = E.count_results(g, base)
    c_narrow = E.count_results(g, narrowed)
    assert c_narrow <= c_base


@settings(max_examples=15, deadline=None)
@given(g=tiny_graphs())
def test_bucket_totals_bound_static_count(g):
    """Per-bucket counts are each ≤ static count (every temporal match is a
    structural match)."""
    qry = Q.PathQuery(
        v_preds=(Q.VertexPredicate(-1), Q.VertexPredicate(-1)),
        e_preds=(Q.EdgePredicate(-1, Q.DIR_OUT),),
    )
    static = E.count_results(g, qry, mode=E.MODE_STATIC)
    out = E.execute(g, qry, mode=E.MODE_BUCKET, n_buckets=16)
    buckets = np.asarray(out.total)
    assert buckets.max(initial=0.0) <= static + 1e-6


@settings(max_examples=10, deadline=None)
@given(g=tiny_graphs())
def test_direction_reversal_symmetry(g):
    """count(A →follows B) == count(B ←follows A) with preds swapped."""
    q1 = Q.PathQuery(
        v_preds=(Q.VertexPredicate(0), Q.VertexPredicate(1)),
        e_preds=(Q.EdgePredicate(-1, Q.DIR_OUT),),
    )
    q2 = Q.PathQuery(
        v_preds=(Q.VertexPredicate(1), Q.VertexPredicate(0)),
        e_preds=(Q.EdgePredicate(-1, Q.DIR_IN),),
    )
    assert E.count_results(g, q1) == E.count_results(g, q2)
