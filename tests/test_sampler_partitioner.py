"""Neighbor sampler + two-level partitioner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graphdata.partitioner import partition_graph, reassign_on_failure
from repro.graphdata.sampler import CSR, sample_neighbors, sample_union_graph


@pytest.fixture(scope="module")
def csr():
    rng = np.random.default_rng(0)
    N, E = 500, 4000
    src = rng.integers(0, N, E)
    dst = rng.integers(0, N, E)
    return CSR.from_edge_index(src, dst, N), src, dst, N


def test_sample_neighbors_valid(csr):
    c, src, dst, N = csr
    frontier = jnp.asarray([0, 5, 10, 499], jnp.int32)
    nbr = sample_neighbors(c, frontier, 8, jax.random.PRNGKey(0))
    assert nbr.shape == (4, 8)
    nbr = np.asarray(nbr)
    indptr = np.asarray(c.indptr)
    indices = np.asarray(c.indices)
    for i, v in enumerate([0, 5, 10, 499]):
        deg = indptr[v + 1] - indptr[v]
        neigh = set(indices[indptr[v]:indptr[v + 1]]) if deg else {v}
        assert set(nbr[i]) <= neigh


def test_sample_union_graph_shapes(csr):
    c, *_ = csr
    seeds = jnp.arange(16, dtype=jnp.int32)
    gids, src_l, dst_l = sample_union_graph(c, seeds, (4, 3), jax.random.PRNGKey(1))
    assert gids.shape == (16 + 64 + 192,)
    assert src_l.shape == dst_l.shape == (64 + 192,)
    # local indices in range, dst of layer-1 edges point at seeds
    assert int(src_l.max()) < gids.shape[0]
    assert int(dst_l[:64].max()) < 16


def test_partitioner_balance_and_cut(medium_static_graph):
    g = medium_static_graph
    p = partition_graph(g, n_workers=4, parts_per_type=4)
    assert p.part_of.shape == (g.n_vertices,)
    assert p.n_parts == g.n_vertex_types * 4
    # every partition holds one vertex type only
    for pid in range(p.n_parts):
        sel = p.part_of == pid
        if sel.any():
            assert len(np.unique(g.v_type[sel])) == 1
    # round-robin placement load balance
    per_worker = np.bincount(p.worker_of_part, minlength=4)
    assert per_worker.max() - per_worker.min() <= 1
    # topo partitioning should beat hash partitioning on weighted edge cut
    ph = partition_graph(g, n_workers=4, parts_per_type=4, hash_baseline=True)
    assert p.stats["edge_cut"] <= ph.stats["edge_cut"]


def test_reassign_on_failure(medium_static_graph):
    g = medium_static_graph
    p = partition_graph(g, n_workers=4, parts_per_type=2)
    p2 = reassign_on_failure(p, failed_worker=1)
    assert not (p2.worker_of_part == 1).any()
    np.testing.assert_array_equal(p.part_of, p2.part_of)


# ------------------------------------------------- p2p exchange routing
def test_p2p_exchange_equals_global_halo_gather(medium_static_graph):
    """The point-to-point lane tables must reproduce the global
    scatter-then-halo-gather exchange exactly: for arbitrary owner-local
    state, p2p_exchange's receive buffer equals each worker's halo slice of
    the published global state — and only ghost entries ride the lanes."""
    from repro.core import superstep as SS
    from repro.graphdata.partitioner import build_partition_arrays

    g = medium_static_graph
    rng = np.random.default_rng(11)
    for w in (2, 4, 8):
        pa = build_partition_arrays(
            g, partition_graph(g, n_workers=w, parts_per_type=4))
        state_w = rng.normal(size=(w, pa.v_max)).astype(np.float32)
        # reference: publish owned rows to a global [V] view, slice halos
        glob = np.zeros(g.n_vertices + 1, np.float32)
        glob[pa.own_ids.reshape(-1)] = state_w.reshape(-1)
        want = np.zeros((w, pa.h_max), np.float32)
        for d in range(w):
            n_h = int(pa.n_halo[d])
            want[d, :n_h] = glob[pa.halo_ids[d, :n_h]]
        got = np.asarray(SS.p2p_exchange(
            jnp.asarray(state_w), jnp.asarray(pa.halo_own_slot),
            jnp.asarray(pa.xchg_send_slot), jnp.asarray(pa.xchg_recv_slot),
            pa.h_max))
        for d in range(w):
            n_h = int(pa.n_halo[d])
            assert np.array_equal(got[d, :n_h], want[d, :n_h]), (w, d)
        # ragged lane content == ghost entries (O(ghost) boundary traffic)
        assert int((pa.xchg_send_slot < pa.v_max).sum()) == \
            pa.exchange_volume() == int(pa.n_ghost.sum())
        assert int((pa.etr_send_slot < pa.s_max).sum()) == \
            pa.etr_exchange_volume() == int(pa.n_src_ghost.sum())
        # diagonal lanes are empty: self-owned entries never hit the network
        for d in range(w):
            assert (pa.xchg_send_slot[d, d] == pa.v_max).all()
            assert (pa.etr_send_slot[d, d] == pa.s_max).all()
