"""Checkpointing (atomic/hashed/async/elastic) + fault tolerance."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt
from repro.training.fault import (FaultTolerantRunner, HeartbeatMonitor,
                                  StragglerPolicy, elastic_remesh,
                                  mitigate_stragglers)


def _state():
    return dict(w=jnp.arange(12.0).reshape(3, 4), step=jnp.asarray(7),
                nested=dict(b=jnp.ones(5)))


def test_save_restore_roundtrip(tmp_path):
    s = _state()
    ckpt.save(s, 10, str(tmp_path))
    got, step = ckpt.restore(s, str(tmp_path))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(s["w"]))
    np.testing.assert_array_equal(np.asarray(got["nested"]["b"]), np.ones(5))


def test_corruption_detected(tmp_path):
    s = _state()
    path = ckpt.save(s, 1, str(tmp_path))
    # corrupt a leaf
    import glob
    f = sorted(glob.glob(os.path.join(path, "arr_*.npy")))[0]
    arr = np.load(f)
    arr = arr + 1000
    np.save(f, arr)
    with pytest.raises(IOError):
        ckpt.restore(s, str(tmp_path))


def test_gc_keeps_last(tmp_path):
    s = _state()
    for i in range(6):
        ckpt.save(s, i, str(tmp_path), keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(steps) == 2


def test_async_save(tmp_path):
    s = _state()
    t = ckpt.save_async(s, 3, str(tmp_path))
    ckpt.wait_pending()
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_elastic_restore_new_sharding(tmp_path):
    s = _state()
    ckpt.save(s, 2, str(tmp_path))
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), s)
    got, step = ckpt.restore(s, str(tmp_path), shardings=sh)
    assert step == 2
    assert got["w"].sharding == NamedSharding(mesh, P())


def test_heartbeat_failure_detection():
    mon = HeartbeatMonitor(4, timeout_s=0.05)
    now = time.time()
    mon.beat(0)
    mon.beat(1)
    mon.last_beat[2] = now - 1.0   # silent worker
    mon.kill(3)
    failed = mon.check()
    assert 2 in failed and 3 in failed and 0 not in failed


def test_straggler_mitigation():
    times = np.asarray([10.0, 11.0, 12.0, 95.0, 9.0, 10.0])
    workers = np.asarray([0, 1, 2, 3, 0, 1])
    dup = mitigate_stragglers(times, workers,
                              StragglerPolicy(slowdown_factor=3.0))
    assert 3 in dup and dup[3] != 3


def test_elastic_remesh():
    assert elastic_remesh(512, (2, 16, 16)) == (2, 16, 16)
    assert elastic_remesh(400, (2, 16, 16)) == (1, 16, 16)
    assert elastic_remesh(9, (2, 16, 16)) == (1, 1, 9)


def test_fault_tolerant_runner_recovers(tmp_path):
    """Training with injected failure reproduces the failure-free result."""
    def step_fn(state, batch):
        new = dict(x=state["x"] + batch)
        return new, dict(x=float(new["x"]))

    batches = [jnp.asarray(float(i + 1)) for i in range(25)]

    r1 = FaultTolerantRunner(step_fn, dict(x=jnp.asarray(0.0)),
                             str(tmp_path / "a"), ckpt_every=5)
    m1 = r1.run(batches)

    r2 = FaultTolerantRunner(step_fn, dict(x=jnp.asarray(0.0)),
                             str(tmp_path / "b"), ckpt_every=5)
    m2 = r2.run(batches, fail_at={7: RuntimeError("node died"),
                                  18: RuntimeError("node died again")})
    assert r2.recoveries == 2
    assert float(r1.state["x"]) == float(r2.state["x"]) == sum(range(1, 26))
