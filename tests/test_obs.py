"""Flight-recorder tests: span trees pinned on the virtual clock, the
metrics registry, the degradation-ladder scenarios per rung, and the
cost-model audit reproducing live telemetry from trace data alone.

Everything runs through the production scheduler code path with the
FakeDispatcher virtual clock (zero JAX compilation) except the
bit-identity leg and the measure_supersteps profile, which use real
dispatch on the small graph.
"""
import json
import math
import subprocess
import sys

import numpy as np
import pytest

from repro.graphdata.queries import make_workload
from repro.obs import (MetricsRegistry, NULL_TRACER, NullTracer, StepClock,
                       Tracer, load_jsonl, span_trees)
from repro.obs import audit
from repro.obs.trace import _NULL_SPAN
from repro.serving import (AdmissionPolicy, BatchScheduler, TelemetryBuffer,
                           replay_workload)
from repro.serving.testing import (FakeDispatcher, constant_service_model,
                                   planner_service_model)

pytestmark = pytest.mark.obs


def _fake_sched(graph, **kw):
    kw.setdefault("dispatcher",
                  FakeDispatcher(service_model=constant_service_model(1e-3)))
    return BatchScheduler(graph, **kw)


def _tree_names(root):
    """Depth-first (span-id order) name list of one span tree."""
    out, stack = [], [root]
    while stack:
        rec = stack.pop(0)
        out.append(rec["name"])
        stack = rec["children"] + stack
    return out


# ================================================================= tracer
def test_step_clock_and_span_tree_exact():
    """The exact span tree — ids, parents, trace ids, timestamps — is a
    deterministic test vector under an injected StepClock."""
    t = Tracer(clock=StepClock(start=10.0, step=0.5))
    root = t.start("query", template="Q1")
    a = t.start("admit", parent=root)
    t.end(a, verdict="admit")
    b = t.start("plan", parent=root)
    t.end(b)
    t.end(root, status="done")
    recs = t.records()
    # completion order: admit, plan, query
    assert [r["name"] for r in recs] == ["admit", "plan", "query"]
    assert [r["span_id"] for r in recs] == [1, 2, 0]
    assert [r["parent_id"] for r in recs] == [0, 0, None]
    assert all(r["trace_id"] == 0 for r in recs)
    assert [(r["t_start"], r["t_end"]) for r in recs] == [
        (10.5, 11.0), (11.5, 12.0), (10.0, 12.5)]
    assert recs[0]["attrs"] == {"verdict": "admit"}
    trees = span_trees(recs)
    assert list(trees) == [0]
    assert _tree_names(trees[0]) == ["query", "admit", "plan"]


def test_tracer_ring_and_jsonl_sink_identical(tmp_path):
    """The in-memory ring and the JSONL sink hold the same records, float
    for float (repr round-trip), including numpy attr normalisation."""
    p = str(tmp_path / "t.jsonl")
    t = Tracer(clock=StepClock(), sink=p)
    root = t.start("query", feats=np.array([1.5, 0.25]), n=np.int64(3),
                   flag=np.bool_(True))
    t.end(root, err=np.float64(1 / 3))
    t.close()
    ring = t.records()
    disk = load_jsonl(p)
    assert ring == disk
    assert ring[0]["attrs"] == {"feats": [1.5, 0.25], "n": 3, "flag": True,
                                "err": 1 / 3}
    # export_jsonl writes the same stream
    p2 = str(tmp_path / "t2.jsonl")
    assert t.export_jsonl(p2) == 1
    assert load_jsonl(p2) == disk


def test_tracer_ring_capacity_keeps_newest():
    t = Tracer(clock=StepClock(), capacity=3)
    for i in range(5):
        t.end(t.start(f"s{i}"))
    assert [r["name"] for r in t.records()] == ["s2", "s3", "s4"]
    assert t.n_completed == 5


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    span = NULL_TRACER.start("query", template="Q1")
    assert span is _NULL_SPAN
    assert NULL_TRACER.start("другой") is span        # singleton, no alloc
    NULL_TRACER.annotate(span, a=1)
    NULL_TRACER.end(span, b=2)
    assert NULL_TRACER.records() == []
    assert isinstance(NULL_TRACER, NullTracer)


def test_recording_tracer_ignores_null_span_parent():
    """A span parented on the null span starts a NEW trace (the scheduler
    can hand entry.span straight through without checking)."""
    t = Tracer(clock=StepClock())
    root = t.start("plan", parent=_NULL_SPAN)
    assert root.parent_id is None and root.trace_id == root.span_id
    t.end(_NULL_SPAN)                                 # no-op, not recorded
    t.annotate(_NULL_SPAN, x=1)
    assert t.records() == []


# ================================================================ metrics
def test_counter_gauge_histogram_semantics():
    mx = MetricsRegistry()
    c = mx.counter("granite_admission_total", "outcomes",
                   labelnames=("verdict", "rung"))
    c.inc(verdict="admit", rung="")
    c.inc(2, verdict="reject", rung="")
    assert c.value(verdict="admit", rung="") == 1
    assert c.value(verdict="reject", rung="") == 2
    assert c.value(verdict="degrade", rung="x") == 0
    with pytest.raises(ValueError):
        c.inc(-1, verdict="admit", rung="")
    with pytest.raises(ValueError):
        c.inc(verdict="admit")                        # missing label
    g = mx.gauge("granite_queue_depth")
    g.set(7)
    g.set(3)
    assert g.value() == 3
    h = mx.histogram("granite_dispatch_ms")
    for v in (0.05, 1.0, 1.5, 100.0, 1e9):            # 1.0 lands in le="1"
        h.observe(v)
    assert h.count() == 5 and h.sum() == pytest.approx(1e9 + 102.55)
    text = mx.to_prometheus()
    assert 'granite_admission_total{verdict="admit",rung=""} 1' in text
    assert "# TYPE granite_dispatch_ms histogram" in text
    assert 'granite_dispatch_ms_bucket{le="1"} 2' in text     # 0.05 + 1.0
    assert 'granite_dispatch_ms_bucket{le="+Inf"} 5' in text  # 1e9 overflows
    assert "granite_dispatch_ms_count 5" in text


def test_registry_memoises_and_rejects_kind_conflicts():
    mx = MetricsRegistry()
    a = mx.counter("x_total")
    assert mx.counter("x_total") is a
    assert "x_total" in mx and mx["x_total"] is a
    with pytest.raises(ValueError):
        mx.gauge("x_total")


def test_snapshot_round_trips_through_json(tmp_path):
    mx = MetricsRegistry()
    mx.counter("c_total", labelnames=("k",)).inc(k="v")
    mx.histogram("h_ms").observe(2.0)
    p = str(tmp_path / "m.json")
    mx.write(p)
    with open(p) as f:
        snap = json.load(f)
    assert snap == mx.snapshot()
    assert snap["c_total"]["series"] == {"v": 1.0}
    assert snap["h_ms"]["series"][""]["count"] == 1
    prom = str(tmp_path / "m.prom")
    mx.write(prom)
    with open(prom) as f:
        assert "# TYPE h_ms histogram" in f.read()


# ==================================================== scheduler span trees
def test_every_query_gets_one_complete_span_tree(medium_static_graph):
    """Acceptance: a replayed workload under FakeDispatcher yields exactly
    one complete span tree per submitted query — admit through exchange for
    dispatched queries, a sealed rejected root for rejects — with the
    predicted-vs-measured fields populated."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=4, seed=40) * 3
    tr = Tracer(clock=StepClock())
    probe = _fake_sched(medium_static_graph)
    sched = _fake_sched(
        medium_static_graph, tracer=tr, pad_batches=False,
        admission=AdmissionPolicy(headroom=0.5, degrade_impls=(),
                                  allow_engine_downgrade=False),
        dispatcher=FakeDispatcher(
            service_model=planner_service_model(probe._planner.coeffs)))
    c = 2e-3
    rep = replay_workload(sched, wl, rate_qps=20.0 / c, seed=41, mode="open",
                          deadline_s=4.0 * c)
    assert rep.n_rejected > 0 and rep.n_completed > 0
    trees = span_trees(tr.records())
    roots = [t for t in trees.values() if t["name"] == "query"]
    assert len(roots) == len(wl)                      # one tree per submit
    n_done = n_rej = 0
    for root in roots:
        kinds = set(_tree_names(root))
        status = root["attrs"]["status"]
        assert root["t_end"] is not None              # every root sealed
        assert any(ch["name"] == "admit" for ch in root["children"])
        if status == "rejected":
            n_rej += 1
            assert kinds == {"query", "admit"}
            continue
        n_done += 1
        assert {"admit", "plan", "compile", "dispatch", "superstep",
                "exchange"} <= kinds
        # predicted-vs-measured populated on the dispatch span
        d = [ch for ch in root["children"] if ch["name"] == "dispatch"]
        assert len(d) == 1
        a = d[0]["attrs"]
        for k in ("seq", "batch", "edf_pos", "predicted_ms", "measured_ms",
                  "group_features", "group_predicted_ms",
                  "group_measured_ms"):
            assert a.get(k) is not None, k
        assert a["predicted_ms"] > 0 and a["measured_ms"] > 0
    assert n_rej == rep.n_rejected and n_done == rep.n_completed


def test_span_tree_pinned_exactly_on_virtual_clock(medium_static_graph):
    """One query, FakeDispatcher + StepClock: the whole tree — names, ids,
    parents, start/end ticks, measured ms — is pinned exactly."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=1, seed=42)
    n_hops = len(wl[0].qry.e_preds)
    tr = Tracer(clock=StepClock())
    sched = _fake_sched(medium_static_graph, tracer=tr)
    res = sched.run(wl)
    assert res[0].ok
    recs = {r["span_id"]: r for r in tr.records()}
    # submit: root=0 (t=0), admit=1 (t=1..2); flush: plan=2 (3..4),
    # compile=3 (5..6), dispatch=4 (7..), then per hop superstep/exchange
    assert recs[0]["name"] == "query" and recs[0]["t_start"] == 0.0
    assert recs[1]["name"] == "admit"
    assert (recs[1]["parent_id"], recs[1]["t_start"], recs[1]["t_end"]) == \
        (0, 1.0, 2.0)
    assert recs[1]["attrs"]["reason"] == "no admission controller"
    assert recs[2]["name"] == "plan"
    assert (recs[2]["t_start"], recs[2]["t_end"]) == (3.0, 4.0)
    assert recs[2]["attrs"]["plan_cached"] is False
    assert recs[2]["attrs"]["candidates"]             # fresh sweep recorded
    assert recs[3]["name"] == "compile"
    assert recs[3]["attrs"]["cache"] == "hit"         # FakeDispatcher path
    assert recs[4]["name"] == "dispatch" and recs[4]["t_start"] == 7.0
    sid = 5
    for h in range(n_hops):
        ss, ex = recs[sid], recs[sid + 1]
        assert ss["name"] == "superstep" and ss["attrs"]["hop"] == h
        assert ss["parent_id"] == 4
        assert ex["name"] == "exchange" and ex["parent_id"] == ss["span_id"]
        assert (ss["t_start"], ex["t_start"], ex["t_end"], ss["t_end"]) == \
            (8.0 + 4 * h, 9.0 + 4 * h, 10.0 + 4 * h, 11.0 + 4 * h)
        sid += 2
    assert recs[4]["t_end"] == 8.0 + 4 * n_hops
    assert recs[0]["t_end"] == 9.0 + 4 * n_hops
    assert recs[0]["attrs"]["status"] == "done"
    # constant_service_model(1e-3) × batch 1 → exactly 1.0 ms, undiluted
    a = recs[4]["attrs"]
    assert a["measured_ms"] == a["group_measured_ms"] == 1.0
    assert a["batch"] == 1 and a["edf_pos"] == 0 and a["seq"] == 0
    # hop shares sum back to the query's measured time exactly
    hops = [recs[5 + 2 * h]["attrs"]["measured_ms"] for h in range(n_hops)]
    assert sum(hops) == pytest.approx(1.0)


def test_failed_group_seals_root_spans(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=2, seed=43)
    tr = Tracer(clock=StepClock())
    fd = FakeDispatcher(fail=lambda queries, engine, impl: True)
    sched = BatchScheduler(medium_static_graph, dispatcher=fd, tracer=tr)
    res = sched.run(wl)
    assert all(not r.ok for r in res)
    roots = [r for r in tr.records() if r["name"] == "query"]
    assert len(roots) == 2
    for r in roots:
        assert r["attrs"]["status"] == "failed"
        assert "injected dispatch failure" in r["attrs"]["error"]
        assert r["t_end"] is not None


def test_traced_flush_leaves_results_unchanged_fake(medium_static_graph):
    """Virtual-clock cross-check: identical ServedResults with and without
    the tracer + metrics attached (the real-dispatch leg is conformance)."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=3, seed=44)
    plain = _fake_sched(medium_static_graph).run(wl)
    traced = _fake_sched(medium_static_graph, tracer=Tracer(StepClock()),
                         metrics=MetricsRegistry()).run(wl)
    assert [(r.count, r.latency_ms, r.ok) for r in plain] == \
        [(r.count, r.latency_ms, r.ok) for r in traced]


# =========================================================== ladder rungs
def test_ladder_rung_admit_metrics_and_span(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=3, seed=45)
    mx = MetricsRegistry()
    tr = Tracer(clock=StepClock())
    sched = _fake_sched(medium_static_graph, metrics=mx, tracer=tr,
                        admission=AdmissionPolicy(headroom=1.0))
    for inst in wl:
        sched.submit(inst, deadline_s=600.0, now=0.0)
    adm = mx["granite_admission_total"]
    assert adm.value(verdict="admit", rung="") == 3
    assert mx["granite_queue_depth"].value() == 3
    sched.flush()
    assert mx["granite_queue_depth"].value() == 0
    assert mx["granite_dispatched_total"].value() == 3
    assert mx["granite_dispatch_ms"].count() == 1
    assert mx["granite_cache_total"].value(cache="plan", event="miss") == 1
    admits = [r for r in tr.records() if r["name"] == "admit"]
    assert all(r["attrs"]["verdict"] == "admit" and r["attrs"]["rungs"] == []
               for r in admits)


def test_ladder_rung_cheaper_impl(medium_static_graph):
    """Rung 1: with θ_scatter_xla inflated, the pallas lowering is strictly
    cheaper, and a deadline between the two costs degrades with exactly the
    impl rung (quantum disabled)."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=2, seed=46)
    mx = MetricsRegistry()
    tr = Tracer(clock=StepClock())
    pol = AdmissionPolicy(headroom=1.0, degrade_impls=("pallas",),
                          allow_engine_downgrade=False,
                          degrade_max_batch=None)
    fd = FakeDispatcher()
    sched = BatchScheduler(medium_static_graph, dispatcher=fd, metrics=mx,
                           tracer=tr, admission=pol)
    sched._planner.coeffs["theta_scatter_xla"] = 10.0
    qry = wl[0].qry
    split = qry.n_vertices - 1
    c_xla = sched._planner.estimate(qry, split, "xla").t_ms / 1e3
    c_pal = sched._planner.estimate(qry, split, "pallas").t_ms / 1e3
    assert c_pal < c_xla
    decs = []
    for inst in wl:
        sched.admission.on_flush()
        decs.append(sched.submit(inst, deadline_s=0.9 * c_xla, now=0.0))
    assert all(d.action == "degrade" and d.rungs == ("impl=pallas",)
               for d in decs)
    adm = mx["granite_admission_total"]
    assert adm.value(verdict="degrade", rung="impl=pallas") == 2
    assert adm.value(verdict="admit", rung="") == 0
    res = sched.flush()
    assert all(r.ok for r in res)
    assert all(c.impl == "pallas" for c in fd.calls)
    admits = [r for r in tr.records() if r["name"] == "admit"]
    assert all(r["attrs"]["verdict"] == "degrade"
               and r["attrs"]["rungs"] == ["impl=pallas"] for r in admits)
    disp = [r for r in tr.records() if r["name"] == "dispatch"]
    assert all(r["attrs"]["impl"] == "pallas" for r in disp)


def test_ladder_rung_engine_downgrade_with_quantum(medium_static_graph):
    """Rungs 2+3: dense→sliced with a bounded dispatch quantum — exact
    counter increments under the compound rung label, chunk sizes capped,
    and the rungs annotated on every admit span."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=5, seed=47)
    mx = MetricsRegistry()
    tr = Tracer(clock=StepClock())
    fd = FakeDispatcher()
    sched = BatchScheduler(medium_static_graph, engine="dense",
                           dispatcher=fd, metrics=mx, tracer=tr)
    from repro.serving import AdmissionController
    probe_cost = sched._planner.estimate(
        wl[0].qry, wl[0].qry.n_vertices - 1, "xla").t_ms / 1e3
    sched.admission = AdmissionController(AdmissionPolicy(
        headroom=1.0, degrade_impls=(), allow_engine_downgrade=True,
        sliced_discount=0.5, degrade_max_batch=2))
    decs = []
    for inst in wl:
        sched.admission.on_flush()
        decs.append(sched.submit(inst, deadline_s=0.75 * probe_cost,
                                 now=0.0))
    assert all(d.action == "degrade" for d in decs)
    assert all(d.rungs == ("engine=sliced", "quantum=2") for d in decs)
    adm = mx["granite_admission_total"]
    assert adm.value(verdict="degrade", rung="engine=sliced,quantum=2") == 5
    res = sched.flush()
    assert all(r.ok for r in res)
    assert all(c.engine == "sliced" and c.n_real <= 2 for c in fd.calls)
    assert mx["granite_dispatch_ms"].count() == len(fd.calls) == 3
    assert mx["granite_dispatched_total"].value() == 5
    admits = [r for r in tr.records() if r["name"] == "admit"]
    assert all(r["attrs"]["rungs"] == ["engine=sliced", "quantum=2"]
               for r in admits)
    # EDF positions recorded per chunk
    disp = [r for r in tr.records() if r["name"] == "dispatch"]
    assert sorted({r["attrs"]["edf_pos"] for r in disp}) == [0, 1, 2]


def test_ladder_rung_reject(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=2, seed=48)
    mx = MetricsRegistry()
    tr = Tracer(clock=StepClock())
    sched = _fake_sched(medium_static_graph, metrics=mx, tracer=tr,
                        admission=AdmissionPolicy(
                            headroom=1.0, degrade_impls=(),
                            allow_engine_downgrade=False))
    for inst in wl:
        dec = sched.submit(inst, deadline_s=0.0, now=0.0)
        assert dec.action == "reject"
    assert mx["granite_admission_total"].value(verdict="reject", rung="") == 2
    assert sched.queued == 0
    roots = [r for r in tr.records() if r["name"] == "query"]
    assert len(roots) == 2
    assert all(r["attrs"]["status"] == "rejected" for r in roots)
    admits = [r for r in tr.records() if r["name"] == "admit"]
    assert all(r["attrs"]["verdict"] == "reject"
               and "exceeds" in r["attrs"]["reason"] for r in admits)


def test_refit_and_invalidation_counters(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=4, seed=49)
    mx = MetricsRegistry()
    tb = TelemetryBuffer(refit_every=3, min_samples=3, blend=1.0)
    sched = BatchScheduler(
        medium_static_graph, telemetry=tb, metrics=mx,
        dispatcher=FakeDispatcher(service_model=planner_service_model(
            {k: 2.0 * v for k, v in
             BatchScheduler(medium_static_graph)._planner.coeffs.items()})))
    for _ in range(3):
        sched.run(wl)
    assert tb.n_refits == 1
    assert mx["granite_refit_total"].value() == 1
    assert mx["granite_cache_total"].value(cache="plan",
                                          event="invalidation") == 1
    assert sched.plan_cache.stats.invalidations == 1


def test_replay_metrics(medium_static_graph):
    """The replay harness mirrors its terminal accounting into the registry:
    per-status counters, goodput gauge, deadline-slack histogram."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=4, seed=50)
    mx = MetricsRegistry()
    sched = _fake_sched(medium_static_graph, metrics=mx,
                        dispatcher=FakeDispatcher(
                            service_model=constant_service_model(
                                0.0, overhead_s=0.05)))
    rep = replay_workload(sched, wl, mode="closed", max_outstanding=4,
                          deadline_s=0.08)
    st = mx["granite_replay_total"]
    assert st.value(status="done") == rep.n_completed == 4
    assert st.value(status="rejected") == 0
    assert mx["granite_goodput_qps"].value() == pytest.approx(
        rep.goodput_qps)
    assert mx["granite_deadline_slack_ms"].count() == rep.n_completed


# ================================================================== audit
def _traced_refit_run(graph, wl, refit, sink):
    tb = TelemetryBuffer(refit_every=4, min_samples=4, blend=1.0,
                         refit=refit)
    tr = Tracer(clock=StepClock(), sink=sink)
    sched = BatchScheduler(
        graph, telemetry=tb, tracer=tr,
        dispatcher=FakeDispatcher(service_model=planner_service_model(
            {k: 3.0 * v for k, v in
             BatchScheduler(graph)._planner.coeffs.items()})))
    for _ in range(8):
        for inst in wl:
            sched.submit(inst)
        assert all(r.ok for r in sched.flush())
    tr.close()
    return tb, tr


def test_audit_reproduces_live_telemetry_exactly(medium_static_graph,
                                                 tmp_path):
    """The acceptance property: obs/audit reproduces the refit-error
    improvement pinned in test_serving_slo.py from trace data ALONE —
    error stats equal to the live TelemetryBuffer float for float, from the
    ring and from the JSONL file alike."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=4, seed=11)
    p_on = str(tmp_path / "online.jsonl")
    p_off = str(tmp_path / "static.jsonl")
    tb_on, tr_on = _traced_refit_run(medium_static_graph, wl, True, p_on)
    tb_off, tr_off = _traced_refit_run(medium_static_graph, wl, False, p_off)
    for tb, tr, path in ((tb_on, tr_on, p_on), (tb_off, tr_off, p_off)):
        live = tb.error_stats(tail=4)
        for src in (tr, path, load_jsonl(path)):
            rep = audit.error_report(src, tail=4)
            assert rep["n"] == live["n"] == 16
            # float-for-float: repr round-trip through the JSONL sink
            assert rep["mean_abs_rel_err"] == live["mean_abs_rel_err"]
            assert rep["p90_abs_rel_err"] == live["p90_abs_rel_err"]
            assert rep["tail_mean_abs_rel_err"] == \
                live["tail_mean_abs_rel_err"]
    # the pinned improvement, reproduced offline: θ* = 3θ → static error
    # 2/3; the online refit drives it under 0.05
    e_off = audit.error_report(p_off, tail=4)["tail_mean_abs_rel_err"]
    e_on = audit.error_report(p_on, tail=4)["tail_mean_abs_rel_err"]
    assert e_off == pytest.approx(2 / 3, rel=1e-3)
    assert e_on < 0.05 and e_on < 0.2 * e_off


def test_audit_dispatch_records_dedupe_by_seq(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=3, seed=51)
    tr = Tracer(clock=StepClock())
    sched = _fake_sched(medium_static_graph, tracer=tr)
    sched.run(wl)
    rows = audit.dispatch_records(tr)
    assert len(rows) == len(sched.last_dispatches) == 2
    assert [r["seq"] for r in rows] == [0, 1]
    # 6 member dispatch spans collapse to 2 group rows
    assert len(audit.spans_named(tr, "dispatch")) == 6
    for row, d in zip(rows, sorted(sched.last_dispatches,
                                   key=lambda d: d.predicted_ms == 0)):
        assert row["batch"] == d.n_real


def test_audit_drift_flags_perturbed_coefficient(medium_static_graph):
    """Feed service times from θ* = 3θ and the trace-refit θ̂ must drift
    toward θ* on the exercised columns."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=4, seed=52)
    tr = Tracer(clock=StepClock())
    base = dict(BatchScheduler(medium_static_graph)._planner.coeffs)
    sched = BatchScheduler(
        medium_static_graph, tracer=tr,
        telemetry=TelemetryBuffer(refit=False),
        dispatcher=FakeDispatcher(service_model=planner_service_model(
            {k: 3.0 * v for k, v in base.items()})))
    for _ in range(4):
        sched.run(wl)
    drift = audit.coefficient_drift(tr, coeffs=base)
    moved = {k: v for k, v in drift.items() if v["abs_delta"] > 0}
    assert moved, "no coefficient drifted"
    fitted = audit.refit_from_trace(tr, coeffs=base)
    rows = audit.dispatch_records(tr)
    X = np.stack([np.asarray(r["group_features"]) for r in rows])
    y = np.asarray([r["group_measured_ms"] for r in rows])
    from repro.core.planner import coeff_vector
    pred = X @ coeff_vector(fitted)
    # θ̂ explains the measured times far better than the incumbent
    err_hat = np.abs(pred - y) / y
    err_inc = np.abs(X @ coeff_vector(base) - y) / y
    assert err_hat.mean() < 0.1 * err_inc.mean()


def test_audit_plan_accuracy_from_consistent_trace(medium_static_graph):
    """Service times ARE the planner's own model (θ* = θ): every chosen plan
    is optimal under the trace-refit θ̂, so the paper's within-X% metric
    must come out at 1.0."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=3, seed=53)
    tr = Tracer(clock=StepClock())
    base = dict(BatchScheduler(medium_static_graph)._planner.coeffs)
    sched = BatchScheduler(
        medium_static_graph, tracer=tr,
        dispatcher=FakeDispatcher(
            service_model=planner_service_model(base)))
    sched.run(wl)
    acc = audit.plan_accuracy(tr, within=0.10, coeffs=base)
    assert acc["n_decisions"] == 2
    assert acc["n_queries"] == len(wl)
    assert acc["frac_within"] == 1.0
    # the trace-refit θ̂ comes from 2 dispatch rows (under-determined
    # least squares), so candidate re-costing reproduces the ranking but
    # not the planner's t_ms bit-for-bit
    assert acc["mean_ratio"] == pytest.approx(1.0, abs=0.05)
    rep = audit.audit_report(tr, coeffs=base)
    assert rep["n_dispatches"] == 2
    assert rep["plan"]["frac_within"] == 1.0
    # θ* = θ → the replayed prediction error is numerically zero
    assert rep["error"]["n"] == 2
    assert rep["error"]["mean_abs_rel_err"] < 1e-6


def test_query_summaries_rollup(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=2, seed=54)
    tr = Tracer(clock=StepClock())
    sched = _fake_sched(medium_static_graph, tracer=tr,
                        admission=AdmissionPolicy(headroom=1.0))
    for inst in wl:
        sched.submit(inst, deadline_s=600.0, now=0.0)
    sched.flush()
    rows = audit.query_summaries(tr)
    assert len(rows) == 2
    for row in rows:
        assert row["template"] == "Q2" and row["status"] == "done"
        assert row["verdict"] == "admit" and row["seq"] == 0
        assert row["predicted_ms"] > 0 and row["measured_ms"] > 0


# ==================================================== measure_supersteps
def test_measure_supersteps_traced_exchange_channels(small_static_graph):
    """The profiler's span tree reports per-hop exchange rows matching the
    canonical hop_exchange_channels rule (and their sum,
    query_exchange_volumes)."""
    from repro.core import engine_partitioned as EP

    wl = make_workload(small_static_graph, templates=("Q2",),
                       n_per_template=1, seed=55)
    qry = wl[0].qry
    tr = Tracer(clock=StepClock())
    prof = EP.measure_supersteps(small_static_graph, qry, n_workers=2,
                                 repeats=1, tracer=tr)
    _, arrays, _ = EP.partition_for(small_static_graph, 2)
    want_rows = EP.hop_exchange_channels(qry, arrays)
    trees = span_trees(tr.records())
    assert len(trees) == 1
    root = next(iter(trees.values()))
    assert root["name"] == "measure_supersteps"
    assert root["attrs"]["n_workers"] == 2
    sss = [c for c in root["children"] if c["name"] == "superstep"]
    assert len(sss) == len(want_rows) == len(qry.e_preds)
    got_total = dict(state=0, extremum=0, etr=0)
    for h, ss in enumerate(sss):
        assert ss["attrs"]["hop"] == h
        assert ss["attrs"]["measured_ms"] > 0
        assert len(ss["attrs"]["per_worker_ms"]) == 2
        ex = [c for c in ss["children"] if c["name"] == "exchange"]
        assert len(ex) == 1
        a = ex[0]["attrs"]
        assert {k: a[k] for k in ("state", "extremum", "etr")} == \
            want_rows[h]
        for k in got_total:
            got_total[k] += a[k]
    assert got_total == EP.query_exchange_volumes(qry, arrays)
    assert prof is not None


# =========================================================== trace_report
def test_trace_report_cli_smoke(medium_static_graph, tmp_path):
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=2, seed=56)
    p = str(tmp_path / "trace.jsonl")
    tr = Tracer(clock=StepClock(), sink=p)
    sched = _fake_sched(medium_static_graph, tracer=tr,
                        telemetry=TelemetryBuffer(refit=False),
                        admission=AdmissionPolicy(headroom=1.0))
    for inst in wl:
        sched.submit(inst, deadline_s=600.0, now=0.0)
    sched.flush()
    tr.close()
    import os
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, os.path.join(repo, "scripts", "trace_report.py"),
         p, "--limit", "1", "--audit"],
        capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "workload rollup" in out.stdout
    assert "queries: 4" in out.stdout
    assert "cost-model audit" in out.stdout
    assert "frac_within" in out.stdout


# ============================================= conformance: bit identity
@pytest.mark.conformance
def test_traced_results_bit_identical_real_dispatch(small_static_graph):
    """Real dispatch: results with the flight recorder attached are
    bit-identical to the untraced scheduler's, across engines."""
    wl = make_workload(small_static_graph, templates=("Q2", "Q4"),
                       n_per_template=2, seed=57)
    for engine in ("auto", "dense"):
        plain = BatchScheduler(small_static_graph, engine=engine,
                               keep_outputs=True).run(wl, warm=True)
        tr = Tracer(clock=StepClock())
        traced = BatchScheduler(small_static_graph, engine=engine,
                                keep_outputs=True, tracer=tr,
                                metrics=MetricsRegistry()).run(wl, warm=True)
        for a, b in zip(plain, traced):
            assert a.ok and b.ok
            assert np.array_equal(a.total, b.total)
        roots = [r for r in tr.records() if r["name"] == "query"]
        assert len(roots) == len(wl)
