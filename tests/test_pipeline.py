"""GPipe pipeline schedule ≡ sequential layer application."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.pipeline import PipelineCfg, make_pipelined_forward


def _layer_fn(lp, x):
    return jnp.tanh(x @ lp["w"] + lp["b"])


def test_pipeline_matches_sequential_one_stage():
    """n_stages=1 on the single CPU device: schedule must equal plain scan."""
    mesh = jax.make_mesh((1,), ("data",))
    L, D = 4, 8
    key = jax.random.PRNGKey(0)
    params = dict(
        w=jax.random.normal(key, (L, D, D)) * 0.3,
        b=jnp.zeros((L, D)),
    )
    n_micro = 3
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, 2, D))

    cfg = PipelineCfg(axis="data", n_microbatches=n_micro)
    with mesh:
        fwd = make_pipelined_forward(_layer_fn, 1, cfg, mesh)
        got = fwd(params, x)

    def seq(xm):
        h = xm
        for i in range(L):
            h = _layer_fn(dict(w=params["w"][i], b=params["b"][i]), h)
        return h

    want = jnp.stack([seq(x[m]) for m in range(n_micro)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_pipeline_bubble_math():
    """Schedule length and bubble fraction (documentation invariant)."""
    for n_stages, n_micro in [(4, 8), (16, 32)]:
        ticks = n_micro + n_stages - 1
        bubble = (n_stages - 1) / ticks
        assert ticks > n_micro and bubble < 0.5


def test_pipeline_lowers_multi_stage():
    """Multi-stage schedule lowers/compiles on a 4-way host mesh via the
    dry-run device override (structure check; numerics need >1 real dev)."""
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import sys
sys.path.insert(0, "src")
from repro.training.pipeline import PipelineCfg, make_pipelined_forward

def layer_fn(lp, x):
    return jnp.tanh(x @ lp["w"] + lp["b"])

mesh = jax.make_mesh((4,), ("data",))
L, D, n_micro = 8, 16, 6
params = dict(w=jnp.zeros((L, D, D)), b=jnp.zeros((L, D)))
x = jnp.zeros((n_micro, 2, D))
cfg = PipelineCfg(axis="data", n_microbatches=n_micro)
with mesh:
    fwd = make_pipelined_forward(layer_fn, 4, cfg, mesh)
    lowered = jax.jit(fwd).lower(params, x)
    compiled = lowered.compile()
    txt = compiled.as_text()
assert "collective-permute" in txt, "pipeline must move activations via ppermute"
print("PIPELINE_LOWER_OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PIPELINE_LOWER_OK" in out.stdout, out.stderr[-2000:]


import os  # noqa: E402  (used in the subprocess test above)
