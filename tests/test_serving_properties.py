"""Property tests for the batch scheduler's flush invariants (hypothesis).

Pinned properties, over arbitrary workload mixes and submission orders:

  * flush returns results in SUBMISSION order, and every query gets ITS OWN
    answer back — grouping, EDF reordering, and chunking never permute or
    alias results (FakeDispatcher's per-query fake counts make aliasing
    detectable);
  * grouping is invariant to submission permutation within a shape bucket:
    the same multiset of dispatch batch sizes, the same per-group members;
  * with deadlines attached, dispatches leave in earliest-deadline-first
    order regardless of submission order.

The seeded (non-hypothesis) versions of these properties run unconditionally
in tests/test_serving_slo.py; this module deepens them when the optional dep
is installed.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep "
    "(pip install hypothesis)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.graphdata.queries import make_workload  # noqa: E402
from repro.serving import BatchScheduler  # noqa: E402
from repro.serving.testing import FakeDispatcher, fake_count  # noqa: E402

pytestmark = pytest.mark.serving

TEMPLATES = ("Q1", "Q2", "Q4")
POOL_PER_TEMPLATE = 4


def _pool(graph):
    return {t: make_workload(graph, templates=(t,),
                             n_per_template=POOL_PER_TEMPLATE, seed=101)
            for t in TEMPLATES}


@st.composite
def workload_and_order(draw):
    """(picks, permutation): which pool instances to serve, in what order."""
    picks = draw(st.lists(
        st.tuples(st.sampled_from(TEMPLATES),
                  st.integers(0, POOL_PER_TEMPLATE - 1)),
        min_size=1, max_size=10))
    perm = draw(st.permutations(range(len(picks))))
    return picks, perm


@settings(max_examples=40, deadline=None)
@given(wo=workload_and_order())
def test_flush_submission_order_and_own_answers(medium_static_graph, wo):
    pool = _pool(medium_static_graph)
    picks, perm = wo
    wl = [pool[t][i] for t, i in picks]
    submitted = [wl[i] for i in perm]
    res = BatchScheduler(medium_static_graph,
                         dispatcher=FakeDispatcher()).run(submitted)
    assert len(res) == len(submitted)
    for inst, r in zip(submitted, res):
        assert r.count == fake_count(inst.qry)
        assert r.ok and r.error == ""


@settings(max_examples=40, deadline=None)
@given(wo=workload_and_order())
def test_grouping_invariant_under_permutation(medium_static_graph, wo):
    """Any permutation of the same multiset of queries produces the same
    multiset of (engine, batch size) dispatches — and each dispatch carries
    exactly the queries of one shape bucket."""
    pool = _pool(medium_static_graph)
    picks, perm = wo
    wl = [pool[t][i] for t, i in picks]

    def dispatch_profile(order):
        fd = FakeDispatcher()
        sched = BatchScheduler(medium_static_graph, dispatcher=fd)
        sched.run(order)
        return sorted((c.engine, c.n_real,
                       tuple(sorted(fake_count(q) for q in c.queries)))
                      for c in fd.calls)

    assert dispatch_profile(wl) == dispatch_profile([wl[i] for i in perm])


@settings(max_examples=25, deadline=None)
@given(deadlines=st.lists(st.floats(0.1, 100.0), min_size=2, max_size=6),
       data=st.data())
def test_edf_dispatch_order_property(medium_static_graph, deadlines, data):
    """Whatever deadlines the queries carry and whatever order they arrive,
    dispatches leave in nondecreasing group-deadline order."""
    pool = _pool(medium_static_graph)
    picks = data.draw(st.lists(
        st.tuples(st.sampled_from(TEMPLATES),
                  st.integers(0, POOL_PER_TEMPLATE - 1)),
        min_size=len(deadlines), max_size=len(deadlines)))
    sched = BatchScheduler(medium_static_graph, dispatcher=FakeDispatcher())
    for (t, i), dl in zip(picks, deadlines):
        sched.submit(pool[t][i], deadline_s=dl, now=0.0)
    res = sched.flush()
    assert len(res) == len(deadlines)
    disp_deadlines = [d.deadline for d in sched.last_dispatches]
    assert disp_deadlines == sorted(disp_deadlines)
