"""Engine vs exact oracle: all modes, all split plans, ETR ops, aggregates."""
import numpy as np
import pytest

from repro.core import engine as E
from repro.core import intervals as iv
from repro.core import query as Q
from repro.core.ref_engine import RefEngine
from repro.graphdata.queries import make_workload


def _schema(g):
    b = g.meta["builder"]
    return b.v_type_ids, b.e_type_ids, b.key_ids, b


@pytest.fixture(scope="module")
def oracle_static(small_static_graph):
    return RefEngine(small_static_graph)


@pytest.fixture(scope="module")
def oracle_dynamic(small_dynamic_graph):
    return RefEngine(small_dynamic_graph)


def test_workload_counts_all_splits(small_static_graph, oracle_static):
    wl = make_workload(small_static_graph, n_per_template=2, seed=1)
    for inst in wl:
        want = oracle_static.count(inst.qry, mode=E.MODE_STATIC)
        for split in range(inst.qry.n_vertices):
            got = E.count_results(small_static_graph, inst.qry, split=split)
            assert got == want, (inst.template, split)


@pytest.mark.parametrize("etr_op", [iv.FULLY_BEFORE, iv.STARTS_BEFORE,
                                    iv.FULLY_AFTER, iv.STARTS_AFTER, iv.OVERLAPS])
def test_etr_ops_exact(small_static_graph, oracle_static, etr_op):
    vt, et, k, b = _schema(small_static_graph)
    qry = Q.PathQuery(
        v_preds=(Q.VertexPredicate(vt["person"]),
                 Q.VertexPredicate(vt["person"]),
                 Q.VertexPredicate(vt["person"])),
        e_preds=(Q.EdgePredicate(et["follows"], Q.DIR_OUT),
                 Q.EdgePredicate(et["follows"], Q.DIR_OUT, etr_op=etr_op)),
    )
    want = oracle_static.count(qry)
    for split in range(3):
        got = E.count_results(small_static_graph, qry, split=split)
        assert got == want, (etr_op, split)


@pytest.mark.parametrize("direction", [Q.DIR_OUT, Q.DIR_IN, Q.DIR_BOTH])
def test_directions(small_static_graph, oracle_static, direction):
    vt, et, k, b = _schema(small_static_graph)
    qry = Q.PathQuery(
        v_preds=(Q.VertexPredicate(vt["person"]), Q.VertexPredicate(-1)),
        e_preds=(Q.EdgePredicate(-1, direction),),
    )
    want = oracle_static.count(qry)
    got = E.count_results(small_static_graph, qry)
    assert got == want


def test_or_clauses_and_neq(small_static_graph, oracle_static):
    vt, et, k, b = _schema(small_static_graph)
    c1 = b.lookup_value(k["country"], "uk")
    c2 = b.lookup_value(k["country"], "india")
    qry = Q.PathQuery(
        v_preds=(
            Q.VertexPredicate(vt["person"],
                              (Q.prop_clause(k["country"], "==", c1),
                               Q.prop_clause(k["country"], "==", c2, conj=Q.OR))),
            Q.VertexPredicate(vt["person"],
                              (Q.prop_clause(k["country"], "!=", c1),)),
        ),
        e_preds=(Q.EdgePredicate(et["follows"], Q.DIR_OUT),),
    )
    want = oracle_static.count(qry)
    got = E.count_results(small_static_graph, qry)
    assert got == want and want > 0


def test_time_clauses(small_static_graph, oracle_static):
    vt, et, k, b = _schema(small_static_graph)
    for cmp_name in ("overlaps", ">", "<", "in"):
        qry = Q.PathQuery(
            v_preds=(Q.VertexPredicate(vt["post"],
                                       (Q.time_clause(cmp_name, (300, 800)),)),
                     Q.VertexPredicate(vt["person"])),
            e_preds=(Q.EdgePredicate(et["created"], Q.DIR_IN),),
        )
        want = oracle_static.count(qry)
        got = E.count_results(small_static_graph, qry)
        assert got == want, cmp_name


def test_bucket_mode_exact(small_dynamic_graph, oracle_dynamic):
    wl = make_workload(small_dynamic_graph, templates=("Q2", "Q8"),
                       n_per_template=2, seed=2)
    for inst in wl:
        want = oracle_dynamic.count(inst.qry, mode=E.MODE_BUCKET, n_buckets=16)
        out = E.execute(small_dynamic_graph, inst.qry, mode=E.MODE_BUCKET,
                        n_buckets=16)
        np.testing.assert_allclose(np.asarray(out.total), want, atol=1e-4)


def test_interval_mode_distinct_counts(small_dynamic_graph, oracle_dynamic):
    vt, et, k, b = _schema(small_dynamic_graph)
    w = b.lookup_value(k["worksAt"], "company1")
    qry = Q.PathQuery(
        v_preds=(Q.VertexPredicate(vt["person"],
                                   (Q.prop_clause(k["worksAt"], "==", w),)),
                 Q.VertexPredicate(vt["person"])),
        e_preds=(Q.EdgePredicate(et["follows"], Q.DIR_OUT),),
    )
    want = oracle_dynamic.count(qry, mode=E.MODE_INTERVAL, n_buckets=16)
    for split in range(2):
        got = E.count_results(small_dynamic_graph, qry, split=split,
                              mode=E.MODE_INTERVAL, n_buckets=16)
        assert got == want


def test_aggregate_count_static(small_static_graph, oracle_static):
    vt, et, k, b = _schema(small_static_graph)
    qry = Q.PathQuery(
        v_preds=(Q.VertexPredicate(vt["person"]), Q.VertexPredicate(vt["post"])),
        e_preds=(Q.EdgePredicate(et["likes"], Q.DIR_OUT),),
        agg_op=Q.AGG_COUNT,
    )
    want = oracle_static.aggregate(qry)
    out = E.execute(small_static_graph, qry)
    pv = np.asarray(out.per_vertex)
    got = {i: float(pv[i]) for i in np.nonzero(pv)[0]}
    assert got == want


def test_aggregate_minmax(small_static_graph, oracle_static):
    vt, et, k, b = _schema(small_static_graph)
    for op in (Q.AGG_MIN, Q.AGG_MAX):
        qry = Q.PathQuery(
            v_preds=(Q.VertexPredicate(vt["person"]),
                     Q.VertexPredicate(vt["post"])),
            e_preds=(Q.EdgePredicate(et["created"], Q.DIR_OUT),),
            agg_op=op, agg_key=k["length"],
        )
        want = oracle_static.aggregate(qry)
        out = E.execute(small_static_graph, qry)
        pv = np.asarray(out.per_vertex)
        mm = np.asarray(out.minmax)
        got = {i: float(mm[i]) for i in np.nonzero(pv)[0]}
        assert got == want, op


def test_aggregate_bucket_timeseries(small_dynamic_graph, oracle_dynamic):
    vt, et, k, b = _schema(small_dynamic_graph)
    qry = Q.PathQuery(
        v_preds=(Q.VertexPredicate(vt["person"]), Q.VertexPredicate(vt["person"])),
        e_preds=(Q.EdgePredicate(et["follows"], Q.DIR_OUT),),
        agg_op=Q.AGG_COUNT,
    )
    want = oracle_dynamic.aggregate(qry, mode=E.MODE_BUCKET, n_buckets=16)
    out = E.execute(small_dynamic_graph, qry, mode=E.MODE_BUCKET, n_buckets=16)
    np.testing.assert_allclose(np.asarray(out.per_vertex), want, atol=1e-4)


def test_single_vertex_query(small_static_graph, oracle_static):
    vt, _, k, b = _schema(small_static_graph)
    cty = b.lookup_value(k["country"], "us")
    qry = Q.PathQuery(
        v_preds=(Q.VertexPredicate(vt["person"],
                                   (Q.prop_clause(k["country"], "==", cty),)),),
        e_preds=(),
    )
    want = oracle_static.count(qry)
    got = E.count_results(small_static_graph, qry, split=0)
    assert got == want and want > 0


def test_etr_validation():
    with pytest.raises(ValueError):
        Q.PathQuery(
            v_preds=(Q.VertexPredicate(0), Q.VertexPredicate(0)),
            e_preds=(Q.EdgePredicate(0, etr_op=iv.OVERLAPS),),
        )
