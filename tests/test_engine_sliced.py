"""Type-sliced engine (§Perf path) ≡ dense engine ≡ oracle.

Equivalence tests are thin wrappers over the shared four-way differential
harness in ``conformance.py``."""
import numpy as np
import pytest

import conformance as C
from repro.core import engine as E
from repro.core import engine_sliced as ES
from repro.core.ref_engine import RefEngine
from repro.graphdata.queries import make_workload


def test_slice_bounds(small_static_graph):
    sb = ES.SliceBounds.from_graph(small_static_graph)
    g = small_static_graph
    assert sb.v[-1][1] == g.n_vertices
    assert sb.e[-1][1] == 2 * g.n_edges
    # edge slices are exactly the arrivals of the vertex slices
    ptr = g.traversal["arr_ptr"]
    for (vlo, vhi), (elo, ehi) in zip(sb.v, sb.e):
        assert elo == ptr[vlo] and ehi == ptr[vhi]


def test_sliced_equals_dense_all_templates(small_static_graph):
    ref = RefEngine(small_static_graph)
    wl = make_workload(small_static_graph, n_per_template=2, seed=33)
    n = 0
    for inst in wl:
        if not ES.sliceable(inst.qry):
            continue
        want = ref.count(inst.qry)
        for split in range(inst.qry.n_vertices):
            legs = C.engine_results(small_static_graph, inst.qry,
                                    E.MODE_STATIC, workers=(), split=split)
            C.assert_engines_identical(legs, (inst.template, split))
            assert float(legs["dense"]["total"]) == want, (inst.template, split)
        n += 1
    assert n >= 10


def test_sliced_bucket_and_aggregate(small_dynamic_graph):
    ref = RefEngine(small_dynamic_graph)
    wl = make_workload(small_dynamic_graph, templates=("Q2", "Q8"),
                       n_per_template=2, seed=34)
    for inst in wl:
        want = ref.count(inst.qry, mode=E.MODE_BUCKET, n_buckets=16)
        out = E.execute(small_dynamic_graph, inst.qry, mode=E.MODE_BUCKET,
                        n_buckets=16, sliced=True)
        np.testing.assert_allclose(np.asarray(out.total), want, atol=1e-4)
    wla = make_workload(small_dynamic_graph, templates=("Q2",), n_per_template=1,
                        seed=35, aggregate=True)
    for inst in wla:
        want = ref.aggregate(inst.qry, mode=E.MODE_BUCKET, n_buckets=16)
        out = E.execute(small_dynamic_graph, inst.qry, mode=E.MODE_BUCKET,
                        n_buckets=16, sliced=True)
        np.testing.assert_allclose(np.asarray(out.per_vertex), want, atol=1e-4)


def test_wildcard_type_not_sliceable():
    from repro.core import query as Q

    q = Q.PathQuery(
        v_preds=(Q.VertexPredicate(-1), Q.VertexPredicate(0)),
        e_preds=(Q.EdgePredicate(0),),
    )
    assert not ES.sliceable(q)
    with pytest.raises(ValueError):
        # explicit sliced=True on an unsliceable query must fail loudly
        from repro.graphdata.ldbc import LdbcParams, generate_ldbc
        g = generate_ldbc(LdbcParams(n_persons=10, seed=0))
        E.execute(g, q, sliced=True)


def test_gqa_native_equivalence():
    """Optimised GQA paths (decode + chunked train) match the baseline."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs.gemma3_4b import SMOKE
    from repro.models import transformer as tr

    base = SMOKE
    opt = dataclasses.replace(SMOKE, gqa_native=True)
    p = tr.init_params(base, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, base.vocab)
    f_b = tr.forward(base, p, toks)
    f_o = tr.forward(opt, p, toks)
    np.testing.assert_allclose(np.asarray(f_b), np.asarray(f_o), atol=2e-5)
    cache_b = tr.init_cache(base, 2, 24)
    cache_o = tr.init_cache(opt, 2, 24)
    for t in range(4):
        lb, cache_b = tr.decode_step(base, p, cache_b, toks[:, t], t + 1)
        lo, cache_o = tr.decode_step(opt, p, cache_o, toks[:, t], t + 1)
        np.testing.assert_allclose(np.asarray(lb), np.asarray(lo), atol=2e-5)
