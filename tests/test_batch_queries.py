"""Batched same-template query execution (beyond-paper serving mode)."""
import time

import numpy as np
import pytest

from repro.core import engine as E
from repro.core.ref_engine import RefEngine
from repro.graphdata.queries import make_workload


def test_batch_matches_single(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2",), n_per_template=8,
                       seed=40)
    qs = [inst.qry for inst in wl]
    batch = E.execute_batch(medium_static_graph, qs)
    assert batch.shape == (8,)
    for q, got in zip(qs, batch):
        want = E.count_results(medium_static_graph, q)
        assert float(got) == want


def test_batch_rejects_mixed_templates(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=1, seed=41)
    with pytest.raises(ValueError):
        E.execute_batch(medium_static_graph, [wl[0].qry, wl[1].qry])


def test_batch_throughput_wins(medium_static_graph):
    """Amortised per-query time in a batch must beat sequential execution."""
    wl = make_workload(medium_static_graph, templates=("Q4",), n_per_template=16,
                       seed=42)
    qs = [inst.qry for inst in wl]
    E.execute_batch(medium_static_graph, qs)            # compile
    for q in qs[:2]:
        E.count_results(medium_static_graph, q)          # compile single
    t0 = time.perf_counter()
    E.execute_batch(medium_static_graph, qs)
    t_batch = time.perf_counter() - t0
    t0 = time.perf_counter()
    for q in qs[:4]:
        E.count_results(medium_static_graph, q)
    t_seq = (time.perf_counter() - t0) * (len(qs) / 4)
    assert t_batch < t_seq, (t_batch, t_seq)


def test_server_scheduled_mode(medium_static_graph):
    """The server's throughput entrypoint is the batch-scheduler runtime
    (the legacy run_workload_batched per-server mode is gone): results in
    submission order, equal to the sequential loop."""
    from repro.launch.query import GraniteServer
    from repro.graphdata.queries import make_workload

    server = GraniteServer(medium_static_graph, use_planner=True)
    assert not hasattr(server, "run_workload_batched")
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=6, seed=44)
    seq = server.run_workload(wl)
    bat = server.run_workload_scheduled(wl)
    for a, b in zip(seq, bat):
        assert a.count == b.count, (a.template, a.count, b.count)
    assert all(r.ok for r in bat)
