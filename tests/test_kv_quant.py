"""int8 KV-cache quantization: decode matches the bf16 path within int8
error; cache memory halves."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.llama3_405b import SMOKE
from repro.models import transformer as tr


def test_quantized_decode_close_to_exact():
    base = SMOKE
    quant = dataclasses.replace(SMOKE, kv_cache_quant=True)
    p = tr.init_params(base, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, base.vocab)
    c_b = tr.init_cache(base, 2, 16)
    c_q = tr.init_cache(quant, 2, 16)
    assert c_q[0].dtype == jnp.int8 and len(c_q) == 4
    errs = []
    for t in range(8):
        lb, c_b = tr.decode_step(base, p, c_b, toks[:, t], t + 1)
        lq, c_q = tr.decode_step(quant, p, c_q, toks[:, t], t + 1)
        # compare post-softmax next-token distributions (the decision object)
        pb = jax.nn.softmax(lb, -1)
        pq = jax.nn.softmax(lq, -1)
        errs.append(float(jnp.abs(pb - pq).max()))
        assert jnp.argmax(lb, -1).tolist() == jnp.argmax(lq, -1).tolist()
    assert max(errs) < 0.05, errs


def test_quantized_cache_bytes_halved():
    base = SMOKE
    quant = dataclasses.replace(SMOKE, kv_cache_quant=True)
    c_b = tr.init_cache(base, 4, 64)
    c_q = tr.init_cache(quant, 4, 64)
    bytes_b = sum(np.asarray(x).nbytes for x in c_b)
    bytes_q = sum(np.asarray(x).nbytes for x in c_q)
    assert bytes_q < 0.65 * bytes_b, (bytes_q, bytes_b)


def test_quantize_roundtrip_error_bounded():
    from repro.models.transformer import _quantize_kv

    x = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 8, 32)) * 3.0
    q, s = _quantize_kv(x)
    back = q.astype(jnp.float32) * s.astype(jnp.float32)[..., None]
    rel = float(jnp.abs(back - x).max() / jnp.abs(x).max())
    assert rel < 0.02   # int8 symmetric: ≤ 1/254 of per-row max + bf16 scale
