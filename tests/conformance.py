"""Four-way differential conformance harness for the engine stack.

One query runs through every executor and the results must agree bit for bit:

  ref_engine   explicit-path oracle (pure numpy) — defines the semantics
  dense        engine.execute(sliced=False)      — whole-graph supersteps
  sliced       engine.execute(sliced=True)       — type-slice extents
  partitioned  engine_partitioned.execute        — per-worker shards +
                                                   boundary exchange, at
                                                   n_workers ∈ {2, 4, 8}

The matrix (``case_matrix``) spans the full query surface: every aggregate
(COUNT / MIN / MAX), every temporal mode (static / bucket / interval), ETR
and non-ETR hops, empty-result and single-vertex edge cases.  Engine legs
are compared with ``np.array_equal`` — any divergence between executors is a
hard failure, which is what makes the partitioned closure (MIN/MAX extremum
exchange, rank-prefix ETR exchange) safe to ship.

A second axis (``IMPLS``) reruns the engines with the fused hop-kernel
delivery (``impl='pallas'``, interpreter mode on CPU CI): the kernel legs
must be bit-identical to xla for every engine × mode × aggregate — exact
because engine counts are integers in float32, so prefix-difference sums
equal scatter sums bit for bit.

Oracle-leg scope (the oracle only *defines* a subset of the surface):
  * path counts: all three modes (float64 enumeration → tolerance compare
    in the temporal modes, exact in static);
  * aggregates: static COUNT/MIN/MAX and bucket COUNT.  Temporal-mode
    MIN/MAX is engine-differential only — the engines' extremum channel is
    gated per hop by *any* live bucket/cell (a documented DP
    over-approximation of per-path liveness), so enumeration is not its
    ground truth.  MIN/MAX across ETR hops is rejected by every engine and
    excluded from the matrix.
  * ETR hops whose operator permits DISJOINT adjacent edge lifespans
    (fully/starts before/after) take the oracle leg in static mode only:
    the tensor engines evaluate temporal validity at bucket granularity, so
    a bucket straddling the gap between two disjoint adjacent edges stays
    live where the oracle's exact-time running intersection is already
    empty.  (First surfaced by this harness — the engines agree with each
    other bit for bit; the divergence is oracle-vs-bucketisation, maximal
    under fully-before.)  OVERLAPS guarantees pairwise-nonempty
    intersections, where bucket and exact granularity coincide on 2-hop
    chains, so it keeps all three oracle modes.

Scale: ``CONFORMANCE_SCALE=smoke`` (default, tier-1) runs partitioned legs
at the workers each case names; ``CONFORMANCE_SCALE=ci`` (scripts/ci.sh)
forces n_workers ∈ {2, 4, 8} everywhere and adds the full ETR-operator
sweep.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import engine as E
from repro.core import engine_partitioned as EP
from repro.core import engine_sliced as ES
from repro.core import intervals as iv
from repro.core import query as Q
from repro.core.ref_engine import RefEngine

ALL_MODES = (E.MODE_STATIC, E.MODE_BUCKET, E.MODE_INTERVAL)
WORKERS_FULL = (2, 4, 8)
WORKERS_SMOKE = (2, 4)
N_BUCKETS = 8
#: the hop-delivery lowering axis: every matrix cell runs its engines under
#: both and the kernel legs must be bit-identical to the xla legs (on CPU CI
#: the kernels run in interpreter mode via the auto interpret default)
IMPLS = ("xla", "pallas")


def scale() -> str:
    return os.environ.get("CONFORMANCE_SCALE", "smoke")


@dataclasses.dataclass(frozen=True)
class Case:
    name: str
    qry: Q.PathQuery
    workers: Tuple[int, ...]          # partitioned legs to run
    oracle_modes: Tuple[int, ...]     # modes where the oracle leg applies
    expect_empty: bool = False        # the result must be exactly zero


# =========================================================================
# the generated matrix
# =========================================================================
def case_matrix(graph) -> Dict[str, Case]:
    """Named conformance cases over the LDBC schema of ``graph``.

    Acceptance-critical cases (MIN/MAX aggregates, ETR hops) always carry the
    full worker sweep {2, 4, 8}; the rest use {2, 4} at smoke scale.
    """
    b = graph.meta["builder"]
    vt, et, k = b.v_type_ids, b.e_type_ids, b.key_ids
    cty = b.lookup_value(k["country"], "india")
    person = vt["person"]
    follows = et["follows"]
    created = et["created"]
    wide = WORKERS_FULL
    slim = WORKERS_FULL if scale() == "ci" else WORKERS_SMOKE

    def vp(vtype, *clauses):
        return Q.VertexPredicate(vtype, tuple(clauses))

    cases = {}

    def add(name, qry, workers, oracle_modes=ALL_MODES, expect_empty=False):
        cases[name] = Case(name, qry, workers, oracle_modes, expect_empty)

    # ---- plain paths, non-ETR
    add("plain-2hop", Q.PathQuery(
        v_preds=(vp(person, Q.prop_clause(k["country"], "==", cty)),
                 vp(vt["post"]), vp(person)),
        e_preds=(Q.EdgePredicate(created, Q.DIR_OUT),
                 Q.EdgePredicate(et["likes"], Q.DIR_IN)),
    ), slim)
    add("plain-bidir", Q.PathQuery(
        v_preds=(vp(person), vp(person)),
        e_preds=(Q.EdgePredicate(follows, Q.DIR_BOTH),),
    ), slim)

    # ---- ETR hops (acceptance-critical: full worker sweep).  Operators
    # permitting disjoint adjacent lifespans are oracle-checked in static
    # mode only (bucket-granularity rounding, see module docstring).
    etr_ops = ((iv.FULLY_BEFORE, "before"), (iv.OVERLAPS, "overlaps"))
    if scale() == "ci":
        etr_ops += ((iv.STARTS_BEFORE, "starts-before"),
                    (iv.FULLY_AFTER, "after"),
                    (iv.STARTS_AFTER, "starts-after"))
    for op, tag in etr_ops:
        add(f"etr-{tag}", Q.PathQuery(
            v_preds=(vp(person), vp(person), vp(person)),
            e_preds=(Q.EdgePredicate(follows, Q.DIR_OUT),
                     Q.EdgePredicate(follows, Q.DIR_OUT, etr_op=op)),
        ), wide,
            oracle_modes=(ALL_MODES if op == iv.OVERLAPS
                          else (E.MODE_STATIC,)))

    # ---- aggregates (COUNT; MIN/MAX acceptance-critical)
    add("agg-count", Q.PathQuery(
        v_preds=(vp(person), vp(person)),
        e_preds=(Q.EdgePredicate(follows, Q.DIR_OUT),),
        agg_op=Q.AGG_COUNT,
    ), slim, oracle_modes=(E.MODE_STATIC, E.MODE_BUCKET))
    for op, tag in ((Q.AGG_MIN, "min"), (Q.AGG_MAX, "max")):
        add(f"agg-{tag}", Q.PathQuery(
            v_preds=(vp(person), vp(vt["post"])),
            e_preds=(Q.EdgePredicate(created, Q.DIR_OUT),),
            agg_op=op, agg_key=k["length"],
        ), wide, oracle_modes=(E.MODE_STATIC,))
    add("agg-min-2hop", Q.PathQuery(
        v_preds=(vp(person), vp(person), vp(vt["post"])),
        e_preds=(Q.EdgePredicate(follows, Q.DIR_OUT),
                 Q.EdgePredicate(created, Q.DIR_OUT)),
        agg_op=Q.AGG_MIN, agg_key=k["length"],
    ), wide, oracle_modes=(E.MODE_STATIC,))
    # ETR hop + aggregate: the reversed (right-to-left) segment carries the
    # ETR with backward comparator specs — the partitioned path must agree.
    add("etr-agg-count", Q.PathQuery(
        v_preds=(vp(person), vp(person), vp(person)),
        e_preds=(Q.EdgePredicate(follows, Q.DIR_OUT),
                 Q.EdgePredicate(follows, Q.DIR_IN, etr_op=iv.OVERLAPS)),
        agg_op=Q.AGG_COUNT,
    ), wide, oracle_modes=(E.MODE_STATIC, E.MODE_BUCKET))

    # ---- edge cases
    add("empty-result", Q.PathQuery(
        v_preds=(vp(person, Q.prop_clause(k["country"], "==", 10 ** 6)),
                 vp(person)),
        e_preds=(Q.EdgePredicate(follows, Q.DIR_OUT),),
    ), slim, expect_empty=True)
    add("single-vertex", Q.PathQuery(
        v_preds=(vp(person, Q.prop_clause(k["country"], "==", cty)),),
        e_preds=(),
    ), slim)
    return cases


# =========================================================================
# engine legs + comparison
# =========================================================================
def _np(x):
    return None if x is None else np.asarray(x)


def engine_results(graph, qry: Q.PathQuery, mode: int,
                   workers: Sequence[int] = WORKERS_SMOKE,
                   n_buckets: int = N_BUCKETS,
                   split: Optional[int] = None,
                   impls: Sequence[str] = IMPLS) -> Dict[str, dict]:
    """Run every applicable executor; returns name → {total, per_vertex,
    minmax} numpy views.

    ``impls`` adds the hop-delivery lowering axis: for every non-xla impl
    the dense/sliced legs and the partitioned legs (first worker count at
    smoke scale, the full sweep at ci scale) rerun through the fused kernel
    path and are compared bit-for-bit against the xla dense leg like any
    other executor."""
    legs = {}

    def record(name, out):
        legs[name] = dict(total=_np(out.total), per_vertex=_np(out.per_vertex),
                          minmax=_np(out.minmax))

    record("dense", E.execute(graph, qry, split=split, mode=mode,
                              n_buckets=n_buckets, sliced=False))
    if ES.sliceable(qry):
        record("sliced", E.execute(graph, qry, split=split, mode=mode,
                                   n_buckets=n_buckets, sliced=True))
    for w in workers:
        record(f"partitioned-w{w}",
               EP.execute(graph, qry, split=split, mode=mode,
                          n_buckets=n_buckets, n_workers=w))
    kernel_workers = workers if scale() == "ci" else tuple(workers)[:1]
    for impl in impls:
        if impl == "xla":
            continue
        record(f"dense+{impl}",
               E.execute(graph, qry, split=split, mode=mode,
                         n_buckets=n_buckets, sliced=False, impl=impl))
        if ES.sliceable(qry):
            record(f"sliced+{impl}",
                   E.execute(graph, qry, split=split, mode=mode,
                             n_buckets=n_buckets, sliced=True, impl=impl))
        for w in kernel_workers:
            record(f"partitioned-w{w}+{impl}",
                   EP.execute(graph, qry, split=split, mode=mode,
                              n_buckets=n_buckets, n_workers=w, impl=impl))
    return legs


def assert_engines_identical(legs: Dict[str, dict], ctx=""):
    """Every executor leg must agree bit for bit with the dense leg."""
    ref = legs["dense"]
    for name, got in legs.items():
        if name == "dense":
            continue
        for field in ("total", "per_vertex", "minmax"):
            a, b = ref[field], got[field]
            if a is None and b is None:
                continue
            assert a is not None and b is not None, (ctx, name, field)
            assert np.array_equal(a, b), (ctx, name, field, a, b)


def assert_oracle_counts(oracle: RefEngine, graph, qry, mode, legs,
                         n_buckets=N_BUCKETS, ctx=""):
    want = oracle.count(qry, mode=mode, n_buckets=n_buckets)
    got = legs["dense"]["total"]
    if mode == E.MODE_STATIC or mode == E.MODE_INTERVAL:
        assert float(np.sum(got)) == float(np.sum(want)), (ctx, got, want)
    else:
        np.testing.assert_allclose(got, want, atol=1e-4, err_msg=str(ctx))


def assert_oracle_aggregate(oracle: RefEngine, graph, qry, mode, legs,
                            n_buckets=N_BUCKETS, ctx=""):
    pv = legs["dense"]["per_vertex"]
    if mode == E.MODE_BUCKET:
        assert qry.agg_op == Q.AGG_COUNT, "oracle: bucket aggregates are COUNT"
        want = oracle.aggregate(qry, mode=mode, n_buckets=n_buckets)
        np.testing.assert_allclose(pv, want, atol=1e-4, err_msg=str(ctx))
        return
    assert mode == E.MODE_STATIC, "oracle aggregates: static or bucket COUNT"
    want = oracle.aggregate(qry, mode=mode)
    if qry.agg_op == Q.AGG_COUNT:
        got = {i: float(pv[i]) for i in np.nonzero(pv)[0]}
    else:
        mm = legs["dense"]["minmax"]
        got = {i: float(mm[i]) for i in np.nonzero(pv)[0]}
    assert got == want, (ctx, sorted(got.items())[:5], sorted(want.items())[:5])


def check_case(graph, oracle: Optional[RefEngine], case: Case, mode: int,
               n_buckets: int = N_BUCKETS) -> Dict[str, dict]:
    """Run one (case, mode) cell of the matrix and assert conformance.

    Returns the legs so wrappers can make extra assertions."""
    ctx = (case.name, mode)
    legs = engine_results(graph, case.qry, mode, case.workers, n_buckets)
    assert_engines_identical(legs, ctx)
    if case.expect_empty:
        assert float(np.sum(legs["dense"]["total"])) == 0.0, ctx
    if oracle is not None and mode in case.oracle_modes:
        if case.qry.agg_op == Q.AGG_NONE:
            assert_oracle_counts(oracle, graph, case.qry, mode, legs,
                                 n_buckets, ctx)
        else:
            assert_oracle_aggregate(oracle, graph, case.qry, mode, legs,
                                    n_buckets, ctx)
    return legs


# =========================================================================
# serving leg: batched scheduler vs the sequential per-query loop
# =========================================================================
def perturbed_batch(qry: Q.PathQuery, n: int):
    """Same-shape instance batch: the original query plus n-1 variants with
    shifted clause parameters (values/intervals are DATA in the traced
    program; structure — the shape bucket — is untouched).  Shifted values
    may match nothing, which is exactly the selectivity spread a real
    template workload shows."""
    import dataclasses as dc

    def shift_clause(c: Q.Clause, d: int) -> Q.Clause:
        if c.kind == Q.K_PROP:
            return dc.replace(c, value=c.value + d)
        lo, hi = c.interval
        return dc.replace(c, interval=(max(0, lo - d), hi))

    def shift_query(q: Q.PathQuery, d: int) -> Q.PathQuery:
        v = tuple(dc.replace(vp, clauses=tuple(shift_clause(c, d)
                                               for c in vp.clauses))
                  for vp in q.v_preds)
        e = tuple(dc.replace(ep, clauses=tuple(shift_clause(c, d)
                                               for c in ep.clauses))
                  for ep in q.e_preds)
        return Q.PathQuery(v, e, q.agg_op, q.agg_key)

    batch = [shift_query(qry, d) for d in range(n)]
    assert all(q.shape_key() == qry.shape_key() for q in batch)
    return batch


def serving_engines(case: Case):
    """(engine, n_workers) serving configurations for a case: dense, sliced
    when the query qualifies, and the partitioned engine (full worker sweep
    at ci scale, first worker count at smoke scale)."""
    out = [("dense", 0)]
    if ES.sliceable(case.qry):
        out.append(("sliced", 0))
    workers = case.workers if scale() == "ci" else case.workers[:1]
    out += [("partitioned", w) for w in workers]
    return out


def _sequential_leg(graph, qry, split, mode, n_buckets, engine, n_workers):
    if engine == "partitioned":
        return EP.execute(graph, qry, split=split, mode=mode,
                          n_buckets=n_buckets, n_workers=n_workers)
    return E.execute(graph, qry, split=split, mode=mode, n_buckets=n_buckets,
                     sliced=(engine == "sliced"))


def check_serving_case(graph, case: Case, mode: int,
                       n_buckets: int = N_BUCKETS, batch: int = 3):
    """The serving leg of the matrix: a same-shape batch of ``case``'s query
    through the batch scheduler must be bit-identical to the sequential
    per-query loop, on every engine, dispatched as ONE vmapped group (no
    per-query fallback — aggregates and the partitioned engine included)."""
    from repro.serving import BatchScheduler

    queries = perturbed_batch(case.qry, batch)
    for engine, n_workers in serving_engines(case):
        ctx = (case.name, mode, engine, n_workers)
        sched = BatchScheduler(graph, engine=engine, mode=mode,
                               n_buckets=n_buckets, n_workers=max(n_workers, 1),
                               keep_outputs=True)
        results = sched.run(queries)
        # one group, batched end to end: the zero-fallback invariant
        assert len(sched.last_dispatches) == 1, ctx
        disp = sched.last_dispatches[0]
        assert disp.engine == engine and disp.n_real == len(queries), ctx
        eff_mode = sched._mode_for(case.qry)
        for q, r in zip(queries, results):
            out = _sequential_leg(graph, q, r.split, eff_mode, n_buckets,
                                  engine, n_workers)
            for field, got in (("total", r.total), ("per_vertex", r.per_vertex),
                               ("minmax", r.minmax)):
                want = _np(getattr(out, field))
                if want is None and got is None:
                    continue
                assert want is not None and got is not None, (ctx, field)
                assert np.array_equal(want, got), (ctx, field, want, got)


# =========================================================================
# ingestion leg: epoch-pinned serving vs from-scratch builds
# =========================================================================
def check_ingestion_case(graph, case: Case, mode: int,
                         n_buckets: int = N_BUCKETS, n_epochs: int = 2):
    """The live-graph leg of the matrix: split ``graph`` into a seed epoch
    plus ``n_epochs`` held-out edge batches, serve ``case``'s query through
    an epoch-pinned scheduler while ingestion advances between batches, and
    require every epoch's answers — on dense, sliced (when the query
    qualifies) and the partitioned engine — to be bit-identical to a
    scheduler built from scratch on that epoch's ``materialize`` graph.
    Snapshot isolation is asserted structurally: unsealed events never
    change a pinned scheduler's results."""
    from repro.graphdata import ingest
    from repro.serving import BatchScheduler, EpochManager

    held_n = max(6, 3 * n_epochs)
    log, held = ingest.log_from_graph(graph, holdout_edges=held_n,
                                      seed=hash(case.name) % 1000)
    per = len(held) // n_epochs
    chunks = [held[i * per:(i + 1) * per] for i in range(n_epochs - 1)]
    chunks.append(held[(n_epochs - 1) * per:])
    for engine, n_workers in serving_engines(case):
        ctx = (case.name, mode, engine, n_workers, "ingest")
        mgr = EpochManager(log.clone())
        ep = mgr.seal()
        sched = BatchScheduler(ep.graph, engine=engine, mode=mode,
                               n_buckets=n_buckets,
                               n_workers=max(n_workers, 1))
        mgr.attach(sched)
        for k, chunk in enumerate(chunks, start=1):
            mgr.ingest(chunk)
            # snapshot isolation: the pinned epoch ignores unsealed events
            before = sched.run([case.qry])[0]
            mgr.advance(sched)
            after = sched.run([case.qry])[0]
            ref_graph = ingest.materialize(mgr.log, k + 1)
            ref = BatchScheduler(ref_graph, engine=engine, mode=mode,
                                 n_buckets=n_buckets,
                                 n_workers=max(n_workers, 1)).run(
                                     [case.qry])[0]
            frozen = BatchScheduler(ep.graph, engine=engine, mode=mode,
                                    n_buckets=n_buckets,
                                    n_workers=max(n_workers, 1)).run(
                                        [case.qry])[0]
            for field in ("total", "per_vertex", "minmax"):
                want, got = getattr(ref, field), getattr(after, field)
                if want is None and got is None:
                    continue
                assert np.array_equal(_np(want), _np(got)), (ctx, k, field)
                pre, froz = getattr(before, field), getattr(frozen, field)
                assert np.array_equal(_np(pre), _np(froz)), \
                    (ctx, k, field, "snapshot isolation")
            ep = mgr.current
