"""Multi-device serving conformance: the shard_map-native batched
partitioned path on a real (forced-host) device mesh must be bit-identical
to the single-device vmap simulation across the full conformance matrix.

These tests only run with >1 JAX devices; scripts/ci.sh provides them by
launching pytest with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
and selecting ``-m multidevice`` (the tier-1 default deselects the marker,
and the skipif below keeps a plain single-device run green either way).
"""
import jax
import numpy as np
import pytest

import conformance as C
from repro.core import engine_partitioned as EP
from repro.serving import BatchScheduler

pytestmark = [
    pytest.mark.multidevice,
    pytest.mark.skipif(
        jax.device_count() < 2,
        reason="needs XLA_FLAGS=--xla_force_host_platform_device_count"),
]

N_WORKERS = 8


def _case_names():
    # collection-time static mirror of test_conformance.CASE_NAMES
    from test_conformance import CASE_NAMES
    return CASE_NAMES


@pytest.fixture(scope="module")
def matrix(small_dynamic_graph):
    return C.case_matrix(small_dynamic_graph)


def _fields(r):
    return (("total", r.total), ("per_vertex", r.per_vertex),
            ("minmax", r.minmax))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("mode", C.ALL_MODES)
@pytest.mark.parametrize("name", _case_names())
def test_batched_sharded_serving_matches_vmap_simulation(
        small_dynamic_graph, matrix, name, mode, impl):
    """One shard_map dispatch (batch × workers on the device mesh, p2p
    boundary exchange) ≡ the vmap-simulated single-device leg, bit for bit,
    for every matrix cell — served through the batch scheduler with zero
    per-query fallbacks.  The ``impl`` axis reruns the leg with the fused
    hop kernel inside the shard_map body (per-worker layout tables sharded
    over the mesh like the partitioner's other padded tensors)."""
    assert N_WORKERS % jax.device_count() == 0
    case = matrix[name]
    queries = C.perturbed_batch(case.qry, 3)

    def serve(use_shard_map):
        sched = BatchScheduler(small_dynamic_graph, engine="partitioned",
                               mode=mode, n_buckets=C.N_BUCKETS,
                               n_workers=N_WORKERS, keep_outputs=True,
                               use_shard_map=use_shard_map, impl=impl)
        res = sched.run(queries)
        assert len(sched.last_dispatches) == 1, (name, mode, use_shard_map)
        assert sched.last_dispatches[0].impl == impl
        return sched, res

    sched_sh, shard = serve(True)
    sched_sim, sim = serve(False)
    assert sched_sh.n_devices == jax.device_count() > 1
    assert sched_sim.n_devices == 1
    for i, (a, b) in enumerate(zip(shard, sim)):
        assert a.split == b.split
        for field, got in _fields(a):
            want = dict(_fields(b))[field]
            if want is None and got is None:
                continue
            assert want is not None and got is not None, (name, mode, field)
            assert np.array_equal(got, want), (name, mode, i, field)


def test_sharded_execute_matches_simulation(small_dynamic_graph, matrix):
    """The sequential (non-batched) partitioned entry also lowers the worker
    axis to the device mesh, bit-identically, for a representative slice."""
    for name in ("plain-2hop", "etr-overlaps", "agg-min"):
        case = matrix[name]
        for mode in C.ALL_MODES:
            sh = EP.execute(small_dynamic_graph, case.qry, mode=mode,
                            n_buckets=C.N_BUCKETS, n_workers=N_WORKERS,
                            use_shard_map=True)
            sim = EP.execute(small_dynamic_graph, case.qry, mode=mode,
                             n_buckets=C.N_BUCKETS, n_workers=N_WORKERS,
                             use_shard_map=False)
            for field in ("total", "per_vertex", "minmax"):
                a, b = getattr(sh, field), getattr(sim, field)
                if a is None and b is None:
                    continue
                assert np.array_equal(np.asarray(a), np.asarray(b)), \
                    (name, mode, field)


def test_exchange_is_point_to_point(small_dynamic_graph):
    """Structural acceptance: boundary traffic per hop is O(ghost entries)
    for all three channels — the lane tables cover exactly the ghosts, and
    the profiler reports those ragged volumes, never the frontier."""
    from repro.graphdata.queries import make_workload, to_minmax

    g = small_dynamic_graph
    _, arrays, _ = EP.partition_for(g, 4, None)
    frontier = 2 * g.n_edges
    assert 0 < arrays.exchange_volume() < frontier
    assert 0 < arrays.etr_exchange_volume() < frontier
    # lanes cover exactly the ghost entries (ragged content == channel volume)
    real_state_lanes = int((arrays.xchg_send_slot < arrays.v_max).sum())
    assert real_state_lanes == arrays.exchange_volume()
    real_etr_lanes = int((arrays.etr_send_slot < arrays.s_max).sum())
    assert real_etr_lanes == arrays.etr_exchange_volume()

    inst = make_workload(g, templates=("Q4",), n_per_template=1, seed=7)[0]
    prof = EP.measure_supersteps(g, inst.qry, n_workers=4, repeats=1)
    for i, ep in enumerate(inst.qry.e_preds):
        ch = prof.exchange_channels[i]
        if ep.etr_op != -1:
            assert ch[2] == arrays.etr_exchange_volume() < frontier
        else:
            assert ch[0] == arrays.exchange_volume() < frontier
    # extremum channel: rides the state lanes, doubling the state volume
    qmm = to_minmax(
        make_workload(g, templates=("Q2",), n_per_template=1, seed=8)[0],
        g).qry
    profm = EP.measure_supersteps(g, qmm, n_workers=4, repeats=1)
    assert (profm.exchange_channels[:, 1] == arrays.exchange_volume()).all()
    # ... and those are exactly the canonical per-query volumes the serving
    # bench reports (one rule, one helper)
    want = EP.query_exchange_volumes(qmm, arrays)
    got = profm.channel_totals()
    assert got == want, (got, want)
