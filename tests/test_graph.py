"""Graph container: type-major layout, traversal arrays, ETR rank tables."""
import numpy as np
import pytest

from repro.core.graph import make_prop_column
from repro.graphdata.loader import GraphBuilder, load_graph, save_graph


def test_type_major_and_ranges(small_static_graph):
    g = small_static_graph
    assert (np.diff(g.v_type) >= 0).all(), "vertices must be type-major"
    tr = g.type_ranges
    for t in range(g.n_vertex_types):
        lo, hi = tr[t]
        if hi > lo:
            assert (g.v_type[lo:hi] == t).all()
    assert tr[:, 1].max() == g.n_vertices


def test_traversal_arrays(small_static_graph):
    g = small_static_graph
    tr = g.traversal
    E = g.n_edges
    assert tr["t_src"].shape == (2 * E,)
    # arrival-sorted
    assert (np.diff(tr["t_dst"]) >= 0).all()
    # each edge appears once forward, once backward
    assert tr["t_isfwd"].sum() == E
    # ptr consistency
    ptr = tr["arr_ptr"]
    assert ptr[0] == 0 and ptr[-1] == 2 * E
    counts = np.bincount(tr["t_dst"], minlength=g.n_vertices)
    np.testing.assert_array_equal(np.diff(ptr), counts)


def test_etr_rank_tables_bruteforce(small_static_graph):
    g = small_static_graph
    tr = g.traversal
    et = g.etr_tables
    ptr = tr["arr_ptr"].astype(np.int64)
    starts = tr["t_life"][:, 0].astype(np.int64)
    ends = tr["t_life"][:, 1].astype(np.int64)
    rng = np.random.default_rng(0)
    for e in rng.integers(0, 2 * g.n_edges, size=50):
        v = tr["t_src"][e]
        seg = np.arange(ptr[v], ptr[v + 1])   # canonical order groups by t_dst
        assert (tr["t_dst"][seg] == v).all()
        arr_start = starts[seg]
        arr_end = ends[seg]
        # term 0: #(acc.start < cur.start)
        assert et.dep_ranks[0, e] == (arr_start < starts[e]).sum()
        # term 1: #(acc.start <= cur.start)
        assert et.dep_ranks[1, e] == (arr_start <= starts[e]).sum()
        # term 2: #(acc.start < cur.end)
        assert et.dep_ranks[2, e] == (arr_start < ends[e]).sum()
        # term 3: #(acc.end <= cur.start)
        assert et.dep_ranks[3, e] == (arr_end <= starts[e]).sum()


def test_prop_column_pivot():
    col = make_prop_column(
        4,
        entity_ids=[0, 0, 2, 3, 0],
        values=[5, 6, 7, 8, 9],
        lifespans=[[0, 10], [10, 20], [0, 5], [2, 9], [20, 30]],
    )
    assert col.vals.shape == (4, 3)
    assert set(col.vals[0]) == {5, 6, 9}
    assert col.vals[1, 0] == -1
    assert col.vals[2, 0] == 7 and col.vals[3, 0] == 8


def test_save_load_roundtrip(tmp_path, small_static_graph):
    g = small_static_graph
    p = str(tmp_path / "g.npz")
    save_graph(g, p)
    g2 = load_graph(p)
    assert g2.n_vertices == g.n_vertices and g2.n_edges == g.n_edges
    np.testing.assert_array_equal(g2.e_src, g.e_src)
    np.testing.assert_array_equal(g2.v_life, g.v_life)
    k = next(iter(g.vprops))
    np.testing.assert_array_equal(g2.vprops[k].vals, g.vprops[k].vals)
