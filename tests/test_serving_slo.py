"""SLO-layer tests: deadline EDF ordering, admission control (admit /
degrade / reject), online θ refit, and the open- vs closed-loop replay
harness — all on the deterministic FakeDispatcher virtual clock (zero JAX
compilation), plus a real-dispatch bit-identity leg proving the SLO layer
never changes an admitted query's answer.
"""
import dataclasses as dc
import math

import numpy as np
import pytest

from repro.core import engine as E
from repro.core import query as Q
from repro.graphdata.queries import QueryInstance, make_workload
from repro.serving import (AdmissionController, AdmissionPolicy,
                           BatchScheduler, TelemetryBuffer, replay_workload)
from repro.serving.replay import DONE, FAILED, REJECTED
from repro.serving.testing import (FakeDispatcher, constant_service_model,
                                   fake_count, planner_service_model)

pytestmark = pytest.mark.serving


def _sched(graph, **kw):
    kw.setdefault("dispatcher",
                  FakeDispatcher(service_model=constant_service_model(1e-3)))
    return BatchScheduler(graph, **kw)


# ------------------------------------------------------------------- EDF
def test_edf_dispatch_order(medium_static_graph):
    """Groups dispatch earliest-deadline-first: the group deadline is its
    most urgent member, regardless of submission order."""
    wl2 = make_workload(medium_static_graph, templates=("Q2",),
                        n_per_template=3, seed=1)
    wl4 = make_workload(medium_static_graph, templates=("Q4",),
                        n_per_template=3, seed=2)
    sched = _sched(medium_static_graph)
    # Q2 submitted FIRST but with the LATER deadlines
    for inst in wl2:
        sched.submit(inst, deadline_s=50.0, now=0.0)
    for inst in wl4:
        sched.submit(inst, deadline_s=5.0, now=0.0)
    res = sched.flush()
    assert [r.ok for r in res] == [True] * 6
    deadlines = [d.deadline for d in sched.last_dispatches]
    assert deadlines == sorted(deadlines) == [5.0, 50.0]
    # results still return in SUBMISSION order even though dispatch reordered
    for inst, r in zip(wl2 + wl4, res):
        assert r.count == fake_count(inst.qry)


def test_edf_ties_preserve_arrival_order(medium_static_graph):
    """No deadlines → every group ties at +inf and the historical arrival
    order of groups is preserved exactly."""
    wl2 = make_workload(medium_static_graph, templates=("Q2",),
                        n_per_template=2, seed=3)
    wl4 = make_workload(medium_static_graph, templates=("Q4",),
                        n_per_template=2, seed=4)
    fd = FakeDispatcher()
    sched = BatchScheduler(medium_static_graph, dispatcher=fd)
    sched.run([wl4[0], wl2[0], wl4[1], wl2[1]])   # Q4's bucket arrives first
    assert [c.n_real for c in fd.calls] == [2, 2]
    assert fd.calls[0].queries[0] is wl4[0].qry
    assert fd.calls[1].queries[0] is wl2[0].qry
    assert all(d.deadline == math.inf for d in sched.last_dispatches)


def test_mixed_deadline_and_plain_submissions(medium_static_graph):
    """Entries with deadlines outrank the no-deadline (+inf) backlog."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=2, seed=5)
    sched = _sched(medium_static_graph)
    for inst in wl[:2]:                       # Q2: no deadline
        sched.submit(inst)
    for inst in wl[2:]:                       # Q4: urgent
        sched.submit(inst, deadline_s=1.0, now=0.0)
    sched.flush()
    assert sched.last_dispatches[0].deadline == 1.0
    assert sched.last_dispatches[1].deadline == math.inf


# ------------------------------------------------------------- admission
def _plain_cost_s(sched, qry):
    """What the admission controller predicts for one query at the default
    plan (no cached batch plan yet): default split, fixed impl."""
    split = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
    return sched._planner_for(sched._engine_for(qry)).estimate(
        qry, split, sched.impl).t_ms / 1e3


def test_admission_admit_then_reject_on_backlog(medium_static_graph):
    """Backlog accounting: identical queries admit until predicted wait +
    service exceeds headroom·deadline, then reject — and a flush resets the
    backlog so admission reopens."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=8, seed=6)
    probe = _sched(medium_static_graph)
    cost = _plain_cost_s(probe, wl[0].qry)
    # deadline fits ~3 predicted service times (headroom 1.0 for exactness)
    rel = 3.49 * cost
    pol = AdmissionPolicy(headroom=1.0, degrade_impls=(),
                          allow_engine_downgrade=False)
    sched = _sched(medium_static_graph, admission=pol)
    actions = [sched.submit(inst, deadline_s=rel, now=0.0).action
               for inst in wl]
    n_admit = actions.count("admit")
    assert 1 <= n_admit < len(wl)
    assert actions == ["admit"] * n_admit + ["reject"] * (len(wl) - n_admit)
    assert sched.queued == n_admit and sched.n_rejected == len(wl) - n_admit
    res = sched.flush()
    assert len(res) == n_admit
    # backlog reset: the same query admits again
    assert sched.submit(wl[0], deadline_s=rel, now=1.0).action == "admit"
    rep = sched.slo_report()
    assert rep["n_rejected"] == len(wl) - n_admit
    assert rep["admission"]["n_admitted"] == n_admit + 1


def test_admission_degrades_to_sliced_with_bounded_chunks(
        medium_static_graph):
    """The dense→sliced ladder rung: a deadline between the sliced-discounted
    cost and the dense cost degrades instead of rejecting; degraded entries
    dispatch on the override engine in chunks capped by degrade_max_batch."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=5, seed=7)
    fd = FakeDispatcher()
    sched = BatchScheduler(medium_static_graph, engine="dense", dispatcher=fd)
    cost = _plain_cost_s(sched, wl[0].qry)
    pol = AdmissionPolicy(headroom=1.0, degrade_impls=(),
                          allow_engine_downgrade=True, sliced_discount=0.5,
                          degrade_max_batch=2)
    sched.admission = AdmissionController(pol)
    decisions = []
    for inst in wl:
        sched.admission.on_flush()            # isolate: no backlog between
        decisions.append(sched.submit(inst, deadline_s=0.75 * cost, now=0.0))
    assert all(d.action == "degrade" for d in decisions)
    assert all(d.engine == "sliced" and d.max_batch == 2 for d in decisions)
    assert sched.n_degraded == len(wl)
    res = sched.flush()
    assert all(r.ok for r in res)
    assert all(c.engine == "sliced" and c.n_real <= 2 for c in fd.calls)
    assert sum(c.n_real for c in fd.calls) == len(wl)
    for inst, r in zip(wl, res):              # answers survive degradation
        assert r.count == fake_count(inst.qry)


def test_admission_rejects_hopeless_deadline(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=1, seed=8)
    sched = _sched(medium_static_graph, admission=AdmissionPolicy())
    dec = sched.submit(wl[0], deadline_s=0.0, now=0.0)
    assert dec.action == "reject" and not dec.admitted
    assert "exceeds" in dec.reason
    assert sched.queued == 0 and sched.flush() == []


def test_admission_never_writes_plan_cache(medium_static_graph):
    """Admission predicts from plan-cache PEEKs: the batch-aware plan must
    still be computed once per group over ALL members at flush time."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=4, seed=9)
    sched = _sched(medium_static_graph, admission=AdmissionPolicy())
    for inst in wl:
        sched.submit(inst, deadline_s=600.0, now=0.0)
    assert len(sched.plan_cache) == 0         # decisions wrote nothing
    assert sched.plan_cache.stats.lookups == 0  # peeks don't count either
    sched.flush()
    assert len(sched.plan_cache) == 1
    assert sched.plan_cache.stats.misses == 1


def test_max_backlog_cap(medium_static_graph):
    """max_backlog_s bounds admitted work even when deadlines are generous."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=6, seed=10)
    probe = _sched(medium_static_graph)
    cost = _plain_cost_s(probe, wl[0].qry)
    pol = AdmissionPolicy(headroom=1.0, max_backlog_s=2.5 * cost,
                          degrade_impls=(), allow_engine_downgrade=False)
    sched = _sched(medium_static_graph, admission=pol)
    actions = [sched.submit(inst, deadline_s=600.0, now=0.0).action
               for inst in wl]
    assert actions == ["admit", "admit", "reject", "reject", "reject",
                       "reject"]


# ------------------------------------------------------------- telemetry
def test_online_refit_converges_to_true_theta(medium_static_graph):
    """Service times come from a hidden linear θ* ≠ the live θ: the online
    refit must drive prediction error from ~2/3 (θ* = 3·θ) to ~0, while the
    refit-disabled baseline stays wrong on the same trace."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=4, seed=11)

    def run(refit: bool) -> TelemetryBuffer:
        tb = TelemetryBuffer(refit_every=4, min_samples=4, blend=1.0,
                             refit=refit)
        sched = BatchScheduler(
            medium_static_graph, telemetry=tb,
            dispatcher=FakeDispatcher(service_model=planner_service_model(
                {k: 3.0 * v for k, v in
                 BatchScheduler(medium_static_graph)._planner.coeffs.items()})))
        for _ in range(8):                    # 8 flushes × 2 groups
            for inst in wl:
                sched.submit(inst)
            assert all(r.ok for r in sched.flush())
        return tb

    online, static = run(True), run(False)
    s_on, s_off = online.error_stats(tail=4), static.error_stats(tail=4)
    assert s_on["n_refits"] >= 1 and s_off["n_refits"] == 0
    assert s_off["tail_mean_abs_rel_err"] == pytest.approx(2 / 3, rel=1e-3)
    assert s_on["tail_mean_abs_rel_err"] < 0.05
    assert s_on["tail_mean_abs_rel_err"] < 0.2 * s_off["tail_mean_abs_rel_err"]


def test_refit_updates_planner_and_clears_plan_cache(medium_static_graph):
    """A refit rewrites the live planner θ in place and invalidates cached
    split choices (they were optimal under the old θ)."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=4, seed=12)
    tb = TelemetryBuffer(refit_every=3, min_samples=3, blend=1.0)
    sched = BatchScheduler(
        medium_static_graph, telemetry=tb,
        dispatcher=FakeDispatcher(service_model=planner_service_model(
            {k: 2.0 * v for k, v in
             BatchScheduler(medium_static_graph)._planner.coeffs.items()})))
    theta_before = dict(sched._planner.coeffs)
    for _ in range(2):                        # 2 dispatches: no refit yet
        sched.run(wl)
    assert tb.n_refits == 0 and len(sched.plan_cache) == 1
    sched.run(wl)                             # 3rd dispatch triggers refit
    assert tb.n_refits == 1
    assert len(sched.plan_cache) == 0         # cleared, will re-plan
    assert sched._planner.coeffs != theta_before
    misses = sched.plan_cache.stats.misses
    sched.run(wl)
    assert sched.plan_cache.stats.misses == misses + 1  # re-planned once


def test_telemetry_without_refit_is_pure_recorder(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=2, seed=13)
    tb = TelemetryBuffer(refit=False, refit_every=1, min_samples=2)
    sched = _sched(medium_static_graph, telemetry=tb)
    for _ in range(4):
        sched.run(wl)
    assert len(tb) == 4 and tb.n_refits == 0
    stats = tb.error_stats()
    assert stats["n"] == 4 and stats["n_refits"] == 0


# ---------------------------------------------------------------- replay
def test_replay_empty_workload(medium_static_graph):
    """Regression: n=0 must return a well-formed zero report, not crash in
    np.percentile over an empty array."""
    rep = replay_workload(_sched(medium_static_graph), [], rate_qps=10.0)
    assert rep.n_queries == 0 and rep.n_dispatches == 0
    assert rep.latency_ms_p50 == rep.latency_ms_p99 == 0.0
    assert rep.completion_rate == 0.0 and rep.deadline_hit_rate == 1.0
    assert rep.as_dict()["n_queries"] == 0


def test_replay_single_query(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=1, seed=14)
    rep = replay_workload(_sched(medium_static_graph), wl, rate_qps=10.0)
    assert rep.n_queries == rep.n_completed == 1
    assert rep.completion_rate == 1.0
    assert rep.latency_ms_p50 == rep.latency_ms_p99 > 0


def test_replay_failed_group_not_counted_completed(medium_static_graph):
    """Regression: a failed group's queries used to keep latency 0.0 and
    slip through `lat <= budget` as completed.  They must count FAILED, keep
    NaN latency, and depress the completion rate."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=3, seed=15)
    fd = FakeDispatcher(fail=lambda queries, engine, impl:
                        queries[0].n_vertices == wl[-1].qry.n_vertices)
    sched = BatchScheduler(medium_static_graph, dispatcher=fd)
    rep = replay_workload(sched, wl, rate_qps=1000.0, seed=16)
    assert rep.n_failed == 3 and rep.n_completed == 3
    assert rep.completion_rate == 0.5
    failed = [i for i, s in enumerate(rep.statuses) if s == FAILED]
    assert len(failed) == 3
    assert np.isnan(rep.latencies_ms[failed]).all()
    assert np.isfinite(rep.latencies_ms[
        [i for i, s in enumerate(rep.statuses) if s == DONE]]).all()


def test_replay_failed_group_real_sliced_engine(small_static_graph):
    """Same regression on the REAL dispatch path: a MIN aggregate forced
    onto the sliced engine fails to build; its replay accounting must say
    failed, not completed."""
    wl = make_workload(small_static_graph, templates=("Q2",),
                       n_per_template=3, seed=17)
    bad = QueryInstance("Q2-min", dc.replace(
        wl[0].qry, agg_op=Q.AGG_MIN, agg_key=next(iter(
            small_static_graph.meta["builder"].key_ids.values()))), {})
    sched = BatchScheduler(small_static_graph, engine="sliced")
    rep = replay_workload(sched, wl + [bad], rate_qps=1000.0, seed=18,
                          warm=True)
    assert rep.n_failed == 1 and rep.n_completed == 3
    assert rep.statuses[3] == FAILED and np.isnan(rep.latencies_ms[3])
    assert rep.completion_rate == 0.75


def test_replay_deadline_hit_accounting(medium_static_graph):
    """Exact virtual-clock arithmetic: two groups, EDF ties → arrival order,
    0.05 s per dispatch; a 0.08 s deadline catches the first dispatch
    (t=0.05) and misses the second (t=0.10)."""
    wl2 = make_workload(medium_static_graph, templates=("Q2",),
                        n_per_template=2, seed=19)
    wl4 = make_workload(medium_static_graph, templates=("Q4",),
                        n_per_template=2, seed=20)
    sched = _sched(medium_static_graph, dispatcher=FakeDispatcher(
        service_model=constant_service_model(0.0, overhead_s=0.05)))
    rep = replay_workload(sched, wl2 + wl4, mode="closed", max_outstanding=4,
                          deadline_s=0.08)
    assert rep.n_completed == 4 and rep.n_dispatches == 2
    assert rep.deadline_hit_rate == 0.5
    assert rep.goodput_qps == pytest.approx(2 / rep.wall_s)
    assert sorted(np.round(rep.latencies_ms, 6)) == [50.0, 50.0, 100.0, 100.0]


def test_open_loop_diverges_closed_loop_bounded(medium_static_graph):
    """The tentpole's control experiment in miniature: at an arrival rate
    beyond capacity, open-loop latency grows with queue depth while the
    closed loop keeps both backlog and batch size bounded."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=2, seed=21) * 30
    model = constant_service_model(0.02)      # capacity far below 500 qps

    open_rep = replay_workload(
        _sched(medium_static_graph,
               dispatcher=FakeDispatcher(service_model=model)),
        wl, rate_qps=500.0, seed=22, mode="open")
    closed_rep = replay_workload(
        _sched(medium_static_graph,
               dispatcher=FakeDispatcher(service_model=model)),
        wl, mode="closed", max_outstanding=4)
    assert open_rep.n_completed == closed_rep.n_completed == len(wl)
    # open loop: later arrivals wait behind an ever-deeper queue
    lat = open_rep.latencies_ms
    assert lat[-1] > 3 * lat[0]
    assert open_rep.latency_ms_p99 > 3 * closed_rep.latency_ms_p99
    assert closed_rep.max_batch <= 4 and closed_rep.max_outstanding == 4


def test_admission_holds_deadlines_under_overload(medium_static_graph):
    """Under the same overload, the plain scheduler misses most deadlines
    (open-loop queueing) while the admission-controlled one keeps nearly all
    of its ADMITTED queries inside theirs — trading rejects for goodput.
    Service times come from the planner's own cost model (scale=1), so
    admission's predictions are consistent with the virtual clock."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=2, seed=23) * 25
    probe = _sched(medium_static_graph)
    c = float(np.mean([_plain_cost_s(probe, inst.qry) for inst in wl]))
    deadline = 4.0 * c                        # fair for small waves only

    def run(admission):
        sched = _sched(
            medium_static_graph, admission=admission, pad_batches=False,
            dispatcher=FakeDispatcher(
                service_model=planner_service_model(probe._planner.coeffs)))
        return replay_workload(sched, wl, rate_qps=5.0 / c, seed=24,
                               mode="open", deadline_s=deadline)

    plain = run(None)
    slo = run(AdmissionPolicy(headroom=0.5, degrade_impls=(),
                              allow_engine_downgrade=False))
    assert plain.deadline_hit_rate < 0.5          # overload: open loop sinks
    assert slo.n_rejected > 0 and slo.reject_rate > 0
    # admitted queries overwhelmingly finish inside their deadlines
    admitted_lat = slo.latencies_ms[[i for i, s in enumerate(slo.statuses)
                                     if s == DONE]]
    assert admitted_lat.size > 0
    hits = float(np.mean(admitted_lat <= deadline * 1e3 + 1e-6))
    assert hits >= 0.9
    assert slo.deadline_hit_rate > plain.deadline_hit_rate
    assert slo.goodput_qps > plain.goodput_qps
    assert slo.slo["admission"]["n_rejected"] == slo.n_rejected


def test_replay_rejected_queries_excluded(medium_static_graph):
    """Rejected queries never dispatch: statuses say so and the dispatched
    query count matches the admitted population exactly."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=2, seed=25) * 10
    probe = _sched(medium_static_graph)
    c = float(np.mean([_plain_cost_s(probe, inst.qry) for inst in wl]))
    fd = FakeDispatcher(service_model=planner_service_model(
        probe._planner.coeffs))
    sched = _sched(medium_static_graph, dispatcher=fd, pad_batches=False,
                   admission=AdmissionPolicy(headroom=1.0, degrade_impls=(),
                                             allow_engine_downgrade=False))
    rep = replay_workload(sched, wl, rate_qps=10.0 / c, seed=26, mode="open",
                          deadline_s=2.0 * c)
    assert rep.n_rejected > 0
    assert rep.n_completed + rep.n_rejected + rep.n_failed == len(wl)
    n_dispatched = sum(c.n_real for c in fd.calls)
    assert n_dispatched == rep.n_completed
    for i, s in enumerate(rep.statuses):
        if s == REJECTED:
            assert np.isnan(rep.latencies_ms[i])
        else:
            assert s == DONE and np.isfinite(rep.latencies_ms[i])


def test_closed_loop_with_admission_frees_rejected_slots(
        medium_static_graph):
    """A closed-loop wave of all-rejects must free its slots and terminate,
    not deadlock the issue loop."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=6, seed=31)
    sched = _sched(medium_static_graph, admission=AdmissionPolicy(
        headroom=1.0, degrade_impls=(), allow_engine_downgrade=False))
    rep = replay_workload(sched, wl, mode="closed", max_outstanding=2,
                          deadline_s=0.0)
    assert rep.n_rejected == len(wl) and rep.n_completed == 0
    assert rep.reject_rate == 1.0 and rep.n_dispatches == 0
    assert rep.deadline_hit_rate == 0.0 and rep.goodput_qps == 0.0


# ------------------------------------------- conformance: SLO ≡ plain
@pytest.mark.conformance
def test_slo_scheduler_bit_identical_answers(small_static_graph):
    """Real dispatch: answers from the SLO-layered scheduler (admission +
    telemetry + deadlines, including a forced dense→sliced degrade) are
    bit-identical to the plain scheduler's for every admitted query."""
    wl = make_workload(small_static_graph, templates=("Q2", "Q4"),
                       n_per_template=2, seed=27)
    plain = BatchScheduler(small_static_graph, keep_outputs=True).run(
        wl, warm=True)

    # generous admission: everything admits, telemetry records (no refit —
    # determinism of the comparison, refit correctness is pinned above)
    slo_sched = BatchScheduler(
        small_static_graph, keep_outputs=True,
        admission=AdmissionPolicy(headroom=1.0),
        telemetry=TelemetryBuffer(refit=False))
    for inst in wl:
        slo_sched.submit(inst, deadline_s=600.0, now=0.0)
    slo = slo_sched.flush(warm=True)
    for a, b in zip(plain, slo):
        assert a.ok and b.ok
        assert np.array_equal(a.total, b.total)

    # forced degrade (dense → sliced): still bit-identical where sliceable
    from repro.core import engine_sliced as ES
    sl = [inst for inst in wl if ES.sliceable(inst.qry)]
    assert sl, "workload must contain sliceable queries"
    probe = BatchScheduler(small_static_graph, engine="dense")
    deg_sched = BatchScheduler(
        small_static_graph, engine="dense", keep_outputs=True,
        admission=AdmissionPolicy(headroom=1.0, degrade_impls=(),
                                  allow_engine_downgrade=True,
                                  sliced_discount=0.25,
                                  degrade_max_batch=None))
    decs = []
    for inst in sl:
        deg_sched.admission.on_flush()
        # per-query deadline between its sliced-discounted and dense cost
        decs.append(deg_sched.submit(
            inst, deadline_s=0.5 * _plain_cost_s(probe, inst.qry), now=0.0))
    assert all(d.action == "degrade" and d.engine == "sliced" for d in decs)
    deg = deg_sched.flush(warm=True)
    want = {id(inst): r for inst, r in zip(wl, plain)}
    for inst, r in zip(sl, deg):
        assert r.ok and r.engine == "sliced"
        assert np.array_equal(r.total, want[id(inst)].total)


# -------------------------------------- seeded permutation invariance
def test_flush_results_in_submission_order_any_permutation(
        medium_static_graph):
    """Seeded version of the hypothesis property (runs even without the
    optional dep): under any submission permutation, flush returns each
    query ITS OWN answer, at its submission position."""
    base = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                         n_per_template=4, seed=28)
    base += make_workload(medium_static_graph, templates=("Q2",),
                          n_per_template=2, seed=29, aggregate=True)
    rng = np.random.default_rng(30)
    ref = None
    for _ in range(5):
        perm = rng.permutation(len(base))
        fd = FakeDispatcher()
        res = BatchScheduler(medium_static_graph, dispatcher=fd).run(
            [base[i] for i in perm])
        assert [r.count for r in res] == \
            [fake_count(base[i].qry) for i in perm]
        counts = sorted((c.n_real for c in fd.calls))
        if ref is None:
            ref = counts
        assert counts == ref                  # grouping permutation-invariant


# -------------------------------------- telemetry error_stats edge cases
def test_error_stats_empty_buffer_is_zeroed():
    tb = TelemetryBuffer()
    s = tb.error_stats()
    assert s == dict(n=0, mean_abs_rel_err=0.0, p90_abs_rel_err=0.0,
                     tail_mean_abs_rel_err=0.0, n_refits=0)


def test_error_stats_tail_clamping():
    tb = TelemetryBuffer(refit=False)
    # predicted 1ms, measured 2ms → |1-2|/2 = 0.5 abs rel err per sample
    for _ in range(3):
        tb.record(np.ones(10), 1.0, 2.0)
    full = tb.error_stats()
    assert full["n"] == 3
    assert full["mean_abs_rel_err"] == pytest.approx(0.5)
    # tail longer than the buffer clamps to the whole buffer
    assert tb.error_stats(tail=100) == tb.error_stats(tail=3)
    # tail=0 means "no tail window", not "whole array"
    assert tb.error_stats(tail=0)["tail_mean_abs_rel_err"] == 0.0
    # a genuine tail sees only the newest samples
    tb.record(np.ones(10), 1.0, 1.0)          # perfect prediction
    assert tb.error_stats(tail=1)["tail_mean_abs_rel_err"] == 0.0
    assert tb.error_stats(tail=2)["tail_mean_abs_rel_err"] == \
        pytest.approx(0.25)


# -------------------------------------- injected clock routing (dispatch)
def test_fake_dispatch_duration_equals_service_model_exactly(
        medium_static_graph):
    """The recorded dispatch duration is the fake service model's value
    EXACTLY — timing flows through the injected clock, not time.monotonic."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=3, seed=60)
    fd = FakeDispatcher(
        service_model=constant_service_model(2e-3, overhead_s=5e-3))
    sched = BatchScheduler(medium_static_graph, dispatcher=fd)
    res = sched.run(wl)
    assert all(r.ok for r in res)
    assert sched.last_dispatches
    for d in sched.last_dispatches:
        # padded batch = n_real + n_pad; == (not approx) — the duration IS
        # the model's output, untouched by any wall clock
        assert d.service_s == 5e-3 + 2e-3 * (d.n_real + d.n_pad)
    # per-query latency is the group time apportioned — still exact algebra:
    # every group's service time is fully distributed over its members
    assert sum(r.latency_ms for r in res) == pytest.approx(
        sum(d.service_s for d in sched.last_dispatches) * 1e3, rel=1e-9)


def test_real_dispatch_duration_comes_from_injected_clock(
        small_static_graph):
    """Real JAX dispatch with a virtual clock on the scheduler: every
    recorded duration is exactly one clock step — proof that _dispatch_jax
    reads self._clock and never the wall clock."""
    from repro.obs import StepClock

    wl = make_workload(small_static_graph, templates=("Q2", "Q4"),
                       n_per_template=2, seed=61)
    sched = BatchScheduler(small_static_graph, clock=StepClock(step=0.125))
    res = sched.run(wl, warm=True)
    assert all(r.ok for r in res)
    assert sched.last_dispatches
    for d in sched.last_dispatches:
        assert d.service_s == 0.125                    # exact, not approx
