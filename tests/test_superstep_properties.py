"""Property tests for the superstep interval/bucket state algebra.

The running-intersection cell algebra (``apply_validity`` clamping in
MODE_INTERVAL) must behave like interval intersection at bucket granularity:
idempotent, commutative, and — whenever the exact intersection is non-empty —
equal to clamping by ``iv.intersect`` directly.  (When the exact intersection
is empty the sequential clamps may legitimately keep a bucket straddling the
gap: the algebra is bucket-granular by design; see the conformance-harness
docstring.)  Delivery reductions are checked against plain numpy oracles.

Intervals are drawn INSIDE the bucketed span, mirroring the engine invariant
that every entity lifespan lies within the graph lifespan the bucket edges
cover (out-of-span intervals would be clipped into the edge buckets).
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import intervals as iv  # noqa: E402
from repro.core import query as Q  # noqa: E402
from repro.core import superstep as SS  # noqa: E402

B = 5
SPAN = 100
BEDGES = jnp.asarray(iv.bucket_edges(0, SPAN, B))
N = 6

ivl = st.tuples(st.integers(0, SPAN - 1), st.integers(1, SPAN)).map(
    lambda t: (t[0], min(t[0] + t[1], SPAN)))
ivls = st.lists(ivl, min_size=N, max_size=N).map(
    lambda xs: jnp.asarray(np.asarray(xs, np.int32)))
matches = st.lists(st.booleans(), min_size=N, max_size=N).map(
    lambda xs: jnp.asarray(np.asarray(xs)))
cells = st.lists(
    st.lists(st.integers(0, 3), min_size=B * (B + 1), max_size=B * (B + 1)),
    min_size=N, max_size=N,
).map(lambda xs: jnp.asarray(
    np.asarray(xs, np.float32).reshape(N, B, B + 1)))


def _apply(state, m, v):
    with SS.bucket_scope(BEDGES):
        return np.asarray(SS.apply_validity(state, m, v, SS.MODE_INTERVAL))


@settings(max_examples=50, deadline=None)
@given(cells, matches, ivls)
def test_clamp_idempotent(state, m, v):
    once = _apply(state, m, v)
    assert np.array_equal(_apply(jnp.asarray(once), m, v), once)


@settings(max_examples=50, deadline=None)
@given(cells, matches, ivls, ivls)
def test_clamp_commutes(state, m, v1, v2):
    ab = _apply(jnp.asarray(_apply(state, m, v1)), m, v2)
    ba = _apply(jnp.asarray(_apply(state, m, v2)), m, v1)
    assert np.array_equal(ab, ba)


@settings(max_examples=50, deadline=None)
@given(cells, matches, ivls, ivls)
def test_clamp_join_matches_exact_intersection(state, m, v1, v2):
    """Sequential clamping ≡ clamping by the exact intersection, wherever
    that intersection is non-empty (the associativity of the join)."""
    ab = _apply(jnp.asarray(_apply(state, m, v1)), m, v2)
    inter = iv.intersect(v1, v2)
    direct = _apply(state, m, inter)
    nonempty = np.asarray(inter[:, 0] < inter[:, 1])
    assert np.array_equal(ab[nonempty], direct[nonempty])


@settings(max_examples=50, deadline=None)
@given(cells)
def test_valid_cell_mask_idempotent(state):
    once = SS._mask_valid_cells(state)
    assert np.array_equal(np.asarray(SS._mask_valid_cells(once)),
                          np.asarray(once))


@settings(max_examples=50, deadline=None)
@given(matches, ivls)
def test_interval_init_projects_to_bucket_init(m, v):
    """cells_to_buckets ∘ interval-init ≡ bucket-init: the two temporal modes
    agree on the per-bucket view of a freshly seeded state."""
    with SS.bucket_scope(BEDGES):
        ic = SS.init_state(m, v, SS.MODE_INTERVAL, B)
        bmask = iv.interval_to_bucket_mask(v, BEDGES)
        binit = SS.init_state(m, bmask, SS.MODE_BUCKET, B)
        assert np.array_equal(np.asarray(SS.cells_to_buckets(ic)),
                              np.asarray(binit))


segments = st.integers(2, 6).flatmap(lambda ns: st.tuples(
    st.just(ns),
    st.lists(st.integers(0, ns - 1), min_size=1, max_size=24),
    ))


@settings(max_examples=50, deadline=None)
@given(segments, st.data())
def test_deliver_extremum_matches_numpy(seg_spec, data):
    """Per-segment segment_min/segment_max against a numpy loop oracle,
    including empty segments (→ the aggregation-neutral ±inf)."""
    nseg, seg_list = seg_spec
    seg = np.sort(np.asarray(seg_list, np.int32))
    vals = np.asarray(
        data.draw(st.lists(st.integers(-50, 50), min_size=len(seg),
                           max_size=len(seg))), np.float32)
    for op in (Q.AGG_MIN, Q.AGG_MAX):
        got = np.asarray(SS.deliver_extremum(
            jnp.asarray(vals), jnp.asarray(seg), nseg, op))
        want = np.full(nseg, np.asarray(SS.minmax_neutral(op)), np.float32)
        for s, v in zip(seg, vals):
            want[s] = min(want[s], v) if op == Q.AGG_MIN else max(want[s], v)
        assert np.array_equal(got, want), op


@settings(max_examples=50, deadline=None)
@given(segments, st.data())
def test_deliver_matches_numpy(seg_spec, data):
    nseg, seg_list = seg_spec
    seg = np.sort(np.asarray(seg_list, np.int32))
    vals = np.asarray(
        data.draw(st.lists(st.integers(-50, 50), min_size=len(seg),
                           max_size=len(seg))), np.float32)
    got = np.asarray(SS.deliver(jnp.asarray(vals), jnp.asarray(seg), nseg))
    want = np.zeros(nseg, np.float32)
    np.add.at(want, seg, vals)
    assert np.array_equal(got, want)
