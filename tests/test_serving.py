"""Serving-runtime unit tests: plan-tensor compiler, caches, scheduler
dispatch invariants, workload determinism, batch-aware group planning, and
the open-loop replay harness.  Bit-level scheduler-vs-sequential conformance
lives in test_conformance.py (the serving leg of the matrix)."""
import numpy as np
import pytest

from repro.core import engine as E
from repro.core import query as Q
from repro.core.planner import Planner
from repro.core.stats import GraphStats
from repro.graphdata.queries import QueryInstance, make_workload
from repro.serving import (BatchScheduler, ExecutableCache, PlanCache,
                           compile_plan_tensor, graph_fingerprint,
                           replay_workload)
from repro.serving.compile import pad_batch_size


# ---------------------------------------------------------------- compiler
def test_pad_batch_size_pow2():
    assert [pad_batch_size(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


def test_compile_plan_tensor_padding(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=3, seed=1)
    pt = compile_plan_tensor([i.qry for i in wl])
    assert pt.n_real == 3 and pt.params.shape[0] == 4 and pt.n_pad == 1
    # pad rows repeat the first instance's parameters
    assert np.array_equal(pt.params[3], pt.params[0])
    assert np.array_equal(pt.params[0], Q.query_params(wl[0].qry))


def test_compile_rejects_mixed_shapes(medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=1, seed=2)
    with pytest.raises(ValueError):
        compile_plan_tensor([wl[0].qry, wl[1].qry])


# ------------------------------------------------------------------ caches
def test_graph_fingerprint_content_keyed(small_static_graph,
                                         medium_static_graph):
    fp1 = graph_fingerprint(small_static_graph)
    assert fp1 == graph_fingerprint(small_static_graph)   # cached + stable
    assert fp1 != graph_fingerprint(medium_static_graph)


def test_steady_state_no_replan_no_retrace(medium_static_graph):
    """Second flush of the same workload shape: every plan and executable
    lookup hits — steady-state serving re-plans and re-traces nothing."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=4, seed=3)
    plan_cache, exec_cache = PlanCache(), ExecutableCache()
    first = BatchScheduler(medium_static_graph, plan_cache=plan_cache,
                           exec_cache=exec_cache).run(wl)
    assert plan_cache.stats.hits == 0
    p_miss, e_miss = plan_cache.stats.misses, exec_cache.stats.misses
    again = BatchScheduler(medium_static_graph, plan_cache=plan_cache,
                           exec_cache=exec_cache).run(wl)
    assert plan_cache.stats.misses == p_miss
    assert exec_cache.stats.misses == e_miss
    assert plan_cache.stats.hits > 0 and exec_cache.stats.hits > 0
    for a, b in zip(first, again):
        assert a.count == b.count and a.split == b.split


# --------------------------------------------------------------- scheduler
def test_scheduler_groups_mixed_workload(medium_static_graph):
    """A mixed drain (plain + aggregate templates) forms one group per shape
    bucket and serves every group batched — no per-query fallback."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=5, seed=4)
    wla = make_workload(medium_static_graph, templates=("Q2",),
                        n_per_template=3, seed=5, aggregate=True)
    sched = BatchScheduler(medium_static_graph)
    res = sched.run(wl + wla)
    assert len(res) == len(wl) + len(wla)
    assert len(sched.last_dispatches) == 3          # Q2, Q4, Q2-agg buckets
    assert sorted(d.n_real for d in sched.last_dispatches) == [3, 5, 5]
    by_idx = {i: r for i, r in enumerate(res)}
    for disp in sched.last_dispatches:
        for i in disp.indices:
            assert by_idx[i].batch_size == disp.n_real
    # results in submission order, equal to sequential execution
    for inst, r in zip(wl + wla, res):
        want = E.count_results(medium_static_graph, inst.qry, split=r.split)
        assert r.count == want, (inst.template, r.count, want)


def test_scheduler_aggregate_and_partitioned_batched(small_dynamic_graph):
    """The two classes the legacy batched mode fell back on — aggregates and
    the partitioned engine — dispatch as single vmapped groups."""
    from repro.core import engine_partitioned as EP
    wla = make_workload(small_dynamic_graph, templates=("Q3",),
                        n_per_template=4, seed=6, aggregate=True)
    sched = BatchScheduler(small_dynamic_graph, engine="partitioned",
                           n_workers=2, keep_outputs=True)
    res = sched.run(wla)
    assert len(sched.last_dispatches) == 1
    assert sched.last_dispatches[0].engine == "partitioned"
    assert sched.last_dispatches[0].n_real == 4
    for inst, r in zip(wla, res):
        out = EP.execute(small_dynamic_graph, inst.qry, split=r.split,
                         mode=sched._mode_for(inst.qry),
                         n_buckets=sched.n_buckets, n_workers=2)
        assert np.array_equal(np.asarray(out.total), r.total)
        assert np.array_equal(np.asarray(out.per_vertex), r.per_vertex)


def test_scheduler_failing_group_isolated(medium_static_graph):
    """A group that cannot build (MIN/MAX forced onto the sliced engine) must
    return error results without dropping the other groups in the flush."""
    import dataclasses as dc
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=3, seed=10)
    bad = QueryInstance("Q2-min", dc.replace(
        wl[0].qry, agg_op=Q.AGG_MIN, agg_key=next(iter(
            medium_static_graph.meta["builder"].key_ids.values()))), {})
    sched = BatchScheduler(medium_static_graph, engine="sliced")
    res = sched.run(wl + [bad])
    assert sched.queued == 0
    good, err = res[:3], res[3]
    assert all(r.ok and r.error == "" for r in good)
    assert not err.ok and "sliceable" in err.error
    for inst, r in zip(wl, good):
        assert r.count == E.count_results(medium_static_graph, inst.qry,
                                          split=r.split)


# ---------------------------------------------------- batch-aware planning
def test_planner_choose_batch_costs_whole_batch(medium_static_graph):
    """choose_batch must minimise the batch-summed cost; estimate_batch sums
    per-instance costs (selectivities differ across instances)."""
    wl = make_workload(medium_static_graph, templates=("Q4",),
                       n_per_template=6, seed=7)
    qs = [i.qry for i in wl]
    planner = Planner(medium_static_graph, GraphStats(medium_static_graph))
    est = planner.choose_batch(qs)
    per_instance = {
        s: sum(planner.estimate(q, s).t_ms for q in qs)
        for s in planner.enumerate_plans(qs[0])
    }
    assert est.t_ms == pytest.approx(min(per_instance.values()))
    assert est.split == min(per_instance, key=per_instance.get)
    with pytest.raises(ValueError):
        wl2 = make_workload(medium_static_graph, templates=("Q2",),
                            n_per_template=1, seed=8)
        planner.choose_batch([qs[0], wl2[0].qry])


def test_scheduler_group_planning_regression(medium_static_graph,
                                             monkeypatch):
    """Regression for the (removed) run_workload_batched planning bug, now
    pinned on its replacement: the scheduler's group split must come from
    the batch-aware planner over ALL group instances, not from the first
    instance alone."""
    from repro.launch.query import GraniteServer
    server = GraniteServer(medium_static_graph, use_planner=True)
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=4, seed=9)
    seen = []
    orig = Planner.choose_batch

    def spy(self, queries, *args, **kwargs):
        seen.append(len(queries))
        return orig(self, queries, *args, **kwargs)

    monkeypatch.setattr(Planner, "choose_batch", spy)
    bat = server.run_workload_scheduled(wl, warm=False)
    assert seen == [4, 4]                  # whole group, once per bucket
    seq = server.run_workload(wl)
    for a, b in zip(seq, bat):
        assert a.count == b.count, (a.template, a.count, b.count)
    assert all(r.ok for r in bat)


# ------------------------------------------------------------ determinism
def test_make_workload_deterministic(medium_static_graph):
    wl1 = make_workload(medium_static_graph, n_per_template=3, seed=13)
    wl2 = make_workload(medium_static_graph, n_per_template=3, seed=13)
    wl3 = make_workload(medium_static_graph, n_per_template=3, seed=14)
    assert len(wl1) == len(wl2)
    for a, b in zip(wl1, wl2):
        assert a.template == b.template and a.params == b.params
        assert np.array_equal(Q.query_params(a.qry), Q.query_params(b.qry))
    assert any(a.params != c.params for a, c in zip(wl1, wl3))
    # explicit rng generator threads through identically
    wl4 = make_workload(medium_static_graph, n_per_template=3,
                        rng=np.random.default_rng(13))
    for a, d in zip(wl1, wl4):
        assert a.params == d.params


def test_replay_deterministic_schedule(medium_static_graph):
    """Same seed → the same workload and arrival process (the reproducible
    inputs of BENCH_serving.json; batching and wall times legitimately vary
    with measured service speed).  The report counts every query once."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=4, seed=15)

    def run_once():
        sched = BatchScheduler(medium_static_graph)
        rep = replay_workload(sched, wl, rate_qps=500.0, seed=16, warm=True)
        return rep

    r1, r2 = run_once(), run_once()
    assert r1.n_queries == r2.n_queries == len(wl)
    assert r1.seed == r2.seed
    assert r1.completion_rate == 1.0
    assert np.all(r1.latencies_ms > 0)
    assert r1.latency_ms_p50 <= r1.latency_ms_p95 <= r1.latency_ms_p99
    d = r1.as_dict()
    assert "latencies_ms" not in d and d["n_queries"] == len(wl)
