"""Interval algebra unit + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import intervals as iv


def brute(op, a, b):
    (a_s, a_e), (b_s, b_e) = a, b
    if a_s >= a_e or b_s >= b_e:
        return False
    return {
        iv.FULLY_BEFORE: a_e <= b_s,
        iv.STARTS_BEFORE: a_s < b_s,
        iv.FULLY_AFTER: a_s >= b_e,
        iv.STARTS_AFTER: a_s > b_s,
        iv.DURING: a_s > b_s and a_e < b_e,
        iv.EQUALS: (a_s, a_e) == (b_s, b_e),
        iv.DURING_EQ: a_s >= b_s and a_e <= b_e,
        iv.OVERLAPS: a_s < b_e and b_s < a_e,
    }[op]


ivs = st.tuples(st.integers(0, 50), st.integers(0, 50))


@settings(max_examples=200, deadline=None)
@given(a=ivs, b=ivs, op=st.sampled_from(list(range(8))))
def test_compare_matches_bruteforce(a, b, op):
    got = bool(iv.compare(op, jnp.asarray(a), jnp.asarray(b)))
    assert got == brute(op, a, b)


def test_intersect_and_empty():
    a = jnp.asarray([[0, 10], [5, 8], [0, 3]])
    b = jnp.asarray([[5, 15], [0, 20], [3, 9]])
    out = iv.intersect(a, b)
    np.testing.assert_array_equal(np.asarray(out), [[5, 10], [5, 8], [3, 3]])
    assert bool(iv.is_empty(out[2])) and not bool(iv.is_empty(out[0]))


@settings(max_examples=100, deadline=None)
@given(a=ivs, b=ivs)
def test_overlaps_symmetric_and_consistent_with_intersect(a, b):
    ja, jb = jnp.asarray(a), jnp.asarray(b)
    ov = bool(iv.overlaps(ja, jb))
    assert ov == bool(iv.overlaps(jb, ja))
    inter = iv.intersect(ja, jb)
    valid = a[0] < a[1] and b[0] < b[1]
    assert ov == (valid and not bool(iv.is_empty(inter)))


def test_bucket_mask_exact_on_aligned():
    edges = iv.bucket_edges(0, 160, 16)
    assert edges[0] == 0 and edges[-1] >= 160
    m = iv.interval_to_bucket_mask(jnp.asarray([10, 30]), jnp.asarray(edges))
    width = edges[1] - edges[0]
    got = np.nonzero(np.asarray(m))[0]
    assert got.min() == 10 // width and got.max() == (30 - 1) // width


@settings(max_examples=100, deadline=None)
@given(s=st.integers(0, 99), e=st.integers(1, 100), B=st.sampled_from([4, 8, 16]))
def test_bucket_mask_covers_interval(s, e, B):
    if s >= e:
        return
    edges = iv.bucket_edges(0, 100, B)
    m = np.asarray(iv.interval_to_bucket_mask(jnp.asarray([s, e]),
                                              jnp.asarray(edges)))
    for b in range(B):
        expect = (s < edges[b + 1]) and (edges[b] < e)
        assert m[b] == expect
