"""Per-architecture reduced-config smoke tests (assignment deliverable f)."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, load_arch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke(arch_id):
    spec = load_arch(arch_id)
    out = spec.smoke()
    assert out.get("ok"), (arch_id, out)
    if "loss" in out:
        assert np.isfinite(out["loss"])


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_shapes_declared(arch_id):
    spec = load_arch(arch_id)
    assert spec.shapes or spec.skip
    # every LM arch must declare all four shapes (as runnable or skipped)
    if spec.family.startswith("lm"):
        names = set(spec.shapes) | set(spec.skip)
        assert {"train_4k", "prefill_32k", "decode_32k", "long_500k"} <= names


def test_lm_decode_matches_forward():
    """Decode path consistency on the reduced gemma3 config (local:global)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.gemma3_4b import SMOKE
    from repro.models import transformer as tr

    p = tr.init_params(SMOKE, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, SMOKE.vocab)
    cache = tr.init_cache(SMOKE, 2, 24)
    outs = []
    for t in range(12):
        lg, cache = tr.decode_step(SMOKE, p, cache, toks[:, t], t + 1)
        outs.append(lg)
    full = tr.forward(SMOKE, p, toks)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=2e-3)


def test_moe_capacity_drops_bounded():
    """Over-capacity tokens are dropped, never mis-routed: output is finite
    and within the convex hull scale of expert outputs."""
    import jax
    import jax.numpy as jnp
    from repro.configs.olmoe_1b_7b import SMOKE
    from repro.models import transformer as tr

    p = tr.init_params(SMOKE, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, SMOKE.vocab)
    logits = tr.forward(SMOKE, p, toks)
    assert bool(jnp.isfinite(logits).all())
