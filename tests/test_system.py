"""End-to-end system tests: GraniteServer over LDBC graphs + planner +
verification against the oracle (the paper's full pipeline at test scale)."""
import numpy as np
import pytest

from repro.core import engine as E
from repro.core.ref_engine import RefEngine
from repro.graphdata.ldbc import LdbcParams, generate_ldbc
from repro.graphdata.queries import make_workload
from repro.launch.query import GraniteServer


@pytest.fixture(scope="module")
def server(medium_static_graph):
    return GraniteServer(medium_static_graph, use_planner=True)


def test_workload_end_to_end_counts(medium_static_graph, server):
    ref = RefEngine(medium_static_graph)
    wl = make_workload(medium_static_graph, n_per_template=2, seed=10)
    recs = server.run_workload(wl)
    assert all(r.ok for r in recs)
    for inst, rec in zip(wl, recs):
        want = ref.count(inst.qry, mode=E.MODE_STATIC)
        assert rec.count == want, (inst.template, rec.count, want)


def test_workload_completion_within_budget(medium_static_graph, server):
    wl = make_workload(medium_static_graph, n_per_template=3, seed=11)
    recs = server.run_workload(wl)
    assert sum(r.ok for r in recs) == len(recs), "100% completion (paper Tbl 7)"
    assert all(r.latency_ms < 5000 for r in recs)


def test_aggregate_workload(medium_static_graph, server):
    ref = RefEngine(medium_static_graph)
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=1, seed=12, aggregate=True)
    for inst in wl:
        rec = server.execute(inst)
        want = ref.aggregate(inst.qry, mode=E.MODE_STATIC)
        assert rec.ok
        assert rec.count == sum(want.values())


def test_dynamic_graph_end_to_end(small_dynamic_graph):
    server = GraniteServer(small_dynamic_graph)
    assert server.mode == E.MODE_BUCKET
    ref = RefEngine(small_dynamic_graph)
    wl = make_workload(small_dynamic_graph, templates=("Q8",), n_per_template=3,
                       seed=13)
    for inst in wl:
        rec = server.execute(inst)
        want = float(np.sum(ref.count(inst.qry, mode=E.MODE_BUCKET, n_buckets=16)))
        assert rec.ok and rec.count == want


def test_planner_vs_fixed_plans_latency(medium_static_graph):
    """Cost-model-selected plans must not systematically lose to the default
    left-to-right plan (paper Fig. 8)."""
    s_planned = GraniteServer(medium_static_graph, use_planner=True)
    s_default = GraniteServer(medium_static_graph, use_planner=False)
    wl = make_workload(medium_static_graph, templates=("Q2", "Q7"),
                       n_per_template=3, seed=14)
    # min-of-3 to be robust to background load on the shared CPU
    t_planned = min(np.mean([r.latency_ms for r in s_planned.run_workload(wl)])
                    for _ in range(3))
    t_default = min(np.mean([r.latency_ms for r in s_default.run_workload(wl)])
                    for _ in range(3))
    r_planned = s_planned.run_workload(wl)
    r_default = s_default.run_workload(wl)
    for a, b in zip(r_planned, r_default):
        assert a.count == b.count, "plans must agree on results"
    assert t_planned <= t_default * 2.0


def test_four_degree_distributions_generate():
    for dist in ("altmann", "weibull", "facebook", "zipf"):
        g = generate_ldbc(LdbcParams(n_persons=30, degree_dist=dist, seed=1))
        assert g.n_edges > 0
        wl = make_workload(g, templates=("Q2",), n_per_template=1)
        E.count_results(g, wl[0].qry)  # executes without error
