"""Streaming ingestion + epoch-pinned serving (graphdata/ingest.py,
serving/epochs.py).

Pinned invariants:

  * the event log validates referential integrity incrementally (duplicate
    keys, dangling endpoints, lifespan containment, closes that would
    truncate live incident edges);
  * incremental materialization is bit-identical to a from-scratch build of
    every epoch — graphs, traversal tables and fingerprints — across edge
    appends, vertex adds, property sets and interval closes;
  * replay is order-insensitive within an epoch: any permutation of an
    epoch's events yields the same materialized layout fingerprint AND the
    same chained epoch fingerprint (seeded always; hypothesis when
    installed);
  * the conformance matrix's ingestion leg: epoch-pinned serving on all
    three engines stays bit-identical to from-scratch builds while
    ingestion advances between batches, and pinned epochs never observe
    unsealed events (snapshot isolation);
  * delta execution (base graph + padded delta block) is bit-identical to
    the merged epoch graph across modes and aggregates;
  * the scheduler's delta-aware cache behavior: pure edge-append epochs
    re-use plans and the delta executable (cache HITS, zero invalidation),
    compaction evicts exactly the retired fingerprints, and per-partition
    fingerprints evolve only for touched vertex types.
"""
import numpy as np
import pytest

from repro.core import engine as E
from repro.core import query as Q
from repro.graphdata import ingest
from repro.graphdata.ingest import (EventLog, add_edge, add_vertex,
                                    close_edge, close_vertex,
                                    events_fingerprint, log_from_graph,
                                    materialize, set_vprop)
from repro.graphdata.queries import make_workload
from repro.obs.metrics import MetricsRegistry
from repro.serving import BatchScheduler, EpochManager
from repro.serving.cache import graph_fingerprint

from conformance import (ALL_MODES, case_matrix, check_ingestion_case,
                         perturbed_batch)

pytestmark = pytest.mark.ingest


# =========================================================================
# event-log validation
# =========================================================================
def test_event_log_validation():
    log = EventLog(2, 1, (0, 100))
    log.append(add_vertex(0, 0, (0, 50)))
    log.append(add_vertex(1, 1, (10, 100)))
    with pytest.raises(ValueError, match="duplicate vertex"):
        log.append(add_vertex(0, 0, (0, 50)))
    with pytest.raises(ValueError, match="type .* out of range"):
        log.append(add_vertex(2, 5, (0, 50)))
    with pytest.raises(ValueError, match="empty vertex lifespan"):
        log.append(add_vertex(2, 0, (30, 30)))
    with pytest.raises(ValueError, match="unknown vertex"):
        log.append(add_edge(0, 0, 9, 0, (10, 40)))
    with pytest.raises(ValueError, match="outside vertex"):
        log.append(add_edge(0, 0, 1, 0, (5, 40)))   # starts before v1
    log.append(add_edge(0, 0, 1, 0, (10, 40)))
    with pytest.raises(ValueError, match="duplicate edge"):
        log.append(add_edge(0, 1, 0, 0, (10, 40)))
    with pytest.raises(ValueError, match="truncates a live incident edge"):
        log.append(close_vertex(0, 20))
    log.append(close_edge(0, 30))
    # the incident-edge bound is conservative: it tracks the max lifespan
    # any incident edge was ADDED with, so the vertex close must clear 40
    log.append(close_vertex(0, 40))
    with pytest.raises(ValueError, match="unknown entity"):
        log.append(set_vprop(7, 0, 1, (0, 10)))
    assert log.n_open == len(log)
    log.seal()
    assert log.n_open == 0 and log.n_epochs == 1
    g = materialize(log)
    assert g.n_vertices == 2 and g.n_edges == 1
    assert tuple(g.e_life[0]) == (10, 30)
    assert tuple(g.v_life[0]) == (0, 40)


def test_epoch0_rebuilds_source_graph(small_dynamic_graph):
    g = small_dynamic_graph
    log, held = log_from_graph(g)
    assert held == []
    g0 = materialize(log)
    assert np.array_equal(g0.v_type, g.v_type)
    assert np.array_equal(g0.v_life, g.v_life)
    assert g0.n_edges == g.n_edges
    # edges re-sort into canonical key order; compare as row sets
    rows = lambda gg: {tuple(r) for r in np.stack(
        [gg.e_src, gg.e_dst, gg.e_type, gg.e_life[:, 0], gg.e_life[:, 1]],
        axis=1)}
    assert rows(g0) == rows(g)
    # property columns may re-pivot slot order; compare populated row sets
    for pk, col in g.vprops.items():
        want = {(int(e), int(col.vals[e, s]), *map(int, col.life[e, s]))
                for e, s in zip(*np.nonzero(col.vals != ingest.NO_VALUE))}
        c0 = g0.vprops[pk]
        got = {(int(e), int(c0.vals[e, s]), *map(int, c0.life[e, s]))
               for e, s in zip(*np.nonzero(c0.vals != ingest.NO_VALUE))}
        assert got == want, pk


# =========================================================================
# incremental == from-scratch, across epoch varieties
# =========================================================================
def _mixed_epochs(g, log, held):
    """Three epochs: pure edge appends; vertex adds + props + an edge to a
    new vertex; closes on both base and appended entities."""
    V, EE = g.n_vertices, g.n_edges
    person = g.meta["builder"].v_type_ids["person"]
    lo, hi = g.lifespan
    yield held[: len(held) // 2]
    nv = [add_vertex(V, person, (lo, hi)), add_vertex(V + 1, person, (lo, hi))]
    pk = sorted(g.vprops)[0]
    yield nv + [set_vprop(V, pk, 7, (lo, hi)),
                add_edge(EE, V, V + 1, 0, (lo + 1, hi)),
                *held[len(held) // 2:]]
    # close just after the edge's start — always valid, truncates its life
    yield [close_edge(held[0].key, int(held[0].data[3]) + 1)]


def test_incremental_matches_materialize(small_dynamic_graph):
    g = small_dynamic_graph
    log, held = log_from_graph(g, holdout_edges=12, seed=3)
    mat = ingest.Materializer(log)
    mat.apply_next()
    for k, events in enumerate(_mixed_epochs(g, log, held), start=2):
        log.extend(events)
        log.seal()
        inc = mat.apply_next()
        ref = materialize(log, k)
        assert graph_fingerprint(inc) == graph_fingerprint(ref), k
        for f in ("t_src", "t_dst", "t_life", "t_type", "t_isfwd", "t_eid",
                  "arr_ptr"):
            assert np.array_equal(inc.traversal[f], ref.traversal[f]), (k, f)
    # the close on an appended edge keeps the window delta-pure; the
    # vertex/prop epoch broke it earlier
    assert not mat.delta_pure


def test_delta_purity_tracking(small_dynamic_graph):
    log, held = log_from_graph(small_dynamic_graph, holdout_edges=8, seed=1)
    mat = ingest.Materializer(log)
    mat.apply_next()
    log.extend(held[:4])
    log.seal()
    mat.apply_next()
    assert mat.delta_pure and mat.delta_spec() is not None
    # close on an APPENDED edge keeps purity; close on a BASE edge breaks it
    log.append(close_edge(held[0].key, int(held[0].data[3]) + 1))
    log.seal()
    mat.apply_next()
    assert mat.delta_pure
    base_key = next(k for k in range(small_dynamic_graph.n_edges)
                    if k not in {h.key for h in held})
    log.append(close_edge(base_key, int(log._e[base_key][2]) + 1))
    log.seal()
    mat.apply_next()
    assert not mat.delta_pure and mat.delta_spec() is None
    mat.compact()
    assert mat.delta_pure


# =========================================================================
# replay order-insensitivity (the satellite property test)
# =========================================================================
def _permuted_fingerprints(graph, perm_seed: int):
    log, held = log_from_graph(graph, holdout_edges=10, seed=2)
    base_events = log.epoch_events(0)
    rng = np.random.default_rng(perm_seed)
    log2 = EventLog(graph.n_vertex_types, graph.n_edge_types, graph.lifespan,
                    meta=dict(graph.meta), validate=False)
    log2.extend([base_events[i] for i in rng.permutation(len(base_events))])
    log2.seal()
    for lg, evs in ((log, held), (log2,
                                  [held[i]
                                   for i in rng.permutation(len(held))])):
        lg.extend(evs)
        lg.seal()
    fp1 = graph_fingerprint(materialize(log, 2))
    fp2 = graph_fingerprint(materialize(log2, 2))
    e1 = events_fingerprint("seed", log.epoch_events(1))
    e2 = events_fingerprint("seed", log2.epoch_events(1))
    return fp1, fp2, e1, e2


def test_replay_order_insensitive_seeded(small_dynamic_graph):
    for seed in (0, 1, 2, 3):
        fp1, fp2, e1, e2 = _permuted_fingerprints(small_dynamic_graph, seed)
        assert fp1 == fp2, seed
        assert e1 == e2, seed


def test_replay_order_insensitive_hypothesis(small_dynamic_graph):
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need the optional hypothesis "
        "dep (pip install hypothesis)")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def prop(seed):
        fp1, fp2, e1, e2 = _permuted_fingerprints(small_dynamic_graph, seed)
        assert fp1 == fp2 and e1 == e2

    prop()


# =========================================================================
# conformance ingestion leg: all three engines, serving during ingestion
# =========================================================================
@pytest.mark.conformance
@pytest.mark.parametrize("case_name", ["plain-2hop", "plain-bidir",
                                       "agg-min-2hop"])
@pytest.mark.parametrize("mode", ALL_MODES)
def test_conformance_ingestion_leg(small_dynamic_graph, case_name, mode):
    case = case_matrix(small_dynamic_graph)[case_name]
    check_ingestion_case(small_dynamic_graph, case, mode)


# =========================================================================
# delta execution == merged execution
# =========================================================================
@pytest.mark.parametrize("mode", ALL_MODES)
def test_delta_executable_matches_merged(small_dynamic_graph, mode):
    g = small_dynamic_graph
    log, held = log_from_graph(g, holdout_edges=20, seed=4)
    mat = ingest.Materializer(log)
    base = mat.apply_next()
    log.extend(held)
    log.seal()
    merged = mat.apply_next()
    spec = mat.delta_spec()
    assert spec is not None and spec.n_edges == len(held)
    cases = case_matrix(g)
    for name in ("plain-2hop", "plain-bidir", "agg-count", "agg-min-2hop"):
        qry = cases[name].qry
        batch = perturbed_batch(qry, 3)
        split = 0 if qry.agg_op != Q.AGG_NONE else qry.n_vertices - 1
        run = E.batch_executable_delta(base, qry, split=split, mode=mode)
        params = np.stack([Q.query_params(q) for q in batch])
        got = run(params, spec.device())
        want = E.execute_batch_out(merged, batch, split=split, mode=mode,
                                   sliced=False)
        for field in ("total", "per_vertex", "minmax"):
            w, o = getattr(want, field), getattr(got, field)
            if w is None and o is None:
                continue
            assert np.array_equal(np.asarray(w), np.asarray(o)), (name, field)


def test_delta_executable_rejects_etr(small_dynamic_graph):
    case = next(c for n, c in case_matrix(small_dynamic_graph).items()
                if n.startswith("etr-"))
    with pytest.raises(ValueError, match="ETR"):
        E.batch_executable_delta(small_dynamic_graph, case.qry)


# =========================================================================
# scheduler: epoch pinning, delta dispatch, cache metrics
# =========================================================================
def test_scheduler_epoch_pinning_and_cache_metrics(small_dynamic_graph):
    g = small_dynamic_graph
    log, held = log_from_graph(g, holdout_edges=30, seed=7)
    mx = MetricsRegistry()
    mgr = EpochManager(log, compact_every=10, metrics=mx)
    e0 = mgr.seal()
    wl = [i.qry for i in make_workload(e0.graph, n_per_template=1, seed=11)]
    sched = BatchScheduler(e0.graph, metrics=mx)
    mgr.attach(sched)
    assert sched.pinned_epoch is e0 and e0.compacted

    cache = mx.counter("granite_cache_total", "serving cache events",
                       labelnames=("cache", "event"))
    counts = lambda ev: cache.value(cache="executable", event=ev)

    sched.run(wl)
    miss0 = counts("miss")
    assert miss0 > 0 and counts("invalidation") == 0

    # epoch 1: pure edge appends — delta dispatch, no invalidation
    mgr.ingest(held[:15])
    ep1 = mgr.advance(sched)
    assert not ep1.compacted and ep1.delta is not None
    sched.run(wl)
    nd1 = sum(1 for d in sched.last_dispatches if d.delta)
    assert nd1 > 0
    assert counts("invalidation") == 0

    # epoch 2: same shape groups — the delta executable must now HIT
    hits1 = counts("hit")
    mgr.ingest(held[15:])
    ep2 = mgr.advance(sched)
    assert not ep2.compacted
    sched.run(wl)
    assert sum(1 for d in sched.last_dispatches if d.delta) == nd1
    assert counts("hit") > hits1

    # part fingerprints evolve only for touched types
    touched = {t for ev in log.epoch_events(2)
               for t in (int(g.v_type[ev.data[0]]), int(g.v_type[ev.data[1]]))}
    for t, fp in ep2.part_fingerprints.items():
        if t in touched:
            assert fp != ep1.part_fingerprints[t], t
        else:
            assert fp == ep1.part_fingerprints[t], t

    # snapshot isolation: unsealed events don't perturb pinned results
    before = sched.run(wl)
    mgr.ingest([add_vertex(g.n_vertices, 0, g.lifespan)])
    after = sched.run(wl)
    for a, b in zip(before, after):
        assert np.array_equal(np.asarray(a.total), np.asarray(b.total))

    # compaction: retired fingerprints evicted and counted
    ep3 = mgr.advance(sched, compact=True)
    assert ep3.compacted and counts("invalidation") > 0
    assert ep3.base_fingerprint != ep1.base_fingerprint
    sched.run(wl)
    ref = BatchScheduler(materialize(log, log.n_epochs)).run(wl)
    got = sched.run(wl)
    for a, b in zip(got, ref):
        assert np.array_equal(np.asarray(a.total), np.asarray(b.total))
        assert np.array_equal(np.asarray(a.per_vertex),
                              np.asarray(b.per_vertex))


# =========================================================================
# partition extension
# =========================================================================
def test_partition_extension_consistent(small_dynamic_graph):
    from repro.core import engine_partitioned as EP

    g = small_dynamic_graph
    log, held = log_from_graph(g, holdout_edges=10, seed=9)
    mgr = EpochManager(log)
    e0 = mgr.seal()
    # warm the base partitioning cache, as the serving path would
    base_part, _, _ = EP.partition_for(e0.graph, 2)
    mgr.ingest(held)
    ep = mgr.seal()
    assert getattr(ep.graph, "_partition_hint", None) is not None
    part, _, _ = EP.partition_for(ep.graph, 2)
    # extension: every base vertex keeps its part assignment
    remap = mgr.mat._remap_from_base
    assert np.array_equal(part.part_of[remap], base_part.part_of)
    assert part.n_parts == base_part.n_parts
