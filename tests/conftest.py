import os
import sys

# Tests run on the single real CPU device (the 512-device override is ONLY in
# launch/dryrun.py, per the assignment).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.graphdata.ldbc import LdbcParams, generate_ldbc


@pytest.fixture(scope="session")
def small_static_graph():
    return generate_ldbc(LdbcParams(n_persons=60, seed=3, dynamic=False))


@pytest.fixture(scope="session")
def small_dynamic_graph():
    return generate_ldbc(LdbcParams(n_persons=40, seed=5, dynamic=True))


@pytest.fixture(scope="session")
def medium_static_graph():
    return generate_ldbc(LdbcParams(n_persons=200, seed=9, dynamic=False))
