import os
import sys

# Tests run on the single real CPU device (the 512-device override is ONLY in
# launch/dryrun.py, per the assignment).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import gc

import numpy as np
import pytest

from repro.graphdata.ldbc import LdbcParams, generate_ldbc


@pytest.fixture(autouse=True, scope="module")
def _bound_jax_jit_footprint():
    """XLA:CPU's JIT segfaults once enough compiled executables accumulate in
    one process (reproducible: `pytest -x -q` dies in backend_compile ~175
    tests in, on a test that passes in isolation — jaxlib 0.4.x, CPU).  Drop
    executable references at module boundaries so the live code footprint
    stays bounded; within a module nothing is evicted, so steady-state
    caching behavior (and everything the serving tests assert about cache
    hits) is untouched."""
    import jax
    jax.clear_caches()
    gc.collect()
    yield


@pytest.fixture(scope="session")
def small_static_graph():
    return generate_ldbc(LdbcParams(n_persons=60, seed=3, dynamic=False))


@pytest.fixture(scope="session")
def small_dynamic_graph():
    return generate_ldbc(LdbcParams(n_persons=40, seed=5, dynamic=True))


@pytest.fixture(scope="session")
def medium_static_graph():
    return generate_ldbc(LdbcParams(n_persons=200, seed=9, dynamic=False))
