"""Cost model: statistics accuracy, recurrences, plan discrimination."""
import numpy as np
import pytest

from repro.core import engine as E
from repro.core import query as Q
from repro.core.planner import Planner, fit_linear, load_coeffs
from repro.core.stats import GraphStats
from repro.graphdata.queries import make_workload


@pytest.fixture(scope="module")
def stats(medium_static_graph):
    return GraphStats(medium_static_graph, n_time_buckets=16)


@pytest.fixture(scope="module")
def planner(medium_static_graph, stats):
    return Planner(medium_static_graph, stats)


def test_histogram_frequency_accuracy(medium_static_graph, stats):
    """H(val, full-lifespan) should approximate exact value counts."""
    g = medium_static_graph
    b = g.meta["builder"]
    k = b.key_ids["country"]
    col = g.vprops[k]
    vals = col.vals.reshape(-1)
    vals = vals[vals >= 0]
    uniq, cnts = np.unique(vals, return_counts=True)
    for v, c in list(zip(uniq, cnts))[:8]:
        h = stats.h_lookup(k, int(v), None)
        assert h.f > 0
        # tiled estimate within 3x of exact (variance-bounded tiles)
        assert 0.33 * c <= h.f <= 3.0 * c, (v, c, h.f)


def test_degree_table(medium_static_graph, stats):
    g = medium_static_graph
    b = g.meta["builder"]
    vt, et = b.v_type_ids, b.e_type_ids
    d = stats.degree(vt["person"], et["follows"], Q.DIR_OUT)
    exact = (g.e_type == et["follows"]).sum() / g.type_counts[vt["person"]]
    assert abs(d - exact) / max(exact, 1) < 0.05


def test_estimates_monotone_in_hops(planner, medium_static_graph):
    wl = make_workload(medium_static_graph, templates=("Q4",), n_per_template=1)
    est = planner.estimate(wl[0].qry, split=wl[0].qry.n_vertices - 1)
    assert est.t_ms > 0
    assert len(est.steps) == wl[0].qry.n_vertices


def test_choose_returns_valid_split(planner, medium_static_graph):
    wl = make_workload(medium_static_graph, n_per_template=2)
    for inst in wl:
        best = planner.choose(inst.qry)
        assert 0 <= best.split < inst.qry.n_vertices
        if inst.qry.agg_op != Q.AGG_NONE:
            assert best.split == 0


def test_etr_selectivity_sampled(stats):
    for op, p in stats.etr_select.items():
        assert 0.0 <= p <= 1.0
    # before+after ≈ complement-ish on interval starts
    sb = stats.etr_select[1]   # starts-before
    sa = stats.etr_select[3]   # starts-after
    assert 0.8 <= sb + sa <= 1.05


def test_stats_size_reported(stats):
    rep = stats.size_report()
    assert rep["n_tiles"] > 0
    assert rep["bytes_tiled"] <= rep["bytes_raw"]


def test_fit_linear_recovers_coefficients():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(200, 3))
    theta = np.asarray([2.0, -1.0, 0.5])
    y = X @ theta + rng.normal(scale=1e-3, size=200)
    got = fit_linear(X, y)
    np.testing.assert_allclose(got, theta, atol=1e-2)


def test_cost_model_discriminates(medium_static_graph, planner):
    """The planner's *ranking* should correlate with actual execution: the
    chosen plan should not be the worst plan (paper Sec. 6.4 criterion)."""
    import time
    wl = make_workload(medium_static_graph, templates=("Q2", "Q7"),
                       n_per_template=2, seed=4)
    for inst in wl:
        times = {}
        for split in range(inst.qry.n_vertices):
            E.count_results(medium_static_graph, inst.qry, split=split)  # warm
            t0 = time.perf_counter()
            for _ in range(3):
                E.count_results(medium_static_graph, inst.qry, split=split)
            times[split] = time.perf_counter() - t0
        chosen = planner.choose(inst.qry).split
        worst = max(times, key=times.get)
        best = min(times, key=times.get)
        # allow ties within noise: chosen must be within 2x of best
        assert times[chosen] <= max(2.0 * times[best], times[worst] * 0.999), (
            inst.template, chosen, times)
