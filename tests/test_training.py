"""Optimizer, schedules, train loop, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.compression import (CompressionCfg, compress, decompress,
                                        init_error_state)
from repro.training.optimizer import OptCfg, apply_updates, init_state, schedule_lr
from repro.training.train_loop import make_train_step


def test_schedules():
    for sched in ("const", "cosine", "wsd"):
        cfg = OptCfg(lr=1e-3, schedule=sched, warmup_steps=10, total_steps=100)
        lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in range(101)]
        assert lrs[0] < lrs[10] * 0.5, "warmup ramps"
        assert abs(lrs[10] - 1e-3) < 1e-9
        if sched == "wsd":
            assert lrs[50] == pytest.approx(1e-3), "stable plateau"
            assert lrs[100] < 2e-4, "fast final decay"
        if sched == "cosine":
            assert lrs[100] < lrs[50] < lrs[11]


def test_adamw_converges_quadratic():
    cfg = OptCfg(lr=0.1, schedule="const", warmup_steps=0, weight_decay=0.0,
                 clip_norm=None)
    params = dict(w=jnp.asarray([5.0, -3.0]))
    state = init_state(params)
    for _ in range(300):
        grads = dict(w=2 * params["w"])
        params, state, _ = apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_train_step_loss_decreases():
    from repro.configs.minicpm_2b import SMOKE
    from repro.models import transformer as tr

    params = tr.init_params(SMOKE, jax.random.PRNGKey(0))
    opt = init_state(params)
    cfg = OptCfg(lr=3e-3, schedule="const", warmup_steps=0)
    step = make_train_step(lambda p, b: tr.loss_fn(SMOKE, p, b), cfg, donate=False)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, SMOKE.vocab)
    batch = dict(tokens=toks, labels=toks)
    losses = []
    for _ in range(20):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::5]


def test_microbatch_equivalence():
    from repro.configs.llama3_405b import SMOKE
    from repro.models import transformer as tr

    params = tr.init_params(SMOKE, jax.random.PRNGKey(0))
    cfg = OptCfg(lr=1e-3, schedule="const", warmup_steps=0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, SMOKE.vocab)
    batch = dict(tokens=toks, labels=toks)
    s1 = make_train_step(lambda p, b: tr.loss_fn(SMOKE, p, b), cfg, 1, donate=False)
    s2 = make_train_step(lambda p, b: tr.loss_fn(SMOKE, p, b), cfg, 2, donate=False)
    p1, _, m1 = s1(params, init_state(params), batch)
    p2, _, m2 = s2(params, init_state(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    d = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree_util.tree_leaves(d)) < 5e-3


@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_compression_error_feedback(kind):
    """Error feedback makes repeated compression unbiased: summed decoded
    gradients converge to summed true gradients."""
    rng = np.random.default_rng(0)
    cfg = CompressionCfg(kind=kind, topk_frac=0.2)
    g_true = dict(w=jnp.asarray(rng.normal(size=(64,)), jnp.float32))
    err = init_error_state(g_true)
    total_dec, total_true = jnp.zeros(64), jnp.zeros(64)
    for _ in range(30):
        payload, err = compress(cfg, g_true, err)
        dec = decompress(cfg, payload, g_true)
        total_dec = total_dec + dec["w"]
        total_true = total_true + g_true["w"]
    rel = float(jnp.linalg.norm(total_dec - total_true) /
                jnp.linalg.norm(total_true))
    assert rel < 0.1, rel


def test_dp_train_step_with_compression_single_axis():
    """shard_map DP path with compressed psum (axis size 1 on CPU —
    exercises the full compress/psum/decompress graph)."""
    from repro.configs.llama3_405b import SMOKE
    from repro.models import transformer as tr
    from repro.training.train_loop import make_dp_train_step

    mesh = jax.make_mesh((1,), ("data",))
    params = tr.init_params(SMOKE, jax.random.PRNGKey(0))
    opt = init_state(params)
    err = init_error_state(params)
    cfg = OptCfg(lr=1e-3, schedule="const", warmup_steps=0)
    step = make_dp_train_step(lambda p, b: tr.loss_fn(SMOKE, p, b), cfg, mesh,
                              CompressionCfg(kind="int8"))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, SMOKE.vocab)
    batch = dict(tokens=toks, labels=toks)
    with mesh:
        p2, o2, e2, m = step(params, opt, err, batch)
    assert np.isfinite(float(m["loss"]))
    moved = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()),
                                   params, p2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0
