"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle.

The ``kernels`` marker selects the hop-kernel equivalence leg (fused hop
kernel vs the superstep XLA path across the temporal-mode × aggregate
matrix, empty blocks, padded slots, layout invariants) that scripts/ci.sh
runs as its own full-gate step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import query as Q
from repro.core import superstep as SS
from repro.core.intervals import bucket_edges
from repro.kernels import hop_scatter as HK
from repro.kernels.bucket_scatter import bucket_scatter, bucket_scatter_ref
from repro.kernels.bucket_scatter.ops import build_layout
from repro.kernels.common import check_impl, resolve_interpret
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.interval_warp import interval_warp, interval_warp_ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 4, 2, 128, 64),
    (2, 8, 8, 256, 64),
    (1, 8, 1, 128, 128),   # MQA
    (2, 2, 2, 192, 32),    # non-pow2 seq (pad path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64), (False, None)])
def test_flash_attention_sweep(B, Hq, Hkv, S, D, dtype, causal, window):
    if not causal and S % 64 != 0:
        pytest.skip("non-causal pallas path requires divisible Sk")
    q = jnp.asarray(RNG.normal(size=(B, Hq, S, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), dtype)
    want = attention_ref(q, k, v, causal=causal, window=window)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          impl="pallas_interpret", block_q=64, block_k=64)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_attention_decode_offset():
    q = jnp.asarray(RNG.normal(size=(2, 4, 1, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 2, 256, 64)), jnp.float32)
    for cache_len in (64, 199, 256):
        want = attention_ref(q, k, v, causal=True, q_offset=cache_len - 1)
        got = flash_attention(q, k, v, causal=True, q_offset=cache_len - 1,
                              impl="pallas_interpret", block_q=8, block_k=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("E,V,C", [(1000, 100, 8), (5000, 700, 16), (300, 512, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bucket_scatter_sweep(E, V, C, dtype):
    seg = np.sort(RNG.integers(0, V, size=E)).astype(np.int32)
    contrib = jnp.asarray(RNG.normal(size=(E, C)), dtype)
    lay = build_layout(seg, V, block_v=128, block_e_mult=128)
    want = bucket_scatter_ref(contrib, jnp.asarray(seg), V)
    got = bucket_scatter(contrib, jnp.asarray(seg), V, layout=lay,
                         impl="pallas", interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_bucket_scatter_empty_segments():
    seg = np.asarray([3, 3, 9], np.int32)
    contrib = jnp.ones((3, 2), jnp.float32)
    lay = build_layout(seg, 16, block_v=8, block_e_mult=8)
    got = bucket_scatter(contrib, jnp.asarray(seg), 16, layout=lay,
                         impl="pallas", interpret=True)
    want = np.zeros((16, 2))
    want[3] = 2
    want[9] = 1
    np.testing.assert_allclose(np.asarray(got), want)


@pytest.mark.parametrize("N,B", [(512, 8), (3000, 16), (100, 32)])
def test_interval_warp_sweep(N, B):
    cnts = jnp.asarray(RNG.normal(size=(N, B)), jnp.float32)
    ivl = np.stack([RNG.integers(0, 500, N), RNG.integers(0, 1100, N)], 1)
    be = jnp.asarray(bucket_edges(0, 1096, B))
    want = interval_warp_ref(cnts, jnp.asarray(ivl.astype(np.int32)), be)
    got = interval_warp(cnts, jnp.asarray(ivl.astype(np.int32)), be,
                        impl="pallas", interpret=True, block_n=256)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("V,D,Bb,L", [(1000, 32, 64, 8), (257, 16, 33, 3),
                                      (4096, 64, 16, 1)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_sweep(V, D, Bb, L, mode):
    table = jnp.asarray(RNG.normal(size=(V, D)), jnp.float32)
    idx = RNG.integers(-1, V, size=(Bb, L)).astype(np.int32)
    want = embedding_bag_ref(table, jnp.asarray(idx), mode)
    got = embedding_bag(table, jnp.asarray(idx), mode=mode, impl="pallas",
                        interpret=True, block_b=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_embedding_bag_all_padding():
    table = jnp.ones((10, 4), jnp.float32)
    idx = jnp.full((4, 3), -1, jnp.int32)
    got = embedding_bag(table, idx, impl="pallas", interpret=True, block_b=4)
    np.testing.assert_array_equal(np.asarray(got), np.zeros((4, 4)))


# =========================================================================
# hop_scatter: the fused hop kernel vs the superstep XLA path
# =========================================================================
def _hop_problem(V=97, E=900, n_buckets=6, seed=0):
    """A random one-hop problem with INTEGER counts (the engine's invariant
    that makes kernel and XLA sums bit-identical)."""
    rng = np.random.default_rng(seed)
    t_dst = np.sort(rng.integers(0, V, E)).astype(np.int32)
    t_src = jnp.asarray(rng.integers(0, V, E).astype(np.int32))
    wmask = jnp.asarray(rng.random(E) < 0.6)
    bedges = jnp.asarray(bucket_edges(0, 960, n_buckets))
    return V, E, t_dst, t_src, wmask, bedges, rng


def _mode_state(rng, V, E, mode, B):
    if mode == SS.MODE_STATIC:
        return (jnp.asarray(rng.integers(0, 9, V).astype(np.float32)), None)
    if mode == SS.MODE_BUCKET:
        return (jnp.asarray(rng.integers(0, 9, (V, B)).astype(np.float32)),
                jnp.asarray(rng.random((E, B)) < 0.7))
    ivl = np.sort(rng.integers(0, 960, (E, 2)), axis=1).astype(np.int32)
    return (jnp.asarray(rng.integers(0, 4, (V, B, B + 1)).astype(np.float32)),
            jnp.asarray(ivl))


def _xla_hop(state, t_src, wmask, evalid, t_dst, V, mode, mch=None,
             op=Q.AGG_MIN):
    sv = state[t_src]
    cnt = SS.apply_edge(sv, wmask, evalid, mode)
    arr = SS.deliver(cnt, jnp.asarray(t_dst), V)
    if mch is None:
        return arr, None
    m_e = SS.minmax_edge(mch[t_src], cnt, op, mode)
    return arr, SS.deliver_extremum(m_e, jnp.asarray(t_dst), V, op)


@pytest.mark.kernels
@pytest.mark.parametrize("mode", [SS.MODE_STATIC, SS.MODE_BUCKET,
                                  SS.MODE_INTERVAL])
@pytest.mark.parametrize("agg", ["count", "min", "max"])
@pytest.mark.parametrize("block_v", [None, 32])   # single-block & multi-block
def test_hop_kernel_vs_deliver(mode, agg, block_v):
    """The conformance cell of the kernel layer: fused gather→mask→reduce ≡
    the three-step XLA hop, bit for bit, per temporal mode × aggregate."""
    B = 6
    V, E, t_dst, t_src, wmask, bedges, rng = _hop_problem()
    state, evalid = _mode_state(rng, V, E, mode, B)
    lay = HK.build_hop_layout(t_dst, V, block_v=block_v, block_e_mult=128)
    mch = (None if agg == "count"
           else jnp.asarray(rng.random(V).astype(np.float32)))
    op = Q.AGG_MIN if agg == "min" else Q.AGG_MAX
    with SS.bucket_scope(bedges):
        want, want_m = jax.jit(
            lambda s, w, e, m: _xla_hop(s, t_src, w, e, t_dst, V, mode, m, op)
        )(state, wmask, evalid, mch)
        got, got_m = jax.jit(
            lambda s, w, e, m: SS.fused_hop_deliver(
                s, t_src, w, e, mode, lay.tables, lay.block_v, V,
                impl="pallas_interpret", mch=m, minmax_op=op)
        )(state, wmask, evalid, mch)
    assert np.array_equal(np.asarray(want), np.asarray(got))
    if mch is not None:
        assert np.array_equal(np.asarray(want_m), np.asarray(got_m))


@pytest.mark.kernels
@pytest.mark.parametrize("mode", [SS.MODE_STATIC, SS.MODE_BUCKET])
def test_hop_kernel_empty_blocks(mode):
    """Whole destination blocks without edges (and trailing edgeless
    destinations) deliver exact zeros / extremum neutrals."""
    B = 4
    V, E = 100, 60
    rng = np.random.default_rng(3)
    # all edges arrive in [0, 20) → blocks past the first are empty
    t_dst = np.sort(rng.integers(0, 20, E)).astype(np.int32)
    t_src = jnp.asarray(rng.integers(0, V, E).astype(np.int32))
    wmask = jnp.asarray(np.ones(E, bool))
    bedges = jnp.asarray(bucket_edges(0, 960, B))
    state, evalid = _mode_state(rng, V, E, mode, B)
    lay = HK.build_hop_layout(t_dst, V, block_v=16, block_e_mult=128)
    mch = jnp.asarray(rng.random(V).astype(np.float32))
    with SS.bucket_scope(bedges):
        want, want_m = _xla_hop(state, t_src, wmask, evalid, t_dst, V, mode,
                                mch)
        got, got_m = SS.fused_hop_deliver(
            state, t_src, wmask, evalid, mode, lay.tables, lay.block_v, V,
            impl="pallas_interpret", mch=mch)
    assert np.array_equal(np.asarray(want), np.asarray(got))
    assert np.array_equal(np.asarray(want_m), np.asarray(got_m))
    assert float(np.abs(np.asarray(got)[20:]).sum()) == 0.0
    assert (np.asarray(got_m)[20:] == np.inf).all()


@pytest.mark.kernels
def test_hop_kernel_padded_slots():
    """Pad slots (forced-oversized block_e) read the zero row and contribute
    nothing; src sentinels (out-of-table sources) do the same."""
    V, E, t_dst, t_src, wmask, bedges, rng = _hop_problem(V=40, E=50)
    state, evalid = _mode_state(rng, V, E, SS.MODE_BUCKET, 6)
    # sentinel sources: point some edges at the zero row (slot V)
    src_sentinel = jnp.where(jnp.arange(E) % 5 == 0, V, t_src)
    lay = HK.build_hop_layout(t_dst, V, block_v=None, block_e_mult=512)
    assert lay.block_e >= 512 > E    # real padding exercised
    with SS.bucket_scope(bedges):
        state_p = jnp.concatenate([state, jnp.zeros((1, 6), state.dtype)])
        want, _ = _xla_hop(state_p, src_sentinel, wmask, evalid, t_dst, V,
                           SS.MODE_BUCKET)
        got, _ = SS.fused_hop_deliver(
            state, src_sentinel, wmask, evalid, SS.MODE_BUCKET, lay.tables,
            lay.block_v, V, impl="pallas_interpret")
    assert np.array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.kernels
def test_scatter_deliver_and_extremum_vs_xla():
    """Delivery-only entries (the ETR-hop path): blocked prefix reduce and
    masked extremum ≡ segment_sum / segment_min over the same layout."""
    V, E, t_dst, t_src, wmask, bedges, rng = _hop_problem(V=70, E=400)
    cnt = jnp.asarray(rng.integers(0, 7, (E, 5)).astype(np.float32))
    lay = HK.build_hop_layout(t_dst, V, block_v=32, block_e_mult=128)
    want = SS.deliver(cnt, jnp.asarray(t_dst), V)
    got = SS.deliver(cnt, jnp.asarray(t_dst), V, impl="pallas_interpret",
                     layout=lay)
    assert np.array_equal(np.asarray(want), np.asarray(got))
    m_e = jnp.asarray(rng.random(E).astype(np.float32))
    for op in (Q.AGG_MIN, Q.AGG_MAX):
        want_m = SS.deliver_extremum(m_e, jnp.asarray(t_dst), V, op)
        got_m = SS.deliver_extremum(m_e, jnp.asarray(t_dst), V, op,
                                    impl="pallas_interpret", layout=lay)
        assert np.array_equal(np.asarray(want_m), np.asarray(got_m))


@pytest.mark.kernels
def test_worker_layouts_share_slot_shape():
    """Per-worker layouts stack: one (n_blocks, block_e, block_v) across
    ragged shards, pads delivering to the sliced-off trash segment."""
    rng = np.random.default_rng(5)
    v_max, W = 30, 3
    rows = []
    for w in range(W):
        n = rng.integers(10, 60)
        seg = np.sort(rng.integers(0, v_max, n)).astype(np.int32)
        rows.append(np.concatenate([seg, np.full(80 - n, v_max, np.int32)]))
    layouts = HK.build_worker_layouts(np.stack(rows), v_max + 1)
    assert len({(l.n_blocks, l.block_e, l.block_v) for l in layouts}) == 1
    tables = HK.stack_layout_tables(layouts)
    assert tables["hop_ldst"].shape[0] == W
    cnt = jnp.asarray(rng.integers(0, 5, (W, 80, 2)).astype(np.float32))
    lt = {k[len("hop_"):]: v for k, v in tables.items()}
    got = jax.vmap(lambda c, t: HK.scatter_deliver(
        c, t, v_max + 1, layouts[0].block_v))(cnt, lt)
    want = jax.vmap(lambda c, d: SS.deliver(c, d, v_max + 1))(
        cnt, jnp.asarray(np.stack(rows)))
    assert np.array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.kernels
def test_build_hop_layout_invariants_hypothesis():
    """Property test: every edge lands in exactly one valid slot, block-local
    destinations stay in range, and the boundary tables tile each block's
    real slots exactly."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 200), st.integers(0, 500), st.integers(0, 2 ** 31),
           st.sampled_from([None, 16, 64]))
    def check(num_segments, n_edges, seed, block_v):
        rng = np.random.default_rng(seed)
        seg = np.sort(rng.integers(0, num_segments, n_edges)).astype(np.int32)
        lay = HK.build_hop_layout(seg, num_segments, block_v=block_v,
                                  block_e_mult=128)
        host = lay.host
        # every edge placed exactly once, in ascending order per block
        placed = np.sort(host.gather_idx[host.valid])
        assert np.array_equal(placed, np.arange(n_edges))
        valid2d = host.valid.reshape(host.n_blocks, host.block_e)
        # valid slots are a prefix of each block; local dst within range
        for b in range(host.n_blocks):
            n = int(valid2d[b].sum())
            assert valid2d[b, :n].all() and not valid2d[b, n:].any()
            ld = host.local_dst[b, :n]
            assert ((ld >= 0) & (ld < host.block_v)).all()
            # boundary tables tile the block's real slots exactly
            ss = np.asarray(lay.seg_start)[b]
            se = np.asarray(lay.seg_end)[b]
            assert (se >= ss).all()
            assert int((se - ss).sum()) == n
            # slot runs agree with the membership table
            for v in range(min(host.block_v,
                               num_segments - b * host.block_v)):
                run = np.arange(ss[v], se[v])
                assert (ld[run] == v).all()

    check()


@pytest.mark.kernels
def test_impl_selection_idiom():
    """The shared impl/interpret idiom: auto-interpret only on CPU backends,
    pallas_interpret always forces the interpreter, bad impls fail loudly."""
    on_cpu = jax.default_backend() == "cpu"
    assert resolve_interpret(None, "pallas") == on_cpu
    assert resolve_interpret(None, "pallas_interpret") is True
    assert resolve_interpret(False, "pallas_interpret") is True
    assert resolve_interpret(True, "pallas") is True
    assert resolve_interpret(False, "pallas") is False
    with pytest.raises(ValueError):
        check_impl("cuda")
    with pytest.raises(ValueError):
        SS.deliver(jnp.zeros((4,)), jnp.zeros((4,), jnp.int32), 2,
                   impl="nope")


@pytest.mark.kernels
def test_build_hop_layout_invariants_deterministic():
    """The same invariants over a fixed seed sweep, so the leg keeps its
    teeth on hosts without the optional hypothesis dep."""
    for seed, num_segments, n_edges, block_v in [
        (0, 1, 0, None), (1, 7, 13, 16), (2, 200, 500, 64),
        (3, 129, 128, None), (4, 64, 300, 16), (5, 33, 1, 64),
    ]:
        rng = np.random.default_rng(seed)
        seg = np.sort(rng.integers(0, num_segments, n_edges)).astype(np.int32)
        lay = HK.build_hop_layout(seg, num_segments, block_v=block_v,
                                  block_e_mult=128)
        host = lay.host
        placed = np.sort(host.gather_idx[host.valid])
        assert np.array_equal(placed, np.arange(n_edges))
        valid2d = host.valid.reshape(host.n_blocks, host.block_e)
        for b in range(host.n_blocks):
            n = int(valid2d[b].sum())
            assert valid2d[b, :n].all() and not valid2d[b, n:].any()
            ld = host.local_dst[b, :n]
            assert ((ld >= 0) & (ld < host.block_v)).all()
            ss = np.asarray(lay.seg_start)[b]
            se = np.asarray(lay.seg_end)[b]
            assert (se >= ss).all() and int((se - ss).sum()) == n
            for v in range(min(host.block_v,
                               num_segments - b * host.block_v)):
                assert (ld[np.arange(ss[v], se[v])] == v).all()
