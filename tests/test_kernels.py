"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.intervals import bucket_edges
from repro.kernels.bucket_scatter import bucket_scatter, bucket_scatter_ref
from repro.kernels.bucket_scatter.ops import build_layout
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.interval_warp import interval_warp, interval_warp_ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 4, 2, 128, 64),
    (2, 8, 8, 256, 64),
    (1, 8, 1, 128, 128),   # MQA
    (2, 2, 2, 192, 32),    # non-pow2 seq (pad path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64), (False, None)])
def test_flash_attention_sweep(B, Hq, Hkv, S, D, dtype, causal, window):
    if not causal and S % 64 != 0:
        pytest.skip("non-causal pallas path requires divisible Sk")
    q = jnp.asarray(RNG.normal(size=(B, Hq, S, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), dtype)
    want = attention_ref(q, k, v, causal=causal, window=window)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          impl="pallas_interpret", block_q=64, block_k=64)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_attention_decode_offset():
    q = jnp.asarray(RNG.normal(size=(2, 4, 1, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 2, 256, 64)), jnp.float32)
    for cache_len in (64, 199, 256):
        want = attention_ref(q, k, v, causal=True, q_offset=cache_len - 1)
        got = flash_attention(q, k, v, causal=True, q_offset=cache_len - 1,
                              impl="pallas_interpret", block_q=8, block_k=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("E,V,C", [(1000, 100, 8), (5000, 700, 16), (300, 512, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bucket_scatter_sweep(E, V, C, dtype):
    seg = np.sort(RNG.integers(0, V, size=E)).astype(np.int32)
    contrib = jnp.asarray(RNG.normal(size=(E, C)), dtype)
    lay = build_layout(seg, V, block_v=128, block_e_mult=128)
    want = bucket_scatter_ref(contrib, jnp.asarray(seg), V)
    got = bucket_scatter(contrib, jnp.asarray(seg), V, layout=lay,
                         impl="pallas", interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_bucket_scatter_empty_segments():
    seg = np.asarray([3, 3, 9], np.int32)
    contrib = jnp.ones((3, 2), jnp.float32)
    lay = build_layout(seg, 16, block_v=8, block_e_mult=8)
    got = bucket_scatter(contrib, jnp.asarray(seg), 16, layout=lay,
                         impl="pallas", interpret=True)
    want = np.zeros((16, 2))
    want[3] = 2
    want[9] = 1
    np.testing.assert_allclose(np.asarray(got), want)


@pytest.mark.parametrize("N,B", [(512, 8), (3000, 16), (100, 32)])
def test_interval_warp_sweep(N, B):
    cnts = jnp.asarray(RNG.normal(size=(N, B)), jnp.float32)
    ivl = np.stack([RNG.integers(0, 500, N), RNG.integers(0, 1100, N)], 1)
    be = jnp.asarray(bucket_edges(0, 1096, B))
    want = interval_warp_ref(cnts, jnp.asarray(ivl.astype(np.int32)), be)
    got = interval_warp(cnts, jnp.asarray(ivl.astype(np.int32)), be,
                        impl="pallas", interpret=True, block_n=256)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("V,D,Bb,L", [(1000, 32, 64, 8), (257, 16, 33, 3),
                                      (4096, 64, 16, 1)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_sweep(V, D, Bb, L, mode):
    table = jnp.asarray(RNG.normal(size=(V, D)), jnp.float32)
    idx = RNG.integers(-1, V, size=(Bb, L)).astype(np.int32)
    want = embedding_bag_ref(table, jnp.asarray(idx), mode)
    got = embedding_bag(table, jnp.asarray(idx), mode=mode, impl="pallas",
                        interpret=True, block_b=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_embedding_bag_all_padding():
    table = jnp.ones((10, 4), jnp.float32)
    idx = jnp.full((4, 3), -1, jnp.int32)
    got = embedding_bag(table, idx, impl="pallas", interpret=True, block_b=4)
    np.testing.assert_array_equal(np.asarray(got), np.zeros((4, 4)))
