"""The conformance matrix as tier-1 tests (smoke scale; ci.sh runs the same
matrix with CONFORMANCE_SCALE=ci: full worker sweep + all ETR operators)."""
import numpy as np
import pytest

import conformance as C
from repro.core import engine as E
from repro.core import query as Q
from repro.core.ref_engine import RefEngine

# tier-1 runs this file at smoke scale; scripts/ci.sh re-selects it BY
# MARKER (`-m conformance`) with CONFORMANCE_SCALE=ci for the full sweep
pytestmark = pytest.mark.conformance

# Parametrization must be collection-time static: list the names the matrix
# generates (the ci-only ETR sweep is appended when the env says so).
_SMOKE_NAMES = [
    "plain-2hop", "plain-bidir", "etr-before", "etr-overlaps",
    "agg-count", "agg-min", "agg-max", "agg-min-2hop", "etr-agg-count",
    "empty-result", "single-vertex",
]
_CI_NAMES = ["etr-starts-before", "etr-after", "etr-starts-after"]
CASE_NAMES = _SMOKE_NAMES + (_CI_NAMES if C.scale() == "ci" else [])


@pytest.fixture(scope="module")
def matrix(small_dynamic_graph):
    cases = C.case_matrix(small_dynamic_graph)
    assert set(CASE_NAMES) <= set(cases), "matrix drifted from CASE_NAMES"
    return cases


@pytest.fixture(scope="module")
def oracle(small_dynamic_graph):
    return RefEngine(small_dynamic_graph)


@pytest.mark.parametrize("mode", C.ALL_MODES)
@pytest.mark.parametrize("name", CASE_NAMES)
def test_conformance_matrix(small_dynamic_graph, matrix, oracle, name, mode):
    C.check_case(small_dynamic_graph, oracle, matrix[name], mode)


def test_matrix_covers_acceptance_surface(matrix):
    """MIN/MAX aggregates and ETR hops must run the full worker sweep, so the
    matrix itself proves the acceptance combinations execute partitioned."""
    for name, case in matrix.items():
        if name.startswith(("agg-min", "agg-max", "etr-")):
            assert case.workers == C.WORKERS_FULL, name
    kinds = set()
    for case in matrix.values():
        kinds.add(("agg", case.qry.agg_op))
        kinds.add(("etr", any(e.etr_op != -1 for e in case.qry.e_preds)))
    assert {("agg", Q.AGG_COUNT), ("agg", Q.AGG_MIN), ("agg", Q.AGG_MAX),
            ("agg", Q.AGG_NONE), ("etr", True), ("etr", False)} <= kinds


def test_matrix_exercises_matches(small_dynamic_graph, matrix):
    """The generated matrix must not be vacuous: most non-empty cases
    produce results in static mode."""
    nonzero = 0
    for name, case in matrix.items():
        if case.expect_empty:
            continue
        out = E.execute(small_dynamic_graph, case.qry, mode=E.MODE_STATIC,
                        n_buckets=C.N_BUCKETS, sliced=False)
        nonzero += float(np.sum(np.asarray(out.total))) > 0
    assert nonzero >= 6, "conformance matrix queries mostly match nothing"


@pytest.mark.parametrize("mode", C.ALL_MODES)
@pytest.mark.parametrize("name", CASE_NAMES)
def test_serving_conformance_matrix(small_dynamic_graph, matrix, name, mode):
    """Serving leg: a batched scheduler dispatch of each matrix cell must be
    bit-identical to the sequential per-query loop on every engine, with the
    whole batch served by ONE vmapped call (zero per-query fallbacks — the
    aggregate and partitioned cells are exactly the ones the legacy batched
    mode fell back on)."""
    C.check_serving_case(small_dynamic_graph, matrix[name], mode)


@pytest.mark.parametrize("engine,n_workers", [("dense", 0),
                                              ("partitioned", 2)])
def test_serving_kernel_impl_matches_xla(small_dynamic_graph, matrix, engine,
                                         n_workers):
    """Scheduler dispatches on the fused-kernel lowering are bit-identical
    to the xla dispatches (representative cells; the multidevice leg and the
    kernels leg cover the full matrix)."""
    from repro.serving import BatchScheduler

    for name in ("plain-2hop", "agg-min"):
        queries = C.perturbed_batch(matrix[name].qry, 3)
        outs = {}
        for impl in ("xla", "pallas"):
            sched = BatchScheduler(small_dynamic_graph, engine=engine,
                                   mode=E.MODE_BUCKET, n_buckets=C.N_BUCKETS,
                                   n_workers=max(n_workers, 1),
                                   keep_outputs=True, impl=impl)
            res = sched.run(queries)
            assert len(sched.last_dispatches) == 1
            assert sched.last_dispatches[0].impl == impl
            outs[impl] = res
        for a, b in zip(outs["xla"], outs["pallas"]):
            assert a.split == b.split, name
            for field in ("total", "per_vertex", "minmax"):
                x, y = getattr(a, field), getattr(b, field)
                if x is None and y is None:
                    continue
                assert np.array_equal(x, y), (name, engine, field)


def test_serving_empty_batch(small_dynamic_graph):
    from repro.serving import BatchScheduler
    sched = BatchScheduler(small_dynamic_graph)
    assert sched.flush() == []
    assert sched.run([]) == []
    assert sched.last_dispatches == []


def test_serving_single_query_batch(small_dynamic_graph, matrix):
    """A batch of one is a degenerate-but-legal group: same result as the
    sequential call, dispatched batched (B padded to 1, no fallback)."""
    from repro.serving import BatchScheduler
    case = matrix["agg-min"]
    sched = BatchScheduler(small_dynamic_graph, mode=E.MODE_STATIC,
                           n_buckets=C.N_BUCKETS, keep_outputs=True)
    (r,) = sched.run([case.qry])
    assert len(sched.last_dispatches) == 1
    assert sched.last_dispatches[0].n_real == 1
    out = E.execute(small_dynamic_graph, case.qry, split=r.split,
                    mode=E.MODE_STATIC, n_buckets=C.N_BUCKETS, sliced=False)
    assert np.array_equal(np.asarray(out.total), r.total)
    assert np.array_equal(np.asarray(out.minmax), r.minmax)


def test_minmax_across_etr_rejected_everywhere(small_dynamic_graph):
    """The one intentionally unsupported combination fails loudly (and
    identically) on the dense AND partitioned paths."""
    from repro.core import engine_partitioned as EP
    b = small_dynamic_graph.meta["builder"]
    vt, et, k = b.v_type_ids, b.e_type_ids, b.key_ids
    qry = Q.PathQuery(
        v_preds=(Q.VertexPredicate(vt["person"]),
                 Q.VertexPredicate(vt["person"]),
                 Q.VertexPredicate(vt["post"])),
        e_preds=(Q.EdgePredicate(et["follows"], Q.DIR_OUT),
                 Q.EdgePredicate(et["created"], Q.DIR_OUT, etr_op=7),),
        agg_op=Q.AGG_MIN, agg_key=k["length"],
    )
    with pytest.raises(NotImplementedError):
        E.execute(small_dynamic_graph, qry, sliced=False)
    with pytest.raises(NotImplementedError):
        EP.execute(small_dynamic_graph, qry, n_workers=2)
