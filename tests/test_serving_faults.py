"""Fault-tolerance tests: deterministic chaos injection through the serving
stack — retry bit-identity, deadline-aware retry budgets, poison-query
quarantine bisection, worker-loss dense fallback with probe recovery, and
WAL torn-tail crash recovery — plus the completion property: any seeded
FaultPlan with rates < 1.0 still answers-or-structured-rejects 100% of the
workload (never an unhandled exception).
"""
import numpy as np
import pytest

from repro.graphdata.ingest import log_from_graph
from repro.graphdata.queries import make_workload
from repro.obs import MetricsRegistry
from repro.serving import (AdmissionPolicy, BatchScheduler, EpochManager,
                           FaultPlan, RetryPolicy, TornWriteError)
from repro.serving.faults import FAULT_POINTS
from repro.serving.testing import (FakeDispatcher, constant_service_model,
                                   fake_count)

pytestmark = pytest.mark.fault

TERMINAL = ("done", "failed", "quarantined", "timeout")


def _sched(graph, **kw):
    kw.setdefault("dispatcher",
                  FakeDispatcher(service_model=constant_service_model(1e-3)))
    kw.setdefault("retry", RetryPolicy())
    return BatchScheduler(graph, **kw)


# --------------------------------------------------------------- fault plan
def test_fault_plan_deterministic_and_interleaving_independent():
    """Decisions are keyed (seed, point, k): the same plan config replays
    identically, and the per-point streams don't perturb each other."""
    kw = dict(seed=42, rates={"dispatch": 0.4, "compile": 0.2})
    a, b = FaultPlan(**kw), FaultPlan(**kw)
    seq_a = [a.should_fail("dispatch") for _ in range(50)]
    # interleave a foreign point's consultations in plan b
    seq_b = []
    for _ in range(50):
        b.should_fail("compile")
        seq_b.append(b.should_fail("dispatch"))
    assert seq_a == seq_b and any(seq_a) and not all(seq_a)
    assert a.report()["fired"]["dispatch"] == sum(seq_a)


def test_fault_plan_schedule_and_validation():
    plan = FaultPlan(schedule={"wal": {0, 2}})
    assert [plan.should_fail("wal") for _ in range(4)] == [
        True, False, True, False]
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultPlan(rates={"disk": 0.5})


# ------------------------------------------------------- retry bit-identity
def test_transient_fault_retried_bit_identical(medium_static_graph):
    """An injected transient dispatch error is retried with accounted
    backoff and the answers are bit-identical to a fault-free run."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=3, seed=21)
    ref = _sched(medium_static_graph, retry=None).run(wl)
    mx = MetricsRegistry()
    sched = _sched(medium_static_graph, metrics=mx,
                   fault_plan=FaultPlan(schedule={"dispatch": {0}}))
    res = sched.run(wl)
    assert [r.status for r in res] == ["done"] * len(wl)
    assert [r.count for r in res] == [r.count for r in ref]
    assert [r.count for r in res] == [fake_count(i.qry) for i in wl]
    rep = sched.fault_report()
    assert rep["n_retries"] == 1 and rep["n_quarantined"] == 0
    assert mx.counter("granite_retries_total", labelnames=("kind",)).value(
        kind="dispatch") == 1
    # the retried group's latency carries the accounted backoff penalty
    hit = [d for d in sched.last_dispatches if d.n_retries][0]
    assert hit.penalty_s > 0 and hit.service_s > hit.penalty_s


def test_backoff_penalty_accounted_not_slept(medium_static_graph):
    """Retry backoff inflates the client-visible latency (virtual clock),
    never the telemetry/θ-refit service time."""
    import time
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=2, seed=22)
    sched = _sched(medium_static_graph,
                   retry=RetryPolicy(base_delay_s=30.0, max_delay_s=30.0,
                                     jitter_frac=0.0),
                   fault_plan=FaultPlan(schedule={"dispatch": {0}}))
    t0 = time.perf_counter()
    res = sched.run(wl)
    assert time.perf_counter() - t0 < 5.0          # 30 s delay never slept
    assert all(r.status == "done" for r in res)
    assert all(r.latency_ms > 1e3 for r in res)    # ...but fully accounted


# ------------------------------------------------------ deadline-aware retry
def test_retry_respects_deadline_budget(medium_static_graph):
    """A retry whose backoff lands past the group's EDF deadline never
    fires: with no admission path left, the group times out with a
    structured error instead of blowing the deadline silently."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=3, seed=23)
    sched = _sched(medium_static_graph,
                   retry=RetryPolicy(base_delay_s=10.0, jitter_frac=0.0,
                                     max_group_failures=99),
                   fault_plan=FaultPlan(rates={"dispatch": 1.0}))
    for inst in wl:
        sched.submit(inst, deadline_s=1.0, now=0.0)
    res = sched.flush()
    assert [r.status for r in res] == ["timeout"] * len(wl)
    assert all(not r.ok and "deadline" in r.error for r in res)
    assert sched.fault_report()["n_timeout"] == len(wl)


def test_deadline_breach_reenters_admission(medium_static_graph):
    """When admission is attached, a deadline-breaching retry re-enters
    admission with the remaining budget and earns one immediate attempt —
    here the fault was transient, so the group still answers."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=3, seed=24)
    sched = _sched(medium_static_graph, admission=AdmissionPolicy(),
                   retry=RetryPolicy(base_delay_s=10.0, jitter_frac=0.0),
                   fault_plan=FaultPlan(schedule={"dispatch": {0}}))
    for inst in wl:
        assert sched.submit(inst, deadline_s=1.0, now=0.0).admitted
    res = sched.flush()
    assert [r.status for r in res] == ["done"] * len(wl)
    assert [r.count for r in res] == [fake_count(i.qry) for i in wl]
    assert sched.fault_report()["n_timeout"] == 0


# --------------------------------------------------------------- quarantine
def test_quarantine_bisects_to_exactly_the_poison_query(medium_static_graph):
    """A deterministically-failing group bisects down to the single poison
    query, which is rejected with a structured error while every other
    member of the batch still answers — 100% workload completion."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=8, seed=25)
    bad = wl[3].qry
    mx = MetricsRegistry()
    sched = _sched(medium_static_graph, metrics=mx,
                   fault_plan=FaultPlan(poison=lambda q: q is bad))
    res = sched.run(wl)
    assert [r.status for r in res] == [
        "done"] * 3 + ["quarantined"] + ["done"] * 4
    assert "quarantined" in res[3].error
    for inst, r in zip(wl, res):
        if r.status == "done":
            assert r.count == fake_count(inst.qry)
    rep = sched.fault_report()
    assert rep["n_quarantined"] == 1
    assert mx.counter("granite_quarantined_total").value() == 1


# -------------------------------------------------------------- worker loss
def test_worker_loss_falls_back_dense_then_probes(medium_static_graph):
    """Losing a partition worker re-plans the unit onto the dense executor
    (same answers), holds the partitioned path down for ``probe_after``
    flushes, then probes and restores it."""
    wl = make_workload(medium_static_graph, templates=("Q2",),
                       n_per_template=4, seed=26)
    expect = [fake_count(i.qry) for i in wl]
    mx = MetricsRegistry()
    sched = _sched(medium_static_graph, engine="partitioned", metrics=mx,
                   retry=RetryPolicy(probe_after=2),
                   fault_plan=FaultPlan(schedule={"worker": {0}}))
    res1 = sched.run(wl)                      # worker dies mid-dispatch
    assert [r.engine for r in res1] == ["dense"] * len(wl)
    assert [r.count for r in res1] == expect
    assert sched.last_dispatches[0].fallback_from == "partitioned"
    assert not sched.fault_report()["partitioned_available"]

    res2 = sched.run(wl)                      # down window: no probe yet
    assert [r.engine for r in res2] == ["dense"] * len(wl)
    assert not sched.fault_report()["partitioned_available"]

    res3 = sched.run(wl)                      # probe fires and succeeds
    assert [r.engine for r in res3] == ["partitioned"] * len(wl)
    assert [r.count for r in res3] == expect
    assert sched.fault_report()["partitioned_available"]
    assert mx.counter("granite_degraded_dispatches_total",
                      labelnames=("reason",)).value(reason="worker-loss") == 1
    assert mx.counter("granite_degraded_dispatches_total",
                      labelnames=("reason",)).value(reason="path-down") == 1


# ------------------------------------------------------------- WAL recovery
def _build_epochs(graph, path, holdout=60, fault_plan=None):
    log, held = log_from_graph(graph, holdout_edges=holdout, seed=7)
    log.attach_wal(path, fault_plan=fault_plan)
    mgr = EpochManager(log, compact_every=2)
    mgr.seal()
    mgr.ingest(held[:20])
    mgr.seal()
    mgr.ingest(held[20:40])
    mgr.seal()
    return mgr, held


def test_wal_clean_recovery_bit_identical(small_static_graph, tmp_path):
    """Recovering a cleanly-written WAL replays every sealed epoch to the
    exact pre-crash pinned fingerprint (compaction decisions journaled)."""
    wal = str(tmp_path / "clean.wal")
    mgr, _ = _build_epochs(small_static_graph, wal)
    pre = mgr.current
    mgr.log.close_wal()
    mx = MetricsRegistry()
    mgr2 = EpochManager.recover(wal, compact_every=2, metrics=mx)
    assert mgr2.current.fingerprint == pre.fingerprint
    assert mgr2.current.compacted == pre.compacted
    assert mgr2.log.n_epochs == 3 and mgr2.log.n_open == 0
    assert mx.counter("granite_recovery_epochs").value() == 3
    fp = {t: f for t, f in pre.part_fingerprints.items()}
    assert mgr2.current.part_fingerprints == fp


def test_wal_torn_tail_recovery(small_static_graph, tmp_path):
    """A write torn mid-line (simulated crash) is truncated at recovery:
    the log rebuilds to the last intact record, every sealed epoch replays
    bit-identically, and ingestion continues on the re-attached WAL."""
    wal = str(tmp_path / "torn.wal")
    mgr, held = _build_epochs(small_static_graph, wal)
    pre_fp = mgr.current.fingerprint
    # re-attach with a plan that tears the 3rd post-attach append mid-line
    mgr.log.close_wal()
    mgr.log.attach_wal(wal, fault_plan=FaultPlan(schedule={"wal": {2}}))
    with pytest.raises(TornWriteError):
        mgr.ingest(held[40:])
    del mgr                                    # the crash

    mgr2 = EpochManager.recover(wal, compact_every=2)
    assert mgr2.current.fingerprint == pre_fp  # sealed state fully intact
    assert mgr2.log.n_epochs == 3
    survivors = mgr2.log.n_open                # appends before the tear
    assert survivors == 2
    # ingestion continues where it left off: same final graph as a run
    # that never crashed
    mgr2.ingest(held[40 + survivors:])
    ep = mgr2.seal()
    ref_mgr, _ = _build_epochs(
        small_static_graph, str(tmp_path / "ref.wal"))
    ref_mgr.ingest(held[40:])
    assert ep.fingerprint == ref_mgr.seal().fingerprint


# ---------------------------------------------------- completion (property)
def _completion_case(graph, wl, seed, rates, deadline_s=None):
    """One seeded chaos run; returns statuses after asserting the
    completion contract (terminal status for every query, done answers
    bit-identical to the fault-free reference)."""
    plan = FaultPlan(seed=seed, rates=rates)
    sched = _sched(graph, fault_plan=plan)
    for inst in wl:
        if deadline_s is None:
            sched.submit(inst)
        else:
            sched.submit(inst, deadline_s=deadline_s, now=0.0)
    res = sched.flush()
    assert len(res) == len(wl)
    for inst, r in zip(wl, res):
        assert r.status in TERMINAL
        assert r.status != "failed", r.error   # only STRUCTURED outcomes
        if r.status == "done":
            assert r.count == fake_count(inst.qry)
        else:
            assert not r.ok and r.error
    return [r.status for r in res]


def test_seeded_chaos_sweep_completes(medium_static_graph):
    """The completion property, concretely: across seeds and fault rates
    < 1.0, every query gets an answer or a structured reject — never an
    unhandled exception, never a silently-dropped query."""
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4", "Q6"),
                       n_per_template=3, seed=27)
    n_done = 0
    for seed in range(6):
        statuses = _completion_case(
            medium_static_graph, wl, seed,
            rates={"dispatch": 0.3, "compile": 0.15, "straggler": 0.2})
        n_done += statuses.count("done")
    assert n_done > 0                          # chaos didn't reject the world


def test_property_chaos_completion_hypothesis(medium_static_graph):
    """Hypothesis-deepened sweep over (seed, rate) when the optional dep is
    installed (pip install hypothesis); the seeded sweep above runs always."""
    hyp = pytest.importorskip(
        "hypothesis",
        reason="property tests need the optional hypothesis dep "
               "(pip install hypothesis)")
    st = pytest.importorskip("hypothesis.strategies")
    wl = make_workload(medium_static_graph, templates=("Q2", "Q4"),
                       n_per_template=2, seed=28)

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(seed=st.integers(0, 2 ** 16),
               rate=st.floats(0.0, 0.9),
               point=st.sampled_from([p for p in FAULT_POINTS
                                      if p != "wal"]))
    def prop(seed, rate, point):
        _completion_case(medium_static_graph, wl, seed, rates={point: rate})

    prop()
