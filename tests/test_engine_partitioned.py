"""Partitioned executor ≡ dense engine (bit-identical), partitioner arrays
invariants, exchange accounting, and the distribution-aware cost model."""
import numpy as np
import pytest

from repro.core import engine as E
from repro.core import engine_partitioned as EP
from repro.graphdata.partitioner import (build_partition_arrays,
                                         partition_graph)
from repro.graphdata.queries import make_workload

ALL_MODES = (E.MODE_STATIC, E.MODE_BUCKET, E.MODE_INTERVAL)
WORKERS = (2, 4, 8)


# ---------------------------------------------------------------- arrays
def _arrays(graph, w):
    return build_partition_arrays(
        graph, partition_graph(graph, n_workers=w, parts_per_type=4))


def test_partition_arrays_cover_exactly_once(medium_static_graph):
    g = medium_static_graph
    for w in WORKERS:
        pa = _arrays(g, w)
        own = pa.own_ids[pa.own_ids < g.n_vertices]
        assert own.shape[0] == g.n_vertices
        assert np.array_equal(np.sort(own), np.arange(g.n_vertices))
        eids = pa.edge_ids[pa.edge_ids < 2 * g.n_edges]
        assert np.array_equal(np.sort(eids), np.arange(2 * g.n_edges))


def test_partition_arrays_edges_follow_arrival_owner(medium_static_graph):
    g = medium_static_graph
    pa = _arrays(g, 4)
    t_dst = g.traversal["t_dst"]
    t_src = g.traversal["t_src"]
    for w in range(4):
        eids = pa.edge_ids[w][pa.edge_ids[w] < 2 * g.n_edges]
        # every owned edge arrives at a vertex this worker owns ...
        assert (pa.owner_of_vertex[t_dst[eids]] == w).all()
        # ... in canonical (arrival-sorted) order
        assert np.array_equal(eids, np.sort(eids))
        # halo covers exactly the sources of the owned edges
        halo = pa.halo_ids[w][: pa.n_halo[w]]
        assert set(t_src[eids]) == set(halo.tolist())


def test_partition_arrays_balanced_and_deterministic(medium_static_graph):
    g = medium_static_graph
    pa1 = _arrays(g, 4)
    pa2 = _arrays(g, 4)
    assert np.array_equal(pa1.own_ids, pa2.own_ids)
    assert np.array_equal(pa1.edge_ids, pa2.edge_ids)
    # round-robin typed sub-partitions keep owned-vertex counts balanced
    assert pa1.n_own.max() <= 2.0 * max(pa1.n_own.mean(), 1)
    assert pa1.exchange_volume() == int(pa1.n_ghost.sum()) > 0


# ---------------------------------------------------------------- parity
def test_partitioned_equals_dense_all_modes(small_dynamic_graph):
    """Acceptance: bit-identical totals for all modes × n_workers ∈ {2,4,8}."""
    g = small_dynamic_graph
    wl = make_workload(g, n_per_template=1, seed=33)
    nonzero = 0
    for inst in wl:
        for mode in ALL_MODES:
            want = np.asarray(
                E.execute(g, inst.qry, mode=mode, n_buckets=8,
                          sliced=False).total)
            for w in WORKERS:
                got = np.asarray(
                    EP.execute(g, inst.qry, mode=mode, n_buckets=8,
                               n_workers=w).total)
                assert np.array_equal(got, want), (inst.template, mode, w)
            nonzero += float(np.sum(want)) > 0
    assert nonzero >= 5  # the workload must actually exercise matches


def test_partitioned_all_splits(small_static_graph):
    g = small_static_graph
    inst = make_workload(g, templates=("Q4",), n_per_template=1, seed=7)[0]
    for split in range(inst.qry.n_vertices):
        want = E.count_results(g, inst.qry, split=split, sliced=False)
        got = EP.count_results(g, inst.qry, split=split, n_workers=4)
        assert got == want, (split, got, want)


def test_partitioned_count_aggregate(small_static_graph):
    g = small_static_graph
    inst = make_workload(g, templates=("Q2",), n_per_template=1, seed=5,
                         aggregate=True)[0]
    dense = E.execute(g, inst.qry, sliced=False)
    part = EP.execute(g, inst.qry, n_workers=4)
    assert np.array_equal(np.asarray(dense.per_vertex),
                          np.asarray(part.per_vertex))


def test_partitioned_rejects_minmax(small_static_graph):
    from repro.core import query as Q
    g = small_static_graph
    inst = make_workload(g, templates=("Q2",), n_per_template=1, seed=5,
                         aggregate=True)[0]
    qry = Q.PathQuery(inst.qry.v_preds, inst.qry.e_preds, agg_op=Q.AGG_MIN,
                      agg_key=0)
    with pytest.raises(NotImplementedError):
        EP.execute(g, qry, n_workers=2)


# ------------------------------------------------------------ instrumented
def test_measure_supersteps_matches_dense(small_static_graph):
    g = small_static_graph
    inst = make_workload(g, templates=("Q2",), n_per_template=1, seed=31)[0]
    prof = EP.measure_supersteps(g, inst.qry, n_workers=4, repeats=1)
    want = E.count_results(g, inst.qry, sliced=False)
    assert prof.total == want
    n_hops = len(inst.qry.e_preds)
    assert prof.times_s.shape == (n_hops, 4)
    assert (prof.times_s > 0).all()          # measured, not modelled
    assert prof.makespan_s.shape == (n_hops,)
    assert 0 < prof.balance_eff <= 1.0
    assert (prof.exchange_msgs >= 0).all()


# ------------------------------------------------------------- shard_map
def test_partitioned_shard_map_multi_device():
    """The worker axis lowers to a real device mesh (4 forced host devices)."""
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np, jax
assert jax.device_count() == 4
from repro.core import engine as E
from repro.core import engine_partitioned as EP
from repro.graphdata.ldbc import LdbcParams, generate_ldbc
from repro.graphdata.queries import make_workload
g = generate_ldbc(LdbcParams(n_persons=40, seed=5, dynamic=True))
inst = make_workload(g, templates=("Q2",), n_per_template=1, seed=33)[0]
for mode in (E.MODE_STATIC, E.MODE_BUCKET):
    want = np.asarray(E.execute(g, inst.qry, mode=mode, n_buckets=8,
                                sliced=False).total)
    got = np.asarray(EP.execute(g, inst.qry, mode=mode, n_buckets=8,
                                n_workers=4, use_shard_map=True).total)
    assert np.array_equal(got, want), (mode, got, want)
print("PARTITIONED_SHARD_MAP_OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PARTITIONED_SHARD_MAP_OK" in out.stdout, out.stderr[-2000:]


# ------------------------------------------------------------- cost model
def test_planner_distribution_aware(medium_static_graph):
    """With a partitioning, plans pay a θ_net exchange term scaled by the
    partitioner's cut; distributed estimates stay finite and ordered."""
    from repro.core.planner import Planner
    from repro.core.stats import GraphStats

    g = medium_static_graph
    stats = GraphStats(g, n_time_buckets=16)
    part = partition_graph(g, n_workers=4, parts_per_type=4)
    coeffs = dict(theta0=0.1, theta_v=1e-5, theta_e=1e-5, theta_etr=1e-5,
                  theta_m=1e-5, theta_init=1e-5, theta_net=1e-4)
    single = Planner(g, stats, coeffs=coeffs)
    multi = Planner(g, stats, coeffs=coeffs, partitioning=part)
    assert multi.n_workers == 4 and 0.0 < multi.cut_frac < 1.0
    # structural exchange volumes in the executor's units (halo ghosts / 2E)
    assert 0 < multi.exchange_volume
    assert multi.frontier_volume == 2 * g.n_edges
    wl = make_workload(g, templates=("Q2", "Q4"), n_per_template=1, seed=3)
    for inst in wl:
        for split in single.enumerate_plans(inst.qry):
            e1 = single.estimate(inst.qry, split)
            e4 = multi.estimate(inst.qry, split)
            assert np.isfinite(e4.t_ms) and e4.t_ms > 0
            # exchange volume recorded on the distributed steps only
            assert all(s.m_net == 0.0 for s in e1.steps)
        # the distributed planner still returns a valid best plan
        best = multi.choose(inst.qry)
        assert best.split in single.enumerate_plans(inst.qry)
